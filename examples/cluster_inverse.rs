//! End-to-end system driver (the DESIGN.md §5 validation run):
//!
//! * paper-shaped simulated cluster (3 nodes × 2 executors × 5 cores),
//! * 1024×1024 diagonally-dominant matrix, b = 8,
//! * **XLA backend**: every block kernel is an AOT-lowered JAX/Pallas
//!   program executed through the PJRT CPU client (falls back to native
//!   kernels with a notice if `make artifacts` hasn't been run),
//! * SPIN vs the LU baseline through the algorithm registry, per-method
//!   breakdown, residual check.
//!
//! Run: `make artifacts && cargo run --release --example cluster_inverse`
//! Recorded in EXPERIMENTS.md §End-to-end.

use spin::config::{BackendKind, LeafMethod};
use spin::session::{SessionBuilder, SpinSession};
use spin::util::fmt;

fn builder() -> SessionBuilder {
    SpinSession::builder()
        .paper_cluster()
        .leaf(LeafMethod::GaussJordan) // matches the Pallas leaf kernel
        .seed(2018)
}

fn main() -> spin::Result<()> {
    spin::util::logger::init();

    // Prefer the XLA backend; fall back to native with a notice. The
    // builder instantiates the backend, so a missing `make artifacts`
    // fails here — not mid-job.
    let session = match builder().backend(BackendKind::Xla).build() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("XLA backend unavailable ({e}); falling back to native kernels");
            builder().backend(BackendKind::Native).build()?
        }
    };

    let (n, block) = (1024usize, 128usize); // b = 8
    println!(
        "cluster: {} nodes × {} executors × {} cores — backend {}",
        session.config().nodes,
        session.config().executors_per_node,
        session.config().cores_per_executor,
        session.backend_name()
    );
    println!("job: n = {n}, block {block}×{block}, b = {}\n", n / block);

    let a = session.random(n, block)?;

    let mut summary: Vec<(String, f64, f64)> = Vec::new();
    for algo in ["spin", "lu"] {
        session.reset_clock(); // fresh measurement window per algorithm
        let t0 = std::time::Instant::now();
        let inv = a.inverse_with(algo)?; // lazy plan…
        inv.collect()?; // …materialized here, inside the timed window
        let real = t0.elapsed().as_secs_f64();
        let resid = a.inverse_residual(&inv)?;
        println!(
            "== {algo} ==\nvirtual wall clock: {}   host compute: {}   residual {resid:.3e}",
            fmt::secs(session.virtual_secs()),
            fmt::secs(real),
        );
        println!("{}", session.metrics().render_table());
        assert!(resid < 1e-8, "{algo} residual too large: {resid}");
        summary.push((algo.to_string(), session.virtual_secs(), real));
    }

    let (spin_v, lu_v) = (summary[0].1, summary[1].1);
    println!(
        "SPIN vs LU (virtual): {} vs {} — SPIN is {:.2}x faster",
        fmt::secs(spin_v),
        fmt::secs(lu_v),
        lu_v / spin_v
    );
    assert!(spin_v < lu_v, "paper headline violated: SPIN not faster");

    if session.backend_name() == "xla" {
        println!("(block kernels executed via PJRT CPU client from AOT JAX/Pallas HLO)");
    }
    println!("cluster_inverse OK");
    Ok(())
}
