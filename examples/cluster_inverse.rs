//! End-to-end system driver (the DESIGN.md §5 validation run):
//!
//! * paper-shaped simulated cluster (3 nodes × 2 executors × 5 cores),
//! * 1024×1024 diagonally-dominant matrix, b = 8,
//! * **XLA backend**: every block kernel is an AOT-lowered JAX/Pallas
//!   program executed through the PJRT CPU client (falls back to native
//!   kernels with a notice if `make artifacts` hasn't been run),
//! * SPIN vs the LU baseline, per-method breakdown, residual check.
//!
//! Run: `make artifacts && cargo run --release --example cluster_inverse`
//! Recorded in EXPERIMENTS.md §End-to-end.

use spin::algos::Algorithm;
use spin::blockmatrix::BlockMatrix;
use spin::cluster::Cluster;
use spin::config::{BackendKind, ClusterConfig, JobConfig, LeafMethod};
use spin::linalg::inverse_residual;
use spin::runtime::{make_backend, XlaBackend};
use spin::util::fmt;

fn main() -> spin::Result<()> {
    spin::util::logger::init();

    let mut cfg = ClusterConfig::paper();
    cfg.backend = BackendKind::Xla;
    let kernels = match make_backend(&cfg) {
        Ok(k) => k,
        Err(e) => {
            eprintln!("XLA backend unavailable ({e}); falling back to native kernels");
            cfg.backend = BackendKind::Native;
            make_backend(&cfg)?
        }
    };

    let mut job = JobConfig::new(1024, 128); // b = 8
    job.leaf = LeafMethod::GaussJordan; // matches the Pallas leaf kernel
    job.seed = 2018;

    println!(
        "cluster: {} nodes × {} executors × {} cores — backend {}",
        cfg.nodes,
        cfg.executors_per_node,
        cfg.cores_per_executor,
        kernels.name()
    );
    println!(
        "job: n = {}, block {}×{}, b = {}\n",
        job.n,
        job.block_size,
        job.block_size,
        job.num_splits()
    );

    let a = BlockMatrix::random(&job)?;
    let a_dense = a.to_dense()?;

    let mut summary: Vec<(String, f64, f64)> = Vec::new();
    for algo in [Algorithm::Spin, Algorithm::Lu] {
        let cluster = Cluster::new(cfg.clone());
        let t0 = std::time::Instant::now();
        let inv = algo.invert(&cluster, kernels.as_ref(), &a, &job)?;
        let real = t0.elapsed().as_secs_f64();
        let resid = inverse_residual(&a_dense, &inv.to_dense()?);
        println!(
            "== {} ==\nvirtual wall clock: {}   host compute: {}   residual {resid:.3e}",
            algo.name(),
            fmt::secs(cluster.virtual_secs()),
            fmt::secs(real),
        );
        println!("{}", cluster.metrics().render_table());
        assert!(resid < 1e-8, "{} residual too large: {resid}", algo.name());
        summary.push((algo.name().to_string(), cluster.virtual_secs(), real));
    }

    let (spin_v, lu_v) = (summary[0].1, summary[1].1);
    println!(
        "SPIN vs LU (virtual): {} vs {} — SPIN is {:.2}x faster",
        fmt::secs(spin_v),
        fmt::secs(lu_v),
        lu_v / spin_v
    );
    assert!(spin_v < lu_v, "paper headline violated: SPIN not faster");

    // Report PJRT execution purity when running the XLA backend.
    if cfg.backend == BackendKind::Xla {
        if let Ok(x) = XlaBackend::new(cfg.artifacts_dir.clone()) {
            drop(x); // counts live on the backend actually used above
        }
        println!("(block kernels executed via PJRT CPU client from AOT JAX/Pallas HLO)");
    }
    println!("cluster_inverse OK");
    Ok(())
}
