//! ML-pipeline workload: precision-matrix computation and Mahalanobis
//! scoring over a feature covariance.
//!
//! Draw samples from a correlated Gaussian-ish model, estimate the feature
//! covariance Σ, invert it **distributedly with SPIN** through the session
//! API to get the precision matrix P = Σ⁻¹, then use P for Mahalanobis
//! distances — inliers drawn from the model must score lower than planted
//! outliers, and the P-whitened covariance must be ≈ identity
//! (`Σ·P ≈ I` checked too).
//!
//! Run: `cargo run --release --example covariance_whitening`

use spin::linalg::{matmul, Matrix};
use spin::session::SpinSession;
use spin::util::Rng;

fn mahalanobis2(p: &Matrix, x: &[f64], mu: &[f64]) -> f64 {
    let d = x.len();
    let diff = Matrix::from_fn(d, 1, |i, _| x[i] - mu[i]);
    matmul(&matmul(&diff.transpose(), p), &diff).get(0, 0)
}

fn main() -> spin::Result<()> {
    spin::util::logger::init();
    let dim = 256usize; // features (power of two for the block recursion)
    let samples = 2048usize;
    let block = 32usize;
    let mut rng = Rng::new(0xC01);

    // --- correlated data: x = A·z with a banded mixing matrix.
    let mixing = Matrix::from_fn(dim, dim, |i, j| {
        if i == j {
            1.0
        } else if i.abs_diff(j) <= 3 {
            0.35 / (1 + i.abs_diff(j)) as f64
        } else {
            0.0
        }
    });
    let mut data = Matrix::zeros(samples, dim);
    for s in 0..samples {
        let z = Matrix::from_fn(dim, 1, |_, _| rng.normal());
        let x = matmul(&mixing, &z);
        for f in 0..dim {
            data.set(s, f, x.get(f, 0));
        }
    }

    // --- empirical covariance (+ small ridge to keep it comfortably SPD).
    let mut mu = vec![0.0f64; dim];
    for f in 0..dim {
        for s in 0..samples {
            mu[f] += data.get(s, f);
        }
        mu[f] /= samples as f64;
    }
    let mut sigma = Matrix::zeros(dim, dim);
    for s in 0..samples {
        for i in 0..dim {
            let di = data.get(s, i) - mu[i];
            for j in i..dim {
                let dj = data.get(s, j) - mu[j];
                sigma.add_assign_at(i, j, di * dj);
            }
        }
    }
    for i in 0..dim {
        for j in i..dim {
            let v = sigma.get(i, j) / (samples - 1) as f64;
            sigma.set(i, j, v);
            sigma.set(j, i, v);
        }
        sigma.add_assign_at(i, i, 1e-3);
    }

    // --- distributed inversion: P = Σ⁻¹ via the session (SPIN default).
    let session = SpinSession::builder().paper_cluster().build()?;
    let sigma_b = session.from_dense(&sigma, block)?;
    let p_b = sigma_b.inverse()?;
    let p = p_b.to_dense()?;
    let resid = sigma_b.inverse_residual(&p_b)?;
    println!(
        "Σ ({dim}x{dim}, b = {}) inverted with SPIN: residual {resid:.3e}, virtual {:.1} ms",
        sigma_b.nblocks(),
        session.virtual_secs() * 1e3
    );
    assert!(resid < 1e-8);

    // --- whitening sanity: Σ·P ≈ I.
    let eye_err = matmul(&sigma, &p).max_abs_diff(&Matrix::identity(dim));
    println!("‖Σ·P − I‖∞ = {eye_err:.3e}");
    assert!(eye_err < 1e-6);

    // --- Mahalanobis outlier scoring.
    let inlier_scores: Vec<f64> = (0..16)
        .map(|s| {
            let x: Vec<f64> = (0..dim).map(|f| data.get(s, f)).collect();
            mahalanobis2(&p, &x, &mu)
        })
        .collect();
    let outlier_scores: Vec<f64> = (0..16)
        .map(|i| {
            // planted outlier: shift 8 features by 6σ-ish.
            let s = i * 7 % samples;
            let mut x: Vec<f64> = (0..dim).map(|f| data.get(s, f)).collect();
            for f in 0..8 {
                x[(f * 31 + i) % dim] += 6.0;
            }
            mahalanobis2(&p, &x, &mu)
        })
        .collect();
    let in_mean = inlier_scores.iter().sum::<f64>() / inlier_scores.len() as f64;
    let out_mean = outlier_scores.iter().sum::<f64>() / outlier_scores.len() as f64;
    println!("mean Mahalanobis²: inliers {in_mean:.1}, planted outliers {out_mean:.1}");
    assert!(
        out_mean > 2.0 * in_mean,
        "outliers should score far above inliers"
    );
    println!("covariance_whitening OK");
    Ok(())
}
