//! Job service: the multi-tenant front door. Three tenants submit four
//! workloads at once; the fair-share scheduler spreads worker time
//! across them, the cross-job plan cache shares the common inversion,
//! and an LRU byte budget bounds the resident value set.
//!
//! Run: `cargo run --release --example job_service`

use spin::config::ClusterConfig;
use spin::service::{JobSpec, MatrixSpec, SpinService};
use spin::session::SpinSession;

fn main() -> spin::Result<()> {
    spin::util::logger::init();

    // A 4-slot cluster with a 256 KiB value budget: intermediates beyond
    // that are LRU-evicted and recompute on demand.
    let mut cfg = ClusterConfig::local(4);
    cfg.cache_budget_bytes = 256 * 1024;
    let service = SpinService::builder()
        .session_builder(SpinSession::builder().cluster_config(cfg))
        .workers(2)
        .queue_capacity(16)
        .build()?;

    // One shared 128x128 SPD matrix, described by parameters — equal
    // descriptions intern to ONE lazy plan leaf, so jobs share it, and
    // submit() is O(1): not a single block exists until a worker
    // materializes the first job (generation then runs per-partition on
    // the workers, bit-identical to eager generation of the same spec).
    let a = MatrixSpec::new(128, 16).seeded(7).spd();
    let rhs = MatrixSpec::new(128, 16).seeded(8);

    let jobs = vec![
        JobSpec::invert(a.clone()).tenant("alice").label("A-inverse"),
        JobSpec::solve(a.clone(), rhs.clone()).tenant("bob").label("gls"),
        JobSpec::pseudo_inverse(a.clone()).tenant("carol").label("pinv"),
        JobSpec::invert(a.clone()).tenant("alice").label("again").algorithm("lu"),
    ];
    let handles: Vec<_> = jobs
        .into_iter()
        .map(|spec| service.submit(spec))
        .collect::<spin::Result<_>>()?;

    // The solve's plan, with fusion, CSE caches and cache decisions.
    println!("{}", handles[1].explain()?);

    for handle in &handles {
        let out = handle.wait()?;
        println!(
            "job {:>2} [{}] {:<10} {:<9} exchanges: {:<3} residual: {}",
            handle.id(),
            handle.spec().tenant,
            handle.spec().label,
            handle.spec().kind.name(),
            out.metrics.total_shuffle_stages(),
            out.residual
                .map(|r| format!("{r:.2e}"))
                .unwrap_or_else(|| "-".to_string()),
        );
    }

    let plans = service.plan_cache_stats();
    let values = service.cache_stats();
    println!(
        "\nplan cache: {} node(s), {} hit(s) · resident values: {} KiB · evictions: {}",
        plans.entries,
        plans.hits,
        values.resident_bytes / 1024,
        values.evictions,
    );
    // alice's two inversions plus bob's solve all read matrix A — the
    // spin inversion ran once (bob reused it), and the leaf count proves
    // it stayed shared even under the byte budget.
    println!("total leaf inversions: {}",
        service
            .metrics()
            .method("leafNode")
            .map(|s| s.calls)
            .unwrap_or(0));
    // Finished jobs release their metric scopes (outcome snapshots keep
    // the per-job view), so a serve loop holds steady-state memory.
    let retention = service.metrics();
    println!(
        "metrics retention: {} record(s) retained, {} released over {} finished job(s)",
        retention.retained_stage_records(),
        retention.released_stage_records(),
        retention.released_scopes(),
    );
    println!("job_service OK");
    Ok(())
}
