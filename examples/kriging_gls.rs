//! Earth-science workload (the paper's intro motivation): generalized
//! least squares over a spatial covariance.
//!
//! We sample station locations on a unit square, build an exponential
//! covariance matrix `K[i][j] = σ²·exp(−‖xᵢ−xⱼ‖/ℓ) + τ²·δᵢⱼ` (SPD), invert
//! it **distributedly with SPIN**, and solve the GLS problem
//! `β̂ = (Xᵀ K⁻¹ X)⁻¹ Xᵀ K⁻¹ y` for a linear spatial trend — recovering the
//! known coefficients from noisy observations.
//!
//! Run: `cargo run --release --example kriging_gls`

use spin::algos::spin_inverse;
use spin::blockmatrix::BlockMatrix;
use spin::cluster::Cluster;
use spin::config::{ClusterConfig, JobConfig};
use spin::linalg::{inverse_residual, lu_inverse, matmul, Matrix};
use spin::runtime::NativeBackend;
use spin::util::Rng;

fn main() -> spin::Result<()> {
    spin::util::logger::init();
    let n = 512usize; // stations (power of two for the block recursion)
    let block = 64usize;
    let mut rng = Rng::new(0x6E0);

    // --- station coordinates and spatial covariance.
    let xs: Vec<(f64, f64)> = (0..n)
        .map(|_| (rng.next_f64(), rng.next_f64()))
        .collect();
    let (sigma2, ell, nugget) = (1.0, 0.3, 0.05);
    let k = Matrix::from_fn(n, n, |i, j| {
        let (xi, yi) = xs[i];
        let (xj, yj) = xs[j];
        let d = ((xi - xj).powi(2) + (yi - yj).powi(2)).sqrt();
        sigma2 * (-d / ell).exp() + if i == j { nugget } else { 0.0 }
    });

    // --- design matrix [1, x, y] and observations with a known trend.
    let beta_true = [2.0, -1.5, 0.75];
    let x = Matrix::from_fn(n, 3, |i, j| match j {
        0 => 1.0,
        1 => xs[i].0,
        _ => xs[i].1,
    });
    // y = X·β + correlated noise (scaled rows of K act as a cheap stand-in
    // for a correlated draw; the point is exercising the GLS pipeline).
    let y = Matrix::from_fn(n, 1, |i, _| {
        beta_true[0] + beta_true[1] * xs[i].0 + beta_true[2] * xs[i].1
            + 0.01 * (k.get(i, (i + 1) % n) - k.get(i, (i + 7) % n))
    });

    // --- distributed inversion of K with SPIN.
    let cluster = Cluster::new(ClusterConfig::paper());
    let job = JobConfig::new(n, block);
    let kb = BlockMatrix::from_dense(&k, block)?;
    let kinv_b = spin_inverse(&cluster, &NativeBackend, &kb, &job)?;
    let kinv = kinv_b.to_dense()?;
    let resid = inverse_residual(&k, &kinv);
    println!(
        "K ({n}x{n}, b = {}) inverted with SPIN: residual {resid:.3e}, virtual {:.1} ms",
        job.num_splits(),
        cluster.virtual_secs() * 1e3
    );
    assert!(resid < 1e-8);

    // --- GLS solve (driver-side small algebra).
    let xt_kinv = matmul(&x.transpose(), &kinv); // 3×n
    let normal = matmul(&xt_kinv, &x); // 3×3
    let rhs = matmul(&xt_kinv, &y); // 3×1
    let beta_hat = matmul(&lu_inverse(&normal)?, &rhs);

    println!("\nGLS estimates (true → estimated):");
    let names = ["intercept", "x-slope", "y-slope"];
    for (i, name) in names.iter().enumerate() {
        println!(
            "  {name:>9}: {:+.4} → {:+.4}",
            beta_true[i],
            beta_hat.get(i, 0)
        );
        assert!(
            (beta_hat.get(i, 0) - beta_true[i]).abs() < 0.05,
            "GLS failed to recover {name}"
        );
    }
    println!("kriging_gls OK");
    Ok(())
}
