//! Earth-science workload (the paper's intro motivation): generalized
//! least squares over a spatial covariance.
//!
//! We sample station locations on a unit square, build an exponential
//! covariance matrix `K[i][j] = σ²·exp(−‖xᵢ−xⱼ‖/ℓ) + τ²·δᵢⱼ` (SPD), and
//! solve the GLS problem `β̂ = (Xᵀ K⁻¹ X)⁻¹ Xᵀ K⁻¹ y` for a linear spatial
//! trend — recovering the known coefficients from noisy observations.
//!
//! The heavy step — K⁻¹ applied to the design matrix — is one call:
//! `k.solve_dense(&x)` runs the SPIN inversion distributedly on the
//! session's cluster and finishes with a thin driver-side product.
//!
//! Run: `cargo run --release --example kriging_gls`

use spin::linalg::{lu_inverse, matmul, Matrix};
use spin::session::SpinSession;
use spin::util::Rng;

fn main() -> spin::Result<()> {
    spin::util::logger::init();
    let n = 512usize; // stations (power of two for the block recursion)
    let block = 64usize;
    let mut rng = Rng::new(0x6E0);

    // --- station coordinates and spatial covariance.
    let xs: Vec<(f64, f64)> = (0..n)
        .map(|_| (rng.next_f64(), rng.next_f64()))
        .collect();
    let (sigma2, ell, nugget) = (1.0, 0.3, 0.05);
    let k = Matrix::from_fn(n, n, |i, j| {
        let (xi, yi) = xs[i];
        let (xj, yj) = xs[j];
        let d = ((xi - xj).powi(2) + (yi - yj).powi(2)).sqrt();
        sigma2 * (-d / ell).exp() + if i == j { nugget } else { 0.0 }
    });

    // --- design matrix [1, x, y] and observations with a known trend.
    let beta_true = [2.0, -1.5, 0.75];
    let x = Matrix::from_fn(n, 3, |i, j| match j {
        0 => 1.0,
        1 => xs[i].0,
        _ => xs[i].1,
    });
    // y = X·β + correlated noise (scaled rows of K act as a cheap stand-in
    // for a correlated draw; the point is exercising the GLS pipeline).
    let y = Matrix::from_fn(n, 1, |i, _| {
        beta_true[0] + beta_true[1] * xs[i].0 + beta_true[2] * xs[i].1
            + 0.01 * (k.get(i, (i + 1) % n) - k.get(i, (i + 7) % n))
    });

    // --- session on the paper's cluster topology; K lives distributed.
    let session = SpinSession::builder().paper_cluster().build()?;
    let kb = session.from_dense(&k, block)?;

    // K⁻¹·[X | y] in one shot via the session solver (one distributed SPIN
    // inversion, thin driver-side product).
    let xy = Matrix::from_fn(n, 4, |i, j| if j < 3 { x.get(i, j) } else { y.get(i, 0) });
    let kinv_xy = kb.solve_dense(&xy)?; // n×4
    let kinv_x = Matrix::from_fn(n, 3, |i, j| kinv_xy.get(i, j));
    let kinv_y = Matrix::from_fn(n, 1, |i, _| kinv_xy.get(i, 3));
    println!(
        "K ({n}x{n}, b = {}) solved with SPIN: virtual {:.1} ms",
        kb.nblocks(),
        session.virtual_secs() * 1e3
    );

    // --- GLS solve (driver-side small algebra).
    let normal = matmul(&x.transpose(), &kinv_x); // 3×3
    let rhs = matmul(&x.transpose(), &kinv_y); // 3×1
    let beta_hat = matmul(&lu_inverse(&normal)?, &rhs);

    println!("\nGLS estimates (true → estimated):");
    let names = ["intercept", "x-slope", "y-slope"];
    for (i, name) in names.iter().enumerate() {
        println!(
            "  {name:>9}: {:+.4} → {:+.4}",
            beta_true[i],
            beta_hat.get(i, 0)
        );
        assert!(
            (beta_hat.get(i, 0) - beta_true[i]).abs() < 0.05,
            "GLS failed to recover {name}"
        );
    }
    println!("kriging_gls OK");
    Ok(())
}
