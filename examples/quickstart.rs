//! Quickstart: the smallest end-to-end path through the public API —
//! build a cluster, generate a distributed SPD matrix, invert it with
//! SPIN, verify the residual.
//!
//! Run: `cargo run --release --example quickstart`

use spin::algos::spin_inverse;
use spin::blockmatrix::BlockMatrix;
use spin::cluster::Cluster;
use spin::config::{ClusterConfig, GeneratorKind, JobConfig};
use spin::linalg::inverse_residual;
use spin::runtime::NativeBackend;

fn main() -> spin::Result<()> {
    spin::util::logger::init();

    // A local 4-slot "cluster" with the native (pure-Rust) block kernels.
    let cluster = Cluster::new(ClusterConfig::local(4));

    // 256x256 SPD matrix split into a 4x4 grid of 64x64 blocks.
    let mut job = JobConfig::new(256, 64);
    job.generator = GeneratorKind::Spd;
    job.seed = 7;
    let a = BlockMatrix::random(&job)?;

    // Invert with the SPIN recursion (Algorithm 2).
    let inv = spin_inverse(&cluster, &NativeBackend, &a, &job)?;

    // Check ‖A·A⁻¹ − I‖.
    let resid = inverse_residual(&a.to_dense()?, &inv.to_dense()?);
    println!(
        "inverted {0}x{0} (b = {1}): residual = {resid:.3e}, virtual wall clock = {2:.1} ms",
        job.n,
        job.num_splits(),
        cluster.virtual_secs() * 1e3,
    );
    println!("\nper-method breakdown:\n{}", cluster.metrics().render_table());
    assert!(resid < 1e-10);
    println!("quickstart OK");
    Ok(())
}
