//! Quickstart: the smallest end-to-end path through the public API —
//! build a session, generate a distributed SPD matrix, invert it with
//! SPIN, verify the residual. No `Cluster` / `BlockKernels` plumbing:
//! the session owns all of it.
//!
//! Run: `cargo run --release --example quickstart`

use spin::session::SpinSession;

fn main() -> spin::Result<()> {
    spin::util::logger::init();

    // A local 4-slot "cluster" with the native (pure-Rust) block kernels.
    let session = SpinSession::builder().cores(4).seed(7).build()?;

    // 256x256 SPD matrix split into a 4x4 grid of 64x64 blocks.
    let a = session.random_spd(256, 64)?;

    // Invert with the SPIN recursion (Algorithm 2) — the session default.
    let inv = a.inverse()?;

    // Check ‖A·A⁻¹ − I‖.
    let resid = a.inverse_residual(&inv)?;
    println!(
        "inverted {0}x{0} (b = {1}): residual = {resid:.3e}, virtual wall clock = {2:.1} ms",
        a.n(),
        a.nblocks(),
        session.virtual_secs() * 1e3,
    );
    println!("\nper-method breakdown:\n{}", session.metrics().render_table());
    assert!(resid < 1e-10);

    // Any registered algorithm resolves by name — here the LU baseline.
    let lu = session.invert_with("lu", &a)?;
    assert!(a.inverse_residual(&lu)? < 1e-10);
    println!("registered algorithms: {}", session.algorithms().join(", "));
    println!("quickstart OK");
    Ok(())
}
