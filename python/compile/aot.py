"""AOT compile path: lower every L2 op at every block size to HLO text.

Runs ONCE at build time (``make artifacts``); the Rust coordinator loads the
results through PJRT and Python never appears on the request path again.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.

Outputs::

    artifacts/<op>_b<block_size>.hlo.txt   one XLA program per (op, size)
    artifacts/manifest.json                index the Rust runtime loads

Usage::

    python -m compile.aot --out ../artifacts [--block-sizes 16,32,64]
                          [--ops matmul,leaf_inverse] [--check]
"""

import argparse
import json
import os
import sys
import time

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from compile import model

DEFAULT_BLOCK_SIZES = (16, 32, 64, 128, 256)
DTYPE = "float64"

MANIFEST_VERSION = 2


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_op(op_name: str, block_size: int) -> str:
    fn, n_blocks, n_scalars = model.OPS[op_name]
    dtype = jnp.dtype(DTYPE)
    block = jax.ShapeDtypeStruct((block_size, block_size), dtype)
    scalar = jax.ShapeDtypeStruct((), dtype)
    specs = [block] * n_blocks + [scalar] * n_scalars
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def n_outputs(op_name: str) -> int:
    return {"strassen_2x2": 4, "lu_factor": 2}.get(op_name, 1)


def build(out_dir: str, block_sizes, ops, check: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for op_name in ops:
        fn, n_blocks, n_scalars = model.OPS[op_name]
        for bs in block_sizes:
            t0 = time.time()
            hlo = lower_op(op_name, bs)
            fname = f"{op_name}_b{bs}.hlo.txt"
            path = os.path.join(out_dir, fname)
            with open(path, "w") as f:
                f.write(hlo)
            if check:
                _check_artifact(hlo, op_name, bs)
            entries.append(
                {
                    "op": op_name,
                    "block_size": bs,
                    "file": fname,
                    "num_block_inputs": n_blocks,
                    "num_scalar_inputs": n_scalars,
                    "num_outputs": n_outputs(op_name),
                    "dtype": DTYPE,
                }
            )
            print(
                f"  lowered {op_name:>16} b={bs:<4} "
                f"({len(hlo) / 1024:.0f} KiB, {time.time() - t0:.2f}s)",
                file=sys.stderr,
            )
    manifest = {
        "version": MANIFEST_VERSION,
        "dtype": DTYPE,
        "block_sizes": list(block_sizes),
        "entries": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def _check_artifact(hlo: str, op_name: str, bs: int) -> None:
    """Sanity constraints every artifact must satisfy for the CPU PJRT path."""
    if "ENTRY" not in hlo:
        raise RuntimeError(f"{op_name} b={bs}: HLO text has no ENTRY computation")
    if "custom-call" in hlo:
        # interpret=True must have lowered Pallas to plain HLO; a Mosaic
        # custom-call would be unloadable on the CPU client.
        raise RuntimeError(f"{op_name} b={bs}: unexpected custom-call in HLO")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--block-sizes",
        default=",".join(str(b) for b in DEFAULT_BLOCK_SIZES),
        help="comma-separated block sizes to lower",
    )
    ap.add_argument(
        "--ops",
        default=",".join(model.OPS),
        help="comma-separated op subset (default: all)",
    )
    ap.add_argument("--check", action="store_true", help="validate artifacts")
    args = ap.parse_args()

    block_sizes = [int(b) for b in args.block_sizes.split(",") if b]
    ops = [o for o in args.ops.split(",") if o]
    unknown = [o for o in ops if o not in model.OPS]
    if unknown:
        ap.error(f"unknown ops: {unknown}; available: {list(model.OPS)}")

    t0 = time.time()
    manifest = build(args.out, block_sizes, ops, check=args.check)
    print(
        f"wrote {len(manifest['entries'])} artifacts + manifest.json "
        f"to {args.out} in {time.time() - t0:.1f}s",
        file=sys.stderr,
    )


if __name__ == "__main__":
    main()
