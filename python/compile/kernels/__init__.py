"""Layer-1 Pallas kernels for SPIN's block algebra.

Every kernel here is the TPU-shaped rethink of what the paper delegated to
JBlas on a Spark executor: one Spark block-task = one Pallas grid program.
Kernels are lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); correctness is asserted against the pure-jnp oracles in
:mod:`ref` by the pytest suite.
"""

from compile.kernels.matmul import matmul, matmul_acc, neg_matmul_sub
from compile.kernels.gauss_jordan import gauss_jordan_inverse
from compile.kernels.elementwise import subtract, scale, axpy, negate
from compile.kernels.triangular import lu_factor, invert_lower, invert_upper

__all__ = [
    "matmul",
    "matmul_acc",
    "neg_matmul_sub",
    "gauss_jordan_inverse",
    "subtract",
    "scale",
    "axpy",
    "negate",
    "lu_factor",
    "invert_lower",
    "invert_upper",
]
