"""Tiled elementwise Pallas kernels: SPIN's ``subtract`` and ``scalarMul``.

These are bandwidth-bound; the grid tiles the block so each step streams one
VMEM-resident tile (HBM→VMEM→HBM), the TPU analogue of the paper's per-block
``map`` transformation.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

DEFAULT_TILE = 256


def _pick_tile(dim: int, tile: int) -> int:
    t = min(dim, tile)
    while dim % t != 0:
        t -= 1
    return t


def _tiled(kernel, n_in, shape, dtype, *args, tile):
    m, n = shape
    tm, tn = _pick_tile(m, tile), _pick_tile(n, tile)
    spec = pl.BlockSpec((tm, tn), lambda mi, ni: (mi, ni))
    return pl.pallas_call(
        kernel,
        grid=(m // tm, n // tn),
        in_specs=[spec] * n_in,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), dtype),
        interpret=True,
    )(*args)


def _subtract_kernel(x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] - y_ref[...]


def _scale_kernel(s_ref, x_ref, o_ref):
    o_ref[...] = x_ref[...] * s_ref[0, 0]


def _axpy_kernel(s_ref, x_ref, y_ref, o_ref):
    o_ref[...] = x_ref[...] * s_ref[0, 0] + y_ref[...]


def _negate_kernel(x_ref, o_ref):
    o_ref[...] = -x_ref[...]


def _tiled_with_scalar(kernel, n_mat, shape, dtype, s, *mats, tile):
    """Like :func:`_tiled` but with a leading (1,1) scalar operand that every
    grid step maps to the same block (the Pallas idiom for SMEM scalars)."""
    m, n = shape
    tm, tn = _pick_tile(m, tile), _pick_tile(n, tile)
    spec = pl.BlockSpec((tm, tn), lambda mi, ni: (mi, ni))
    s_spec = pl.BlockSpec((1, 1), lambda mi, ni: (0, 0))
    return pl.pallas_call(
        kernel,
        grid=(m // tm, n // tn),
        in_specs=[s_spec] + [spec] * n_mat,
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((m, n), dtype),
        interpret=True,
    )(s, *mats)


@functools.partial(jax.jit, static_argnames=("tile",))
def subtract(x, y, *, tile: int = DEFAULT_TILE):
    """C = X - Y (paper's ``subtract`` method at block granularity)."""
    if x.shape != y.shape:
        raise ValueError(f"subtract shape mismatch: {x.shape} vs {y.shape}")
    return _tiled(_subtract_kernel, 2, x.shape, x.dtype, x, y, tile=tile)


@functools.partial(jax.jit, static_argnames=("tile",))
def scale(x, s, *, tile: int = DEFAULT_TILE):
    """C = s·X (paper's ``scalarMul``).  ``s`` is traced as a (1,1) operand."""
    s = jnp.asarray(s, dtype=x.dtype).reshape(1, 1)
    return _tiled_with_scalar(_scale_kernel, 1, x.shape, x.dtype, s, x, tile=tile)


@functools.partial(jax.jit, static_argnames=("tile",))
def axpy(x, y, s, *, tile: int = DEFAULT_TILE):
    """C = s·X + Y."""
    if x.shape != y.shape:
        raise ValueError(f"axpy shape mismatch: {x.shape} vs {y.shape}")
    s = jnp.asarray(s, dtype=x.dtype).reshape(1, 1)
    return _tiled_with_scalar(_axpy_kernel, 2, x.shape, x.dtype, s, x, y, tile=tile)


@functools.partial(jax.jit, static_argnames=("tile",))
def negate(x, *, tile: int = DEFAULT_TILE):
    """C = -X (SPIN's C22 = -VI)."""
    return _tiled(_negate_kernel, 1, x.shape, x.dtype, x, tile=tile)
