"""Leaf-block inversion as a single Pallas program.

SPIN inverts leaf blocks "in any approach (e.g., LU, QR, SVD)" serially on
one executor.  Here the leaf inversion is one Pallas kernel: Gauss-Jordan
elimination with scaled partial pivoting over the augmented system [A | I],
expressed as a ``fori_loop`` over pivot columns.  The whole block lives in
VMEM for the duration (2·bs²·8 bytes: bs=256 f64 → 1 MiB ≪ VMEM), which is
exactly the paper's leaf regime — a block small enough for one worker.

Pivoting uses whole-row ``where`` swaps rather than scatter so every step is
a dense vector op (TPU-friendly; no dynamic row indexing on the lane axis).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _gj_body(k, aug):
    """One pivot step of Gauss-Jordan on the augmented [A | I] matrix."""
    n = aug.shape[0]
    rows = jax.lax.iota(jnp.int32, n)

    # --- scaled partial pivot: argmax |aug[i, k]| over i >= k.
    col = jnp.abs(aug[:, k])
    col = jnp.where(rows >= k, col, -jnp.inf)
    p = jnp.argmax(col)

    # --- swap rows k and p with a dense select (no scatter).
    row_k = aug[k, :]
    row_p = aug[p, :]
    is_k = (rows == k)[:, None]
    is_p = (rows == p)[:, None]
    aug = jnp.where(is_k, row_p[None, :], aug)
    aug = jnp.where(is_p & ~is_k, row_k[None, :], aug)

    # --- normalise the pivot row.
    pivot = aug[k, k]
    norm_row = aug[k, :] / pivot

    # --- eliminate column k from every other row.
    factors = jnp.where(rows == k, 0.0, aug[:, k])
    aug = aug - factors[:, None] * norm_row[None, :]
    aug = jnp.where(is_k, norm_row[None, :], aug)
    return aug


def _gauss_jordan_kernel(a_ref, o_ref):
    a = a_ref[...]
    n = a.shape[0]
    eye = jnp.eye(n, dtype=a.dtype)
    aug = jnp.concatenate([a, eye], axis=1)
    aug = jax.lax.fori_loop(0, n, _gj_body, aug)
    o_ref[...] = aug[:, n:]


@jax.jit
def gauss_jordan_inverse(a):
    """A⁻¹ for a square block via in-VMEM Gauss-Jordan with partial pivoting."""
    n, n2 = a.shape
    if n != n2:
        raise ValueError(f"gauss_jordan_inverse needs a square block, got {a.shape}")
    return pl.pallas_call(
        _gauss_jordan_kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        interpret=True,
    )(a)
