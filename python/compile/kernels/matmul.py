"""Tiled Pallas matmul kernels — the paper's ``multiply`` hot-spot.

The paper's distributed ``multiply`` co-groups blocks onto an executor and
calls JBlas DGEMM per block pair.  Here the per-block GEMM is a Pallas grid
program: the grid iterates ``(mi, ni, ki)`` with ``ki`` innermost so the
output tile stays resident in VMEM and is revisited across the contraction —
the TPU analogue of a threadblock accumulating in shared memory/registers.

VMEM budget per grid step (f64): ``(tm*tk + tk*tn + tm*tn) * 8`` bytes; the
default 128³ tiles use 384 KiB, far under the ~16 MiB/core VMEM, leaving
headroom for double-buffered HBM→VMEM prefetch on real hardware.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)

# Default tile edge.  MXU-friendly (multiple of 8x128 lanes for f32; f64 is
# emulated on TPU, see DESIGN.md §Hardware-Adaptation) and small enough that
# three tiles + accumulator fit comfortably in VMEM.
DEFAULT_TILE = 128


def _pick_tile(dim: int, tile: int) -> int:
    """Largest divisor of ``dim`` that is <= ``tile`` (block sizes are powers
    of two throughout SPIN, so this normally returns ``min(dim, tile)``)."""
    t = min(dim, tile)
    while dim % t != 0:
        t -= 1
    return t


def _matmul_kernel(x_ref, y_ref, o_ref):
    """o[mi,ni] += x[mi,ki] @ y[ki,ni]; init on the first k step."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], y_ref[...], precision="highest")


def _matmul_acc_kernel(x_ref, y_ref, d_ref, o_ref):
    """o = d + x @ y (fused epilogue add; d is loaded on the first k step)."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = d_ref[...]

    o_ref[...] += jnp.dot(x_ref[...], y_ref[...], precision="highest")


def _neg_matmul_sub_kernel(x_ref, y_ref, d_ref, o_ref):
    """o = x @ y - d — SPIN's Schur-complement step ``V = IV - A22`` fused
    with the producing multiplication ``IV = A21 . III``."""
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        o_ref[...] = -d_ref[...]

    o_ref[...] += jnp.dot(x_ref[...], y_ref[...], precision="highest")


def _grid_call(kernel, n_in, x, y, *rest, tile):
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"matmul shape mismatch: {x.shape} @ {y.shape}")
    tm, tk, tn = _pick_tile(m, tile), _pick_tile(k, tile), _pick_tile(n, tile)
    grid = (m // tm, n // tn, k // tk)
    in_specs = [
        pl.BlockSpec((tm, tk), lambda mi, ni, ki: (mi, ki)),
        pl.BlockSpec((tk, tn), lambda mi, ni, ki: (ki, ni)),
    ]
    # Trailing operands (the fused addend) are tiled like the output.
    in_specs += [pl.BlockSpec((tm, tn), lambda mi, ni, ki: (mi, ni))] * (n_in - 2)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tm, tn), lambda mi, ni, ki: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y, *rest)


@functools.partial(jax.jit, static_argnames=("tile",))
def matmul(x, y, *, tile: int = DEFAULT_TILE):
    """C = X @ Y via the tiled Pallas kernel."""
    return _grid_call(_matmul_kernel, 2, x, y, tile=tile)


@functools.partial(jax.jit, static_argnames=("tile",))
def matmul_acc(x, y, d, *, tile: int = DEFAULT_TILE):
    """C = D + X @ Y (fused multiply-accumulate over whole blocks)."""
    return _grid_call(_matmul_acc_kernel, 3, x, y, d, tile=tile)


@functools.partial(jax.jit, static_argnames=("tile",))
def neg_matmul_sub(x, y, d, *, tile: int = DEFAULT_TILE):
    """C = X @ Y - D (SPIN step V = IV - A22 with IV fused in)."""
    return _grid_call(_neg_matmul_sub_kernel, 3, x, y, d, tile=tile)
