"""Pure-jnp oracles for the Pallas kernels — the correctness ground truth.

Each function mirrors one kernel in this package with straight-line jnp so
pytest can ``assert_allclose`` kernel-vs-oracle over shape/dtype sweeps.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)


def matmul(x, y):
    return jnp.matmul(x, y, precision="highest")


def matmul_acc(x, y, d):
    return d + jnp.matmul(x, y, precision="highest")


def neg_matmul_sub(x, y, d):
    return jnp.matmul(x, y, precision="highest") - d


def gauss_jordan_inverse(a):
    return jnp.linalg.inv(a)


def subtract(x, y):
    return x - y


def scale(x, s):
    return x * jnp.asarray(s, dtype=x.dtype)


def axpy(x, y, s):
    return x * jnp.asarray(s, dtype=x.dtype) + y


def negate(x):
    return -x


def lu_factor(a):
    """Pivot-free LU reference: plain-Python Doolittle elimination."""
    n = a.shape[0]
    lu = a
    for k in range(n):
        pivot = lu[k, k]
        rows = jnp.arange(n)
        factors = jnp.where(rows > k, lu[:, k] / pivot, 0.0)
        u_row = jnp.where(jnp.arange(n) >= k, lu[k, :], 0.0)
        eliminated = lu - factors[:, None] * u_row[None, :]
        col_k = jnp.where(rows > k, factors, lu[:, k])
        lu = jnp.where((jnp.arange(n) == k)[None, :], col_k[:, None], eliminated)
    l = jnp.tril(lu, -1) + jnp.eye(n, dtype=a.dtype)
    u = jnp.triu(lu)
    return l, u


def invert_lower(a):
    return jnp.linalg.inv(a)


def invert_upper(a):
    return jnp.linalg.inv(a)


def strassen_2x2_inverse(a11, a12, a21, a22):
    """One full Strassen inversion step over four leaf blocks (Algorithm 1).

    Reference for the fused L2 op: given the 2x2 block partition of A,
    return (C11, C12, C21, C22) of A⁻¹.
    """
    i = jnp.linalg.inv(a11)                       # I    = A11⁻¹
    ii = matmul(a21, i)                           # II   = A21·I
    iii = matmul(i, a12)                          # III  = I·A12
    iv = matmul(a21, iii)                         # IV   = A21·III
    v = iv - a22                                  # V    = IV − A22
    vi = jnp.linalg.inv(v)                        # VI   = V⁻¹
    c12 = matmul(iii, vi)                         # C12  = III·VI
    c21 = matmul(vi, ii)                          # C21  = VI·II
    vii = matmul(iii, c21)                        # VII  = III·C21
    c11 = i - vii                                 # C11  = I − VII
    c22 = -vi                                     # C22  = −VI
    return c11, c12, c21, c22
