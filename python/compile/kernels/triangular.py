"""Leaf kernels for the LU baseline (Liu et al. 2016): pivot-free LU
factorization and triangular inversion, as Pallas programs.

The block-recursive LU baseline cannot pivot across blocks, so its leaf
factorization is pivot-free (the workload generators guarantee nonsingular
principal minors).  Triangular inversion reuses the Gauss-Jordan elimination
structure without pivoting — for a triangular input the eliminations only
touch one side, so the inverse stays triangular in exact arithmetic.

These exist so the *baseline* pays the same PJRT execution path as SPIN in
the XLA backend — without them the comparison would hand LU free native
leaves (see DESIGN.md §3).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

jax.config.update("jax_enable_x64", True)


def _lu_body(k, lu):
    """One elimination step of pivot-free LU, keeping multipliers in the
    strictly-lower part (packed LU form)."""
    n = lu.shape[0]
    rows = jax.lax.iota(jnp.int32, n)
    cols = jax.lax.iota(jnp.int32, n)
    pivot = lu[k, k]
    factors = jnp.where(rows > k, lu[:, k] / pivot, 0.0)
    u_row = jnp.where(cols >= k, lu[k, :], 0.0)
    eliminated = lu - factors[:, None] * u_row[None, :]
    # Restore the multipliers into column k (the update zeroed them).
    col_k = jnp.where(rows > k, factors, lu[:, k])
    return jnp.where((cols == k)[None, :], col_k[:, None], eliminated)


def _lu_factor_kernel(a_ref, l_ref, u_ref):
    a = a_ref[...]
    n = a.shape[0]
    lu = jax.lax.fori_loop(0, n, _lu_body, a)
    rows = jax.lax.iota(jnp.int32, n)[:, None]
    cols = jax.lax.iota(jnp.int32, n)[None, :]
    eye = jnp.eye(n, dtype=a.dtype)
    l_ref[...] = jnp.where(rows > cols, lu, 0.0) + eye
    u_ref[...] = jnp.where(rows <= cols, lu, 0.0)


@jax.jit
def lu_factor(a):
    """Pivot-free LU: A = L·U with L unit-lower, U upper. Returns (L, U)."""
    n, n2 = a.shape
    if n != n2:
        raise ValueError(f"lu_factor needs a square block, got {a.shape}")
    return pl.pallas_call(
        _lu_factor_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((n, n), a.dtype),
            jax.ShapeDtypeStruct((n, n), a.dtype),
        ),
        interpret=True,
    )(a)


def _gj_nopivot_body(k, aug):
    """Gauss-Jordan elimination step without row exchange (valid whenever
    every leading pivot is nonzero — e.g. triangular inputs)."""
    n = aug.shape[0]
    rows = jax.lax.iota(jnp.int32, n)
    pivot = aug[k, k]
    norm_row = aug[k, :] / pivot
    factors = jnp.where(rows == k, 0.0, aug[:, k])
    aug = aug - factors[:, None] * norm_row[None, :]
    return jnp.where((rows == k)[:, None], norm_row[None, :], aug)


def _tri_inverse_kernel(a_ref, o_ref):
    a = a_ref[...]
    n = a.shape[0]
    aug = jnp.concatenate([a, jnp.eye(n, dtype=a.dtype)], axis=1)
    aug = jax.lax.fori_loop(0, n, _gj_nopivot_body, aug)
    o_ref[...] = aug[:, n:]


def _tri_inverse(a):
    n, n2 = a.shape
    if n != n2:
        raise ValueError(f"triangular inverse needs a square block, got {a.shape}")
    return pl.pallas_call(
        _tri_inverse_kernel,
        out_shape=jax.ShapeDtypeStruct((n, n), a.dtype),
        interpret=True,
    )(a)


@jax.jit
def invert_lower(a):
    """L⁻¹ for a lower-triangular block (nonzero diagonal)."""
    return _tri_inverse(a)


@jax.jit
def invert_upper(a):
    """U⁻¹ for an upper-triangular block (nonzero diagonal)."""
    return _tri_inverse(a)
