"""Layer-2 JAX model: SPIN's block-algebra ops, composed from the L1 kernels.

These are the functions the Rust coordinator executes through PJRT — one HLO
executable per (op, block_size), lowered once by :mod:`compile.aot`.  The
recursion itself (Algorithm 2) lives in Rust; this layer is the complete
vocabulary of block-level compute the recursion needs:

==================  =========================================  =============
op                  computes                                   SPIN step
==================  =========================================  =============
``leaf_inverse``    A⁻¹ (Pallas Gauss-Jordan)                  leaf node
``matmul``          X·Y                                        II, III, IV,
                                                               C12, C21, VII
``matmul_acc``      D + X·Y                                    block-matmul
                                                               reduce step
``neg_matmul_sub``  X·Y − D                                    V = IV − A22
``subtract``        X − Y                                      C11 = I − VII
``scale``           s·X                                        C22 = −VI
``negate``          −X                                         C22 = −VI
``axpy``            s·X + Y                                    utility
``strassen_2x2``    full Algorithm-1 step over 4 blocks        fused leaf
                                                               pair (n/bs=2)
==================  =========================================  =============

``strassen_2x2`` is the fusion opportunity the paper leaves on the table:
when the recursion reaches a 2×2 block grid, the entire level — two leaf
inversions, six multiplies, two subtractions, one negation — lowers into a
single XLA program, eliminating seven scheduler round-trips.
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from compile import kernels
from compile.kernels import gauss_jordan


def leaf_inverse(a):
    """Invert one leaf block on a single worker (paper's ``if`` branch)."""
    return kernels.gauss_jordan_inverse(a)


def matmul(x, y):
    return kernels.matmul(x, y)


def matmul_acc(x, y, d):
    return kernels.matmul_acc(x, y, d)


def neg_matmul_sub(x, y, d):
    return kernels.neg_matmul_sub(x, y, d)


def subtract(x, y):
    return kernels.subtract(x, y)


def scale(x, s):
    return kernels.scale(x, s)


def axpy(x, y, s):
    return kernels.axpy(x, y, s)


def negate(x):
    return kernels.negate(x)


def lu_factor(a):
    """Pivot-free leaf LU for the baseline: returns (L, U)."""
    return kernels.lu_factor(a)


def invert_lower(a):
    """L⁻¹ for a lower-triangular leaf block (baseline leaf)."""
    return kernels.invert_lower(a)


def invert_upper(a):
    """U⁻¹ for an upper-triangular leaf block (baseline leaf)."""
    return kernels.invert_upper(a)


def strassen_2x2(a11, a12, a21, a22):
    """Fused Strassen inversion step over a 2×2 grid of leaf blocks.

    Exactly Algorithm 1 with both sub-inversions at the leaf, built from the
    L1 kernels so the whole level is one HLO module.
    """
    i = kernels.gauss_jordan_inverse(a11)          # I
    ii = kernels.matmul(a21, i)                    # II
    iii = kernels.matmul(i, a12)                   # III
    v = kernels.neg_matmul_sub(a21, iii, a22)      # V = A21·III − A22
    vi = kernels.gauss_jordan_inverse(v)           # VI
    c12 = kernels.matmul(iii, vi)                  # C12
    c21 = kernels.matmul(vi, ii)                   # C21
    c11 = kernels.neg_matmul_sub(iii, c21, i)      # III·C21 − I = −C11
    c11 = kernels.negate(c11)                      # C11 = I − VII
    c22 = kernels.negate(vi)                       # C22
    return c11, c12, c21, c22


#: op name -> (callable, number of square-block args, number of scalar args)
OPS = {
    "leaf_inverse": (leaf_inverse, 1, 0),
    "matmul": (matmul, 2, 0),
    "matmul_acc": (matmul_acc, 3, 0),
    "neg_matmul_sub": (neg_matmul_sub, 3, 0),
    "subtract": (subtract, 2, 0),
    "scale": (scale, 1, 1),
    "axpy": (axpy, 2, 1),
    "negate": (negate, 1, 0),
    "strassen_2x2": (strassen_2x2, 4, 0),
    "lu_factor": (lu_factor, 1, 0),
    "invert_lower": (invert_lower, 1, 0),
    "invert_upper": (invert_upper, 1, 0),
}
