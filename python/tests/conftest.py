import os
import sys

import jax
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

# Make `compile.*` importable when pytest is launched from python/ or repo root.
_HERE = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)


@pytest.fixture
def rng():
    return np.random.default_rng(0xC0FFEE)


def make_diag_dominant(rng, n, dtype=np.float64):
    """Random strictly diagonally dominant matrix — always invertible and
    Strassen-recursion safe (every principal minor is nonsingular)."""
    a = rng.uniform(-1.0, 1.0, size=(n, n)).astype(dtype)
    a += np.diag(np.sign(np.diag(a)) * (np.abs(a).sum(axis=1) + 1.0))
    return a


def make_spd(rng, n, dtype=np.float64):
    """Random symmetric positive definite matrix (paper's stated scope)."""
    b = rng.uniform(-1.0, 1.0, size=(n, n)).astype(dtype)
    return b @ b.T + n * np.eye(n, dtype=dtype)
