"""AOT pipeline: artifacts lower, validate, and the manifest is complete."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    ops = ["matmul", "leaf_inverse", "subtract", "scale", "strassen_2x2"]
    manifest = aot.build(str(out), block_sizes=[8, 16], ops=ops, check=True)
    return out, manifest, ops


class TestAot:
    def test_manifest_entries(self, built):
        out, manifest, ops = built
        assert manifest["version"] == aot.MANIFEST_VERSION
        assert manifest["dtype"] == "float64"
        assert len(manifest["entries"]) == len(ops) * 2
        for e in manifest["entries"]:
            assert e["op"] in ops
            assert e["block_size"] in (8, 16)
            assert os.path.exists(os.path.join(out, e["file"]))

    def test_manifest_file_round_trip(self, built):
        out, manifest, _ = built
        with open(os.path.join(out, "manifest.json")) as f:
            assert json.load(f) == manifest

    def test_hlo_text_is_parseable_shape(self, built):
        out, manifest, _ = built
        for e in manifest["entries"]:
            text = open(os.path.join(out, e["file"])).read()
            assert "HloModule" in text
            assert "ENTRY" in text
            # f64 programs: entry params must be f64
            assert "f64[" in text

    def test_no_mosaic_custom_calls(self, built):
        """interpret=True must lower Pallas to plain HLO for the CPU client."""
        out, manifest, _ = built
        for e in manifest["entries"]:
            text = open(os.path.join(out, e["file"])).read()
            assert "custom-call" not in text, e["file"]

    def test_output_arity(self, built):
        _, manifest, _ = built
        by_op = {e["op"]: e for e in manifest["entries"]}
        assert by_op["strassen_2x2"]["num_outputs"] == 4
        assert by_op["matmul"]["num_outputs"] == 1
        assert by_op["scale"]["num_scalar_inputs"] == 1
        assert by_op["strassen_2x2"]["num_block_inputs"] == 4

    def test_lower_unknown_op_raises(self):
        with pytest.raises(KeyError):
            aot.lower_op("nonexistent", 8)

    def test_check_rejects_custom_call(self):
        with pytest.raises(RuntimeError):
            aot._check_artifact("ENTRY main { custom-call }", "x", 8)
        with pytest.raises(RuntimeError):
            aot._check_artifact("no entry here", "x", 8)
