"""L1 elementwise kernels (subtract / scalarMul / axpy / negate) vs oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import kernels
from compile.kernels import ref

DIMS = st.sampled_from([1, 2, 7, 16, 33, 64, 128, 256, 300])


def _rand(rng, *shape, dtype=np.float64):
    return rng.uniform(-10.0, 10.0, size=shape).astype(dtype)


class TestElementwise:
    @pytest.mark.parametrize("shape", [(1, 1), (16, 16), (64, 128), (256, 256), (5, 300)])
    def test_subtract(self, rng, shape):
        x, y = _rand(rng, *shape), _rand(rng, *shape)
        assert_allclose(kernels.subtract(x, y), ref.subtract(x, y))

    @pytest.mark.parametrize("s", [-1.0, 0.0, 0.5, 3.25])
    def test_scale(self, rng, s):
        x = _rand(rng, 64, 64)
        assert_allclose(kernels.scale(x, s), ref.scale(x, s))

    def test_scale_minus_one_is_negate(self, rng):
        """C22 = −VI is computed as scalarMul(VI, −1) in the paper."""
        x = _rand(rng, 32, 32)
        assert_allclose(kernels.scale(x, -1.0), kernels.negate(x))

    @pytest.mark.parametrize("s", [-2.0, 1.0, 0.125])
    def test_axpy(self, rng, s):
        x, y = _rand(rng, 48, 48), _rand(rng, 48, 48)
        assert_allclose(kernels.axpy(x, y, s), ref.axpy(x, y, s))

    def test_negate(self, rng):
        x = _rand(rng, 128, 128)
        assert_allclose(kernels.negate(x), -x)

    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    def test_dtypes(self, rng, dtype):
        x, y = _rand(rng, 32, 32, dtype=dtype), _rand(rng, 32, 32, dtype=dtype)
        assert kernels.subtract(x, y).dtype == dtype
        assert kernels.scale(x, 2.0).dtype == dtype
        assert kernels.negate(x).dtype == dtype

    def test_subtract_shape_mismatch_raises(self, rng):
        with pytest.raises(ValueError):
            kernels.subtract(_rand(rng, 4, 4), _rand(rng, 8, 8))

    @pytest.mark.parametrize("tile", [8, 64, 256, 1024])
    def test_tile_invariance(self, rng, tile):
        x, y = _rand(rng, 128, 128), _rand(rng, 128, 128)
        assert_allclose(kernels.subtract(x, y, tile=tile), x - y)
        assert_allclose(kernels.scale(x, 2.5, tile=tile), x * 2.5)

    @settings(max_examples=25, deadline=None)
    @given(
        m=DIMS,
        n=DIMS,
        s=st.floats(-1e3, 1e3, allow_nan=False),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_algebra(self, m, n, s, seed):
        r = np.random.default_rng(seed)
        x, y = _rand(r, m, n), _rand(r, m, n)
        # subtract(x, x) = 0
        assert_allclose(kernels.subtract(x, x), np.zeros_like(x))
        # scale distributes over subtract
        assert_allclose(
            kernels.scale(kernels.subtract(x, y), s),
            kernels.subtract(kernels.scale(x, s), kernels.scale(y, s)),
            rtol=1e-12,
            atol=1e-9,
        )
        # axpy(x, y, s) = scale(x, s) + y
        assert_allclose(
            kernels.axpy(x, y, s),
            np.asarray(kernels.scale(x, s)) + y,
            rtol=1e-12,
            atol=1e-9,
        )
