"""L1 Gauss-Jordan leaf-inversion kernel vs jnp.linalg.inv."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import kernels
from compile.kernels import ref
from tests.conftest import make_diag_dominant, make_spd


class TestGaussJordan:
    @pytest.mark.parametrize("n", [1, 2, 3, 4, 8, 16, 32, 64, 128])
    def test_diag_dominant(self, rng, n):
        a = make_diag_dominant(rng, n)
        assert_allclose(
            kernels.gauss_jordan_inverse(a),
            ref.gauss_jordan_inverse(a),
            rtol=1e-9,
            atol=1e-11,
        )

    @pytest.mark.parametrize("n", [4, 16, 64])
    def test_spd(self, rng, n):
        a = make_spd(rng, n)
        inv = np.asarray(kernels.gauss_jordan_inverse(a))
        assert_allclose(inv @ a, np.eye(n), atol=1e-8)

    def test_residual_is_tight(self, rng):
        """‖A·A⁻¹ − I‖∞ small relative to cond — the acceptance criterion the
        Rust integration tests reuse."""
        n = 64
        a = make_diag_dominant(rng, n)
        inv = np.asarray(kernels.gauss_jordan_inverse(a))
        resid = np.abs(a @ inv - np.eye(n)).max()
        assert resid < 1e-10

    def test_identity(self):
        assert_allclose(kernels.gauss_jordan_inverse(np.eye(16)), np.eye(16), atol=1e-14)

    def test_diagonal(self):
        d = np.diag(np.arange(1.0, 17.0))
        assert_allclose(
            kernels.gauss_jordan_inverse(d), np.diag(1.0 / np.arange(1.0, 17.0)), atol=1e-14
        )

    def test_needs_pivoting(self):
        """Zero leading pivot: fails without row exchanges, so this proves the
        in-kernel partial pivoting actually engages."""
        a = np.array(
            [
                [0.0, 1.0, 2.0],
                [1.0, 0.0, 3.0],
                [4.0, 5.0, 0.0],
            ]
        )
        assert_allclose(
            kernels.gauss_jordan_inverse(a), np.linalg.inv(a), rtol=1e-10, atol=1e-12
        )

    def test_permutation_matrix(self):
        p = np.eye(8)[::-1].copy()  # anti-diagonal permutation, all pivots off-diagonal
        assert_allclose(kernels.gauss_jordan_inverse(p), np.linalg.inv(p), atol=1e-12)

    def test_ill_conditioned_hilbert(self):
        """Small Hilbert matrix — loose tolerance scaled by condition number."""
        n = 6
        h = np.array([[1.0 / (i + j + 1) for j in range(n)] for i in range(n)])
        inv = np.asarray(kernels.gauss_jordan_inverse(h))
        # cond(H_6) ~ 1.5e7; expect ~cond * eps accuracy.
        assert_allclose(inv @ h, np.eye(n), atol=1e-6)

    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    def test_dtypes(self, rng, dtype):
        a = make_diag_dominant(rng, 32).astype(dtype)
        out = kernels.gauss_jordan_inverse(a)
        assert out.dtype == dtype
        atol = 1e-4 if dtype == np.float32 else 1e-11
        assert_allclose(np.asarray(out) @ a, np.eye(32, dtype=dtype), atol=atol)

    def test_non_square_raises(self, rng):
        with pytest.raises(ValueError):
            kernels.gauss_jordan_inverse(rng.uniform(size=(4, 8)))

    @settings(max_examples=20, deadline=None)
    @given(n=st.sampled_from([2, 3, 5, 8, 17, 33, 64]), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_inverse_roundtrip(self, n, seed):
        r = np.random.default_rng(seed)
        a = make_diag_dominant(r, n)
        inv = np.asarray(kernels.gauss_jordan_inverse(a))
        assert_allclose(a @ inv, np.eye(n), atol=1e-9)
        assert_allclose(inv @ a, np.eye(n), atol=1e-9)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_involution(self, seed):
        """inv(inv(A)) ≈ A."""
        r = np.random.default_rng(seed)
        a = make_diag_dominant(r, 24)
        twice = kernels.gauss_jordan_inverse(kernels.gauss_jordan_inverse(a))
        assert_allclose(twice, a, rtol=1e-8, atol=1e-9)
