"""L1 matmul kernels vs pure-jnp oracle."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import kernels
from compile.kernels import ref

DIMS = st.sampled_from([1, 2, 3, 4, 8, 16, 24, 32, 48, 64, 96, 128, 160, 256])


def _rand(rng, *shape, dtype=np.float64):
    return rng.uniform(-1.0, 1.0, size=shape).astype(dtype)


class TestMatmul:
    @pytest.mark.parametrize("n", [1, 2, 4, 16, 64, 128, 256])
    def test_square(self, rng, n):
        x, y = _rand(rng, n, n), _rand(rng, n, n)
        assert_allclose(kernels.matmul(x, y), ref.matmul(x, y), rtol=1e-11, atol=1e-12)

    @pytest.mark.parametrize("m,k,n", [(8, 16, 32), (128, 64, 32), (256, 128, 64), (3, 5, 7)])
    def test_rectangular(self, rng, m, k, n):
        x, y = _rand(rng, m, k), _rand(rng, k, n)
        assert_allclose(kernels.matmul(x, y), ref.matmul(x, y), rtol=1e-11, atol=1e-12)

    @pytest.mark.parametrize("tile", [8, 32, 64, 128])
    def test_tile_invariance(self, rng, tile):
        """Result must not depend on the VMEM tile decomposition."""
        x, y = _rand(rng, 128, 128), _rand(rng, 128, 128)
        assert_allclose(
            kernels.matmul(x, y, tile=tile), ref.matmul(x, y), rtol=1e-10, atol=1e-12
        )

    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    def test_dtypes(self, rng, dtype):
        x, y = _rand(rng, 64, 64, dtype=dtype), _rand(rng, 64, 64, dtype=dtype)
        out = kernels.matmul(x, y)
        assert out.dtype == dtype
        tol = 1e-5 if dtype == np.float32 else 1e-12
        assert_allclose(out, ref.matmul(x, y), rtol=tol, atol=tol)

    def test_identity(self, rng):
        x = _rand(rng, 64, 64)
        assert_allclose(kernels.matmul(x, np.eye(64)), x, rtol=1e-14)

    def test_zeros(self):
        z = np.zeros((32, 32))
        assert_allclose(kernels.matmul(z, z), z)

    @settings(max_examples=25, deadline=None)
    @given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_shapes(self, m, k, n, seed):
        r = np.random.default_rng(seed)
        x, y = _rand(r, m, k), _rand(r, k, n)
        assert_allclose(kernels.matmul(x, y), ref.matmul(x, y), rtol=1e-11, atol=1e-12)

    def test_shape_mismatch_raises(self, rng):
        with pytest.raises(ValueError):
            kernels.matmul(_rand(rng, 4, 8), _rand(rng, 4, 8))


class TestFusedMatmul:
    @pytest.mark.parametrize("n", [16, 64, 128])
    def test_matmul_acc(self, rng, n):
        x, y, d = _rand(rng, n, n), _rand(rng, n, n), _rand(rng, n, n)
        assert_allclose(
            kernels.matmul_acc(x, y, d), ref.matmul_acc(x, y, d), rtol=1e-12
        )

    @pytest.mark.parametrize("n", [16, 64, 128])
    def test_neg_matmul_sub(self, rng, n):
        x, y, d = _rand(rng, n, n), _rand(rng, n, n), _rand(rng, n, n)
        assert_allclose(
            kernels.neg_matmul_sub(x, y, d), ref.neg_matmul_sub(x, y, d), rtol=1e-12
        )

    def test_matmul_acc_is_schur_building_block(self, rng):
        """V = A21·III − A22 must equal the composed form exactly enough."""
        a21, iii, a22 = _rand(rng, 64, 64), _rand(rng, 64, 64), _rand(rng, 64, 64)
        fused = kernels.neg_matmul_sub(a21, iii, a22)
        composed = kernels.subtract(kernels.matmul(a21, iii), a22)
        assert_allclose(fused, composed, rtol=1e-12, atol=1e-13)

    @settings(max_examples=15, deadline=None)
    @given(n=st.sampled_from([8, 32, 96, 128]), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_fused(self, n, seed):
        r = np.random.default_rng(seed)
        x, y, d = _rand(r, n, n), _rand(r, n, n), _rand(r, n, n)
        assert_allclose(
            kernels.matmul_acc(x, y, d), ref.matmul_acc(x, y, d), rtol=1e-11, atol=1e-12
        )
        assert_allclose(
            kernels.neg_matmul_sub(x, y, d),
            ref.neg_matmul_sub(x, y, d),
            rtol=1e-11,
            atol=1e-12,
        )
