"""L2 model ops: contracts, fused strassen_2x2 vs Algorithm-1 composition."""

import numpy as np
import pytest
from numpy.testing import assert_allclose

from compile import model
from compile.kernels import ref
from tests.conftest import make_diag_dominant, make_spd


class TestModelOps:
    def test_ops_table_is_complete(self):
        """Every op the Rust runtime expects must be lowered."""
        expected = {
            "leaf_inverse",
            "matmul",
            "matmul_acc",
            "neg_matmul_sub",
            "subtract",
            "scale",
            "axpy",
            "negate",
            "strassen_2x2",
            "lu_factor",
            "invert_lower",
            "invert_upper",
        }
        assert set(model.OPS) == expected

    @pytest.mark.parametrize("op", sorted(model.OPS))
    def test_op_arity_metadata(self, rng, op):
        fn, n_blocks, n_scalars = model.OPS[op]
        bs = 16
        blocks = [
            make_diag_dominant(rng, bs) for _ in range(n_blocks)
        ]  # dominant => invertible where inversion happens
        scalars = [1.5] * n_scalars
        out = fn(*blocks, *scalars)
        outs = out if isinstance(out, tuple) else (out,)
        for o in outs:
            assert o.shape == (bs, bs)
            assert o.dtype == np.float64

    def test_leaf_inverse(self, rng):
        a = make_spd(rng, 32)
        assert_allclose(np.asarray(model.leaf_inverse(a)) @ a, np.eye(32), atol=1e-8)

    def test_strassen_2x2_vs_reference(self, rng):
        bs = 32
        a11 = make_diag_dominant(rng, bs)
        a22 = make_diag_dominant(rng, bs)
        a12 = rng.uniform(-0.1, 0.1, size=(bs, bs))
        a21 = rng.uniform(-0.1, 0.1, size=(bs, bs))
        got = model.strassen_2x2(a11, a12, a21, a22)
        want = ref.strassen_2x2_inverse(a11, a12, a21, a22)
        for g, w, name in zip(got, want, ["C11", "C12", "C21", "C22"]):
            assert_allclose(g, w, rtol=1e-8, atol=1e-9, err_msg=name)

    def test_strassen_2x2_inverts_full_matrix(self, rng):
        """Assembled [Cij] must equal inv of assembled [Aij] — end-to-end check
        of the fused leaf-pair op against numpy on the full 2bs×2bs system."""
        bs = 24
        a = make_spd(rng, 2 * bs)
        a11, a12 = a[:bs, :bs], a[:bs, bs:]
        a21, a22 = a[bs:, :bs], a[bs:, bs:]
        c11, c12, c21, c22 = [np.asarray(x) for x in model.strassen_2x2(a11, a12, a21, a22)]
        c = np.block([[c11, c12], [c21, c22]])
        assert_allclose(c @ a, np.eye(2 * bs), atol=1e-7)

    def test_fused_ops_match_composition(self, rng):
        x, y, d = (rng.uniform(-1, 1, (48, 48)) for _ in range(3))
        assert_allclose(
            model.matmul_acc(x, y, d),
            np.asarray(model.matmul(x, y)) + d,
            rtol=1e-12,
            atol=1e-13,
        )
        assert_allclose(
            model.neg_matmul_sub(x, y, d),
            np.asarray(model.matmul(x, y)) - d,
            rtol=1e-12,
            atol=1e-13,
        )
