"""L1 triangular/LU leaf kernels (the baseline's leaves) vs oracles."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from numpy.testing import assert_allclose

from compile import kernels
from tests.conftest import make_diag_dominant


def rand_lower(rng, n):
    l = np.tril(rng.uniform(-1.0, 1.0, size=(n, n)))
    np.fill_diagonal(l, 2.0 + rng.uniform(0.0, 1.0, size=n))
    return l


class TestLuFactor:
    @pytest.mark.parametrize("n", [1, 2, 4, 16, 64, 128])
    def test_reconstructs(self, rng, n):
        a = make_diag_dominant(rng, n)
        l, u = kernels.lu_factor(a)
        l, u = np.asarray(l), np.asarray(u)
        assert_allclose(l @ u, a, rtol=1e-10, atol=1e-11)

    def test_l_unit_lower_u_upper(self, rng):
        a = make_diag_dominant(rng, 32)
        l, u = kernels.lu_factor(a)
        l, u = np.asarray(l), np.asarray(u)
        assert_allclose(np.triu(l, 1), 0.0, atol=1e-14)
        assert_allclose(np.diag(l), 1.0, atol=1e-14)
        assert_allclose(np.tril(u, -1), 0.0, atol=1e-14)

    def test_identity(self):
        l, u = kernels.lu_factor(np.eye(8))
        assert_allclose(l, np.eye(8), atol=1e-14)
        assert_allclose(u, np.eye(8), atol=1e-14)

    @settings(max_examples=15, deadline=None)
    @given(n=st.sampled_from([2, 3, 8, 17, 33]), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_reconstruction(self, n, seed):
        r = np.random.default_rng(seed)
        a = make_diag_dominant(r, n)
        l, u = kernels.lu_factor(a)
        assert_allclose(np.asarray(l) @ np.asarray(u), a, rtol=1e-9, atol=1e-10)

    def test_non_square_raises(self, rng):
        with pytest.raises(ValueError):
            kernels.lu_factor(rng.uniform(size=(4, 6)))


class TestTriangularInverse:
    @pytest.mark.parametrize("n", [1, 2, 8, 32, 128])
    def test_lower(self, rng, n):
        l = rand_lower(rng, n)
        inv = np.asarray(kernels.invert_lower(l))
        assert_allclose(inv @ l, np.eye(n), atol=1e-9)
        # stays lower-triangular
        assert_allclose(np.triu(inv, 1), 0.0, atol=1e-11)

    @pytest.mark.parametrize("n", [1, 2, 8, 32, 128])
    def test_upper(self, rng, n):
        u = rand_lower(rng, n).T.copy()
        inv = np.asarray(kernels.invert_upper(u))
        assert_allclose(u @ inv, np.eye(n), atol=1e-9)
        assert_allclose(np.tril(inv, -1), 0.0, atol=1e-11)

    def test_matches_numpy(self, rng):
        l = rand_lower(rng, 24)
        assert_allclose(
            kernels.invert_lower(l), np.linalg.inv(l), rtol=1e-9, atol=1e-10
        )

    def test_lu_plus_triangular_is_full_inverse(self, rng):
        """U⁻¹·L⁻¹ == A⁻¹ — the identity the LU baseline's leaves rely on."""
        a = make_diag_dominant(rng, 48)
        l, u = kernels.lu_factor(a)
        li = np.asarray(kernels.invert_lower(np.asarray(l)))
        ui = np.asarray(kernels.invert_upper(np.asarray(u)))
        assert_allclose((ui @ li) @ a, np.eye(48), atol=1e-8)

    @settings(max_examples=10, deadline=None)
    @given(n=st.sampled_from([2, 5, 16, 40]), seed=st.integers(0, 2**31 - 1))
    def test_hypothesis_lower_roundtrip(self, n, seed):
        r = np.random.default_rng(seed)
        l = rand_lower(r, n)
        inv = np.asarray(kernels.invert_lower(l))
        assert_allclose(l @ inv, np.eye(n), atol=1e-8)
