//! Ablation: the fused 2×2 recursion base (`strassen_2x2` artifact /
//! `JobConfig::fuse_leaf_2x2`) vs the plain Algorithm-2 base — the design
//! choice DESIGN.md §2 calls out ("the fusion opportunity the paper leaves
//! on the table"). Reports virtual time and stage counts for both arms.

mod common;

use spin::config::LeafMethod;
use spin::experiments::report;
use spin::util::fmt::{self, Table};

fn main() {
    spin::util::logger::init();
    common::banner("ablation_fusion", "fused strassen_2x2 base vs plain recursion");

    let mut csv = Table::new(vec!["n", "block", "fused", "virtual_secs", "stages"]);
    let mut t = Table::new(vec!["n", "block", "plain", "fused", "delta", "stages plain→fused"]);
    for (n, bs) in [(256usize, 128usize), (512, 256), (1024, 128), (1024, 64)] {
        let arm = |fuse: bool| {
            // One session per arm: each owns a fresh cluster (clean clock +
            // stage counts) and carries the fusion toggle as a job default.
            let session = common::session_from_env()
                .leaf(LeafMethod::GaussJordan)
                .seed(0xF05E ^ n as u64)
                .fuse_leaf_2x2(fuse)
                .build()
                .expect("session");
            let a = session.random(n, bs).expect("gen");
            let inv = a.inverse().expect("invert");
            std::hint::black_box(inv.block_matrix().expect("materialize"));
            let stages = session.metrics().stages().len();
            (session.virtual_secs(), stages)
        };
        let (plain_s, plain_stages) = arm(false);
        let (fused_s, fused_stages) = arm(true);
        t.row(vec![
            n.to_string(),
            bs.to_string(),
            fmt::secs(plain_s),
            fmt::secs(fused_s),
            format!("{:+.0}%", 100.0 * (fused_s - plain_s) / plain_s),
            format!("{plain_stages} → {fused_stages}"),
        ]);
        for (fused, s, st) in [(false, plain_s, plain_stages), (true, fused_s, fused_stages)] {
            csv.row(vec![
                n.to_string(),
                bs.to_string(),
                fused.to_string(),
                format!("{s}"),
                st.to_string(),
            ]);
        }
    }
    println!("{}", t.render());
    let path = report::write_csv("ablation_fusion", &csv).expect("csv");
    println!("csv: {}", path.display());
    println!(
        "note: fusion collapses the seven distributed stages of each 2x2\n\
         recursion base into one task — it wins when the base level's\n\
         scheduler/shuffle overhead outweighs the lost intra-level\n\
         parallelism (small grids, slow fabrics)."
    );
}
