//! Shared bench-harness plumbing (criterion is not in the offline vendor
//! set; each bench is a `harness = false` binary using the experiment
//! drivers).

use spin::config::ClusterConfig;
use spin::experiments::Scale;

/// Scale from `SPIN_BENCH_SCALE` (smoke|default|full), default `default`.
pub fn scale_from_env() -> Scale {
    match std::env::var("SPIN_BENCH_SCALE").as_deref() {
        Ok("smoke") => Scale::smoke(),
        Ok("full") => Scale::full(),
        _ => Scale::default_scale(),
    }
}

/// The paper's cluster topology, with backend/threads overridable via
/// `SPIN_BENCH_BACKEND` (native|xla).
pub fn cluster_from_env() -> ClusterConfig {
    let mut cfg = ClusterConfig::paper();
    if let Ok(be) = std::env::var("SPIN_BENCH_BACKEND") {
        let _ = cfg.apply_override(&format!("backend={be}"));
    }
    cfg
}

pub fn banner(name: &str, what: &str) {
    eprintln!("\n==== bench: {name} — {what} ====");
    eprintln!(
        "scale: SPIN_BENCH_SCALE={} backend: SPIN_BENCH_BACKEND={}\n",
        std::env::var("SPIN_BENCH_SCALE").unwrap_or_else(|_| "default".into()),
        std::env::var("SPIN_BENCH_BACKEND").unwrap_or_else(|_| "native".into()),
    );
}
