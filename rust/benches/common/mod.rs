//! Shared bench-harness plumbing (criterion is not in the offline vendor
//! set; each bench is a `harness = false` binary using the experiment
//! drivers).

use spin::config::ClusterConfig;
use spin::experiments::Scale;
use spin::session::{SessionBuilder, SpinSession};

/// Scale from `SPIN_BENCH_SCALE` (smoke|default|full), default `default`.
#[allow(dead_code)] // not every bench binary links every helper
pub fn scale_from_env() -> Scale {
    match std::env::var("SPIN_BENCH_SCALE").as_deref() {
        Ok("smoke") => Scale::smoke(),
        Ok("full") => Scale::full(),
        _ => Scale::default_scale(),
    }
}

/// The paper's cluster topology, with backend/threads overridable via
/// `SPIN_BENCH_BACKEND` (native|xla).
#[allow(dead_code)] // not every bench binary links every helper
pub fn cluster_from_env() -> ClusterConfig {
    let mut cfg = ClusterConfig::paper();
    if let Ok(be) = std::env::var("SPIN_BENCH_BACKEND") {
        let _ = cfg.apply_override(&format!("backend={be}"));
    }
    cfg
}

/// A session builder over [`cluster_from_env`] — benches layer their own
/// seeds/leaf/fusion defaults on top and call `.build()`.
#[allow(dead_code)] // not every bench binary links every helper
pub fn session_from_env() -> SessionBuilder {
    SpinSession::builder().cluster_config(cluster_from_env())
}

pub fn banner(name: &str, what: &str) {
    eprintln!("\n==== bench: {name} — {what} ====");
    eprintln!(
        "scale: SPIN_BENCH_SCALE={} backend: SPIN_BENCH_BACKEND={}\n",
        std::env::var("SPIN_BENCH_SCALE").unwrap_or_else(|_| "default".into()),
        std::env::var("SPIN_BENCH_BACKEND").unwrap_or_else(|_| "native".into()),
    );
}
