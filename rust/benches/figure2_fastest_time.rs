//! Bench: regenerate the paper's Figure 2 — fastest wall time over block
//! sizes, SPIN vs LU, per matrix size. Writes `bench_results/figure2.csv`.

mod common;

fn main() {
    spin::util::logger::init();
    common::banner("figure2", "fastest time over b: SPIN vs LU");
    let cluster = common::cluster_from_env();
    let scale = common::scale_from_env();
    let rows = spin::experiments::figure2::run(&cluster, &scale, 42).expect("figure2 run");
    print!("{}", spin::experiments::figure2::render(&rows).expect("render"));
    match spin::experiments::figure2::check_shape(&rows) {
        Ok(()) => println!("shape check: OK — SPIN ≤ LU everywhere, gap grows with n"),
        Err(e) => println!("shape check: DEVIATION — {e}"),
    }
}
