//! Bench: regenerate the paper's Figure 3 — wall time vs partition count b
//! (the U-shape), SPIN vs LU, per matrix size. Writes
//! `bench_results/figure3.csv`.

mod common;

fn main() {
    spin::util::logger::init();
    common::banner("figure3", "U-shaped time vs partition count");
    let cluster = common::cluster_from_env();
    let scale = common::scale_from_env();
    let rows = spin::experiments::figure3::run(&cluster, &scale, 43).expect("figure3 run");
    print!("{}", spin::experiments::figure3::render(&rows).expect("render"));
    match spin::experiments::figure3::check_shape(&rows, true) {
        Ok(()) => println!("shape check: OK — SPIN wins pointwise; U-shape present"),
        Err(e) => println!("shape check: DEVIATION — {e}"),
    }
}
