//! Bench: regenerate the paper's Figure 4 — calibrated Lemma 4.1 cost
//! model vs measured SPIN wall clock, per (n, b). Writes
//! `bench_results/figure4.csv`.

mod common;

fn main() {
    spin::util::logger::init();
    common::banner("figure4", "theoretical vs experimental SPIN time");
    let cluster = common::cluster_from_env();
    let scale = common::scale_from_env();
    let (rows, k) = spin::experiments::figure4::run(&cluster, &scale, 44).expect("figure4 run");
    print!("{}", spin::experiments::figure4::render(&rows).expect("render"));
    println!("calibrated constants: {k:?}");
    match spin::experiments::figure4::check_shape(&rows) {
        Ok(()) => println!("shape check: OK — model within an order of magnitude pointwise"),
        Err(e) => println!("shape check: DEVIATION — {e}"),
    }
}
