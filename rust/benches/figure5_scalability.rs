//! Bench: regenerate the paper's Figure 5 — SPIN wall time vs executor
//! count with the ideal T(1)/k line. Writes `bench_results/figure5.csv`.

mod common;

fn main() {
    spin::util::logger::init();
    common::banner("figure5", "scalability vs executors + ideal line");
    let cluster = common::cluster_from_env();
    let scale = common::scale_from_env();
    let rows = spin::experiments::figure5::run(&cluster, &scale, 45).expect("figure5 run");
    print!("{}", spin::experiments::figure5::render(&rows).expect("render"));
    match spin::experiments::figure5::check_shape(&rows) {
        Ok(()) => println!("shape check: OK — time monotone in executors"),
        Err(e) => println!("shape check: DEVIATION — {e}"),
    }
}
