//! Microbench: per-block kernel timings (native vs XLA/PJRT) across block
//! sizes — the §Perf instrumentation for the hot path. Writes
//! `bench_results/microbench.csv`.

mod common;

use spin::config::LeafMethod;
use spin::linalg::{self, Matrix};
use spin::runtime::{BlockKernels, NativeBackend, XlaBackend};
use spin::util::fmt;
use spin::util::timer::min_time_of;
use spin::util::Rng;

fn bench_backend(name: &str, be: &dyn BlockKernels, sizes: &[usize], csv: &mut fmt::Table) {
    let mut rng = Rng::new(0xBEEF);
    for &bs in sizes {
        let a = linalg::diag_dominant(bs, &mut rng);
        let b = Matrix::random_uniform(bs, bs, -1.0, 1.0, &mut rng);
        let d = Matrix::random_uniform(bs, bs, -1.0, 1.0, &mut rng);
        let reps = if bs <= 64 { 20 } else { 5 };

        let t_mm = min_time_of(reps, || be.matmul(&a, &b).unwrap());
        let t_acc = min_time_of(reps, || be.matmul_acc(&a, &b, d.clone()).unwrap());
        let t_sub = min_time_of(reps, || be.subtract(&a, &b).unwrap());
        let t_inv = min_time_of(reps, || be.leaf_inverse(&a, LeafMethod::GaussJordan).unwrap());

        let gemm_flops = linalg::gemm_flops(bs);
        println!(
            "{name:>7} bs={bs:<4} matmul {:>10} ({:>10})  acc {:>10}  sub {:>10}  inverse {:>10}",
            fmt::secs(t_mm),
            fmt::gflops(gemm_flops, t_mm),
            fmt::secs(t_acc),
            fmt::secs(t_sub),
            fmt::secs(t_inv),
        );
        for (op, t) in [
            ("matmul", t_mm),
            ("matmul_acc", t_acc),
            ("subtract", t_sub),
            ("leaf_inverse", t_inv),
        ] {
            csv.row(vec![
                name.to_string(),
                op.to_string(),
                bs.to_string(),
                format!("{t}"),
            ]);
        }
    }
}

fn main() {
    spin::util::logger::init();
    common::banner("microbench", "block kernels: native vs XLA");
    let sizes = [16usize, 32, 64, 128, 256];
    let mut csv = fmt::Table::new(vec!["backend", "op", "block_size", "secs"]);

    bench_backend("native", &NativeBackend, &sizes, &mut csv);

    match XlaBackend::new(std::path::PathBuf::from("artifacts")) {
        Ok(xla) => {
            bench_backend("xla", &xla, &sizes, &mut csv);
            println!(
                "xla ops executed={} fallbacks={}",
                xla.executed_count(),
                xla.fallback_count()
            );
        }
        Err(e) => println!("xla backend unavailable ({e}); run `make artifacts`"),
    }

    // Naive-vs-blocked GEMM (the §Perf before/after pair).
    let mut rng = Rng::new(1);
    for bs in [64usize, 128, 256] {
        let a = Matrix::random_uniform(bs, bs, -1.0, 1.0, &mut rng);
        let b = Matrix::random_uniform(bs, bs, -1.0, 1.0, &mut rng);
        let t_naive = min_time_of(3, || linalg::matmul_naive(&a, &b));
        let t_blocked = min_time_of(3, || linalg::matmul(&a, &b));
        println!(
            "gemm bs={bs:<4} naive {:>10} ({:>10})  blocked {:>10} ({:>10})  speedup {:.2}x",
            fmt::secs(t_naive),
            fmt::gflops(linalg::gemm_flops(bs), t_naive),
            fmt::secs(t_blocked),
            fmt::gflops(linalg::gemm_flops(bs), t_blocked),
            t_naive / t_blocked
        );
        csv.row(vec![
            "native".into(),
            "matmul_naive".into(),
            bs.to_string(),
            format!("{t_naive}"),
        ]);
    }

    let path = spin::experiments::report::write_csv("microbench", &csv).expect("csv");
    println!("csv: {}", path.display());
}
