//! Bench: regenerate the paper's Table 3 — per-method wall-clock breakdown
//! of SPIN over split counts. Writes `bench_results/table3.csv`.

mod common;

fn main() {
    spin::util::logger::init();
    common::banner("table3", "per-method breakdown over b");
    let cluster = common::cluster_from_env();
    let scale = common::scale_from_env();
    // Paper uses n = 4096; we use the middle of the configured sweep.
    let n = scale.sizes[scale.sizes.len() / 2];
    let cols = spin::experiments::table3::run(&cluster, n, scale.max_b, 46).expect("table3 run");
    print!("{}", spin::experiments::table3::render(n, &cols).expect("render"));
    match spin::experiments::table3::check_shape(&cols) {
        Ok(()) => println!("shape check: OK — leafNode falls with b, multiply rises"),
        Err(e) => println!("shape check: DEVIATION — {e}"),
    }
}
