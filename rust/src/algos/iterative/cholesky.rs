//! Block-recursive Cholesky inversion for symmetric positive-definite
//! inputs — the structure-exploiting correctness foil.
//!
//! For SPD `A = L·Lᵀ` the inverse is `A⁻¹ = L⁻ᵀ·L⁻¹`: ONE recursive
//! factorization + ONE triangular inversion + ONE full-size product,
//! against the LU baseline's two-of-each. Per factor level over
//! `[[A11, A21ᵀ], [A21, A22]]`:
//!
//! 1. `L11 = chol(A11)` (recurse),
//! 2. `L21 = A21·L11⁻ᵀ` (one triangular inversion + one multiply),
//! 3. `S = A22 − L21·L21ᵀ` (the symmetric Schur complement — `D − A·B`,
//!    correctly NOT fused by the `A·B − D` rule; the shared `L21` plan
//!    node feeds both the Schur product and the final arrange),
//! 4. `L22 = chol(S)` (recurse).
//!
//! The triangular inversion is shared verbatim with the LU baseline
//! (`invert_block_lower`), so the exchange-counter gap between `cholesky`
//! and `lu` measures exactly the factorization structure: symmetry halves
//! the per-level work (no `U` factor, no second triangular inversion),
//! which shows up as strictly smaller deterministic counters at every
//! grid (e.g. 30 vs 52 exchanges at b=4, 78 vs 140 at b=8).
//!
//! Non-SPD inputs fail loudly: asymmetry is rejected up front by a
//! driver-side check, and an indefinite (symmetric but not
//! positive-definite) matrix surfaces the leaf kernel's
//! "not positive definite" pivot error from inside the recursion.

use crate::blockmatrix::ops_method as method;
use crate::blockmatrix::BlockMatrix;
use crate::cluster::{Cluster, ResilienceTotals};
use crate::config::JobConfig;
use crate::error::{Result, SpinError};
use crate::plan::{MatExpr, PlanExec};
use crate::runtime::BlockKernels;
use crate::store::checkpoint;

use super::super::lu::invert_block_lower;
use super::super::registry::InversionAlgorithm;

/// Block-recursive Cholesky inversion (`cholesky` in the registry).
pub struct CholeskyAlgorithm;

impl InversionAlgorithm for CholeskyAlgorithm {
    fn name(&self) -> &str {
        "cholesky"
    }

    fn description(&self) -> &str {
        "block-recursive Cholesky for SPD inputs (A^-1 = L^-T.L^-1, fewer stages than LU)"
    }

    fn invert(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        a: &BlockMatrix,
        job: &JobConfig,
    ) -> Result<BlockMatrix> {
        cholesky_inverse_impl(cluster, kernels, a, job)
    }

    fn plan(&self, a: &MatExpr) -> Result<Option<MatExpr>> {
        if a.nblocks() < 2 {
            return Ok(None); // single-block leaf: no distributed level
        }
        // One factor level; the `invert[cholesky]` nodes mark recursion.
        let (a11e, _a12e, a21e, a22e) = a.split()?;
        let l11i = a11e.invert("cholesky");
        let l21 = a21e.multiply(&l11i.transpose())?;
        let s = a22e.subtract(&l21.multiply(&l21.transpose())?)?;
        let l22 = s.invert("cholesky");
        let zero = MatExpr::source(BlockMatrix::zeros(a11e.nblocks(), a11e.block_size())?);
        Ok(Some(MatExpr::arrange(&l11i, &zero, &l21, &l22)?))
    }

    fn analysis_model(&self) -> Option<AlgoModel> {
        Some(analysis_model())
    }
}

/// Record checkpoint activity on this job's metric scope.
fn record_ckpt(cluster: &Cluster, written: usize, restored: usize) {
    cluster.record_resilience(&ResilienceTotals {
        checkpoints_written: written,
        checkpoints_restored: restored,
        ..ResilienceTotals::default()
    });
}

/// Cholesky inversion entry — reached through [`CholeskyAlgorithm`].
pub(crate) fn cholesky_inverse_impl(
    cluster: &Cluster,
    kernels: &dyn BlockKernels,
    a: &BlockMatrix,
    job: &JobConfig,
) -> Result<BlockMatrix> {
    if !a.nblocks().is_power_of_two() {
        return Err(SpinError::shape(format!(
            "cholesky needs a power-of-two block grid, got {}",
            a.nblocks()
        )));
    }
    // Up-front symmetry gate: the recursion assumes A21 = A12ᵀ (it never
    // reads A12), so an asymmetric input would silently invert a
    // different matrix. Checked driver-side against the matrix's scale.
    let dense = a.to_dense()?;
    let asym = dense.max_abs_diff(&dense.transpose());
    if asym > 1e-10 * dense.inf_norm().max(1.0) {
        return Err(SpinError::numerical(format!(
            "cholesky requires a symmetric matrix (‖A − Aᵀ‖∞ = {asym:.3e})"
        )));
    }

    let ckpt = checkpoint::boundary();
    let restored = ckpt
        .as_ref()
        .and_then(|level| level.try_restore("m", a.nblocks(), a.block_size()));
    let inv = match restored {
        Some(inv) => {
            record_ckpt(cluster, 0, 1);
            inv
        }
        None => {
            let l = block_cholesky(cluster, kernels, a, job)?;
            let li = invert_block_lower(cluster, kernels, &l, job)?;
            // The final full-size product A⁻¹ = L⁻ᵀ·L⁻¹.
            let exec = PlanExec::new(cluster, kernels);
            let lie = MatExpr::source(li);
            let inv = exec.eval(&lie.transpose().multiply(&lie)?)?;
            if let Some(level) = &ckpt {
                record_ckpt(cluster, level.persist("m", &inv) as usize, 0);
            }
            inv
        }
    };
    if job.residual_check {
        let resid = crate::linalg::inverse_residual(&dense, &inv.to_dense()?);
        if resid > 1e-8 {
            return Err(SpinError::numerical(format!(
                "cholesky residual check failed: {resid:.3e}"
            )));
        }
    }
    Ok(inv)
}

/// Recursive block Cholesky factor: A = L·Lᵀ, L block lower-triangular.
fn block_cholesky(
    cluster: &Cluster,
    kernels: &dyn BlockKernels,
    a: &BlockMatrix,
    job: &JobConfig,
) -> Result<BlockMatrix> {
    let ckpt = checkpoint::boundary();
    let b = a.nblocks();
    if let Some(level) = &ckpt {
        if let Some(restored) = level.try_restore("l", b, a.block_size()) {
            record_ckpt(cluster, 0, 1);
            return Ok(restored);
        }
    }
    let l = block_cholesky_compute(cluster, kernels, a, job)?;
    if let Some(level) = &ckpt {
        record_ckpt(cluster, level.persist("l", &l) as usize, 0);
    }
    Ok(l)
}

fn block_cholesky_compute(
    cluster: &Cluster,
    kernels: &dyn BlockKernels,
    a: &BlockMatrix,
    job: &JobConfig,
) -> Result<BlockMatrix> {
    let b = a.nblocks();
    if b == 1 {
        // Leaf: serial Cholesky on one worker; a non-positive pivot here
        // is the documented non-SPD failure mode.
        return a.map_blocks_try(cluster, method::LEAF_NODE, |m| kernels.cholesky_factor(m));
    }

    let exec = PlanExec::new(cluster, kernels);
    let ae = MatExpr::source(a.clone());
    // A12 = A21ᵀ by the symmetry gate — never evaluated.
    let (a11e, _a12e, a21e, a22e) = ae.split()?;

    let a11 = exec.eval(&a11e)?;
    let l11 = block_cholesky(cluster, kernels, &a11, job)?;
    let l11i = invert_block_lower(cluster, kernels, &l11, job)?;

    // L21 = A21·L11⁻ᵀ; the node is shared by the Schur update and the
    // final arrange, so it lowers once (executor per-node memoization).
    let l21e = a21e.multiply(&MatExpr::source(l11i).transpose())?;
    // S = A22 − L21·L21ᵀ (symmetric Schur complement; stays SPD).
    let se = a22e.subtract(&l21e.multiply(&l21e.transpose())?)?;
    let s = exec.eval(&se)?;
    let l22 = block_cholesky(cluster, kernels, &s, job)?;

    let half = a11.nblocks();
    let bs = a11.block_size();
    let zero = MatExpr::source(BlockMatrix::zeros(half, bs)?);
    let le = MatExpr::arrange(&MatExpr::source(l11), &zero, &l21e, &MatExpr::source(l22))?;
    exec.eval(&le)
}

// ---------------------------------------------------------------------------
// Static analysis model
// ---------------------------------------------------------------------------
//
// Unexecuted restatement of the eager recursion above for the plan
// verifier: one factor recursion + one triangular inversion (shared
// verbatim with the LU model) + one full-size product. Entry rounds
// `C(b) + L(b) + 1` (C(g) = 2C(g/2) + L(g/2) + 2) reproduce the analytic
// 10/30/78 exchange stages at b = 2/4/8.

/// Entry: `A⁻¹ = L⁻ᵀ·L⁻¹` — factor, invert the lower triangle, multiply.
pub(crate) fn model_entry(a: &MatExpr) -> Result<MatExpr> {
    let l = a.invert("chol.factor");
    let li = l.invert("tri.lower");
    li.transpose().multiply(&li)
}

/// One `block_cholesky_compute` level: `L21 = A21·L11⁻ᵀ`, the symmetric
/// Schur update `S = A22 − L21·L21ᵀ` (unfused `D − A·B` shape), two
/// factor recursions and one triangular inversion.
pub(crate) fn model_factor(a: &MatExpr) -> Result<MatExpr> {
    let (a11, _a12, a21, a22) = a.split()?;
    let l11 = a11.invert("chol.factor");
    let l11i = l11.invert("tri.lower");
    let l21 = a21.multiply(&l11i.transpose())?;
    let s = a22.subtract(&l21.multiply(&l21.transpose())?)?;
    let l22 = s.invert("chol.factor");
    let zero = MatExpr::source(BlockMatrix::zeros(a11.nblocks(), a11.block_size())?);
    MatExpr::arrange(&l11, &zero, &l21, &l22)
}

pub(crate) fn analysis_model() -> AlgoModel {
    use crate::analysis::{AlgoModel, Procedure};
    AlgoModel {
        entry: "cholesky",
        procedures: vec![
            // The entry's final product is a plan multiply at any grid.
            Procedure { name: "cholesky", min_grid: 1, build: model_entry },
            Procedure { name: "chol.factor", min_grid: 2, build: model_factor },
            Procedure {
                name: "tri.lower",
                min_grid: 2,
                build: crate::algos::lu::model_tri_lower,
            },
        ],
        iteration: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, GeneratorKind};
    use crate::linalg::inverse_residual;
    use crate::runtime::NativeBackend;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig::local(4))
    }

    fn spd_job(n: usize, bs: usize) -> JobConfig {
        let mut job = JobConfig::new(n, bs);
        job.generator = GeneratorKind::Spd;
        job
    }

    fn invert_and_check(n: usize, bs: usize) {
        let c = cluster();
        let job = spd_job(n, bs);
        let a = BlockMatrix::random(&job).unwrap();
        let inv = cholesky_inverse_impl(&c, &NativeBackend, &a, &job).unwrap();
        let resid = inverse_residual(&a.to_dense().unwrap(), &inv.to_dense().unwrap());
        assert!(resid < 1e-10, "n={n} bs={bs}: residual {resid:.3e}");
    }

    #[test]
    fn single_block() {
        invert_and_check(8, 8);
    }

    #[test]
    fn two_by_two() {
        invert_and_check(16, 8);
    }

    #[test]
    fn deeper_recursion() {
        invert_and_check(32, 4);
        invert_and_check(64, 16);
    }

    #[test]
    fn factor_reconstructs_spd() {
        let c = cluster();
        let job = spd_job(32, 8);
        let a = BlockMatrix::random(&job).unwrap();
        let l = block_cholesky(&c, &NativeBackend, &a, &job).unwrap();
        let lt = l.transpose(&c);
        let prod = l.multiply(&c, &NativeBackend, &lt).unwrap();
        let diff = prod.to_dense().unwrap().max_abs_diff(&a.to_dense().unwrap());
        assert!(diff < 1e-9, "L·Lᵀ ≠ A: {diff}");
        assert!(crate::linalg::is_lower_triangular(&l.to_dense().unwrap(), 1e-10));
    }

    #[test]
    fn rejects_asymmetric_input() {
        let c = cluster();
        let job = JobConfig::new(16, 4); // diag-dominant: not symmetric
        let a = BlockMatrix::random(&job).unwrap();
        let err = cholesky_inverse_impl(&c, &NativeBackend, &a, &job)
            .unwrap_err()
            .to_string();
        assert!(err.contains("symmetric"), "{err}");
    }

    #[test]
    fn rejects_indefinite_input() {
        // Symmetric but indefinite: eigenvalues 3 and −1 in each 2×2
        // diagonal sub-block.
        let mut dense = crate::linalg::Matrix::identity(8);
        for i in (0..8).step_by(2) {
            dense.set(i, i + 1, 2.0);
            dense.set(i + 1, i, 2.0);
        }
        let a = BlockMatrix::from_dense(&dense, 2).unwrap();
        let c = cluster();
        let job = spd_job(8, 2);
        let err = cholesky_inverse_impl(&c, &NativeBackend, &a, &job)
            .unwrap_err()
            .to_string();
        assert!(err.contains("not positive definite"), "{err}");
    }

    #[test]
    fn agrees_with_spin_on_spd() {
        let c1 = cluster();
        let c2 = cluster();
        let job = spd_job(32, 8);
        let a = BlockMatrix::random(&job).unwrap();
        let chol = cholesky_inverse_impl(&c1, &NativeBackend, &a, &job).unwrap();
        let spin = crate::algos::spin::spin_inverse_impl(&c2, &NativeBackend, &a, &job).unwrap();
        let diff = chol
            .to_dense()
            .unwrap()
            .max_abs_diff(&spin.to_dense().unwrap());
        assert!(diff < 1e-8, "cholesky vs SPIN diff {diff}");
    }

    #[test]
    fn beats_lu_exchange_counters() {
        // Symmetry halves the per-level structure: strictly fewer
        // exchange stages than the LU baseline at every multi-block
        // grid. Counters depend only on the grid, so small n suffices.
        for (n, bs) in [(16usize, 4usize), (32, 4), (64, 8)] {
            let c_chol = cluster();
            let c_lu = cluster();
            let job = spd_job(n, bs);
            let a = BlockMatrix::random(&job).unwrap();
            let _ = cholesky_inverse_impl(&c_chol, &NativeBackend, &a, &job).unwrap();
            let _ = crate::algos::lu::lu_inverse_distributed_impl(&c_lu, &NativeBackend, &a, &job)
                .unwrap();
            let chol = c_chol.metrics_totals().shuffle_stages;
            let lu = c_lu.metrics_totals().shuffle_stages;
            assert!(
                chol < lu,
                "n={n} b={}: cholesky exchanges {chol} !< lu {lu}",
                n / bs
            );
        }
    }
}
