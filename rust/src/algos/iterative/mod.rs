//! The iterative & structure-exploiting inversion subsystem — the two
//! post-paper registry entries that prove the plan/optimizer/executor
//! stack generalizes past SPIN and the LU baseline:
//!
//! * [`NewtonAlgorithm`] (`newton`) — Newton–Schulz approximate inverse,
//!   the "fast approximate answer under an SLA" serving mode. Each
//!   iteration `X ← X(2I − A·X)` is expressed as one lazy plan and driven
//!   through the standard optimizer/fusion rules; a driver-side
//!   convergence loop tracks the residual trajectory and stops early at
//!   `JobConfig::tolerance` or the `JobConfig::max_iters` budget
//!   (cf. Charalambides, Pilanci & Hero, arXiv 2003.02948).
//!
//! * [`CholeskyAlgorithm`] (`cholesky`) — block-recursive Cholesky
//!   inversion for symmetric positive-definite inputs, the structure-
//!   exploiting fast path (cf. Zadeh et al., arXiv 1509.02256): one
//!   recursive factor + one triangular inversion + one product, strictly
//!   fewer exchange stages than the LU baseline *and* SPIN at every grid.
//!
//! Both ride the same [`crate::plan::MatExpr`]/[`crate::plan::PlanExec`]
//! substrate as the seed algorithms, so counter comparisons measure
//! algorithm structure, not dataflow overhead.

mod cholesky;
mod newton;

pub use cholesky::CholeskyAlgorithm;
pub use newton::NewtonAlgorithm;
