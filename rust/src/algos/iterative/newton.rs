//! Newton–Schulz iterative inverse approximation.
//!
//! The iteration `X_{k+1} = X_k(2I − A·X_k)` converges quadratically
//! whenever `‖I − A·X₀‖ < 1`. We seed with the scaled transpose
//! `X₀ = Aᵀ/(‖A‖₁·‖A‖∞)`, which satisfies that bound for **every**
//! nonsingular `A` (σ_max(A)² ≤ ‖A‖₁·‖A‖∞, a classical norm inequality),
//! so convergence is guaranteed for the crate's diag-dominant and SPD
//! generator families — only the iteration *count* depends on
//! conditioning.
//!
//! ## One plan per iteration
//!
//! Each pass builds `M_k = 2I − A·X_k` as ONE lazy plan (a `Multiply`
//! under a `Subtract` against the loop-invariant `2I` source) and lowers
//! it through the standard optimizer. Note the shape is `D − A·B`, which
//! the fusion rule correctly does NOT turn into `multiply_sub` (that
//! fusion only matches `A·B − D`) — the optimizer-rule contract holds
//! with zero special-casing. The residual `‖I − A·X_k‖∞ = ‖M_k − I‖∞`
//! is then read off `M_k` driver-side for free, and the update
//! `X_{k+1} = X_k·M_k` reuses `M_k`'s memoized value through the plan
//! executor's per-node slot — each non-final pass pays exactly two
//! distributed multiplies, and the final pass only one.
//!
//! ## SLA semantics
//!
//! The driver stops as soon as the residual reaches
//! `JobConfig::tolerance`, or after `JobConfig::max_iters` passes. A run
//! that exhausts its budget still returns the best iterate — with
//! `converged: false` in its [`ConvergenceReport`] — because the serving
//! mode's contract is "the best answer by the deadline", not "exact or
//! nothing". Non-finite residuals (a singular input driving the
//! iteration apart) are a hard numerical error.

use crate::blockmatrix::BlockMatrix;
use crate::cluster::{Cluster, ConvergenceReport};
use crate::config::JobConfig;
use crate::error::{Result, SpinError};
use crate::plan::{MatExpr, PlanExec};
use crate::runtime::BlockKernels;

use super::super::registry::InversionAlgorithm;

/// Newton–Schulz approximate inverse (`newton` in the registry).
pub struct NewtonAlgorithm;

impl InversionAlgorithm for NewtonAlgorithm {
    fn name(&self) -> &str {
        "newton"
    }

    fn description(&self) -> &str {
        "Newton-Schulz iterative inverse (early-stop at tolerance/max_iters)"
    }

    fn iterative(&self) -> bool {
        true
    }

    fn convergence_note(&self) -> Option<String> {
        Some(
            "convergence loop: repeat the plan above (X ← X·(2I − A·X), seeded X₀ = Aᵀ/(‖A‖₁‖A‖∞)) \
             until ‖I − A·Xₖ‖∞ ≤ tolerance or max_iters passes; residual read driver-side from \
             the 2I − A·X value each pass"
                .to_string(),
        )
    }

    fn invert(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        a: &BlockMatrix,
        job: &JobConfig,
    ) -> Result<BlockMatrix> {
        newton_inverse_impl(cluster, kernels, a, job)
    }

    fn plan(&self, a: &MatExpr) -> Result<Option<MatExpr>> {
        pass_plan(a).map(Some)
    }

    fn analysis_model(&self) -> Option<AlgoModel> {
        Some(analysis_model())
    }
}

/// One iteration of the loop, as the convergence note explains: two
/// distributed multiplies (`A·X` and `X·M`), everything else narrow. The
/// seed's true scale factor 1/(‖A‖₁‖A‖∞) is data-dependent; 0.5 stands
/// in so the scale node renders instead of folding.
pub(crate) fn pass_plan(a: &MatExpr) -> Result<MatExpr> {
    let x0 = a.transpose().scale(0.5);
    let two_i = MatExpr::source(BlockMatrix::identity(a.n(), a.block_size())?).scale(2.0);
    let m = two_i.subtract(&a.multiply(&x0)?)?;
    x0.multiply(&m)
}

/// Static iteration model for the plan verifier: `max_iters` passes of
/// [`pass_plan`], the final pass paying only the residual's `A·X` round
/// (the root update is skipped once the budget or tolerance is reached) —
/// the `2·(2·max_iters − 1)` exchange-stage ceiling the bench gates.
pub(crate) fn analysis_model() -> AlgoModel {
    use crate::analysis::{AlgoModel, IterationModel, Procedure};
    AlgoModel {
        entry: "newton",
        procedures: vec![Procedure { name: "newton", min_grid: 1, build: pass_plan }],
        iteration: Some(IterationModel { final_pass_drops_root: true }),
    }
}

/// The driver loop (see module docs for the per-pass plan structure).
pub(crate) fn newton_inverse_impl(
    cluster: &Cluster,
    kernels: &dyn BlockKernels,
    a: &BlockMatrix,
    job: &JobConfig,
) -> Result<BlockMatrix> {
    let n = a.n();
    let bs = a.block_size();
    let tol = job.tolerance;
    let max_iters = job.max_iters;

    // Seed scale from the two driver-side norms. Zero norms mean a zero
    // matrix — singular, and the iteration could never move off X₀ = 0.
    let dense = a.to_dense()?;
    let norm_product = dense.one_norm() * dense.inf_norm();
    if norm_product <= 0.0 || !norm_product.is_finite() {
        return Err(SpinError::numerical(format!(
            "newton seed undefined: ‖A‖₁·‖A‖∞ = {norm_product:.3e}"
        )));
    }

    let exec = PlanExec::new(cluster, kernels);
    let ae = MatExpr::source(a.clone());
    // Loop-invariant 2I: one shared plan node, so its (narrow) scaling
    // runs once and every iteration's subtract reuses the memoized value.
    let two_i = MatExpr::source(BlockMatrix::identity(n, bs)?).scale(2.0);

    // X₀ = Aᵀ/(‖A‖₁‖A‖∞): transpose + scale are narrow — no exchange.
    let mut x = exec.eval(&ae.transpose().scale(1.0 / norm_product))?;

    let mut residuals: Vec<f64> = Vec::new();
    let mut converged = false;
    for pass in 1..=max_iters {
        let xe = MatExpr::source(x.clone());
        let me = two_i.subtract(&ae.multiply(&xe)?)?;
        let m = exec.eval(&me)?;

        // M − I = I − A·X, so the iterate's residual is ‖M − I‖∞.
        let md = m.to_dense()?;
        let mut r: f64 = 0.0;
        for i in 0..n {
            let mut row = 0.0;
            for j in 0..n {
                let expect = if i == j { 1.0 } else { 0.0 };
                row += (md.get(i, j) - expect).abs();
            }
            r = r.max(row);
        }
        residuals.push(r);
        if !r.is_finite() {
            return Err(SpinError::numerical(format!(
                "newton diverged at iteration {pass}: residual {r}"
            )));
        }
        if r <= tol {
            converged = true;
            break;
        }
        if pass == max_iters {
            // Budget exhausted: return THIS iterate (whose residual we
            // just measured) rather than paying for an update we could
            // not verify.
            break;
        }
        // X_{k+1} = X_k·M_k — M_k's value is memoized on its plan node,
        // so this costs one distributed multiply, not a recompute.
        x = exec.eval(&xe.multiply(&me)?)?;
    }

    // `max_iters >= 1` is validated at submit, so the loop always pushes
    // at least one residual; the fallback is unreachable but panic-free.
    let final_residual = residuals.last().copied().unwrap_or(f64::INFINITY);
    cluster.record_convergence(ConvergenceReport {
        algo: "newton".to_string(),
        iterations: residuals.len(),
        converged,
        tolerance: tol,
        final_residual,
        residuals,
    });
    Ok(x)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, GeneratorKind};
    use crate::linalg::inverse_residual;
    use crate::runtime::NativeBackend;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig::local(4))
    }

    fn job(n: usize, bs: usize, gen: GeneratorKind) -> JobConfig {
        let mut job = JobConfig::new(n, bs);
        job.generator = gen;
        job
    }

    #[test]
    fn converges_on_diag_dominant_with_early_stop() {
        let c = cluster();
        let mut j = job(32, 8, GeneratorKind::DiagDominant);
        j.tolerance = 1e-10;
        j.max_iters = 64;
        let a = BlockMatrix::random(&j).unwrap();
        let inv = newton_inverse_impl(&c, &NativeBackend, &a, &j).unwrap();
        let resid = inverse_residual(&a.to_dense().unwrap(), &inv.to_dense().unwrap());
        assert!(resid < 1e-8, "residual {resid:.3e}");
        let reports = c.metrics_scoped(0).convergence().to_vec();
        assert_eq!(reports.len(), 1);
        let r = &reports[0];
        assert!(r.converged);
        assert!(
            r.iterations < j.max_iters,
            "early stop not honored: {} iterations",
            r.iterations
        );
        assert_eq!(r.iterations, r.residuals.len());
        assert!(r.final_residual <= j.tolerance);
        // Quadratic convergence: the trajectory is strictly decreasing
        // once contraction kicks in; at minimum the last step improves.
        assert!(r.residuals.last().unwrap() <= r.residuals.first().unwrap());
    }

    #[test]
    fn converges_on_spd() {
        let c = cluster();
        let mut j = job(32, 4, GeneratorKind::Spd);
        j.tolerance = 1e-9;
        let a = BlockMatrix::random(&j).unwrap();
        let inv = newton_inverse_impl(&c, &NativeBackend, &a, &j).unwrap();
        let resid = inverse_residual(&a.to_dense().unwrap(), &inv.to_dense().unwrap());
        assert!(resid < 1e-8, "residual {resid:.3e}");
        let totals = c.convergence_totals();
        assert_eq!(totals.runs, 1);
        assert_eq!(totals.converged_runs, 1);
    }

    #[test]
    fn loose_tolerance_stops_sooner() {
        let j_strict = {
            let mut j = job(32, 8, GeneratorKind::DiagDominant);
            j.tolerance = 1e-12;
            j
        };
        let j_loose = {
            let mut j = job(32, 8, GeneratorKind::DiagDominant);
            j.tolerance = 1e-2;
            j
        };
        let iters = |j: &JobConfig| {
            let c = cluster();
            let a = BlockMatrix::random(j).unwrap();
            newton_inverse_impl(&c, &NativeBackend, &a, j).unwrap();
            c.metrics_scoped(0).convergence()[0].iterations
        };
        let strict = iters(&j_strict);
        let loose = iters(&j_loose);
        assert!(
            loose < strict,
            "loose tolerance ran {loose} iterations vs strict {strict}"
        );
    }

    #[test]
    fn exhausted_budget_returns_best_iterate_unconverged() {
        let c = cluster();
        let mut j = job(32, 8, GeneratorKind::DiagDominant);
        j.tolerance = 1e-14; // unreachable in 2 passes
        j.max_iters = 2;
        let a = BlockMatrix::random(&j).unwrap();
        // SLA semantics: Ok, not Err — the best-so-far iterate.
        let inv = newton_inverse_impl(&c, &NativeBackend, &a, &j).unwrap();
        assert!(inv.to_dense().unwrap().all_finite());
        let reports = c.metrics_scoped(0).convergence().to_vec();
        let r = &reports[0];
        assert!(!r.converged);
        assert_eq!(r.iterations, 2);
        assert!(r.final_residual > j.tolerance);
        let totals = c.convergence_totals();
        assert_eq!((totals.runs, totals.converged_runs), (1, 0));
    }

    #[test]
    fn matches_exact_inverse() {
        let c1 = cluster();
        let c2 = cluster();
        let mut j = job(16, 4, GeneratorKind::DiagDominant);
        j.tolerance = 1e-13;
        let a = BlockMatrix::random(&j).unwrap();
        let newton = newton_inverse_impl(&c1, &NativeBackend, &a, &j).unwrap();
        let spin = crate::algos::spin::spin_inverse_impl(&c2, &NativeBackend, &a, &j).unwrap();
        let diff = newton
            .to_dense()
            .unwrap()
            .max_abs_diff(&spin.to_dense().unwrap());
        assert!(diff < 1e-9, "newton vs spin diff {diff}");
    }

    #[test]
    fn schur_shape_is_not_miss_fused() {
        // 2I − A·X is D − A·B: the multiply_sub fusion must not fire.
        let c = cluster();
        let j = job(16, 4, GeneratorKind::DiagDominant);
        let a = BlockMatrix::random(&j).unwrap();
        let _ = newton_inverse_impl(&c, &NativeBackend, &a, &j).unwrap();
        let snap = c.metrics();
        assert!(snap.method("subtract").is_some());
        assert!(!snap.plan_nodes().iter().any(|p| p.op == "multiply_sub"));
    }

    #[test]
    fn exchange_count_is_deterministic_per_iteration_count() {
        // Every pass pays the same stage structure, so exchanges are a
        // pure function of the iteration count — the property the bench
        // gate relies on.
        let counts = |seed: u64| {
            let c = cluster();
            let mut j = job(32, 8, GeneratorKind::DiagDominant);
            j.seed = seed;
            let a = BlockMatrix::random(&j).unwrap();
            newton_inverse_impl(&c, &NativeBackend, &a, &j).unwrap();
            let iters = c.metrics_scoped(0).convergence()[0].iterations;
            (iters, c.metrics_totals().shuffle_stages)
        };
        let (i1, e1) = counts(7);
        let (i2, e2) = counts(7);
        assert_eq!((i1, e1), (i2, e2), "same input must replay identically");
    }
}
