//! The baseline: block-recursive LU-decomposition inversion (Liu et al.,
//! "Spark-based large-scale matrix inversion for big data processing",
//! IEEE Access 2016) — the competitor the paper evaluates SPIN against.
//!
//! Structure (matching the paper's Lemma 4.2 accounting):
//! 1. recursive block LU: `A = L·U` — per level 2 recursive LU calls,
//!    2 triangular-inverse subcomputations, 3 multiplies, 1 subtract;
//! 2. recursive block-triangular inversions of L and U — per level
//!    2 recursive calls + 2 multiplies + 1 negation each;
//! 3. the final full-size product `A⁻¹ = U⁻¹·L⁻¹` (the paper's
//!    "additional cost", 7·(n/2)³ in their count).
//!
//! Like SPIN, every recursion level's arithmetic is expressed as a lazy
//! [`MatExpr`] plan and lowered by [`PlanExec`] — the baseline rides the
//! same plan layer and partitioner-aware substrate, so the SPIN-vs-LU
//! comparison measures algorithm structure, not dataflow overhead. Note
//! the Schur update here is `S = A22 − L21·U12` (`D − A·B`), which does
//! **not** match the `A·B − D` fusion pattern — the optimizer correctly
//! leaves it unfused, exactly as the eager code did. Laziness has one
//! free win: the triangular-inverse levels never evaluate their
//! structurally-zero quadrant, so its extraction pass is skipped.
//!
//! At the leaves the baseline pays 3 serial O((n/b)³) kernels per block
//! position (LU factor + two triangular inverses) versus SPIN's single
//! leaf inversion — the "9×" leaf-cost gap the paper cites collapses to
//! ~3× in this formulation, but the direction and growth with b are
//! preserved (see EXPERIMENTS.md).
//!
//! Block-level LU uses no pivoting (pivoting breaks the block recursion;
//! Liu et al. make the same restriction) — the workload generators keep
//! every principal minor nonsingular.

use crate::blockmatrix::ops_method as method;
use crate::blockmatrix::BlockMatrix;
use crate::cluster::{Cluster, ResilienceTotals};
use crate::config::JobConfig;
use crate::error::{Result, SpinError};
use crate::plan::{MatExpr, PlanExec};
use crate::runtime::BlockKernels;
use crate::store::checkpoint;

/// Record checkpoint activity on this job's metric scope (no-op deltas
/// are dropped by the metrics layer).
fn record_ckpt(cluster: &Cluster, written: usize, restored: usize) {
    cluster.record_resilience(&ResilienceTotals {
        checkpoints_written: written,
        checkpoints_restored: restored,
        ..ResilienceTotals::default()
    });
}

/// Block-recursive LU inversion implementation entry — reached through
/// [`crate::algos::LuAlgorithm`] in the registry.
pub(crate) fn lu_inverse_distributed_impl(
    cluster: &Cluster,
    kernels: &dyn BlockKernels,
    a: &BlockMatrix,
    job: &JobConfig,
) -> Result<BlockMatrix> {
    if !a.nblocks().is_power_of_two() {
        return Err(SpinError::shape(format!(
            "LU baseline needs a power-of-two block grid, got {}",
            a.nblocks()
        )));
    }
    // Root checkpoint boundary: without it the three top-level phases
    // would all be recursion roots and the two triangular inversions
    // would collide on the same `r-m` key. The residual check below runs
    // on restored results too.
    let ckpt = checkpoint::boundary();
    let restored = ckpt
        .as_ref()
        .and_then(|level| level.try_restore("m", a.nblocks(), a.block_size()));
    let inv = match restored {
        Some(inv) => {
            record_ckpt(cluster, 0, 1);
            inv
        }
        None => {
            let (l, u) = block_lu(cluster, kernels, a, job)?;
            let li = invert_block_lower(cluster, kernels, &l, job)?;
            let ui = invert_block_upper(cluster, kernels, &u, job)?;
            // Additional cost: the full-size product U⁻¹ · L⁻¹.
            let exec = PlanExec::new(cluster, kernels);
            let inv = exec.eval(&MatExpr::source(ui).multiply(&MatExpr::source(li))?)?;
            if let Some(level) = &ckpt {
                record_ckpt(cluster, level.persist("m", &inv) as usize, 0);
            }
            inv
        }
    };
    if job.residual_check {
        let resid = crate::linalg::inverse_residual(&a.to_dense()?, &inv.to_dense()?);
        if resid > 1e-8 {
            return Err(SpinError::numerical(format!(
                "LU baseline residual check failed: {resid:.3e}"
            )));
        }
    }
    Ok(inv)
}

/// Recursive block LU: A = L·U (L unit-lower per leaf convention of the
/// serial kernel, U upper). One plan executor per level; the shared
/// `U12`/`L21` expressions are evaluated once (for the Schur update) and
/// their memoized values feed the L/U assembly plans.
fn block_lu(
    cluster: &Cluster,
    kernels: &dyn BlockKernels,
    a: &BlockMatrix,
    job: &JobConfig,
) -> Result<(BlockMatrix, BlockMatrix)> {
    // This boundary produces a PAIR, checkpointed as two parts under one
    // path key; resume restores both or recomputes both.
    let ckpt = checkpoint::boundary();
    let b = a.nblocks();
    if let Some(level) = &ckpt {
        let l = level.try_restore("l", b, a.block_size());
        let u = level.try_restore("u", b, a.block_size());
        if let (Some(l), Some(u)) = (l, u) {
            record_ckpt(cluster, 0, 2);
            return Ok((l, u));
        }
    }
    let (l, u) = block_lu_compute(cluster, kernels, a, job)?;
    if let Some(level) = &ckpt {
        let wrote = level.persist("l", &l) as usize + level.persist("u", &u) as usize;
        record_ckpt(cluster, wrote, 0);
    }
    Ok((l, u))
}

fn block_lu_compute(
    cluster: &Cluster,
    kernels: &dyn BlockKernels,
    a: &BlockMatrix,
    job: &JobConfig,
) -> Result<(BlockMatrix, BlockMatrix)> {
    let b = a.nblocks();
    if b == 1 {
        // Leaf: serial LU on one worker (the paper's "2 LU decompositions"
        // per leaf pair live across the recursion's two child calls).
        let l = a.map_blocks_try(cluster, method::LEAF_NODE, |m| {
            kernels.lu_factor(m).map(|(l, _)| l)
        })?;
        let u = a.map_blocks_try(cluster, method::LEAF_NODE, |m| {
            kernels.lu_factor(m).map(|(_, u)| u)
        })?;
        return Ok((l, u));
    }

    let exec = PlanExec::new(cluster, kernels);
    let ae = MatExpr::source(a.clone());
    let (a11e, a12e, a21e, a22e) = ae.split()?;

    let a11 = exec.eval(&a11e)?;
    let (l11, u11) = block_lu(cluster, kernels, &a11, job)?;
    let l11i = invert_block_lower(cluster, kernels, &l11, job)?;
    let u11i = invert_block_upper(cluster, kernels, &u11, job)?;

    let u12e = MatExpr::source(l11i).multiply(&a12e)?; // U12 = L11⁻¹·A12
    let l21e = a21e.multiply(&MatExpr::source(u11i))?; // L21 = A21·U11⁻¹
    let se = a22e.subtract(&l21e.multiply(&u12e)?)?; //  S = A22 − L21·U12
    let s = exec.eval(&se)?;
    let (l22, u22) = block_lu(cluster, kernels, &s, job)?;

    let half = a11.nblocks();
    let bs = a11.block_size();
    let zero = MatExpr::source(BlockMatrix::zeros(half, bs)?);
    let le = MatExpr::arrange(&MatExpr::source(l11), &zero, &l21e, &MatExpr::source(l22))?;
    let ue = MatExpr::arrange(&MatExpr::source(u11), &u12e, &zero, &MatExpr::source(u22))?;
    Ok((exec.eval(&le)?, exec.eval(&ue)?))
}

/// Recursive inversion of a block lower-triangular matrix:
/// `[[L11,0],[L21,L22]]⁻¹ = [[L11⁻¹, 0], [−L22⁻¹·L21·L11⁻¹, L22⁻¹]]`.
/// Shared with the Cholesky scheme (`A⁻¹ = L⁻ᵀ·L⁻¹` needs the same
/// triangular inversion).
pub(crate) fn invert_block_lower(
    cluster: &Cluster,
    kernels: &dyn BlockKernels,
    l: &BlockMatrix,
    job: &JobConfig,
) -> Result<BlockMatrix> {
    let ckpt = checkpoint::boundary();
    let b = l.nblocks();
    if let Some(level) = &ckpt {
        if let Some(restored) = level.try_restore("m", b, l.block_size()) {
            record_ckpt(cluster, 0, 1);
            return Ok(restored);
        }
    }
    if b == 1 {
        return l.map_blocks_try(cluster, method::LEAF_NODE, |m| kernels.invert_lower(m));
    }
    let exec = PlanExec::new(cluster, kernels);
    let le = MatExpr::source(l.clone());
    // The upper-right quadrant is structurally zero and never evaluated.
    let (l11e, _zero12, l21e, l22e) = le.split()?;
    let li11 = MatExpr::source(invert_block_lower(
        cluster,
        kernels,
        &exec.eval(&l11e)?,
        job,
    )?);
    let li22 = MatExpr::source(invert_block_lower(
        cluster,
        kernels,
        &exec.eval(&l22e)?,
        job,
    )?);
    let c21 = li22.multiply(&l21e)?.multiply(&li11)?.scale(-1.0);
    let zero = MatExpr::source(BlockMatrix::zeros(l11e.nblocks(), l11e.block_size())?);
    let inv = exec.eval(&MatExpr::arrange(&li11, &zero, &c21, &li22)?)?;
    if let Some(level) = &ckpt {
        record_ckpt(cluster, level.persist("m", &inv) as usize, 0);
    }
    Ok(inv)
}

/// Recursive inversion of a block upper-triangular matrix:
/// `[[U11,U12],[0,U22]]⁻¹ = [[U11⁻¹, −U11⁻¹·U12·U22⁻¹], [0, U22⁻¹]]`.
fn invert_block_upper(
    cluster: &Cluster,
    kernels: &dyn BlockKernels,
    u: &BlockMatrix,
    job: &JobConfig,
) -> Result<BlockMatrix> {
    let ckpt = checkpoint::boundary();
    let b = u.nblocks();
    if let Some(level) = &ckpt {
        if let Some(restored) = level.try_restore("m", b, u.block_size()) {
            record_ckpt(cluster, 0, 1);
            return Ok(restored);
        }
    }
    if b == 1 {
        return u.map_blocks_try(cluster, method::LEAF_NODE, |m| kernels.invert_upper(m));
    }
    let exec = PlanExec::new(cluster, kernels);
    let ue = MatExpr::source(u.clone());
    // The lower-left quadrant is structurally zero and never evaluated.
    let (u11e, u12e, _zero21, u22e) = ue.split()?;
    let ui11 = MatExpr::source(invert_block_upper(
        cluster,
        kernels,
        &exec.eval(&u11e)?,
        job,
    )?);
    let ui22 = MatExpr::source(invert_block_upper(
        cluster,
        kernels,
        &exec.eval(&u22e)?,
        job,
    )?);
    let c12 = ui11.multiply(&u12e)?.multiply(&ui22)?.scale(-1.0);
    let zero = MatExpr::source(BlockMatrix::zeros(u11e.nblocks(), u11e.block_size())?);
    let inv = exec.eval(&MatExpr::arrange(&ui11, &c12, &zero, &ui22)?)?;
    if let Some(level) = &ckpt {
        record_ckpt(cluster, level.persist("m", &inv) as usize, 0);
    }
    Ok(inv)
}

// ---------------------------------------------------------------------------
// Static analysis model
// ---------------------------------------------------------------------------
//
// The eager recursion above materializes per level, so an executed LU job
// never contains an `invert[lu]` plan node to walk. These procedures
// restate each level's dataflow as unexecuted plans — same multiplies,
// subtracts, scales, and arranges — for the verifier to unfold
// (`analysis::algo_cost`). The derived entry cost
// `F(b) + 2·L(b) + 1` rounds (F(g) = 2F(g/2) + 2L(g/2) + 3,
// L(g) = 2L(g/2) + 2) reproduces the analytic 16/52/140 exchange stages
// at b = 2/4/8, cross-checked against `costmodel::lemma42`.

/// Entry: factor once, invert both triangles, one full-size product.
/// The shared `lu.factor` node mirrors `block_lu` running once for both
/// triangular inversions.
pub(crate) fn model_entry(a: &MatExpr) -> Result<MatExpr> {
    let f = a.invert("lu.factor");
    let li = f.invert("tri.lower");
    let ui = f.invert("tri.upper");
    ui.multiply(&li)
}

/// One `block_lu_compute` level: 3 half-grid multiplies + the unfused
/// `A22 − L21·U12` Schur update (the `D − A·B` shape the fusion rule
/// correctly leaves alone), two factor recursions and one triangular
/// inversion of each kind.
pub(crate) fn model_factor(a: &MatExpr) -> Result<MatExpr> {
    let (a11, a12, a21, a22) = a.split()?;
    let f11 = a11.invert("lu.factor");
    let l11i = f11.invert("tri.lower");
    let u11i = f11.invert("tri.upper");
    let u12 = l11i.multiply(&a12)?; //           U12 = L11⁻¹·A12
    let l21 = a21.multiply(&u11i)?; //           L21 = A21·U11⁻¹
    let s = a22.subtract(&l21.multiply(&u12)?)?; // S = A22 − L21·U12
    let sf = s.invert("lu.factor");
    MatExpr::arrange(&f11, &u12, &l21, &sf)
}

/// One `invert_block_lower` level: two recursions + the two-multiply
/// corner `−L22⁻¹·L21·L11⁻¹`. Shared verbatim with the Cholesky model.
pub(crate) fn model_tri_lower(l: &MatExpr) -> Result<MatExpr> {
    let (l11, _zero12, l21, l22) = l.split()?;
    let li11 = l11.invert("tri.lower");
    let li22 = l22.invert("tri.lower");
    let c21 = li22.multiply(&l21)?.multiply(&li11)?.scale(-1.0);
    let zero = MatExpr::source(BlockMatrix::zeros(l11.nblocks(), l11.block_size())?);
    MatExpr::arrange(&li11, &zero, &c21, &li22)
}

/// One `invert_block_upper` level (mirror of [`model_tri_lower`]).
pub(crate) fn model_tri_upper(u: &MatExpr) -> Result<MatExpr> {
    let (u11, u12, _zero21, u22) = u.split()?;
    let ui11 = u11.invert("tri.upper");
    let ui22 = u22.invert("tri.upper");
    let c12 = ui11.multiply(&u12)?.multiply(&ui22)?.scale(-1.0);
    let zero = MatExpr::source(BlockMatrix::zeros(u11.nblocks(), u11.block_size())?);
    MatExpr::arrange(&ui11, &c12, &zero, &ui22)
}

pub(crate) fn analysis_model() -> AlgoModel {
    use crate::analysis::{AlgoModel, Procedure};
    AlgoModel {
        entry: "lu",
        procedures: vec![
            // The entry's final product runs as a plan multiply even on a
            // 1×1 grid, so its floor is 1; the recursions leaf at grid 1.
            Procedure { name: "lu", min_grid: 1, build: model_entry },
            Procedure { name: "lu.factor", min_grid: 2, build: model_factor },
            Procedure { name: "tri.lower", min_grid: 2, build: model_tri_lower },
            Procedure { name: "tri.upper", min_grid: 2, build: model_tri_upper },
        ],
        iteration: None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, GeneratorKind};
    use crate::linalg::inverse_residual;
    use crate::runtime::NativeBackend;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig::local(4))
    }

    fn invert_and_check(n: usize, bs: usize, gen: GeneratorKind) {
        let c = cluster();
        let mut job = JobConfig::new(n, bs);
        job.generator = gen;
        let a = BlockMatrix::random(&job).unwrap();
        let inv = lu_inverse_distributed_impl(&c, &NativeBackend, &a, &job).unwrap();
        let resid = inverse_residual(&a.to_dense().unwrap(), &inv.to_dense().unwrap());
        assert!(resid < 1e-9, "n={n} bs={bs}: residual {resid:.3e}");
    }

    #[test]
    fn single_block() {
        invert_and_check(8, 8, GeneratorKind::DiagDominant);
    }

    #[test]
    fn two_by_two() {
        invert_and_check(16, 8, GeneratorKind::DiagDominant);
    }

    #[test]
    fn deeper_recursion() {
        invert_and_check(32, 4, GeneratorKind::DiagDominant);
        invert_and_check(64, 16, GeneratorKind::Spd);
    }

    #[test]
    fn block_lu_reconstructs() {
        let c = cluster();
        let job = JobConfig::new(16, 4);
        let a = BlockMatrix::random(&job).unwrap();
        let (l, u) = block_lu(&c, &NativeBackend, &a, &job).unwrap();
        let prod = l.multiply(&c, &NativeBackend, &u).unwrap();
        let diff = prod.to_dense().unwrap().max_abs_diff(&a.to_dense().unwrap());
        assert!(diff < 1e-9, "L·U ≠ A: {diff}");
        // L lower, U upper at the dense level.
        assert!(crate::linalg::is_lower_triangular(&l.to_dense().unwrap(), 1e-10));
        assert!(crate::linalg::is_upper_triangular(&u.to_dense().unwrap(), 1e-10));
    }

    #[test]
    fn triangular_inverses_correct() {
        let c = cluster();
        let job = JobConfig::new(16, 4);
        let a = BlockMatrix::random(&job).unwrap();
        let (l, u) = block_lu(&c, &NativeBackend, &a, &job).unwrap();
        let li = invert_block_lower(&c, &NativeBackend, &l, &job).unwrap();
        let ui = invert_block_upper(&c, &NativeBackend, &u, &job).unwrap();
        let eye = crate::linalg::Matrix::identity(16);
        let lprod = l.multiply(&c, &NativeBackend, &li).unwrap().to_dense().unwrap();
        assert!(lprod.max_abs_diff(&eye) < 1e-9);
        let uprod = u.multiply(&c, &NativeBackend, &ui).unwrap().to_dense().unwrap();
        assert!(uprod.max_abs_diff(&eye) < 1e-9);
    }

    #[test]
    fn agrees_with_spin() {
        let c1 = cluster();
        let c2 = cluster();
        let job = JobConfig::new(32, 8);
        let a = BlockMatrix::random(&job).unwrap();
        let lu = lu_inverse_distributed_impl(&c1, &NativeBackend, &a, &job).unwrap();
        let spin = crate::algos::spin::spin_inverse_impl(&c2, &NativeBackend, &a, &job).unwrap();
        let diff = lu.to_dense().unwrap().max_abs_diff(&spin.to_dense().unwrap());
        assert!(diff < 1e-8, "LU vs SPIN diff {diff}");
    }

    #[test]
    fn lu_does_more_leaf_work_than_spin() {
        // The paper's structural claim behind Figure 3: LU pays ≥3 serial
        // leaf kernels per leaf position vs SPIN's 1.
        let c1 = cluster();
        let c2 = cluster();
        let job = JobConfig::new(16, 4);
        let a = BlockMatrix::random(&job).unwrap();
        let _ = lu_inverse_distributed_impl(&c1, &NativeBackend, &a, &job).unwrap();
        let _ = crate::algos::spin::spin_inverse_impl(&c2, &NativeBackend, &a, &job).unwrap();
        let lu_leaf = c1.metrics().method("leafNode").unwrap().calls;
        let spin_leaf = c2.metrics().method("leafNode").unwrap().calls;
        assert!(
            lu_leaf >= 3 * spin_leaf,
            "LU leaf stages {lu_leaf} < 3× SPIN's {spin_leaf}"
        );
    }

    #[test]
    fn schur_update_is_not_miss_fused() {
        // S = A22 − L21·U12 is D − A·B, not A·B − D: the fusion rule must
        // not fire on it (a fused multiply_sub would compute the wrong
        // sign). The metrics prove the subtract stage survives.
        let c = cluster();
        let job = JobConfig::new(16, 4);
        let a = BlockMatrix::random(&job).unwrap();
        let _ = block_lu(&c, &NativeBackend, &a, &job).unwrap();
        let snap = c.metrics();
        assert!(snap.method("subtract").is_some());
        assert!(!snap.plan_nodes().iter().any(|p| p.op == "multiply_sub"));
    }
}
