//! The inversion algorithms: SPIN (the paper's contribution), the
//! LU-decomposition baseline it is evaluated against (Liu et al. 2016),
//! single-node serial references used by tests — and the open
//! [`InversionAlgorithm`] registry new schemes plug into.
//!
//! Dispatch goes through a name-keyed [`AlgorithmRegistry`] (default
//! entries: `spin`, `lu`). Both built-ins express each recursion level as
//! a lazy [`crate::plan::MatExpr`] plan and lower it through
//! [`crate::plan::PlanExec`]; an algorithm can additionally expose its
//! level plan for `explain` via [`InversionAlgorithm::plan`].
//!
//! The deprecated closed `Algorithm` enum and the `spin_inverse` /
//! `lu_inverse_distributed` free-function shims were removed in PR 3
//! after their scheduled two-PR deprecation window — the registry is the
//! only dispatch path.

mod lu;
mod registry;
mod serial;
mod spin;

pub use registry::{AlgorithmRegistry, InversionAlgorithm, LuAlgorithm, SpinAlgorithm};
pub use serial::{lu_inverse_serial, strassen_inverse_serial};
