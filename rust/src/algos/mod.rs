//! The inversion algorithms: SPIN (the paper's contribution), the
//! LU-decomposition baseline it is evaluated against (Liu et al. 2016),
//! and single-node serial references used by tests.

mod lu;
mod serial;
mod spin;

pub use lu::lu_inverse_distributed;
pub use serial::{lu_inverse_serial, strassen_inverse_serial};
pub use spin::spin_inverse;

use crate::blockmatrix::BlockMatrix;
use crate::cluster::Cluster;
use crate::config::JobConfig;
use crate::error::Result;
use crate::runtime::BlockKernels;

/// Which distributed inversion algorithm to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// Strassen-scheme recursion (the paper's SPIN, Algorithm 2).
    Spin,
    /// Block-recursive LU baseline (Liu et al. 2016).
    Lu,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "spin" => Ok(Algorithm::Spin),
            "lu" => Ok(Algorithm::Lu),
            other => Err(crate::error::SpinError::config(format!(
                "unknown algorithm `{other}` (expected spin|lu)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Spin => "spin",
            Algorithm::Lu => "lu",
        }
    }

    /// Dispatch to the distributed implementation.
    pub fn invert(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        a: &BlockMatrix,
        job: &JobConfig,
    ) -> Result<BlockMatrix> {
        match self {
            Algorithm::Spin => spin_inverse(cluster, kernels, a, job),
            Algorithm::Lu => lu_inverse_distributed(cluster, kernels, a, job),
        }
    }
}
