//! The inversion algorithms: SPIN (the paper's contribution), the
//! LU-decomposition baseline it is evaluated against (Liu et al. 2016),
//! single-node serial references used by tests — and the open
//! [`InversionAlgorithm`] registry new schemes plug into.
//!
//! Dispatch goes through a name-keyed [`AlgorithmRegistry`] (default
//! entries: `spin`, `lu`); the old closed [`Algorithm`] enum and the free
//! functions remain as `#[deprecated]` shims.

mod lu;
mod registry;
mod serial;
mod spin;

#[allow(deprecated)]
pub use lu::lu_inverse_distributed;
use lu::lu_inverse_distributed_impl;
pub use registry::{AlgorithmRegistry, InversionAlgorithm, LuAlgorithm, SpinAlgorithm};
pub use serial::{lu_inverse_serial, strassen_inverse_serial};
#[allow(deprecated)]
pub use spin::spin_inverse;
use spin::spin_inverse_impl;

use crate::blockmatrix::BlockMatrix;
use crate::cluster::Cluster;
use crate::config::JobConfig;
use crate::error::Result;
use crate::runtime::BlockKernels;

/// Which distributed inversion algorithm to run.
///
/// Deprecated shim: the closed enum cannot express externally registered
/// schemes. Use [`AlgorithmRegistry`] / [`crate::session::SpinSession`]
/// instead; `--algo` on the CLI already resolves through the registry.
#[deprecated(
    since = "0.2.0",
    note = "use AlgorithmRegistry (algos::registry) or SpinSession::invert_with; the enum cannot name externally registered algorithms"
)]
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Algorithm {
    /// Strassen-scheme recursion (the paper's SPIN, Algorithm 2).
    Spin,
    /// Block-recursive LU baseline (Liu et al. 2016).
    Lu,
}

#[allow(deprecated)]
impl Algorithm {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "spin" => Ok(Algorithm::Spin),
            "lu" => Ok(Algorithm::Lu),
            other => Err(crate::error::SpinError::config(format!(
                "unknown algorithm `{other}` (expected spin|lu)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::Spin => "spin",
            Algorithm::Lu => "lu",
        }
    }

    /// Dispatch to the distributed implementation.
    pub fn invert(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        a: &BlockMatrix,
        job: &JobConfig,
    ) -> Result<BlockMatrix> {
        match self {
            Algorithm::Spin => spin_inverse_impl(cluster, kernels, a, job),
            Algorithm::Lu => lu_inverse_distributed_impl(cluster, kernels, a, job),
        }
    }
}
