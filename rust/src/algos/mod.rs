//! The inversion algorithms: SPIN (the paper's contribution), the
//! LU-decomposition baseline it is evaluated against (Liu et al. 2016),
//! single-node serial references used by tests — and the open
//! [`InversionAlgorithm`] registry new schemes plug into.
//!
//! Dispatch goes through a name-keyed [`AlgorithmRegistry`] (default
//! entries: `spin`, `lu`, `newton`, `cholesky` — the latter two from the
//! [`iterative`] subsystem). Every built-in expresses its distributed
//! arithmetic as lazy [`crate::plan::MatExpr`] plans and lowers them
//! through [`crate::plan::PlanExec`]; an algorithm can additionally
//! expose its level plan for `explain` via [`InversionAlgorithm::plan`],
//! and iterative schemes (`newton`) report their residual trajectory
//! through [`crate::cluster::ConvergenceReport`].
//!
//! The deprecated closed `Algorithm` enum and the `spin_inverse` /
//! `lu_inverse_distributed` free-function shims were removed in PR 3
//! after their scheduled two-PR deprecation window — the registry is the
//! only dispatch path.

pub mod iterative;
mod lu;
mod registry;
mod serial;
mod spin;

pub use iterative::{CholeskyAlgorithm, NewtonAlgorithm};
pub use registry::{AlgorithmRegistry, InversionAlgorithm, LuAlgorithm, SpinAlgorithm};
pub use serial::{lu_inverse_serial, strassen_inverse_serial};
