//! Open algorithm dispatch: the [`InversionAlgorithm`] trait and a
//! name-keyed [`AlgorithmRegistry`].
//!
//! This replaces the old closed two-variant `Algorithm` enum: new inversion
//! schemes (e.g. iterative inverse approximations, Newton–Schulz, straggler-
//! robust coded variants) plug in by implementing the trait and registering
//! under a unique name — no dispatch site needs to change. The CLI's
//! `--algo` flag, [`crate::session::SpinSession::invert_with`], and the
//! experiment harness all resolve through a registry.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::blockmatrix::BlockMatrix;
use crate::cluster::Cluster;
use crate::config::JobConfig;
use crate::error::{Result, SpinError};
use crate::plan::MatExpr;
use crate::runtime::BlockKernels;

/// One distributed inversion scheme.
///
/// Implementations must be stateless w.r.t. a single call (they may cache
/// internally behind synchronization): the same object is shared across
/// sessions via `Arc` and may be invoked from several jobs.
pub trait InversionAlgorithm: Send + Sync {
    /// Registry key (`"spin"`, `"lu"`, …). Lower-case, no whitespace.
    fn name(&self) -> &str;

    /// Short human description for `spin info` and docs.
    fn description(&self) -> &str {
        ""
    }

    /// Invert `a` on `cluster` using `kernels` for block compute.
    fn invert(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        a: &BlockMatrix,
        job: &JobConfig,
    ) -> Result<BlockMatrix>;

    /// One recursion level of this scheme over `a`, as a lazy plan — the
    /// hook behind `explain()` / `spin explain`. `Ok(None)` (the default)
    /// means the scheme does not expose a plan (e.g. its level is a pure
    /// leaf at this geometry).
    fn plan(&self, a: &MatExpr) -> Result<Option<MatExpr>> {
        let _ = a;
        Ok(None)
    }

    /// Whether this scheme iterates to a tolerance. Iterative schemes
    /// honor `JobConfig::{tolerance, max_iters}` and record convergence
    /// metrics; exact schemes reject those knobs at submit.
    fn iterative(&self) -> bool {
        false
    }

    /// For iterative schemes: a one-line convergence-loop annotation
    /// appended to `spin explain` output (the rendered plan is one
    /// iteration of the loop).
    fn convergence_note(&self) -> Option<String> {
        None
    }

    /// Static recursion model for the plan verifier (`spin lint`,
    /// `verify_plans`, `GET /v1/jobs/:id/analysis`): the scheme's
    /// per-level plans as unexecuted procedures, so the analyzer can
    /// derive its full exchange-stage/shuffle-byte cost at any geometry
    /// without running it. `None` (the default) means the scheme is
    /// opaque to the analyzer — reported as unmodeled, never guessed at.
    fn analysis_model(&self) -> Option<crate::analysis::AlgoModel> {
        None
    }
}

/// The paper's SPIN recursion (Algorithm 2).
pub struct SpinAlgorithm;

impl InversionAlgorithm for SpinAlgorithm {
    fn name(&self) -> &str {
        "spin"
    }

    fn description(&self) -> &str {
        "Strassen-scheme recursion (the paper's SPIN, Algorithm 2)"
    }

    fn invert(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        a: &BlockMatrix,
        job: &JobConfig,
    ) -> Result<BlockMatrix> {
        super::spin::spin_inverse_impl(cluster, kernels, a, job)
    }

    fn plan(&self, a: &MatExpr) -> Result<Option<MatExpr>> {
        if a.nblocks() < 2 {
            return Ok(None); // single-block leaf: no distributed level
        }
        super::spin::level_plan(a).map(Some)
    }

    fn analysis_model(&self) -> Option<crate::analysis::AlgoModel> {
        Some(super::spin::analysis_model())
    }
}

/// The block-recursive LU baseline (Liu et al. 2016).
pub struct LuAlgorithm;

impl InversionAlgorithm for LuAlgorithm {
    fn name(&self) -> &str {
        "lu"
    }

    fn description(&self) -> &str {
        "block-recursive LU baseline (Liu et al. 2016)"
    }

    fn invert(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        a: &BlockMatrix,
        job: &JobConfig,
    ) -> Result<BlockMatrix> {
        super::lu::lu_inverse_distributed_impl(cluster, kernels, a, job)
    }

    fn analysis_model(&self) -> Option<crate::analysis::AlgoModel> {
        Some(super::lu::analysis_model())
    }
}

/// Name-keyed set of inversion algorithms.
///
/// `BTreeMap` keeps `names()` sorted, so error messages and `spin info`
/// output are deterministic.
#[derive(Clone, Default)]
pub struct AlgorithmRegistry {
    algos: BTreeMap<String, Arc<dyn InversionAlgorithm>>,
}

impl AlgorithmRegistry {
    /// Empty registry (no algorithms).
    pub fn new() -> Self {
        AlgorithmRegistry::default()
    }

    /// Registry pre-loaded with the built-in schemes: `spin`, `lu`,
    /// `newton`, and `cholesky`.
    //
    // expect is invariant-backed: registering four distinct built-in
    // names into a fresh registry cannot collide.
    #[allow(clippy::expect_used)]
    pub fn with_defaults() -> Self {
        let mut r = AlgorithmRegistry::new();
        r.register(Arc::new(SpinAlgorithm))
            .expect("empty registry accepts spin");
        r.register(Arc::new(LuAlgorithm))
            .expect("fresh registry accepts lu");
        r.register(Arc::new(super::iterative::NewtonAlgorithm))
            .expect("fresh registry accepts newton");
        r.register(Arc::new(super::iterative::CholeskyAlgorithm))
            .expect("fresh registry accepts cholesky");
        r
    }

    /// Register a scheme under its `name()`. Rejects duplicates — shadowing
    /// a built-in silently would make `--algo` results ambiguous.
    pub fn register(&mut self, algo: Arc<dyn InversionAlgorithm>) -> Result<()> {
        let name = algo.name().to_string();
        if name.is_empty() || name.contains(char::is_whitespace) {
            return Err(SpinError::config(format!(
                "invalid algorithm name `{name}` (must be non-empty, no whitespace)"
            )));
        }
        if self.algos.contains_key(&name) {
            return Err(SpinError::config(format!(
                "algorithm `{name}` is already registered"
            )));
        }
        self.algos.insert(name, algo);
        Ok(())
    }

    /// Look up by name; the error lists what is available.
    pub fn get(&self, name: &str) -> Result<Arc<dyn InversionAlgorithm>> {
        self.algos.get(name).cloned().ok_or_else(|| {
            SpinError::config(format!(
                "unknown algorithm `{name}` (registered: {})",
                self.names().join("|")
            ))
        })
    }

    pub fn contains(&self, name: &str) -> bool {
        self.algos.contains_key(name)
    }

    /// Sorted registered names.
    pub fn names(&self) -> Vec<String> {
        self.algos.keys().cloned().collect()
    }

    pub fn len(&self) -> usize {
        self.algos.len()
    }

    pub fn is_empty(&self) -> bool {
        self.algos.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::linalg::inverse_residual;
    use crate::runtime::NativeBackend;

    #[test]
    fn defaults_contain_all_builtin_schemes() {
        let r = AlgorithmRegistry::with_defaults();
        assert_eq!(
            r.names(),
            vec![
                "cholesky".to_string(),
                "lu".to_string(),
                "newton".to_string(),
                "spin".to_string()
            ]
        );
        assert!(r.contains("spin"));
        assert!(!r.contains("qr"));
        // Only newton iterates; the exact schemes reject tolerance knobs.
        assert!(r.get("newton").unwrap().iterative());
        for exact in ["spin", "lu", "cholesky"] {
            assert!(!r.get(exact).unwrap().iterative(), "{exact}");
        }
    }

    #[test]
    fn duplicate_registration_rejected() {
        let mut r = AlgorithmRegistry::with_defaults();
        let err = r.register(Arc::new(SpinAlgorithm)).unwrap_err();
        assert!(err.to_string().contains("already registered"), "{err}");
    }

    #[test]
    fn unknown_name_lists_available() {
        let r = AlgorithmRegistry::with_defaults();
        let err = r.get("qr").unwrap_err();
        let msg = err.to_string();
        assert!(
            msg.contains("qr") && msg.contains("cholesky|lu|newton|spin"),
            "{msg}"
        );
    }

    #[test]
    fn invalid_names_rejected() {
        struct Bad;
        impl InversionAlgorithm for Bad {
            fn name(&self) -> &str {
                "has space"
            }
            fn invert(
                &self,
                _cluster: &Cluster,
                _kernels: &dyn BlockKernels,
                _a: &BlockMatrix,
                _job: &JobConfig,
            ) -> Result<BlockMatrix> {
                unreachable!()
            }
        }
        let mut r = AlgorithmRegistry::new();
        assert!(r.register(Arc::new(Bad)).is_err());
    }

    #[test]
    fn custom_algorithm_plugs_in() {
        /// Toy scheme: delegate to SPIN (stands in for e.g. Newton–Schulz).
        struct Delegating;
        impl InversionAlgorithm for Delegating {
            fn name(&self) -> &str {
                "delegating"
            }
            fn invert(
                &self,
                cluster: &Cluster,
                kernels: &dyn BlockKernels,
                a: &BlockMatrix,
                job: &JobConfig,
            ) -> Result<BlockMatrix> {
                SpinAlgorithm.invert(cluster, kernels, a, job)
            }
        }
        let mut r = AlgorithmRegistry::with_defaults();
        r.register(Arc::new(Delegating)).unwrap();
        let cluster = Cluster::new(ClusterConfig::local(2));
        let job = JobConfig::new(16, 4);
        let a = BlockMatrix::random(&job).unwrap();
        let inv = r
            .get("delegating")
            .unwrap()
            .invert(&cluster, &NativeBackend, &a, &job)
            .unwrap();
        let resid = inverse_residual(&a.to_dense().unwrap(), &inv.to_dense().unwrap());
        assert!(resid < 1e-10, "residual {resid:.3e}");
    }
}
