//! Single-node serial reference implementations — Algorithm 1 (Strassen's
//! serial inversion scheme) on dense matrices, plus an LU-based serial
//! inverse. Used as test oracles and by the cost-model calibration probes.

use crate::error::{Result, SpinError};
use crate::linalg::{lu_inverse, matmul, Matrix};

/// Strassen's serial inversion (Algorithm 1): recursive 2×2 splitting down
/// to `threshold`, below which the block is inverted by LU.
pub fn strassen_inverse_serial(a: &Matrix, threshold: usize) -> Result<Matrix> {
    if !a.is_square() {
        return Err(SpinError::shape("inversion needs a square matrix"));
    }
    let n = a.rows();
    if n <= threshold || n % 2 != 0 {
        return lu_inverse(a);
    }
    let h = n / 2;
    let a11 = a.submatrix(0, 0, h, h)?;
    let a12 = a.submatrix(0, h, h, h)?;
    let a21 = a.submatrix(h, 0, h, h)?;
    let a22 = a.submatrix(h, h, h, h)?;

    let i = strassen_inverse_serial(&a11, threshold)?; // I   = A11⁻¹
    let ii = matmul(&a21, &i); //                         II  = A21·I
    let iii = matmul(&i, &a12); //                        III = I·A12
    let iv = matmul(&a21, &iii); //                       IV  = A21·III
    let v = iv.sub(&a22)?; //                             V   = IV − A22
    let vi = strassen_inverse_serial(&v, threshold)?; //  VI  = V⁻¹
    let c12 = matmul(&iii, &vi); //                       C12 = III·VI
    let c21 = matmul(&vi, &ii); //                        C21 = VI·II
    let vii = matmul(&iii, &c21); //                      VII = III·C21
    let c11 = i.sub(&vii)?; //                            C11 = I − VII
    let c22 = vi.neg(); //                                C22 = −VI

    let mut out = Matrix::zeros(n, n);
    out.set_submatrix(0, 0, &c11)?;
    out.set_submatrix(0, h, &c12)?;
    out.set_submatrix(h, 0, &c21)?;
    out.set_submatrix(h, h, &c22)?;
    Ok(out)
}

/// Serial LU-based inversion (re-export shape for symmetry with the
/// distributed API).
pub fn lu_inverse_serial(a: &Matrix) -> Result<Matrix> {
    lu_inverse(a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{diag_dominant, inverse_residual, spd};
    use crate::util::check::forall;
    use crate::util::Rng;

    #[test]
    fn strassen_serial_matches_lu() {
        let mut rng = Rng::new(1);
        for n in [4usize, 8, 16, 32, 64] {
            let a = diag_dominant(n, &mut rng);
            let s = strassen_inverse_serial(&a, 4).unwrap();
            let l = lu_inverse_serial(&a).unwrap();
            let diff = s.max_abs_diff(&l);
            assert!(diff < 1e-8, "n={n} diff={diff}");
        }
    }

    #[test]
    fn threshold_equal_n_degenerates_to_lu() {
        let mut rng = Rng::new(2);
        let a = diag_dominant(16, &mut rng);
        let s = strassen_inverse_serial(&a, 16).unwrap();
        assert!(s.max_abs_diff(&lu_inverse_serial(&a).unwrap()) < 1e-14);
    }

    #[test]
    fn odd_size_falls_back_to_lu() {
        let mut rng = Rng::new(3);
        let a = diag_dominant(15, &mut rng);
        let s = strassen_inverse_serial(&a, 2).unwrap();
        assert!(inverse_residual(&a, &s) < 1e-11);
    }

    #[test]
    fn property_strassen_serial_residual() {
        forall(
            "serial strassen inverts",
            0xAA,
            12,
            |r| {
                let n = 1usize << (2 + r.next_usize(4)); // 4..32
                if r.next_f64() < 0.5 {
                    diag_dominant(n, r)
                } else {
                    spd(n, r)
                }
            },
            |a| {
                let inv = strassen_inverse_serial(a, 2).map_err(|e| e.to_string())?;
                let resid = inverse_residual(a, &inv);
                if resid < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("residual {resid}"))
                }
            },
        );
    }
}
