//! SPIN — Algorithm 2: the distributed Strassen-scheme inversion,
//! expressed one recursion level at a time as a lazy [`MatExpr`] plan.
//!
//! Per level (grid edge `b` → `b/2`) the plan built by [`level_plan`]
//! contains: 4 quadrant extractions (sharing 1 `breakMat` pass), two
//! `invert` nodes (A11 and the Schur complement V — lowered by recursing
//! into this module), 6 multiplies, 1 subtract, 1 scalarMul, 1 arrange.
//! The plan **optimizer** — not this file — turns the written
//! `multiply` + `subtract` Schur step `V = A21·III − A22` into the fused
//! [`crate::blockmatrix::BlockMatrix::multiply_sub`] stage (PR 2's hand
//! fusion, now a rewrite rule), and its CSE pass marks the shared
//! intermediates (`I`, `III`, `VI` are each consumed by several nodes) as
//! automatic cache points. With `plan_optimizer = false` the same plan
//! lowers unfused — the measurable "before" arm of the Table-3 comparison.
//!
//! At `b = 1` the single block is inverted serially on one worker (the
//! `leafNode` map). Our extension (off by default,
//! `JobConfig::fuse_leaf_2x2`): when the recursion reaches a 2×2 grid, run
//! the whole Algorithm-1 step as one fused kernel (`strassen_2x2`
//! artifact) — eliminating seven distributed stages at the recursion base.

use crate::blockmatrix::ops_method as method;
use crate::blockmatrix::{Block, BlockMatrix};
use crate::cluster::{Cluster, ResilienceTotals};
use crate::config::JobConfig;
use crate::error::{Result, SpinError};
use crate::plan::{MatExpr, PlanExec};
use crate::runtime::BlockKernels;
use crate::store::checkpoint;

/// `Invert` nodes inside a SPIN level plan resolve to this scheme name —
/// the recursion itself, not a registry entry (a registry round-trip
/// would re-run the top-level residual check per level).
pub(crate) const SPIN_RECURSE: &str = "spin";

/// One SPIN recursion level (Algorithm 2's else-branch) as a lazy plan
/// over `a`. Written in the paper's unfused notation; fusion, CSE and the
/// rest are the optimizer's job.
pub(crate) fn level_plan(a: &MatExpr) -> Result<MatExpr> {
    let (a11, a12, a21, a22) = a.split()?;

    let i = a11.invert(SPIN_RECURSE); //        I   = A11⁻¹
    let ii = a21.multiply(&i)?; //              II  = A21·I
    let iii = i.multiply(&a12)?; //             III = I·A12
    let v = a21.multiply(&iii)?.subtract(&a22)?; // V = A21·III − A22 (optimizer fuses)
    let vi = v.invert(SPIN_RECURSE); //         VI  = V⁻¹
    let c12 = iii.multiply(&vi)?; //            C12 = III·VI
    let c21 = vi.multiply(&ii)?; //             C21 = VI·II
    let vii = iii.multiply(&c21)?; //           VII = III·C21
    let c11 = i.subtract(&vii)?; //             C11 = I − VII
    let c22 = vi.scale(-1.0); //                C22 = −VI
    MatExpr::arrange(&c11, &c12, &c21, &c22)
}

/// Static recursion model for the plan verifier: the level plan *is* the
/// recursion — both `invert[spin]` nodes (A11⁻¹ and the Schur complement)
/// unfold through the same procedure one grid level down, bottoming out
/// in the serial single-block leaf.
pub(crate) fn analysis_model() -> crate::analysis::AlgoModel {
    crate::analysis::AlgoModel {
        entry: SPIN_RECURSE,
        procedures: vec![crate::analysis::Procedure {
            name: SPIN_RECURSE,
            min_grid: 2,
            build: level_plan,
        }],
        iteration: None,
    }
}

/// SPIN (Algorithm 2) implementation entry — reached through
/// [`crate::algos::SpinAlgorithm`] in the registry.
///
/// `a` must be a power-of-two grid of square blocks; the input must be
/// invertible with invertible leading principal quadrants (guaranteed for
/// the diagonally-dominant / SPD generator families).
pub(crate) fn spin_inverse_impl(
    cluster: &Cluster,
    kernels: &dyn BlockKernels,
    a: &BlockMatrix,
    job: &JobConfig,
) -> Result<BlockMatrix> {
    if !a.nblocks().is_power_of_two() {
        return Err(SpinError::shape(format!(
            "SPIN needs a power-of-two block grid, got {}",
            a.nblocks()
        )));
    }
    let inv = inverse_rec(cluster, kernels, a, job)?;
    if job.residual_check {
        let resid = crate::linalg::inverse_residual(&a.to_dense()?, &inv.to_dense()?);
        if resid > 1e-8 {
            return Err(SpinError::numerical(format!(
                "SPIN residual check failed: {resid:.3e}"
            )));
        }
    }
    Ok(inv)
}

/// Materialize one recursion level: build the level plan, optimize it per
/// the cluster's `plan_optimizer` setting, and evaluate it — `invert`
/// nodes recurse back into this function. The recursion boundary is the
/// plan's materialization point: a level needs its children's *values*
/// (their block payloads), not their expressions — and therefore also the
/// checkpoint boundary: a resumed job restores the level's value here and
/// skips the whole subtree below it.
fn inverse_rec(
    cluster: &Cluster,
    kernels: &dyn BlockKernels,
    a: &BlockMatrix,
    job: &JobConfig,
) -> Result<BlockMatrix> {
    let ckpt = checkpoint::boundary();
    let b = a.nblocks();
    if let Some(level) = &ckpt {
        if let Some(restored) = level.try_restore("m", b, a.block_size()) {
            cluster.record_resilience(&ResilienceTotals {
                checkpoints_restored: 1,
                ..ResilienceTotals::default()
            });
            return Ok(restored);
        }
    }

    let inv = if b == 1 {
        // ---- leaf: one block, inverted serially on a worker (paper's
        // if-part).
        a.map_blocks_try(cluster, method::LEAF_NODE, |m| {
            kernels.leaf_inverse(m, job.leaf)
        })?
    } else if b == 2 && job.fuse_leaf_2x2 {
        // ---- optional fused 2×2 base (our extension).
        fused_2x2(cluster, kernels, a, job)?
    } else {
        // ---- else-part: one Strassen level as a plan.
        let plan = level_plan(&MatExpr::source(a.clone()))?;
        let exec = PlanExec::new(cluster, kernels);
        exec.eval_with(
            &plan,
            &|_algo: &str, _opts: &crate::plan::InvertOpts, m: &BlockMatrix| {
                inverse_rec(cluster, kernels, m, job)
            },
        )?
    };

    if let Some(level) = &ckpt {
        if level.persist("m", &inv) {
            cluster.record_resilience(&ResilienceTotals {
                checkpoints_written: 1,
                ..ResilienceTotals::default()
            });
        }
    }
    Ok(inv)
}

/// Collect the four leaf blocks and run the fused Algorithm-1 step as one
/// task (`leafNode` attribution: it replaces the two leaf inversions plus
/// every intermediate stage of that level).
fn fused_2x2(
    cluster: &Cluster,
    kernels: &dyn BlockKernels,
    a: &BlockMatrix,
    job: &JobConfig,
) -> Result<BlockMatrix> {
    let find = |r: usize, c: usize| -> Result<crate::linalg::Matrix> {
        a.get_block(r, c)
            .map(|b| b.matrix.clone())
            .ok_or_else(|| SpinError::shape(format!("missing block ({r},{c})")))
    };
    let (a11, a12, a21, a22) = (find(0, 0)?, find(0, 1)?, find(1, 0)?, find(1, 1)?);
    let leaf = job.leaf;
    let fused = cluster.run_single(method::LEAF_NODE, move || {
        kernels.strassen_2x2(&a11, &a12, &a21, &a22, leaf)
    })?;
    let (c11, c12, c21, c22) = fused;
    let bs = a.block_size();
    let blocks = vec![
        Block::new(0, 0, c11),
        Block::new(0, 1, c12),
        Block::new(1, 0, c21),
        Block::new(1, 1, c22),
    ];
    // from_blocks restores the grid partitioner, so the parent level's
    // arrange stays narrow after a fused base.
    BlockMatrix::from_blocks(blocks, 2, bs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, GeneratorKind, LeafMethod};
    use crate::linalg::{inverse_residual, lu_inverse};
    use crate::runtime::NativeBackend;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig::local(4))
    }

    fn invert_and_check(n: usize, bs: usize, job_mut: impl FnOnce(&mut JobConfig)) {
        let c = cluster();
        let mut job = JobConfig::new(n, bs);
        job_mut(&mut job);
        let a = BlockMatrix::random(&job).unwrap();
        let inv = spin_inverse_impl(&c, &NativeBackend, &a, &job).unwrap();
        let resid = inverse_residual(&a.to_dense().unwrap(), &inv.to_dense().unwrap());
        assert!(resid < 1e-10, "n={n} bs={bs}: residual {resid:.3e}");
    }

    #[test]
    fn single_block_leaf() {
        invert_and_check(8, 8, |_| {});
    }

    #[test]
    fn two_by_two_grid() {
        invert_and_check(16, 8, |_| {});
    }

    #[test]
    fn deeper_recursion() {
        invert_and_check(32, 4, |_| {});
        invert_and_check(64, 8, |_| {});
    }

    #[test]
    fn spd_generator() {
        invert_and_check(32, 8, |j| j.generator = GeneratorKind::Spd);
    }

    #[test]
    fn gauss_jordan_leaf() {
        invert_and_check(16, 4, |j| j.leaf = LeafMethod::GaussJordan);
    }

    #[test]
    fn fused_2x2_matches_unfused() {
        let c1 = cluster();
        let c2 = cluster();
        let mut job = JobConfig::new(16, 8);
        let a = BlockMatrix::random(&job).unwrap();
        let plain = spin_inverse_impl(&c1, &NativeBackend, &a, &job).unwrap();
        job.fuse_leaf_2x2 = true;
        let fused = spin_inverse_impl(&c2, &NativeBackend, &a, &job).unwrap();
        let diff = plain
            .to_dense()
            .unwrap()
            .max_abs_diff(&fused.to_dense().unwrap());
        assert!(diff < 1e-9, "fused vs plain diff {diff}");
    }

    #[test]
    fn matches_serial_lu_inverse() {
        let c = cluster();
        let job = JobConfig::new(32, 8);
        let a = BlockMatrix::random(&job).unwrap();
        let inv = spin_inverse_impl(&c, &NativeBackend, &a, &job).unwrap();
        let want = lu_inverse(&a.to_dense().unwrap()).unwrap();
        let diff = inv.to_dense().unwrap().max_abs_diff(&want);
        assert!(diff < 1e-8, "diff {diff}");
    }

    #[test]
    fn residual_check_passes_for_good_input() {
        invert_and_check(16, 4, |j| j.residual_check = true);
    }

    #[test]
    fn rejects_non_pow2_grid() {
        let c = cluster();
        let job = JobConfig::new(16, 4);
        // Build a 3x3 grid manually (n=12, bs=4).
        let dense = crate::linalg::diag_dominant(12, &mut crate::util::Rng::new(1));
        let a = BlockMatrix::from_dense(&dense, 4).unwrap();
        assert!(spin_inverse_impl(&c, &NativeBackend, &a, &job).is_err());
    }

    #[test]
    fn metrics_cover_all_paper_methods() {
        let c = cluster();
        let job = JobConfig::new(32, 4); // b = 8: multi-level recursion
        let a = BlockMatrix::random(&job).unwrap();
        let _ = spin_inverse_impl(&c, &NativeBackend, &a, &job).unwrap();
        let snap = c.metrics();
        for m in [
            "leafNode", "breakMat", "xy", "multiply", "subtract", "scalar", "arrange",
        ] {
            assert!(snap.method(m).is_some(), "missing method metric {m}");
        }
        // leafNode count: recursion tree has 2^depth leaves for b=8 -> 8.
        assert_eq!(snap.method("leafNode").unwrap().calls, 8);
        // The plan executor stamped per-node windows, with the Schur fusion
        // applied by the optimizer (not hand-wired here).
        assert!(snap.plan_nodes().iter().any(|p| p.op == "multiply_sub"));
        assert!(snap.plan_nodes().iter().any(|p| p.cse_cached));
    }

    #[test]
    fn unfused_plan_mode_matches_and_pays_extra_stages() {
        let mut cfg = ClusterConfig::local(4);
        cfg.plan_optimizer = false;
        let c_raw = Cluster::new(cfg);
        let c_opt = cluster();
        let job = JobConfig::new(32, 8);
        let a = BlockMatrix::random(&job).unwrap();
        let opt = spin_inverse_impl(&c_opt, &NativeBackend, &a, &job).unwrap();
        let raw = spin_inverse_impl(&c_raw, &NativeBackend, &a, &job).unwrap();
        // multiply_sub is bit-identical to multiply + subtract.
        assert_eq!(
            opt.to_dense()
                .unwrap()
                .max_abs_diff(&raw.to_dense().unwrap()),
            0.0,
            "fused and unfused plans must agree bit-for-bit"
        );
        let (mo, mr) = (c_opt.metrics(), c_raw.metrics());
        assert!(
            mo.stages().len() < mr.stages().len(),
            "fusion must delete stages: {} vs {}",
            mo.stages().len(),
            mr.stages().len()
        );
        assert!(mo.plan_nodes().iter().any(|p| p.op == "multiply_sub"));
        assert!(
            !mr.plan_nodes().iter().any(|p| p.op == "multiply_sub"),
            "optimizer off must leave the plan unfused"
        );
        // The raw plan pays one extra standalone subtract per fused level.
        assert!(
            mr.method("subtract").unwrap().calls > mo.method("subtract").unwrap().calls
        );
    }
}
