//! Static plan verifier: prove the standing contracts on an optimized
//! [`MatExpr`] DAG before (or without) running it.
//!
//! The bench gates and Table-3 reports assert *hand-maintained* analytic
//! constants (SPIN 12/36/84, LU 16/52/140, Cholesky 10/30/78 exchange
//! stages at b = 2/4/8; Newton's per-pass counts). This module *derives*
//! those numbers from plan structure alone, with no execution, and proves
//! the contracts every PR inherits (see `ROADMAP.md`):
//!
//! 1. **Geometry & partitioner propagation** ([`geometry_check`]) —
//!    re-derive every node's `(nblocks, block_size)` bottom-up from its
//!    children and flag any op that disagrees with its stamped geometry.
//!    Under the one-block-per-partition invariant the grid partitioner is
//!    a pure function of `nblocks`, so a clean geometry pass *is* the
//!    proof that every op re-stamps a correct partitioner.
//! 2. **Analytic cost accounting** ([`analyze_plan`], [`algo_cost`]) —
//!    predicted exchange stages, multiply rounds, driver collects, and
//!    shuffle-byte ceilings per node. Recursive `invert[name]` nodes are
//!    unfolded through a per-algorithm [`AlgoModel`]: a set of plan-valued
//!    procedures (one per recursion level / iteration pass) that the
//!    analyzer instantiates at every grid size down to the serial leaves.
//!    The derived totals are cross-checked against the closed forms in
//!    [`crate::costmodel::analytic_multiply_rounds`].
//! 3. **Rewrite soundness** ([`soundness::rewrite_soundness`]) — diff an
//!    unoptimized plan against its optimized form and assert the applied
//!    rules were value-preserving (equal semantic normal forms modulo the
//!    documented rewrites), geometry-preserving, and cost-non-increasing
//!    under the derived model.
//! 4. **Lifecycle soundness** ([`soundness::lifecycle_soundness`]) —
//!    every evictable node's recompute closure reaches only interned
//!    sources (seeded generators / identified store paths) or values held
//!    by the DAG itself, so eviction safety is provable rather than
//!    sampled.
//!
//! Surfaces: `spin lint` (CLI, nonzero exit on violations),
//! `spin explain --verify`, `GET /v1/jobs/:id/analysis` (HTTP), and the
//! `verify_plans` debug mode in [`crate::plan::PlanExec`] that
//! cross-checks these static predictions against measured `Metrics`
//! counters after every plan node and fails the job on divergence. See
//! `docs/ANALYSIS.md` for what is proved vs sampled.

use std::collections::{HashMap, HashSet};

use crate::blockmatrix::BlockMatrix;
use crate::error::{Result, SpinError};
use crate::plan::{predicted_exchanges, ExprOp, MatExpr, Optimizer, OptimizerConfig};
use crate::ser::json::Json;

mod soundness;

pub use soundness::{lifecycle_soundness, rewrite_soundness, semantic_normal_form, LifecycleReport};

// ---------------------------------------------------------------------------
// Algorithm recursion models
// ---------------------------------------------------------------------------

/// Static recursion model of an inversion scheme: enough structure for the
/// analyzer to unfold the scheme's *entire* distributed cost at any grid
/// size without executing it. Returned by
/// [`crate::algos::InversionAlgorithm::analysis_model`].
#[derive(Clone)]
pub struct AlgoModel {
    /// Name of the procedure invoked on the full input.
    pub entry: &'static str,
    /// Every procedure the recursion can reach. A procedure builds one
    /// level of its recursion as an unexecuted plan over a caller-supplied
    /// source; nested `invert[name]` nodes reference other procedures (or
    /// itself) one level down.
    pub procedures: Vec<Procedure>,
    /// `Some` for iterative schemes: the entry procedure models **one
    /// pass**, and the total is `max_iters` passes (an SLA ceiling).
    pub iteration: Option<IterationModel>,
}

/// One level (or pass) of a recursion, as a plan builder. The builder must
/// mirror the real dataflow the scheme lowers through [`crate::plan::PlanExec`]
/// — same multiplies, subtracts, scales, transposes, arranges — so the
/// derived counts are exact, not estimates.
#[derive(Clone, Copy)]
pub struct Procedure {
    /// Name matched against `invert[name]` nodes during unfolding.
    pub name: &'static str,
    /// Grids strictly below this run as serial driver leaves: zero
    /// distributed stages, zero shuffle bytes.
    pub min_grid: usize,
    /// Build the level's plan over `a` (an unexecuted source of the
    /// procedure's input geometry).
    pub build: fn(&MatExpr) -> Result<MatExpr>,
}

/// Iteration shape of an iterative scheme's entry procedure.
#[derive(Clone, Copy)]
pub struct IterationModel {
    /// The final pass computes the residual check but skips the root
    /// update (`X_{k+1} = X_k·M_k`), so the last pass costs one root-node
    /// round less — Newton's `2·(2·max_iters − 1)` stage ceiling.
    pub final_pass_drops_root: bool,
}

// ---------------------------------------------------------------------------
// Cost profiles
// ---------------------------------------------------------------------------

/// Derived distributed cost of a plan or recursion, all statically proved
/// ceilings/equalities (see `docs/ANALYSIS.md` for which is which).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CostProfile {
    /// Exact count of exchange (shuffle) stages.
    pub exchange_stages: usize,
    /// Exact count of distributed multiply / multiply_sub rounds.
    pub multiply_rounds: usize,
    /// Upper bound on shuffle bytes moved between executors.
    pub shuffle_bytes_ceiling: u64,
    /// Exact count of driver collect stages (always 0 for plan nodes —
    /// the partitioner-aware dataflow never collects).
    pub driver_collects: usize,
    /// True when the counts are an iteration-budget ceiling (the run may
    /// early-stop below them), not an equality.
    pub iterative_ceiling: bool,
}

impl CostProfile {
    fn add(&mut self, other: &CostProfile) {
        self.exchange_stages += other.exchange_stages;
        self.multiply_rounds += other.multiply_rounds;
        self.shuffle_bytes_ceiling += other.shuffle_bytes_ceiling;
        self.driver_collects += other.driver_collects;
        self.iterative_ceiling |= other.iterative_ceiling;
    }

    fn sub(&mut self, other: &CostProfile) {
        self.exchange_stages -= other.exchange_stages;
        self.multiply_rounds -= other.multiply_rounds;
        self.shuffle_bytes_ceiling -= other.shuffle_bytes_ceiling;
        self.driver_collects -= other.driver_collects;
    }
}

/// Shuffle-byte ceiling for one plan node. A multiply (or fused
/// multiply_sub) at grid `g` over an `m×m` value routes two exchanges —
/// the A-stream and the B-stream — and each replicates every source block
/// to at most `g` output buckets: `≤ g·8·m²` routed bytes per exchange,
/// `2·8·g·m²` per node. Measured `shuffle_bytes` counts only the
/// cross-executor subset of that routing, so the ceiling dominates it.
/// Every other partitioner-aware op is narrow (zero shuffle bytes — the
/// ceiling 0 makes the verifier *prove* narrowness); the legacy
/// non-aware subtract cogroups both operands once.
pub fn node_shuffle_bytes_ceiling(
    op: &ExprOp,
    nblocks: usize,
    n: usize,
    partitioner_aware: bool,
) -> u64 {
    let g = nblocks as u64;
    let m = n as u64;
    match op {
        ExprOp::Multiply(..) | ExprOp::MultiplySub(..) => 2 * 8 * g * m * m,
        ExprOp::Subtract(..) if !partitioner_aware => 2 * 8 * m * m,
        _ => 0,
    }
}

// ---------------------------------------------------------------------------
// Recursion unfolding
// ---------------------------------------------------------------------------

/// Derive the full distributed cost of `model` inverting an
/// `nblocks × nblocks` grid of `block_size`-sized blocks, by instantiating
/// each procedure's plan at every grid the recursion reaches and summing
/// per-node costs under the session's optimizer config. `max_iters` is the
/// iteration budget for iterative models (ignored otherwise).
pub fn algo_cost(
    model: &AlgoModel,
    nblocks: usize,
    block_size: usize,
    config: OptimizerConfig,
    partitioner_aware: bool,
    max_iters: usize,
) -> Result<CostProfile> {
    let mut memo: HashMap<(&'static str, usize), CostProfile> = HashMap::new();
    let per_entry = procedure_cost(
        model,
        model.entry,
        nblocks,
        block_size,
        config,
        partitioner_aware,
        &mut memo,
    )?;
    let Some(iter) = model.iteration else {
        return Ok(per_entry);
    };
    if max_iters == 0 {
        return Err(SpinError::plan("iterative model needs max_iters >= 1"));
    }
    // One pass × the SLA budget; the final pass skips the root update.
    let mut total = CostProfile::default();
    for _ in 0..max_iters {
        total.add(&per_entry);
    }
    if iter.final_pass_drops_root {
        let root = build_optimized(lookup(model, model.entry)?, nblocks, config)?;
        let mut root_own = CostProfile::default();
        add_node_cost(&mut root_own, &root, block_size, partitioner_aware);
        total.sub(&root_own);
    }
    total.iterative_ceiling = true;
    Ok(total)
}

fn lookup<'m>(model: &'m AlgoModel, name: &str) -> Result<&'m Procedure> {
    model.procedures.iter().find(|p| p.name == name).ok_or_else(|| {
        SpinError::plan(format!(
            "analysis model references procedure `{name}` but defines no model for it"
        ))
    })
}

/// Instantiate `proc` at `grid` over a unit-block placeholder source and
/// optimize it exactly as the executor would — the analyzed plan is the
/// executed plan.
fn build_optimized(proc: &Procedure, grid: usize, config: OptimizerConfig) -> Result<MatExpr> {
    let src = MatExpr::source(BlockMatrix::zeros(grid, 1)?);
    let raw = (proc.build)(&src)?;
    Optimizer::new(config).optimize(&raw)
}

fn add_node_cost(profile: &mut CostProfile, e: &MatExpr, block_size: usize, aware: bool) {
    if let Some(stages) = predicted_exchanges(e.op(), aware) {
        profile.exchange_stages += stages;
    }
    if matches!(e.op(), ExprOp::Multiply(..) | ExprOp::MultiplySub(..)) {
        profile.multiply_rounds += 1;
    }
    profile.shuffle_bytes_ceiling +=
        node_shuffle_bytes_ceiling(e.op(), e.nblocks(), e.nblocks() * block_size, aware);
}

fn procedure_cost(
    model: &AlgoModel,
    name: &str,
    grid: usize,
    block_size: usize,
    config: OptimizerConfig,
    aware: bool,
    memo: &mut HashMap<(&'static str, usize), CostProfile>,
) -> Result<CostProfile> {
    let proc = lookup(model, name)?;
    if let Some(p) = memo.get(&(proc.name, grid)) {
        return Ok(*p);
    }
    if grid < proc.min_grid {
        // Serial driver leaf: below the recursion floor the scheme
        // inverts on a single block, distributing nothing.
        memo.insert((proc.name, grid), CostProfile::default());
        return Ok(CostProfile::default());
    }
    let root = build_optimized(proc, grid, config)?;
    let mut profile = CostProfile::default();
    let mut stack = vec![root];
    let mut seen: HashSet<u64> = HashSet::new();
    while let Some(e) = stack.pop() {
        if !seen.insert(e.id()) {
            continue;
        }
        if let ExprOp::Invert { algo, .. } = e.op() {
            let sub = procedure_cost(model, algo, e.nblocks(), block_size, config, aware, memo)?;
            profile.add(&sub);
        } else {
            add_node_cost(&mut profile, &e, block_size, aware);
        }
        stack.extend(e.children());
    }
    memo.insert((proc.name, grid), profile);
    Ok(profile)
}

// ---------------------------------------------------------------------------
// Whole-plan analysis
// ---------------------------------------------------------------------------

/// Everything the analyzer needs besides the plan itself.
pub struct AnalysisContext<'a> {
    /// Resolve an `invert[name]` node to its recursion model (`None` for
    /// schemes that publish no model — reported, not a violation).
    pub resolve: &'a dyn Fn(&str) -> Option<AlgoModel>,
    /// The optimizer config the evaluating session would apply — the
    /// analyzed plan must be the executed plan.
    pub optimizer: OptimizerConfig,
    pub partitioner_aware: bool,
    /// Session-default iteration budget for iterative schemes; per-node
    /// `InvertOpts::max_iters` overrides it.
    pub default_max_iters: usize,
}

/// Per-node facts derived by [`analyze_plan`].
#[derive(Debug, Clone)]
pub struct NodeFact {
    pub id: u64,
    pub op: String,
    pub nblocks: usize,
    pub n: usize,
    /// Exchange stages this node's own lowering pays (`None` for a
    /// recursive invert — covered by `invert_profile`).
    pub exchange_stages: Option<usize>,
    pub shuffle_bytes_ceiling: u64,
    /// Unfolded whole-recursion cost for resolved `invert` nodes.
    pub invert_profile: Option<CostProfile>,
}

/// Result of statically analyzing one plan.
#[derive(Debug, Clone, Default)]
pub struct PlanAnalysis {
    pub nodes: Vec<NodeFact>,
    pub node_count: usize,
    /// Whole-plan totals (plan nodes + unfolded recursions).
    pub total: CostProfile,
    /// True when the geometry/partitioner pass found no violation — the
    /// one-block-per-partition invariant is proved for every node.
    pub partitioner_proved: bool,
    /// Invert nodes whose scheme publishes no [`AlgoModel`]: their cost is
    /// not included in `total` (reported so callers can tell "proved 0"
    /// from "unknown").
    pub opaque_inverts: Vec<String>,
    pub violations: Vec<String>,
}

impl PlanAnalysis {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Re-derive every node's geometry bottom-up and return violations. An
/// empty result proves geometry (and with it the grid-partitioner stamp)
/// for the whole DAG.
pub fn geometry_check(root: &MatExpr) -> Vec<String> {
    let mut violations = Vec::new();
    let mut seen: HashSet<u64> = HashSet::new();
    let mut stack = vec![root.clone()];
    while let Some(e) = stack.pop() {
        if !seen.insert(e.id()) {
            continue;
        }
        stack.extend(e.children());
        let geom = |m: &MatExpr| (m.nblocks(), m.block_size());
        let expected: std::result::Result<(usize, usize), String> = match e.op() {
            ExprOp::Source(m) => Ok((m.nblocks(), m.block_size())),
            ExprOp::LazySource(spec) => Ok((spec.nblocks(), spec.block_size())),
            ExprOp::Multiply(a, b) | ExprOp::Subtract(a, b) => {
                if geom(a) != geom(b) {
                    Err(format!(
                        "operand grids disagree: {}x{}@{} vs {}x{}@{}",
                        a.nblocks(),
                        a.nblocks(),
                        a.block_size(),
                        b.nblocks(),
                        b.nblocks(),
                        b.block_size()
                    ))
                } else {
                    Ok(geom(a))
                }
            }
            ExprOp::MultiplySub(a, b, d) => {
                if geom(a) != geom(b) || geom(a) != geom(d) {
                    Err("multiply_sub operands disagree on grid geometry".to_string())
                } else {
                    Ok(geom(a))
                }
            }
            ExprOp::Scale(x, _) | ExprOp::Transpose(x) | ExprOp::Invert { child: x, .. } => {
                Ok(geom(x))
            }
            ExprOp::Quadrant { child, .. } => {
                if child.nblocks() < 2 || child.nblocks() % 2 != 0 {
                    Err(format!(
                        "quadrant of a non-splittable {}x{} grid",
                        child.nblocks(),
                        child.nblocks()
                    ))
                } else {
                    Ok((child.nblocks() / 2, child.block_size()))
                }
            }
            ExprOp::Arrange(a, b, c, d) => {
                if geom(a) != geom(b) || geom(a) != geom(c) || geom(a) != geom(d) {
                    Err("arrange quadrants disagree on grid geometry".to_string())
                } else {
                    Ok((a.nblocks() * 2, a.block_size()))
                }
            }
        };
        match expected {
            Err(msg) => violations.push(format!("%{} {}: {}", e.id(), e.op().name(), msg)),
            Ok(exp) if exp != (e.nblocks(), e.block_size()) => violations.push(format!(
                "%{} {}: stamped {}x{} grid of {}-blocks, children derive {}x{} of {}-blocks \
                 (partitioner stamp would be wrong)",
                e.id(),
                e.op().name(),
                e.nblocks(),
                e.nblocks(),
                e.block_size(),
                exp.0,
                exp.0,
                exp.1
            )),
            Ok(_) => {}
        }
    }
    violations.sort();
    violations
}

/// Statically analyze an (already optimized) plan: prove geometry, derive
/// per-node and total cost, and unfold recursive inverts through their
/// published models. Performs no execution.
pub fn analyze_plan(root: &MatExpr, ctx: &AnalysisContext<'_>) -> Result<PlanAnalysis> {
    let mut out = PlanAnalysis {
        violations: geometry_check(root),
        ..PlanAnalysis::default()
    };
    out.partitioner_proved = out.violations.is_empty();
    let mut seen: HashSet<u64> = HashSet::new();
    let mut stack = vec![root.clone()];
    while let Some(e) = stack.pop() {
        if !seen.insert(e.id()) {
            continue;
        }
        stack.extend(e.children());
        out.node_count += 1;
        let mut fact = NodeFact {
            id: e.id(),
            op: e.op().name().to_string(),
            nblocks: e.nblocks(),
            n: e.n(),
            exchange_stages: predicted_exchanges(e.op(), ctx.partitioner_aware),
            shuffle_bytes_ceiling: node_shuffle_bytes_ceiling(
                e.op(),
                e.nblocks(),
                e.n(),
                ctx.partitioner_aware,
            ),
            invert_profile: None,
        };
        if let ExprOp::Invert { algo, opts, .. } = e.op() {
            match (ctx.resolve)(algo) {
                Some(model) => {
                    let budget = opts.max_iters.unwrap_or(ctx.default_max_iters);
                    let profile = algo_cost(
                        &model,
                        e.nblocks(),
                        e.block_size(),
                        ctx.optimizer,
                        ctx.partitioner_aware,
                        budget,
                    )?;
                    out.total.add(&profile);
                    fact.invert_profile = Some(profile);
                }
                None => out.opaque_inverts.push(algo.clone()),
            }
        } else {
            let mut own = CostProfile::default();
            if let Some(stages) = fact.exchange_stages {
                own.exchange_stages = stages;
            }
            if matches!(e.op(), ExprOp::Multiply(..) | ExprOp::MultiplySub(..)) {
                own.multiply_rounds = 1;
            }
            own.shuffle_bytes_ceiling = fact.shuffle_bytes_ceiling;
            out.total.add(&own);
        }
        out.nodes.push(fact);
    }
    out.nodes.sort_by_key(|f| f.id);
    out.opaque_inverts.sort();
    out.opaque_inverts.dedup();
    Ok(out)
}

// ---------------------------------------------------------------------------
// Session-level verdict (analysis + soundness, JSON-renderable)
// ---------------------------------------------------------------------------

/// The full verifier verdict on one plan: cost analysis of the optimized
/// form, rewrite-soundness diff against the unoptimized form, and the
/// lifecycle closure proof. Built by
/// [`crate::session::SpinSession::analyze_expr`].
#[derive(Debug, Clone)]
pub struct PlanVerdict {
    pub analysis: PlanAnalysis,
    pub rewrite_violations: Vec<String>,
    pub lifecycle: LifecycleReport,
}

impl PlanVerdict {
    pub fn ok(&self) -> bool {
        self.analysis.ok() && self.rewrite_violations.is_empty() && self.lifecycle.ok()
    }

    /// All violations across the three passes, for flat reporting.
    pub fn violations(&self) -> Vec<String> {
        let mut v = self.analysis.violations.clone();
        v.extend(self.rewrite_violations.iter().cloned());
        v.extend(self.lifecycle.violations.iter().cloned());
        v
    }

    pub fn to_json(&self) -> Json {
        let a = &self.analysis;
        Json::object(vec![
            ("ok", Json::Bool(self.ok())),
            (
                "predicted",
                Json::object(vec![
                    ("exchange_stages", Json::num(a.total.exchange_stages as f64)),
                    ("multiply_rounds", Json::num(a.total.multiply_rounds as f64)),
                    (
                        "shuffle_bytes_ceiling",
                        Json::num(a.total.shuffle_bytes_ceiling as f64),
                    ),
                    ("driver_collects", Json::num(a.total.driver_collects as f64)),
                    ("iterative_ceiling", Json::Bool(a.total.iterative_ceiling)),
                ]),
            ),
            ("node_count", Json::num(a.node_count as f64)),
            ("partitioner_proved", Json::Bool(a.partitioner_proved)),
            (
                "opaque_inverts",
                Json::Array(a.opaque_inverts.iter().map(|s| Json::str(s.clone())).collect()),
            ),
            (
                "lifecycle",
                Json::object(vec![
                    ("evictable", Json::num(self.lifecycle.evictable as f64)),
                    (
                        "interned_leaves",
                        Json::num(self.lifecycle.interned_leaves as f64),
                    ),
                    ("held_leaves", Json::num(self.lifecycle.held_leaves as f64)),
                    (
                        "notes",
                        Json::Array(
                            self.lifecycle.notes.iter().map(|s| Json::str(s.clone())).collect(),
                        ),
                    ),
                ]),
            ),
            (
                "violations",
                Json::Array(self.violations().into_iter().map(Json::str).collect()),
            ),
        ])
    }
}
