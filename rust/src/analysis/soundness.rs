//! Rewrite soundness and lifecycle soundness: the two contract proofs
//! that diff plans rather than cost them.
//!
//! **Rewrite soundness** checks the optimizer rule contract
//! (`plan/optimizer.rs`): every documented rewrite — multiply+subtract
//! fusion, transpose pushdown, exact scalar folding, CSE — is
//! value-preserving, geometry-preserving, and cost-non-increasing. The
//! value check reduces both plans to a *semantic normal form* that is
//! invariant under exactly those rewrites (and nothing else): transposes
//! are distributed down to the leaves, `multiply_sub` is expanded to
//! `sub(mul(..), ..)`, and scale chains collapse to one bit-exact factor.
//! Equal normal forms ⇒ the optimized plan computes the same value; the
//! check is deterministic, so it can never pass a plan the rules would
//! reject.
//!
//! **Lifecycle soundness** proves the eviction contract: a value may be
//! dropped only if its recompute closure reaches leaves that are either
//! held by the DAG itself (`Source`) or interned by a deterministic spec
//! (`LazySource`: seeded generator or identified store path). The walk
//! matches `ExprOp` exhaustively, so a new operator cannot ship without
//! being classified here.

use std::collections::{HashMap, HashSet};
use std::rc::Rc;

use crate::plan::{predicted_exchanges, ExprOp, MatExpr};

use super::node_shuffle_bytes_ceiling;

// ---------------------------------------------------------------------------
// Rewrite soundness
// ---------------------------------------------------------------------------

/// Reduce a plan to its semantic normal form: a string that is equal for
/// two plans iff they compute the same value *modulo the documented
/// optimizer rewrites*. Exposed for tests and debugging.
pub fn semantic_normal_form(e: &MatExpr) -> String {
    let mut memo = HashMap::new();
    norm(e, false, &mut memo).to_string()
}

fn norm(e: &MatExpr, t: bool, memo: &mut HashMap<(u64, bool), Rc<str>>) -> Rc<str> {
    if let Some(s) = memo.get(&(e.id(), t)) {
        return Rc::clone(s);
    }
    let wrap = |s: String, t: bool| if t { format!("T({s})") } else { s };
    let s: String = match e.op() {
        // Sources are canonical by identity: the optimizer never rebuilds
        // a leaf, so raw and optimized plans share the same leaf nodes.
        ExprOp::Source(_) => wrap(format!("src#{}", e.id()), t),
        ExprOp::LazySource(spec) => wrap(format!("lazy[{}]", spec.label()), t),
        // (A·B)ᵀ = Bᵀ·Aᵀ — the transpose-pushdown rule.
        ExprOp::Multiply(a, b) => {
            if t {
                format!("mul({},{})", norm(b, true, memo), norm(a, true, memo))
            } else {
                format!("mul({},{})", norm(a, false, memo), norm(b, false, memo))
            }
        }
        // The fusion rule: multiply_sub(A,B,D) ≡ sub(mul(A,B), D).
        ExprOp::MultiplySub(a, b, d) => {
            let prod = if t {
                format!("mul({},{})", norm(b, true, memo), norm(a, true, memo))
            } else {
                format!("mul({},{})", norm(a, false, memo), norm(b, false, memo))
            };
            format!("sub({prod},{})", norm(d, t, memo))
        }
        ExprOp::Subtract(a, b) => {
            format!("sub({},{})", norm(a, t, memo), norm(b, t, memo))
        }
        // Collapse a scale chain to one factor. The folding rule only
        // merges exact (±1) factors, so both sides accumulate the *same*
        // chain in the same order — the products are bit-identical.
        ExprOp::Scale(..) => {
            let mut f = 1.0f64;
            let mut cur = e.clone();
            loop {
                let next = match cur.op() {
                    ExprOp::Scale(inner, s) => {
                        f *= *s;
                        inner.clone()
                    }
                    _ => break,
                };
                cur = next;
            }
            let body = norm(&cur, t, memo);
            if f == 1.0 {
                body.to_string()
            } else {
                format!("scale[{:016x}]({body})", f.to_bits())
            }
        }
        ExprOp::Transpose(x) => norm(x, !t, memo).to_string(),
        // No rule crosses an invert/quadrant/arrange boundary: keep them
        // literal (transposed context wraps instead of distributing —
        // symmetric on both sides, so determinism is preserved).
        ExprOp::Invert { algo, opts, child } => wrap(
            format!("inv[{algo}|{:?}]({})", opts.key(), norm(child, false, memo)),
            t,
        ),
        ExprOp::Quadrant { child, which } => {
            wrap(format!("q[{which:?}]({})", norm(child, false, memo)), t)
        }
        ExprOp::Arrange(a, b, c, d) => wrap(
            format!(
                "arr({},{},{},{})",
                norm(a, false, memo),
                norm(b, false, memo),
                norm(c, false, memo),
                norm(d, false, memo)
            ),
            t,
        ),
    };
    let rc: Rc<str> = Rc::from(s);
    memo.insert((e.id(), t), Rc::clone(&rc));
    rc
}

fn plan_cost(root: &MatExpr, aware: bool) -> (usize, u64) {
    let mut stages = 0usize;
    let mut bytes = 0u64;
    let mut seen: HashSet<u64> = HashSet::new();
    let mut stack = vec![root.clone()];
    while let Some(e) = stack.pop() {
        if !seen.insert(e.id()) {
            continue;
        }
        stack.extend(e.children());
        // Inverts are opaque here: the value check guarantees the rewrite
        // did not change *which* inversions run, so they cancel out.
        stages += predicted_exchanges(e.op(), aware).unwrap_or(0);
        bytes += node_shuffle_bytes_ceiling(e.op(), e.nblocks(), e.n(), aware);
    }
    (stages, bytes)
}

/// Diff an unoptimized plan against its optimized form and return every
/// violated clause of the optimizer rule contract (empty = proved sound).
pub fn rewrite_soundness(
    raw: &MatExpr,
    optimized: &MatExpr,
    partitioner_aware: bool,
) -> Vec<String> {
    let mut violations = Vec::new();
    // Value preservation.
    let mut memo = HashMap::new();
    let n_raw = norm(raw, false, &mut memo);
    let n_opt = norm(optimized, false, &mut memo);
    if n_raw != n_opt {
        let prefix = |s: &str| s.chars().take(96).collect::<String>();
        violations.push(format!(
            "rewrite changed the computed value: normal forms diverge ({}... vs {}...)",
            prefix(&n_raw),
            prefix(&n_opt)
        ));
    }
    // Geometry preservation: same root geometry, and the optimized DAG is
    // internally consistent (the raw plan was validated at construction).
    if (raw.nblocks(), raw.block_size()) != (optimized.nblocks(), optimized.block_size()) {
        violations.push(format!(
            "rewrite changed root geometry: {}x{}@{} -> {}x{}@{}",
            raw.nblocks(),
            raw.nblocks(),
            raw.block_size(),
            optimized.nblocks(),
            optimized.nblocks(),
            optimized.block_size()
        ));
    }
    for v in super::geometry_check(optimized) {
        violations.push(format!("optimized plan breaks geometry: {v}"));
    }
    // Cost non-increase under the derived model.
    let (raw_stages, raw_bytes) = plan_cost(raw, partitioner_aware);
    let (opt_stages, opt_bytes) = plan_cost(optimized, partitioner_aware);
    if opt_stages > raw_stages {
        violations.push(format!(
            "rewrite increased exchange stages: {raw_stages} -> {opt_stages}"
        ));
    }
    if opt_bytes > raw_bytes {
        violations.push(format!(
            "rewrite increased the shuffle-byte ceiling: {raw_bytes} -> {opt_bytes}"
        ));
    }
    violations
}

// ---------------------------------------------------------------------------
// Lifecycle soundness
// ---------------------------------------------------------------------------

/// Result of the eviction-safety closure proof.
#[derive(Debug, Clone, Default)]
pub struct LifecycleReport {
    /// Unique nodes walked.
    pub nodes: usize,
    /// Operator nodes whose value the lifecycle manager may evict.
    pub evictable: usize,
    /// `LazySource` leaves interned by a deterministic spec.
    pub interned_leaves: usize,
    /// `Source` leaves whose value is held by the DAG itself.
    pub held_leaves: usize,
    /// Conditionally-sound cases worth surfacing (not violations): e.g. a
    /// pre-id block store, whose identity is re-checked at materialization
    /// rather than proved here.
    pub notes: Vec<String>,
    pub violations: Vec<String>,
}

impl LifecycleReport {
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }
}

/// Prove that every evictable node's recompute closure bottoms out in
/// interned or held sources. The `ExprOp` match is exhaustive on purpose:
/// adding an operator without classifying its recompute story is a
/// compile error, not a silently-sampled gap.
pub fn lifecycle_soundness(root: &MatExpr) -> LifecycleReport {
    let mut report = LifecycleReport::default();
    let mut seen: HashSet<u64> = HashSet::new();
    let mut stack = vec![root.clone()];
    while let Some(e) = stack.pop() {
        if !seen.insert(e.id()) {
            continue;
        }
        stack.extend(e.children());
        report.nodes += 1;
        match e.op() {
            ExprOp::Source(_) => report.held_leaves += 1,
            ExprOp::LazySource(spec) => {
                report.interned_leaves += 1;
                if let crate::plan::SourceSpec::Store { dir, store_id: None, .. } = spec {
                    report.notes.push(format!(
                        "store leaf {} has no recorded store_id (pre-id store): recompute \
                         identity is re-checked at materialization, not proved statically",
                        dir.display()
                    ));
                }
            }
            // Deterministic pure functions of their children: recomputable
            // bit-identically whenever the children are.
            ExprOp::Multiply(..)
            | ExprOp::MultiplySub(..)
            | ExprOp::Subtract(..)
            | ExprOp::Scale(..)
            | ExprOp::Transpose(..)
            | ExprOp::Invert { .. }
            | ExprOp::Quadrant { .. }
            | ExprOp::Arrange(..) => report.evictable += 1,
        }
    }
    report.notes.sort();
    report
}
