//! The distribution unit: one `((rowIndex, colIndex), Matrix)` tuple,
//! exactly the paper's MLLib `MatrixBlock` (§3.2).

use crate::cluster::Bytes;
use crate::linalg::Matrix;

/// Grid coordinates of a block.
pub type BlockIdx = (usize, usize);

/// One block of a distributed matrix.
#[derive(Debug, Clone)]
pub struct Block {
    pub row: usize,
    pub col: usize,
    pub matrix: Matrix,
}

impl Block {
    pub fn new(row: usize, col: usize, matrix: Matrix) -> Self {
        Block { row, col, matrix }
    }

    pub fn idx(&self) -> BlockIdx {
        (self.row, self.col)
    }
}

impl Bytes for Block {
    fn size_bytes(&self) -> u64 {
        16 + self.matrix.size_bytes()
    }
}

impl Bytes for Matrix {
    fn size_bytes(&self) -> u64 {
        Matrix::size_bytes(self)
    }
}

impl Bytes for std::sync::Arc<Matrix> {
    fn size_bytes(&self) -> u64 {
        // The shuffle still ships the full payload across executors even
        // when the in-process representation is shared.
        Matrix::size_bytes(self)
    }
}

/// Quadrant tag produced by `breakMat` (paper: "A11"… strings; a fieldless
/// enum shuffles cheaper and hashes identically well).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Quadrant {
    Q11,
    Q12,
    Q21,
    Q22,
}

impl Quadrant {
    /// Tag for a block at `(ri, ci)` in a grid split at `half` —
    /// the paper's `ri/size` / `ci/size` test in Algorithm 3.
    pub fn of(ri: usize, ci: usize, half: usize) -> Quadrant {
        match (ri / half, ci / half) {
            (0, 0) => Quadrant::Q11,
            (0, _) => Quadrant::Q12,
            (_, 0) => Quadrant::Q21,
            _ => Quadrant::Q22,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Quadrant::Q11 => "A11",
            Quadrant::Q12 => "A12",
            Quadrant::Q21 => "A21",
            Quadrant::Q22 => "A22",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quadrant_tagging_matches_paper() {
        // 4x4 grid split at half=2.
        assert_eq!(Quadrant::of(0, 0, 2), Quadrant::Q11);
        assert_eq!(Quadrant::of(1, 3, 2), Quadrant::Q12);
        assert_eq!(Quadrant::of(2, 0, 2), Quadrant::Q21);
        assert_eq!(Quadrant::of(3, 3, 2), Quadrant::Q22);
        assert_eq!(Quadrant::Q21.label(), "A21");
    }

    #[test]
    fn block_size_accounting() {
        let b = Block::new(0, 1, Matrix::zeros(4, 4));
        assert_eq!(Bytes::size_bytes(&b), 16 + 128);
        assert_eq!(b.idx(), (0, 1));
    }
}
