//! The distributed matrix: an RDD of `((row, col), block)` over the
//! cluster substrate — MLLib's `BlockMatrix` (paper §3.2), plus the six
//! distributed methods of §3.3 (in [`ops`]).

mod block;
mod ops;

pub use block::{Block, BlockIdx, Quadrant};
pub use ops::method as ops_method;

use crate::cluster::{Cluster, Partitioner, Rdd};
use crate::config::{GeneratorKind, JobConfig};
use crate::error::{Result, SpinError};
use crate::linalg::{self, Matrix};

/// A square matrix distributed as an `nblocks × nblocks` grid of square
/// `block_size × block_size` blocks.
#[derive(Debug, Clone)]
pub struct BlockMatrix {
    rdd: Rdd<Block>,
    nblocks: usize,
    block_size: usize,
}

impl BlockMatrix {
    // ---------- constructors ----------

    /// Wrap blocks; validates the grid is complete and uniformly sized.
    /// Partitioning: one block per partition (a block is the task unit in
    /// the paper's cost model), placed by the grid partitioner — block
    /// `(i, j)` in partition `i * nblocks + j` — so every matrix built
    /// here is co-partitioned with every other of the same grid and the
    /// block ops can run narrow.
    pub fn from_blocks(blocks: Vec<Block>, nblocks: usize, block_size: usize) -> Result<Self> {
        if blocks.len() != nblocks * nblocks {
            return Err(SpinError::shape(format!(
                "expected {}x{} = {} blocks, got {}",
                nblocks,
                nblocks,
                nblocks * nblocks,
                blocks.len()
            )));
        }
        let mut seen = vec![false; nblocks * nblocks];
        for b in &blocks {
            if b.row >= nblocks || b.col >= nblocks {
                return Err(SpinError::shape(format!(
                    "block index ({},{}) outside {nblocks}x{nblocks} grid",
                    b.row, b.col
                )));
            }
            if b.matrix.rows() != block_size || b.matrix.cols() != block_size {
                return Err(SpinError::shape(format!(
                    "block ({},{}) is {}x{}, expected {block_size}x{block_size}",
                    b.row,
                    b.col,
                    b.matrix.rows(),
                    b.matrix.cols()
                )));
            }
            let slot = b.row * nblocks + b.col;
            if seen[slot] {
                return Err(SpinError::shape(format!(
                    "duplicate block index ({},{})",
                    b.row, b.col
                )));
            }
            seen[slot] = true;
        }
        let mut parts: Vec<Vec<Block>> = (0..nblocks * nblocks).map(|_| Vec::new()).collect();
        for b in blocks {
            let p = b.row * nblocks + b.col;
            parts[p].push(b);
        }
        Ok(BlockMatrix {
            rdd: Rdd::from_partitions_with(parts, Partitioner::Grid { nblocks }),
            nblocks,
            block_size,
        })
    }

    /// Internal: wrap an already-partitioned RDD (ops preserve invariants).
    pub(crate) fn from_rdd(rdd: Rdd<Block>, nblocks: usize, block_size: usize) -> Self {
        BlockMatrix {
            rdd,
            nblocks,
            block_size,
        }
    }

    /// Split a driver-side dense matrix into blocks (HDFS load stand-in).
    pub fn from_dense(dense: &Matrix, block_size: usize) -> Result<Self> {
        if !dense.is_square() {
            return Err(SpinError::shape("BlockMatrix requires a square matrix"));
        }
        let n = dense.rows();
        if n % block_size != 0 {
            return Err(SpinError::shape(format!(
                "block_size {block_size} does not divide n {n}"
            )));
        }
        let nblocks = n / block_size;
        let mut blocks = Vec::with_capacity(nblocks * nblocks);
        for bi in 0..nblocks {
            for bj in 0..nblocks {
                let m =
                    dense.submatrix(bi * block_size, bj * block_size, block_size, block_size)?;
                blocks.push(Block::new(bi, bj, m));
            }
        }
        BlockMatrix::from_blocks(blocks, nblocks, block_size)
    }

    /// Assemble back into one dense matrix on the driver.
    pub fn to_dense(&self) -> Result<Matrix> {
        let n = self.n();
        let mut out = Matrix::zeros(n, n);
        let mut seen = 0usize;
        for b in self.rdd.iter() {
            out.set_submatrix(b.row * self.block_size, b.col * self.block_size, &b.matrix)?;
            seen += 1;
        }
        if seen != self.nblocks * self.nblocks {
            return Err(SpinError::shape(format!(
                "grid incomplete: {seen} of {} blocks",
                self.nblocks * self.nblocks
            )));
        }
        Ok(out)
    }

    /// Generate a distributed test matrix per the job's generator family.
    /// Blocks come from seed-derived per-block RNG streams
    /// ([`linalg::generate_block`]) — the same pure function the lazy
    /// `ExprOp::LazySource` leaves evaluate on the workers, so eager and
    /// lazy generation are bit-identical by construction.
    pub fn random(job: &JobConfig) -> Result<Self> {
        job.validate()?;
        let nblocks = job.num_splits();
        let blocks = (0..nblocks)
            .flat_map(|bi| (0..nblocks).map(move |bj| (bi, bj)))
            .map(|(bi, bj)| {
                Block::new(
                    bi,
                    bj,
                    linalg::generate_block(job.generator, job.n, job.block_size, bi, bj, job.seed),
                )
            })
            .collect();
        BlockMatrix::from_blocks(blocks, nblocks, job.block_size)
    }

    /// Build a distributed matrix by producing each block **on the
    /// workers**: one grid-placed index per partition, one narrow stage
    /// attributed to `method`, block `(i, j)` produced by `produce` inside
    /// the partition's task. This is the lazy-source materialization path
    /// — the driver never holds more than the assembled RDD, and the
    /// produced blocks land directly under the grid partitioner.
    pub fn materialize_blocks(
        cluster: &Cluster,
        method: &str,
        nblocks: usize,
        block_size: usize,
        produce: impl Fn(usize, usize) -> Result<Matrix> + Sync,
    ) -> Result<Self> {
        let parts: Vec<Vec<(usize, usize)>> = (0..nblocks)
            .flat_map(|i| (0..nblocks).map(move |j| vec![(i, j)]))
            .collect();
        let idx = Rdd::from_partitions_with(parts, Partitioner::Grid { nblocks });
        let out = cluster.map(method, idx, |(i, j): (usize, usize)| {
            produce(i, j).and_then(|m| {
                if m.rows() != block_size || m.cols() != block_size {
                    return Err(SpinError::shape(format!(
                        "source block ({i},{j}) is {}x{}, expected {block_size}x{block_size}",
                        m.rows(),
                        m.cols()
                    )));
                }
                Ok(Block::new(i, j, m))
            })
        });
        let mut ok_parts = Vec::with_capacity(nblocks * nblocks);
        for part in out.into_partitions() {
            let mut ok = Vec::with_capacity(part.len());
            for r in part {
                ok.push(r?);
            }
            ok_parts.push(ok);
        }
        let rdd = Rdd::from_partitions(ok_parts).with_partitioner(Partitioner::Grid { nblocks });
        Ok(BlockMatrix::from_rdd(rdd, nblocks, block_size))
    }

    /// Convenience for examples: a random SPD distributed matrix.
    pub fn random_spd(n: usize, block_size: usize, seed: u64) -> Result<Self> {
        let mut job = JobConfig::new(n, block_size);
        job.generator = GeneratorKind::Spd;
        job.seed = seed;
        BlockMatrix::random(&job)
    }

    /// Distributed identity.
    pub fn identity(n: usize, block_size: usize) -> Result<Self> {
        let eye = Matrix::identity(n);
        BlockMatrix::from_dense(&eye, block_size)
    }

    /// All-zero distributed matrix of the given grid shape.
    pub fn zeros(nblocks: usize, block_size: usize) -> Result<Self> {
        let blocks = (0..nblocks)
            .flat_map(|i| (0..nblocks).map(move |j| (i, j)))
            .map(|(i, j)| Block::new(i, j, Matrix::zeros(block_size, block_size)))
            .collect();
        BlockMatrix::from_blocks(blocks, nblocks, block_size)
    }

    // ---------- accessors ----------

    /// Grid edge — the paper's number of splits `b` at this recursion level.
    pub fn nblocks(&self) -> usize {
        self.nblocks
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// Full matrix order `n`.
    pub fn n(&self) -> usize {
        self.nblocks * self.block_size
    }

    #[allow(dead_code)] // used by unit tests; benches build without cfg(test)
    pub(crate) fn rdd(&self) -> &Rdd<Block> {
        &self.rdd
    }

    pub(crate) fn rdd_clone(&self) -> Rdd<Block> {
        self.rdd.clone()
    }

    /// The grid placement every matrix of this shape should follow.
    pub(crate) fn grid_partitioner(&self) -> Partitioner {
        Partitioner::Grid {
            nblocks: self.nblocks,
        }
    }

    /// This matrix's blocks, guaranteed grid-partitioned: free when the
    /// RDD already carries the grid partitioner (the invariant every
    /// constructor and op maintains), one counted shuffle otherwise.
    pub(crate) fn aligned_rdd(&self, cluster: &Cluster, method: &str) -> Rdd<Block> {
        let nb = self.nblocks;
        cluster.partition_items_by(method, self.rdd.clone(), self.grid_partitioner(), move |b| {
            b.row * nb + b.col
        })
    }

    /// Driver-side block lookup (test helper; O(blocks)).
    pub fn get_block(&self, row: usize, col: usize) -> Option<&Block> {
        self.rdd.iter().find(|b| b.row == row && b.col == col)
    }

    /// Shape/grid compatibility check for binary ops.
    pub(crate) fn check_same_grid(&self, other: &BlockMatrix, op: &str) -> Result<()> {
        if self.nblocks != other.nblocks || self.block_size != other.block_size {
            return Err(SpinError::shape(format!(
                "{op}: grid mismatch {}x{} (bs {}) vs {}x{} (bs {})",
                self.nblocks,
                self.nblocks,
                self.block_size,
                other.nblocks,
                other.nblocks,
                other.block_size
            )));
        }
        Ok(())
    }

    /// Map every block's payload through a fallible kernel, as one
    /// distributed stage attributed to `method`. Payload-only: block
    /// indices never move, so the input's partitioner is re-stamped.
    pub fn map_blocks_try(
        &self,
        cluster: &Cluster,
        method: &str,
        f: impl Fn(&Matrix) -> Result<Matrix> + Sync,
    ) -> Result<BlockMatrix> {
        let partitioner = self.rdd.partitioner();
        let out = cluster.map(method, self.rdd_clone(), |blk: Block| {
            f(&blk.matrix).map(|m| Block::new(blk.row, blk.col, m))
        });
        let parts = out.into_partitions();
        let mut ok_parts = Vec::with_capacity(parts.len());
        for part in parts {
            let mut ok = Vec::with_capacity(part.len());
            for r in part {
                ok.push(r?);
            }
            ok_parts.push(ok);
        }
        let mut rdd = Rdd::from_partitions(ok_parts);
        if let Some(p) = partitioner {
            rdd = rdd.with_partitioner(p);
        }
        Ok(BlockMatrix::from_rdd(rdd, self.nblocks, self.block_size))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn from_dense_round_trip() {
        let mut rng = Rng::new(1);
        let dense = Matrix::random_uniform(8, 8, -1.0, 1.0, &mut rng);
        let bm = BlockMatrix::from_dense(&dense, 2).unwrap();
        assert_eq!(bm.nblocks(), 4);
        assert_eq!(bm.n(), 8);
        assert!(bm.to_dense().unwrap().max_abs_diff(&dense) < 1e-15);
    }

    #[test]
    fn block_payload_matches_quadrant() {
        let dense = Matrix::from_fn(4, 4, |i, j| (i * 4 + j) as f64);
        let bm = BlockMatrix::from_dense(&dense, 2).unwrap();
        let b01 = bm.get_block(0, 1).unwrap();
        assert_eq!(b01.matrix.get(0, 0), dense.get(0, 2));
        assert_eq!(b01.matrix.get(1, 1), dense.get(1, 3));
    }

    #[test]
    fn from_blocks_validates() {
        // wrong count
        assert!(BlockMatrix::from_blocks(vec![], 1, 4).is_err());
        // bad index
        let blocks = vec![Block::new(2, 0, Matrix::zeros(4, 4))];
        assert!(BlockMatrix::from_blocks(blocks, 1, 4).is_err());
        // bad size
        let blocks = vec![Block::new(0, 0, Matrix::zeros(3, 4))];
        assert!(BlockMatrix::from_blocks(blocks, 1, 4).is_err());
        // duplicate
        let blocks = vec![
            Block::new(0, 0, Matrix::zeros(2, 2)),
            Block::new(0, 0, Matrix::zeros(2, 2)),
        ];
        assert!(BlockMatrix::from_blocks(blocks, 1, 2).is_err());
    }

    #[test]
    fn from_dense_rejects_bad_shapes() {
        let m = Matrix::zeros(4, 6);
        assert!(BlockMatrix::from_dense(&m, 2).is_err()); // not square
        let m = Matrix::zeros(6, 6);
        assert!(BlockMatrix::from_dense(&m, 4).is_err()); // 4 ∤ 6
    }

    #[test]
    fn identity_and_zeros() {
        let i = BlockMatrix::identity(8, 4).unwrap();
        assert!(i.to_dense().unwrap().max_abs_diff(&Matrix::identity(8)) < 1e-15);
        let z = BlockMatrix::zeros(2, 4).unwrap();
        assert_eq!(z.to_dense().unwrap().max_abs(), 0.0);
    }

    #[test]
    fn one_block_per_partition_under_grid_placement() {
        let bm = BlockMatrix::identity(8, 2).unwrap();
        assert_eq!(bm.rdd().num_partitions(), 16);
        assert_eq!(bm.rdd().partitioner(), Some(Partitioner::Grid { nblocks: 4 }));
        // Block (i, j) lives alone in partition i * nblocks + j.
        for (p, part) in bm.rdd().partitions().iter().enumerate() {
            assert_eq!(part.len(), 1);
            assert_eq!(part[0].row * 4 + part[0].col, p);
        }
    }

    #[test]
    fn random_is_seed_deterministic() {
        let job = JobConfig::new(16, 4);
        let a = BlockMatrix::random(&job).unwrap().to_dense().unwrap();
        let b = BlockMatrix::random(&job).unwrap().to_dense().unwrap();
        assert_eq!(a.max_abs_diff(&b), 0.0);
    }

    #[test]
    fn materialize_blocks_matches_eager_random_bitwise() {
        use crate::config::{ClusterConfig, GeneratorKind};
        let cluster = Cluster::new(ClusterConfig::local(2));
        for generator in [GeneratorKind::DiagDominant, GeneratorKind::Spd] {
            let mut job = JobConfig::new(32, 8);
            job.seed = 0xBEE;
            job.generator = generator;
            let eager = BlockMatrix::random(&job).unwrap();
            let lazy = BlockMatrix::materialize_blocks(&cluster, "generate", 4, 8, |i, j| {
                Ok(linalg::generate_block(generator, 32, 8, i, j, 0xBEE))
            })
            .unwrap();
            assert_eq!(
                lazy.to_dense()
                    .unwrap()
                    .max_abs_diff(&eager.to_dense().unwrap()),
                0.0,
                "{generator:?}: worker-produced blocks must match eager bits"
            );
            assert_eq!(
                lazy.rdd().partitioner(),
                Some(Partitioner::Grid { nblocks: 4 }),
                "lazy sources land grid-partitioned"
            );
        }
        // The production stage is attributed and narrow.
        let m = cluster.metrics();
        assert_eq!(m.method("generate").unwrap().calls, 2);
        assert_eq!(m.method("generate").unwrap().shuffle_stages, 0);
        assert_eq!(m.driver_collects(), 0);
    }

    #[test]
    fn materialize_blocks_surfaces_producer_errors() {
        use crate::config::ClusterConfig;
        let cluster = Cluster::new(ClusterConfig::local(2));
        let bad_shape = BlockMatrix::materialize_blocks(&cluster, "generate", 2, 4, |_, _| {
            Ok(Matrix::zeros(3, 3))
        });
        assert!(bad_shape.unwrap_err().to_string().contains("expected 4x4"));
        let io = BlockMatrix::materialize_blocks(&cluster, "load", 2, 4, |i, j| {
            Err(SpinError::artifact(format!("missing block ({i},{j})")))
        });
        assert!(io.is_err());
    }
}
