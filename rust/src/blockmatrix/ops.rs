//! The six distributed methods of paper §3.3 — `breakMat`, `xy`,
//! `multiply`, `subtract`, `scalarMul`, `arrange` — plus `transpose`.
//!
//! Method-name strings match the paper's Table 3 rows so the metrics
//! registry regenerates that table directly.

use crate::blockmatrix::block::{Block, Quadrant};
use crate::blockmatrix::BlockMatrix;
use crate::cluster::{Cluster, Rdd};
use crate::error::{Result, SpinError};

use crate::runtime::BlockKernels;

/// Metric names (Table 3 rows).
pub mod method {
    pub const LEAF_NODE: &str = "leafNode";
    pub const BREAK_MAT: &str = "breakMat";
    pub const XY: &str = "xy";
    pub const MULTIPLY: &str = "multiply";
    pub const SUBTRACT: &str = "subtract";
    pub const SCALAR_MUL: &str = "scalar";
    pub const ARRANGE: &str = "arrange";
}

impl BlockMatrix {
    /// Algorithm 3: tag every block with its quadrant and remap indices into
    /// the half-grid (`ri % size`, `ci % size`). One `mapToPair` pass.
    pub fn break_mat(&self, cluster: &Cluster) -> Result<Rdd<(Quadrant, Block)>> {
        if self.nblocks() % 2 != 0 {
            return Err(SpinError::shape(format!(
                "cannot break a {}x{} block grid in half",
                self.nblocks(),
                self.nblocks()
            )));
        }
        let half = self.nblocks() / 2;
        Ok(cluster.map(method::BREAK_MAT, self.rdd_clone(), move |mut blk: Block| {
            let tag = Quadrant::of(blk.row, blk.col, half);
            blk.row %= half;
            blk.col %= half;
            (tag, blk)
        }))
    }

    /// Algorithm 4 (`xy`): filter one quadrant out of a broken pair-RDD and
    /// strip the tags. The paper runs `_11`…`_22` as four filter+map passes
    /// over the same RDD; `quadrant` is one such pass.
    pub fn quadrant(
        cluster: &Cluster,
        broken: &Rdd<(Quadrant, Block)>,
        which: Quadrant,
        half: usize,
        block_size: usize,
    ) -> BlockMatrix {
        let filtered = cluster.filter(method::XY, broken.clone(), move |(tag, _)| *tag == which);
        let rdd = cluster.map(method::XY, filtered, |(_, blk)| blk);
        // Re-partition: one block per partition for downstream task counts.
        let blocks = rdd.into_items();
        let nparts = blocks.len().max(1);
        BlockMatrix::from_rdd(Rdd::from_items(blocks, nparts), half, block_size)
    }

    /// Break into the four half-grid quadrants (breakMat + 4 × xy).
    pub fn split(
        &self,
        cluster: &Cluster,
    ) -> Result<(BlockMatrix, BlockMatrix, BlockMatrix, BlockMatrix)> {
        let broken = self.break_mat(cluster)?;
        let half = self.nblocks() / 2;
        let bs = self.block_size();
        let a11 = BlockMatrix::quadrant(cluster, &broken, Quadrant::Q11, half, bs);
        let a12 = BlockMatrix::quadrant(cluster, &broken, Quadrant::Q12, half, bs);
        let a21 = BlockMatrix::quadrant(cluster, &broken, Quadrant::Q21, half, bs);
        let a22 = BlockMatrix::quadrant(cluster, &broken, Quadrant::Q22, half, bs);
        Ok((a11, a12, a21, a22))
    }

    /// Paper §3.3 `multiply`: naive replicated block matmul. Every A block
    /// `(i,k)` is replicated to all `(i,j,k)` keys, every B block `(k,j)` to
    /// all `(i,j,k)`; a co-group brings each pair to one reducer, which
    /// multiplies; a reduce-by-key sums over `k`.
    pub fn multiply(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        other: &BlockMatrix,
    ) -> Result<BlockMatrix> {
        self.check_same_grid(other, "multiply")?;
        let b = self.nblocks();
        let bs = self.block_size();
        let nparts = b * b;

        // Replicate (map-side, narrow). §Perf: payloads are shared via
        // `Arc` — Spark replicates references into shuffle files, not b
        // deep copies in executor memory; deep-cloning here dominated the
        // replication stage at large b (EXPERIMENTS.md §Perf, L3-2).
        let a_rep = cluster.flat_map(method::MULTIPLY, self.rdd_clone(), move |blk: Block| {
            let m = std::sync::Arc::new(blk.matrix);
            (0..b)
                .map(move |j| ((blk.row, j, blk.col), std::sync::Arc::clone(&m)))
                .collect::<Vec<_>>()
        });
        let b_rep = cluster.flat_map(method::MULTIPLY, other.rdd_clone(), move |blk: Block| {
            let m = std::sync::Arc::new(blk.matrix);
            (0..b)
                .map(move |i| ((i, blk.col, blk.row), std::sync::Arc::clone(&m)))
                .collect::<Vec<_>>()
        });

        // Co-group on (i, j, k): exactly one A and one B block per key.
        let paired = cluster.cogroup(method::MULTIPLY, a_rep, b_rep, nparts);

        // Per-key block GEMM.
        let products = cluster.map(method::MULTIPLY, paired, |((i, j, _k), (avs, bvs))| {
            debug_assert_eq!(avs.len(), 1);
            debug_assert_eq!(bvs.len(), 1);
            let prod = kernels
                .matmul(&avs[0], &bvs[0])
                .expect("block matmul kernel failed");
            ((i, j), prod)
        });

        // Sum the k partial products per output block.
        let summed = cluster.reduce_by_key(method::MULTIPLY, products, nparts, |acc, m| {
            acc.add(&m).expect("partial product shapes agree")
        });

        let blocks = cluster.map(method::MULTIPLY, summed, |((i, j), m)| Block::new(i, j, m));
        let items = blocks.into_items();
        if items.len() != b * b {
            return Err(SpinError::cluster(format!(
                "multiply produced {} blocks, expected {}",
                items.len(),
                b * b
            )));
        }
        let n = items.len();
        Ok(BlockMatrix::from_rdd(Rdd::from_items(items, n), b, bs))
    }

    /// Paper §3.3 `subtract`: align blocks by index, C = A − B.
    pub fn subtract(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        other: &BlockMatrix,
    ) -> Result<BlockMatrix> {
        self.check_same_grid(other, "subtract")?;
        self.binary_elementwise(cluster, kernels, other, method::SUBTRACT, false)
    }

    /// Fused C = A·B − D used for SPIN's Schur step when enabled; kept
    /// separate so the ablation bench can compare fused vs composed.
    pub fn multiply_sub(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        other: &BlockMatrix,
        d: &BlockMatrix,
    ) -> Result<BlockMatrix> {
        let prod = self.multiply(cluster, kernels, other)?;
        prod.subtract(cluster, kernels, d)
    }

    fn binary_elementwise(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        other: &BlockMatrix,
        name: &str,
        _add: bool,
    ) -> Result<BlockMatrix> {
        let b = self.nblocks();
        let bs = self.block_size();
        let nparts = b * b;
        let left = cluster.map(name, self.rdd_clone(), |blk: Block| (blk.idx(), blk.matrix));
        let right = cluster.map(name, other.rdd_clone(), |blk: Block| (blk.idx(), blk.matrix));
        let paired = cluster.cogroup(name, left, right, nparts);
        let out = cluster.map(name, paired, |((i, j), (ls, rs))| {
            debug_assert_eq!(ls.len(), 1);
            debug_assert_eq!(rs.len(), 1);
            let m = kernels
                .subtract(&ls[0], &rs[0])
                .expect("subtract kernel failed");
            Block::new(i, j, m)
        });
        let items = out.into_items();
        let n = items.len();
        Ok(BlockMatrix::from_rdd(Rdd::from_items(items, n), b, bs))
    }

    /// Paper §3.3 / Algorithm 5 `scalarMul`: one map over blocks.
    pub fn scalar_mul(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        s: f64,
    ) -> Result<BlockMatrix> {
        self.map_blocks_try(cluster, method::SCALAR_MUL, |m| kernels.scale(m, s))
    }

    /// Algorithm 6 `arrange`: re-index the four quadrants into the full
    /// grid (three shifting maps — C11 keeps its indices) and union.
    pub fn arrange(
        cluster: &Cluster,
        c11: BlockMatrix,
        c12: BlockMatrix,
        c21: BlockMatrix,
        c22: BlockMatrix,
    ) -> Result<BlockMatrix> {
        c11.check_same_grid(&c12, "arrange")?;
        c11.check_same_grid(&c21, "arrange")?;
        c11.check_same_grid(&c22, "arrange")?;
        let half = c11.nblocks();
        let bs = c11.block_size();

        let r12 = cluster.map(method::ARRANGE, c12.rdd_clone(), move |mut b: Block| {
            b.col += half;
            b
        });
        let r21 = cluster.map(method::ARRANGE, c21.rdd_clone(), move |mut b: Block| {
            b.row += half;
            b
        });
        let r22 = cluster.map(method::ARRANGE, c22.rdd_clone(), move |mut b: Block| {
            b.row += half;
            b.col += half;
            b
        });
        let unioned = c11
            .rdd_clone()
            .union(r12)
            .union(r21)
            .union(r22);
        let items = unioned.into_items();
        let n = items.len();
        Ok(BlockMatrix::from_rdd(
            Rdd::from_items(items, n),
            2 * half,
            bs,
        ))
    }

    /// Distributed transpose (one map: swap indices + transpose payloads).
    pub fn transpose(&self, cluster: &Cluster) -> BlockMatrix {
        let out = cluster.map("transpose", self.rdd_clone(), |blk: Block| {
            Block::new(blk.col, blk.row, blk.matrix.transpose())
        });
        let items = out.into_items();
        let n = items.len();
        BlockMatrix::from_rdd(
            Rdd::from_items(items, n),
            self.nblocks(),
            self.block_size(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::linalg::{self, matmul, Matrix};
    use crate::runtime::NativeBackend;
    use crate::util::check::forall;
    use crate::util::Rng;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig::local(4))
    }

    fn rand_bm(n: usize, bs: usize, seed: u64) -> (Matrix, BlockMatrix) {
        let mut rng = Rng::new(seed);
        let dense = Matrix::random_uniform(n, n, -1.0, 1.0, &mut rng);
        let bm = BlockMatrix::from_dense(&dense, bs).unwrap();
        (dense, bm)
    }

    #[test]
    fn break_then_quadrants_match_dense() {
        let c = cluster();
        let (dense, bm) = rand_bm(8, 2, 1);
        let (a11, a12, a21, a22) = bm.split(&c).unwrap();
        assert_eq!(a11.nblocks(), 2);
        assert!(a11.to_dense().unwrap().max_abs_diff(&dense.submatrix(0, 0, 4, 4).unwrap()) < 1e-15);
        assert!(a12.to_dense().unwrap().max_abs_diff(&dense.submatrix(0, 4, 4, 4).unwrap()) < 1e-15);
        assert!(a21.to_dense().unwrap().max_abs_diff(&dense.submatrix(4, 0, 4, 4).unwrap()) < 1e-15);
        assert!(a22.to_dense().unwrap().max_abs_diff(&dense.submatrix(4, 4, 4, 4).unwrap()) < 1e-15);
    }

    #[test]
    fn split_arrange_round_trip() {
        let c = cluster();
        let (dense, bm) = rand_bm(8, 2, 2);
        let (a11, a12, a21, a22) = bm.split(&c).unwrap();
        let back = BlockMatrix::arrange(&c, a11, a12, a21, a22).unwrap();
        assert!(back.to_dense().unwrap().max_abs_diff(&dense) < 1e-15);
    }

    #[test]
    fn break_mat_rejects_odd_grids() {
        let bm = BlockMatrix::identity(6, 2).unwrap(); // 3x3 grid
        assert!(bm.break_mat(&cluster()).is_err());
    }

    #[test]
    fn multiply_matches_serial() {
        let c = cluster();
        for (n, bs) in [(4usize, 2usize), (8, 2), (8, 4), (16, 4)] {
            let (da, a) = rand_bm(n, bs, 10 + n as u64);
            let (db, b) = rand_bm(n, bs, 20 + n as u64);
            let got = a.multiply(&c, &NativeBackend, &b).unwrap();
            let want = matmul(&da, &db);
            let diff = got.to_dense().unwrap().max_abs_diff(&want);
            assert!(diff < 1e-11, "n={n} bs={bs} diff={diff}");
        }
    }

    #[test]
    fn multiply_single_block_grid() {
        let c = cluster();
        let (da, a) = rand_bm(4, 4, 30);
        let (db, b) = rand_bm(4, 4, 31);
        let got = a.multiply(&c, &NativeBackend, &b).unwrap();
        assert!(got.to_dense().unwrap().max_abs_diff(&matmul(&da, &db)) < 1e-12);
    }

    #[test]
    fn multiply_grid_mismatch_errors() {
        let c = cluster();
        let a = BlockMatrix::identity(8, 2).unwrap();
        let b = BlockMatrix::identity(8, 4).unwrap();
        assert!(a.multiply(&c, &NativeBackend, &b).is_err());
    }

    #[test]
    fn subtract_matches_dense() {
        let c = cluster();
        let (da, a) = rand_bm(8, 4, 40);
        let (db, b) = rand_bm(8, 4, 41);
        let got = a.subtract(&c, &NativeBackend, &b).unwrap();
        assert!(got.to_dense().unwrap().max_abs_diff(&da.sub(&db).unwrap()) < 1e-15);
    }

    #[test]
    fn scalar_mul_matches_dense() {
        let c = cluster();
        let (d, a) = rand_bm(8, 2, 50);
        let got = a.scalar_mul(&c, &NativeBackend, -2.5).unwrap();
        assert!(got.to_dense().unwrap().max_abs_diff(&d.scale(-2.5)) < 1e-15);
    }

    #[test]
    fn transpose_matches_dense() {
        let c = cluster();
        let (d, a) = rand_bm(8, 4, 60);
        let got = a.transpose(&c);
        assert!(got.to_dense().unwrap().max_abs_diff(&d.transpose()) < 1e-15);
    }

    #[test]
    fn multiply_by_identity_is_noop() {
        let c = cluster();
        let (d, a) = rand_bm(8, 2, 70);
        let eye = BlockMatrix::identity(8, 2).unwrap();
        let got = a.multiply(&c, &NativeBackend, &eye).unwrap();
        assert!(got.to_dense().unwrap().max_abs_diff(&d) < 1e-14);
    }

    #[test]
    fn metrics_use_paper_method_names() {
        let c = cluster();
        let (_, a) = rand_bm(8, 2, 80);
        let (_, b) = rand_bm(8, 2, 81);
        let _ = a.multiply(&c, &NativeBackend, &b).unwrap();
        let _ = a.split(&c).unwrap();
        let _ = a.scalar_mul(&c, &NativeBackend, 2.0).unwrap();
        let snap = c.metrics();
        for name in ["multiply", "breakMat", "xy", "scalar"] {
            assert!(snap.method(name).is_some(), "missing metric {name}");
        }
    }

    #[test]
    fn property_distributed_ops_match_dense() {
        forall(
            "blockmatrix ≡ dense algebra",
            0xB0,
            8,
            |r| {
                let pow = 2 + r.next_usize(2); // n = 4 or 8
                let n = 1usize << pow;
                let bs = 1usize << (1 + r.next_usize(pow - 1));
                (n, bs, r.next_u64())
            },
            |&(n, bs, seed)| {
                let c = cluster();
                let mut rng = Rng::new(seed);
                let da = Matrix::random_uniform(n, n, -1.0, 1.0, &mut rng);
                let db = Matrix::random_uniform(n, n, -1.0, 1.0, &mut rng);
                let a = BlockMatrix::from_dense(&da, bs).unwrap();
                let b = BlockMatrix::from_dense(&db, bs).unwrap();
                let prod = a
                    .multiply(&c, &NativeBackend, &b)
                    .map_err(|e| e.to_string())?
                    .to_dense()
                    .unwrap();
                let want = linalg::matmul(&da, &db);
                let diff = prod.max_abs_diff(&want);
                if diff > 1e-10 {
                    return Err(format!("multiply diff {diff} (n={n} bs={bs})"));
                }
                let sub = a
                    .subtract(&c, &NativeBackend, &b)
                    .map_err(|e| e.to_string())?
                    .to_dense()
                    .unwrap();
                if sub.max_abs_diff(&da.sub(&db).unwrap()) > 1e-14 {
                    return Err("subtract mismatch".into());
                }
                Ok(())
            },
        );
    }
}
