//! The six distributed methods of paper §3.3 — `breakMat`, `xy`,
//! `multiply`, `subtract`, `scalarMul`, `arrange` — plus `transpose`.
//!
//! Method-name strings match the paper's Table 3 rows so the metrics
//! registry regenerates that table directly.
//!
//! ## Partitioner contract
//!
//! Every [`BlockMatrix`] keeps its blocks under the **grid partitioner**
//! (block `(i, j)` alone in partition `i * nblocks + j` — see
//! [`crate::cluster::Partitioner::Grid`]), and every op here restores that
//! invariant on its output. That one promise decides which ops are narrow
//! and which must shuffle:
//!
//! * **Narrow (zero shuffle bytes, zero driver round-trips):** `breakMat`
//!   and `xy` (quadrant extraction moves *whole* one-block partitions, a
//!   1-to-1 dependency), `arrange` (the inverse interleave), `subtract`
//!   and every elementwise op (co-partitioned `zip_partitions` join),
//!   `scalarMul`, and `transpose` (a partition permutation).
//! * **Wide (one shuffle round):** the pairing stage of `multiply`.
//!   Each A block `(i, k)` and B block `(k, j)` is replicated to key
//!   `(i, j, k)` and routed **by output index `(i, j)`** straight to the
//!   grid partition its product lands in — so the k-summing reduce (and,
//!   for [`BlockMatrix::multiply_sub`], the fused Schur subtraction) runs
//!   inside the same narrow stage. That single round is recorded as two
//!   exchange stages in the metrics (one per operand stream); the
//!   replicated path's *extra* round — re-shuffling every partial product
//!   for the reduce — is gone.
//!
//! The pre-partitioner pipeline — replicated cogroup multiply plus
//! driver-side re-parallelization after every op — is kept behind
//! `ClusterConfig::partitioner_aware = false` (and
//! [`BlockMatrix::multiply_replicated`]) so the shuffle-byte and
//! driver-round-trip savings stay measurable.

use std::collections::{BTreeMap, HashMap};
use std::sync::Arc;

use crate::blockmatrix::block::{Block, Quadrant};
use crate::blockmatrix::BlockMatrix;
use crate::cluster::{Cluster, Partitioner, Rdd};
use crate::error::{Result, SpinError};
use crate::linalg::Matrix;

use crate::runtime::BlockKernels;

/// Metric names (Table 3 rows).
pub mod method {
    pub const LEAF_NODE: &str = "leafNode";
    pub const BREAK_MAT: &str = "breakMat";
    pub const XY: &str = "xy";
    pub const MULTIPLY: &str = "multiply";
    pub const SUBTRACT: &str = "subtract";
    pub const SCALAR_MUL: &str = "scalar";
    pub const ARRANGE: &str = "arrange";
    pub const TRANSPOSE: &str = "transpose";
}

/// One replicated operand copy in the multiply pairing stage: the key is
/// `(i, j, k)` — output block `(i, j)`, inner index `k` — and the payload
/// is shared via `Arc` (Spark replicates references into shuffle files,
/// not deep copies in executor memory; see EXPERIMENTS.md §Perf, L3-2).
type RepEntry = ((usize, usize, usize), Arc<Matrix>);

impl BlockMatrix {
    /// Algorithm 3: tag every block with its quadrant and remap indices into
    /// the half-grid (`ri % size`, `ci % size`). One `mapToPair` pass; the
    /// blocks stay in their grid partitions.
    ///
    /// In partitioner-aware mode the output is stamped with the *parent's*
    /// grid partitioner: the map only re-keys payloads in place, so element
    /// at partition `p` is still the parent's block `(p / b, p % b)`. That
    /// stamp is the provenance [`BlockMatrix::quadrant`] requires before it
    /// extracts quadrants by moving whole partitions.
    pub fn break_mat(&self, cluster: &Cluster) -> Result<Rdd<(Quadrant, Block)>> {
        if self.nblocks() % 2 != 0 {
            return Err(SpinError::shape(format!(
                "cannot break a {}x{} block grid in half",
                self.nblocks(),
                self.nblocks()
            )));
        }
        let b = self.nblocks();
        let half = b / 2;
        let aware = cluster.config().partitioner_aware;
        let src = if aware {
            self.aligned_rdd(cluster, method::BREAK_MAT)
        } else {
            self.rdd_clone()
        };
        let out = cluster.map(method::BREAK_MAT, src, move |mut blk: Block| {
            let tag = Quadrant::of(blk.row, blk.col, half);
            blk.row %= half;
            blk.col %= half;
            (tag, blk)
        });
        Ok(if aware {
            out.with_partitioner(Partitioner::Grid { nblocks: b })
        } else {
            out
        })
    }

    /// Algorithm 4 (`xy`): filter one quadrant out of a broken pair-RDD and
    /// strip the tags. The paper runs `_11`…`_22` as four filter+map passes
    /// over the same RDD; `quadrant` is one such pass.
    ///
    /// When `broken` carries the parent-grid provenance stamp that
    /// [`BlockMatrix::break_mat`] sets, the result is re-gridded by moving
    /// whole one-block partitions — a narrow 1-to-1 dependency with zero
    /// shuffle bytes. Otherwise (a hand-built pair-RDD, or with
    /// `partitioner_aware` off) it falls back to the original driver-side
    /// re-parallelization.
    pub fn quadrant(
        cluster: &Cluster,
        broken: &Rdd<(Quadrant, Block)>,
        which: Quadrant,
        half: usize,
        block_size: usize,
    ) -> BlockMatrix {
        let b = 2 * half;
        let parent_grid = broken.partitioner() == Some(Partitioner::Grid { nblocks: b });
        let filtered = cluster.filter(method::XY, broken.clone(), move |(tag, _)| *tag == which);
        let rdd = cluster.map(method::XY, filtered, |(_, blk)| blk);
        if cluster.config().partitioner_aware && parent_grid {
            let (roff, coff) = match which {
                Quadrant::Q11 => (0, 0),
                Quadrant::Q12 => (0, half),
                Quadrant::Q21 => (half, 0),
                Quadrant::Q22 => (half, half),
            };
            let sources: Vec<usize> = (0..half)
                .flat_map(|i| (0..half).map(move |j| (i + roff) * b + (j + coff)))
                .collect();
            let grid = rdd
                .select_partitions(&sources)
                .with_partitioner(Partitioner::Grid { nblocks: half });
            BlockMatrix::from_rdd(grid, half, block_size)
        } else {
            // Legacy: materialize on the driver and re-parallelize.
            let blocks = cluster.collect(rdd);
            let nparts = blocks.len().max(1);
            BlockMatrix::from_rdd(Rdd::from_items(blocks, nparts), half, block_size)
        }
    }

    /// Break into the four half-grid quadrants (breakMat + 4 × xy).
    pub fn split(
        &self,
        cluster: &Cluster,
    ) -> Result<(BlockMatrix, BlockMatrix, BlockMatrix, BlockMatrix)> {
        let broken = self.break_mat(cluster)?;
        let half = self.nblocks() / 2;
        let bs = self.block_size();
        let a11 = BlockMatrix::quadrant(cluster, &broken, Quadrant::Q11, half, bs);
        let a12 = BlockMatrix::quadrant(cluster, &broken, Quadrant::Q12, half, bs);
        let a21 = BlockMatrix::quadrant(cluster, &broken, Quadrant::Q21, half, bs);
        let a22 = BlockMatrix::quadrant(cluster, &broken, Quadrant::Q22, half, bs);
        Ok((a11, a12, a21, a22))
    }

    /// Paper §3.3 `multiply`: C = A·B. With the partitioner-aware dataflow
    /// this is one shuffle round (the `(i, j, k)` pairing — two recorded
    /// exchanges, one per operand stream — routed by output index)
    /// followed by one narrow GEMM+reduce stage; with it disabled, the
    /// original replicated-cogroup path runs instead.
    pub fn multiply(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        other: &BlockMatrix,
    ) -> Result<BlockMatrix> {
        self.check_same_grid(other, "multiply")?;
        if cluster.config().partitioner_aware {
            self.multiply_partitioned(cluster, kernels, other, None)
        } else {
            self.multiply_replicated(cluster, kernels, other)
        }
    }

    /// Fused C = A·B − D — SPIN's Schur step `V = A21·III − A22`. The
    /// subtraction happens **inside** the multiply's final reduce stage
    /// (D is co-partitioned with the routed products), so the composed
    /// `multiply` + `subtract` pair's extra stage disappears entirely —
    /// and with the legacy wide subtract, a whole shuffle per recursion
    /// level with it.
    pub fn multiply_sub(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        other: &BlockMatrix,
        d: &BlockMatrix,
    ) -> Result<BlockMatrix> {
        self.check_same_grid(other, "multiply_sub")?;
        self.check_same_grid(d, "multiply_sub")?;
        if cluster.config().partitioner_aware {
            self.multiply_partitioned(cluster, kernels, other, Some(d))
        } else {
            let prod = self.multiply_replicated(cluster, kernels, other)?;
            prod.subtract(cluster, kernels, d)
        }
    }

    /// Partitioner-aware multiply core: replicate map-side, shuffle once
    /// routed by output block index, then multiply + sum (+ optionally
    /// subtract `minus`) in a single narrow stage.
    fn multiply_partitioned(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        other: &BlockMatrix,
        minus: Option<&BlockMatrix>,
    ) -> Result<BlockMatrix> {
        let b = self.nblocks();
        let bs = self.block_size();
        let target = Partitioner::Grid { nblocks: b };

        // Replicate (map-side, narrow): A block (i, k) to keys (i, j, k)
        // for all j; B block (k, j) to keys (i, j, k) for all i.
        let a_rep = cluster.flat_map(
            method::MULTIPLY,
            self.aligned_rdd(cluster, method::MULTIPLY),
            move |blk: Block| {
                let m = Arc::new(blk.matrix);
                (0..b)
                    .map(move |j| ((blk.row, j, blk.col), Arc::clone(&m)))
                    .collect::<Vec<_>>()
            },
        );
        let b_rep = cluster.flat_map(
            method::MULTIPLY,
            other.aligned_rdd(cluster, method::MULTIPLY),
            move |blk: Block| {
                let m = Arc::new(blk.matrix);
                (0..b)
                    .map(move |i| ((i, blk.col, blk.row), Arc::clone(&m)))
                    .collect::<Vec<_>>()
            },
        );

        // The single shuffle round (one exchange per operand stream):
        // route every (i, j, k) replica straight to the grid partition of
        // its OUTPUT block (i, j). All k-terms for one product land
        // together, so the sum never shuffles again.
        let a_parts =
            cluster.partition_pairs_by(method::MULTIPLY, a_rep, target, move |&(i, j, _k)| {
                i * b + j
            });
        let b_parts =
            cluster.partition_pairs_by(method::MULTIPLY, b_rep, target, move |&(i, j, _k)| {
                i * b + j
            });

        // One narrow stage: per-key GEMM, k-sum, and (when fused) the
        // Schur subtraction against the co-partitioned D blocks.
        let joined = match minus {
            Some(d) => {
                let d_rdd = d.aligned_rdd(cluster, method::MULTIPLY);
                cluster.zip_partitions3(method::MULTIPLY, a_parts, b_parts, d_rdd, |avs, bvs, dvs| {
                    join_products(kernels, avs, bvs, Some(dvs))
                })
            }
            None => cluster.zip_partitions(method::MULTIPLY, a_parts, b_parts, |avs, bvs| {
                join_products(kernels, avs, bvs, None)
            }),
        };

        let out = joined.with_partitioner(target);
        if out.len() != b * b {
            return Err(SpinError::cluster(format!(
                "multiply produced {} blocks, expected {}",
                out.len(),
                b * b
            )));
        }
        Ok(BlockMatrix::from_rdd(out, b, bs))
    }

    /// The paper's original naive replicated block matmul: every A block
    /// `(i,k)` is replicated to all `(i,j,k)` keys, every B block `(k,j)`
    /// to all `(i,j,k)`; a co-group brings each pair to one reducer, which
    /// multiplies; a reduce-by-key sums over `k` (a second shuffle); the
    /// result is re-parallelized through the driver. Kept as the
    /// measurable "before" of the partitioner-aware dataflow and for
    /// ablation benches.
    //
    // expect is invariant-backed: the replicate-k exchange emits every
    // (i, j, k) replica pair and the kernel contract returns a block for
    // conforming shapes, both established before this hot path runs.
    #[allow(clippy::expect_used)]
    pub fn multiply_replicated(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        other: &BlockMatrix,
    ) -> Result<BlockMatrix> {
        self.check_same_grid(other, "multiply")?;
        let b = self.nblocks();
        let bs = self.block_size();
        let nparts = b * b;

        let a_rep = cluster.flat_map(method::MULTIPLY, self.rdd_clone(), move |blk: Block| {
            let m = Arc::new(blk.matrix);
            (0..b)
                .map(move |j| ((blk.row, j, blk.col), Arc::clone(&m)))
                .collect::<Vec<_>>()
        });
        let b_rep = cluster.flat_map(method::MULTIPLY, other.rdd_clone(), move |blk: Block| {
            let m = Arc::new(blk.matrix);
            (0..b)
                .map(move |i| ((i, blk.col, blk.row), Arc::clone(&m)))
                .collect::<Vec<_>>()
        });

        // Co-group on (i, j, k): exactly one A and one B block per key.
        let paired = cluster.cogroup(method::MULTIPLY, a_rep, b_rep, nparts);

        // Per-key block GEMM.
        let products = cluster.map(method::MULTIPLY, paired, |((i, j, _k), (avs, bvs))| {
            debug_assert_eq!(avs.len(), 1);
            debug_assert_eq!(bvs.len(), 1);
            let prod = kernels
                .matmul(&avs[0], &bvs[0])
                .expect("block matmul kernel failed");
            ((i, j), prod)
        });

        // Sum the k partial products per output block.
        let summed = cluster.reduce_by_key(method::MULTIPLY, products, nparts, |acc, m| {
            acc.add(&m).expect("partial product shapes agree")
        });

        let blocks = cluster.map(method::MULTIPLY, summed, |((i, j), m)| Block::new(i, j, m));
        let items = cluster.collect(blocks);
        if items.len() != b * b {
            return Err(SpinError::cluster(format!(
                "multiply produced {} blocks, expected {}",
                items.len(),
                b * b
            )));
        }
        let n = items.len();
        Ok(BlockMatrix::from_rdd(Rdd::from_items(items, n), b, bs))
    }

    /// Paper §3.3 `subtract`: align blocks by index, C = A − B. Narrow
    /// (zero shuffle bytes) on co-partitioned operands — which every
    /// `BlockMatrix` of the same grid is.
    pub fn subtract(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        other: &BlockMatrix,
    ) -> Result<BlockMatrix> {
        self.check_same_grid(other, "subtract")?;
        self.binary_elementwise(cluster, kernels, other, method::SUBTRACT)
    }

    //
    // expect is invariant-backed: both operands are co-partitioned on the
    // same grid (checked by the callers' shape guards), so every slot has
    // exactly one block from each side and the kernel cannot reject them.
    #[allow(clippy::expect_used)]
    fn binary_elementwise(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        other: &BlockMatrix,
        name: &str,
    ) -> Result<BlockMatrix> {
        let b = self.nblocks();
        let bs = self.block_size();
        if cluster.config().partitioner_aware {
            // Narrow co-partitioned join: each grid partition holds the
            // same block index on both sides.
            let left = self.aligned_rdd(cluster, name);
            let right = other.aligned_rdd(cluster, name);
            let out = cluster.zip_partitions(name, left, right, |ls: Vec<Block>, rs: Vec<Block>| {
                let mut rmap: HashMap<(usize, usize), Matrix> =
                    rs.into_iter().map(|blk| (blk.idx(), blk.matrix)).collect();
                ls.into_iter()
                    .map(|blk| {
                        let r = rmap
                            .remove(&blk.idx())
                            .expect("co-partitioned operand missing block");
                        let m = kernels
                            .subtract(&blk.matrix, &r)
                            .expect("subtract kernel failed");
                        Block::new(blk.row, blk.col, m)
                    })
                    .collect()
            });
            Ok(BlockMatrix::from_rdd(
                out.with_partitioner(Partitioner::Grid { nblocks: b }),
                b,
                bs,
            ))
        } else {
            // Legacy wide path: cogroup both sides, then re-parallelize
            // through the driver.
            let nparts = b * b;
            let left = cluster.map(name, self.rdd_clone(), |blk: Block| (blk.idx(), blk.matrix));
            let right =
                cluster.map(name, other.rdd_clone(), |blk: Block| (blk.idx(), blk.matrix));
            let paired = cluster.cogroup(name, left, right, nparts);
            let out = cluster.map(name, paired, |((i, j), (ls, rs))| {
                debug_assert_eq!(ls.len(), 1);
                debug_assert_eq!(rs.len(), 1);
                let m = kernels
                    .subtract(&ls[0], &rs[0])
                    .expect("subtract kernel failed");
                Block::new(i, j, m)
            });
            let items = cluster.collect(out);
            let n = items.len();
            Ok(BlockMatrix::from_rdd(Rdd::from_items(items, n), b, bs))
        }
    }

    /// Paper §3.3 / Algorithm 5 `scalarMul`: one map over blocks.
    pub fn scalar_mul(
        &self,
        cluster: &Cluster,
        kernels: &dyn BlockKernels,
        s: f64,
    ) -> Result<BlockMatrix> {
        self.map_blocks_try(cluster, method::SCALAR_MUL, |m| kernels.scale(m, s))
    }

    /// Algorithm 6 `arrange`: re-index the four quadrants into the full
    /// grid (three shifting maps — C11 keeps its indices) and interleave.
    /// Narrow: the shifted quadrants' one-block partitions slot 1-to-1
    /// into the full grid's partitions, so no element moves executors and
    /// the result carries the grid partitioner for the next level.
    //
    // expect is invariant-backed: the quadrant math covers every output
    // grid slot exactly once.
    #[allow(clippy::expect_used)]
    pub fn arrange(
        cluster: &Cluster,
        c11: BlockMatrix,
        c12: BlockMatrix,
        c21: BlockMatrix,
        c22: BlockMatrix,
    ) -> Result<BlockMatrix> {
        c11.check_same_grid(&c12, "arrange")?;
        c11.check_same_grid(&c21, "arrange")?;
        c11.check_same_grid(&c22, "arrange")?;
        let half = c11.nblocks();
        let bs = c11.block_size();
        let b = 2 * half;

        let shift = |src: Rdd<Block>, dr: usize, dc: usize| {
            cluster.map(method::ARRANGE, src, move |mut blk: Block| {
                blk.row += dr;
                blk.col += dc;
                blk
            })
        };

        if cluster.config().partitioner_aware {
            let r11 = c11.aligned_rdd(cluster, method::ARRANGE);
            let r12 = shift(c12.aligned_rdd(cluster, method::ARRANGE), 0, half);
            let r21 = shift(c21.aligned_rdd(cluster, method::ARRANGE), half, 0);
            let r22 = shift(c22.aligned_rdd(cluster, method::ARRANGE), half, half);

            let mut slots: Vec<Option<Vec<Block>>> = (0..b * b).map(|_| None).collect();
            let mut place = |rdd: Rdd<Block>, roff: usize, coff: usize| {
                for (p, part) in rdd.into_partitions().into_iter().enumerate() {
                    let (i, j) = (p / half + roff, p % half + coff);
                    slots[i * b + j] = Some(part);
                }
            };
            place(r11, 0, 0);
            place(r12, 0, half);
            place(r21, half, 0);
            place(r22, half, half);
            let parts: Vec<Vec<Block>> = slots
                .into_iter()
                .map(|s| s.expect("arrange covered every grid slot"))
                .collect();
            let rdd = Rdd::from_partitions_with(parts, Partitioner::Grid { nblocks: b });
            Ok(BlockMatrix::from_rdd(rdd, b, bs))
        } else {
            let r12 = shift(c12.rdd_clone(), 0, half);
            let r21 = shift(c21.rdd_clone(), half, 0);
            let r22 = shift(c22.rdd_clone(), half, half);
            let unioned = c11.rdd_clone().union(r12).union(r21).union(r22);
            let items = cluster.collect(unioned);
            let n = items.len();
            Ok(BlockMatrix::from_rdd(Rdd::from_items(items, n), b, bs))
        }
    }

    /// Distributed transpose: one map (swap indices + transpose payloads)
    /// plus a narrow partition permutation back onto the grid layout.
    pub fn transpose(&self, cluster: &Cluster) -> BlockMatrix {
        let nb = self.nblocks();
        let bs = self.block_size();
        let mapped = |src: Rdd<Block>| {
            cluster.map(method::TRANSPOSE, src, |blk: Block| {
                Block::new(blk.col, blk.row, blk.matrix.transpose())
            })
        };
        if cluster.config().partitioner_aware {
            let out = mapped(self.aligned_rdd(cluster, method::TRANSPOSE));
            // Source partition j*nb+i now holds block (i, j); permute it
            // into grid slot i*nb+j.
            let sources: Vec<usize> = (0..nb)
                .flat_map(|i| (0..nb).map(move |j| j * nb + i))
                .collect();
            let rdd = out
                .select_partitions(&sources)
                .with_partitioner(Partitioner::Grid { nblocks: nb });
            BlockMatrix::from_rdd(rdd, nb, bs)
        } else {
            let out = mapped(self.rdd_clone());
            let items = cluster.collect(out);
            let n = items.len();
            BlockMatrix::from_rdd(Rdd::from_items(items, n), nb, bs)
        }
    }
}

/// Reduce side of the partitioner-aware multiply, run inside one narrow
/// task per grid partition: hash-join the A/B replicas on `(i, j, k)`,
/// GEMM each pair, accumulate the k-sum in place (`matmul_acc` takes the
/// accumulator by value — no per-term allocation), and optionally apply
/// the fused Schur subtraction.
//
// expect is invariant-backed: the routed exchange delivers a B replica for
// every (i, j, k) key it routed an A replica for, each output block has at
// least one k-term, and the kernels accept conforming blocks.
#[allow(clippy::expect_used)]
fn join_products(
    kernels: &dyn BlockKernels,
    avs: Vec<RepEntry>,
    bvs: Vec<RepEntry>,
    minus: Option<Vec<Block>>,
) -> Vec<Block> {
    let mut bmap: HashMap<(usize, usize, usize), Arc<Matrix>> = bvs.into_iter().collect();
    let mut by_out: BTreeMap<(usize, usize), Vec<(usize, Arc<Matrix>)>> = BTreeMap::new();
    for ((i, j, k), m) in avs {
        by_out.entry((i, j)).or_default().push((k, m));
    }
    let mut dmap: HashMap<(usize, usize), Matrix> = minus
        .map(|blocks| blocks.into_iter().map(|blk| (blk.idx(), blk.matrix)).collect())
        .unwrap_or_default();
    let mut out = Vec::with_capacity(by_out.len());
    for ((i, j), mut terms) in by_out {
        // Deterministic summation order over k.
        terms.sort_unstable_by_key(|&(k, _)| k);
        let mut acc: Option<Matrix> = None;
        for (k, am) in terms {
            let bm = bmap
                .remove(&(i, j, k))
                .expect("B replica missing for (i, j, k)");
            acc = Some(match acc {
                None => kernels.matmul(&am, &bm).expect("block matmul kernel failed"),
                Some(sum) => kernels
                    .matmul_acc(&am, &bm, sum)
                    .expect("block matmul kernel failed"),
            });
        }
        let mut m = acc.expect("each output block has at least one k-term");
        if let Some(d) = dmap.remove(&(i, j)) {
            m = kernels
                .subtract(&m, &d)
                .expect("fused subtract kernel failed");
        }
        out.push(Block::new(i, j, m));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::linalg::{self, matmul, Matrix};
    use crate::runtime::NativeBackend;
    use crate::util::check::forall;
    use crate::util::Rng;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig::local(4))
    }

    /// Multi-executor topology so cross-executor shuffle bytes are nonzero.
    fn multi_exec_cluster() -> Cluster {
        let mut cfg = ClusterConfig::local(4);
        cfg.executors_per_node = 4;
        Cluster::new(cfg)
    }

    fn legacy_cluster() -> Cluster {
        let mut cfg = ClusterConfig::local(4);
        cfg.partitioner_aware = false;
        Cluster::new(cfg)
    }

    fn rand_bm(n: usize, bs: usize, seed: u64) -> (Matrix, BlockMatrix) {
        let mut rng = Rng::new(seed);
        let dense = Matrix::random_uniform(n, n, -1.0, 1.0, &mut rng);
        let bm = BlockMatrix::from_dense(&dense, bs).unwrap();
        (dense, bm)
    }

    #[test]
    fn break_then_quadrants_match_dense() {
        let c = cluster();
        let (dense, bm) = rand_bm(8, 2, 1);
        let (a11, a12, a21, a22) = bm.split(&c).unwrap();
        assert_eq!(a11.nblocks(), 2);
        assert!(a11.to_dense().unwrap().max_abs_diff(&dense.submatrix(0, 0, 4, 4).unwrap()) < 1e-15);
        assert!(a12.to_dense().unwrap().max_abs_diff(&dense.submatrix(0, 4, 4, 4).unwrap()) < 1e-15);
        assert!(a21.to_dense().unwrap().max_abs_diff(&dense.submatrix(4, 0, 4, 4).unwrap()) < 1e-15);
        assert!(a22.to_dense().unwrap().max_abs_diff(&dense.submatrix(4, 4, 4, 4).unwrap()) < 1e-15);
    }

    #[test]
    fn split_arrange_round_trip() {
        let c = cluster();
        let (dense, bm) = rand_bm(8, 2, 2);
        let (a11, a12, a21, a22) = bm.split(&c).unwrap();
        let back = BlockMatrix::arrange(&c, a11, a12, a21, a22).unwrap();
        assert!(back.to_dense().unwrap().max_abs_diff(&dense) < 1e-15);
    }

    #[test]
    fn split_and_arrange_are_narrow() {
        let c = multi_exec_cluster();
        let (dense, bm) = rand_bm(8, 2, 3);
        let (a11, a12, a21, a22) = bm.split(&c).unwrap();
        let back = BlockMatrix::arrange(&c, a11, a12, a21, a22).unwrap();
        assert!(back.to_dense().unwrap().max_abs_diff(&dense) < 1e-15);
        assert_eq!(back.rdd().partitioner(), Some(Partitioner::Grid { nblocks: 4 }));
        let snap = c.metrics();
        assert_eq!(snap.driver_collects(), 0);
        for m in [method::BREAK_MAT, method::XY, method::ARRANGE] {
            let s = snap.method(m).unwrap();
            assert_eq!(s.shuffle_bytes, 0, "{m} shuffled");
            assert_eq!(s.shuffle_stages, 0, "{m} paid an exchange");
        }
    }

    #[test]
    fn break_mat_rejects_odd_grids() {
        let bm = BlockMatrix::identity(6, 2).unwrap(); // 3x3 grid
        assert!(bm.break_mat(&cluster()).is_err());
    }

    #[test]
    fn multiply_matches_serial() {
        let c = cluster();
        for (n, bs) in [(4usize, 2usize), (8, 2), (8, 4), (16, 4)] {
            let (da, a) = rand_bm(n, bs, 10 + n as u64);
            let (db, b) = rand_bm(n, bs, 20 + n as u64);
            let got = a.multiply(&c, &NativeBackend, &b).unwrap();
            let want = matmul(&da, &db);
            let diff = got.to_dense().unwrap().max_abs_diff(&want);
            assert!(diff < 1e-11, "n={n} bs={bs} diff={diff}");
        }
    }

    #[test]
    fn multiply_single_block_grid() {
        let c = cluster();
        let (da, a) = rand_bm(4, 4, 30);
        let (db, b) = rand_bm(4, 4, 31);
        let got = a.multiply(&c, &NativeBackend, &b).unwrap();
        assert!(got.to_dense().unwrap().max_abs_diff(&matmul(&da, &db)) < 1e-12);
    }

    #[test]
    fn multiply_grid_mismatch_errors() {
        let c = cluster();
        let a = BlockMatrix::identity(8, 2).unwrap();
        let b = BlockMatrix::identity(8, 4).unwrap();
        assert!(a.multiply(&c, &NativeBackend, &b).is_err());
        assert!(a.multiply_sub(&c, &NativeBackend, &a, &b).is_err());
    }

    #[test]
    fn subtract_matches_dense() {
        let c = cluster();
        let (da, a) = rand_bm(8, 4, 40);
        let (db, b) = rand_bm(8, 4, 41);
        let got = a.subtract(&c, &NativeBackend, &b).unwrap();
        assert!(got.to_dense().unwrap().max_abs_diff(&da.sub(&db).unwrap()) < 1e-15);
    }

    #[test]
    fn narrow_subtract_records_zero_shuffle() {
        let c = multi_exec_cluster();
        let (da, a) = rand_bm(8, 2, 42);
        let (db, b) = rand_bm(8, 2, 43);
        let got = a.subtract(&c, &NativeBackend, &b).unwrap();
        assert!(got.to_dense().unwrap().max_abs_diff(&da.sub(&db).unwrap()) < 1e-15);
        let s = c.metrics();
        assert_eq!(s.method("subtract").unwrap().shuffle_bytes, 0);
        assert_eq!(s.method("subtract").unwrap().shuffle_stages, 0);
        assert_eq!(s.driver_collects(), 0);
    }

    #[test]
    fn unaligned_operand_pays_one_alignment_exchange() {
        let c = multi_exec_cluster();
        let (da, a) = rand_bm(8, 2, 44);
        let (db, b) = rand_bm(8, 2, 45);
        // Strip the partitioner and scramble placement: same blocks, but
        // the substrate can no longer prove co-partitioning.
        let mut blocks = b.rdd_clone().into_items();
        blocks.reverse();
        let n = blocks.len();
        let scrambled = BlockMatrix::from_rdd(Rdd::from_items(blocks, n), b.nblocks(), b.block_size());
        let got = a.subtract(&c, &NativeBackend, &scrambled).unwrap();
        assert!(got.to_dense().unwrap().max_abs_diff(&da.sub(&db).unwrap()) < 1e-15);
        let s = c.metrics().method("subtract").unwrap().clone();
        assert_eq!(s.shuffle_stages, 1, "one side needed re-gridding");
        assert!(s.shuffle_bytes > 0);
    }

    #[test]
    fn copartitioned_multiply_shuffles_less_than_replicated() {
        let c_new = multi_exec_cluster();
        let c_old = multi_exec_cluster();
        let (da, a) = rand_bm(16, 4, 46);
        let (db, b) = rand_bm(16, 4, 47);
        let want = matmul(&da, &db);
        let got_new = a.multiply(&c_new, &NativeBackend, &b).unwrap();
        let got_old = a.multiply_replicated(&c_old, &NativeBackend, &b).unwrap();
        assert!(got_new.to_dense().unwrap().max_abs_diff(&want) < 1e-11);
        assert!(got_old.to_dense().unwrap().max_abs_diff(&want) < 1e-11);
        let new = c_new.metrics();
        let old = c_old.metrics();
        let new_bytes = new.method("multiply").unwrap().shuffle_bytes;
        let old_bytes = old.method("multiply").unwrap().shuffle_bytes;
        assert!(new_bytes > 0, "pairing shuffle still moves data");
        assert!(
            new_bytes < old_bytes,
            "co-partitioned multiply must shuffle strictly less: {new_bytes} vs {old_bytes}"
        );
        assert_eq!(new.driver_collects(), 0);
        assert!(old.driver_collects() > 0);
        // The output is grid-partitioned for the next op.
        assert_eq!(
            got_new.rdd().partitioner(),
            Some(Partitioner::Grid { nblocks: 4 })
        );
    }

    #[test]
    fn fused_multiply_sub_saves_a_stage_and_matches_composed() {
        let c_fused = cluster();
        let c_composed = cluster();
        let (da, a) = rand_bm(8, 2, 48);
        let (db, b) = rand_bm(8, 2, 49);
        let (dd, d) = rand_bm(8, 2, 50);
        let want = matmul(&da, &db).sub(&dd).unwrap();
        let fused = a.multiply_sub(&c_fused, &NativeBackend, &b, &d).unwrap();
        let composed = a
            .multiply(&c_composed, &NativeBackend, &b)
            .unwrap()
            .subtract(&c_composed, &NativeBackend, &d)
            .unwrap();
        assert!(fused.to_dense().unwrap().max_abs_diff(&want) < 1e-11);
        assert!(composed.to_dense().unwrap().max_abs_diff(&want) < 1e-11);
        let sf = c_fused.metrics();
        let sc = c_composed.metrics();
        // The subtraction ran inside multiply's reduce: no subtract stage
        // at all, and at least one fewer stage end to end.
        assert!(sf.method("subtract").is_none());
        assert!(
            sf.stages().len() < sc.stages().len(),
            "fused: {} stages, composed: {}",
            sf.stages().len(),
            sc.stages().len()
        );
        assert!(sf.total_shuffle_stages() <= sc.total_shuffle_stages());
        assert!(sf.total_shuffle_bytes() <= sc.total_shuffle_bytes());
    }

    #[test]
    fn legacy_mode_still_correct() {
        // partitioner_aware = false exercises the original wide pipeline.
        let c = legacy_cluster();
        let (da, a) = rand_bm(8, 2, 51);
        let (db, b) = rand_bm(8, 2, 52);
        let prod = a.multiply(&c, &NativeBackend, &b).unwrap();
        assert!(prod.to_dense().unwrap().max_abs_diff(&matmul(&da, &db)) < 1e-11);
        let sub = a.subtract(&c, &NativeBackend, &b).unwrap();
        assert!(sub.to_dense().unwrap().max_abs_diff(&da.sub(&db).unwrap()) < 1e-15);
        let (a11, a12, a21, a22) = a.split(&c).unwrap();
        let back = BlockMatrix::arrange(&c, a11, a12, a21, a22).unwrap();
        assert!(back.to_dense().unwrap().max_abs_diff(&da) < 1e-15);
        let t = a.transpose(&c);
        assert!(t.to_dense().unwrap().max_abs_diff(&da.transpose()) < 1e-15);
        assert!(c.metrics().driver_collects() > 0, "legacy path round-trips");
    }

    #[test]
    fn scalar_mul_matches_dense() {
        let c = cluster();
        let (d, a) = rand_bm(8, 2, 53);
        let got = a.scalar_mul(&c, &NativeBackend, -2.5).unwrap();
        assert!(got.to_dense().unwrap().max_abs_diff(&d.scale(-2.5)) < 1e-15);
    }

    #[test]
    fn transpose_matches_dense_and_stays_narrow() {
        let c = multi_exec_cluster();
        let (d, a) = rand_bm(8, 4, 54);
        let got = a.transpose(&c);
        assert!(got.to_dense().unwrap().max_abs_diff(&d.transpose()) < 1e-15);
        assert_eq!(got.rdd().partitioner(), Some(Partitioner::Grid { nblocks: 2 }));
        let s = c.metrics().method("transpose").unwrap().clone();
        assert_eq!(s.shuffle_bytes, 0);
        assert_eq!(s.shuffle_stages, 0);
    }

    #[test]
    fn multiply_by_identity_is_noop() {
        let c = cluster();
        let (d, a) = rand_bm(8, 2, 55);
        let eye = BlockMatrix::identity(8, 2).unwrap();
        let got = a.multiply(&c, &NativeBackend, &eye).unwrap();
        assert!(got.to_dense().unwrap().max_abs_diff(&d) < 1e-14);
    }

    #[test]
    fn metrics_use_paper_method_names() {
        let c = cluster();
        let (_, a) = rand_bm(8, 2, 80);
        let (_, b) = rand_bm(8, 2, 81);
        let _ = a.multiply(&c, &NativeBackend, &b).unwrap();
        let _ = a.split(&c).unwrap();
        let _ = a.scalar_mul(&c, &NativeBackend, 2.0).unwrap();
        let snap = c.metrics();
        for name in ["multiply", "breakMat", "xy", "scalar"] {
            assert!(snap.method(name).is_some(), "missing metric {name}");
        }
    }

    #[test]
    fn property_distributed_ops_match_dense() {
        forall(
            "blockmatrix ≡ dense algebra",
            0xB0,
            8,
            |r| {
                let pow = 2 + r.next_usize(2); // n = 4 or 8
                let n = 1usize << pow;
                let bs = 1usize << (1 + r.next_usize(pow - 1));
                (n, bs, r.next_u64())
            },
            |&(n, bs, seed)| {
                let c = cluster();
                let mut rng = Rng::new(seed);
                let da = Matrix::random_uniform(n, n, -1.0, 1.0, &mut rng);
                let db = Matrix::random_uniform(n, n, -1.0, 1.0, &mut rng);
                let dd = Matrix::random_uniform(n, n, -1.0, 1.0, &mut rng);
                let a = BlockMatrix::from_dense(&da, bs).unwrap();
                let b = BlockMatrix::from_dense(&db, bs).unwrap();
                let d = BlockMatrix::from_dense(&dd, bs).unwrap();
                let prod = a
                    .multiply(&c, &NativeBackend, &b)
                    .map_err(|e| e.to_string())?
                    .to_dense()
                    .unwrap();
                let want = linalg::matmul(&da, &db);
                let diff = prod.max_abs_diff(&want);
                if diff > 1e-10 {
                    return Err(format!("multiply diff {diff} (n={n} bs={bs})"));
                }
                let sub = a
                    .subtract(&c, &NativeBackend, &b)
                    .map_err(|e| e.to_string())?
                    .to_dense()
                    .unwrap();
                if sub.max_abs_diff(&da.sub(&db).unwrap()) > 1e-14 {
                    return Err("subtract mismatch".into());
                }
                let fused = a
                    .multiply_sub(&c, &NativeBackend, &b, &d)
                    .map_err(|e| e.to_string())?
                    .to_dense()
                    .unwrap();
                if fused.max_abs_diff(&want.sub(&dd).unwrap()) > 1e-10 {
                    return Err("multiply_sub mismatch".into());
                }
                Ok(())
            },
        );
    }
}
