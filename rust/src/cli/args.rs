//! Tiny argv parser: positionals, `--flag`, `--flag value`, repeatable
//! flags, and strict "no unknown flags" finishing.

use crate::error::{Result, SpinError};

/// Mutable view over the remaining argv tokens.
pub struct Args {
    tokens: Vec<Option<String>>,
}

impl Args {
    pub fn new(argv: Vec<String>) -> Self {
        Args {
            tokens: argv.into_iter().map(Some).collect(),
        }
    }

    /// Consume the next unconsumed positional (non-`--`) token.
    pub fn positional(&mut self) -> Option<String> {
        for slot in self.tokens.iter_mut() {
            if let Some(tok) = slot {
                if !tok.starts_with("--") {
                    return slot.take();
                }
            }
        }
        None
    }

    /// Consume a boolean flag; true if present.
    pub fn flag(&mut self, name: &str) -> bool {
        for slot in self.tokens.iter_mut() {
            if slot.as_deref() == Some(name) {
                *slot = None;
                return true;
            }
        }
        false
    }

    /// Consume `--name value` (or `--name=value`); errors if the value is
    /// missing.
    pub fn flag_value(&mut self, name: &str) -> Result<Option<String>> {
        let eq_prefix = format!("{name}=");
        for i in 0..self.tokens.len() {
            let Some(tok) = self.tokens[i].as_deref() else {
                continue;
            };
            if let Some(v) = tok.strip_prefix(&eq_prefix) {
                let v = v.to_string();
                self.tokens[i] = None;
                return Ok(Some(v));
            }
            if tok == name {
                self.tokens[i] = None;
                let val = self
                    .tokens
                    .get_mut(i + 1)
                    .and_then(Option::take)
                    .ok_or_else(|| SpinError::config(format!("flag {name} needs a value")))?;
                if val.starts_with("--") {
                    return Err(SpinError::config(format!("flag {name} needs a value")));
                }
                return Ok(Some(val));
            }
        }
        Ok(None)
    }

    /// Consume every occurrence of `--name value`.
    pub fn flag_values(&mut self, name: &str) -> Result<Vec<String>> {
        let mut out = Vec::new();
        while let Some(v) = self.flag_value(name)? {
            out.push(v);
        }
        Ok(out)
    }

    /// Re-insert a `--name value` pair. Used to route a `--set` key owned
    /// by another config domain (e.g. `--set tolerance=…` is per-job, not
    /// cluster topology) to the flag that domain actually reads.
    pub fn push(&mut self, name: &str, value: &str) {
        self.tokens.push(Some(name.to_string()));
        self.tokens.push(Some(value.to_string()));
    }

    /// Error if any tokens were not consumed (catches typos).
    pub fn finish(self) -> Result<()> {
        let leftovers: Vec<String> = self.tokens.into_iter().flatten().collect();
        if leftovers.is_empty() {
            Ok(())
        } else {
            Err(SpinError::config(format!(
                "unrecognized arguments: {}",
                leftovers.join(" ")
            )))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::new(s.split_whitespace().map(String::from).collect())
    }

    #[test]
    fn positional_and_flags() {
        let mut a = args("invert --n 64 --residual-check --set x=1 --set y=2");
        assert_eq!(a.positional().as_deref(), Some("invert"));
        assert_eq!(a.flag_value("--n").unwrap().as_deref(), Some("64"));
        assert!(a.flag("--residual-check"));
        assert_eq!(a.flag_values("--set").unwrap(), vec!["x=1", "y=2"]);
        a.finish().unwrap();
    }

    #[test]
    fn equals_syntax() {
        let mut a = args("--n=128");
        assert_eq!(a.flag_value("--n").unwrap().as_deref(), Some("128"));
        a.finish().unwrap();
    }

    #[test]
    fn missing_value_errors() {
        let mut a = args("--n");
        assert!(a.flag_value("--n").is_err());
        let mut b = args("--n --other");
        assert!(b.flag_value("--n").is_err());
    }

    #[test]
    fn leftover_tokens_error() {
        let a = args("--typo-flag");
        assert!(a.finish().is_err());
    }

    #[test]
    fn absent_flag_is_none_or_false() {
        let mut a = args("cmd");
        assert_eq!(a.flag_value("--missing").unwrap(), None);
        assert!(!a.flag("--missing"));
    }
}
