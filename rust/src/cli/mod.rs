//! Command-line launcher (no `clap` in the offline vendor set — a small
//! hand-rolled parser).
//!
//! ```text
//! spin invert  --n 1024 --block-size 128 [--algo spin|lu] [--backend native|xla]
//!              [--generator diag-dominant|spd] [--seed N] [--fuse-leaf-2x2]
//!              [--residual-check] [--set cluster.key=value]...
//! spin ingest  --n 512 --block-size 64 --out DIR [--generator …] [--seed N]
//! spin gen     --n 512 --block-size 64 --out DIR [--generator …] [--seed N]
//! spin cost    [--n 4096] [--b 8] [--cores 30] [--calibrate]
//! spin exp     figure2|figure3|figure4|figure5|table3|all [--smoke|--full]
//! spin bench   [--smoke] [--out BENCH_spin.json] [--seed N] [--schema-baseline FILE]
//! spin explain [--n 256 --block-size 32] [--algo spin] [--set plan_optimizer=false]
//!              [--verify]
//! spin lint    [--algo NAME] [--n N --block-size S] [--spec JOBS.json]
//! spin serve   --script JOBS.json | --store DIR [--workers N]
//!              [--set cache_budget_bytes=N] [--set metrics_history=N]
//! spin serve   --http ADDR [--store DIR] [--workers N]
//!              [--http-set listen|max_body_bytes|sse_heartbeat_ms=V]
//! spin info
//! ```

mod args;

pub use args::Args;

use std::path::PathBuf;

use crate::config::{ClusterConfig, GeneratorKind, HttpConfig, JobConfig};
use crate::costmodel::{self, CostConstants};
use crate::error::{Result, SpinError};
use crate::experiments::{self, Scale};
use crate::http::{HttpServer, RecoveredJob, ServerState};
use crate::runtime::Manifest;
use crate::ser::json::Json;
use crate::service::{JobSpec, MatrixSpec, SpinService};
use crate::session::SpinSession;
use crate::store::{self, JobLog, LocalDirStore};
use crate::util::fmt;

/// Entry point for the `spin` binary; returns the process exit code.
pub fn run(argv: Vec<String>) -> i32 {
    crate::util::logger::init();
    match dispatch(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let mut args = Args::new(argv);
    let cmd = args.positional().unwrap_or_else(|| "help".to_string());
    match cmd.as_str() {
        "invert" => cmd_invert(args),
        "ingest" | "gen" => cmd_ingest(args),
        "cost" => cmd_cost(args),
        "exp" => cmd_exp(args),
        "bench" => cmd_bench(args),
        "explain" => cmd_explain(args),
        "lint" => cmd_lint(args),
        "serve" => cmd_serve(args),
        "info" => cmd_info(args),
        "help" | "--help" | "-h" => {
            print!("{}", usage());
            Ok(())
        }
        other => Err(SpinError::config(format!(
            "unknown command `{other}`\n{}",
            usage()
        ))),
    }
}

pub fn usage() -> String {
    "SPIN — Strassen-based distributed matrix inversion (ICDCN '18 reproduction)\n\
     \n\
     USAGE: spin <command> [flags]\n\
     \n\
     COMMANDS:\n\
     \x20 invert   invert a generated matrix on the simulated cluster\n\
     \x20 ingest   generate a matrix block-by-block into a block store (O(block) memory;\n\
     \x20          serve it lazily with `spin serve --store DIR`; `gen` is an alias)\n\
     \x20 cost     print the Table-1 cost model (optionally calibrated)\n\
     \x20 exp      run a paper experiment: figure2|figure3|figure4|figure5|table3|all\n\
     \x20 bench    invert the tracked size sweep, write BENCH_spin.json (perf trajectory)\n\
     \x20 explain  print an algorithm's optimized recursion-level plan (fusion, CSE caches,\n\
     \x20          predicted shuffle stages per node, cache decisions + resident bytes);\n\
     \x20          --verify appends the static plan verifier's verdict (exit 1 on violation)\n\
     \x20 lint     statically prove the standing contracts on every optimized plan without\n\
     \x20          running anything: geometry/partitioner propagation, rewrite + lifecycle\n\
     \x20          soundness, and exact exchange-stage/shuffle-byte accounting cross-checked\n\
     \x20          against the closed-form cost model (see docs/ANALYSIS.md); default corpus\n\
     \x20          is every registered algorithm at n∈{64,128,256}, b∈{2,4,8}; --spec FILE\n\
     \x20          lints a JobSpec script instead; exit 1 if any proof fails\n\
     \x20 serve    replay a JobSpec script ({\"jobs\": [...]}) through the multi-tenant\n\
     \x20          SpinService and print per-job reports (--script FILE, --workers N),\n\
     \x20          or expose the service over HTTP: --http ADDR [--store DIR] runs the\n\
     \x20          job API (POST /v1/jobs, SSE /v1/jobs/:id/events, /v1/metrics) with a\n\
     \x20          durable job log in DIR replayed on restart (pending jobs resume from\n\
     \x20          their last checkpointed level); ctrl-c drains gracefully, hard-failing\n\
     \x20          whatever is left after --drain-timeout-secs N (default 30)\n\
     \x20 info     show cluster config and artifact status\n\
     \n\
     COMMON FLAGS:\n\
     \x20 --n N --block-size S --algo NAME (any registered algorithm;\n\
     \x20 built-in: spin|lu|newton|cholesky)\n\
     \x20 --set tolerance=T --set max_iters=K (iterative schemes: stop once\n\
     \x20 the residual ≤ T or after K passes; see docs/ALGORITHMS.md)\n\
     \x20 --backend native|xla\n\
     \x20 --generator diag-dominant|spd --seed N --fuse-leaf-2x2\n\
     \x20 --residual-check --set key=value (cluster overrides, repeatable)\n\
     \x20 --set fault_seed=N/fault_rate=F/checkpoint_every_level=N… — deterministic\n\
     \x20 chaos, stage retry, speculation, checkpoints (see docs/RESILIENCE.md)\n\
     \x20 --smoke | --full (experiment scale)\n"
        .to_string()
}

fn cluster_config(args: &mut Args) -> Result<ClusterConfig> {
    let mut cfg = match args.flag_value("--cluster-config")? {
        Some(path) => ClusterConfig::from_file(std::path::Path::new(&path))?,
        None => ClusterConfig::paper(),
    };
    if let Some(backend) = args.flag_value("--backend")? {
        cfg.apply_override(&format!("backend={backend}"))?;
    }
    for kv in args.flag_values("--set")? {
        // Iterative-scheme knobs are per-job parameters, not cluster
        // topology: `--set tolerance=1e-8` / `--set max_iters=20` route to
        // the job override path (commands without a job config reject them
        // as unrecognized).
        if matches!(kv.split_once('='), Some(("tolerance" | "max_iters", _))) {
            args.push("--job", &kv);
        } else {
            cfg.apply_override(&kv)?;
        }
    }
    Ok(cfg)
}

/// Valid `--block-size` values for a power-of-two `n`: every power of two
/// up to `n` (these are exactly the sizes giving a power-of-two grid).
fn valid_block_sizes(n: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut bs = 1usize;
    while bs <= n {
        out.push(bs);
        bs *= 2;
    }
    out
}

/// Up-front geometry validation with actionable messages. The old flow let
/// a bad default (`n/4` for non-power-of-two `n`) reach the job validator,
/// whose error never named a usable value.
fn validate_geometry(n: usize, block_size: usize) -> Result<()> {
    if n == 0 {
        return Err(SpinError::config("--n must be positive"));
    }
    if !n.is_power_of_two() {
        let hi = n.next_power_of_two();
        let lo = (hi / 2).max(1);
        return Err(SpinError::config(format!(
            "--n {n} is not a power of two (the SPIN recursion needs n = 2^k, \
             paper §4); nearest valid sizes: {lo} or {hi}"
        )));
    }
    if block_size == 0
        || block_size > n
        || n % block_size != 0
        || !block_size.is_power_of_two()
        || !(n / block_size).is_power_of_two()
    {
        let valid: Vec<String> = valid_block_sizes(n).iter().map(|b| b.to_string()).collect();
        return Err(SpinError::config(format!(
            "--block-size {block_size} does not give a power-of-two block grid \
             for n = {n}; valid block sizes: {}",
            valid.join(", ")
        )));
    }
    Ok(())
}

fn job_config(args: &mut Args) -> Result<JobConfig> {
    let n = args
        .flag_value("--n")?
        .map(|v| v.parse::<usize>().map_err(|_| SpinError::config("--n needs an integer")))
        .transpose()?
        .unwrap_or(256);
    let bs = args
        .flag_value("--block-size")?
        .map(|v| {
            v.parse::<usize>()
                .map_err(|_| SpinError::config("--block-size needs an integer"))
        })
        .transpose()?
        .unwrap_or_else(|| (n / 4).max(1));
    validate_geometry(n, bs)?;
    let mut job = JobConfig::new(n, bs);
    if let Some(s) = args.flag_value("--seed")? {
        job.seed = s
            .parse()
            .map_err(|_| SpinError::config("--seed needs an integer"))?;
    }
    if let Some(g) = args.flag_value("--generator")? {
        job.generator = GeneratorKind::parse(&g)?;
    }
    if args.flag("--fuse-leaf-2x2") {
        job.fuse_leaf_2x2 = true;
    }
    if args.flag("--residual-check") {
        job.residual_check = true;
    }
    for kv in args.flag_values("--job")? {
        job.apply_override(&kv)?;
    }
    // Overrides may change the geometry — re-check with the actionable
    // messages before the generic validator.
    validate_geometry(job.n, job.block_size)?;
    job.validate()?;
    Ok(job)
}

fn cmd_invert(mut args: Args) -> Result<()> {
    let cfg = cluster_config(&mut args)?;
    let job = job_config(&mut args)?;
    let algo = args
        .flag_value("--algo")?
        .unwrap_or_else(|| "spin".to_string());
    args.finish()?;

    // One session owns the cluster, backend, and job defaults; `--algo`
    // resolves through its algorithm registry.
    let session = SpinSession::builder()
        .cluster_config(cfg)
        .job_defaults(&job)
        .build()?;
    // Fail before the banner on an unknown name (the registry's error
    // already lists what is registered).
    let scheme = session.registry().get(&algo)?;
    // Iterative knobs on an exact algorithm would be silently ignored —
    // reject them like the service does.
    let dflt = JobConfig::new(job.n, job.block_size);
    if !scheme.iterative() && (job.tolerance != dflt.tolerance || job.max_iters != dflt.max_iters)
    {
        return Err(SpinError::config(format!(
            "`tolerance`/`max_iters` apply only to iterative algorithms, \
             but `{algo}` is exact"
        )));
    }

    println!(
        "inverting {}x{} (b = {}, block {}x{}) with {} on {} executors × {} cores [{} backend]",
        job.n,
        job.n,
        job.num_splits(),
        job.block_size,
        job.block_size,
        algo,
        session.config().total_executors(),
        session.config().cores_per_executor,
        session.backend_name(),
    );
    let a = session.random(job.n, job.block_size)?;
    let inv = a.inverse_with(&algo)?;
    let resid = a.inverse_residual(&inv)?;

    println!("\nper-method breakdown:\n{}", session.metrics().render_table());
    println!(
        "virtual wall clock: {}   residual: {resid:.3e}",
        fmt::secs(session.virtual_secs())
    );
    Ok(())
}

/// `spin ingest` (alias `gen`): generate a matrix **block by block**
/// into a block store. Per-block RNG streams mean the driver holds one
/// block at a time — ingest scales to matrices that never fit driver
/// memory, and the stored bits equal what the lazy serve path generates.
fn cmd_ingest(mut args: Args) -> Result<()> {
    let job = job_config(&mut args)?;
    let out = args
        .flag_value("--out")?
        .ok_or_else(|| SpinError::config("ingest requires --out DIR"))?;
    args.finish()?;
    let store = LocalDirStore::create(&out, job.num_splits(), job.block_size)?;
    let written = store::ingest_generated(&store, &job)?;
    println!(
        "wrote {}x{} block store ({} blocks of {}x{}) to {out}",
        job.n, job.n, written, job.block_size, job.block_size
    );
    println!("serve it lazily: spin serve --store {out}   (blocks load on the workers)");
    Ok(())
}

fn cmd_cost(mut args: Args) -> Result<()> {
    let cfg = cluster_config(&mut args)?;
    let n = args
        .flag_value("--n")?
        .map(|v| v.parse().unwrap_or(4096))
        .unwrap_or(4096);
    let b = args
        .flag_value("--b")?
        .map(|v| v.parse().unwrap_or(8))
        .unwrap_or(8);
    let cores = args
        .flag_value("--cores")?
        .map(|v| v.parse().unwrap_or(cfg.total_cores()))
        .unwrap_or_else(|| cfg.total_cores());
    let constants = if args.flag("--calibrate") {
        let rep = costmodel::calibrate(128, &cfg.network);
        println!(
            "calibrated on this host: leaf {:.2} GF/s, gemm {:.2} GF/s\n",
            rep.leaf_gflops, rep.gemm_gflops
        );
        rep.constants
    } else {
        CostConstants::default()
    };
    args.finish()?;
    print!("{}", costmodel::render_table1(n, b, cores, &constants));
    Ok(())
}

fn cmd_exp(mut args: Args) -> Result<()> {
    let which = args
        .positional()
        .ok_or_else(|| SpinError::config("exp requires a target: figure2|figure3|figure4|figure5|table3|all"))?;
    let cfg = cluster_config(&mut args)?;
    let scale = if args.flag("--smoke") {
        Scale::smoke()
    } else if args.flag("--full") {
        Scale::full()
    } else {
        Scale::default_scale()
    };
    let seed = args
        .flag_value("--seed")?
        .map(|v| v.parse().unwrap_or(42))
        .unwrap_or(42);
    args.finish()?;

    let run_one = |name: &str| -> Result<()> {
        match name {
            "figure2" => {
                let rows = experiments::figure2::run(&cfg, &scale, seed)?;
                print!("{}", experiments::figure2::render(&rows)?);
                match experiments::figure2::check_shape(&rows) {
                    Ok(()) => println!("shape check: OK (SPIN ≤ LU, gap grows with n)"),
                    Err(e) => println!("shape check: DEVIATION — {e}"),
                }
            }
            "figure3" => {
                let rows = experiments::figure3::run(&cfg, &scale, seed)?;
                print!("{}", experiments::figure3::render(&rows)?);
                match experiments::figure3::check_shape(&rows, true) {
                    Ok(()) => println!("shape check: OK (SPIN wins pointwise, U-shape present)"),
                    Err(e) => println!("shape check: DEVIATION — {e}"),
                }
            }
            "figure4" => {
                let (rows, _) = experiments::figure4::run(&cfg, &scale, seed)?;
                print!("{}", experiments::figure4::render(&rows)?);
                match experiments::figure4::check_shape(&rows) {
                    Ok(()) => println!("shape check: OK (model within 10x pointwise)"),
                    Err(e) => println!("shape check: DEVIATION — {e}"),
                }
            }
            "figure5" => {
                let rows = experiments::figure5::run(&cfg, &scale, seed)?;
                print!("{}", experiments::figure5::render(&rows)?);
                match experiments::figure5::check_shape(&rows) {
                    Ok(()) => println!("shape check: OK (monotone scaling)"),
                    Err(e) => println!("shape check: DEVIATION — {e}"),
                }
            }
            "table3" => {
                let n = scale.sizes[scale.sizes.len() / 2];
                let cols = experiments::table3::run(&cfg, n, scale.max_b, seed)?;
                print!("{}", experiments::table3::render(n, &cols)?);
                match experiments::table3::check_shape(&cols) {
                    Ok(()) => println!("shape check: OK (leaf falls, multiply rises)"),
                    Err(e) => println!("shape check: DEVIATION — {e}"),
                }
            }
            other => {
                return Err(SpinError::config(format!("unknown experiment `{other}`")));
            }
        }
        Ok(())
    };

    if which == "all" {
        for name in ["figure2", "figure3", "figure4", "figure5", "table3"] {
            println!("\n=== {name} ===");
            run_one(name)?;
        }
        Ok(())
    } else {
        run_one(&which)
    }
}

/// `spin bench`: invert the tracked size sweep (n ∈ {64, 128, 256} at the
/// paper's split counts b ∈ {2, 4, 8}) with every built-in algorithm and
/// write a JSON trajectory file — virtual seconds, shuffle bytes, and the
/// per-method Table-3 breakdown per run — so each PR's perf effect is
/// diffable. `--smoke` shrinks the sweep for CI.
fn cmd_bench(mut args: Args) -> Result<()> {
    let cfg = cluster_config(&mut args)?;
    let smoke = args.flag("--smoke");
    let out = args
        .flag_value("--out")?
        .unwrap_or_else(|| "BENCH_spin.json".to_string());
    let seed: u64 = args
        .flag_value("--seed")?
        .map(|v| v.parse().map_err(|_| SpinError::config("--seed needs an integer")))
        .transpose()?
        .unwrap_or(42);
    let schema_baseline = args.flag_value("--schema-baseline")?;
    args.finish()?;

    let sizes: &[usize] = if smoke { &[64] } else { &[64, 128, 256] };
    let splits: &[usize] = if smoke { &[2, 4] } else { &[2, 4, 8] };
    let mut runs = Vec::new();
    for &n in sizes {
        for &b in splits {
            if n / b < 2 {
                continue;
            }
            // Measured (not assumed): submit this geometry's job through
            // a throwaway service and count the blocks its plan holds
            // driver-side — 0 is the lazy-leaf invariant the baseline
            // gates; an eager-generation regression shows up here. The
            // count depends only on the source leaves, not the algorithm,
            // so one probe covers both algo rows.
            let submit_driver_blocks = {
                let probe = SpinService::builder()
                    .cluster_config(cfg.clone())
                    .workers(0)
                    .build()?;
                let spec = MatrixSpec {
                    n,
                    block_size: n / b,
                    // Only the plan's shape is probed; mask the seed into
                    // the spec-valid range (≤ 2^53).
                    seed: (seed ^ (n as u64) ^ b as u64) & ((1u64 << 53) - 1),
                    generator: GeneratorKind::DiagDominant,
                    store: None,
                };
                let handle = probe.submit(JobSpec::invert(spec))?;
                handle.submit_driver_blocks()
            };
            for algo in ["spin", "lu", "newton", "cholesky"] {
                let mut job = JobConfig::new(n, n / b);
                job.seed = seed ^ (n as u64) ^ b as u64;
                // Cholesky requires a symmetric positive-definite input;
                // the exact schemes and Newton run the default family.
                if algo == "cholesky" {
                    job.generator = GeneratorKind::Spd;
                }
                let r = experiments::run_inversion(&cfg, &job, algo)?;
                println!(
                    "bench {algo:<4} n={n:<4} b={b}: virtual {}  shuffled {}  \
                     exchanges {}  residual {:.2e}",
                    fmt::secs(r.virtual_secs),
                    fmt::bytes(r.metrics.total_shuffle_bytes()),
                    r.metrics.total_shuffle_stages(),
                    r.residual
                );
                runs.push(Json::object(vec![
                    ("algo", Json::str(algo)),
                    ("n", Json::num(n as f64)),
                    ("b", Json::num(b as f64)),
                    ("block_size", Json::num((n / b) as f64)),
                    ("virtual_secs", Json::num(r.virtual_secs)),
                    ("real_secs", Json::num(r.real_secs)),
                    // Measured wall clock (ms) — the armed timing dimension
                    // of the bench trajectory. Gated on presence + nonzero
                    // only (never on magnitude): see `check_bench_schema`.
                    ("wall_clock_ms", Json::num(r.real_secs * 1000.0)),
                    ("residual", Json::num(r.residual)),
                    (
                        "total_shuffle_bytes",
                        Json::num(r.metrics.total_shuffle_bytes() as f64),
                    ),
                    (
                        "shuffle_stages",
                        Json::num(r.metrics.total_shuffle_stages() as f64),
                    ),
                    (
                        "driver_collects",
                        Json::num(r.metrics.driver_collects() as f64),
                    ),
                    (
                        "submit_driver_blocks",
                        Json::num(submit_driver_blocks as f64),
                    ),
                    ("methods", r.metrics.to_json()),
                ]));
            }
        }
    }
    let doc = Json::object(vec![
        ("schema", Json::str("spin-bench-v1")),
        ("scale", Json::str(if smoke { "smoke" } else { "default" })),
        ("seed", Json::num(seed as f64)),
        ("cluster", cfg.to_json()),
        ("runs", Json::Array(runs)),
    ]);
    doc.to_file(std::path::Path::new(&out))?;
    println!("wrote {out}");
    if let Some(bp) = schema_baseline {
        let baseline = Json::from_file(std::path::Path::new(&bp))?;
        check_bench_schema(&baseline, &doc)?;
        print!("{}", report_bytes_gate_sources(&cfg, &baseline)?);
        println!("schema + deterministic-counter gate vs {bp}: OK");
    }
    Ok(())
}

/// Classify where each baseline row's `total_shuffle_bytes` gate comes
/// from: `analyzer` when it equals the static plan verifier's exact
/// routed-byte ceiling for that {algo, n, b} (the tight bound measured
/// runs must stay under), `analytic` when it matches the legacy loose
/// stages·8·b·n² bound, `custom` otherwise (a hand-tuned or
/// measured-refresh value). Printed with the `--schema-baseline` gate so
/// a baseline drifting away from the proved ceiling is visible in CI
/// logs rather than silent.
fn report_bytes_gate_sources(cfg: &ClusterConfig, baseline: &Json) -> Result<String> {
    let session = SpinSession::builder().cluster_config(cfg.clone()).build()?;
    let empty: [Json; 0] = [];
    let runs = baseline.get("runs").and_then(Json::as_array).unwrap_or(&empty);
    let (mut from_analyzer, mut from_analytic, mut custom) = (0usize, 0usize, 0usize);
    let mut lines = String::new();
    for run in runs {
        let fields = (
            run.get("algo").and_then(Json::as_str),
            run.get("n").and_then(Json::as_i64),
            run.get("b").and_then(Json::as_i64),
            run.get("total_shuffle_bytes").and_then(Json::as_f64),
            run.get("shuffle_stages").and_then(Json::as_f64),
        );
        let (Some(algo), Some(n), Some(b), Some(bytes), Some(stages)) = fields else {
            continue;
        };
        let (n, b) = (n as usize, b as usize);
        if b == 0 || n % b != 0 {
            continue;
        }
        // Unknown algorithms (a baseline ahead of this binary) simply
        // have no analyzer value and fall through to analytic/custom.
        let exact = session
            .analyze_invert(algo, n, n / b)
            .ok()
            .map(|v| v.analysis.total.shuffle_bytes_ceiling as f64);
        let loose = stages * 8.0 * b as f64 * (n * n) as f64;
        let source = if exact == Some(bytes) {
            from_analyzer += 1;
            "analyzer"
        } else if bytes == loose {
            from_analytic += 1;
            "analytic"
        } else {
            custom += 1;
            "custom"
        };
        lines.push_str(&format!("  {algo:<9} n={n:<4} b={b}: {source}\n"));
    }
    Ok(format!(
        "bytes gate sources ({from_analyzer} analyzer, {from_analytic} analytic, \
         {custom} custom):\n{lines}"
    ))
}

/// `spin explain`: print the optimized plan of one recursion level of the
/// chosen algorithm — which rewrites fired (the fused `multiply_sub`
/// Schur step, CSE cache points) and the predicted shuffle stages per
/// node. `--set plan_optimizer=false` shows the unoptimized plan.
/// `--verify` appends the static plan verifier's full verdict for the
/// same geometry and exits nonzero if any proof fails.
fn cmd_explain(mut args: Args) -> Result<()> {
    let cfg = cluster_config(&mut args)?;
    let job = job_config(&mut args)?;
    let algo = args
        .flag_value("--algo")?
        .unwrap_or_else(|| "spin".to_string());
    let verify = args.flag("--verify");
    args.finish()?;
    let session = SpinSession::builder()
        .cluster_config(cfg)
        .job_defaults(&job)
        .build()?;
    print!("{}", session.explain_invert(&algo, job.n, job.block_size)?);
    if verify {
        let verdict = session.analyze_invert(&algo, job.n, job.block_size)?;
        println!("\nplan verifier:\n{}", verdict.to_json().pretty());
        if !verdict.ok() {
            return Err(SpinError::plan(format!(
                "plan verification failed: {} violation(s)",
                verdict.violations().len()
            )));
        }
    }
    Ok(())
}

/// Rendered outcome of a `spin lint` run (pure data so tests can gold
/// the report text without capturing stdout).
struct LintReport {
    text: String,
    plans: usize,
    violations: usize,
}

/// Append one report line (plus violation detail lines) for a verified
/// plan; returns the number of violations found. `expect_rounds` is the
/// closed-form multiply-round count from `costmodel` — when present, the
/// analyzer's structural count must reproduce it exactly, and the
/// exchange-stage total must be twice it (each distributed multiply pays
/// an A-stream and a B-stream exchange; nothing else shuffles).
fn render_lint_line(
    text: &mut String,
    label: &str,
    verdict: &crate::analysis::PlanVerdict,
    expect_rounds: Option<usize>,
) -> usize {
    let total = verdict.analysis.total;
    let mut vios = verdict.violations();
    if let Some(want) = expect_rounds {
        if total.multiply_rounds != want {
            vios.push(format!(
                "cost cross-check: analyzer counted {} multiply rounds, closed form says {want}",
                total.multiply_rounds
            ));
        }
        if total.exchange_stages != 2 * total.multiply_rounds {
            vios.push(format!(
                "cost cross-check: {} exchange stages != 2 x {} multiply rounds",
                total.exchange_stages, total.multiply_rounds
            ));
        }
    }
    let ceil = if total.iterative_ceiling { "<=" } else { "" };
    let status = if vios.is_empty() { "OK" } else { "FAIL" };
    text.push_str(&format!(
        "{label}: stages {ceil}{}  rounds {ceil}{}  bytes<={}  collects {}  nodes {}  [{status}]\n",
        total.exchange_stages,
        total.multiply_rounds,
        total.shuffle_bytes_ceiling,
        total.driver_collects,
        verdict.analysis.node_count,
    ));
    for opaque in &verdict.analysis.opaque_inverts {
        text.push_str(&format!(
            "  note: opaque invert `{opaque}` (no analysis model; its interior is not counted)\n"
        ));
    }
    for v in &vios {
        text.push_str(&format!("  violation: {v}\n"));
    }
    vios.len()
}

/// Build the `spin lint` report: statically verify every plan in the
/// corpus (no execution) and render one line per plan plus a summary.
/// Default corpus: every registered algorithm at n ∈ {64, 128, 256},
/// b ∈ {2, 4, 8}; `--algo`/`--n`/`--block-size` narrow it; `--spec FILE`
/// lints each job of a JobSpec script through a zero-worker service
/// instead (plans are built and proved, never run).
fn lint_report(
    cfg: &ClusterConfig,
    algo: Option<&str>,
    n: Option<usize>,
    block_size: Option<usize>,
    spec_path: Option<&str>,
) -> Result<LintReport> {
    let mut text = String::new();
    let mut plans = 0usize;
    let mut violations = 0usize;
    if let Some(path) = spec_path {
        let specs = JobSpec::parse_script(&Json::from_file(std::path::Path::new(path))?)?;
        let probe = SpinService::builder()
            .cluster_config(cfg.clone())
            .workers(0)
            .queue_capacity(specs.len().max(1))
            .build()?;
        for (i, spec) in specs.into_iter().enumerate() {
            let label = if spec.label.is_empty() {
                format!("job {i}")
            } else {
                format!("job {i} [{}]", spec.label)
            };
            let handle = probe.submit(spec)?;
            let verdict = handle.analysis()?;
            violations += render_lint_line(&mut text, &label, &verdict, None);
            plans += 1;
        }
    } else {
        let session = SpinSession::builder().cluster_config(cfg.clone()).build()?;
        let algos: Vec<String> = match algo {
            Some(a) => vec![a.to_string()],
            None => session.algorithms(),
        };
        let geometries: Vec<(usize, usize)> = match n {
            Some(n) => vec![(n, block_size.unwrap_or_else(|| (n / 4).max(1)))],
            None => {
                let mut g = Vec::new();
                for n in [64usize, 128, 256] {
                    for b in [2usize, 4, 8] {
                        g.push((n, n / b));
                    }
                }
                g
            }
        };
        // The closed-form cross-check uses the same iteration budget the
        // session defaults give `analyze_invert` (JobConfig default).
        let max_iters = JobConfig::new(2, 1).max_iters;
        for name in &algos {
            for &(n, bs) in &geometries {
                let verdict = session.analyze_invert(name, n, bs)?;
                let b = n / bs;
                let expect = costmodel::analytic_multiply_rounds(name, b, max_iters);
                let label = format!("{name:<9} n={n:<4} b={b}");
                violations += render_lint_line(&mut text, &label, &verdict, expect);
                plans += 1;
            }
        }
    }
    text.push_str(&format!(
        "plan lint: {plans} plan(s) verified, {violations} violation(s)\n"
    ));
    Ok(LintReport {
        text,
        plans,
        violations,
    })
}

/// `spin lint`: run the static plan verifier over a corpus of optimized
/// plans and exit nonzero if any standing contract fails — geometry and
/// partitioner propagation, rewrite soundness (raw vs optimized plan),
/// recompute-lifecycle soundness, and the analytic cost accounting
/// (exchange stages, multiply rounds, shuffle-byte ceilings) cross-checked
/// against `costmodel::analytic_multiply_rounds`. Nothing executes: every
/// number is derived from plan structure alone.
fn cmd_lint(mut args: Args) -> Result<()> {
    let cfg = cluster_config(&mut args)?;
    let algo = args.flag_value("--algo")?;
    let n = args
        .flag_value("--n")?
        .map(|v| v.parse::<usize>().map_err(|_| SpinError::config("--n needs an integer")))
        .transpose()?;
    let block_size = args
        .flag_value("--block-size")?
        .map(|v| {
            v.parse::<usize>()
                .map_err(|_| SpinError::config("--block-size needs an integer"))
        })
        .transpose()?;
    let spec = args.flag_value("--spec")?;
    args.finish()?;
    let report = lint_report(&cfg, algo.as_deref(), n, block_size, spec.as_deref())?;
    print!("{}", report.text);
    if report.violations > 0 {
        return Err(SpinError::plan(format!(
            "plan lint failed: {} violation(s) across {} plan(s)",
            report.violations, report.plans
        )));
    }
    Ok(())
}

/// `spin serve`: the batch driver for the multi-tenant job service.
/// Reads a `{"jobs": [JobSpec, …]}` script — or, with `--store DIR`,
/// serves one inversion of a block-store matrix (blocks load lazily on
/// the workers) — submits every job to a [`SpinService`], waits for all
/// of them, and prints one report row per job plus the service-wide
/// cache and metrics-retention summary. `--workers 0` drains the queue
/// synchronously on this thread (deterministic replay).
fn cmd_serve(mut args: Args) -> Result<()> {
    let cfg = cluster_config(&mut args)?;
    let script = args.flag_value("--script")?;
    let store_dir = args.flag_value("--store")?;
    let algo = args.flag_value("--algo")?;
    let http_addr = args.flag_value("--http")?;
    let http_overrides = args.flag_values("--http-set")?;
    let workers = args
        .flag_value("--workers")?
        .map(|v| {
            v.parse::<usize>()
                .map_err(|_| SpinError::config("--workers needs an integer"))
        })
        .transpose()?
        .unwrap_or(2);
    let drain_timeout = args
        .flag_value("--drain-timeout-secs")?
        .map(|v| {
            v.parse::<u64>()
                .map_err(|_| SpinError::config("--drain-timeout-secs needs an integer"))
        })
        .transpose()?;
    args.finish()?;

    if let Some(addr) = http_addr {
        if script.is_some() || algo.is_some() {
            return Err(SpinError::config(
                "--http is a live server: jobs arrive over POST /v1/jobs, so \
                 --script/--algo do not apply (--store DIR is the durable job log)",
            ));
        }
        let mut http = HttpConfig {
            listen: addr,
            ..HttpConfig::default()
        };
        for kv in &http_overrides {
            http.apply_override(kv)?;
        }
        return serve_http(cfg, http, store_dir, workers, drain_timeout.unwrap_or(30));
    }
    if !http_overrides.is_empty() {
        return Err(SpinError::config("--http-set requires --http ADDR"));
    }
    if drain_timeout.is_some() {
        return Err(SpinError::config("--drain-timeout-secs requires --http ADDR"));
    }

    let (specs, source_label) = match (&script, &store_dir) {
        (Some(script), None) => {
            if algo.is_some() {
                return Err(SpinError::config(
                    "--algo applies to --store mode only; scripted jobs name their \
                     algorithm per job (\"algo\": \"...\")",
                ));
            }
            (
                JobSpec::parse_script(&Json::from_file(std::path::Path::new(script))?)?,
                script.clone(),
            )
        }
        (None, Some(dir)) => {
            let mut job = JobSpec::invert(MatrixSpec::from_store(dir)?).label("store-invert");
            if let Some(algo) = &algo {
                job = job.algorithm(algo);
            }
            (vec![job], dir.clone())
        }
        _ => {
            return Err(SpinError::config(
                "serve requires exactly one of --script FILE (a {\"jobs\": [...]} document) \
                 or --store DIR",
            ));
        }
    };
    let service = SpinService::builder()
        .session_builder(SpinSession::builder().cluster_config(cfg))
        .workers(workers)
        .queue_capacity(specs.len().max(1))
        .build()?;
    println!(
        "serving {} job(s) from {source_label} on {} worker thread(s)",
        specs.len(),
        service.worker_count()
    );
    let handles = specs
        .into_iter()
        .map(|spec| service.submit(spec))
        .collect::<Result<Vec<_>>>()?;
    if service.worker_count() == 0 {
        service.run_pending();
    }

    let mut table = fmt::Table::new(vec![
        "job", "tenant", "kind", "label", "status", "stages", "exchanges", "shuffled",
        "residual",
    ]);
    let mut failures: Vec<String> = Vec::new();
    for handle in &handles {
        let spec = handle.spec();
        let row = match handle.wait() {
            Ok(out) => vec![
                handle.id().to_string(),
                spec.tenant.clone(),
                spec.kind.name().to_string(),
                spec.label.clone(),
                "ok".to_string(),
                out.metrics.stages().len().to_string(),
                out.metrics.total_shuffle_stages().to_string(),
                fmt::bytes(out.metrics.total_shuffle_bytes()),
                out.residual
                    .map(|r| format!("{r:.2e}"))
                    .unwrap_or_else(|| "-".to_string()),
            ],
            Err(e) => {
                failures.push(format!(
                    "  job {} [{}/{}] {}: {e}",
                    handle.id(),
                    spec.tenant,
                    if spec.label.is_empty() { "-" } else { &spec.label },
                    spec.kind.name(),
                ));
                vec![
                    handle.id().to_string(),
                    spec.tenant.clone(),
                    spec.kind.name().to_string(),
                    spec.label.clone(),
                    format!("FAILED: {e}"),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                    "-".to_string(),
                ]
            }
        };
        table.row(row);
    }
    print!("{}", table.render());
    let plans = service.plan_cache_stats();
    let values = service.cache_stats();
    println!(
        "plan cache: {} node(s), {} hit(s), {} miss(es) · values: {} resident in {} entr(ies), \
         budget {}, {} eviction(s) ({})",
        plans.entries,
        plans.hits,
        plans.misses,
        fmt::bytes(values.resident_bytes),
        values.entries,
        values
            .budget_bytes
            .map(fmt::bytes)
            .unwrap_or_else(|| "unlimited".to_string()),
        values.evictions,
        fmt::bytes(values.evicted_bytes),
    );
    let retention = service.metrics();
    println!(
        "metrics retention: {} stage record(s) retained · {} released across {} finished job scope(s)",
        retention.retained_stage_records(),
        retention.released_stage_records(),
        retention.released_scopes(),
    );
    // Scripted batches are CI fodder: a nonzero exit must *name* what
    // failed, not just count it.
    if !failures.is_empty() {
        return Err(SpinError::cluster(format!(
            "{} job(s) failed:\n{}",
            failures.len(),
            failures.join("\n")
        )));
    }
    Ok(())
}

/// Set by the SIGINT handler; polled by the `--http` serve loop.
static INTERRUPTED: std::sync::atomic::AtomicBool = std::sync::atomic::AtomicBool::new(false);

/// Route SIGINT to the [`INTERRUPTED`] flag so ctrl-c triggers a
/// graceful drain instead of killing jobs mid-flight. Hand-rolled over
/// the raw C `signal(2)` entry point: the offline vendor set has no
/// `libc`/`ctrlc` crate, and `std` already links the platform libc.
#[cfg(unix)]
fn install_sigint_handler() {
    extern "C" fn on_sigint(_sig: i32) {
        INTERRUPTED.store(true, std::sync::atomic::Ordering::SeqCst);
    }
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    // SAFETY: `on_sigint` is async-signal-safe (a single atomic store)
    // and stays alive for the process lifetime (it is a fn item).
    unsafe {
        signal(SIGINT, on_sigint as extern "C" fn(i32) as usize);
    }
}

#[cfg(not(unix))]
fn install_sigint_handler() {}

/// `spin serve --http ADDR`: run the job API server until interrupted.
/// With `--store DIR`, jobs are journaled to a durable log there and the
/// log is replayed at startup — jobs still pending at the last shutdown
/// re-enqueue under their original ids, and already-terminal jobs are
/// served from the log without re-execution.
fn serve_http(
    cfg: ClusterConfig,
    http: HttpConfig,
    store_dir: Option<String>,
    workers: usize,
    drain_timeout_secs: u64,
) -> Result<()> {
    http.validate()?;
    if workers == 0 {
        return Err(SpinError::config(
            "--http needs --workers >= 1 (there is no synchronous drain over a live socket)",
        ));
    }
    let mut builder = SpinService::builder()
        .session_builder(SpinSession::builder().cluster_config(cfg))
        .workers(workers)
        .queue_capacity(256);
    let mut generation = 0u64;
    let mut replayed = None;
    if let Some(dir) = &store_dir {
        let (job_log, replay) = JobLog::open(std::path::Path::new(dir))?;
        generation = job_log.generation();
        builder = builder.job_log(std::sync::Arc::new(job_log));
        replayed = Some(replay);
    }
    let service = builder.build()?;

    let mut recovered = std::collections::BTreeMap::new();
    let mut resumed = 0usize;
    if let Some(replay) = replayed {
        for job in replay.jobs {
            match job.terminal {
                Some(terminal) => {
                    recovered.insert(
                        job.id,
                        RecoveredJob {
                            spec: job.spec,
                            terminal: crate::service::TerminalSummary {
                                status: terminal.status,
                                error: terminal.error,
                                residual: terminal.residual,
                            },
                        },
                    );
                }
                None => {
                    // Still pending at the last shutdown: resume under
                    // the original id (resubmits stay idempotent). Any
                    // recursion levels the crashed run checkpointed are
                    // attached first, so the resumed execution restores
                    // them instead of recomputing.
                    service.preload_checkpoints(job.id, job.checkpoints);
                    service.submit_with_id(job.id, job.spec)?;
                    resumed += 1;
                }
            }
        }
    }
    let recovered_count = recovered.len();

    let state = ServerState {
        service,
        config: http,
        recovered,
        generation,
    };
    let mut server = HttpServer::bind(state)?;
    // Parseable by scripts and the smoke test: exactly one line, the
    // resolved address (ephemeral ports included).
    println!("listening on http://{}", server.local_addr());
    match &store_dir {
        Some(dir) => println!(
            "job log: {dir} (generation {generation}; {recovered_count} terminal job(s) \
             recovered, {resumed} pending job(s) resumed)"
        ),
        None => println!("job log: none (jobs do not survive a restart; add --store DIR)"),
    }
    println!(
        "workers: {} · ctrl-c drains running jobs, then exits",
        server.service().worker_count()
    );

    install_sigint_handler();
    while !INTERRUPTED.load(std::sync::atomic::Ordering::SeqCst) {
        std::thread::sleep(std::time::Duration::from_millis(100));
    }
    println!(
        "interrupted: refusing new connections, draining running jobs \
         (deadline {drain_timeout_secs}s)"
    );
    server.shutdown();
    let drained = server
        .service()
        .wait_idle_timeout(std::time::Duration::from_secs(drain_timeout_secs));
    // Shutdown summary: recovery activity over the server's lifetime,
    // and any tenants leaving work behind at the deadline.
    let r = *server.service().metrics().resilience();
    if r != Default::default() {
        println!(
            "resilience: {} task retrie(s), {} budget exhaustion(s), {}/{} speculative \
             copies won, {} checkpoint level(s) written, {} restored",
            r.retries,
            r.retry_exhausted,
            r.speculative_won,
            r.speculative_launched,
            r.checkpoints_written,
            r.checkpoints_restored
        );
    }
    for g in server.service().tenant_gauges() {
        println!(
            "tenant {}: {} queued, {} running at shutdown",
            g.tenant, g.queued, g.running
        );
    }
    if drained {
        println!("drained; bye");
        return Ok(());
    }
    // The deadline passed with jobs still queued or running: hard-fail
    // them with a journaled terminal (a restart serves the verdict, it
    // does not silently re-run them) and exit nonzero so supervisors see
    // the unclean drain.
    let failed = server
        .service()
        .fail_pending("drain deadline exceeded at shutdown");
    Err(SpinError::cluster(format!(
        "drain deadline of {drain_timeout_secs}s exceeded: hard-failed {failed} job(s)"
    )))
}

/// Deterministic schema + perf gate for `spin bench`: the measured output
/// must keep the committed baseline's shape, and — where the baseline
/// carries runs — must not regress the deterministic dataflow counters
/// (shuffle exchanges, shuffle bytes, driver collects). Timing magnitudes are
/// intentionally NOT compared (host-dependent); measured timing fields
/// (`wall_clock_ms`) gate on schema presence only — every gated row must
/// carry a nonzero measurement, never a particular value.
fn check_bench_schema(baseline: &Json, measured: &Json) -> Result<()> {
    let bschema = baseline.get("schema").and_then(Json::as_str).unwrap_or("<missing>");
    let mschema = measured.get("schema").and_then(Json::as_str).unwrap_or("<missing>");
    if bschema != mschema {
        return Err(SpinError::config(format!(
            "bench schema drift: baseline `{bschema}` vs measured `{mschema}`"
        )));
    }
    let bobj = baseline
        .as_object()
        .ok_or_else(|| SpinError::config("bench baseline is not a JSON object"))?;
    let mobj = measured
        .as_object()
        .ok_or_else(|| SpinError::config("bench output is not a JSON object"))?;
    for key in mobj.keys() {
        if !bobj.contains_key(key) {
            return Err(SpinError::config(format!(
                "bench schema drift: new top-level key `{key}` missing from the committed baseline \
                 (update BENCH_spin.json deliberately)"
            )));
        }
    }
    for key in bobj.keys() {
        if key.as_str() != "note" && !mobj.contains_key(key) {
            return Err(SpinError::config(format!(
                "bench schema drift: baseline key `{key}` disappeared from the measured output"
            )));
        }
    }
    let empty: [Json; 0] = [];
    let bruns = baseline.get("runs").and_then(Json::as_array).unwrap_or(&empty);
    let mruns = measured.get("runs").and_then(Json::as_array).unwrap_or(&empty);
    // Per-run record shape.
    if let (Some(brun), Some(mrun)) = (bruns.first(), mruns.first()) {
        let bkeys: Vec<&String> = brun.as_object().map(|m| m.keys().collect()).unwrap_or_default();
        let mkeys: Vec<&String> = mrun.as_object().map(|m| m.keys().collect()).unwrap_or_default();
        if bkeys != mkeys {
            return Err(SpinError::config(format!(
                "bench schema drift: run-record keys changed (baseline {bkeys:?} vs measured {mkeys:?})"
            )));
        }
    }
    // Deterministic perf counters, matched by (algo, n, b).
    for brun in bruns {
        let key = (
            brun.get("algo").and_then(Json::as_str),
            brun.get("n").and_then(Json::as_i64),
            brun.get("b").and_then(Json::as_i64),
        );
        let (Some(algo), Some(n), Some(b)) = key else { continue };
        for mrun in mruns {
            if mrun.get("algo").and_then(Json::as_str) != Some(algo)
                || mrun.get("n").and_then(Json::as_i64) != Some(n)
                || mrun.get("b").and_then(Json::as_i64) != Some(b)
            {
                continue;
            }
            for counter in [
                "shuffle_stages",
                "driver_collects",
                "submit_driver_blocks",
                "total_shuffle_bytes",
            ] {
                let bv = brun.get(counter).and_then(Json::as_f64);
                let mv = mrun.get(counter).and_then(Json::as_f64);
                if let (Some(bv), Some(mv)) = (bv, mv) {
                    if mv > bv {
                        return Err(SpinError::config(format!(
                            "bench perf regression: {algo} n={n} b={b}: {counter} rose {bv} -> {mv}"
                        )));
                    }
                }
            }
            // Measured timing: gated on presence + nonzero only. The
            // baseline commits 0.0 placeholders (timings are
            // host-dependent); a measured run that reports no wall clock
            // means the timing plumbing broke.
            let timing = "wall_clock_ms";
            if brun.get(timing).is_some() {
                let mv = mrun.get(timing).and_then(Json::as_f64);
                if !mv.is_some_and(|v| v > 0.0) {
                    return Err(SpinError::config(format!(
                        "bench timing gate: {algo} n={n} b={b}: `{timing}` missing or zero \
                         in the measured output (got {mv:?})"
                    )));
                }
            }
        }
    }
    Ok(())
}

fn cmd_info(mut args: Args) -> Result<()> {
    let cfg = cluster_config(&mut args)?;
    args.finish()?;
    println!("cluster config:\n{}", cfg.to_json().pretty());
    let registry = crate::algos::AlgorithmRegistry::with_defaults();
    println!("inversion algorithms:");
    for name in registry.names() {
        let desc = registry.get(&name)?.description().to_string();
        println!("  {name:<8} {desc}");
    }
    let dir: PathBuf = cfg.artifacts_dir.clone();
    match Manifest::load(&dir) {
        Ok(m) => println!(
            "artifacts: {} programs in {} (dtype {}, block sizes {:?})",
            m.len(),
            dir.display(),
            m.dtype,
            m.block_sizes
        ),
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn help_runs() {
        assert_eq!(run(argv("help")), 0);
    }

    #[test]
    fn unknown_command_fails() {
        assert_eq!(run(argv("frobnicate")), 1);
    }

    #[test]
    fn invert_small_native() {
        assert_eq!(
            run(argv(
                "invert --n 32 --block-size 8 --backend native --residual-check"
            )),
            0
        );
    }

    #[test]
    fn invert_lu_algo() {
        assert_eq!(
            run(argv("invert --n 16 --block-size 4 --algo lu")),
            0
        );
    }

    #[test]
    fn invert_rejects_bad_flags() {
        assert_eq!(run(argv("invert --n 33 --block-size 8")), 1); // non-pow2
        assert_eq!(run(argv("invert --bogus-flag")), 1);
    }

    #[test]
    fn invert_rejects_unknown_algo_via_registry() {
        assert_eq!(run(argv("invert --n 16 --block-size 4 --algo qr")), 1);
    }

    #[test]
    fn invert_newton_with_set_tolerance() {
        // `--set tolerance=…` routes to the job config, not the cluster
        // topology, and the newton scheme honors it.
        assert_eq!(
            run(argv(
                "invert --n 16 --block-size 4 --algo newton --set tolerance=1e-8 --set max_iters=50"
            )),
            0
        );
    }

    #[test]
    fn invert_cholesky_on_spd_input() {
        assert_eq!(
            run(argv(
                "invert --n 16 --block-size 4 --algo cholesky --generator spd"
            )),
            0
        );
        // Cholesky on the (asymmetric) default family fails loudly.
        assert_eq!(run(argv("invert --n 16 --block-size 4 --algo cholesky")), 1);
    }

    #[test]
    fn invert_rejects_iterative_knobs_on_exact_algos() {
        assert_eq!(
            run(argv("invert --n 16 --block-size 4 --set tolerance=1e-8")),
            1
        );
        assert_eq!(
            run(argv(
                "invert --n 16 --block-size 4 --algo lu --set max_iters=5"
            )),
            1
        );
    }

    #[test]
    fn non_pow2_n_rejected_up_front_even_with_default_block_size() {
        // The old default `(n/4).max(1)` deferred to the generic validator;
        // now the geometry check fires first, with an actionable message.
        assert_eq!(run(argv("invert --n 48")), 1);
        let err = validate_geometry(48, 12).unwrap_err().to_string();
        assert!(err.contains("not a power of two"), "{err}");
        assert!(err.contains("32") && err.contains("64"), "{err}");
    }

    #[test]
    fn bad_block_size_error_names_valid_sizes() {
        let err = validate_geometry(256, 100).unwrap_err().to_string();
        assert!(err.contains("valid block sizes"), "{err}");
        for b in ["1", "2", "4", "8", "16", "32", "64", "128", "256"] {
            assert!(err.contains(b), "missing {b} in: {err}");
        }
        assert!(validate_geometry(256, 0).is_err());
        assert!(validate_geometry(256, 512).is_err());
        assert!(validate_geometry(0, 1).is_err());
        assert!(validate_geometry(256, 64).is_ok());
        assert_eq!(valid_block_sizes(8), vec![1, 2, 4, 8]);
    }

    #[test]
    fn job_override_geometry_also_validated() {
        // `--job n=...` can smuggle bad geometry past the flag parsing.
        assert_eq!(run(argv("invert --n 16 --block-size 4 --job n=48")), 1);
    }

    #[test]
    fn cost_renders() {
        assert_eq!(run(argv("cost --n 1024 --b 8 --cores 30")), 0);
    }

    #[test]
    fn gen_writes_store() {
        let dir = std::env::temp_dir().join(format!("spin_cli_gen_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cmd = format!("gen --n 16 --block-size 4 --out {}", dir.display());
        assert_eq!(run(argv(&cmd)), 0);
        let meta = crate::ser::bin::read_block_store_meta(&dir).unwrap();
        assert_eq!(meta.nblocks, 4);
    }

    #[test]
    fn ingest_then_serve_store_round_trips() {
        let dir = std::env::temp_dir().join(format!("spin_cli_ingest_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cmd = format!("ingest --n 32 --block-size 8 --seed 9 --out {}", dir.display());
        assert_eq!(run(argv(&cmd)), 0);
        // Serve the store directly: one lazy invert job, blocks loaded on
        // the workers.
        let cmd = format!("serve --store {} --workers 0", dir.display());
        assert_eq!(run(argv(&cmd)), 0);
        let cmd = format!("serve --store {} --workers 0 --algo lu", dir.display());
        assert_eq!(run(argv(&cmd)), 0);
        // Missing ingest args / exclusive serve sources fail.
        assert_eq!(run(argv("ingest --n 16 --block-size 4")), 1);
        assert_eq!(run(argv("serve --workers 0")), 1);
        let both = format!("serve --store {} --script nope.json", dir.display());
        assert_eq!(run(argv(&both)), 1);
        // --algo would be silently ignored with a script: rejected.
        assert_eq!(run(argv("serve --script nope.json --algo lu")), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn info_runs() {
        assert_eq!(run(argv("info")), 0);
    }

    #[test]
    fn explain_prints_fused_plan() {
        assert_eq!(run(argv("explain --n 64 --block-size 16")), 0);
        assert_eq!(run(argv("explain --n 64 --block-size 16 --algo lu")), 0);
        // Unknown algorithm / bad geometry fail.
        assert_eq!(run(argv("explain --n 64 --block-size 16 --algo qr")), 1);
        assert_eq!(run(argv("explain --n 48 --block-size 16")), 1);
        // Unoptimized rendering is reachable via the cluster override.
        assert_eq!(
            run(argv("explain --n 64 --block-size 16 --set plan_optimizer=false")),
            0
        );
    }

    #[test]
    fn bench_schema_gate_accepts_stub_and_rejects_drift() {
        use crate::ser::json::Json;
        // The committed counter baseline accepts a schema-compatible
        // (empty-runs) measurement.
        let stub = Json::from_file(std::path::Path::new(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../BENCH_spin.json"
        )))
        .unwrap();
        let measured = Json::object(vec![
            ("schema", Json::str("spin-bench-v1")),
            ("scale", Json::str("smoke")),
            ("seed", Json::num(42.0)),
            ("cluster", Json::object(vec![])),
            ("runs", Json::Array(vec![])),
        ]);
        check_bench_schema(&stub, &measured).unwrap();
        // Schema string drift fails.
        let drift = Json::object(vec![
            ("schema", Json::str("spin-bench-v2")),
            ("scale", Json::str("smoke")),
            ("seed", Json::num(42.0)),
            ("cluster", Json::object(vec![])),
            ("runs", Json::Array(vec![])),
        ]);
        assert!(check_bench_schema(&stub, &drift).is_err());
        // A new top-level key fails (schema must be updated deliberately).
        let extra = Json::object(vec![
            ("schema", Json::str("spin-bench-v1")),
            ("scale", Json::str("smoke")),
            ("seed", Json::num(42.0)),
            ("cluster", Json::object(vec![])),
            ("runs", Json::Array(vec![])),
            ("surprise", Json::Bool(true)),
        ]);
        assert!(check_bench_schema(&stub, &extra).is_err());
        // Deterministic counter regression fails.
        let run_rec = |stages: f64, wall_ms: f64| {
            Json::object(vec![
                ("algo", Json::str("spin")),
                ("n", Json::num(64.0)),
                ("b", Json::num(2.0)),
                ("shuffle_stages", Json::num(stages)),
                ("driver_collects", Json::num(0.0)),
                ("wall_clock_ms", Json::num(wall_ms)),
            ])
        };
        let base = Json::object(vec![
            ("schema", Json::str("spin-bench-v1")),
            ("runs", Json::Array(vec![run_rec(6.0, 0.0)])),
        ]);
        let ok = Json::object(vec![
            ("schema", Json::str("spin-bench-v1")),
            ("runs", Json::Array(vec![run_rec(6.0, 1.5)])),
        ]);
        let worse = Json::object(vec![
            ("schema", Json::str("spin-bench-v1")),
            ("runs", Json::Array(vec![run_rec(8.0, 1.5)])),
        ]);
        check_bench_schema(&base, &ok).unwrap();
        let err = check_bench_schema(&base, &worse).unwrap_err();
        assert!(err.to_string().contains("perf regression"), "{err}");
        // The armed timing gate: a baseline row carrying `wall_clock_ms`
        // (even the committed 0.0 placeholder) requires the measured run
        // to report a real, nonzero measurement.
        let unmeasured = Json::object(vec![
            ("schema", Json::str("spin-bench-v1")),
            ("runs", Json::Array(vec![run_rec(6.0, 0.0)])),
        ]);
        let err = check_bench_schema(&base, &unmeasured).unwrap_err();
        assert!(err.to_string().contains("timing gate"), "{err}");
    }

    #[test]
    fn bench_end_to_end_gate_against_own_output() {
        // A measured file always passes the gate against itself — the CI
        // wiring (measure, then diff against the committed baseline) is
        // exactly this call.
        let path = std::env::temp_dir().join(format!("BENCH_gate_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let cmd = format!("bench --smoke --out {}", path.display());
        assert_eq!(run(argv(&cmd)), 0);
        let cmd = format!(
            "bench --smoke --out {} --schema-baseline {}",
            path.display(),
            path.display()
        );
        assert_eq!(run(argv(&cmd)), 0);
        let _ = std::fs::remove_file(&path);
    }

    fn write_script(name: &str, doc: &Json) -> std::path::PathBuf {
        let path = std::env::temp_dir().join(format!("{name}_{}.json", std::process::id()));
        doc.to_file(&path).unwrap();
        path
    }

    #[test]
    fn serve_replays_a_job_script() {
        use crate::service::{JobSpec, MatrixSpec};
        let a = MatrixSpec::new(32, 8).seeded(5);
        let b = MatrixSpec::new(32, 8).seeded(6);
        let doc = Json::object(vec![(
            "jobs",
            Json::Array(vec![
                JobSpec::invert(a.clone()).tenant("alice").label("inv").to_json(),
                JobSpec::solve(a.clone(), b).tenant("bob").label("gls").to_json(),
                JobSpec::pseudo_inverse(a).tenant("alice").to_json(),
            ]),
        )]);
        let path = write_script("spin_serve_ok", &doc);
        // Threaded and synchronous drivers both succeed.
        let cmd = format!("serve --script {}", path.display());
        assert_eq!(run(argv(&cmd)), 0);
        let cmd = format!(
            "serve --script {} --workers 0 --set cache_budget_bytes=8192",
            path.display()
        );
        assert_eq!(run(argv(&cmd)), 0);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn serve_rejects_bad_input() {
        // Missing --script.
        assert_eq!(run(argv("serve")), 1);
        // Script that is not a jobs document.
        let path = write_script("spin_serve_bad", &Json::object(vec![]));
        let cmd = format!("serve --script {}", path.display());
        assert_eq!(run(argv(&cmd)), 1);
        let _ = std::fs::remove_file(&path);
        // Script with an invalid job fails at submit.
        let bad = Json::object(vec![(
            "jobs",
            Json::Array(vec![crate::service::JobSpec::invert(
                crate::service::MatrixSpec::new(100, 10),
            )
            .to_json()]),
        )]);
        let path = write_script("spin_serve_badjob", &bad);
        let cmd = format!("serve --script {}", path.display());
        assert_eq!(run(argv(&cmd)), 1);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn lint_corpus_proves_all_plans() {
        // Every registered algorithm × the tracked geometry sweep passes
        // the static verifier (geometry, rewrite soundness, lifecycle,
        // and the closed-form cost cross-check) without executing.
        assert_eq!(run(argv("lint")), 0);
    }

    #[test]
    fn lint_report_is_golden_for_one_plan() {
        let cfg = ClusterConfig::paper();
        let report = lint_report(&cfg, Some("spin"), Some(64), Some(16), None).unwrap();
        assert_eq!(report.plans, 1);
        assert_eq!(report.violations, 0);
        assert_eq!(
            report.text,
            "spin      n=64   b=4: stages 36  rounds 18  bytes<=245760  collects 0  \
             nodes 2  [OK]\nplan lint: 1 plan(s) verified, 0 violation(s)\n"
        );
    }

    #[test]
    fn lint_newton_reports_iteration_ceiling() {
        // Iterative schemes gate a budget ceiling, not an equality: the
        // report marks stages/rounds with `<=` (4·max_iters − 2 = 254
        // stages at the default budget of 64 passes).
        let cfg = ClusterConfig::paper();
        let report = lint_report(&cfg, Some("newton"), Some(64), Some(32), None).unwrap();
        assert_eq!(report.violations, 0);
        assert!(
            report.text.contains("stages <=254  rounds <=127"),
            "{}",
            report.text
        );
    }

    #[test]
    fn lint_cli_narrows_and_rejects_bad_input() {
        assert_eq!(run(argv("lint --algo spin --n 64 --block-size 16")), 0);
        assert_eq!(run(argv("lint --algo qr --n 64 --block-size 16")), 1);
        assert_eq!(run(argv("lint --n 64 --block-size 48")), 1);
        assert_eq!(run(argv("lint --bogus")), 1);
    }

    #[test]
    fn lint_spec_script_without_running() {
        use crate::service::{JobSpec, MatrixSpec};
        let a = MatrixSpec::new(32, 8).seeded(5);
        let mut lu = JobSpec::invert(a.clone()).label("lu-inv");
        lu.algo = Some("lu".to_string());
        let doc = Json::object(vec![(
            "jobs",
            Json::Array(vec![JobSpec::invert(a).label("inv").to_json(), lu.to_json()]),
        )]);
        let path = write_script("spin_lint_spec", &doc);
        let cmd = format!("lint --spec {}", path.display());
        assert_eq!(run(argv(&cmd)), 0);
        let _ = std::fs::remove_file(&path);
        assert_eq!(run(argv("lint --spec /nonexistent/jobs.json")), 1);
    }

    #[test]
    fn explain_verify_appends_verdict() {
        assert_eq!(run(argv("explain --n 64 --block-size 16 --verify")), 0);
        assert_eq!(
            run(argv("explain --n 64 --block-size 16 --algo newton --verify")),
            0
        );
    }

    #[test]
    fn bytes_gate_sources_classifies_baseline_rows() {
        let cfg = ClusterConfig::paper();
        let row = |bytes: f64| {
            Json::object(vec![
                ("algo", Json::str("spin")),
                ("n", Json::num(64.0)),
                ("b", Json::num(2.0)),
                ("shuffle_stages", Json::num(12.0)),
                ("total_shuffle_bytes", Json::num(bytes)),
            ])
        };
        let baseline = Json::object(vec![(
            "runs",
            Json::Array(vec![
                row(98304.0),  // the analyzer's exact routed-byte ceiling
                row(786432.0), // the legacy loose stages·8·b·n² bound
                row(123456.0), // anything else: hand-tuned
            ]),
        )]);
        let report = report_bytes_gate_sources(&cfg, &baseline).unwrap();
        assert!(
            report.starts_with("bytes gate sources (1 analyzer, 1 analytic, 1 custom)"),
            "{report}"
        );
    }

    #[test]
    fn committed_baseline_bytes_are_analyzer_exact() {
        // Satellite guard: every committed `total_shuffle_bytes` gate in
        // BENCH_spin.json is the analyzer's exact ceiling — nobody has to
        // trust a hand-derived constant again.
        let baseline = Json::from_file(std::path::Path::new(concat!(
            env!("CARGO_MANIFEST_DIR"),
            "/../BENCH_spin.json"
        )))
        .unwrap();
        let report = report_bytes_gate_sources(&ClusterConfig::paper(), &baseline).unwrap();
        assert!(
            report.contains("(36 analyzer, 0 analytic, 0 custom)"),
            "{report}"
        );
    }

    #[test]
    fn bench_smoke_writes_trajectory_json() {
        let path = std::env::temp_dir().join(format!("BENCH_spin_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);
        let cmd = format!("bench --smoke --out {}", path.display());
        assert_eq!(run(argv(&cmd)), 0);
        let j = crate::ser::json::Json::from_file(&path).unwrap();
        assert_eq!(j.get("schema").unwrap().as_str(), Some("spin-bench-v1"));
        let runs = j.get("runs").unwrap().as_array().unwrap();
        assert!(runs.len() >= 4, "smoke sweep covers spin+lu at two splits");
        for r in runs {
            assert!(r.get("virtual_secs").unwrap().as_f64().unwrap() > 0.0);
            // Measured wall clock is armed: every row reports a real timing.
            assert!(r.get("wall_clock_ms").unwrap().as_f64().unwrap() > 0.0);
            assert!(r.get("residual").unwrap().as_f64().unwrap() < 1e-8);
            assert!(r.get("methods").unwrap().get("multiply").is_some());
            // The partitioner-aware pipeline never round-trips the driver.
            assert_eq!(r.get("driver_collects").unwrap().as_i64(), Some(0));
            // Lazy leaves: submit generates zero blocks on the driver.
            assert_eq!(r.get("submit_driver_blocks").unwrap().as_i64(), Some(0));
        }
        let _ = std::fs::remove_file(&path);
    }
}
