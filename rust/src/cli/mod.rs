//! Command-line launcher (no `clap` in the offline vendor set — a small
//! hand-rolled parser).
//!
//! ```text
//! spin invert  --n 1024 --block-size 128 [--algo spin|lu] [--backend native|xla]
//!              [--generator diag-dominant|spd] [--seed N] [--fuse-leaf-2x2]
//!              [--residual-check] [--set cluster.key=value]...
//! spin gen     --n 512 --block-size 64 --out DIR [--generator …] [--seed N]
//! spin cost    [--n 4096] [--b 8] [--cores 30] [--calibrate]
//! spin exp     figure2|figure3|figure4|figure5|table3|all [--smoke|--full]
//! spin info
//! ```

mod args;

pub use args::Args;

use std::path::PathBuf;

use crate::algos::Algorithm;
use crate::blockmatrix::BlockMatrix;
use crate::cluster::Cluster;
use crate::config::{ClusterConfig, GeneratorKind, JobConfig};
use crate::costmodel::{self, CostConstants};
use crate::error::{Result, SpinError};
use crate::experiments::{self, Scale};
use crate::linalg::inverse_residual;
use crate::runtime::{make_backend, Manifest};
use crate::ser::bin;
use crate::util::fmt;

/// Entry point for the `spin` binary; returns the process exit code.
pub fn run(argv: Vec<String>) -> i32 {
    crate::util::logger::init();
    match dispatch(argv) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    }
}

fn dispatch(argv: Vec<String>) -> Result<()> {
    let mut args = Args::new(argv);
    let cmd = args.positional().unwrap_or_else(|| "help".to_string());
    match cmd.as_str() {
        "invert" => cmd_invert(args),
        "gen" => cmd_gen(args),
        "cost" => cmd_cost(args),
        "exp" => cmd_exp(args),
        "info" => cmd_info(args),
        "help" | "--help" | "-h" => {
            print!("{}", usage());
            Ok(())
        }
        other => Err(SpinError::config(format!(
            "unknown command `{other}`\n{}",
            usage()
        ))),
    }
}

pub fn usage() -> String {
    "SPIN — Strassen-based distributed matrix inversion (ICDCN '18 reproduction)\n\
     \n\
     USAGE: spin <command> [flags]\n\
     \n\
     COMMANDS:\n\
     \x20 invert   invert a generated matrix on the simulated cluster\n\
     \x20 gen      generate a matrix and write it as a block store\n\
     \x20 cost     print the Table-1 cost model (optionally calibrated)\n\
     \x20 exp      run a paper experiment: figure2|figure3|figure4|figure5|table3|all\n\
     \x20 info     show cluster config and artifact status\n\
     \n\
     COMMON FLAGS:\n\
     \x20 --n N --block-size S --algo spin|lu --backend native|xla\n\
     \x20 --generator diag-dominant|spd --seed N --fuse-leaf-2x2\n\
     \x20 --residual-check --set key=value (cluster overrides, repeatable)\n\
     \x20 --smoke | --full (experiment scale)\n"
        .to_string()
}

fn cluster_config(args: &mut Args) -> Result<ClusterConfig> {
    let mut cfg = match args.flag_value("--cluster-config")? {
        Some(path) => ClusterConfig::from_file(std::path::Path::new(&path))?,
        None => ClusterConfig::paper(),
    };
    if let Some(backend) = args.flag_value("--backend")? {
        cfg.apply_override(&format!("backend={backend}"))?;
    }
    for kv in args.flag_values("--set")? {
        cfg.apply_override(&kv)?;
    }
    Ok(cfg)
}

fn job_config(args: &mut Args) -> Result<JobConfig> {
    let n = args
        .flag_value("--n")?
        .map(|v| v.parse::<usize>().map_err(|_| SpinError::config("--n needs an integer")))
        .transpose()?
        .unwrap_or(256);
    let bs = args
        .flag_value("--block-size")?
        .map(|v| {
            v.parse::<usize>()
                .map_err(|_| SpinError::config("--block-size needs an integer"))
        })
        .transpose()?
        .unwrap_or_else(|| (n / 4).max(1));
    let mut job = JobConfig::new(n, bs);
    if let Some(s) = args.flag_value("--seed")? {
        job.seed = s
            .parse()
            .map_err(|_| SpinError::config("--seed needs an integer"))?;
    }
    if let Some(g) = args.flag_value("--generator")? {
        job.generator = GeneratorKind::parse(&g)?;
    }
    if args.flag("--fuse-leaf-2x2") {
        job.fuse_leaf_2x2 = true;
    }
    if args.flag("--residual-check") {
        job.residual_check = true;
    }
    for kv in args.flag_values("--job")? {
        job.apply_override(&kv)?;
    }
    job.validate()?;
    Ok(job)
}

fn cmd_invert(mut args: Args) -> Result<()> {
    let cfg = cluster_config(&mut args)?;
    let job = job_config(&mut args)?;
    let algo = match args.flag_value("--algo")? {
        Some(a) => Algorithm::parse(&a)?,
        None => Algorithm::Spin,
    };
    args.finish()?;

    println!(
        "inverting {}x{} (b = {}, block {}x{}) with {} on {} executors × {} cores [{} backend]",
        job.n,
        job.n,
        job.num_splits(),
        job.block_size,
        job.block_size,
        algo.name(),
        cfg.total_executors(),
        cfg.cores_per_executor,
        cfg.backend.name(),
    );
    let cluster = Cluster::new(cfg.clone());
    let kernels = make_backend(&cfg)?;
    let a = BlockMatrix::random(&job)?;
    let a_dense = a.to_dense()?;
    let inv = algo.invert(&cluster, kernels.as_ref(), &a, &job)?;
    let resid = inverse_residual(&a_dense, &inv.to_dense()?);

    println!("\nper-method breakdown:\n{}", cluster.metrics().render_table());
    println!(
        "virtual wall clock: {}   residual: {resid:.3e}",
        fmt::secs(cluster.virtual_secs())
    );
    Ok(())
}

fn cmd_gen(mut args: Args) -> Result<()> {
    let job = job_config(&mut args)?;
    let out = args
        .flag_value("--out")?
        .ok_or_else(|| SpinError::config("gen requires --out DIR"))?;
    args.finish()?;
    let a = BlockMatrix::random(&job)?;
    let nblocks = a.nblocks();
    let blocks = a
        .to_dense()?; // materialize once, then re-split for the store
    let bm = BlockMatrix::from_dense(&blocks, job.block_size)?;
    let iter = (0..nblocks)
        .flat_map(|i| (0..nblocks).map(move |j| (i, j)))
        .map(|(i, j)| ((i, j), bm.get_block(i, j).unwrap().matrix.clone()));
    bin::write_block_store(std::path::Path::new(&out), nblocks, job.block_size, iter)?;
    println!(
        "wrote {}x{} block store ({} blocks of {}x{}) to {out}",
        job.n, job.n, nblocks * nblocks, job.block_size, job.block_size
    );
    Ok(())
}

fn cmd_cost(mut args: Args) -> Result<()> {
    let cfg = cluster_config(&mut args)?;
    let n = args
        .flag_value("--n")?
        .map(|v| v.parse().unwrap_or(4096))
        .unwrap_or(4096);
    let b = args
        .flag_value("--b")?
        .map(|v| v.parse().unwrap_or(8))
        .unwrap_or(8);
    let cores = args
        .flag_value("--cores")?
        .map(|v| v.parse().unwrap_or(cfg.total_cores()))
        .unwrap_or_else(|| cfg.total_cores());
    let constants = if args.flag("--calibrate") {
        let rep = costmodel::calibrate(128, &cfg.network);
        println!(
            "calibrated on this host: leaf {:.2} GF/s, gemm {:.2} GF/s\n",
            rep.leaf_gflops, rep.gemm_gflops
        );
        rep.constants
    } else {
        CostConstants::default()
    };
    args.finish()?;
    print!("{}", costmodel::render_table1(n, b, cores, &constants));
    Ok(())
}

fn cmd_exp(mut args: Args) -> Result<()> {
    let which = args
        .positional()
        .ok_or_else(|| SpinError::config("exp requires a target: figure2|figure3|figure4|figure5|table3|all"))?;
    let cfg = cluster_config(&mut args)?;
    let scale = if args.flag("--smoke") {
        Scale::smoke()
    } else if args.flag("--full") {
        Scale::full()
    } else {
        Scale::default_scale()
    };
    let seed = args
        .flag_value("--seed")?
        .map(|v| v.parse().unwrap_or(42))
        .unwrap_or(42);
    args.finish()?;

    let run_one = |name: &str| -> Result<()> {
        match name {
            "figure2" => {
                let rows = experiments::figure2::run(&cfg, &scale, seed)?;
                print!("{}", experiments::figure2::render(&rows)?);
                match experiments::figure2::check_shape(&rows) {
                    Ok(()) => println!("shape check: OK (SPIN ≤ LU, gap grows with n)"),
                    Err(e) => println!("shape check: DEVIATION — {e}"),
                }
            }
            "figure3" => {
                let rows = experiments::figure3::run(&cfg, &scale, seed)?;
                print!("{}", experiments::figure3::render(&rows)?);
                match experiments::figure3::check_shape(&rows, true) {
                    Ok(()) => println!("shape check: OK (SPIN wins pointwise, U-shape present)"),
                    Err(e) => println!("shape check: DEVIATION — {e}"),
                }
            }
            "figure4" => {
                let (rows, _) = experiments::figure4::run(&cfg, &scale, seed)?;
                print!("{}", experiments::figure4::render(&rows)?);
                match experiments::figure4::check_shape(&rows) {
                    Ok(()) => println!("shape check: OK (model within 10x pointwise)"),
                    Err(e) => println!("shape check: DEVIATION — {e}"),
                }
            }
            "figure5" => {
                let rows = experiments::figure5::run(&cfg, &scale, seed)?;
                print!("{}", experiments::figure5::render(&rows)?);
                match experiments::figure5::check_shape(&rows) {
                    Ok(()) => println!("shape check: OK (monotone scaling)"),
                    Err(e) => println!("shape check: DEVIATION — {e}"),
                }
            }
            "table3" => {
                let n = scale.sizes[scale.sizes.len() / 2];
                let cols = experiments::table3::run(&cfg, n, scale.max_b, seed)?;
                print!("{}", experiments::table3::render(n, &cols)?);
                match experiments::table3::check_shape(&cols) {
                    Ok(()) => println!("shape check: OK (leaf falls, multiply rises)"),
                    Err(e) => println!("shape check: DEVIATION — {e}"),
                }
            }
            other => {
                return Err(SpinError::config(format!("unknown experiment `{other}`")));
            }
        }
        Ok(())
    };

    if which == "all" {
        for name in ["figure2", "figure3", "figure4", "figure5", "table3"] {
            println!("\n=== {name} ===");
            run_one(name)?;
        }
        Ok(())
    } else {
        run_one(&which)
    }
}

fn cmd_info(mut args: Args) -> Result<()> {
    let cfg = cluster_config(&mut args)?;
    args.finish()?;
    println!("cluster config:\n{}", cfg.to_json().pretty());
    let dir: PathBuf = cfg.artifacts_dir.clone();
    match Manifest::load(&dir) {
        Ok(m) => println!(
            "artifacts: {} programs in {} (dtype {}, block sizes {:?})",
            m.len(),
            dir.display(),
            m.dtype,
            m.block_sizes
        ),
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(String::from).collect()
    }

    #[test]
    fn help_runs() {
        assert_eq!(run(argv("help")), 0);
    }

    #[test]
    fn unknown_command_fails() {
        assert_eq!(run(argv("frobnicate")), 1);
    }

    #[test]
    fn invert_small_native() {
        assert_eq!(
            run(argv(
                "invert --n 32 --block-size 8 --backend native --residual-check"
            )),
            0
        );
    }

    #[test]
    fn invert_lu_algo() {
        assert_eq!(
            run(argv("invert --n 16 --block-size 4 --algo lu")),
            0
        );
    }

    #[test]
    fn invert_rejects_bad_flags() {
        assert_eq!(run(argv("invert --n 33 --block-size 8")), 1); // non-pow2
        assert_eq!(run(argv("invert --bogus-flag")), 1);
    }

    #[test]
    fn cost_renders() {
        assert_eq!(run(argv("cost --n 1024 --b 8 --cores 30")), 0);
    }

    #[test]
    fn gen_writes_store() {
        let dir = std::env::temp_dir().join(format!("spin_cli_gen_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cmd = format!("gen --n 16 --block-size 4 --out {}", dir.display());
        assert_eq!(run(argv(&cmd)), 0);
        let meta = crate::ser::bin::read_block_store_meta(&dir).unwrap();
        assert_eq!(meta.nblocks, 4);
    }

    #[test]
    fn info_runs() {
        assert_eq!(run(argv("info")), 0);
    }
}
