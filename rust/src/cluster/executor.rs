//! Worker pool: real execution of partition tasks with per-task timing.
//!
//! `worker_threads = 1` (the default on this single-core testbed) runs
//! tasks inline, giving contention-free duration measurements for the
//! virtual-time model. Larger pools use scoped threads pulling from an
//! atomic work queue — useful on multi-core hosts; each thread can hold
//! thread-local state (the XLA backend keeps its PJRT engine there,
//! since PJRT handles are `!Send`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// Fixed-size pool; tasks are one closure application per input item.
pub struct WorkerPool {
    threads: usize,
}

impl WorkerPool {
    pub fn new(threads: usize) -> Self {
        WorkerPool {
            threads: threads.max(1),
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Apply `f` to every task input, returning outputs (input order
    /// preserved) and measured per-task durations in seconds.
    //
    // unwrap/expect here are invariant-backed: the atomic index hands each
    // slot to exactly one thread, nothing panics while a slot lock is held
    // (the guard drops before `f` runs), and a panic inside `f` re-raises
    // out of `thread::scope` before the joins below ever read the slots.
    #[allow(clippy::unwrap_used, clippy::expect_used)]
    pub fn run_tasks<T: Send, U: Send>(
        &self,
        tasks: Vec<T>,
        f: impl Fn(T) -> U + Sync,
    ) -> (Vec<U>, Vec<f64>) {
        let n = tasks.len();
        if n == 0 {
            return (Vec::new(), Vec::new());
        }
        if self.threads == 1 || n == 1 {
            // Inline fast path — no thread overhead, cleanest timings.
            let mut outputs = Vec::with_capacity(n);
            let mut durations = Vec::with_capacity(n);
            for t in tasks {
                let t0 = Instant::now();
                outputs.push(f(t));
                durations.push(t0.elapsed().as_secs_f64());
            }
            return (outputs, durations);
        }

        // Multi-threaded path: atomic work index over boxed slots.
        let inputs: Vec<Mutex<Option<T>>> = tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let slots: Vec<Mutex<Option<(U, f64)>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..self.threads.min(n) {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let input = inputs[i].lock().unwrap().take().expect("task taken twice");
                    let t0 = Instant::now();
                    let out = f(input);
                    let dt = t0.elapsed().as_secs_f64();
                    *slots[i].lock().unwrap() = Some((out, dt));
                });
            }
        });
        let mut outputs = Vec::with_capacity(n);
        let mut durations = Vec::with_capacity(n);
        for slot in slots {
            let (out, dt) = slot.into_inner().unwrap().expect("task not executed");
            outputs.push(out);
            durations.push(dt);
        }
        (outputs, durations)
    }

    /// Like [`run_tasks`](Self::run_tasks) but for fallible tasks with
    /// Spark-style retry: each failing task is re-run up to `max_retries`
    /// times before the whole stage fails (fault-injection tests use this).
    pub fn run_tasks_with_retry<T: Send + Clone, U: Send, E: Send + std::fmt::Display>(
        &self,
        tasks: Vec<T>,
        max_retries: usize,
        f: impl Fn(&T) -> Result<U, E> + Sync,
    ) -> Result<(Vec<U>, Vec<f64>), E> {
        let wrapped = self.run_tasks(tasks, |t: T| {
            let mut attempt = 0;
            loop {
                match f(&t) {
                    Ok(u) => return Ok(u),
                    Err(e) if attempt < max_retries => {
                        log::warn!("task failed (attempt {attempt}): {e}; retrying");
                        attempt += 1;
                    }
                    Err(e) => return Err(e),
                }
            }
        });
        let (outputs, durations) = wrapped;
        let mut oks = Vec::with_capacity(outputs.len());
        for o in outputs {
            oks.push(o?);
        }
        Ok((oks, durations))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn inline_pool_preserves_order() {
        let pool = WorkerPool::new(1);
        let (out, dur) = pool.run_tasks(vec![1, 2, 3], |x| x * 10);
        assert_eq!(out, vec![10, 20, 30]);
        assert_eq!(dur.len(), 3);
        assert!(dur.iter().all(|&d| d >= 0.0));
    }

    #[test]
    fn threaded_pool_preserves_order() {
        let pool = WorkerPool::new(4);
        let inputs: Vec<usize> = (0..100).collect();
        let (out, dur) = pool.run_tasks(inputs, |x| x * x);
        assert_eq!(out, (0..100).map(|x| x * x).collect::<Vec<_>>());
        assert_eq!(dur.len(), 100);
    }

    #[test]
    fn empty_task_list() {
        let pool = WorkerPool::new(2);
        let (out, dur) = pool.run_tasks(Vec::<i32>::new(), |x| x);
        assert!(out.is_empty() && dur.is_empty());
    }

    #[test]
    fn retry_recovers_transient_failure() {
        let pool = WorkerPool::new(1);
        let failures = AtomicUsize::new(0);
        let result = pool.run_tasks_with_retry(vec![1, 2, 3], 2, |&x| {
            if x == 2 && failures.fetch_add(1, Ordering::SeqCst) == 0 {
                Err("transient executor loss".to_string())
            } else {
                Ok(x * 10)
            }
        });
        let (out, _) = result.unwrap();
        assert_eq!(out, vec![10, 20, 30]);
        // the x==2 task touched the counter twice: one failure, one retry
        assert_eq!(failures.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn retry_exhaustion_fails_stage() {
        let pool = WorkerPool::new(1);
        let r = pool.run_tasks_with_retry(vec![1], 2, |_| -> Result<i32, String> {
            Err("permanent failure".into())
        });
        assert!(r.is_err());
    }
}
