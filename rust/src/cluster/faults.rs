//! Deterministic fault injection for the simulated cluster.
//!
//! The chaos harness has to satisfy two constraints at once: runs must
//! be **reproducible** (a seed fully determines which task attempts
//! fail, straggle, or panic) and injected faults must never change the
//! *numeric result* of a job (the acceptance bar is bit-identical
//! inverses vs a clean run). Both fall out of the same design: the user
//! task closure executes exactly once for real, and the fault stream is
//! applied to the **virtual-time accounting** afterwards — a failed
//! attempt charges its wasted compute plus an exponential backoff into
//! the task's effective duration, a straggling attempt inflates it, and
//! a speculative copy caps it. This mirrors how Spark's retry/
//! speculation machinery changes *when* a stage finishes, never *what*
//! it computes (a deterministic task recomputes the same partition).
//!
//! The decision stream is a splitmix64-style hash of
//! `(fault_seed, stage_seq, partition, attempt)`, so every stage/
//! partition/attempt triple draws an independent, reproducible verdict.
//! `stage_seq` is a monotonic per-cluster counter: with a single job in
//! flight the stream is exactly reproducible; with concurrent jobs the
//! interleaving perturbs which stage draws which verdicts (counters may
//! shift between runs) but determinism of *results* is unconditional.
//!
//! Straggler speculation is intentionally timing-coupled: an attempt
//! straggles by a seed-derived inflation factor, and a speculative copy
//! launches once the inflated duration exceeds
//! `speculation_multiplier × median(stage task durations)` — the copy
//! starts at the threshold and runs for the task's clean duration, and
//! the stage takes whichever finishes first (`speculative_won` counts
//! the copy winning). Because the threshold compares *measured*
//! durations, borderline speculation counts can wiggle across runs —
//! stragglers are a timing phenomenon; retry counters, by contrast,
//! depend only on the seed and the stage order.
//!
//! When `fault_seed` is unset the cluster holds no [`FaultPlan`] at all
//! and every stage runs the exact pre-existing path (a single `Option`
//! check) — the "provably inert when disabled" acceptance criterion.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::{ClusterConfig, FaultKinds};

use super::metrics::ResilienceTotals;

/// Salts separating the independent per-attempt draws.
const SALT_DECIDE: u64 = 0x5049_4E5F_4641_494C; // "SPIN_FAIL"
const SALT_KIND: u64 = 0x5049_4E5F_4B49_4E44;
const SALT_FRACTION: u64 = 0x5049_4E5F_4652_4143;
const SALT_STRAGGLE: u64 = 0x5049_4E5F_5354_5247;

/// What the fault stream decided for one attempt of one task.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FaultKind {
    /// The attempt died partway through (charges a seed-derived fraction
    /// of the task's compute, then a retry).
    TaskPanic,
    /// The attempt ran to completion and then failed (charges the full
    /// task compute, then a retry).
    TaskError,
    /// The attempt succeeds but runs slow (seed-derived inflation,
    /// subject to speculation).
    Straggle,
}

/// Effective virtual-time accounting for one stage under injected
/// faults, plus the recovery counters the stage earned.
pub struct StageFaultOutcome {
    /// Per-task effective durations (failed-attempt charges + backoffs +
    /// final attempt) to feed the list scheduler in place of the clean
    /// measured durations.
    pub durations: Vec<f64>,
    /// Per-task extra *real* seconds the final successful attempt
    /// straggled beyond its clean duration (0 for clean/failed tasks).
    /// Under the exec pool (`exec_threads > 1`) the cluster runs these
    /// as an actual parallel sleep wave, so speculation wins real
    /// wall-clock time; on the sequential path they stay virtual-only.
    pub sleeps: Vec<f64>,
    /// Recovery counters earned by this stage.
    pub delta: ResilienceTotals,
    /// First partition whose retry budget was exhausted, if any — the
    /// stage runner turns this into a job-fatal panic naming the stage
    /// and partition.
    pub exhausted: Option<usize>,
}

/// Seed-derived fault schedule owned by a [`super::Cluster`] — present
/// only when `ClusterConfig::fault_seed` is set.
pub struct FaultPlan {
    seed: u64,
    rate: f64,
    kinds: FaultKinds,
    task_retries: usize,
    backoff_secs: f64,
    speculation_multiplier: f64,
    /// Monotonic stage counter — each stage draws from its own slice of
    /// the decision stream.
    stage_seq: AtomicU64,
}

/// splitmix64 finalizer — a full-avalanche mix for the decision stream.
fn mix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

/// Map a hash to a uniform draw in [0, 1).
fn unit(x: u64) -> f64 {
    (x >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

impl FaultPlan {
    /// Build the plan from the cluster config; `None` (no plan, zero
    /// overhead) unless `fault_seed` is set.
    pub fn from_config(cfg: &ClusterConfig) -> Option<FaultPlan> {
        cfg.fault_seed.map(|seed| FaultPlan {
            seed,
            rate: cfg.fault_rate,
            kinds: cfg.fault_kinds,
            task_retries: cfg.task_retries,
            backoff_secs: cfg.retry_backoff_secs,
            speculation_multiplier: cfg.speculation_multiplier,
            stage_seq: AtomicU64::new(0),
        })
    }

    /// One independent draw for `(stage, partition, attempt, salt)`.
    fn draw(&self, stage: u64, partition: u64, attempt: u64, salt: u64) -> u64 {
        let mut h = self.seed;
        for w in [stage, partition, attempt, salt] {
            h = mix(h ^ w);
        }
        h
    }

    /// The verdict for one attempt: `None` = clean success, otherwise a
    /// fault kind chosen uniformly among the configured kinds.
    fn fault_for(&self, stage: u64, partition: u64, attempt: u64) -> Option<FaultKind> {
        if unit(self.draw(stage, partition, attempt, SALT_DECIDE)) >= self.rate {
            return None;
        }
        let mut active = [FaultKind::TaskPanic; 3];
        let mut n = 0;
        if self.kinds.task_panic {
            active[n] = FaultKind::TaskPanic;
            n += 1;
        }
        if self.kinds.task_error {
            active[n] = FaultKind::TaskError;
            n += 1;
        }
        if self.kinds.straggle {
            active[n] = FaultKind::Straggle;
            n += 1;
        }
        if n == 0 {
            return None; // validated away in ClusterConfig, but stay safe
        }
        let pick = self.draw(stage, partition, attempt, SALT_KIND) as usize % n;
        Some(active[pick])
    }

    /// Apply the next stage's slice of the fault stream (implicit
    /// monotonic stage id). Prefer [`FaultPlan::apply_at`] from stage
    /// runners that already allocate explicit stage ids — implicit
    /// numbering is only reproducible when call order is.
    pub fn apply(&self, measured: &[f64]) -> StageFaultOutcome {
        let stage = self.stage_seq.fetch_add(1, Ordering::Relaxed);
        self.apply_at(stage, measured)
    }

    /// Apply stage `stage`'s slice of the fault stream to the measured
    /// task durations: replay the retry loop each task would have gone
    /// through, charging wasted attempts, backoffs, straggle inflation
    /// and speculation caps into the effective durations.
    ///
    /// Taking the stage id explicitly makes the fault stream
    /// **executor-independent**: the inline `threads == 1` fast path and
    /// the work-stealing pool feed the same `(stage, partition, attempt)`
    /// triples regardless of completion order, so a chaos run replays
    /// identically at any `exec_threads`.
    pub fn apply_at(&self, stage: u64, measured: &[f64]) -> StageFaultOutcome {
        let mut sorted: Vec<f64> = measured.to_vec();
        sorted.sort_by(|a, b| a.total_cmp(b));
        let median = if sorted.is_empty() {
            0.0
        } else {
            sorted[sorted.len() / 2]
        };
        let threshold = self.speculation_multiplier * median;
        let speculate = self.speculation_multiplier > 0.0 && median > 0.0;

        let mut delta = ResilienceTotals::default();
        let mut exhausted = None;
        let mut durations = Vec::with_capacity(measured.len());
        let mut sleeps = vec![0.0; measured.len()];
        for (partition, &clean) in measured.iter().enumerate() {
            let mut effective = 0.0;
            for attempt in 0..=self.task_retries as u64 {
                match self.fault_for(stage, partition as u64, attempt) {
                    Some(FaultKind::TaskError) => effective += clean,
                    Some(FaultKind::TaskPanic) => {
                        let frac =
                            unit(self.draw(stage, partition as u64, attempt, SALT_FRACTION));
                        effective += clean * frac;
                    }
                    verdict => {
                        // Success — clean, or straggling (slow success).
                        let mut dur = clean;
                        if verdict == Some(FaultKind::Straggle) {
                            let factor = 2.0
                                + 6.0
                                    * unit(self.draw(
                                        stage,
                                        partition as u64,
                                        attempt,
                                        SALT_STRAGGLE,
                                    ));
                            let inflated = clean * factor;
                            dur = inflated;
                            if speculate && inflated > threshold {
                                delta.speculative_launched += 1;
                                // The copy launches once the original
                                // crosses the threshold and then runs the
                                // task cleanly; take the first finisher.
                                let copy_finish = threshold + clean;
                                if copy_finish < inflated {
                                    delta.speculative_won += 1;
                                    dur = copy_finish;
                                }
                            }
                        }
                        // The real-sleep wave replays only the winner's
                        // slowdown: a won speculation caps the sleep at
                        // the copy's finish, exactly the wall-clock win.
                        sleeps[partition] = (dur - clean).max(0.0);
                        effective += dur;
                        break;
                    }
                }
                // The attempt failed. Either retry (with exponential
                // backoff) or report the budget spent.
                if attempt as usize >= self.task_retries {
                    delta.retry_exhausted += 1;
                    exhausted.get_or_insert(partition);
                    break;
                }
                delta.retries += 1;
                effective += self.backoff_secs * (1u64 << attempt.min(20)) as f64;
            }
            durations.push(effective);
        }
        StageFaultOutcome {
            durations,
            sleeps,
            delta,
            exhausted,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn plan(seed: u64, rate: f64, kinds: FaultKinds) -> FaultPlan {
        let mut cfg = ClusterConfig::local(2);
        cfg.fault_seed = Some(seed);
        cfg.fault_rate = rate;
        cfg.fault_kinds = kinds;
        cfg.task_retries = 3;
        cfg.retry_backoff_secs = 0.05;
        cfg.speculation_multiplier = 3.0;
        FaultPlan::from_config(&cfg).expect("seed set")
    }

    #[test]
    fn disabled_config_builds_no_plan() {
        let cfg = ClusterConfig::local(2);
        assert!(cfg.fault_seed.is_none());
        assert!(FaultPlan::from_config(&cfg).is_none());
    }

    #[test]
    fn zero_rate_is_identity() {
        let p = plan(42, 0.0, FaultKinds::all());
        let measured = vec![0.5, 1.0, 0.25, 0.75];
        let out = p.apply(&measured);
        assert_eq!(out.durations, measured, "bitwise-identical durations");
        assert!(!out.delta.any());
        assert!(out.exhausted.is_none());
    }

    #[test]
    fn same_seed_same_outcome() {
        let measured: Vec<f64> = (0..64).map(|i| 0.5 + (i % 7) as f64 * 0.1).collect();
        let a = plan(7, 0.2, FaultKinds::all()).apply(&measured);
        let b = plan(7, 0.2, FaultKinds::all()).apply(&measured);
        assert_eq!(a.durations, b.durations);
        assert_eq!(a.delta, b.delta);
        assert_eq!(a.exhausted, b.exhausted);
        // A different seed draws a different schedule.
        let c = plan(8, 0.2, FaultKinds::all()).apply(&measured);
        assert_ne!(a.durations, c.durations);
    }

    #[test]
    fn stage_counter_advances_the_stream() {
        let p = plan(7, 0.3, FaultKinds::all());
        let measured = vec![0.5; 32];
        let first = p.apply(&measured);
        let second = p.apply(&measured);
        assert_ne!(
            first.durations, second.durations,
            "each stage draws its own slice of the stream"
        );
    }

    #[test]
    fn fail_kinds_charge_retries_and_exhaust_at_rate_one() {
        let kinds = FaultKinds {
            task_panic: true,
            task_error: true,
            straggle: false,
        };
        let p = plan(3, 1.0, kinds);
        let measured = vec![1.0, 1.0];
        let out = p.apply(&measured);
        // Every attempt fails: budget of 3 retries spent on both tasks.
        assert_eq!(out.delta.retries, 6);
        assert_eq!(out.delta.retry_exhausted, 2);
        assert_eq!(out.exhausted, Some(0));
        // Wasted attempts + backoffs all charge time.
        assert!(out.durations.iter().all(|&d| d > 0.0));
    }

    #[test]
    fn moderate_rate_retries_then_succeeds() {
        let kinds = FaultKinds {
            task_panic: true,
            task_error: true,
            straggle: false,
        };
        let p = plan(12, 0.3, kinds);
        let measured = vec![1.0; 64];
        let out = p.apply(&measured);
        assert!(out.delta.retries > 0, "some attempts fail at rate 0.3");
        assert!(out.exhausted.is_none(), "0.3^4 per task is vanishing");
        // A retried task charges at least its failed attempt's backoff.
        assert!(out
            .durations
            .iter()
            .zip(&measured)
            .all(|(eff, clean)| eff >= clean));
    }

    #[test]
    fn stragglers_launch_and_win_speculation() {
        let kinds = FaultKinds {
            task_panic: false,
            task_error: false,
            straggle: true,
        };
        let p = plan(5, 1.0, kinds);
        let measured = vec![1.0; 32];
        let out = p.apply(&measured);
        assert!(out.delta.retries == 0, "straggle is a slow success");
        assert!(out.delta.speculative_launched > 0);
        assert!(out.delta.speculative_won > 0);
        assert!(out.delta.speculative_won <= out.delta.speculative_launched);
        // A won speculation caps at threshold + clean = 3·median + clean.
        for d in &out.durations {
            assert!(*d <= 3.0 * 1.0 + 1.0 + 1e-12);
            assert!(*d >= 1.0, "straggle never makes a task faster");
        }
    }

    #[test]
    fn empty_stage_is_fine() {
        let p = plan(1, 0.5, FaultKinds::all());
        let out = p.apply(&[]);
        assert!(out.durations.is_empty());
        assert!(out.sleeps.is_empty());
        assert!(!out.delta.any());
    }

    #[test]
    fn explicit_stage_ids_match_the_implicit_sequence() {
        let measured: Vec<f64> = (0..32).map(|i| 0.25 + (i % 5) as f64 * 0.1).collect();
        let implicit = plan(9, 0.4, FaultKinds::all());
        let explicit = plan(9, 0.4, FaultKinds::all());
        for stage in 0..4u64 {
            let a = implicit.apply(&measured);
            let b = explicit.apply_at(stage, &measured);
            assert_eq!(a.durations, b.durations, "stage {stage}");
            assert_eq!(a.sleeps, b.sleeps, "stage {stage}");
            assert_eq!(a.delta, b.delta, "stage {stage}");
        }
    }

    #[test]
    fn sleeps_carry_only_the_straggle_excess() {
        let kinds = FaultKinds {
            task_panic: false,
            task_error: false,
            straggle: true,
        };
        let p = plan(5, 1.0, kinds);
        let measured = vec![1.0; 32];
        let out = p.apply(&measured);
        assert_eq!(out.sleeps.len(), measured.len());
        for (sleep, (eff, clean)) in out.sleeps.iter().zip(out.durations.iter().zip(&measured)) {
            // Final attempt is the only charge at rate 1 straggle-only,
            // so the sleep is exactly the effective excess over clean.
            assert!((sleep - (eff - clean)).abs() < 1e-12, "{sleep} vs {eff}");
            assert!(*sleep > 0.0, "every task straggles at rate 1");
        }
        // Clean runs sleep nowhere.
        let clean = plan(5, 0.0, kinds).apply(&measured);
        assert!(clean.sleeps.iter().all(|&s| s == 0.0));
    }
}
