//! Per-method metrics registry — regenerates the paper's Table 3
//! ("Experimental results of wall clock execution time of different
//! methods in SPIN").
//!
//! ## Scopes (multi-job attribution)
//!
//! One cluster now serves several concurrent jobs (the `service` layer),
//! so every recorded stage carries a **scope** — an opaque `u64` job tag
//! read from a thread-local at record time ([`Metrics::enter_scope`]).
//! Scope 0 is the ambient default; single-job flows never notice it.
//! Scoped accessors ([`Metrics::totals_for_scope`],
//! [`Metrics::snapshot_scope`]) answer "what did *this* job pay", which
//! is what keeps per-plan-node windows honest when two jobs interleave
//! stages on the same cluster: a delta of another job's stages can no
//! longer leak into this job's `PlanNodeReport`.
//!
//! ## Retention (long-lived services)
//!
//! Records are stored **per scope**, so a finished job's history is
//! droppable in O(1) bookkeeping: [`Metrics::release_scope`] removes the
//! scope's stage records, plan-node reports, index and totals (the
//! service calls it after a job reaches a terminal phase — take the
//! job's [`Metrics::snapshot_scope`] *before* releasing). Per-method
//! aggregates survive releases — they are bounded by the method-name set
//! and keep the Table-3 view exact over the cluster's lifetime. An
//! optional windowed history (`ClusterConfig::metrics_history`, CLI
//! `--set metrics_history=N`) additionally caps retained stage records
//! across all live scopes, oldest-first. The retention counters
//! ([`MetricsSnapshot::retained_stage_records`],
//! [`MetricsSnapshot::released_stage_records`],
//! [`MetricsSnapshot::released_scopes`]) let a soak test assert
//! steady-state memory.

use std::cell::Cell;
use std::collections::{BTreeMap, VecDeque};
use std::sync::Mutex;

use crate::ser::json::Json;
use crate::util::{fmt, plock};

thread_local! {
    /// Job tag stamped onto everything the current thread records.
    static CURRENT_SCOPE: Cell<u64> = const { Cell::new(0) };
}

/// RAII guard restoring the previous metrics scope on drop.
pub struct MetricsScope {
    prev: u64,
}

impl Drop for MetricsScope {
    fn drop(&mut self) {
        CURRENT_SCOPE.with(|s| s.set(self.prev));
    }
}

/// One executed stage (narrow pass or shuffle exchange).
#[derive(Debug, Clone, Default)]
pub struct StageReport {
    /// Method attribution (breakMat, xy, multiply, subtract, scalarMul,
    /// arrange, leafNode, …).
    pub method: String,
    /// Tasks in the stage (0 for pure shuffle exchanges).
    pub tasks: usize,
    /// True for shuffle exchanges (the wide half of a wide op), false for
    /// narrow stages — drives the per-method `shuffle_stages` count.
    pub exchange: bool,
    /// Total CPU seconds across tasks (measured, real).
    pub compute_secs: f64,
    /// Virtual wall-clock seconds after list scheduling onto slots.
    pub makespan_secs: f64,
    /// Bytes that crossed a simulated executor boundary.
    pub shuffle_bytes: u64,
    /// Bytes relocated to a different partition (upper bound on
    /// cross-executor traffic at any executor count) — used by replay.
    pub shuffle_total_bytes: u64,
    /// Simulated interconnect seconds for those bytes.
    pub shuffle_secs: f64,
    /// Measured per-task durations (empty for pure shuffle exchanges) —
    /// lets experiments replay the schedule on a different topology
    /// without re-running the compute (noise-free scaling curves).
    pub task_durations: Vec<f64>,
    /// Real wall-clock nanoseconds the stage took on this host, from
    /// submission to last task completion (the measured dimension, as
    /// opposed to the virtual `makespan_secs`).
    pub wall_ns: u64,
    /// Total real nanoseconds tasks waited queued on the exec pool
    /// (0 on the sequential path).
    pub queue_ns: u64,
    /// Total real nanoseconds tasks spent executing.
    pub run_ns: u64,
    /// Tasks that ran via work stealing rather than on the worker they
    /// were queued on.
    pub steals: usize,
}

/// Cheap aggregate counters (no stage-vector clone) — the plan executor
/// brackets each plan node's lowering with two of these to attribute the
/// delta to that node.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MetricsTotals {
    /// Stages recorded so far (narrow + exchange).
    pub stages: usize,
    /// Shuffle exchanges recorded so far.
    pub shuffle_stages: usize,
    /// Cross-executor shuffle bytes so far.
    pub shuffle_bytes: u64,
    /// Driver collect round-trips so far.
    pub driver_collects: usize,
}

/// Recovery counters from the fault-injection / retry / speculation /
/// checkpoint layer. Kept separate from [`MetricsTotals`] so plan-node
/// cost windows (stages, shuffles, collects) stay exactly what they were
/// before the resilience subsystem existed — retries change *time*, not
/// the logical stage structure the windows attribute.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ResilienceTotals {
    /// Failed task attempts that were retried (one per extra attempt).
    pub retries: usize,
    /// Tasks that spent their whole retry budget (job-fatal).
    pub retry_exhausted: usize,
    /// Speculative copies launched for straggling tasks.
    pub speculative_launched: usize,
    /// Speculative copies that finished before the straggling original.
    pub speculative_won: usize,
    /// Recursion-level checkpoints persisted to the block store.
    pub checkpoints_written: usize,
    /// Recursion levels restored from a checkpoint instead of computed.
    pub checkpoints_restored: usize,
}

impl ResilienceTotals {
    /// Fold `other` into `self`.
    pub fn add(&mut self, other: &ResilienceTotals) {
        self.retries += other.retries;
        self.retry_exhausted += other.retry_exhausted;
        self.speculative_launched += other.speculative_launched;
        self.speculative_won += other.speculative_won;
        self.checkpoints_written += other.checkpoints_written;
        self.checkpoints_restored += other.checkpoints_restored;
    }

    /// True when any counter is nonzero — the inertness assertion for
    /// runs with fault injection disabled.
    pub fn any(&self) -> bool {
        *self != ResilienceTotals::default()
    }
}

/// One iterative-solver run's convergence record (`newton`): the residual
/// trajectory the driver measured, iteration by iteration. Recorded under
/// the running job's scope, so per-job metrics and `/v1/metrics` report
/// exactly the iterations *that job* paid for.
#[derive(Debug, Clone, PartialEq)]
pub struct ConvergenceReport {
    /// Scheme that iterated (`"newton"`).
    pub algo: String,
    /// Iterations executed (= `residuals.len()`).
    pub iterations: usize,
    /// Whether the run reached `tolerance` within `max_iters` (false =
    /// the SLA bound cut it off; the best iterate was still returned).
    pub converged: bool,
    /// The tolerance the run stopped against.
    pub tolerance: f64,
    /// Residual after the last iteration (∞-norm of `I − A·Xₖ`).
    pub final_residual: f64,
    /// Residual after each iteration, in order.
    pub residuals: Vec<f64>,
}

/// O(1) aggregate convergence counters — kept like [`ResilienceTotals`]:
/// registry-lifetime totals survive scope releases, per-scope copies
/// answer "what did this job iterate".
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ConvergenceTotals {
    /// Iterative runs recorded.
    pub runs: usize,
    /// Iterations across all runs.
    pub iterations: usize,
    /// Runs that reached tolerance within their iteration budget.
    pub converged_runs: usize,
}

impl ConvergenceTotals {
    /// Fold `other` into `self`.
    pub fn add(&mut self, other: &ConvergenceTotals) {
        self.runs += other.runs;
        self.iterations += other.iterations;
        self.converged_runs += other.converged_runs;
    }

    /// True when any counter is nonzero.
    pub fn any(&self) -> bool {
        *self != ConvergenceTotals::default()
    }
}

/// What one logical plan node actually paid when it was lowered — stamped
/// by [`crate::plan::PlanExec`] so `explain`'s predictions are checkable
/// against measured behaviour.
#[derive(Debug, Clone)]
pub struct PlanNodeReport {
    /// Plan-node label (`%17`).
    pub node: String,
    /// Operator name (`multiply`, `multiply_sub`, `quadrant`, …).
    pub op: String,
    /// Stages (narrow + exchange) recorded while lowering this node. For
    /// `invert` nodes this includes the whole recursive subcomputation.
    pub stages: usize,
    pub shuffle_stages: usize,
    pub shuffle_bytes: u64,
    pub driver_collects: usize,
    /// The optimizer marked this node as a CSE cache point.
    pub cse_cached: bool,
}

/// Accumulated per-method totals.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MethodStats {
    pub calls: usize,
    pub tasks: usize,
    pub compute_secs: f64,
    /// Virtual seconds (makespan + shuffle) — the paper's per-method
    /// "wall clock execution time".
    pub virtual_secs: f64,
    pub shuffle_bytes: u64,
    /// Shuffle exchanges this method paid for (0 when every stage ran
    /// narrow) — the per-op "wide vs narrow" delta the partitioner-aware
    /// dataflow is measured by.
    pub shuffle_stages: usize,
    /// Real wall-clock seconds summed over this method's stages — the
    /// measured trajectory dimension armed by the exec pool (still
    /// populated, from coarse stage timing, on the sequential path).
    pub wall_secs: f64,
    /// Work-stealing migrations across this method's stages.
    pub steals: usize,
}

/// Thread-safe metrics registry owned by a [`crate::cluster::Cluster`].
pub struct Metrics {
    inner: Mutex<MetricsInner>,
}

/// Every record one scope (job) produced — the unit of release.
#[derive(Default)]
struct ScopeRecords {
    /// `(seq, report)` in record order; `seq` is registry-global so the
    /// cross-scope snapshot can interleave scopes back into record order.
    stages: VecDeque<(u64, StageReport)>,
    /// Per-plan-node lowering reports (lazy-plan executions only) —
    /// windowed by the same history cap as the stage records.
    plan_nodes: VecDeque<(u64, PlanNodeReport)>,
    /// Running aggregate counters (O(1) scoped windows) — these survive
    /// the history cap (only full-record payloads are windowed).
    totals: MetricsTotals,
    /// Recovery counters attributed to this scope (O(1), never windowed).
    resilience: ResilienceTotals,
    /// Iterative-run convergence records attributed to this scope (one
    /// per `newton` run; bounded by the scope's run count, released with
    /// the scope).
    convergence: Vec<ConvergenceReport>,
}

#[derive(Default)]
struct MetricsInner {
    methods: BTreeMap<String, MethodStats>,
    /// Per-scope record storage; scope 0 is the ambient (non-job) scope.
    scopes: BTreeMap<u64, ScopeRecords>,
    /// Global record sequence (snapshot ordering across scopes).
    seq: u64,
    /// Stage records recorded over the registry's lifetime (monotonic).
    total_stages: usize,
    /// Windowed-history cap on retained stage records (0 = unlimited).
    history: usize,
    /// Stage records currently held across all scopes.
    retained_stages: usize,
    /// Plan-node reports currently held across all scopes (windowed by
    /// the same `history` cap; not separately surfaced).
    retained_plan_nodes: usize,
    /// Stage records dropped by `release_scope` or the history window.
    released_stages: usize,
    /// Scopes released so far.
    released_scopes: usize,
    /// Driver `collect` round-trips (materialize + re-parallelize). The
    /// partitioner-aware op pipeline records zero of these.
    driver_collects: usize,
    /// Plan-node values dropped by the LRU byte-budget evictor.
    cache_evictions: usize,
    /// Bytes those evictions released.
    cache_evicted_bytes: u64,
    /// Bytes currently pinned by `persist()` (gauge, set by the session).
    pinned_bytes: u64,
    /// Registry-lifetime recovery counters (survive scope releases).
    resilience: ResilienceTotals,
    /// Registry-lifetime convergence counters (survive scope releases).
    convergence: ConvergenceTotals,
}

/// Drop oldest records (across scopes, by global sequence) until the
/// retained counts fit the configured window. Stage records and
/// plan-node reports are windowed independently under the same cap, so
/// neither record class can grow without bound in a scope that is never
/// released (e.g. a long-lived session's ambient scope 0).
fn enforce_history(inner: &mut MetricsInner) {
    if inner.history == 0 {
        return;
    }
    while inner.retained_stages > inner.history {
        let oldest = inner
            .scopes
            .iter()
            .filter_map(|(&scope, rec)| rec.stages.front().map(|(seq, _)| (*seq, scope)))
            .min();
        let Some((_, scope)) = oldest else { break };
        let Some(rec) = inner.scopes.get_mut(&scope) else { break };
        rec.stages.pop_front();
        inner.retained_stages -= 1;
        inner.released_stages += 1;
    }
    while inner.retained_plan_nodes > inner.history {
        let oldest = inner
            .scopes
            .iter()
            .filter_map(|(&scope, rec)| rec.plan_nodes.front().map(|(seq, _)| (*seq, scope)))
            .min();
        let Some((_, scope)) = oldest else { break };
        let Some(rec) = inner.scopes.get_mut(&scope) else { break };
        rec.plan_nodes.pop_front();
        inner.retained_plan_nodes -= 1;
    }
}

/// Fold one stage report into a per-method stats map (shared by the global
/// aggregation and the scoped-snapshot rebuild).
fn accumulate(methods: &mut BTreeMap<String, MethodStats>, report: &StageReport) {
    let stats = methods.entry(report.method.clone()).or_default();
    stats.calls += 1;
    stats.tasks += report.tasks;
    stats.compute_secs += report.compute_secs;
    stats.virtual_secs += report.makespan_secs + report.shuffle_secs;
    stats.shuffle_bytes += report.shuffle_bytes;
    stats.wall_secs += report.wall_ns as f64 * 1e-9;
    stats.steals += report.steals;
    if report.exchange {
        stats.shuffle_stages += 1;
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics::with_history(0)
    }

    /// Registry with a windowed stage history: at most `history` stage
    /// records stay resident (oldest dropped first, across scopes);
    /// `0` retains everything until `release_scope`/`reset`.
    pub fn with_history(history: usize) -> Self {
        Metrics {
            inner: Mutex::new(MetricsInner {
                history,
                ..MetricsInner::default()
            }),
        }
    }

    /// Tag everything the current thread records with `scope` until the
    /// returned guard drops (scopes nest; the previous tag is restored).
    /// The service layer opens one scope per job.
    pub fn enter_scope(scope: u64) -> MetricsScope {
        let prev = CURRENT_SCOPE.with(|s| s.replace(scope));
        MetricsScope { prev }
    }

    /// The current thread's active scope tag (0 outside any job).
    pub fn current_scope() -> u64 {
        CURRENT_SCOPE.with(|s| s.get())
    }

    pub fn record_stage(&self, report: StageReport) {
        let scope = Metrics::current_scope();
        let mut inner = plock(&self.inner);
        accumulate(&mut inner.methods, &report);
        inner.seq += 1;
        inner.total_stages += 1;
        inner.retained_stages += 1;
        let seq = inner.seq;
        {
            let rec = inner.scopes.entry(scope).or_default();
            rec.totals.stages += 1;
            if report.exchange {
                rec.totals.shuffle_stages += 1;
            }
            rec.totals.shuffle_bytes += report.shuffle_bytes;
            rec.stages.push_back((seq, report));
        }
        enforce_history(&mut inner);
    }

    /// Count one driver materialize-and-reparallelize round-trip.
    pub fn record_driver_collect(&self) {
        let scope = Metrics::current_scope();
        let mut inner = plock(&self.inner);
        inner.driver_collects += 1;
        inner.scopes.entry(scope).or_default().totals.driver_collects += 1;
    }

    /// Attribute a lowered plan node's cost window.
    pub fn record_plan_node(&self, report: PlanNodeReport) {
        let scope = Metrics::current_scope();
        let mut inner = plock(&self.inner);
        inner.seq += 1;
        inner.retained_plan_nodes += 1;
        let seq = inner.seq;
        inner
            .scopes
            .entry(scope)
            .or_default()
            .plan_nodes
            .push_back((seq, report));
        enforce_history(&mut inner);
    }

    /// Fold one batch of recovery counters into the registry — both the
    /// registry-lifetime totals and the current thread's scope (so a
    /// job's retries/speculation/checkpoints are attributable per job).
    pub fn record_resilience(&self, delta: &ResilienceTotals) {
        if !delta.any() {
            return;
        }
        let scope = Metrics::current_scope();
        let mut inner = plock(&self.inner);
        inner.resilience.add(delta);
        inner.scopes.entry(scope).or_default().resilience.add(delta);
    }

    /// Registry-lifetime recovery counters (never go backwards; scope
    /// releases and the history window do not touch them).
    pub fn resilience_totals(&self) -> ResilienceTotals {
        plock(&self.inner).resilience
    }

    /// Record one iterative run's convergence trajectory — the full
    /// report under the current thread's scope, the O(1) counters both
    /// there and registry-lifetime (mirrors [`record_resilience`]).
    ///
    /// [`record_resilience`]: Self::record_resilience
    pub fn record_convergence(&self, report: ConvergenceReport) {
        let scope = Metrics::current_scope();
        let delta = ConvergenceTotals {
            runs: 1,
            iterations: report.iterations,
            converged_runs: report.converged as usize,
        };
        let mut inner = plock(&self.inner);
        inner.convergence.add(&delta);
        inner.scopes.entry(scope).or_default().convergence.push(report);
    }

    /// Registry-lifetime convergence counters (never go backwards).
    pub fn convergence_totals(&self) -> ConvergenceTotals {
        plock(&self.inner).convergence
    }

    /// Convergence reports recorded under one scope (a released scope
    /// reads as empty — take the job's snapshot before releasing).
    pub fn convergence_for_scope(&self, scope: u64) -> Vec<ConvergenceReport> {
        let inner = plock(&self.inner);
        inner
            .scopes
            .get(&scope)
            .map(|rec| rec.convergence.clone())
            .unwrap_or_default()
    }

    /// Recovery counters restricted to one scope (a released scope reads
    /// as zero — take the job's snapshot before releasing).
    pub fn resilience_for_scope(&self, scope: u64) -> ResilienceTotals {
        let inner = plock(&self.inner);
        inner
            .scopes
            .get(&scope)
            .map(|rec| rec.resilience)
            .unwrap_or_default()
    }

    /// Count plan-node values dropped by the LRU byte-budget evictor.
    pub fn record_cache_eviction(&self, count: usize, bytes: u64) {
        let mut inner = plock(&self.inner);
        inner.cache_evictions += count;
        inner.cache_evicted_bytes += bytes;
    }

    /// Gauge: bytes currently pinned by `persist()` against eviction
    /// (set by the session whenever a pin changes).
    pub fn set_pinned_bytes(&self, bytes: u64) {
        plock(&self.inner).pinned_bytes = bytes;
    }

    /// Drop everything one scope recorded — stage records, plan-node
    /// reports, index and totals — in one map removal. Called by the
    /// service once a job reaches a terminal phase (after taking the
    /// job's outcome snapshot), so a long-lived server holds steady-state
    /// memory no matter how many jobs it has finished. Per-method
    /// aggregates are deliberately kept (bounded by the method-name set).
    /// Returns how many stage records were released.
    pub fn release_scope(&self, scope: u64) -> usize {
        let mut inner = plock(&self.inner);
        match inner.scopes.remove(&scope) {
            Some(rec) => {
                let released = rec.stages.len();
                inner.retained_stages -= released;
                inner.retained_plan_nodes -= rec.plan_nodes.len();
                inner.released_stages += released;
                inner.released_scopes += 1;
                released
            }
            None => 0,
        }
    }

    /// Aggregate counters, cheap enough to call around every plan node.
    /// `stages` counts records over the registry's lifetime — releases
    /// and the history window never make the totals go backwards.
    pub fn totals(&self) -> MetricsTotals {
        let inner = plock(&self.inner);
        MetricsTotals {
            stages: inner.total_stages,
            shuffle_stages: inner.methods.values().map(|s| s.shuffle_stages).sum(),
            shuffle_bytes: inner.methods.values().map(|s| s.shuffle_bytes).sum(),
            driver_collects: inner.driver_collects,
        }
    }

    /// Aggregate counters restricted to one scope — the per-plan-node
    /// window bracket under concurrent jobs. For scope 0 with no other
    /// scope active this equals [`totals`](Self::totals). A released
    /// scope reads as empty.
    pub fn totals_for_scope(&self, scope: u64) -> MetricsTotals {
        let inner = plock(&self.inner);
        inner
            .scopes
            .get(&scope)
            .map(|rec| rec.totals)
            .unwrap_or_default()
    }

    pub fn reset(&self) {
        let mut inner = plock(&self.inner);
        let history = inner.history;
        *inner = MetricsInner {
            history,
            ..MetricsInner::default()
        };
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let inner = plock(&self.inner);
        // Interleave per-scope records back into global record order.
        let mut stages: Vec<(u64, StageReport)> = inner
            .scopes
            .values()
            .flat_map(|rec| rec.stages.iter().cloned())
            .collect();
        stages.sort_by_key(|(seq, _)| *seq);
        let mut plan_nodes: Vec<(u64, PlanNodeReport)> = inner
            .scopes
            .values()
            .flat_map(|rec| rec.plan_nodes.iter().cloned())
            .collect();
        plan_nodes.sort_by_key(|(seq, _)| *seq);
        let convergence: Vec<ConvergenceReport> = inner
            .scopes
            .values()
            .flat_map(|rec| rec.convergence.iter().cloned())
            .collect();
        MetricsSnapshot {
            methods: inner.methods.clone(),
            stages: stages.into_iter().map(|(_, s)| s).collect(),
            plan_nodes: plan_nodes.into_iter().map(|(_, p)| p).collect(),
            driver_collects: inner.driver_collects,
            cache_evictions: inner.cache_evictions,
            cache_evicted_bytes: inner.cache_evicted_bytes,
            pinned_bytes: inner.pinned_bytes,
            retained_stage_records: inner.retained_stages,
            released_stage_records: inner.released_stages,
            released_scopes: inner.released_scopes,
            resilience: inner.resilience,
            convergence,
            convergence_totals: inner.convergence,
        }
    }

    /// Snapshot of what ONE scope recorded: its stages, per-method stats
    /// rebuilt from those stages alone, its plan-node reports, and its
    /// driver collects — O(this scope's records), not O(total history),
    /// so per-job snapshots stay cheap on a long-running service.
    /// Cache-eviction/pin/retention counters are cluster-global (the
    /// evictor and the retention window serve every job) and reported as
    /// such. A released scope reads as empty. With a `metrics_history`
    /// window smaller than one scope's record count, the snapshot holds
    /// only the scope's most recent retained records (per-method stats
    /// are rebuilt from those) — size the window above the largest single
    /// job, or read [`totals_for_scope`](Self::totals_for_scope), whose
    /// counters are never windowed.
    pub fn snapshot_scope(&self, scope: u64) -> MetricsSnapshot {
        let inner = plock(&self.inner);
        let mut methods = BTreeMap::new();
        let mut stages = Vec::new();
        let mut plan_nodes = Vec::new();
        let mut driver_collects = 0;
        let mut resilience = ResilienceTotals::default();
        let mut convergence = Vec::new();
        if let Some(rec) = inner.scopes.get(&scope) {
            for (_, stage) in &rec.stages {
                accumulate(&mut methods, stage);
                stages.push(stage.clone());
            }
            plan_nodes = rec.plan_nodes.iter().map(|(_, p)| p.clone()).collect();
            driver_collects = rec.totals.driver_collects;
            resilience = rec.resilience;
            convergence = rec.convergence.clone();
        }
        let convergence_totals = convergence.iter().fold(
            ConvergenceTotals::default(),
            |mut acc, r| {
                acc.add(&ConvergenceTotals {
                    runs: 1,
                    iterations: r.iterations,
                    converged_runs: r.converged as usize,
                });
                acc
            },
        );
        MetricsSnapshot {
            methods,
            stages,
            plan_nodes,
            driver_collects,
            cache_evictions: inner.cache_evictions,
            cache_evicted_bytes: inner.cache_evicted_bytes,
            pinned_bytes: inner.pinned_bytes,
            retained_stage_records: inner.retained_stages,
            released_stage_records: inner.released_stages,
            released_scopes: inner.released_scopes,
            resilience,
            convergence,
            convergence_totals,
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Immutable view of the registry at a point in time.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    methods: BTreeMap<String, MethodStats>,
    stages: Vec<StageReport>,
    plan_nodes: Vec<PlanNodeReport>,
    driver_collects: usize,
    cache_evictions: usize,
    cache_evicted_bytes: u64,
    pinned_bytes: u64,
    retained_stage_records: usize,
    released_stage_records: usize,
    released_scopes: usize,
    resilience: ResilienceTotals,
    convergence: Vec<ConvergenceReport>,
    convergence_totals: ConvergenceTotals,
}

impl MetricsSnapshot {
    /// Iterative-run convergence records in this window — every run for
    /// [`Metrics::snapshot`], the scope's own for
    /// [`Metrics::snapshot_scope`]. Empty when no iterative scheme ran.
    pub fn convergence(&self) -> &[ConvergenceReport] {
        &self.convergence
    }

    /// Aggregate convergence counters for this window.
    pub fn convergence_totals(&self) -> &ConvergenceTotals {
        &self.convergence_totals
    }

    /// Recovery counters in this window — registry-lifetime for
    /// [`Metrics::snapshot`], the scope's own for
    /// [`Metrics::snapshot_scope`]. All-zero when fault injection is
    /// disabled and no checkpoints were written or restored.
    pub fn resilience(&self) -> &ResilienceTotals {
        &self.resilience
    }

    pub fn method(&self, name: &str) -> Option<&MethodStats> {
        self.methods.get(name)
    }

    /// Plan-node values dropped by the LRU byte-budget evictor in this
    /// window (cluster-global; see `ClusterConfig::cache_budget_bytes`).
    pub fn cache_evictions(&self) -> usize {
        self.cache_evictions
    }

    /// Bytes released by those evictions.
    pub fn cache_evicted_bytes(&self) -> u64 {
        self.cache_evicted_bytes
    }

    /// Bytes currently pinned by `persist()` against LRU eviction
    /// (cluster-global gauge; the evictor budgets only unpinned bytes).
    pub fn pinned_bytes(&self) -> u64 {
        self.pinned_bytes
    }

    /// Stage records currently resident across all scopes — the quantity
    /// a long-lived service's soak test bounds.
    pub fn retained_stage_records(&self) -> usize {
        self.retained_stage_records
    }

    /// Stage records dropped so far by `release_scope` or the
    /// `metrics_history` window.
    pub fn released_stage_records(&self) -> usize {
        self.released_stage_records
    }

    /// Scopes (completed jobs) whose records were released.
    pub fn released_scopes(&self) -> usize {
        self.released_scopes
    }

    /// Per-plan-node lowering reports recorded in this window (empty for
    /// purely eager `BlockMatrix` work).
    pub fn plan_nodes(&self) -> &[PlanNodeReport] {
        &self.plan_nodes
    }

    /// Driver `collect` round-trips recorded in this window.
    pub fn driver_collects(&self) -> usize {
        self.driver_collects
    }

    /// Shuffle exchanges recorded in this window (across all methods).
    pub fn total_shuffle_stages(&self) -> usize {
        self.methods.values().map(|s| s.shuffle_stages).sum()
    }

    pub fn methods(&self) -> impl Iterator<Item = (&String, &MethodStats)> {
        self.methods.iter()
    }

    pub fn stages(&self) -> &[StageReport] {
        &self.stages
    }

    /// Sum of per-method virtual seconds.
    pub fn total_virtual_secs(&self) -> f64 {
        self.methods.values().map(|s| s.virtual_secs).sum()
    }

    pub fn total_shuffle_bytes(&self) -> u64 {
        self.methods.values().map(|s| s.shuffle_bytes).sum()
    }

    /// Render the Table-3-shaped per-method breakdown.
    pub fn render_table(&self) -> String {
        let mut t = fmt::Table::new(vec![
            "method",
            "calls",
            "tasks",
            "compute",
            "virtual",
            "wall",
            "shuffled",
            "exchanges",
            "steals",
        ]);
        for (name, s) in &self.methods {
            t.row(vec![
                name.clone(),
                s.calls.to_string(),
                s.tasks.to_string(),
                fmt::secs(s.compute_secs),
                fmt::secs(s.virtual_secs),
                fmt::secs(s.wall_secs),
                fmt::bytes(s.shuffle_bytes),
                s.shuffle_stages.to_string(),
                s.steals.to_string(),
            ]);
        }
        let mut out = t.render();
        // Iterative runs append their convergence trajectories below the
        // per-method table (absent entirely for exact-only windows).
        for r in &self.convergence {
            out.push_str(&format!(
                "\nconvergence[{}]: {} iteration{} · {} · tolerance {:.1e} · final residual {:.3e}\n",
                r.algo,
                r.iterations,
                if r.iterations == 1 { "" } else { "s" },
                if r.converged { "converged" } else { "NOT converged (max_iters hit)" },
                r.tolerance,
                r.final_residual,
            ));
            let traj: Vec<String> = r.residuals.iter().map(|v| format!("{v:.3e}")).collect();
            out.push_str(&format!("  residuals: {}\n", traj.join(" → ")));
        }
        out
    }

    pub fn to_json(&self) -> Json {
        let methods: BTreeMap<String, Json> = self
            .methods
            .iter()
            .map(|(k, s)| {
                (
                    k.clone(),
                    Json::object(vec![
                        ("calls", Json::num(s.calls as f64)),
                        ("tasks", Json::num(s.tasks as f64)),
                        ("compute_secs", Json::num(s.compute_secs)),
                        ("virtual_secs", Json::num(s.virtual_secs)),
                        ("wall_secs", Json::num(s.wall_secs)),
                        ("shuffle_bytes", Json::num(s.shuffle_bytes as f64)),
                        ("shuffle_stages", Json::num(s.shuffle_stages as f64)),
                        ("steals", Json::num(s.steals as f64)),
                    ]),
                )
            })
            .collect();
        Json::Object(methods)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stage(method: &str, tasks: usize, compute: f64, makespan: f64) -> StageReport {
        StageReport {
            method: method.into(),
            tasks,
            exchange: false,
            compute_secs: compute,
            makespan_secs: makespan,
            shuffle_bytes: 0,
            shuffle_total_bytes: 0,
            shuffle_secs: 0.0,
            task_durations: vec![compute / tasks.max(1) as f64; tasks],
            wall_ns: (makespan * 1e9) as u64,
            ..StageReport::default()
        }
    }

    #[test]
    fn accumulates_per_method() {
        let m = Metrics::new();
        m.record_stage(stage("multiply", 4, 2.0, 0.5));
        m.record_stage(stage("multiply", 8, 4.0, 1.0));
        m.record_stage(stage("subtract", 2, 0.2, 0.1));
        let snap = m.snapshot();
        let mult = snap.method("multiply").unwrap();
        assert_eq!(mult.calls, 2);
        assert_eq!(mult.tasks, 12);
        assert!((mult.compute_secs - 6.0).abs() < 1e-12);
        assert!((mult.virtual_secs - 1.5).abs() < 1e-12);
        assert_eq!(snap.stages().len(), 3);
    }

    #[test]
    fn shuffle_time_counts_into_virtual() {
        let m = Metrics::new();
        m.record_stage(StageReport {
            method: "multiply".into(),
            tasks: 0,
            exchange: true,
            compute_secs: 0.0,
            makespan_secs: 0.0,
            shuffle_bytes: 1024,
            shuffle_total_bytes: 2048,
            shuffle_secs: 0.25,
            task_durations: Vec::new(),
            ..StageReport::default()
        });
        let snap = m.snapshot();
        let s = snap.method("multiply").unwrap();
        assert_eq!(s.shuffle_bytes, 1024);
        assert!((s.virtual_secs - 0.25).abs() < 1e-12);
        assert_eq!(snap.total_shuffle_bytes(), 1024);
    }

    #[test]
    fn reset_clears() {
        let m = Metrics::new();
        m.record_stage(stage("x", 1, 0.1, 0.1));
        m.record_driver_collect();
        m.record_plan_node(PlanNodeReport {
            node: "%1".into(),
            op: "multiply".into(),
            stages: 3,
            shuffle_stages: 2,
            shuffle_bytes: 64,
            driver_collects: 0,
            cse_cached: false,
        });
        assert_eq!(m.snapshot().plan_nodes().len(), 1);
        m.reset();
        let snap = m.snapshot();
        assert!(snap.method("x").is_none());
        assert!(snap.stages().is_empty());
        assert!(snap.plan_nodes().is_empty());
        assert_eq!(snap.driver_collects(), 0);
    }

    #[test]
    fn totals_track_counters() {
        let m = Metrics::new();
        assert_eq!(m.totals(), MetricsTotals::default());
        m.record_stage(stage("multiply", 4, 1.0, 0.5));
        m.record_stage(StageReport {
            method: "multiply".into(),
            tasks: 0,
            exchange: true,
            compute_secs: 0.0,
            makespan_secs: 0.0,
            shuffle_bytes: 256,
            shuffle_total_bytes: 256,
            shuffle_secs: 0.1,
            task_durations: Vec::new(),
            ..StageReport::default()
        });
        m.record_driver_collect();
        let t = m.totals();
        assert_eq!(t.stages, 2);
        assert_eq!(t.shuffle_stages, 1);
        assert_eq!(t.shuffle_bytes, 256);
        assert_eq!(t.driver_collects, 1);
    }

    #[test]
    fn counts_exchanges_and_driver_collects() {
        let m = Metrics::new();
        m.record_stage(stage("multiply", 4, 1.0, 0.5)); // narrow
        m.record_stage(StageReport {
            method: "multiply".into(),
            tasks: 0,
            exchange: true,
            compute_secs: 0.0,
            makespan_secs: 0.0,
            shuffle_bytes: 64,
            shuffle_total_bytes: 64,
            shuffle_secs: 0.1,
            task_durations: Vec::new(),
            ..StageReport::default()
        });
        m.record_driver_collect();
        m.record_driver_collect();
        let snap = m.snapshot();
        assert_eq!(snap.method("multiply").unwrap().shuffle_stages, 1);
        assert_eq!(snap.total_shuffle_stages(), 1);
        assert_eq!(snap.driver_collects(), 2);
    }

    #[test]
    fn render_and_json() {
        let m = Metrics::new();
        m.record_stage(stage("breakMat", 3, 0.5, 0.2));
        let snap = m.snapshot();
        let table = snap.render_table();
        assert!(table.contains("breakMat"));
        let j = snap.to_json();
        assert_eq!(
            j.get("breakMat").unwrap().get("tasks").unwrap().as_i64(),
            Some(3)
        );
    }

    #[test]
    fn total_virtual_sums_methods() {
        let m = Metrics::new();
        m.record_stage(stage("a", 1, 0.0, 1.0));
        m.record_stage(stage("b", 1, 0.0, 2.0));
        assert!((m.snapshot().total_virtual_secs() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn scopes_partition_the_record_stream() {
        let m = Metrics::new();
        m.record_stage(stage("ambient", 1, 0.1, 0.1)); // scope 0
        {
            let _g = Metrics::enter_scope(7);
            assert_eq!(Metrics::current_scope(), 7);
            m.record_stage(stage("job7", 2, 0.2, 0.2));
            m.record_driver_collect();
            {
                let _inner = Metrics::enter_scope(8);
                m.record_stage(stage("job8", 1, 0.1, 0.1));
            }
            // Nested guard restored the outer scope.
            assert_eq!(Metrics::current_scope(), 7);
            m.record_stage(stage("job7", 1, 0.1, 0.1));
        }
        assert_eq!(Metrics::current_scope(), 0);

        let t7 = m.totals_for_scope(7);
        assert_eq!(t7.stages, 2);
        assert_eq!(t7.driver_collects, 1);
        assert_eq!(m.totals_for_scope(8).stages, 1);
        assert_eq!(m.totals_for_scope(0).stages, 1);
        assert_eq!(m.totals_for_scope(99), MetricsTotals::default());
        // Global view still sees everything.
        assert_eq!(m.totals().stages, 4);
        assert_eq!(m.totals().driver_collects, 1);

        let s7 = m.snapshot_scope(7);
        assert_eq!(s7.stages().len(), 2);
        assert_eq!(s7.method("job7").unwrap().calls, 2);
        assert!(s7.method("ambient").is_none());
        assert!(s7.method("job8").is_none());
        assert_eq!(s7.driver_collects(), 1);
        assert_eq!(m.snapshot_scope(0).driver_collects(), 0);
    }

    #[test]
    fn scoped_exchange_counters() {
        let m = Metrics::new();
        let _g = Metrics::enter_scope(3);
        m.record_stage(StageReport {
            method: "multiply".into(),
            tasks: 0,
            exchange: true,
            compute_secs: 0.0,
            makespan_secs: 0.0,
            shuffle_bytes: 128,
            shuffle_total_bytes: 128,
            shuffle_secs: 0.1,
            task_durations: Vec::new(),
            ..StageReport::default()
        });
        let t = m.totals_for_scope(3);
        assert_eq!(t.shuffle_stages, 1);
        assert_eq!(t.shuffle_bytes, 128);
        assert_eq!(m.totals_for_scope(0).shuffle_stages, 0);
        assert_eq!(m.snapshot_scope(3).total_shuffle_stages(), 1);
    }

    #[test]
    fn release_scope_drops_records_but_keeps_aggregates() {
        let m = Metrics::new();
        {
            let _g = Metrics::enter_scope(5);
            m.record_stage(stage("multiply", 2, 0.2, 0.2));
            m.record_stage(stage("multiply", 2, 0.2, 0.2));
            m.record_plan_node(PlanNodeReport {
                node: "%1".into(),
                op: "multiply".into(),
                stages: 2,
                shuffle_stages: 0,
                shuffle_bytes: 0,
                driver_collects: 0,
                cse_cached: false,
            });
        }
        m.record_stage(stage("ambient", 1, 0.1, 0.1)); // scope 0
        assert_eq!(m.snapshot().retained_stage_records(), 3);
        assert_eq!(m.snapshot_scope(5).stages().len(), 2);

        assert_eq!(m.release_scope(5), 2);
        assert_eq!(m.release_scope(5), 0, "second release is a no-op");
        // The scope reads as empty; the ambient scope is untouched.
        assert!(m.snapshot_scope(5).stages().is_empty());
        assert!(m.snapshot_scope(5).plan_nodes().is_empty());
        assert_eq!(m.totals_for_scope(5), MetricsTotals::default());
        assert_eq!(m.snapshot_scope(0).stages().len(), 1);
        // Retention counters and lifetime aggregates.
        let snap = m.snapshot();
        assert_eq!(snap.retained_stage_records(), 1);
        assert_eq!(snap.released_stage_records(), 2);
        assert_eq!(snap.released_scopes(), 1);
        assert_eq!(snap.stages().len(), 1, "global view holds retained only");
        assert_eq!(
            snap.method("multiply").unwrap().calls,
            2,
            "per-method aggregates survive the release (Table-3 view)"
        );
        assert_eq!(m.totals().stages, 3, "lifetime totals never go backwards");
    }

    #[test]
    fn windowed_history_caps_retained_records() {
        let m = Metrics::with_history(3);
        for i in 0..7 {
            let _g = Metrics::enter_scope(i % 2);
            m.record_stage(stage("s", 1, 0.1, 0.1));
        }
        let snap = m.snapshot();
        assert_eq!(snap.retained_stage_records(), 3);
        assert_eq!(snap.released_stage_records(), 4);
        assert_eq!(snap.stages().len(), 3);
        assert_eq!(snap.method("s").unwrap().calls, 7, "aggregates keep all");
        assert_eq!(m.totals().stages, 7);
        // Plan-node reports ride the same window (no unbounded class).
        for i in 0..5 {
            m.record_plan_node(PlanNodeReport {
                node: format!("%{i}"),
                op: "multiply".into(),
                stages: 1,
                shuffle_stages: 0,
                shuffle_bytes: 0,
                driver_collects: 0,
                cse_cached: false,
            });
        }
        assert_eq!(m.snapshot().plan_nodes().len(), 3);
        // Reset clears records but keeps the configured window.
        m.reset();
        assert_eq!(m.snapshot().retained_stage_records(), 0);
        for _ in 0..5 {
            m.record_stage(stage("s", 1, 0.1, 0.1));
        }
        assert_eq!(m.snapshot().retained_stage_records(), 3);
    }

    #[test]
    fn resilience_counters_scope_and_survive_release() {
        let m = Metrics::new();
        assert!(!m.resilience_totals().any());
        {
            let _g = Metrics::enter_scope(11);
            m.record_resilience(&ResilienceTotals {
                retries: 2,
                speculative_launched: 1,
                speculative_won: 1,
                ..ResilienceTotals::default()
            });
            m.record_resilience(&ResilienceTotals {
                retries: 1,
                checkpoints_written: 1,
                ..ResilienceTotals::default()
            });
        }
        m.record_resilience(&ResilienceTotals {
            checkpoints_restored: 1,
            ..ResilienceTotals::default()
        }); // scope 0
        let s11 = m.resilience_for_scope(11);
        assert_eq!(s11.retries, 3);
        assert_eq!(s11.speculative_launched, 1);
        assert_eq!(s11.speculative_won, 1);
        assert_eq!(s11.checkpoints_written, 1);
        assert_eq!(s11.checkpoints_restored, 0);
        assert_eq!(m.resilience_for_scope(0).checkpoints_restored, 1);
        assert_eq!(m.snapshot_scope(11).resilience().retries, 3);
        assert_eq!(m.snapshot().resilience().retries, 3, "global view");
        // Releasing the scope drops its copy but not the lifetime totals.
        m.release_scope(11);
        assert!(!m.resilience_for_scope(11).any());
        assert_eq!(m.resilience_totals().retries, 3);
        assert_eq!(m.snapshot().resilience().checkpoints_restored, 1);
        // All-zero deltas are a no-op (no scope entry materialized).
        m.record_resilience(&ResilienceTotals::default());
        assert!(!m.resilience_for_scope(11).any());
        m.reset();
        assert!(!m.resilience_totals().any());
    }

    #[test]
    fn pinned_bytes_gauge_round_trips() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().pinned_bytes(), 0);
        m.set_pinned_bytes(4096);
        assert_eq!(m.snapshot().pinned_bytes(), 4096);
        assert_eq!(m.snapshot_scope(3).pinned_bytes(), 4096, "global gauge");
        m.reset();
        assert_eq!(m.snapshot().pinned_bytes(), 0);
    }

    #[test]
    fn cache_eviction_counters_accumulate_and_reset() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().cache_evictions(), 0);
        m.record_cache_eviction(2, 4096);
        m.record_cache_eviction(1, 1024);
        let snap = m.snapshot();
        assert_eq!(snap.cache_evictions(), 3);
        assert_eq!(snap.cache_evicted_bytes(), 5120);
        m.reset();
        assert_eq!(m.snapshot().cache_evictions(), 0);
        assert_eq!(m.snapshot().cache_evicted_bytes(), 0);
    }
}
