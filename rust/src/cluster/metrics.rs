//! Per-method metrics registry — regenerates the paper's Table 3
//! ("Experimental results of wall clock execution time of different
//! methods in SPIN").
//!
//! ## Scopes (multi-job attribution)
//!
//! One cluster now serves several concurrent jobs (the `service` layer),
//! so every recorded stage carries a **scope** — an opaque `u64` job tag
//! read from a thread-local at record time ([`Metrics::enter_scope`]).
//! Scope 0 is the ambient default; single-job flows never notice it.
//! Scoped accessors ([`Metrics::totals_for_scope`],
//! [`Metrics::snapshot_scope`]) answer "what did *this* job pay", which
//! is what keeps per-plan-node windows honest when two jobs interleave
//! stages on the same cluster: a delta of another job's stages can no
//! longer leak into this job's `PlanNodeReport`.

use std::cell::Cell;
use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::ser::json::Json;
use crate::util::fmt;

thread_local! {
    /// Job tag stamped onto everything the current thread records.
    static CURRENT_SCOPE: Cell<u64> = const { Cell::new(0) };
}

/// RAII guard restoring the previous metrics scope on drop.
pub struct MetricsScope {
    prev: u64,
}

impl Drop for MetricsScope {
    fn drop(&mut self) {
        CURRENT_SCOPE.with(|s| s.set(self.prev));
    }
}

/// One executed stage (narrow pass or shuffle exchange).
#[derive(Debug, Clone)]
pub struct StageReport {
    /// Method attribution (breakMat, xy, multiply, subtract, scalarMul,
    /// arrange, leafNode, …).
    pub method: String,
    /// Tasks in the stage (0 for pure shuffle exchanges).
    pub tasks: usize,
    /// True for shuffle exchanges (the wide half of a wide op), false for
    /// narrow stages — drives the per-method `shuffle_stages` count.
    pub exchange: bool,
    /// Total CPU seconds across tasks (measured, real).
    pub compute_secs: f64,
    /// Virtual wall-clock seconds after list scheduling onto slots.
    pub makespan_secs: f64,
    /// Bytes that crossed a simulated executor boundary.
    pub shuffle_bytes: u64,
    /// Bytes relocated to a different partition (upper bound on
    /// cross-executor traffic at any executor count) — used by replay.
    pub shuffle_total_bytes: u64,
    /// Simulated interconnect seconds for those bytes.
    pub shuffle_secs: f64,
    /// Measured per-task durations (empty for pure shuffle exchanges) —
    /// lets experiments replay the schedule on a different topology
    /// without re-running the compute (noise-free scaling curves).
    pub task_durations: Vec<f64>,
}

/// Cheap aggregate counters (no stage-vector clone) — the plan executor
/// brackets each plan node's lowering with two of these to attribute the
/// delta to that node.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MetricsTotals {
    /// Stages recorded so far (narrow + exchange).
    pub stages: usize,
    /// Shuffle exchanges recorded so far.
    pub shuffle_stages: usize,
    /// Cross-executor shuffle bytes so far.
    pub shuffle_bytes: u64,
    /// Driver collect round-trips so far.
    pub driver_collects: usize,
}

/// What one logical plan node actually paid when it was lowered — stamped
/// by [`crate::plan::PlanExec`] so `explain`'s predictions are checkable
/// against measured behaviour.
#[derive(Debug, Clone)]
pub struct PlanNodeReport {
    /// Plan-node label (`%17`).
    pub node: String,
    /// Operator name (`multiply`, `multiply_sub`, `quadrant`, …).
    pub op: String,
    /// Stages (narrow + exchange) recorded while lowering this node. For
    /// `invert` nodes this includes the whole recursive subcomputation.
    pub stages: usize,
    pub shuffle_stages: usize,
    pub shuffle_bytes: u64,
    pub driver_collects: usize,
    /// The optimizer marked this node as a CSE cache point.
    pub cse_cached: bool,
}

/// Accumulated per-method totals.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MethodStats {
    pub calls: usize,
    pub tasks: usize,
    pub compute_secs: f64,
    /// Virtual seconds (makespan + shuffle) — the paper's per-method
    /// "wall clock execution time".
    pub virtual_secs: f64,
    pub shuffle_bytes: u64,
    /// Shuffle exchanges this method paid for (0 when every stage ran
    /// narrow) — the per-op "wide vs narrow" delta the partitioner-aware
    /// dataflow is measured by.
    pub shuffle_stages: usize,
}

/// Thread-safe metrics registry owned by a [`crate::cluster::Cluster`].
pub struct Metrics {
    inner: Mutex<MetricsInner>,
}

#[derive(Default)]
struct MetricsInner {
    methods: BTreeMap<String, MethodStats>,
    stages: Vec<StageReport>,
    /// Indices into `stages` per scope — scoped snapshots touch only
    /// their own job's records, not the whole history.
    stage_index: BTreeMap<u64, Vec<usize>>,
    /// Per-plan-node lowering reports (lazy-plan executions only).
    plan_nodes: Vec<PlanNodeReport>,
    /// Indices into `plan_nodes` per scope.
    plan_node_index: BTreeMap<u64, Vec<usize>>,
    /// Running aggregate counters per scope (O(1) scoped windows).
    scope_totals: BTreeMap<u64, MetricsTotals>,
    /// Driver `collect` round-trips (materialize + re-parallelize). The
    /// partitioner-aware op pipeline records zero of these.
    driver_collects: usize,
    /// Plan-node values dropped by the LRU byte-budget evictor.
    cache_evictions: usize,
    /// Bytes those evictions released.
    cache_evicted_bytes: u64,
}

/// Fold one stage report into a per-method stats map (shared by the global
/// aggregation and the scoped-snapshot rebuild).
fn accumulate(methods: &mut BTreeMap<String, MethodStats>, report: &StageReport) {
    let stats = methods.entry(report.method.clone()).or_default();
    stats.calls += 1;
    stats.tasks += report.tasks;
    stats.compute_secs += report.compute_secs;
    stats.virtual_secs += report.makespan_secs + report.shuffle_secs;
    stats.shuffle_bytes += report.shuffle_bytes;
    if report.exchange {
        stats.shuffle_stages += 1;
    }
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            inner: Mutex::new(MetricsInner::default()),
        }
    }

    /// Tag everything the current thread records with `scope` until the
    /// returned guard drops (scopes nest; the previous tag is restored).
    /// The service layer opens one scope per job.
    pub fn enter_scope(scope: u64) -> MetricsScope {
        let prev = CURRENT_SCOPE.with(|s| s.replace(scope));
        MetricsScope { prev }
    }

    /// The current thread's active scope tag (0 outside any job).
    pub fn current_scope() -> u64 {
        CURRENT_SCOPE.with(|s| s.get())
    }

    pub fn record_stage(&self, report: StageReport) {
        let scope = Metrics::current_scope();
        let mut inner = self.inner.lock().unwrap();
        accumulate(&mut inner.methods, &report);
        {
            let totals = inner.scope_totals.entry(scope).or_default();
            totals.stages += 1;
            if report.exchange {
                totals.shuffle_stages += 1;
            }
            totals.shuffle_bytes += report.shuffle_bytes;
        }
        let idx = inner.stages.len();
        inner.stage_index.entry(scope).or_default().push(idx);
        inner.stages.push(report);
    }

    /// Count one driver materialize-and-reparallelize round-trip.
    pub fn record_driver_collect(&self) {
        let scope = Metrics::current_scope();
        let mut inner = self.inner.lock().unwrap();
        inner.driver_collects += 1;
        inner.scope_totals.entry(scope).or_default().driver_collects += 1;
    }

    /// Attribute a lowered plan node's cost window.
    pub fn record_plan_node(&self, report: PlanNodeReport) {
        let scope = Metrics::current_scope();
        let mut inner = self.inner.lock().unwrap();
        let idx = inner.plan_nodes.len();
        inner.plan_node_index.entry(scope).or_default().push(idx);
        inner.plan_nodes.push(report);
    }

    /// Count plan-node values dropped by the LRU byte-budget evictor.
    pub fn record_cache_eviction(&self, count: usize, bytes: u64) {
        let mut inner = self.inner.lock().unwrap();
        inner.cache_evictions += count;
        inner.cache_evicted_bytes += bytes;
    }

    /// Aggregate counters, cheap enough to call around every plan node.
    pub fn totals(&self) -> MetricsTotals {
        let inner = self.inner.lock().unwrap();
        MetricsTotals {
            stages: inner.stages.len(),
            shuffle_stages: inner.methods.values().map(|s| s.shuffle_stages).sum(),
            shuffle_bytes: inner.methods.values().map(|s| s.shuffle_bytes).sum(),
            driver_collects: inner.driver_collects,
        }
    }

    /// Aggregate counters restricted to one scope — the per-plan-node
    /// window bracket under concurrent jobs. For scope 0 with no other
    /// scope active this equals [`totals`](Self::totals).
    pub fn totals_for_scope(&self, scope: u64) -> MetricsTotals {
        let inner = self.inner.lock().unwrap();
        inner.scope_totals.get(&scope).copied().unwrap_or_default()
    }

    pub fn reset(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.methods.clear();
        inner.stages.clear();
        inner.stage_index.clear();
        inner.plan_nodes.clear();
        inner.plan_node_index.clear();
        inner.scope_totals.clear();
        inner.driver_collects = 0;
        inner.cache_evictions = 0;
        inner.cache_evicted_bytes = 0;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let inner = self.inner.lock().unwrap();
        MetricsSnapshot {
            methods: inner.methods.clone(),
            stages: inner.stages.clone(),
            plan_nodes: inner.plan_nodes.clone(),
            driver_collects: inner.driver_collects,
            cache_evictions: inner.cache_evictions,
            cache_evicted_bytes: inner.cache_evicted_bytes,
        }
    }

    /// Snapshot of what ONE scope recorded: its stages, per-method stats
    /// rebuilt from those stages alone, its plan-node reports, and its
    /// driver collects — O(this scope's records), not O(total history),
    /// so per-job snapshots stay cheap on a long-running service.
    /// Cache-eviction counters are cluster-global (the evictor serves
    /// every job) and reported as such.
    pub fn snapshot_scope(&self, scope: u64) -> MetricsSnapshot {
        let inner = self.inner.lock().unwrap();
        let mut methods = BTreeMap::new();
        let mut stages = Vec::new();
        if let Some(idxs) = inner.stage_index.get(&scope) {
            for &i in idxs {
                let stage = &inner.stages[i];
                accumulate(&mut methods, stage);
                stages.push(stage.clone());
            }
        }
        let plan_nodes = match inner.plan_node_index.get(&scope) {
            Some(idxs) => idxs.iter().map(|&i| inner.plan_nodes[i].clone()).collect(),
            None => Vec::new(),
        };
        MetricsSnapshot {
            methods,
            stages,
            plan_nodes,
            driver_collects: inner
                .scope_totals
                .get(&scope)
                .map(|t| t.driver_collects)
                .unwrap_or(0),
            cache_evictions: inner.cache_evictions,
            cache_evicted_bytes: inner.cache_evicted_bytes,
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Immutable view of the registry at a point in time.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    methods: BTreeMap<String, MethodStats>,
    stages: Vec<StageReport>,
    plan_nodes: Vec<PlanNodeReport>,
    driver_collects: usize,
    cache_evictions: usize,
    cache_evicted_bytes: u64,
}

impl MetricsSnapshot {
    pub fn method(&self, name: &str) -> Option<&MethodStats> {
        self.methods.get(name)
    }

    /// Plan-node values dropped by the LRU byte-budget evictor in this
    /// window (cluster-global; see `ClusterConfig::cache_budget_bytes`).
    pub fn cache_evictions(&self) -> usize {
        self.cache_evictions
    }

    /// Bytes released by those evictions.
    pub fn cache_evicted_bytes(&self) -> u64 {
        self.cache_evicted_bytes
    }

    /// Per-plan-node lowering reports recorded in this window (empty for
    /// purely eager `BlockMatrix` work).
    pub fn plan_nodes(&self) -> &[PlanNodeReport] {
        &self.plan_nodes
    }

    /// Driver `collect` round-trips recorded in this window.
    pub fn driver_collects(&self) -> usize {
        self.driver_collects
    }

    /// Shuffle exchanges recorded in this window (across all methods).
    pub fn total_shuffle_stages(&self) -> usize {
        self.methods.values().map(|s| s.shuffle_stages).sum()
    }

    pub fn methods(&self) -> impl Iterator<Item = (&String, &MethodStats)> {
        self.methods.iter()
    }

    pub fn stages(&self) -> &[StageReport] {
        &self.stages
    }

    /// Sum of per-method virtual seconds.
    pub fn total_virtual_secs(&self) -> f64 {
        self.methods.values().map(|s| s.virtual_secs).sum()
    }

    pub fn total_shuffle_bytes(&self) -> u64 {
        self.methods.values().map(|s| s.shuffle_bytes).sum()
    }

    /// Render the Table-3-shaped per-method breakdown.
    pub fn render_table(&self) -> String {
        let mut t = fmt::Table::new(vec![
            "method",
            "calls",
            "tasks",
            "compute",
            "virtual",
            "shuffled",
            "exchanges",
        ]);
        for (name, s) in &self.methods {
            t.row(vec![
                name.clone(),
                s.calls.to_string(),
                s.tasks.to_string(),
                fmt::secs(s.compute_secs),
                fmt::secs(s.virtual_secs),
                fmt::bytes(s.shuffle_bytes),
                s.shuffle_stages.to_string(),
            ]);
        }
        t.render()
    }

    pub fn to_json(&self) -> Json {
        let methods: std::collections::BTreeMap<String, Json> = self
            .methods
            .iter()
            .map(|(k, s)| {
                (
                    k.clone(),
                    Json::object(vec![
                        ("calls", Json::num(s.calls as f64)),
                        ("tasks", Json::num(s.tasks as f64)),
                        ("compute_secs", Json::num(s.compute_secs)),
                        ("virtual_secs", Json::num(s.virtual_secs)),
                        ("shuffle_bytes", Json::num(s.shuffle_bytes as f64)),
                        ("shuffle_stages", Json::num(s.shuffle_stages as f64)),
                    ]),
                )
            })
            .collect();
        Json::Object(methods)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stage(method: &str, tasks: usize, compute: f64, makespan: f64) -> StageReport {
        StageReport {
            method: method.into(),
            tasks,
            exchange: false,
            compute_secs: compute,
            makespan_secs: makespan,
            shuffle_bytes: 0,
            shuffle_total_bytes: 0,
            shuffle_secs: 0.0,
            task_durations: vec![compute / tasks.max(1) as f64; tasks],
        }
    }

    #[test]
    fn accumulates_per_method() {
        let m = Metrics::new();
        m.record_stage(stage("multiply", 4, 2.0, 0.5));
        m.record_stage(stage("multiply", 8, 4.0, 1.0));
        m.record_stage(stage("subtract", 2, 0.2, 0.1));
        let snap = m.snapshot();
        let mult = snap.method("multiply").unwrap();
        assert_eq!(mult.calls, 2);
        assert_eq!(mult.tasks, 12);
        assert!((mult.compute_secs - 6.0).abs() < 1e-12);
        assert!((mult.virtual_secs - 1.5).abs() < 1e-12);
        assert_eq!(snap.stages().len(), 3);
    }

    #[test]
    fn shuffle_time_counts_into_virtual() {
        let m = Metrics::new();
        m.record_stage(StageReport {
            method: "multiply".into(),
            tasks: 0,
            exchange: true,
            compute_secs: 0.0,
            makespan_secs: 0.0,
            shuffle_bytes: 1024,
            shuffle_total_bytes: 2048,
            shuffle_secs: 0.25,
            task_durations: Vec::new(),
        });
        let snap = m.snapshot();
        let s = snap.method("multiply").unwrap();
        assert_eq!(s.shuffle_bytes, 1024);
        assert!((s.virtual_secs - 0.25).abs() < 1e-12);
        assert_eq!(snap.total_shuffle_bytes(), 1024);
    }

    #[test]
    fn reset_clears() {
        let m = Metrics::new();
        m.record_stage(stage("x", 1, 0.1, 0.1));
        m.record_driver_collect();
        m.record_plan_node(PlanNodeReport {
            node: "%1".into(),
            op: "multiply".into(),
            stages: 3,
            shuffle_stages: 2,
            shuffle_bytes: 64,
            driver_collects: 0,
            cse_cached: false,
        });
        assert_eq!(m.snapshot().plan_nodes().len(), 1);
        m.reset();
        let snap = m.snapshot();
        assert!(snap.method("x").is_none());
        assert!(snap.stages().is_empty());
        assert!(snap.plan_nodes().is_empty());
        assert_eq!(snap.driver_collects(), 0);
    }

    #[test]
    fn totals_track_counters() {
        let m = Metrics::new();
        assert_eq!(m.totals(), MetricsTotals::default());
        m.record_stage(stage("multiply", 4, 1.0, 0.5));
        m.record_stage(StageReport {
            method: "multiply".into(),
            tasks: 0,
            exchange: true,
            compute_secs: 0.0,
            makespan_secs: 0.0,
            shuffle_bytes: 256,
            shuffle_total_bytes: 256,
            shuffle_secs: 0.1,
            task_durations: Vec::new(),
        });
        m.record_driver_collect();
        let t = m.totals();
        assert_eq!(t.stages, 2);
        assert_eq!(t.shuffle_stages, 1);
        assert_eq!(t.shuffle_bytes, 256);
        assert_eq!(t.driver_collects, 1);
    }

    #[test]
    fn counts_exchanges_and_driver_collects() {
        let m = Metrics::new();
        m.record_stage(stage("multiply", 4, 1.0, 0.5)); // narrow
        m.record_stage(StageReport {
            method: "multiply".into(),
            tasks: 0,
            exchange: true,
            compute_secs: 0.0,
            makespan_secs: 0.0,
            shuffle_bytes: 64,
            shuffle_total_bytes: 64,
            shuffle_secs: 0.1,
            task_durations: Vec::new(),
        });
        m.record_driver_collect();
        m.record_driver_collect();
        let snap = m.snapshot();
        assert_eq!(snap.method("multiply").unwrap().shuffle_stages, 1);
        assert_eq!(snap.total_shuffle_stages(), 1);
        assert_eq!(snap.driver_collects(), 2);
    }

    #[test]
    fn render_and_json() {
        let m = Metrics::new();
        m.record_stage(stage("breakMat", 3, 0.5, 0.2));
        let snap = m.snapshot();
        let table = snap.render_table();
        assert!(table.contains("breakMat"));
        let j = snap.to_json();
        assert_eq!(
            j.get("breakMat").unwrap().get("tasks").unwrap().as_i64(),
            Some(3)
        );
    }

    #[test]
    fn total_virtual_sums_methods() {
        let m = Metrics::new();
        m.record_stage(stage("a", 1, 0.0, 1.0));
        m.record_stage(stage("b", 1, 0.0, 2.0));
        assert!((m.snapshot().total_virtual_secs() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn scopes_partition_the_record_stream() {
        let m = Metrics::new();
        m.record_stage(stage("ambient", 1, 0.1, 0.1)); // scope 0
        {
            let _g = Metrics::enter_scope(7);
            assert_eq!(Metrics::current_scope(), 7);
            m.record_stage(stage("job7", 2, 0.2, 0.2));
            m.record_driver_collect();
            {
                let _inner = Metrics::enter_scope(8);
                m.record_stage(stage("job8", 1, 0.1, 0.1));
            }
            // Nested guard restored the outer scope.
            assert_eq!(Metrics::current_scope(), 7);
            m.record_stage(stage("job7", 1, 0.1, 0.1));
        }
        assert_eq!(Metrics::current_scope(), 0);

        let t7 = m.totals_for_scope(7);
        assert_eq!(t7.stages, 2);
        assert_eq!(t7.driver_collects, 1);
        assert_eq!(m.totals_for_scope(8).stages, 1);
        assert_eq!(m.totals_for_scope(0).stages, 1);
        assert_eq!(m.totals_for_scope(99), MetricsTotals::default());
        // Global view still sees everything.
        assert_eq!(m.totals().stages, 4);
        assert_eq!(m.totals().driver_collects, 1);

        let s7 = m.snapshot_scope(7);
        assert_eq!(s7.stages().len(), 2);
        assert_eq!(s7.method("job7").unwrap().calls, 2);
        assert!(s7.method("ambient").is_none());
        assert!(s7.method("job8").is_none());
        assert_eq!(s7.driver_collects(), 1);
        assert_eq!(m.snapshot_scope(0).driver_collects(), 0);
    }

    #[test]
    fn scoped_exchange_counters() {
        let m = Metrics::new();
        let _g = Metrics::enter_scope(3);
        m.record_stage(StageReport {
            method: "multiply".into(),
            tasks: 0,
            exchange: true,
            compute_secs: 0.0,
            makespan_secs: 0.0,
            shuffle_bytes: 128,
            shuffle_total_bytes: 128,
            shuffle_secs: 0.1,
            task_durations: Vec::new(),
        });
        let t = m.totals_for_scope(3);
        assert_eq!(t.shuffle_stages, 1);
        assert_eq!(t.shuffle_bytes, 128);
        assert_eq!(m.totals_for_scope(0).shuffle_stages, 0);
        assert_eq!(m.snapshot_scope(3).total_shuffle_stages(), 1);
    }

    #[test]
    fn cache_eviction_counters_accumulate_and_reset() {
        let m = Metrics::new();
        assert_eq!(m.snapshot().cache_evictions(), 0);
        m.record_cache_eviction(2, 4096);
        m.record_cache_eviction(1, 1024);
        let snap = m.snapshot();
        assert_eq!(snap.cache_evictions(), 3);
        assert_eq!(snap.cache_evicted_bytes(), 5120);
        m.reset();
        assert_eq!(m.snapshot().cache_evictions(), 0);
        assert_eq!(m.snapshot().cache_evicted_bytes(), 0);
    }
}
