//! Per-method metrics registry — regenerates the paper's Table 3
//! ("Experimental results of wall clock execution time of different
//! methods in SPIN").

use std::collections::BTreeMap;
use std::sync::Mutex;

use crate::ser::json::Json;
use crate::util::fmt;

/// One executed stage (narrow pass or shuffle exchange).
#[derive(Debug, Clone)]
pub struct StageReport {
    /// Method attribution (breakMat, xy, multiply, subtract, scalarMul,
    /// arrange, leafNode, …).
    pub method: String,
    /// Tasks in the stage (0 for pure shuffle exchanges).
    pub tasks: usize,
    /// True for shuffle exchanges (the wide half of a wide op), false for
    /// narrow stages — drives the per-method `shuffle_stages` count.
    pub exchange: bool,
    /// Total CPU seconds across tasks (measured, real).
    pub compute_secs: f64,
    /// Virtual wall-clock seconds after list scheduling onto slots.
    pub makespan_secs: f64,
    /// Bytes that crossed a simulated executor boundary.
    pub shuffle_bytes: u64,
    /// Bytes relocated to a different partition (upper bound on
    /// cross-executor traffic at any executor count) — used by replay.
    pub shuffle_total_bytes: u64,
    /// Simulated interconnect seconds for those bytes.
    pub shuffle_secs: f64,
    /// Measured per-task durations (empty for pure shuffle exchanges) —
    /// lets experiments replay the schedule on a different topology
    /// without re-running the compute (noise-free scaling curves).
    pub task_durations: Vec<f64>,
}

/// Cheap aggregate counters (no stage-vector clone) — the plan executor
/// brackets each plan node's lowering with two of these to attribute the
/// delta to that node.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MetricsTotals {
    /// Stages recorded so far (narrow + exchange).
    pub stages: usize,
    /// Shuffle exchanges recorded so far.
    pub shuffle_stages: usize,
    /// Cross-executor shuffle bytes so far.
    pub shuffle_bytes: u64,
    /// Driver collect round-trips so far.
    pub driver_collects: usize,
}

/// What one logical plan node actually paid when it was lowered — stamped
/// by [`crate::plan::PlanExec`] so `explain`'s predictions are checkable
/// against measured behaviour.
#[derive(Debug, Clone)]
pub struct PlanNodeReport {
    /// Plan-node label (`%17`).
    pub node: String,
    /// Operator name (`multiply`, `multiply_sub`, `quadrant`, …).
    pub op: String,
    /// Stages (narrow + exchange) recorded while lowering this node. For
    /// `invert` nodes this includes the whole recursive subcomputation.
    pub stages: usize,
    pub shuffle_stages: usize,
    pub shuffle_bytes: u64,
    pub driver_collects: usize,
    /// The optimizer marked this node as a CSE cache point.
    pub cse_cached: bool,
}

/// Accumulated per-method totals.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct MethodStats {
    pub calls: usize,
    pub tasks: usize,
    pub compute_secs: f64,
    /// Virtual seconds (makespan + shuffle) — the paper's per-method
    /// "wall clock execution time".
    pub virtual_secs: f64,
    pub shuffle_bytes: u64,
    /// Shuffle exchanges this method paid for (0 when every stage ran
    /// narrow) — the per-op "wide vs narrow" delta the partitioner-aware
    /// dataflow is measured by.
    pub shuffle_stages: usize,
}

/// Thread-safe metrics registry owned by a [`crate::cluster::Cluster`].
pub struct Metrics {
    inner: Mutex<MetricsInner>,
}

#[derive(Default)]
struct MetricsInner {
    methods: BTreeMap<String, MethodStats>,
    stages: Vec<StageReport>,
    /// Per-plan-node lowering reports (lazy-plan executions only).
    plan_nodes: Vec<PlanNodeReport>,
    /// Driver `collect` round-trips (materialize + re-parallelize). The
    /// partitioner-aware op pipeline records zero of these.
    driver_collects: usize,
}

impl Metrics {
    pub fn new() -> Self {
        Metrics {
            inner: Mutex::new(MetricsInner::default()),
        }
    }

    pub fn record_stage(&self, report: StageReport) {
        let mut inner = self.inner.lock().unwrap();
        let stats = inner.methods.entry(report.method.clone()).or_default();
        stats.calls += 1;
        stats.tasks += report.tasks;
        stats.compute_secs += report.compute_secs;
        stats.virtual_secs += report.makespan_secs + report.shuffle_secs;
        stats.shuffle_bytes += report.shuffle_bytes;
        if report.exchange {
            stats.shuffle_stages += 1;
        }
        inner.stages.push(report);
    }

    /// Count one driver materialize-and-reparallelize round-trip.
    pub fn record_driver_collect(&self) {
        self.inner.lock().unwrap().driver_collects += 1;
    }

    /// Attribute a lowered plan node's cost window.
    pub fn record_plan_node(&self, report: PlanNodeReport) {
        self.inner.lock().unwrap().plan_nodes.push(report);
    }

    /// Aggregate counters, cheap enough to call around every plan node.
    pub fn totals(&self) -> MetricsTotals {
        let inner = self.inner.lock().unwrap();
        MetricsTotals {
            stages: inner.stages.len(),
            shuffle_stages: inner.methods.values().map(|s| s.shuffle_stages).sum(),
            shuffle_bytes: inner.methods.values().map(|s| s.shuffle_bytes).sum(),
            driver_collects: inner.driver_collects,
        }
    }

    pub fn reset(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.methods.clear();
        inner.stages.clear();
        inner.plan_nodes.clear();
        inner.driver_collects = 0;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let inner = self.inner.lock().unwrap();
        MetricsSnapshot {
            methods: inner.methods.clone(),
            stages: inner.stages.clone(),
            plan_nodes: inner.plan_nodes.clone(),
            driver_collects: inner.driver_collects,
        }
    }
}

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

/// Immutable view of the registry at a point in time.
#[derive(Debug, Clone)]
pub struct MetricsSnapshot {
    methods: BTreeMap<String, MethodStats>,
    stages: Vec<StageReport>,
    plan_nodes: Vec<PlanNodeReport>,
    driver_collects: usize,
}

impl MetricsSnapshot {
    pub fn method(&self, name: &str) -> Option<&MethodStats> {
        self.methods.get(name)
    }

    /// Per-plan-node lowering reports recorded in this window (empty for
    /// purely eager `BlockMatrix` work).
    pub fn plan_nodes(&self) -> &[PlanNodeReport] {
        &self.plan_nodes
    }

    /// Driver `collect` round-trips recorded in this window.
    pub fn driver_collects(&self) -> usize {
        self.driver_collects
    }

    /// Shuffle exchanges recorded in this window (across all methods).
    pub fn total_shuffle_stages(&self) -> usize {
        self.methods.values().map(|s| s.shuffle_stages).sum()
    }

    pub fn methods(&self) -> impl Iterator<Item = (&String, &MethodStats)> {
        self.methods.iter()
    }

    pub fn stages(&self) -> &[StageReport] {
        &self.stages
    }

    /// Sum of per-method virtual seconds.
    pub fn total_virtual_secs(&self) -> f64 {
        self.methods.values().map(|s| s.virtual_secs).sum()
    }

    pub fn total_shuffle_bytes(&self) -> u64 {
        self.methods.values().map(|s| s.shuffle_bytes).sum()
    }

    /// Render the Table-3-shaped per-method breakdown.
    pub fn render_table(&self) -> String {
        let mut t = fmt::Table::new(vec![
            "method",
            "calls",
            "tasks",
            "compute",
            "virtual",
            "shuffled",
            "exchanges",
        ]);
        for (name, s) in &self.methods {
            t.row(vec![
                name.clone(),
                s.calls.to_string(),
                s.tasks.to_string(),
                fmt::secs(s.compute_secs),
                fmt::secs(s.virtual_secs),
                fmt::bytes(s.shuffle_bytes),
                s.shuffle_stages.to_string(),
            ]);
        }
        t.render()
    }

    pub fn to_json(&self) -> Json {
        let methods: std::collections::BTreeMap<String, Json> = self
            .methods
            .iter()
            .map(|(k, s)| {
                (
                    k.clone(),
                    Json::object(vec![
                        ("calls", Json::num(s.calls as f64)),
                        ("tasks", Json::num(s.tasks as f64)),
                        ("compute_secs", Json::num(s.compute_secs)),
                        ("virtual_secs", Json::num(s.virtual_secs)),
                        ("shuffle_bytes", Json::num(s.shuffle_bytes as f64)),
                        ("shuffle_stages", Json::num(s.shuffle_stages as f64)),
                    ]),
                )
            })
            .collect();
        Json::Object(methods)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stage(method: &str, tasks: usize, compute: f64, makespan: f64) -> StageReport {
        StageReport {
            method: method.into(),
            tasks,
            exchange: false,
            compute_secs: compute,
            makespan_secs: makespan,
            shuffle_bytes: 0,
            shuffle_total_bytes: 0,
            shuffle_secs: 0.0,
            task_durations: vec![compute / tasks.max(1) as f64; tasks],
        }
    }

    #[test]
    fn accumulates_per_method() {
        let m = Metrics::new();
        m.record_stage(stage("multiply", 4, 2.0, 0.5));
        m.record_stage(stage("multiply", 8, 4.0, 1.0));
        m.record_stage(stage("subtract", 2, 0.2, 0.1));
        let snap = m.snapshot();
        let mult = snap.method("multiply").unwrap();
        assert_eq!(mult.calls, 2);
        assert_eq!(mult.tasks, 12);
        assert!((mult.compute_secs - 6.0).abs() < 1e-12);
        assert!((mult.virtual_secs - 1.5).abs() < 1e-12);
        assert_eq!(snap.stages().len(), 3);
    }

    #[test]
    fn shuffle_time_counts_into_virtual() {
        let m = Metrics::new();
        m.record_stage(StageReport {
            method: "multiply".into(),
            tasks: 0,
            exchange: true,
            compute_secs: 0.0,
            makespan_secs: 0.0,
            shuffle_bytes: 1024,
            shuffle_total_bytes: 2048,
            shuffle_secs: 0.25,
            task_durations: Vec::new(),
        });
        let snap = m.snapshot();
        let s = snap.method("multiply").unwrap();
        assert_eq!(s.shuffle_bytes, 1024);
        assert!((s.virtual_secs - 0.25).abs() < 1e-12);
        assert_eq!(snap.total_shuffle_bytes(), 1024);
    }

    #[test]
    fn reset_clears() {
        let m = Metrics::new();
        m.record_stage(stage("x", 1, 0.1, 0.1));
        m.record_driver_collect();
        m.record_plan_node(PlanNodeReport {
            node: "%1".into(),
            op: "multiply".into(),
            stages: 3,
            shuffle_stages: 2,
            shuffle_bytes: 64,
            driver_collects: 0,
            cse_cached: false,
        });
        assert_eq!(m.snapshot().plan_nodes().len(), 1);
        m.reset();
        let snap = m.snapshot();
        assert!(snap.method("x").is_none());
        assert!(snap.stages().is_empty());
        assert!(snap.plan_nodes().is_empty());
        assert_eq!(snap.driver_collects(), 0);
    }

    #[test]
    fn totals_track_counters() {
        let m = Metrics::new();
        assert_eq!(m.totals(), MetricsTotals::default());
        m.record_stage(stage("multiply", 4, 1.0, 0.5));
        m.record_stage(StageReport {
            method: "multiply".into(),
            tasks: 0,
            exchange: true,
            compute_secs: 0.0,
            makespan_secs: 0.0,
            shuffle_bytes: 256,
            shuffle_total_bytes: 256,
            shuffle_secs: 0.1,
            task_durations: Vec::new(),
        });
        m.record_driver_collect();
        let t = m.totals();
        assert_eq!(t.stages, 2);
        assert_eq!(t.shuffle_stages, 1);
        assert_eq!(t.shuffle_bytes, 256);
        assert_eq!(t.driver_collects, 1);
    }

    #[test]
    fn counts_exchanges_and_driver_collects() {
        let m = Metrics::new();
        m.record_stage(stage("multiply", 4, 1.0, 0.5)); // narrow
        m.record_stage(StageReport {
            method: "multiply".into(),
            tasks: 0,
            exchange: true,
            compute_secs: 0.0,
            makespan_secs: 0.0,
            shuffle_bytes: 64,
            shuffle_total_bytes: 64,
            shuffle_secs: 0.1,
            task_durations: Vec::new(),
        });
        m.record_driver_collect();
        m.record_driver_collect();
        let snap = m.snapshot();
        assert_eq!(snap.method("multiply").unwrap().shuffle_stages, 1);
        assert_eq!(snap.total_shuffle_stages(), 1);
        assert_eq!(snap.driver_collects(), 2);
    }

    #[test]
    fn render_and_json() {
        let m = Metrics::new();
        m.record_stage(stage("breakMat", 3, 0.5, 0.2));
        let snap = m.snapshot();
        let table = snap.render_table();
        assert!(table.contains("breakMat"));
        let j = snap.to_json();
        assert_eq!(
            j.get("breakMat").unwrap().get("tasks").unwrap().as_i64(),
            Some(3)
        );
    }

    #[test]
    fn total_virtual_sums_methods() {
        let m = Metrics::new();
        m.record_stage(stage("a", 1, 0.0, 1.0));
        m.record_stage(stage("b", 1, 0.0, 2.0));
        assert!((m.snapshot().total_virtual_secs() - 3.0).abs() < 1e-12);
    }
}
