//! The Spark stand-in: a partitioned dataflow substrate with measured task
//! execution and **virtual-time** accounting.
//!
//! Why virtual time: the paper ran on 3 nodes × 30 task slots; this testbed
//! has one physical core. The paper's own wall-clock analysis (§4) is a
//! makespan model — per-method compute divided by the parallelization
//! factor `min(tasks, cores)`, plus shuffle. So the substrate executes every
//! task *for real* (measuring its CPU cost), then derives the cluster wall
//! clock by list-scheduling those measured durations onto the configured
//! `executors × cores` slots and charging shuffle bytes to the simulated
//! interconnect. This reproduces the paper's parallelism effects (U-shaped
//! block-size curves, executor scaling) faithfully on any host.
//! See DESIGN.md §3.
//!
//! The API is deliberately Spark-shaped: [`Rdd`] (partitioned collection),
//! narrow ops (`map`, `filter`, `union`), wide ops (`group_by_key`,
//! `cogroup`, `reduce_by_key`) that shuffle with byte accounting, and a
//! per-method [`Metrics`] registry that regenerates the paper's Table 3.

mod executor;
mod metrics;
mod rdd;
mod scheduler;
mod shuffle;

pub use executor::WorkerPool;
pub use metrics::{MethodStats, Metrics, MetricsSnapshot, StageReport};
pub use rdd::Rdd;
pub use scheduler::{list_schedule_makespan, VirtualClock};
pub use shuffle::{executor_of_partition, hash_partition, Bytes};

use std::sync::Mutex;

use crate::config::ClusterConfig;

/// A simulated Spark cluster: topology + task execution + virtual clock +
/// metrics. One `Cluster` corresponds to one Spark application context.
pub struct Cluster {
    config: ClusterConfig,
    metrics: Metrics,
    vclock: Mutex<VirtualClock>,
    pool: WorkerPool,
    /// Interconnect time of the most recent shuffle exchange, not yet
    /// charged to the clock: Spark overlaps shuffle fetch with reduce-side
    /// execution, so it is folded into the next narrow stage as
    /// `max(compute, transfer)` rather than summed.
    pending_shuffle: Mutex<f64>,
}

impl Cluster {
    pub fn new(config: ClusterConfig) -> Self {
        let pool = WorkerPool::new(config.worker_threads);
        Cluster {
            config,
            metrics: Metrics::new(),
            vclock: Mutex::new(VirtualClock::new()),
            pool,
            pending_shuffle: Mutex::new(0.0),
        }
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.config
    }

    /// Total simulated task slots (the paper's `cores`).
    pub fn slots(&self) -> usize {
        self.config.total_cores()
    }

    /// Current virtual wall-clock seconds consumed by this cluster.
    pub fn virtual_secs(&self) -> f64 {
        self.vclock.lock().unwrap().now()
    }

    /// Reset the virtual clock and metrics (new measurement window).
    pub fn reset(&self) {
        self.vclock.lock().unwrap().reset();
        *self.pending_shuffle.lock().unwrap() = 0.0;
        self.metrics.reset();
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    // ---------- RDD creation ----------

    /// Distribute `items` across `nparts` partitions round-robin
    /// (Spark `parallelize`).
    pub fn parallelize<T>(&self, items: Vec<T>, nparts: usize) -> Rdd<T> {
        Rdd::from_items(items, nparts.max(1))
    }

    // ---------- narrow transformations ----------

    /// Per-element map; one task per partition; no shuffle.
    pub fn map<T: Send, U: Send>(
        &self,
        method: &str,
        input: Rdd<T>,
        f: impl Fn(T) -> U + Sync,
    ) -> Rdd<U> {
        self.run_narrow(method, input, |part| {
            part.into_iter().map(&f).collect()
        })
    }

    /// Per-element filter; one task per partition; no shuffle.
    pub fn filter<T: Send>(
        &self,
        method: &str,
        input: Rdd<T>,
        pred: impl Fn(&T) -> bool + Sync,
    ) -> Rdd<T> {
        self.run_narrow(method, input, |part| {
            part.into_iter().filter(|x| pred(x)).collect()
        })
    }

    /// Per-element flat map; one task per partition; no shuffle.
    pub fn flat_map<T: Send, U: Send, I: IntoIterator<Item = U>>(
        &self,
        method: &str,
        input: Rdd<T>,
        f: impl Fn(T) -> I + Sync,
    ) -> Rdd<U> {
        self.run_narrow(method, input, |part| {
            part.into_iter().flat_map(&f).collect()
        })
    }

    /// Concatenate two RDDs' partition lists (Spark `union` — free).
    pub fn union<T>(&self, a: Rdd<T>, b: Rdd<T>) -> Rdd<T> {
        a.union(b)
    }

    /// Materialize all elements on the driver (Spark `collect`).
    pub fn collect<T>(&self, rdd: Rdd<T>) -> Vec<T> {
        rdd.into_items()
    }

    // ---------- wide transformations (shuffle) ----------

    /// Group values by key into `nparts` output partitions.
    pub fn group_by_key<K, V>(
        &self,
        method: &str,
        input: Rdd<(K, V)>,
        nparts: usize,
    ) -> Rdd<(K, Vec<V>)>
    where
        K: std::hash::Hash + Eq + Clone + Send,
        V: Send + Bytes,
    {
        let buckets = self.shuffle_exchange(method, input, nparts);
        self.run_narrow(method, buckets, |part| {
            shuffle::group_pairs(part).into_iter().collect()
        })
    }

    /// Co-group two keyed RDDs (the paper's `multiply` uses this to bring
    /// matching A/B blocks to the same reducer).
    pub fn cogroup<K, V, W>(
        &self,
        method: &str,
        left: Rdd<(K, V)>,
        right: Rdd<(K, W)>,
        nparts: usize,
    ) -> Rdd<(K, (Vec<V>, Vec<W>))>
    where
        K: std::hash::Hash + Eq + Clone + Send,
        V: Send + Bytes,
        W: Send + Bytes,
    {
        let tagged_l = self.map("cogroup-tag", left, |(k, v)| (k, shuffle::Either::L(v)));
        let tagged_r = self.map("cogroup-tag", right, |(k, w)| (k, shuffle::Either::R(w)));
        let both = self.union(tagged_l, tagged_r);
        let grouped = self.group_by_key(method, both, nparts);
        self.run_narrow(method, grouped, |part| {
            part.into_iter()
                .map(|(k, vals)| {
                    let mut ls = Vec::new();
                    let mut rs = Vec::new();
                    for v in vals {
                        match v {
                            shuffle::Either::L(v) => ls.push(v),
                            shuffle::Either::R(w) => rs.push(w),
                        }
                    }
                    (k, (ls, rs))
                })
                .collect()
        })
    }

    /// Shuffle + per-key reduction (used by block-matmul's sum stage).
    pub fn reduce_by_key<K, V>(
        &self,
        method: &str,
        input: Rdd<(K, V)>,
        nparts: usize,
        reduce: impl Fn(V, V) -> V + Sync,
    ) -> Rdd<(K, V)>
    where
        K: std::hash::Hash + Eq + Clone + Send,
        V: Send + Bytes,
    {
        let buckets = self.shuffle_exchange(method, input, nparts);
        self.run_narrow(method, buckets, |part| {
            shuffle::group_pairs(part)
                .into_iter()
                .map(|(k, vals)| {
                    let mut it = vals.into_iter();
                    let first = it.next().expect("group is non-empty");
                    (k, it.fold(first, &reduce))
                })
                .collect()
        })
    }

    // ---------- internals ----------

    /// Execute one narrow stage: one task per partition, real execution on
    /// the worker pool, measured durations list-scheduled onto the simulated
    /// slots, metrics attributed to `method`.
    fn run_narrow<T: Send, U: Send>(
        &self,
        method: &str,
        input: Rdd<T>,
        per_partition: impl Fn(Vec<T>) -> Vec<U> + Sync,
    ) -> Rdd<U> {
        let parts = input.into_partitions();
        let ntasks = parts.len();
        let (outputs, durations) = self.pool.run_tasks(parts, &per_partition);
        let makespan = list_schedule_makespan(&durations, self.slots());
        // Overlap any pending shuffle transfer with this stage's execution.
        let pending = std::mem::take(&mut *self.pending_shuffle.lock().unwrap());
        self.vclock.lock().unwrap().advance(makespan.max(pending));
        self.metrics.record_stage(StageReport {
            method: method.to_string(),
            tasks: ntasks,
            compute_secs: durations.iter().sum(),
            makespan_secs: makespan,
            shuffle_bytes: 0,
            shuffle_total_bytes: 0,
            shuffle_secs: 0.0,
            task_durations: durations,
        });
        Rdd::from_partitions(outputs)
    }

    /// Exchange phase of a wide op: hash-partition elements into `nparts`
    /// buckets, counting bytes that cross simulated executor boundaries and
    /// charging them to the interconnect.
    fn shuffle_exchange<K, V>(
        &self,
        method: &str,
        input: Rdd<(K, V)>,
        nparts: usize,
    ) -> Rdd<(K, V)>
    where
        K: std::hash::Hash + Eq + Clone + Send,
        V: Send + Bytes,
    {
        let executors = self.config.total_executors();
        let (buckets, moved_bytes, total_bytes) = shuffle::exchange(input, nparts, executors);
        // Transfers happen in parallel across executor pairs; charge the
        // aggregate volume spread over the executor count, plus one latency.
        let secs = if moved_bytes == 0 {
            0.0
        } else {
            self.config
                .network
                .transfer_secs((moved_bytes / executors.max(1) as u64).max(1))
        };
        // Deferred: folded into the next narrow stage (fetch/execute overlap).
        *self.pending_shuffle.lock().unwrap() += secs;
        self.metrics.record_stage(StageReport {
            method: method.to_string(),
            tasks: 0,
            compute_secs: 0.0,
            makespan_secs: 0.0,
            shuffle_bytes: moved_bytes,
            shuffle_total_bytes: total_bytes,
            shuffle_secs: secs,
            task_durations: Vec::new(),
        });
        Rdd::from_partitions(buckets)
    }

    /// Run an arbitrary closure as a single named task on the pool —
    /// used for driver-side serial steps that still cost virtual time
    /// (e.g. the paper's single-block leaf inversion when b = 1).
    pub fn run_single<T: Send>(&self, method: &str, f: impl FnOnce() -> T + Send) -> T {
        let t0 = std::time::Instant::now();
        let out = f();
        let dt = t0.elapsed().as_secs_f64();
        self.vclock.lock().unwrap().advance(dt);
        self.metrics.record_stage(StageReport {
            method: method.to_string(),
            tasks: 1,
            compute_secs: dt,
            makespan_secs: dt,
            shuffle_bytes: 0,
            shuffle_total_bytes: 0,
            shuffle_secs: 0.0,
            task_durations: vec![dt],
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn cluster(cores: usize) -> Cluster {
        Cluster::new(ClusterConfig::local(cores))
    }

    #[test]
    fn map_preserves_all_elements() {
        let c = cluster(4);
        let rdd = c.parallelize((0..100).collect(), 8);
        let out = c.map("test", rdd, |x: i32| x * 2);
        let mut v = c.collect(out);
        v.sort_unstable();
        assert_eq!(v, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn filter_keeps_matching() {
        let c = cluster(2);
        let rdd = c.parallelize((0..50).collect(), 4);
        let out = c.filter("test", rdd, |x: &i32| x % 5 == 0);
        let mut v = c.collect(out);
        v.sort_unstable();
        assert_eq!(v, vec![0, 5, 10, 15, 20, 25, 30, 35, 40, 45]);
    }

    #[test]
    fn flat_map_expands() {
        let c = cluster(2);
        let rdd = c.parallelize(vec![1, 2, 3], 2);
        let out = c.flat_map("test", rdd, |x: i32| vec![x; x as usize]);
        let mut v = c.collect(out);
        v.sort_unstable();
        assert_eq!(v, vec![1, 2, 2, 3, 3, 3]);
    }

    #[test]
    fn union_concatenates() {
        let c = cluster(2);
        let a = c.parallelize(vec![1, 2], 1);
        let b = c.parallelize(vec![3], 1);
        let mut v = c.collect(c.union(a, b));
        v.sort_unstable();
        assert_eq!(v, vec![1, 2, 3]);
    }

    #[test]
    fn group_by_key_groups_everything() {
        let c = cluster(4);
        let pairs: Vec<(u32, i32)> = (0..40).map(|i| (i % 4, i as i32)).collect();
        let rdd = c.parallelize(pairs, 8);
        let grouped = c.group_by_key("test", rdd, 4);
        let out = c.collect(grouped);
        assert_eq!(out.len(), 4);
        for (k, vals) in out {
            assert_eq!(vals.len(), 10, "key {k}");
            for v in vals {
                assert_eq!(v as u32 % 4, k);
            }
        }
    }

    #[test]
    fn cogroup_aligns_keys() {
        let c = cluster(4);
        let left = c.parallelize(vec![(1u32, 10), (2, 20), (1, 11)], 2);
        let right = c.parallelize(vec![(1u32, -1), (3, -3)], 2);
        let mut out = c.collect(c.cogroup("test", left, right, 3));
        out.sort_by_key(|(k, _)| *k);
        assert_eq!(out.len(), 3);
        let (k1, (mut l1, r1)) = out[0].clone();
        l1.sort_unstable();
        assert_eq!((k1, l1, r1), (1, vec![10, 11], vec![-1]));
        assert_eq!(out[1], (2, (vec![20], vec![])));
        assert_eq!(out[2], (3, (vec![], vec![-3])));
    }

    #[test]
    fn reduce_by_key_sums() {
        let c = cluster(4);
        let pairs: Vec<(u32, i32)> = (0..30).map(|i| (i % 3, 1)).collect();
        let rdd = c.parallelize(pairs, 5);
        let mut out = c.collect(c.reduce_by_key("test", rdd, 3, |a, b| a + b));
        out.sort_by_key(|(k, _)| *k);
        assert_eq!(out, vec![(0, 10), (1, 10), (2, 10)]);
    }

    #[test]
    fn virtual_clock_advances_and_resets() {
        let c = cluster(2);
        assert_eq!(c.virtual_secs(), 0.0);
        let rdd = c.parallelize((0..1000).collect(), 4);
        let _ = c.collect(c.map("test", rdd, |x: i64| x * x));
        assert!(c.virtual_secs() > 0.0);
        c.reset();
        assert_eq!(c.virtual_secs(), 0.0);
    }

    #[test]
    fn metrics_attribute_methods() {
        let c = cluster(2);
        let rdd = c.parallelize((0..10).collect(), 2);
        let out = c.map("alpha", rdd, |x: i32| x + 1);
        let _ = c.collect(c.filter("beta", out, |_| true));
        let snap = c.metrics();
        assert!(snap.method("alpha").is_some());
        assert!(snap.method("beta").is_some());
        assert_eq!(snap.method("alpha").unwrap().tasks, 2);
    }

    #[test]
    fn run_single_counts_as_task() {
        let c = cluster(1);
        let out = c.run_single("leafNode", || 7 * 6);
        assert_eq!(out, 42);
        assert_eq!(c.metrics().method("leafNode").unwrap().calls, 1);
        assert!(c.virtual_secs() > 0.0);
    }

    #[test]
    fn shuffle_records_bytes() {
        // 2 executors so some data must cross the boundary.
        let mut cfg = ClusterConfig::local(2);
        cfg.executors_per_node = 2;
        let c = Cluster::new(cfg);
        let pairs: Vec<(u32, i32)> = (0..64).map(|i| (i, i as i32)).collect();
        let rdd = c.parallelize(pairs, 4);
        let _ = c.collect(c.group_by_key("shufl", rdd, 4));
        let snap = c.metrics();
        assert!(snap.method("shufl").unwrap().shuffle_bytes > 0);
    }

    #[test]
    fn multithreaded_pool_same_results() {
        let mut cfg = ClusterConfig::local(4);
        cfg.worker_threads = 3;
        let c = Cluster::new(cfg);
        let rdd = c.parallelize((0..1000).collect(), 16);
        let mut v = c.collect(c.map("mt", rdd, |x: i64| x * 3));
        v.sort_unstable();
        assert_eq!(v, (0..1000).map(|x| x * 3).collect::<Vec<_>>());
    }
}
