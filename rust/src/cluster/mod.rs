//! The Spark stand-in: a partitioned dataflow substrate with measured task
//! execution and **virtual-time** accounting.
//!
//! Why virtual time: the paper ran on 3 nodes × 30 task slots; this testbed
//! has one physical core. The paper's own wall-clock analysis (§4) is a
//! makespan model — per-method compute divided by the parallelization
//! factor `min(tasks, cores)`, plus shuffle. So the substrate executes every
//! task *for real* (measuring its CPU cost), then derives the cluster wall
//! clock by list-scheduling those measured durations onto the configured
//! `executors × cores` slots and charging shuffle bytes to the simulated
//! interconnect. This reproduces the paper's parallelism effects (U-shaped
//! block-size curves, executor scaling) faithfully on any host.
//! See DESIGN.md §3.
//!
//! The API is deliberately Spark-shaped: [`Rdd`] (partitioned collection),
//! narrow ops (`map`, `filter`, `union`, `zip_partitions`), wide ops
//! (`group_by_key`, `cogroup`, `reduce_by_key`, `partition_*_by`) that
//! shuffle with byte accounting, and a per-method [`Metrics`] registry
//! that regenerates the paper's Table 3.
//!
//! ## The partitioner contract (narrow vs wide)
//!
//! An [`Rdd`] may carry a [`Partitioner`] — a promise that element
//! placement is a deterministic function of the key (Spark's
//! `HashPartitioner` / MLLib's `GridPartitioner`). The substrate exploits
//! it exactly the way Spark does:
//!
//! * **Wide ops become no-ops on matching input.** `group_by_key`,
//!   `reduce_by_key`, and `partition_*_by` skip the exchange entirely
//!   (zero shuffle bytes, no exchange stage recorded) when the input
//!   already carries the target partitioner — keys are then confined to
//!   single partitions and the reduction runs narrow.
//! * **Co-partitioned binary ops run narrow.** [`Cluster::zip_partitions`]
//!   pairs equal-length partition lists task-by-task with no shuffle; two
//!   RDDs sharing a partitioner can be keyed-joined inside each task.
//! * **Explicit exchanges route to the consumer.** `partition_pairs_by`
//!   takes an arbitrary key→partition function, so a producer can land
//!   its shuffle output directly in the partition its *consumer* needs
//!   (block-matmul routes `(i, j, k)` replicas by output index `(i, j)`,
//!   which makes the summing reduce narrow and saves a whole shuffle).
//!
//! Ops that re-key elements drop the partitioner; ops that provably keep
//! keys in place (e.g. a payload-only map) may re-stamp it with
//! [`Rdd::with_partitioner`]. Driver round-trips ([`Cluster::collect`])
//! are counted in [`MetricsSnapshot::driver_collects`]; the
//! partitioner-aware block-matrix pipeline records none.

mod executor;
mod faults;
mod metrics;
mod rdd;
mod scheduler;
mod shuffle;

pub use executor::WorkerPool;
pub use faults::FaultPlan;
pub use metrics::{
    ConvergenceReport, ConvergenceTotals, MethodStats, Metrics, MetricsScope, MetricsSnapshot,
    MetricsTotals, PlanNodeReport, ResilienceTotals, StageReport,
};
pub use rdd::{Partitioner, Rdd};
pub use scheduler::{list_schedule_makespan, VirtualClock};
pub use shuffle::{executor_of_partition, hash_partition, Bytes};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::config::ClusterConfig;
use crate::exec::{ExecPool, StageExecStats};
use crate::util::plock;

/// A simulated Spark cluster: topology + task execution + virtual clock +
/// metrics. One `Cluster` corresponds to one Spark application context.
pub struct Cluster {
    config: ClusterConfig,
    metrics: Metrics,
    vclock: Mutex<VirtualClock>,
    pool: WorkerPool,
    /// Work-stealing partition runtime (`ClusterConfig::exec_threads > 1`):
    /// the process-wide pool every compute stage, shuffle wave and
    /// straggler sleep fans out on. `None` keeps the legacy sequential /
    /// per-stage-scoped-thread path.
    exec: Option<Arc<ExecPool>>,
    /// Explicit stage-id allocator shared by every executor path, so the
    /// fault stream sees identical `(stage, partition, attempt)` triples
    /// regardless of which executor ran the stage (see
    /// [`FaultPlan::apply_at`]).
    stage_seq: AtomicU64,
    /// Interconnect time of the most recent shuffle exchange, not yet
    /// charged to the clock: Spark overlaps shuffle fetch with reduce-side
    /// execution, so it is folded into the next narrow stage as
    /// `max(compute, transfer)` rather than summed.
    pending_shuffle: Mutex<f64>,
    /// Seeded fault-injection schedule (`ClusterConfig::fault_seed`);
    /// `None` disables the chaos layer entirely — every stage runs the
    /// exact pre-existing path behind a single `Option` check.
    fault: Option<FaultPlan>,
}

impl Cluster {
    pub fn new(config: ClusterConfig) -> Self {
        let pool = WorkerPool::new(config.worker_threads);
        let exec = if config.exec_threads > 1 {
            Some(ExecPool::shared(config.exec_threads))
        } else {
            None
        };
        let metrics = Metrics::with_history(config.metrics_history);
        let fault = FaultPlan::from_config(&config);
        Cluster {
            config,
            metrics,
            vclock: Mutex::new(VirtualClock::new()),
            pool,
            exec,
            stage_seq: AtomicU64::new(0),
            pending_shuffle: Mutex::new(0.0),
            fault,
        }
    }

    /// Allocate the next stage id — the executor-independent key into the
    /// fault stream. Allocated once per compute stage, in submission
    /// order, exactly where the implicit per-`apply` numbering used to
    /// advance.
    fn next_stage_id(&self) -> u64 {
        self.stage_seq.fetch_add(1, Ordering::Relaxed)
    }

    pub fn config(&self) -> &ClusterConfig {
        &self.config
    }

    /// Total simulated task slots (the paper's `cores`).
    pub fn slots(&self) -> usize {
        self.config.total_cores()
    }

    /// Current virtual wall-clock seconds consumed by this cluster.
    pub fn virtual_secs(&self) -> f64 {
        plock(&self.vclock).now()
    }

    /// Reset the virtual clock and metrics (new measurement window).
    pub fn reset(&self) {
        plock(&self.vclock).reset();
        *plock(&self.pending_shuffle) = 0.0;
        self.metrics.reset();
    }

    pub fn metrics(&self) -> MetricsSnapshot {
        self.metrics.snapshot()
    }

    /// Cheap aggregate counters — the plan executor brackets each plan
    /// node's lowering with these to attribute the delta to that node.
    pub fn metrics_totals(&self) -> MetricsTotals {
        self.metrics.totals()
    }

    /// Aggregate counters restricted to the calling thread's metrics
    /// scope — what the plan executor actually brackets with, so two
    /// jobs interleaving stages on this cluster cannot double-count each
    /// other's work into their plan-node windows.
    pub fn metrics_totals_current(&self) -> MetricsTotals {
        self.metrics.totals_for_scope(Metrics::current_scope())
    }

    /// Per-method snapshot of everything one scope (job) recorded.
    pub fn metrics_scoped(&self, scope: u64) -> MetricsSnapshot {
        self.metrics.snapshot_scope(scope)
    }

    /// Stamp one lowered plan node's measured cost window.
    pub fn record_plan_node(&self, report: PlanNodeReport) {
        self.metrics.record_plan_node(report)
    }

    /// Count plan-node values dropped by the LRU byte-budget evictor.
    pub fn record_cache_eviction(&self, count: usize, bytes: u64) {
        self.metrics.record_cache_eviction(count, bytes)
    }

    /// Drop one scope's retained metric records (stage history, plan-node
    /// reports, totals) — the service calls this when a job reaches a
    /// terminal phase, after taking the job's outcome snapshot. Returns
    /// the number of stage records released.
    pub fn release_metrics_scope(&self, scope: u64) -> usize {
        self.metrics.release_scope(scope)
    }

    /// Update the pinned-bytes gauge surfaced by
    /// [`MetricsSnapshot::pinned_bytes`].
    pub fn set_pinned_bytes(&self, bytes: u64) {
        self.metrics.set_pinned_bytes(bytes)
    }

    /// Fold recovery counters (retries, speculation, checkpoints) into
    /// the registry — attributed to the calling thread's scope. The
    /// checkpoint layer records its written/restored counts through
    /// this; the stage runner records retry/speculation deltas itself.
    pub fn record_resilience(&self, delta: &ResilienceTotals) {
        self.metrics.record_resilience(delta)
    }

    /// Cluster-lifetime recovery counters (all-zero when fault injection
    /// is disabled and no checkpoints were written or restored).
    pub fn resilience_totals(&self) -> ResilienceTotals {
        self.metrics.resilience_totals()
    }

    /// Recovery counters attributed to one job scope.
    pub fn resilience_for_scope(&self, scope: u64) -> ResilienceTotals {
        self.metrics.resilience_for_scope(scope)
    }

    /// Record one iterative run's convergence trajectory — attributed to
    /// the calling thread's scope (the iterative schemes report through
    /// this at the end of their driver loop).
    pub fn record_convergence(&self, report: ConvergenceReport) {
        self.metrics.record_convergence(report)
    }

    /// Cluster-lifetime convergence counters (all-zero when no iterative
    /// scheme has run).
    pub fn convergence_totals(&self) -> ConvergenceTotals {
        self.metrics.convergence_totals()
    }

    /// Convergence reports attributed to one job scope.
    pub fn convergence_for_scope(&self, scope: u64) -> Vec<ConvergenceReport> {
        self.metrics.convergence_for_scope(scope)
    }

    // ---------- RDD creation ----------

    /// Distribute `items` across `nparts` partitions round-robin
    /// (Spark `parallelize`).
    pub fn parallelize<T>(&self, items: Vec<T>, nparts: usize) -> Rdd<T> {
        Rdd::from_items(items, nparts.max(1))
    }

    // ---------- narrow transformations ----------

    /// Per-element map; one task per partition; no shuffle.
    pub fn map<T: Send, U: Send>(
        &self,
        method: &str,
        input: Rdd<T>,
        f: impl Fn(T) -> U + Sync,
    ) -> Rdd<U> {
        self.run_narrow(method, input, |part| {
            part.into_iter().map(&f).collect()
        })
    }

    /// Per-element filter; one task per partition; no shuffle. Keeps the
    /// input's partitioner (elements never move, Spark does the same).
    pub fn filter<T: Send>(
        &self,
        method: &str,
        input: Rdd<T>,
        pred: impl Fn(&T) -> bool + Sync,
    ) -> Rdd<T> {
        let partitioner = input.partitioner();
        let out = self.run_narrow(method, input, |part| {
            part.into_iter().filter(|x| pred(x)).collect()
        });
        match partitioner {
            Some(p) => out.with_partitioner(p),
            None => out,
        }
    }

    /// Per-element flat map; one task per partition; no shuffle.
    pub fn flat_map<T: Send, U: Send, I: IntoIterator<Item = U>>(
        &self,
        method: &str,
        input: Rdd<T>,
        f: impl Fn(T) -> I + Sync,
    ) -> Rdd<U> {
        self.run_narrow(method, input, |part| {
            part.into_iter().flat_map(&f).collect()
        })
    }

    /// Concatenate two RDDs' partition lists (Spark `union` — free).
    pub fn union<T>(&self, a: Rdd<T>, b: Rdd<T>) -> Rdd<T> {
        a.union(b)
    }

    /// Materialize all elements on the driver (Spark `collect`). Counted
    /// in [`MetricsSnapshot::driver_collects`] — the partitioner-aware op
    /// pipeline is measured by recording zero of these.
    pub fn collect<T>(&self, rdd: Rdd<T>) -> Vec<T> {
        self.metrics.record_driver_collect();
        rdd.into_items()
    }

    /// Zip two co-partitioned RDDs partition-by-partition: one task per
    /// partition pair, **no shuffle** (Spark `zipPartitions`). The inputs
    /// must have equal partition counts — callers align them first (a
    /// no-op for RDDs that already share a partitioner).
    pub fn zip_partitions<A: Send, B: Send, R: Send>(
        &self,
        method: &str,
        left: Rdd<A>,
        right: Rdd<B>,
        f: impl Fn(Vec<A>, Vec<B>) -> Vec<R> + Sync,
    ) -> Rdd<R> {
        assert_eq!(
            left.num_partitions(),
            right.num_partitions(),
            "zip_partitions needs co-partitioned inputs"
        );
        let tasks: Vec<(Vec<A>, Vec<B>)> = left
            .into_partitions()
            .into_iter()
            .zip(right.into_partitions())
            .collect();
        self.run_narrow_tasks(method, tasks, |(a, b)| f(a, b))
    }

    /// Three-way [`zip_partitions`](Self::zip_partitions) — lets a fused
    /// op (block-matmul's multiply−subtract) consume a third co-partitioned
    /// operand inside the same narrow stage.
    pub fn zip_partitions3<A: Send, B: Send, C: Send, R: Send>(
        &self,
        method: &str,
        left: Rdd<A>,
        mid: Rdd<B>,
        right: Rdd<C>,
        f: impl Fn(Vec<A>, Vec<B>, Vec<C>) -> Vec<R> + Sync,
    ) -> Rdd<R> {
        assert!(
            left.num_partitions() == mid.num_partitions()
                && left.num_partitions() == right.num_partitions(),
            "zip_partitions3 needs co-partitioned inputs"
        );
        let tasks: Vec<((Vec<A>, Vec<B>), Vec<C>)> = left
            .into_partitions()
            .into_iter()
            .zip(mid.into_partitions())
            .zip(right.into_partitions())
            .collect();
        self.run_narrow_tasks(method, tasks, |((a, b), c)| f(a, b, c))
    }

    // ---------- wide transformations (shuffle) ----------

    /// Re-place elements under `partitioner` via `part_fn` (which must
    /// realize that partitioner's placement — the stamp is the caller's
    /// promise). A no-op (no stage, no bytes) when the input already
    /// carries that partitioner; otherwise one counted shuffle exchange.
    pub fn partition_items_by<T: Bytes + Send>(
        &self,
        method: &str,
        input: Rdd<T>,
        partitioner: Partitioner,
        part_fn: impl Fn(&T) -> usize + Sync,
    ) -> Rdd<T> {
        if input.partitioner() == Some(partitioner) {
            return input;
        }
        let np = partitioner.nparts();
        let executors = self.config.total_executors();
        let (buckets, moved, total, stats) = match &self.exec {
            Some(pool) => {
                shuffle::route_parallel(pool, input, np, executors, part_fn, T::size_bytes)
            }
            None => {
                let t0 = std::time::Instant::now();
                let (b, m, t) = shuffle::route(input, np, executors, part_fn, T::size_bytes);
                (b, m, t, wall_only_stats(t0))
            }
        };
        self.charge_shuffle(method, moved, total, stats);
        Rdd::from_partitions_with(buckets, partitioner)
    }

    /// [`partition_items_by`](Self::partition_items_by) for keyed pairs:
    /// routes by key, counts value payload bytes.
    pub fn partition_pairs_by<K: Send, V: Bytes + Send>(
        &self,
        method: &str,
        input: Rdd<(K, V)>,
        partitioner: Partitioner,
        part_fn: impl Fn(&K) -> usize + Sync,
    ) -> Rdd<(K, V)> {
        if input.partitioner() == Some(partitioner) {
            return input;
        }
        let np = partitioner.nparts();
        let executors = self.config.total_executors();
        let (buckets, moved, total, stats) = match &self.exec {
            Some(pool) => shuffle::route_parallel(
                pool,
                input,
                np,
                executors,
                |(k, _)| part_fn(k),
                |(_, v)| v.size_bytes(),
            ),
            None => {
                let t0 = std::time::Instant::now();
                let (b, m, t) = shuffle::route(
                    input,
                    np,
                    executors,
                    |(k, _)| part_fn(k),
                    |(_, v)| v.size_bytes(),
                );
                (b, m, t, wall_only_stats(t0))
            }
        };
        self.charge_shuffle(method, moved, total, stats);
        Rdd::from_partitions_with(buckets, partitioner)
    }

    /// Group values by key into `nparts` output partitions. Skips the
    /// exchange when the input is already hash-partitioned onto `nparts`.
    pub fn group_by_key<K, V>(
        &self,
        method: &str,
        input: Rdd<(K, V)>,
        nparts: usize,
    ) -> Rdd<(K, Vec<V>)>
    where
        K: std::hash::Hash + Eq + Clone + Send,
        V: Send + Bytes,
    {
        let target = Partitioner::Hash {
            nparts: nparts.max(1),
        };
        let buckets = if input.partitioner() == Some(target) {
            input
        } else {
            self.shuffle_exchange(method, input, nparts)
        };
        self.run_narrow(method, buckets, |part| {
            shuffle::group_pairs(part).into_iter().collect()
        })
        .with_partitioner(target)
    }

    /// Co-group two keyed RDDs (the paper's `multiply` uses this to bring
    /// matching A/B blocks to the same reducer).
    pub fn cogroup<K, V, W>(
        &self,
        method: &str,
        left: Rdd<(K, V)>,
        right: Rdd<(K, W)>,
        nparts: usize,
    ) -> Rdd<(K, (Vec<V>, Vec<W>))>
    where
        K: std::hash::Hash + Eq + Clone + Send,
        V: Send + Bytes,
        W: Send + Bytes,
    {
        let tagged_l = self.map("cogroup-tag", left, |(k, v)| (k, shuffle::Either::L(v)));
        let tagged_r = self.map("cogroup-tag", right, |(k, w)| (k, shuffle::Either::R(w)));
        let both = self.union(tagged_l, tagged_r);
        let grouped = self.group_by_key(method, both, nparts);
        self.run_narrow(method, grouped, |part| {
            part.into_iter()
                .map(|(k, vals)| {
                    let mut ls = Vec::new();
                    let mut rs = Vec::new();
                    for v in vals {
                        match v {
                            shuffle::Either::L(v) => ls.push(v),
                            shuffle::Either::R(w) => rs.push(w),
                        }
                    }
                    (k, (ls, rs))
                })
                .collect()
        })
    }

    /// Shuffle + per-key reduction (used by the replicated block-matmul's
    /// sum stage). Skips the exchange — a fully narrow reduce — when the
    /// input is already hash-partitioned onto `nparts`.
    pub fn reduce_by_key<K, V>(
        &self,
        method: &str,
        input: Rdd<(K, V)>,
        nparts: usize,
        reduce: impl Fn(V, V) -> V + Sync,
    ) -> Rdd<(K, V)>
    where
        K: std::hash::Hash + Eq + Clone + Send,
        V: Send + Bytes,
    {
        let target = Partitioner::Hash {
            nparts: nparts.max(1),
        };
        let buckets = if input.partitioner() == Some(target) {
            input
        } else {
            self.shuffle_exchange(method, input, nparts)
        };
        self.run_narrow(method, buckets, |part| {
            shuffle::group_pairs(part)
                .into_iter()
                .filter_map(|(k, vals)| vals.into_iter().reduce(&reduce).map(|v| (k, v)))
                .collect()
        })
        .with_partitioner(target)
    }

    // ---------- internals ----------

    /// Execute one narrow stage: one task per partition, real execution on
    /// the worker pool, measured durations list-scheduled onto the simulated
    /// slots, metrics attributed to `method`.
    fn run_narrow<T: Send, U: Send>(
        &self,
        method: &str,
        input: Rdd<T>,
        per_partition: impl Fn(Vec<T>) -> Vec<U> + Sync,
    ) -> Rdd<U> {
        self.run_narrow_tasks(method, input.into_partitions(), per_partition)
    }

    /// Narrow-stage core over arbitrary per-task inputs (a plain partition
    /// for `run_narrow`, a tuple of zipped partitions for `zip_partitions`).
    fn run_narrow_tasks<T: Send, U: Send>(
        &self,
        method: &str,
        tasks: Vec<T>,
        per_task: impl Fn(T) -> Vec<U> + Sync,
    ) -> Rdd<U> {
        let ntasks = tasks.len();
        let stage_id = self.next_stage_id();
        let (outputs, mut durations, mut stats) = self.execute_stage(tasks, &per_task);
        if let Some(plan) = &self.fault {
            let (effective, sleeps) = self.apply_faults(method, plan, stage_id, &durations);
            durations = effective;
            // Under the pool, straggle is a *real* parallel sleep wave —
            // speculation wins actual wall clock, not just virtual time.
            if let Some(pool) = &self.exec {
                stats.wall_ns += pool.sleep_parallel(&sleeps);
            }
        }
        let makespan = list_schedule_makespan(&durations, self.slots());
        // Overlap any pending shuffle transfer with this stage's execution.
        let pending = std::mem::take(&mut *plock(&self.pending_shuffle));
        plock(&self.vclock).advance(makespan.max(pending));
        self.metrics.record_stage(StageReport {
            method: method.to_string(),
            tasks: ntasks,
            exchange: false,
            compute_secs: durations.iter().sum(),
            makespan_secs: makespan,
            shuffle_bytes: 0,
            shuffle_total_bytes: 0,
            shuffle_secs: 0.0,
            task_durations: durations,
            wall_ns: stats.wall_ns,
            queue_ns: stats.queue_ns,
            run_ns: stats.run_ns,
            steals: stats.steals,
        });
        Rdd::from_partitions(outputs)
    }

    /// Execute one wave of tasks: on the work-stealing partition runtime
    /// when `exec_threads > 1`, else on the legacy per-stage pool (inline
    /// for `worker_threads == 1`) with coarse wall timing so the measured
    /// dimension is populated on every path.
    fn execute_stage<T: Send, U: Send>(
        &self,
        tasks: Vec<T>,
        f: impl Fn(T) -> U + Sync,
    ) -> (Vec<U>, Vec<f64>, StageExecStats) {
        let ntasks = tasks.len();
        if let Some(pool) = &self.exec {
            if ntasks > 1 {
                let run = pool.run_stage(tasks, &f);
                return (run.outputs, run.durations, run.stats);
            }
        }
        let t0 = std::time::Instant::now();
        let (outputs, durations) = self.pool.run_tasks(tasks, &f);
        let stats = StageExecStats {
            tasks: ntasks,
            steals: 0,
            queue_ns: 0,
            run_ns: (durations.iter().sum::<f64>() * 1e9) as u64,
            wall_ns: t0.elapsed().as_nanos() as u64,
        };
        (outputs, durations, stats)
    }

    /// Run one stage's measured durations through the fault plan: the
    /// effective durations (wasted attempts + backoffs + straggle/
    /// speculation) replace the clean ones for virtual-time accounting,
    /// recovery counters land in the metrics, and a spent retry budget
    /// is job-fatal — the panic names the stage and partition, and the
    /// service's per-job `catch_unwind` turns it into a Failed terminal.
    /// Also returns the per-task real-sleep straggle excess (see
    /// [`faults::StageFaultOutcome::sleeps`]).
    fn apply_faults(
        &self,
        method: &str,
        plan: &FaultPlan,
        stage_id: u64,
        durations: &[f64],
    ) -> (Vec<f64>, Vec<f64>) {
        let outcome = plan.apply_at(stage_id, durations);
        self.metrics.record_resilience(&outcome.delta);
        if let Some(partition) = outcome.exhausted {
            panic!(
                "stage `{method}` partition {partition}: task failed after {} attempts \
                 (retry budget exhausted)",
                self.config.task_retries + 1
            );
        }
        (outcome.durations, outcome.sleeps)
    }

    /// Charge one shuffle exchange to the interconnect and the metrics.
    /// Transfers happen in parallel across executor pairs; charge the
    /// aggregate volume spread over the executor count, plus one latency.
    /// The time is deferred: folded into the next narrow stage
    /// (fetch/execute overlap). `stats` carries the exchange's *real*
    /// execution timings (map/reduce waves under the pool).
    fn charge_shuffle(
        &self,
        method: &str,
        moved_bytes: u64,
        total_bytes: u64,
        stats: StageExecStats,
    ) {
        let executors = self.config.total_executors();
        let secs = if moved_bytes == 0 {
            0.0
        } else {
            self.config
                .network
                .transfer_secs((moved_bytes / executors.max(1) as u64).max(1))
        };
        *plock(&self.pending_shuffle) += secs;
        self.metrics.record_stage(StageReport {
            method: method.to_string(),
            tasks: 0,
            exchange: true,
            compute_secs: 0.0,
            makespan_secs: 0.0,
            shuffle_bytes: moved_bytes,
            shuffle_total_bytes: total_bytes,
            shuffle_secs: secs,
            task_durations: Vec::new(),
            wall_ns: stats.wall_ns,
            queue_ns: stats.queue_ns,
            run_ns: stats.run_ns,
            steals: stats.steals,
        });
    }

    /// Exchange phase of a wide op: hash-partition elements into `nparts`
    /// buckets, counting bytes that cross simulated executor boundaries and
    /// charging them to the interconnect.
    fn shuffle_exchange<K, V>(
        &self,
        method: &str,
        input: Rdd<(K, V)>,
        nparts: usize,
    ) -> Rdd<(K, V)>
    where
        K: std::hash::Hash + Eq + Clone + Send,
        V: Send + Bytes,
    {
        let executors = self.config.total_executors();
        let np = nparts.max(1);
        let (buckets, moved_bytes, total_bytes, stats) = match &self.exec {
            Some(pool) => shuffle::route_parallel(
                pool,
                input,
                np,
                executors,
                |(k, _)| hash_partition(k, np),
                |(_, v)| v.size_bytes(),
            ),
            None => {
                let t0 = std::time::Instant::now();
                let (b, m, t) = shuffle::exchange(input, nparts, executors);
                (b, m, t, wall_only_stats(t0))
            }
        };
        self.charge_shuffle(method, moved_bytes, total_bytes, stats);
        Rdd::from_partitions(buckets)
    }

    /// Run an arbitrary closure as a single named task on the pool —
    /// used for driver-side serial steps that still cost virtual time
    /// (e.g. the paper's single-block leaf inversion when b = 1).
    pub fn run_single<T: Send>(&self, method: &str, f: impl FnOnce() -> T + Send) -> T {
        let stage_id = self.next_stage_id();
        let t0 = std::time::Instant::now();
        let out = f();
        let mut dt = t0.elapsed().as_secs_f64();
        let run_ns = t0.elapsed().as_nanos() as u64;
        let mut wall_ns = run_ns;
        if let Some(plan) = &self.fault {
            let (eff, sleeps) = self.apply_faults(method, plan, stage_id, &[dt]);
            dt = eff[0];
            if let Some(pool) = &self.exec {
                wall_ns += pool.sleep_parallel(&sleeps);
            }
        }
        plock(&self.vclock).advance(dt);
        self.metrics.record_stage(StageReport {
            method: method.to_string(),
            tasks: 1,
            exchange: false,
            compute_secs: dt,
            makespan_secs: dt,
            shuffle_bytes: 0,
            shuffle_total_bytes: 0,
            shuffle_secs: 0.0,
            task_durations: vec![dt],
            wall_ns,
            queue_ns: 0,
            run_ns,
            steals: 0,
        });
        out
    }
}

/// Coarse stage stats for the sequential paths: only the wall clock is
/// measured (no queueing, no steals).
fn wall_only_stats(t0: std::time::Instant) -> StageExecStats {
    StageExecStats {
        wall_ns: t0.elapsed().as_nanos() as u64,
        ..StageExecStats::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn cluster(cores: usize) -> Cluster {
        Cluster::new(ClusterConfig::local(cores))
    }

    #[test]
    fn map_preserves_all_elements() {
        let c = cluster(4);
        let rdd = c.parallelize((0..100).collect(), 8);
        let out = c.map("test", rdd, |x: i32| x * 2);
        let mut v = c.collect(out);
        v.sort_unstable();
        assert_eq!(v, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn filter_keeps_matching() {
        let c = cluster(2);
        let rdd = c.parallelize((0..50).collect(), 4);
        let out = c.filter("test", rdd, |x: &i32| x % 5 == 0);
        let mut v = c.collect(out);
        v.sort_unstable();
        assert_eq!(v, vec![0, 5, 10, 15, 20, 25, 30, 35, 40, 45]);
    }

    #[test]
    fn flat_map_expands() {
        let c = cluster(2);
        let rdd = c.parallelize(vec![1, 2, 3], 2);
        let out = c.flat_map("test", rdd, |x: i32| vec![x; x as usize]);
        let mut v = c.collect(out);
        v.sort_unstable();
        assert_eq!(v, vec![1, 2, 2, 3, 3, 3]);
    }

    #[test]
    fn union_concatenates() {
        let c = cluster(2);
        let a = c.parallelize(vec![1, 2], 1);
        let b = c.parallelize(vec![3], 1);
        let mut v = c.collect(c.union(a, b));
        v.sort_unstable();
        assert_eq!(v, vec![1, 2, 3]);
    }

    #[test]
    fn group_by_key_groups_everything() {
        let c = cluster(4);
        let pairs: Vec<(u32, i32)> = (0..40).map(|i| (i % 4, i as i32)).collect();
        let rdd = c.parallelize(pairs, 8);
        let grouped = c.group_by_key("test", rdd, 4);
        let out = c.collect(grouped);
        assert_eq!(out.len(), 4);
        for (k, vals) in out {
            assert_eq!(vals.len(), 10, "key {k}");
            for v in vals {
                assert_eq!(v as u32 % 4, k);
            }
        }
    }

    #[test]
    fn cogroup_aligns_keys() {
        let c = cluster(4);
        let left = c.parallelize(vec![(1u32, 10), (2, 20), (1, 11)], 2);
        let right = c.parallelize(vec![(1u32, -1), (3, -3)], 2);
        let mut out = c.collect(c.cogroup("test", left, right, 3));
        out.sort_by_key(|(k, _)| *k);
        assert_eq!(out.len(), 3);
        let (k1, (mut l1, r1)) = out[0].clone();
        l1.sort_unstable();
        assert_eq!((k1, l1, r1), (1, vec![10, 11], vec![-1]));
        assert_eq!(out[1], (2, (vec![20], vec![])));
        assert_eq!(out[2], (3, (vec![], vec![-3])));
    }

    #[test]
    fn reduce_by_key_sums() {
        let c = cluster(4);
        let pairs: Vec<(u32, i32)> = (0..30).map(|i| (i % 3, 1)).collect();
        let rdd = c.parallelize(pairs, 5);
        let mut out = c.collect(c.reduce_by_key("test", rdd, 3, |a, b| a + b));
        out.sort_by_key(|(k, _)| *k);
        assert_eq!(out, vec![(0, 10), (1, 10), (2, 10)]);
    }

    #[test]
    fn virtual_clock_advances_and_resets() {
        let c = cluster(2);
        assert_eq!(c.virtual_secs(), 0.0);
        let rdd = c.parallelize((0..1000).collect(), 4);
        let _ = c.collect(c.map("test", rdd, |x: i64| x * x));
        assert!(c.virtual_secs() > 0.0);
        c.reset();
        assert_eq!(c.virtual_secs(), 0.0);
    }

    #[test]
    fn metrics_attribute_methods() {
        let c = cluster(2);
        let rdd = c.parallelize((0..10).collect(), 2);
        let out = c.map("alpha", rdd, |x: i32| x + 1);
        let _ = c.collect(c.filter("beta", out, |_| true));
        let snap = c.metrics();
        assert!(snap.method("alpha").is_some());
        assert!(snap.method("beta").is_some());
        assert_eq!(snap.method("alpha").unwrap().tasks, 2);
    }

    #[test]
    fn run_single_counts_as_task() {
        let c = cluster(1);
        let out = c.run_single("leafNode", || 7 * 6);
        assert_eq!(out, 42);
        assert_eq!(c.metrics().method("leafNode").unwrap().calls, 1);
        assert!(c.virtual_secs() > 0.0);
    }

    #[test]
    fn shuffle_records_bytes() {
        // 2 executors so some data must cross the boundary.
        let mut cfg = ClusterConfig::local(2);
        cfg.executors_per_node = 2;
        let c = Cluster::new(cfg);
        let pairs: Vec<(u32, i32)> = (0..64).map(|i| (i, i as i32)).collect();
        let rdd = c.parallelize(pairs, 4);
        let _ = c.collect(c.group_by_key("shufl", rdd, 4));
        let snap = c.metrics();
        assert!(snap.method("shufl").unwrap().shuffle_bytes > 0);
    }

    #[test]
    fn zip_partitions_pairs_tasks() {
        let c = cluster(2);
        let a = Rdd::from_partitions(vec![vec![1, 2], vec![3]]);
        let b = Rdd::from_partitions(vec![vec![10], vec![20, 30]]);
        let out = c.zip_partitions("zip", a, b, |xs: Vec<i32>, ys: Vec<i32>| {
            vec![xs.iter().sum::<i32>() + ys.iter().sum::<i32>()]
        });
        assert_eq!(out.partitions(), &[vec![13], vec![53]]);
        // Narrow: no exchange stage, no shuffle bytes.
        let s = c.metrics();
        assert_eq!(s.method("zip").unwrap().shuffle_stages, 0);
        assert_eq!(s.method("zip").unwrap().shuffle_bytes, 0);
    }

    #[test]
    #[should_panic(expected = "co-partitioned")]
    fn zip_partitions_rejects_mismatched_layouts() {
        let c = cluster(2);
        let a = Rdd::from_partitions(vec![vec![1]]);
        let b = Rdd::from_partitions(vec![vec![1], vec![2]]);
        let _ = c.zip_partitions("zip", a, b, |xs: Vec<i32>, _: Vec<i32>| xs);
    }

    #[test]
    fn reduce_by_key_skips_exchange_on_copartitioned_input() {
        let mut cfg = ClusterConfig::local(2);
        cfg.executors_per_node = 2;
        let c = Cluster::new(cfg);
        let pairs: Vec<(u32, i32)> = (0..40).map(|i| (i % 8, 1)).collect();
        let rdd = c.parallelize(pairs, 4);
        let once = c.reduce_by_key("first", rdd, 4, |a, b| a + b);
        assert_eq!(once.partitioner(), Some(Partitioner::Hash { nparts: 4 }));
        // Re-reducing the already-partitioned output is fully narrow.
        let twice = c.reduce_by_key("second", once, 4, |a, b| a + b);
        let snap = c.metrics();
        assert_eq!(snap.method("first").unwrap().shuffle_stages, 1);
        assert_eq!(snap.method("second").unwrap().shuffle_stages, 0);
        assert_eq!(snap.method("second").unwrap().shuffle_bytes, 0);
        let mut out = c.collect(twice);
        out.sort_by_key(|(k, _)| *k);
        assert_eq!(out.len(), 8);
        assert!(out.iter().all(|&(_, v)| v == 5));
    }

    #[test]
    fn partition_items_by_is_noop_on_matching_partitioner() {
        let mut cfg = ClusterConfig::local(2);
        cfg.executors_per_node = 2;
        let c = Cluster::new(cfg);
        let target = Partitioner::Hash { nparts: 4 };
        let rdd = c.parallelize((0..32u64).collect(), 8);
        let placed = c.partition_items_by("place", rdd, target, |x| hash_partition(x, 4));
        assert_eq!(placed.partitioner(), Some(target));
        assert!(c.metrics().method("place").unwrap().shuffle_bytes > 0);
        // Second placement under the same partitioner: free.
        let again = c.partition_items_by("replace", placed, target, |x| hash_partition(x, 4));
        assert!(c.metrics().method("replace").is_none());
        assert_eq!(again.len(), 32);
    }

    #[test]
    fn collect_counts_driver_round_trips() {
        let c = cluster(2);
        assert_eq!(c.metrics().driver_collects(), 0);
        let rdd = c.parallelize(vec![1, 2, 3], 2);
        let _ = c.collect(rdd);
        assert_eq!(c.metrics().driver_collects(), 1);
        c.reset();
        assert_eq!(c.metrics().driver_collects(), 0);
    }

    #[test]
    fn fault_injection_changes_time_not_results() {
        let clean = cluster(4);
        let mut cfg = ClusterConfig::local(4);
        cfg.fault_seed = Some(0xC0FFEE);
        cfg.fault_rate = 0.2;
        let chaotic = Cluster::new(cfg);
        let run = |c: &Cluster| {
            let rdd = c.parallelize((0..512i64).collect(), 16);
            let doubled = c.map("chaos-map", rdd, |x: i64| x * 2);
            let mut v = c.collect(c.filter("chaos-filter", doubled, |x| x % 4 == 0));
            v.sort_unstable();
            v
        };
        assert_eq!(run(&clean), run(&chaotic), "faults never change values");
        assert!(!clean.resilience_totals().any(), "disabled path stays inert");
        let r = chaotic.resilience_totals();
        assert!(r.retries > 0, "rate 0.2 over 32 tasks must retry");
        assert_eq!(r.retry_exhausted, 0);
        // Retried/straggling stages charge more virtual time.
        assert!(chaotic.virtual_secs() > 0.0);
    }

    #[test]
    fn exhausted_retry_budget_names_stage_and_partition() {
        let mut cfg = ClusterConfig::local(2);
        cfg.fault_seed = Some(9);
        cfg.fault_rate = 1.0;
        cfg.fault_kinds = crate::config::FaultKinds {
            task_panic: true,
            task_error: true,
            straggle: false,
        };
        cfg.task_retries = 2;
        let c = Cluster::new(cfg);
        let err = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let rdd = c.parallelize((0..8).collect(), 4);
            let _ = c.collect(c.map("doomed", rdd, |x: i32| x));
        }))
        .expect_err("budget must exhaust at rate 1.0");
        let msg = err
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "non-string panic".into());
        assert!(msg.contains("stage `doomed`"), "panic names the stage: {msg}");
        assert!(msg.contains("partition"), "panic names the partition: {msg}");
        assert!(msg.contains("3 attempts"), "panic names the budget: {msg}");
        assert!(c.resilience_totals().retry_exhausted > 0);
    }

    #[test]
    fn multithreaded_pool_same_results() {
        let mut cfg = ClusterConfig::local(4);
        cfg.worker_threads = 3;
        let c = Cluster::new(cfg);
        let rdd = c.parallelize((0..1000).collect(), 16);
        let mut v = c.collect(c.map("mt", rdd, |x: i64| x * 3));
        v.sort_unstable();
        assert_eq!(v, (0..1000).map(|x| x * 3).collect::<Vec<_>>());
    }

    /// One mixed narrow+wide pipeline, element order included — the
    /// parallel runtime's determinism contract is exact equality, not
    /// set equality.
    fn pipeline_fingerprint(c: &Cluster) -> Vec<(u64, i64)> {
        let rdd = c.parallelize((0..600i64).collect(), 12);
        let mapped = c.map("exec-map", rdd, |x: i64| ((x % 17) as u64, x * x));
        let reduced = c.reduce_by_key("exec-reduce", mapped, 6, |a, b| a + b);
        let filtered = c.filter("exec-filter", reduced, |(_, v)| *v % 2 == 0);
        c.collect(filtered)
    }

    #[test]
    fn exec_pool_stages_bit_identical_to_sequential() {
        let sequential = cluster(4);
        let baseline = pipeline_fingerprint(&sequential);
        for threads in [2usize, 4, 8] {
            let mut cfg = ClusterConfig::local(4);
            cfg.exec_threads = threads;
            let parallel = Cluster::new(cfg);
            assert_eq!(
                pipeline_fingerprint(&parallel),
                baseline,
                "exec_threads={threads} must reproduce the sequential run exactly"
            );
        }
    }

    #[test]
    fn fault_stream_is_executor_independent() {
        // Straggle/speculation excluded: their *counters* are coupled to
        // measured durations, which legitimately differ across executors.
        // Panic/error injection must hit identical (stage, partition,
        // attempt) triples on every executor path.
        let chaotic = |threads: usize| {
            let mut cfg = ClusterConfig::local(4);
            cfg.exec_threads = threads;
            cfg.fault_seed = Some(0xDEC0DE);
            cfg.fault_rate = 0.25;
            cfg.fault_kinds = crate::config::FaultKinds {
                task_panic: true,
                task_error: true,
                straggle: false,
            };
            cfg.task_retries = 10;
            Cluster::new(cfg)
        };
        let base_cluster = chaotic(1);
        let base = pipeline_fingerprint(&base_cluster);
        let base_retries = base_cluster.resilience_totals().retries;
        assert!(base_retries > 0, "rate 0.25 must inject retries");
        for threads in [2usize, 4] {
            let c = chaotic(threads);
            assert_eq!(pipeline_fingerprint(&c), base, "results at exec_threads={threads}");
            assert_eq!(
                c.resilience_totals().retries,
                base_retries,
                "identical fault stream at exec_threads={threads}"
            );
        }
    }

    #[test]
    fn stages_record_wall_clock_and_shuffle_timings() {
        let mut cfg = ClusterConfig::local(4);
        cfg.exec_threads = 4;
        let c = Cluster::new(cfg);
        let _ = pipeline_fingerprint(&c);
        let snap = c.metrics();
        let map = snap.method("exec-map").expect("map stats recorded");
        assert!(map.wall_secs > 0.0, "narrow stages measure wall clock");
        let red = snap.method("exec-reduce").expect("reduce stats recorded");
        assert!(red.wall_secs > 0.0, "exchange + reduce measure wall clock");
        // Sequential paths also populate the measured dimension.
        let seq = cluster(2);
        let _ = pipeline_fingerprint(&seq);
        let s = seq.metrics();
        assert!(s.method("exec-map").unwrap().wall_secs > 0.0);
    }
}
