//! Partitioned collection — the RDD stand-in.
//!
//! Unlike Spark's lazy lineage graph, this RDD is eager and materialized:
//! the recursion driver (Algorithm 2) forces evaluation at every step
//! anyway, and eager execution is what lets the substrate measure real
//! per-task durations for the virtual-time model.
//!
//! Like Spark, an RDD may carry an optional [`Partitioner`]: a promise
//! that element placement is a known deterministic function of the
//! element's key. Two RDDs sharing the same partitioner are
//! *co-partitioned*: keyed binary ops between them (`zip_partitions`,
//! the pairing half of block-matmul, elementwise subtract) run as
//! **narrow** stages — no shuffle bytes, no driver round-trip. The
//! partitioner is metadata only; constructors that cannot prove placement
//! (`from_items`, `from_partitions`, `union`) leave it `None`, and ops
//! that re-key elements must either re-stamp it (when the key→partition
//! map provably still holds) or drop it.

/// How a keyed RDD's elements are placed into partitions.
///
/// Strictly, the stamp promises *placement*: which partition an element
/// lives in is the partitioner's deterministic function. For most keyed
/// RDDs that function is over the current key; a few producer/consumer
/// pairs use it as a **layout-provenance marker** where placement follows
/// the function over an *ancestor's* key (e.g. `break_mat` stamps its
/// tagged, re-keyed output with the parent's grid so `quadrant` can move
/// whole partitions; block-matmul stamps its `(i, j, k)` pairing streams
/// with the output grid they were routed by). Only consume a stamp under
/// the contract of the op that set it — see the stamping op's docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Partitioner {
    /// `hash_partition(key) % nparts` — Spark's `HashPartitioner`.
    Hash { nparts: usize },
    /// Block-grid placement for distributed matrices: block `(i, j)` of an
    /// `nblocks × nblocks` grid lives alone in partition `i * nblocks + j`
    /// (MLLib's `GridPartitioner` specialized to one block per partition —
    /// the block is the task unit in the paper's cost model).
    Grid { nblocks: usize },
}

impl Partitioner {
    /// Number of partitions this placement function maps onto.
    pub fn nparts(&self) -> usize {
        match self {
            Partitioner::Hash { nparts } => *nparts,
            Partitioner::Grid { nblocks } => nblocks * nblocks,
        }
    }
}

/// A collection split into partitions; one partition = one task.
#[derive(Debug, Clone)]
pub struct Rdd<T> {
    partitions: Vec<Vec<T>>,
    partitioner: Option<Partitioner>,
}

impl<T> Rdd<T> {
    /// Round-robin distribute items over `nparts` partitions.
    pub fn from_items(items: Vec<T>, nparts: usize) -> Self {
        assert!(nparts > 0, "need at least one partition");
        let mut partitions: Vec<Vec<T>> = (0..nparts).map(|_| Vec::new()).collect();
        for (i, item) in items.into_iter().enumerate() {
            partitions[i % nparts].push(item);
        }
        Rdd {
            partitions,
            partitioner: None,
        }
    }

    pub fn from_partitions(partitions: Vec<Vec<T>>) -> Self {
        assert!(!partitions.is_empty(), "need at least one partition");
        Rdd {
            partitions,
            partitioner: None,
        }
    }

    /// Wrap partitions whose layout is known to follow `partitioner`.
    pub fn from_partitions_with(partitions: Vec<Vec<T>>, partitioner: Partitioner) -> Self {
        assert_eq!(
            partitions.len(),
            partitioner.nparts(),
            "partition count must match the partitioner"
        );
        Rdd {
            partitions,
            partitioner: Some(partitioner),
        }
    }

    /// The placement promise, if any.
    pub fn partitioner(&self) -> Option<Partitioner> {
        self.partitioner
    }

    /// Stamp a partitioner the *caller* has proven holds (e.g. a
    /// payload-only map that left every key in place). Panics if the
    /// partition count contradicts the claim.
    pub fn with_partitioner(mut self, partitioner: Partitioner) -> Self {
        assert_eq!(
            self.partitions.len(),
            partitioner.nparts(),
            "partition count must match the partitioner"
        );
        self.partitioner = Some(partitioner);
        self
    }

    pub fn num_partitions(&self) -> usize {
        self.partitions.len()
    }

    pub fn len(&self) -> usize {
        self.partitions.iter().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn partitions(&self) -> &[Vec<T>] {
        &self.partitions
    }

    pub fn into_partitions(self) -> Vec<Vec<T>> {
        self.partitions
    }

    /// Flatten to a single Vec (driver-side `collect`). Prefer
    /// [`crate::cluster::Cluster::collect`], which records the driver
    /// round-trip in the metrics registry.
    pub fn into_items(self) -> Vec<T> {
        self.partitions.into_iter().flatten().collect()
    }

    /// Concatenate partition lists (Spark `union` keeps both lineages'
    /// partitions but cannot promise a joint placement function).
    pub fn union(mut self, other: Rdd<T>) -> Rdd<T> {
        self.partitions.extend(other.partitions);
        self.partitioner = None;
        self
    }

    /// Re-layout by moving *whole partitions*: output partition `t` is
    /// source partition `sources[t]`. A 1-to-1 narrow dependency (Spark's
    /// shuffle-free `coalesce` / partition pruning) — no element crosses
    /// an executor, so no stage and no shuffle bytes are recorded. Each
    /// source may be selected at most once; unselected partitions are
    /// dropped. The partitioner is cleared (the caller re-stamps when the
    /// new layout provably follows one).
    pub fn select_partitions(self, sources: &[usize]) -> Rdd<T> {
        assert!(!sources.is_empty(), "need at least one partition");
        let mut slots: Vec<Option<Vec<T>>> = self.partitions.into_iter().map(Some).collect();
        let partitions = sources
            .iter()
            .map(|&s| {
                slots
                    .get_mut(s)
                    .unwrap_or_else(|| panic!("source partition {s} out of range"))
                    .take()
                    .unwrap_or_else(|| panic!("source partition {s} selected twice"))
            })
            .collect();
        Rdd {
            partitions,
            partitioner: None,
        }
    }

    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.partitions.iter().flatten()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_distribution() {
        let rdd = Rdd::from_items((0..10).collect::<Vec<i32>>(), 3);
        assert_eq!(rdd.num_partitions(), 3);
        assert_eq!(rdd.len(), 10);
        assert_eq!(rdd.partitions()[0], vec![0, 3, 6, 9]);
        assert_eq!(rdd.partitions()[1], vec![1, 4, 7]);
        assert_eq!(rdd.partitioner(), None);
    }

    #[test]
    fn empty_partitions_allowed() {
        let rdd = Rdd::from_items(vec![1], 4);
        assert_eq!(rdd.num_partitions(), 4);
        assert_eq!(rdd.len(), 1);
        assert!(!rdd.is_empty());
        assert!(Rdd::<i32>::from_items(vec![], 2).is_empty());
    }

    #[test]
    fn union_keeps_partitions_but_drops_partitioner() {
        let a = Rdd::from_items(vec![1, 2], 2).with_partitioner(Partitioner::Hash { nparts: 2 });
        let b = Rdd::from_items(vec![3], 1);
        let u = a.union(b);
        assert_eq!(u.num_partitions(), 3);
        assert_eq!(u.len(), 3);
        assert_eq!(u.partitioner(), None);
    }

    #[test]
    fn into_items_flattens_in_partition_order() {
        let rdd = Rdd::from_partitions(vec![vec![1, 2], vec![3]]);
        assert_eq!(rdd.into_items(), vec![1, 2, 3]);
    }

    #[test]
    fn partitioner_metadata_round_trip() {
        let p = Partitioner::Grid { nblocks: 2 };
        assert_eq!(p.nparts(), 4);
        let rdd = Rdd::from_partitions_with(vec![vec![1], vec![2], vec![3], vec![4]], p);
        assert_eq!(rdd.partitioner(), Some(p));
        assert_ne!(p, Partitioner::Hash { nparts: 4 });
    }

    #[test]
    #[should_panic(expected = "partition count must match")]
    fn partitioner_count_mismatch_panics() {
        let _ = Rdd::from_items(vec![1, 2], 3).with_partitioner(Partitioner::Hash { nparts: 2 });
    }

    #[test]
    fn select_partitions_moves_whole_partitions() {
        let rdd = Rdd::from_partitions(vec![vec![1], vec![2], vec![3], vec![4]]);
        let sel = rdd.select_partitions(&[2, 0]);
        assert_eq!(sel.num_partitions(), 2);
        assert_eq!(sel.partitions()[0], vec![3]);
        assert_eq!(sel.partitions()[1], vec![1]);
        assert_eq!(sel.partitioner(), None);
    }

    #[test]
    #[should_panic(expected = "selected twice")]
    fn select_partitions_rejects_reuse() {
        let rdd = Rdd::from_partitions(vec![vec![1], vec![2]]);
        let _ = rdd.select_partitions(&[0, 0]);
    }

    #[test]
    #[should_panic(expected = "at least one partition")]
    fn zero_partitions_panics() {
        let _ = Rdd::from_items(vec![1], 0);
    }
}
