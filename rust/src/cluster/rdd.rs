//! Partitioned collection — the RDD stand-in.
//!
//! Unlike Spark's lazy lineage graph, this RDD is eager and materialized:
//! the recursion driver (Algorithm 2) forces evaluation at every step
//! anyway, and eager execution is what lets the substrate measure real
//! per-task durations for the virtual-time model.

/// A collection split into partitions; one partition = one task.
#[derive(Debug, Clone)]
pub struct Rdd<T> {
    partitions: Vec<Vec<T>>,
}

impl<T> Rdd<T> {
    /// Round-robin distribute items over `nparts` partitions.
    pub fn from_items(items: Vec<T>, nparts: usize) -> Self {
        assert!(nparts > 0, "need at least one partition");
        let mut partitions: Vec<Vec<T>> = (0..nparts).map(|_| Vec::new()).collect();
        for (i, item) in items.into_iter().enumerate() {
            partitions[i % nparts].push(item);
        }
        Rdd { partitions }
    }

    pub fn from_partitions(partitions: Vec<Vec<T>>) -> Self {
        assert!(!partitions.is_empty(), "need at least one partition");
        Rdd { partitions }
    }

    pub fn num_partitions(&self) -> usize {
        self.partitions.len()
    }

    pub fn len(&self) -> usize {
        self.partitions.iter().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn partitions(&self) -> &[Vec<T>] {
        &self.partitions
    }

    pub fn into_partitions(self) -> Vec<Vec<T>> {
        self.partitions
    }

    /// Flatten to a single Vec (driver-side `collect`).
    pub fn into_items(self) -> Vec<T> {
        self.partitions.into_iter().flatten().collect()
    }

    /// Concatenate partition lists (Spark `union` keeps both lineages'
    /// partitioning).
    pub fn union(mut self, other: Rdd<T>) -> Rdd<T> {
        self.partitions.extend(other.partitions);
        self
    }

    pub fn iter(&self) -> impl Iterator<Item = &T> {
        self.partitions.iter().flatten()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_robin_distribution() {
        let rdd = Rdd::from_items((0..10).collect::<Vec<i32>>(), 3);
        assert_eq!(rdd.num_partitions(), 3);
        assert_eq!(rdd.len(), 10);
        assert_eq!(rdd.partitions()[0], vec![0, 3, 6, 9]);
        assert_eq!(rdd.partitions()[1], vec![1, 4, 7]);
    }

    #[test]
    fn empty_partitions_allowed() {
        let rdd = Rdd::from_items(vec![1], 4);
        assert_eq!(rdd.num_partitions(), 4);
        assert_eq!(rdd.len(), 1);
        assert!(!rdd.is_empty());
        assert!(Rdd::<i32>::from_items(vec![], 2).is_empty());
    }

    #[test]
    fn union_keeps_partitions() {
        let a = Rdd::from_items(vec![1, 2], 2);
        let b = Rdd::from_items(vec![3], 1);
        let u = a.union(b);
        assert_eq!(u.num_partitions(), 3);
        assert_eq!(u.len(), 3);
    }

    #[test]
    fn into_items_flattens_in_partition_order() {
        let rdd = Rdd::from_partitions(vec![vec![1, 2], vec![3]]);
        assert_eq!(rdd.into_items(), vec![1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "at least one partition")]
    fn zero_partitions_panics() {
        let _ = Rdd::from_items(vec![1], 0);
    }
}
