//! Virtual-time core: FIFO list scheduling of measured task durations onto
//! simulated slots, and the monotone virtual clock.
//!
//! This is exactly the model behind the paper's parallelization factor
//! `min(tasks, cores)`: a stage with `t` equal tasks on `s` slots takes
//! `ceil(t/s)` waves. Real task durations are unequal, so we schedule them
//! FIFO onto the earliest-free slot, like Spark's task scheduler within a
//! stage.

/// FIFO list scheduling: assign each duration (in submission order) to the
/// earliest-free slot; return the makespan.
pub fn list_schedule_makespan(durations: &[f64], slots: usize) -> f64 {
    assert!(slots > 0, "need at least one slot");
    if durations.is_empty() {
        return 0.0;
    }
    let mut slot_free = vec![0.0f64; slots.min(durations.len())];
    for &d in durations {
        // earliest-free slot
        let idx = slot_free
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        slot_free[idx] += d;
    }
    slot_free.into_iter().fold(0.0, f64::max)
}

/// Monotone virtual clock accumulating simulated seconds.
#[derive(Debug, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        VirtualClock { now: 0.0 }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn advance(&mut self, secs: f64) {
        debug_assert!(secs >= 0.0, "clock cannot run backwards");
        self.now += secs.max(0.0);
    }

    pub fn reset(&mut self) {
        self.now = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn single_slot_is_serial() {
        assert_eq!(list_schedule_makespan(&[1.0, 2.0, 3.0], 1), 6.0);
    }

    #[test]
    fn enough_slots_is_max() {
        assert_eq!(list_schedule_makespan(&[1.0, 2.0, 3.0], 8), 3.0);
    }

    #[test]
    fn equal_tasks_make_waves() {
        // 6 unit tasks on 2 slots -> 3 waves.
        let d = vec![1.0; 6];
        assert!((list_schedule_makespan(&d, 2) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn empty_and_degenerate() {
        assert_eq!(list_schedule_makespan(&[], 4), 0.0);
        assert_eq!(list_schedule_makespan(&[5.0], 4), 5.0);
    }

    #[test]
    fn clock_accumulates() {
        let mut c = VirtualClock::new();
        c.advance(1.5);
        c.advance(0.5);
        assert_eq!(c.now(), 2.0);
        c.reset();
        assert_eq!(c.now(), 0.0);
    }

    #[test]
    fn property_makespan_bounds() {
        // serial/slots <= makespan <= serial, and makespan >= max task.
        forall(
            "makespan bounds",
            0x5C,
            64,
            |r| {
                let n = 1 + r.next_usize(40);
                let slots = 1 + r.next_usize(16);
                let d: Vec<f64> = (0..n).map(|_| r.uniform(0.01, 2.0)).collect();
                (d, slots)
            },
            |(d, slots)| {
                let m = list_schedule_makespan(d, *slots);
                let serial: f64 = d.iter().sum();
                let longest = d.iter().fold(0.0f64, |a, &b| a.max(b));
                let lower = (serial / *slots as f64).max(longest);
                // list scheduling is within 2x of optimal; and optimal >= lower
                if m + 1e-12 < lower {
                    return Err(format!("makespan {m} below lower bound {lower}"));
                }
                if m > serial + 1e-12 {
                    return Err(format!("makespan {m} exceeds serial {serial}"));
                }
                // Graham bound: m <= lower_serial/slots + longest
                if m > serial / *slots as f64 + longest + 1e-12 {
                    return Err(format!("makespan {m} violates Graham bound"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_more_slots_never_slower() {
        forall(
            "monotone in slots",
            0x5D,
            32,
            |r| {
                let n = 1 + r.next_usize(30);
                let d: Vec<f64> = (0..n).map(|_| r.uniform(0.01, 1.0)).collect();
                let s = 1 + r.next_usize(8);
                (d, s)
            },
            |(d, s)| {
                let m1 = list_schedule_makespan(d, *s);
                let m2 = list_schedule_makespan(d, s + 1);
                // FIFO list scheduling is not strictly monotone in general,
                // but within a factor-of-2 envelope it is; check the sane
                // envelope rather than strict monotonicity.
                if m2 <= m1 * 2.0 + 1e-12 {
                    Ok(())
                } else {
                    Err(format!("slots {s}->{} regressed {m1} -> {m2}", s + 1))
                }
            },
        );
    }
}
