//! Shuffle machinery: hash partitioning, executor placement, and the byte
//! accounting that feeds the simulated interconnect.
//!
//! Two execution strategies produce identical buckets: the sequential
//! [`route`] and the pool-backed [`route_parallel`] (map-side bucketing
//! and reduce-side merges as separate task waves over a sharded-lock
//! exchange). The determinism contract — incoming runs merge in
//! ascending source-partition order, items in original order within a
//! run — makes the parallel path bit-identical, k-sum reduce order
//! included. See `docs/EXECUTOR.md`.

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::Mutex;

use crate::cluster::rdd::Rdd;
use crate::exec::{ExecPool, StageExecStats};
use crate::util::plock;

/// Payload size estimation for shuffle-cost accounting.
pub trait Bytes {
    fn size_bytes(&self) -> u64;
}

impl Bytes for i32 {
    fn size_bytes(&self) -> u64 {
        4
    }
}

impl Bytes for i64 {
    fn size_bytes(&self) -> u64 {
        8
    }
}

impl Bytes for u64 {
    fn size_bytes(&self) -> u64 {
        8
    }
}

impl Bytes for f64 {
    fn size_bytes(&self) -> u64 {
        8
    }
}

impl Bytes for usize {
    fn size_bytes(&self) -> u64 {
        8
    }
}

impl Bytes for String {
    fn size_bytes(&self) -> u64 {
        self.len() as u64
    }
}

impl<A: Bytes, B: Bytes> Bytes for (A, B) {
    fn size_bytes(&self) -> u64 {
        self.0.size_bytes() + self.1.size_bytes()
    }
}

impl<T: Bytes> Bytes for Vec<T> {
    fn size_bytes(&self) -> u64 {
        self.iter().map(Bytes::size_bytes).sum()
    }
}

/// Internal tag for cogroup's two sides.
#[derive(Debug, Clone)]
pub enum Either<V, W> {
    L(V),
    R(W),
}

impl<V: Bytes, W: Bytes> Bytes for Either<V, W> {
    fn size_bytes(&self) -> u64 {
        match self {
            Either::L(v) => v.size_bytes(),
            Either::R(w) => w.size_bytes(),
        }
    }
}

/// Deterministic hash partitioner (Spark `HashPartitioner` equivalent).
pub fn hash_partition<K: Hash>(key: &K, nparts: usize) -> usize {
    let mut h = DefaultHasher::new();
    key.hash(&mut h);
    (h.finish() % nparts as u64) as usize
}

/// Static partition→executor placement (round-robin, like Spark's
/// locality-free assignment).
pub fn executor_of_partition(partition: usize, executors: usize) -> usize {
    partition % executors.max(1)
}

/// Exchange phase: scatter `(K, V)` pairs into `nparts` hash buckets.
/// Returns the buckets, the payload bytes that crossed a simulated
/// executor boundary (same-executor moves are free, like Spark's local
/// shuffle reads), and the total bytes that changed partition (an
/// executor-count-independent upper bound used by topology replays).
pub fn exchange<K, V>(
    input: Rdd<(K, V)>,
    nparts: usize,
    executors: usize,
) -> (Vec<Vec<(K, V)>>, u64, u64)
where
    K: Hash + Eq + Clone,
    V: Bytes,
{
    let nparts = nparts.max(1);
    route(
        input,
        nparts,
        executors,
        |(k, _)| hash_partition(k, nparts),
        |(_, v)| v.size_bytes(),
    )
}

/// Generalized exchange: scatter elements of any type into `nparts`
/// buckets with an arbitrary routing function, with the same byte
/// accounting as [`exchange`]. This is what partitioner-aware ops use to
/// route shuffle output directly to its *consumer's* partition (e.g.
/// block-matmul routing `(i, j, k)` replicas by output index `(i, j)`,
/// which turns the downstream reduce into a narrow stage).
pub fn route<T>(
    input: Rdd<T>,
    nparts: usize,
    executors: usize,
    part_fn: impl Fn(&T) -> usize,
    bytes_fn: impl Fn(&T) -> u64,
) -> (Vec<Vec<T>>, u64, u64) {
    let nparts = nparts.max(1);
    let mut buckets: Vec<Vec<T>> = (0..nparts).map(|_| Vec::new()).collect();
    let mut moved = 0u64;
    let mut total = 0u64;
    for (src_part, part) in input.into_partitions().into_iter().enumerate() {
        let src_exec = executor_of_partition(src_part, executors);
        for item in part {
            let dst_part = part_fn(&item) % nparts;
            let dst_exec = executor_of_partition(dst_part, executors);
            if dst_part != src_part {
                total += bytes_fn(&item);
            }
            if dst_exec != src_exec {
                moved += bytes_fn(&item);
            }
            buckets[dst_part].push(item);
        }
    }
    (buckets, moved, total)
}

/// Parallel [`route`]: map-side bucketing fans out one task per source
/// partition (each computes its own byte counts and scatters
/// per-destination runs into a sharded-lock exchange), then reduce-side
/// merges fan out one task per destination partition.
///
/// **Determinism contract**: each destination sorts its incoming runs by
/// ascending source partition before concatenating, and a run preserves
/// the source's item order — exactly the element order the sequential
/// [`route`] produces. Downstream `group_pairs` first-seen key order and
/// k-sum reduce order are therefore identical, which is what keeps
/// parallel runs bit-identical to sequential ones. Byte counters are
/// per-item sums, so they match trivially.
///
/// Also returns the pool's merged execution stats for the two waves
/// (wall clock, queue/run time, steals) for the stage record.
pub fn route_parallel<T: Send>(
    pool: &ExecPool,
    input: Rdd<T>,
    nparts: usize,
    executors: usize,
    part_fn: impl Fn(&T) -> usize + Sync,
    bytes_fn: impl Fn(&T) -> u64 + Sync,
) -> (Vec<Vec<T>>, u64, u64, StageExecStats) {
    // One mailbox per destination partition, each holding (source, run)
    // pairs published by the map wave.
    type Shard<T> = Mutex<Vec<(usize, Vec<T>)>>;
    let nparts = nparts.max(1);
    let shards: Vec<Shard<T>> = (0..nparts).map(|_| Mutex::new(Vec::new())).collect();
    let map_tasks: Vec<(usize, Vec<T>)> = input.into_partitions().into_iter().enumerate().collect();

    // Map wave: bucket each source partition locally, then publish the
    // non-empty runs into the destination shards.
    let map_run = pool.run_stage(map_tasks, |(src_part, items)| {
        let src_exec = executor_of_partition(src_part, executors);
        let mut runs: Vec<Vec<T>> = (0..nparts).map(|_| Vec::new()).collect();
        let mut moved = 0u64;
        let mut total = 0u64;
        for item in items {
            let dst_part = part_fn(&item) % nparts;
            let dst_exec = executor_of_partition(dst_part, executors);
            if dst_part != src_part {
                total += bytes_fn(&item);
            }
            if dst_exec != src_exec {
                moved += bytes_fn(&item);
            }
            runs[dst_part].push(item);
        }
        for (dst, run) in runs.into_iter().enumerate() {
            if !run.is_empty() {
                plock(&shards[dst]).push((src_part, run));
            }
        }
        (moved, total)
    });
    let moved = map_run.outputs.iter().map(|(m, _)| m).sum();
    let total = map_run.outputs.iter().map(|(_, t)| t).sum();

    // Reduce wave: merge each destination's runs in canonical
    // (ascending-source) order.
    let reduce_run = pool.run_stage((0..nparts).collect(), |dst: usize| {
        let mut incoming = std::mem::take(&mut *plock(&shards[dst]));
        incoming.sort_by_key(|(src, _)| *src);
        let mut bucket = Vec::with_capacity(incoming.iter().map(|(_, r)| r.len()).sum());
        for (_, mut run) in incoming {
            bucket.append(&mut run);
        }
        bucket
    });

    let (m, r) = (map_run.stats, reduce_run.stats);
    let stats = StageExecStats {
        tasks: m.tasks + r.tasks,
        steals: m.steals + r.steals,
        queue_ns: m.queue_ns + r.queue_ns,
        run_ns: m.run_ns + r.run_ns,
        wall_ns: m.wall_ns + r.wall_ns,
    };
    (reduce_run.outputs, moved, total, stats)
}

/// Group a partition's pairs by key, preserving first-seen key order.
pub fn group_pairs<K: Hash + Eq + Clone, V>(pairs: Vec<(K, V)>) -> Vec<(K, Vec<V>)> {
    let mut order: Vec<K> = Vec::new();
    let mut groups: HashMap<K, Vec<V>> = HashMap::new();
    for (k, v) in pairs {
        groups
            .entry(k.clone())
            .or_insert_with(|| {
                order.push(k.clone());
                Vec::new()
            })
            .push(v);
    }
    order
        .into_iter()
        .filter_map(|k| {
            let vs = groups.remove(&k)?;
            Some((k, vs))
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn hash_partition_is_deterministic_and_in_range() {
        for k in 0..1000u64 {
            let p = hash_partition(&k, 7);
            assert!(p < 7);
            assert_eq!(p, hash_partition(&k, 7));
        }
    }

    #[test]
    fn executor_placement_round_robin() {
        assert_eq!(executor_of_partition(0, 3), 0);
        assert_eq!(executor_of_partition(4, 3), 1);
        assert_eq!(executor_of_partition(5, 0), 0); // degenerate: 1 executor
    }

    #[test]
    fn exchange_routes_all_pairs_by_hash() {
        let pairs: Vec<(u64, i32)> = (0..100).map(|i| (i, i as i32)).collect();
        let rdd = Rdd::from_items(pairs, 4);
        let (buckets, _, _) = exchange(rdd, 5, 2);
        assert_eq!(buckets.iter().map(Vec::len).sum::<usize>(), 100);
        for (p, bucket) in buckets.iter().enumerate() {
            for (k, _) in bucket {
                assert_eq!(hash_partition(k, 5), p);
            }
        }
    }

    #[test]
    fn exchange_single_executor_moves_nothing() {
        let pairs: Vec<(u64, i32)> = (0..50).map(|i| (i, 1)).collect();
        let rdd = Rdd::from_items(pairs, 4);
        let (_, moved, total) = exchange(rdd, 8, 1);
        assert!(total >= moved);
        assert_eq!(moved, 0);
    }

    #[test]
    fn exchange_counts_cross_executor_bytes() {
        let pairs: Vec<(u64, i32)> = (0..64).map(|i| (i, 1)).collect();
        let rdd = Rdd::from_items(pairs, 4);
        let (_, moved, total) = exchange(rdd, 4, 4);
        assert!(total >= moved);
        assert!(moved > 0);
        assert_eq!(moved % 4, 0); // multiples of the i32 payload
    }

    #[test]
    fn route_honors_custom_partition_function() {
        let pairs: Vec<(u64, i32)> = (0..30).map(|i| (i, 1)).collect();
        let rdd = Rdd::from_items(pairs, 3);
        let (buckets, moved, total) = route(rdd, 5, 2, |(k, _)| (*k as usize) % 5, |_| 4);
        assert_eq!(buckets.iter().map(Vec::len).sum::<usize>(), 30);
        for (p, bucket) in buckets.iter().enumerate() {
            for (k, _) in bucket {
                assert_eq!(*k as usize % 5, p);
            }
        }
        assert!(total >= moved);
    }

    #[test]
    fn group_pairs_collects_all() {
        let pairs = vec![("a", 1), ("b", 2), ("a", 3)];
        let grouped = group_pairs(pairs);
        assert_eq!(grouped, vec![("a", vec![1, 3]), ("b", vec![2])]);
    }

    #[test]
    fn property_parallel_route_identical_to_sequential() {
        let pool = ExecPool::new(4);
        forall(
            "parallel route ≡ sequential route (order included)",
            0xB7,
            32,
            |r| {
                let n = r.next_usize(300);
                let items: Vec<(u64, i64)> =
                    (0..n).map(|_| (r.next_u64() % 16, r.next_u64() as i64)).collect();
                let nparts = 1 + r.next_usize(8);
                let execs = 1 + r.next_usize(6);
                let srcparts = 1 + r.next_usize(8);
                (items, nparts, execs, srcparts)
            },
            |(items, nparts, execs, srcparts)| {
                let part = |it: &(u64, i64)| (it.0 as usize) % *nparts;
                let bytes = |it: &(u64, i64)| it.size_bytes();
                let (seq, smoved, stotal) =
                    route(Rdd::from_items(items.clone(), *srcparts), *nparts, *execs, part, bytes);
                let (par, pmoved, ptotal, stats) = route_parallel(
                    &pool,
                    Rdd::from_items(items.clone(), *srcparts),
                    *nparts,
                    *execs,
                    part,
                    bytes,
                );
                if seq != par {
                    return Err(format!("buckets diverge: {seq:?} vs {par:?}"));
                }
                if (smoved, stotal) != (pmoved, ptotal) {
                    return Err(format!(
                        "byte counters diverge: ({smoved},{stotal}) vs ({pmoved},{ptotal})"
                    ));
                }
                if stats.tasks != *srcparts + *nparts {
                    return Err(format!("expected map+reduce task waves, got {stats:?}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn property_exchange_conserves_elements_and_bytes_bounded() {
        forall(
            "shuffle conservation",
            0xA5,
            48,
            |r| {
                let n = r.next_usize(200);
                let pairs: Vec<(u64, i64)> =
                    (0..n).map(|_| (r.next_u64() % 32, r.next_u64() as i64)).collect();
                let nparts = 1 + r.next_usize(8);
                let execs = 1 + r.next_usize(6);
                let srcparts = 1 + r.next_usize(8);
                (pairs, nparts, execs, srcparts)
            },
            |(pairs, nparts, execs, srcparts)| {
                let total_bytes: u64 = pairs.iter().map(|(_, v)| v.size_bytes()).sum();
                let rdd = Rdd::from_items(pairs.clone(), *srcparts);
                let (buckets, moved, total) = exchange(rdd, *nparts, *execs);
                let count: usize = buckets.iter().map(Vec::len).sum();
                if count != pairs.len() {
                    return Err(format!("lost elements: {count} vs {}", pairs.len()));
                }
                if moved > total || total > total_bytes {
                    return Err(format!("moved {moved} > total {total_bytes}"));
                }
                Ok(())
            },
        );
    }
}
