//! Configuration system: cluster topology, job parameters, presets, JSON
//! loading and `key=value` CLI overrides.
//!
//! Mirrors a Spark deployment's split between *cluster* resources (paper
//! Table 2 / §5.1 "Resource Utilization Plan") and per-*job* parameters
//! (matrix size, block size, algorithm toggles).

use std::path::{Path, PathBuf};

use crate::error::{Result, SpinError};
use crate::ser::json::Json;

/// Which block-kernel backend executes leaf/block compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Pure-Rust kernels (`linalg`) — the JBlas stand-in, always available.
    Native,
    /// AOT JAX/Pallas programs executed through the PJRT CPU client.
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "native" => Ok(BackendKind::Native),
            "xla" => Ok(BackendKind::Xla),
            other => Err(SpinError::config(format!(
                "unknown backend `{other}` (expected native|xla)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        }
    }
}

/// Simulated interconnect (paper: 14 Gb/s InfiniBand).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetworkConfig {
    /// Point-to-point bandwidth in gigabits per second.
    pub bandwidth_gbps: f64,
    /// Per-transfer latency in microseconds.
    pub latency_us: f64,
}

impl NetworkConfig {
    /// Seconds to move `bytes` across the simulated fabric.
    pub fn transfer_secs(&self, bytes: u64) -> f64 {
        self.latency_us * 1e-6 + bytes as f64 * 8.0 / (self.bandwidth_gbps * 1e9)
    }
}

/// Which fault kinds the deterministic injector may draw for a faulted
/// task attempt. Parsed from a `|`-separated list
/// (`fault_kinds=task_panic|task_error|straggle`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultKinds {
    /// The attempt dies mid-task (partial work lost, charged at a
    /// seed-derived fraction of the task's duration).
    pub task_panic: bool,
    /// The attempt runs to the end and then fails (full duration charged).
    pub task_error: bool,
    /// The attempt succeeds but its duration is inflated by a
    /// seed-derived factor — the straggler-speculation trigger.
    pub straggle: bool,
}

impl FaultKinds {
    pub fn all() -> Self {
        FaultKinds {
            task_panic: true,
            task_error: true,
            straggle: true,
        }
    }

    pub fn none() -> Self {
        FaultKinds {
            task_panic: false,
            task_error: false,
            straggle: false,
        }
    }

    pub fn any(&self) -> bool {
        self.task_panic || self.task_error || self.straggle
    }

    pub fn parse(s: &str) -> Result<Self> {
        let mut kinds = FaultKinds::none();
        for part in s.split('|').filter(|p| !p.is_empty()) {
            match part {
                "task_panic" => kinds.task_panic = true,
                "task_error" => kinds.task_error = true,
                "straggle" => kinds.straggle = true,
                other => {
                    return Err(SpinError::config(format!(
                        "unknown fault kind `{other}` (expected task_panic|task_error|straggle)"
                    )));
                }
            }
        }
        Ok(kinds)
    }

    pub fn name(&self) -> String {
        let mut parts = Vec::new();
        if self.task_panic {
            parts.push("task_panic");
        }
        if self.task_error {
            parts.push("task_error");
        }
        if self.straggle {
            parts.push("straggle");
        }
        parts.join("|")
    }
}

/// Cluster topology + runtime knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct ClusterConfig {
    /// Physical nodes in the simulated cluster.
    pub nodes: usize,
    /// Spark executors per node (paper: 2).
    pub executors_per_node: usize,
    /// Task slots (cores) per executor (paper: 5).
    pub cores_per_executor: usize,
    /// Simulated interconnect between nodes.
    pub network: NetworkConfig,
    /// Which backend executes block kernels.
    pub backend: BackendKind,
    /// Where `manifest.json` + HLO artifacts live (Xla backend).
    pub artifacts_dir: PathBuf,
    /// Real worker threads used to chew through tasks on this machine
    /// (orthogonal to the *simulated* slot count above).
    pub worker_threads: usize,
    /// Execution lanes of the work-stealing partition runtime
    /// (`spin::exec`). At 1 (the default) stages run on the legacy
    /// inline/scoped-thread path; above 1 every narrow stage, shuffle
    /// wave, and straggler sleep fans out on the shared process-wide
    /// pool — bit-identical results, real wall-clock metrics. CLI:
    /// `--set exec_threads=N`; env default: `SPIN_EXEC_THREADS`.
    pub exec_threads: usize,
    /// Report virtual (discrete-event) time instead of raw wall clock.
    /// See DESIGN.md §3 — this is the single-core testbed substitution.
    pub virtual_time: bool,
    /// Partitioner-aware dataflow (default). When disabled, the block
    /// ops fall back to the original replicated-cogroup multiply and
    /// driver-side re-parallelization — kept so the shuffle/driver
    /// round-trip savings stay measurable (and for ablation benches).
    pub partitioner_aware: bool,
    /// Run the matrix-expression plan optimizer (default). When disabled,
    /// lazy plans lower exactly as written — no multiply+subtract fusion,
    /// no transpose pushdown, no scalar folding, no CSE — which is the
    /// measurable "unfused plan" arm of the Table-3 comparison.
    pub plan_optimizer: bool,
    /// Debug mode: cross-check the static plan verifier's predictions
    /// (`spin::analysis`) against measured `Metrics` counters after every
    /// plan node, failing the job on divergence — measured exchange
    /// stages must equal the prediction, shuffle bytes must stay under
    /// the derived ceiling, and the partitioner-aware dataflow must never
    /// collect to the driver. Off by default (it brackets every node with
    /// a metrics snapshot). CLI: `--set verify_plans=true`; env default:
    /// `SPIN_VERIFY_PLANS`.
    pub verify_plans: bool,
    /// Byte budget for memoized plan-node values (0 = unlimited). Above
    /// the budget, the session's LRU evictor drops least-recently-used
    /// unpinned values; evicted nodes recompute bit-identically on the
    /// next read. CLI: `--set cache_budget_bytes=N`.
    pub cache_budget_bytes: u64,
    /// Windowed metrics history: retain at most this many stage records
    /// (and, independently, plan-node reports) across all scopes,
    /// dropping oldest-first (0 = unlimited). Pairs with the service's
    /// per-job scope release to hold a long-lived `spin serve` at
    /// steady-state memory. Size it above the largest single job's stage
    /// count — a smaller window truncates that job's scoped snapshot
    /// (scope *totals* stay exact either way). CLI:
    /// `--set metrics_history=N`.
    pub metrics_history: usize,
    /// Deterministic fault injection: `Some(seed)` arms the injector —
    /// every partition-task attempt draws from a stream derived from
    /// `(seed, stage, partition, attempt)`, so a chaos run replays
    /// exactly. `None` (default) disables injection entirely; the
    /// execution path is then byte-identical to a build without the
    /// feature. CLI: `--set fault_seed=N`.
    pub fault_seed: Option<u64>,
    /// Probability in `[0, 1]` that a given task attempt is faulted
    /// (only consulted when `fault_seed` is set).
    /// CLI: `--set fault_rate=0.05`.
    pub fault_rate: f64,
    /// Which fault kinds the injector may draw.
    /// CLI: `--set fault_kinds=task_panic|task_error|straggle`.
    pub fault_kinds: FaultKinds,
    /// Retry budget per partition task: a task may fail this many times
    /// and still succeed on the next attempt; one more fault exhausts
    /// the budget and fails the stage (naming stage + partition).
    /// CLI: `--set task_retries=N`.
    pub task_retries: usize,
    /// Base of the exponential retry backoff in virtual seconds: attempt
    /// `k` (1-based) waits `retry_backoff_secs · 2^(k−1)` before
    /// re-running. CLI: `--set retry_backoff_secs=0.05`.
    pub retry_backoff_secs: f64,
    /// Straggler speculation: when a task attempt runs longer than this
    /// multiple of the stage's median task duration, a speculative copy
    /// is launched at the threshold and the first finisher wins
    /// (0 = speculation off). CLI: `--set speculation_multiplier=3`.
    pub speculation_multiplier: f64,
    /// Persist recursion-level results every N levels of the inversion
    /// recursion to the job's checkpoint store, journaling a
    /// `checkpoint` record — a restarted server resumes the job from
    /// the deepest completed checkpoints instead of from scratch
    /// (0 = off). CLI: `--set checkpoint_every_level=N`.
    pub checkpoint_every_level: usize,
    /// Per-tenant cap on *queued* jobs in the service (0 = unlimited):
    /// a tenant at its quota gets a retryable rejection (HTTP 429)
    /// instead of filling the shared queue.
    /// CLI: `--set tenant_queue_quota=N`.
    pub tenant_queue_quota: usize,
    /// Per-tenant cap on *running* jobs (0 = unlimited): workers skip a
    /// tenant already at its cap, so one tenant cannot occupy every
    /// worker. CLI: `--set tenant_inflight_cap=N`.
    pub tenant_inflight_cap: usize,
}

/// Default real worker-thread count: `SPIN_WORKER_THREADS` when set to a
/// positive integer, else 1. This is the CI thread-matrix hook — the env
/// var seeds every preset so the whole test suite runs multi-threaded
/// without touching each construction site. The trade-off is a
/// deliberately environment-sensitive *default*: deployments that need a
/// pinned value should set `worker_threads` explicitly (builder,
/// config file, or `--set worker_threads=N`), which always wins.
fn default_worker_threads() -> usize {
    std::env::var("SPIN_WORKER_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(1)
}

/// Default exec-pool lane count: `SPIN_EXEC_THREADS` when set to a
/// positive integer, else 1 (sequential inline execution). Same CI
/// thread-matrix contract as [`default_worker_threads`]: an explicit
/// `exec_threads` (builder, config file, `--set exec_threads=N`) wins.
fn default_exec_threads() -> usize {
    std::env::var("SPIN_EXEC_THREADS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .filter(|&n| n > 0)
        .unwrap_or(1)
}

/// Default for the `verify_plans` debug mode: `SPIN_VERIFY_PLANS` set to
/// `1` or `true` arms it fleet-wide (the CI plan-lint job does this), else
/// off. Same contract as the other env-seeded defaults: an explicit
/// `verify_plans` (builder, config file, `--set verify_plans=true`) wins.
fn default_verify_plans() -> bool {
    matches!(
        std::env::var("SPIN_VERIFY_PLANS").as_deref(),
        Ok("1") | Ok("true")
    )
}

impl ClusterConfig {
    /// Single-node local "cluster" with `cores` slots — unit-test topology.
    pub fn local(cores: usize) -> Self {
        ClusterConfig {
            nodes: 1,
            executors_per_node: 1,
            cores_per_executor: cores,
            network: NetworkConfig {
                bandwidth_gbps: 100.0,
                latency_us: 1.0,
            },
            backend: BackendKind::Native,
            artifacts_dir: PathBuf::from("artifacts"),
            worker_threads: default_worker_threads(),
            exec_threads: default_exec_threads(),
            virtual_time: true,
            partitioner_aware: true,
            plan_optimizer: true,
            verify_plans: default_verify_plans(),
            cache_budget_bytes: 0,
            metrics_history: 0,
            fault_seed: None,
            fault_rate: 0.02,
            fault_kinds: FaultKinds::all(),
            task_retries: 3,
            retry_backoff_secs: 0.05,
            speculation_multiplier: 3.0,
            checkpoint_every_level: 0,
            tenant_queue_quota: 0,
            tenant_inflight_cap: 0,
        }
    }

    /// The paper's testbed (Table 2 + §5.1): 3 nodes, 2 executors each,
    /// 5 cores per executor, 14 Gb/s InfiniBand.
    pub fn paper() -> Self {
        ClusterConfig {
            nodes: 3,
            executors_per_node: 2,
            cores_per_executor: 5,
            network: NetworkConfig {
                bandwidth_gbps: 14.0,
                latency_us: 50.0,
            },
            backend: BackendKind::Native,
            artifacts_dir: PathBuf::from("artifacts"),
            worker_threads: default_worker_threads(),
            exec_threads: default_exec_threads(),
            virtual_time: true,
            partitioner_aware: true,
            plan_optimizer: true,
            verify_plans: default_verify_plans(),
            cache_budget_bytes: 0,
            metrics_history: 0,
            fault_seed: None,
            fault_rate: 0.02,
            fault_kinds: FaultKinds::all(),
            task_retries: 3,
            retry_backoff_secs: 0.05,
            speculation_multiplier: 3.0,
            checkpoint_every_level: 0,
            tenant_queue_quota: 0,
            tenant_inflight_cap: 0,
        }
    }

    pub fn total_executors(&self) -> usize {
        self.nodes * self.executors_per_node
    }

    /// Total task slots — the paper's `cores` in `min[tasks, cores]`.
    pub fn total_cores(&self) -> usize {
        self.total_executors() * self.cores_per_executor
    }

    /// Same cluster with a different executor count (Figure 5 sweeps this,
    /// keeping cores-per-executor fixed).
    pub fn with_executors(&self, executors: usize) -> Self {
        let mut c = self.clone();
        c.nodes = 1;
        c.executors_per_node = executors;
        c
    }

    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 || self.executors_per_node == 0 || self.cores_per_executor == 0 {
            return Err(SpinError::config("cluster dimensions must be positive"));
        }
        if self.worker_threads == 0 {
            return Err(SpinError::config("worker_threads must be positive"));
        }
        if self.exec_threads == 0 {
            return Err(SpinError::config("exec_threads must be positive"));
        }
        if !(self.network.bandwidth_gbps > 0.0) || self.network.latency_us < 0.0 {
            return Err(SpinError::config("invalid network parameters"));
        }
        if !(0.0..=1.0).contains(&self.fault_rate) {
            return Err(SpinError::config("fault_rate must be in [0, 1]"));
        }
        if self.fault_seed.is_some() && !self.fault_kinds.any() {
            return Err(SpinError::config(
                "fault_seed is set but fault_kinds is empty",
            ));
        }
        if !(self.retry_backoff_secs >= 0.0) {
            return Err(SpinError::config("retry_backoff_secs must be >= 0"));
        }
        if !(self.speculation_multiplier >= 0.0) {
            return Err(SpinError::config("speculation_multiplier must be >= 0"));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::object(vec![
            ("nodes", Json::num(self.nodes as f64)),
            ("executors_per_node", Json::num(self.executors_per_node as f64)),
            ("cores_per_executor", Json::num(self.cores_per_executor as f64)),
            ("bandwidth_gbps", Json::num(self.network.bandwidth_gbps)),
            ("latency_us", Json::num(self.network.latency_us)),
            ("backend", Json::str(self.backend.name())),
            (
                "artifacts_dir",
                Json::str(self.artifacts_dir.to_string_lossy().to_string()),
            ),
            ("worker_threads", Json::num(self.worker_threads as f64)),
            ("exec_threads", Json::num(self.exec_threads as f64)),
            ("virtual_time", Json::Bool(self.virtual_time)),
            ("partitioner_aware", Json::Bool(self.partitioner_aware)),
            ("plan_optimizer", Json::Bool(self.plan_optimizer)),
            ("verify_plans", Json::Bool(self.verify_plans)),
            (
                "cache_budget_bytes",
                Json::num(self.cache_budget_bytes as f64),
            ),
            ("metrics_history", Json::num(self.metrics_history as f64)),
            (
                "fault_seed",
                match self.fault_seed {
                    Some(s) => Json::num(s as f64),
                    None => Json::Null,
                },
            ),
            ("fault_rate", Json::num(self.fault_rate)),
            ("fault_kinds", Json::str(self.fault_kinds.name())),
            ("task_retries", Json::num(self.task_retries as f64)),
            ("retry_backoff_secs", Json::num(self.retry_backoff_secs)),
            (
                "speculation_multiplier",
                Json::num(self.speculation_multiplier),
            ),
            (
                "checkpoint_every_level",
                Json::num(self.checkpoint_every_level as f64),
            ),
            (
                "tenant_queue_quota",
                Json::num(self.tenant_queue_quota as f64),
            ),
            (
                "tenant_inflight_cap",
                Json::num(self.tenant_inflight_cap as f64),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let base = ClusterConfig::paper();
        let get_usize = |key: &str, dflt: usize| -> Result<usize> {
            match v.get(key) {
                None => Ok(dflt),
                Some(j) => j
                    .as_usize()
                    .ok_or_else(|| SpinError::config(format!("`{key}` must be a non-negative integer"))),
            }
        };
        let get_f64 = |key: &str, dflt: f64| -> Result<f64> {
            match v.get(key) {
                None => Ok(dflt),
                Some(j) => j
                    .as_f64()
                    .ok_or_else(|| SpinError::config(format!("`{key}` must be a number"))),
            }
        };
        let cfg = ClusterConfig {
            nodes: get_usize("nodes", base.nodes)?,
            executors_per_node: get_usize("executors_per_node", base.executors_per_node)?,
            cores_per_executor: get_usize("cores_per_executor", base.cores_per_executor)?,
            network: NetworkConfig {
                bandwidth_gbps: get_f64("bandwidth_gbps", base.network.bandwidth_gbps)?,
                latency_us: get_f64("latency_us", base.network.latency_us)?,
            },
            backend: match v.get("backend") {
                None => base.backend,
                Some(j) => BackendKind::parse(
                    j.as_str()
                        .ok_or_else(|| SpinError::config("`backend` must be a string"))?,
                )?,
            },
            artifacts_dir: match v.get("artifacts_dir") {
                None => base.artifacts_dir,
                Some(j) => PathBuf::from(
                    j.as_str()
                        .ok_or_else(|| SpinError::config("`artifacts_dir` must be a string"))?,
                ),
            },
            worker_threads: get_usize("worker_threads", base.worker_threads)?,
            exec_threads: get_usize("exec_threads", base.exec_threads)?,
            virtual_time: match v.get("virtual_time") {
                None => base.virtual_time,
                Some(j) => j
                    .as_bool()
                    .ok_or_else(|| SpinError::config("`virtual_time` must be a bool"))?,
            },
            partitioner_aware: match v.get("partitioner_aware") {
                None => base.partitioner_aware,
                Some(j) => j
                    .as_bool()
                    .ok_or_else(|| SpinError::config("`partitioner_aware` must be a bool"))?,
            },
            plan_optimizer: match v.get("plan_optimizer") {
                None => base.plan_optimizer,
                Some(j) => j
                    .as_bool()
                    .ok_or_else(|| SpinError::config("`plan_optimizer` must be a bool"))?,
            },
            verify_plans: match v.get("verify_plans") {
                None => base.verify_plans,
                Some(j) => j
                    .as_bool()
                    .ok_or_else(|| SpinError::config("`verify_plans` must be a bool"))?,
            },
            cache_budget_bytes: match v.get("cache_budget_bytes") {
                None => base.cache_budget_bytes,
                Some(j) => j.as_i64().and_then(|n| u64::try_from(n).ok()).ok_or_else(
                    || SpinError::config("`cache_budget_bytes` must be a non-negative integer"),
                )?,
            },
            metrics_history: get_usize("metrics_history", base.metrics_history)?,
            fault_seed: match v.get("fault_seed") {
                None | Some(Json::Null) => base.fault_seed,
                Some(j) => Some(j.as_i64().and_then(|n| u64::try_from(n).ok()).ok_or_else(
                    || SpinError::config("`fault_seed` must be a non-negative integer or null"),
                )?),
            },
            fault_rate: get_f64("fault_rate", base.fault_rate)?,
            fault_kinds: match v.get("fault_kinds") {
                None => base.fault_kinds,
                Some(j) => FaultKinds::parse(
                    j.as_str()
                        .ok_or_else(|| SpinError::config("`fault_kinds` must be a string"))?,
                )?,
            },
            task_retries: get_usize("task_retries", base.task_retries)?,
            retry_backoff_secs: get_f64("retry_backoff_secs", base.retry_backoff_secs)?,
            speculation_multiplier: get_f64(
                "speculation_multiplier",
                base.speculation_multiplier,
            )?,
            checkpoint_every_level: get_usize(
                "checkpoint_every_level",
                base.checkpoint_every_level,
            )?,
            tenant_queue_quota: get_usize("tenant_queue_quota", base.tenant_queue_quota)?,
            tenant_inflight_cap: get_usize("tenant_inflight_cap", base.tenant_inflight_cap)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> Result<Self> {
        Self::from_json(&Json::from_file(path)?)
    }

    /// Apply a `key=value` override (CLI `--set`).
    pub fn apply_override(&mut self, kv: &str) -> Result<()> {
        let (key, value) = kv
            .split_once('=')
            .ok_or_else(|| SpinError::config(format!("override `{kv}` is not key=value")))?;
        let parse_usize = |v: &str| {
            v.parse::<usize>()
                .map_err(|_| SpinError::config(format!("`{key}` needs an integer, got `{v}`")))
        };
        let parse_f64 = |v: &str| {
            v.parse::<f64>()
                .map_err(|_| SpinError::config(format!("`{key}` needs a number, got `{v}`")))
        };
        match key {
            "nodes" => self.nodes = parse_usize(value)?,
            "executors_per_node" => self.executors_per_node = parse_usize(value)?,
            "cores_per_executor" => self.cores_per_executor = parse_usize(value)?,
            "bandwidth_gbps" => self.network.bandwidth_gbps = parse_f64(value)?,
            "latency_us" => self.network.latency_us = parse_f64(value)?,
            "backend" => self.backend = BackendKind::parse(value)?,
            "artifacts_dir" => self.artifacts_dir = PathBuf::from(value),
            "worker_threads" => self.worker_threads = parse_usize(value)?,
            "exec_threads" => self.exec_threads = parse_usize(value)?,
            "virtual_time" => {
                self.virtual_time = value
                    .parse::<bool>()
                    .map_err(|_| SpinError::config("virtual_time needs true|false"))?
            }
            "partitioner_aware" => {
                self.partitioner_aware = value
                    .parse::<bool>()
                    .map_err(|_| SpinError::config("partitioner_aware needs true|false"))?
            }
            "plan_optimizer" => {
                self.plan_optimizer = value
                    .parse::<bool>()
                    .map_err(|_| SpinError::config("plan_optimizer needs true|false"))?
            }
            "verify_plans" => {
                self.verify_plans = value
                    .parse::<bool>()
                    .map_err(|_| SpinError::config("verify_plans needs true|false"))?
            }
            "cache_budget_bytes" => {
                self.cache_budget_bytes = value.parse::<u64>().map_err(|_| {
                    SpinError::config("cache_budget_bytes needs a non-negative integer")
                })?
            }
            "metrics_history" => {
                self.metrics_history = parse_usize(value)?;
            }
            "fault_seed" => {
                self.fault_seed = match value {
                    "none" | "off" => None,
                    v => Some(v.parse::<u64>().map_err(|_| {
                        SpinError::config("fault_seed needs a non-negative integer (or none)")
                    })?),
                }
            }
            "fault_rate" => self.fault_rate = parse_f64(value)?,
            "fault_kinds" => self.fault_kinds = FaultKinds::parse(value)?,
            "task_retries" => self.task_retries = parse_usize(value)?,
            "retry_backoff_secs" => self.retry_backoff_secs = parse_f64(value)?,
            "speculation_multiplier" => self.speculation_multiplier = parse_f64(value)?,
            "checkpoint_every_level" => self.checkpoint_every_level = parse_usize(value)?,
            "tenant_queue_quota" => self.tenant_queue_quota = parse_usize(value)?,
            "tenant_inflight_cap" => self.tenant_inflight_cap = parse_usize(value)?,
            other => {
                return Err(SpinError::config(format!("unknown cluster key `{other}`")));
            }
        }
        self.validate()
    }
}

/// `spin serve --http` front-door knobs: where to listen and the wire
/// limits the hand-rolled HTTP/1.1 server enforces.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct HttpConfig {
    /// Listen address, `host:port` (`port 0` = ephemeral, the bound
    /// address is printed at startup).
    pub listen: String,
    /// Largest accepted request body in bytes; larger submits are
    /// rejected with `413` before buffering.
    pub max_body_bytes: usize,
    /// SSE keep-alive: a `: heartbeat` comment is written on any event
    /// stream idle this long, so proxies and clients can distinguish a
    /// quiet job from a dead connection.
    pub sse_heartbeat_ms: u64,
}

impl Default for HttpConfig {
    fn default() -> Self {
        HttpConfig {
            listen: "127.0.0.1:8017".to_string(),
            max_body_bytes: 1 << 20,
            sse_heartbeat_ms: 10_000,
        }
    }
}

impl HttpConfig {
    pub fn validate(&self) -> Result<()> {
        if self.listen.is_empty() {
            return Err(SpinError::config("http listen address must not be empty"));
        }
        if self.max_body_bytes == 0 {
            return Err(SpinError::config("http max_body_bytes must be positive"));
        }
        if self.sse_heartbeat_ms == 0 {
            return Err(SpinError::config("http sse_heartbeat_ms must be positive"));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::object(vec![
            ("listen", Json::str(self.listen.clone())),
            ("max_body_bytes", Json::num(self.max_body_bytes as f64)),
            ("sse_heartbeat_ms", Json::num(self.sse_heartbeat_ms as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        v.check_known_keys("http config", &["listen", "max_body_bytes", "sse_heartbeat_ms"])?;
        let base = HttpConfig::default();
        let cfg = HttpConfig {
            listen: match v.get("listen") {
                None => base.listen,
                Some(j) => j
                    .as_str()
                    .ok_or_else(|| SpinError::config("`listen` must be a string"))?
                    .to_string(),
            },
            max_body_bytes: match v.get("max_body_bytes") {
                None => base.max_body_bytes,
                Some(j) => j.as_usize().ok_or_else(|| {
                    SpinError::config("`max_body_bytes` must be a non-negative integer")
                })?,
            },
            sse_heartbeat_ms: match v.get("sse_heartbeat_ms") {
                None => base.sse_heartbeat_ms,
                Some(j) => j
                    .as_i64()
                    .and_then(|n| u64::try_from(n).ok())
                    .ok_or_else(|| {
                        SpinError::config("`sse_heartbeat_ms` must be a non-negative integer")
                    })?,
            },
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_file(path: &Path) -> Result<Self> {
        Self::from_json(&Json::from_file(path)?)
    }

    /// Apply a `key=value` override (CLI `--set` in serve's http mode).
    pub fn apply_override(&mut self, kv: &str) -> Result<()> {
        let (key, value) = kv
            .split_once('=')
            .ok_or_else(|| SpinError::config(format!("override `{kv}` is not key=value")))?;
        match key {
            "listen" => self.listen = value.to_string(),
            "max_body_bytes" => {
                self.max_body_bytes = value
                    .parse()
                    .map_err(|_| SpinError::config("max_body_bytes needs an integer"))?
            }
            "sse_heartbeat_ms" => {
                self.sse_heartbeat_ms = value
                    .parse()
                    .map_err(|_| SpinError::config("sse_heartbeat_ms needs an integer"))?
            }
            other => return Err(SpinError::config(format!("unknown http key `{other}`"))),
        }
        self.validate()
    }
}

/// Test-matrix generator families (all invertible by construction).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GeneratorKind {
    /// Strictly diagonally dominant — Strassen-safe, well conditioned.
    DiagDominant,
    /// Symmetric positive definite `B·Bᵀ + n·I` (the paper's stated scope).
    Spd,
}

impl GeneratorKind {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "diag-dominant" => Ok(GeneratorKind::DiagDominant),
            "spd" => Ok(GeneratorKind::Spd),
            other => Err(SpinError::config(format!(
                "unknown generator `{other}` (expected diag-dominant|spd)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            GeneratorKind::DiagDominant => "diag-dominant",
            GeneratorKind::Spd => "spd",
        }
    }
}

/// Serial method used on leaf blocks (paper: "any approach").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LeafMethod {
    /// LU decomposition with partial pivoting, then back-substitution.
    Lu,
    /// Gauss-Jordan with partial pivoting (matches the Pallas kernel).
    GaussJordan,
}

impl LeafMethod {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "lu" => Ok(LeafMethod::Lu),
            "gauss-jordan" => Ok(LeafMethod::GaussJordan),
            other => Err(SpinError::config(format!(
                "unknown leaf method `{other}` (expected lu|gauss-jordan)"
            ))),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            LeafMethod::Lu => "lu",
            LeafMethod::GaussJordan => "gauss-jordan",
        }
    }
}

/// Per-job parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct JobConfig {
    /// Matrix order `n` (power of two, as in the paper's analysis).
    pub n: usize,
    /// Block edge (`n / b`); paper's `2^q`.
    pub block_size: usize,
    /// Workload seed.
    pub seed: u64,
    /// Test-matrix family.
    pub generator: GeneratorKind,
    /// Serial leaf inversion method.
    pub leaf: LeafMethod,
    /// Fuse the 2×2-grid recursion base into one XLA program
    /// (`strassen_2x2` artifact) — our extension, off by default.
    pub fuse_leaf_2x2: bool,
    /// Verify ‖A·A⁻¹ − I‖∞ after inversion.
    pub residual_check: bool,
    /// Convergence threshold for iterative schemes (`newton`): stop once
    /// ‖I − A·Xₖ‖∞ ≤ tolerance. Ignored by the exact algorithms.
    pub tolerance: f64,
    /// Iteration budget for iterative schemes — the SLA bound: the best
    /// iterate so far is returned (with `converged = false` in the
    /// convergence metrics) once the budget is spent.
    pub max_iters: usize,
}

impl JobConfig {
    pub fn new(n: usize, block_size: usize) -> Self {
        JobConfig {
            n,
            block_size,
            seed: 0x5710_2018,
            generator: GeneratorKind::DiagDominant,
            leaf: LeafMethod::Lu,
            fuse_leaf_2x2: false,
            residual_check: false,
            tolerance: 1e-10,
            max_iters: 64,
        }
    }

    /// Number of splits per dimension — the paper's `b`.
    pub fn num_splits(&self) -> usize {
        self.n / self.block_size
    }

    pub fn validate(&self) -> Result<()> {
        if !self.n.is_power_of_two() {
            return Err(SpinError::config(format!(
                "matrix size n={} must be a power of two (paper §4)",
                self.n
            )));
        }
        if !self.block_size.is_power_of_two() {
            return Err(SpinError::config(format!(
                "block_size {} must be a power of two",
                self.block_size
            )));
        }
        if self.block_size > self.n {
            return Err(SpinError::config(format!(
                "block_size {} exceeds n {}",
                self.block_size, self.n
            )));
        }
        if !(self.tolerance > 0.0 && self.tolerance.is_finite()) {
            return Err(SpinError::config(format!(
                "tolerance must be a positive finite number, got {}",
                self.tolerance
            )));
        }
        if self.max_iters == 0 {
            return Err(SpinError::config("max_iters must be at least 1"));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::object(vec![
            ("n", Json::num(self.n as f64)),
            ("block_size", Json::num(self.block_size as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("generator", Json::str(self.generator.name())),
            ("leaf", Json::str(self.leaf.name())),
            ("fuse_leaf_2x2", Json::Bool(self.fuse_leaf_2x2)),
            ("residual_check", Json::Bool(self.residual_check)),
            ("tolerance", Json::num(self.tolerance)),
            ("max_iters", Json::num(self.max_iters as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let n = v
            .req("n")?
            .as_usize()
            .ok_or_else(|| SpinError::config("`n` must be a positive integer"))?;
        let block_size = v
            .req("block_size")?
            .as_usize()
            .ok_or_else(|| SpinError::config("`block_size` must be a positive integer"))?;
        let mut job = JobConfig::new(n, block_size);
        if let Some(j) = v.get("seed") {
            job.seed = j
                .as_i64()
                .ok_or_else(|| SpinError::config("`seed` must be an integer"))? as u64;
        }
        if let Some(j) = v.get("generator") {
            job.generator = GeneratorKind::parse(
                j.as_str()
                    .ok_or_else(|| SpinError::config("`generator` must be a string"))?,
            )?;
        }
        if let Some(j) = v.get("leaf") {
            job.leaf = LeafMethod::parse(
                j.as_str()
                    .ok_or_else(|| SpinError::config("`leaf` must be a string"))?,
            )?;
        }
        if let Some(j) = v.get("fuse_leaf_2x2") {
            job.fuse_leaf_2x2 = j
                .as_bool()
                .ok_or_else(|| SpinError::config("`fuse_leaf_2x2` must be a bool"))?;
        }
        if let Some(j) = v.get("residual_check") {
            job.residual_check = j
                .as_bool()
                .ok_or_else(|| SpinError::config("`residual_check` must be a bool"))?;
        }
        if let Some(j) = v.get("tolerance") {
            job.tolerance = j
                .as_f64()
                .ok_or_else(|| SpinError::config("`tolerance` must be a number"))?;
        }
        if let Some(j) = v.get("max_iters") {
            job.max_iters = j
                .as_usize()
                .ok_or_else(|| SpinError::config("`max_iters` must be a positive integer"))?;
        }
        job.validate()?;
        Ok(job)
    }

    /// Apply a `key=value` override (CLI `--job`).
    pub fn apply_override(&mut self, kv: &str) -> Result<()> {
        let (key, value) = kv
            .split_once('=')
            .ok_or_else(|| SpinError::config(format!("override `{kv}` is not key=value")))?;
        match key {
            "n" => {
                self.n = value
                    .parse()
                    .map_err(|_| SpinError::config("n needs an integer"))?
            }
            "block_size" => {
                self.block_size = value
                    .parse()
                    .map_err(|_| SpinError::config("block_size needs an integer"))?
            }
            "seed" => {
                self.seed = value
                    .parse()
                    .map_err(|_| SpinError::config("seed needs an integer"))?
            }
            "generator" => self.generator = GeneratorKind::parse(value)?,
            "leaf" => self.leaf = LeafMethod::parse(value)?,
            "fuse_leaf_2x2" => {
                self.fuse_leaf_2x2 = value
                    .parse()
                    .map_err(|_| SpinError::config("fuse_leaf_2x2 needs true|false"))?
            }
            "residual_check" => {
                self.residual_check = value
                    .parse()
                    .map_err(|_| SpinError::config("residual_check needs true|false"))?
            }
            "tolerance" => {
                self.tolerance = value
                    .parse()
                    .map_err(|_| SpinError::config("tolerance needs a number"))?
            }
            "max_iters" => {
                self.max_iters = value
                    .parse()
                    .map_err(|_| SpinError::config("max_iters needs an integer"))?
            }
            other => return Err(SpinError::config(format!("unknown job key `{other}`"))),
        }
        self.validate()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_cluster_dimensions() {
        let c = ClusterConfig::paper();
        assert_eq!(c.total_executors(), 6);
        assert_eq!(c.total_cores(), 30);
        c.validate().unwrap();
    }

    #[test]
    fn local_preset() {
        let c = ClusterConfig::local(4);
        assert_eq!(c.total_cores(), 4);
        assert!(c.virtual_time);
    }

    #[test]
    fn network_transfer_time() {
        let net = NetworkConfig {
            bandwidth_gbps: 8.0,
            latency_us: 0.0,
        };
        // 1 GB over 8 Gb/s = 1 second.
        assert!((net.transfer_secs(1_000_000_000) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cluster_json_round_trip() {
        let mut c = ClusterConfig::paper();
        c.backend = BackendKind::Xla;
        c.worker_threads = 3;
        c.exec_threads = 4;
        c.partitioner_aware = false;
        c.plan_optimizer = false;
        c.verify_plans = true;
        c.cache_budget_bytes = 1 << 20;
        c.metrics_history = 500;
        c.fault_seed = Some(0xC0FFEE);
        c.fault_rate = 0.25;
        c.fault_kinds = FaultKinds {
            task_panic: false,
            task_error: true,
            straggle: true,
        };
        c.task_retries = 5;
        c.retry_backoff_secs = 0.125;
        c.speculation_multiplier = 2.5;
        c.checkpoint_every_level = 2;
        c.tenant_queue_quota = 8;
        c.tenant_inflight_cap = 2;
        let back = ClusterConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
        // fault_seed=None survives the trip too (serialized as null).
        let c = ClusterConfig::paper();
        assert_eq!(ClusterConfig::from_json(&c.to_json()).unwrap(), c);
    }

    #[test]
    fn fault_kinds_parse_and_render() {
        assert_eq!(FaultKinds::parse("task_panic|task_error|straggle").unwrap(), FaultKinds::all());
        let k = FaultKinds::parse("straggle").unwrap();
        assert!(k.straggle && !k.task_panic && !k.task_error);
        assert_eq!(k.name(), "straggle");
        assert_eq!(FaultKinds::all().name(), "task_panic|task_error|straggle");
        assert!(FaultKinds::parse("os_kill").is_err());
        assert!(!FaultKinds::parse("").unwrap().any());
    }

    #[test]
    fn resilience_validation_and_overrides() {
        let mut c = ClusterConfig::local(2);
        c.apply_override("fault_seed=42").unwrap();
        assert_eq!(c.fault_seed, Some(42));
        c.apply_override("fault_rate=0.1").unwrap();
        c.apply_override("fault_kinds=straggle").unwrap();
        c.apply_override("task_retries=2").unwrap();
        c.apply_override("retry_backoff_secs=0.01").unwrap();
        c.apply_override("speculation_multiplier=4").unwrap();
        c.apply_override("checkpoint_every_level=1").unwrap();
        c.apply_override("tenant_queue_quota=4").unwrap();
        c.apply_override("tenant_inflight_cap=1").unwrap();
        c.validate().unwrap();
        c.apply_override("fault_seed=none").unwrap();
        assert_eq!(c.fault_seed, None);
        // Out-of-range and inconsistent settings are rejected.
        assert!(c.apply_override("fault_rate=1.5").is_err());
        assert!(c.apply_override("retry_backoff_secs=-1").is_err());
        let mut armed = ClusterConfig::local(2);
        armed.fault_seed = Some(1);
        armed.fault_kinds = FaultKinds::none();
        assert!(armed.validate().is_err(), "armed injector needs kinds");
    }

    #[test]
    fn job_json_round_trip() {
        let mut j = JobConfig::new(512, 64);
        j.generator = GeneratorKind::Spd;
        j.fuse_leaf_2x2 = true;
        let back = JobConfig::from_json(&j.to_json()).unwrap();
        assert_eq!(back, j);
    }

    #[test]
    fn job_validation() {
        assert!(JobConfig::new(100, 10).validate().is_err()); // not pow2
        assert!(JobConfig::new(64, 128).validate().is_err()); // block > n
        assert!(JobConfig::new(256, 64).validate().is_ok());
        assert_eq!(JobConfig::new(256, 64).num_splits(), 4);
    }

    #[test]
    fn overrides() {
        let mut c = ClusterConfig::paper();
        c.apply_override("nodes=5").unwrap();
        assert_eq!(c.nodes, 5);
        c.apply_override("backend=xla").unwrap();
        assert_eq!(c.backend, BackendKind::Xla);
        c.apply_override("plan_optimizer=false").unwrap();
        assert!(!c.plan_optimizer);
        c.apply_override("verify_plans=true").unwrap();
        assert!(c.verify_plans);
        assert!(c.apply_override("verify_plans=maybe").is_err());
        c.apply_override("cache_budget_bytes=65536").unwrap();
        assert_eq!(c.cache_budget_bytes, 65536);
        assert!(c.apply_override("cache_budget_bytes=lots").is_err());
        c.apply_override("metrics_history=200").unwrap();
        assert_eq!(c.metrics_history, 200);
        assert!(c.apply_override("metrics_history=many").is_err());
        assert!(c.apply_override("bogus=1").is_err());
        assert!(c.apply_override("no-equals").is_err());

        let mut j = JobConfig::new(256, 64);
        j.apply_override("block_size=32").unwrap();
        assert_eq!(j.num_splits(), 8);
        assert!(j.apply_override("block_size=7").is_err());
    }

    #[test]
    fn http_config_round_trip_validation_and_overrides() {
        let base = HttpConfig::default();
        base.validate().unwrap();
        let back = HttpConfig::from_json(&base.to_json()).unwrap();
        assert_eq!(back, base);
        let mut c = base.clone();
        c.apply_override("listen=0.0.0.0:9000").unwrap();
        assert_eq!(c.listen, "0.0.0.0:9000");
        c.apply_override("max_body_bytes=4096").unwrap();
        c.apply_override("sse_heartbeat_ms=250").unwrap();
        assert_eq!((c.max_body_bytes, c.sse_heartbeat_ms), (4096, 250));
        assert!(c.apply_override("max_body_bytes=0").is_err());
        assert!(c.apply_override("bogus=1").is_err());
        // Strict JSON: a typo'd key is named in the error.
        let doc = Json::parse(r#"{"listn": "x:1"}"#).unwrap();
        let err = HttpConfig::from_json(&doc).unwrap_err().to_string();
        assert!(err.contains("`listn`"), "{err}");
    }

    #[test]
    fn with_executors_scales() {
        let c = ClusterConfig::paper().with_executors(4);
        assert_eq!(c.total_executors(), 4);
        assert_eq!(c.total_cores(), 20);
    }
}
