//! Calibration: fit the cost model's machine constants from measured
//! probes on this host, so Figure 4 compares theory and measurement on the
//! same footing (the paper implicitly calibrates by running on one fixed
//! testbed).

use std::time::Instant;

use super::CostConstants;
use crate::config::NetworkConfig;
use crate::linalg::{self, diag_dominant, Matrix};
use crate::util::Rng;

/// What the probes measured.
#[derive(Debug, Clone)]
pub struct CalibrationReport {
    pub constants: CostConstants,
    /// Measured serial leaf-inversion GFLOP/s.
    pub leaf_gflops: f64,
    /// Measured block-GEMM GFLOP/s.
    pub gemm_gflops: f64,
    /// Probe block size used.
    pub probe_size: usize,
}

/// Run the probes (a leaf inversion and a GEMM at `probe_size`, plus a
/// block-metadata pass) and fit [`CostConstants`].
pub fn calibrate(probe_size: usize, network: &NetworkConfig) -> CalibrationReport {
    let mut rng = Rng::new(0xCA11B);
    let s = probe_size;
    let a = diag_dominant(s, &mut rng);
    let b = Matrix::random_uniform(s, s, -1.0, 1.0, &mut rng);

    // --- leaf inversion probe (LU + solve ≈ 8/3·s³ flops).
    let reps = 3;
    let mut leaf_best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        let Ok(inv) = linalg::lu_inverse(&a) else {
            continue;
        };
        std::hint::black_box(&inv);
        leaf_best = leaf_best.min(t0.elapsed().as_secs_f64());
    }
    let leaf_flops = (8.0 / 3.0) * (s as f64).powi(3);
    let sec_per_leaf_flop = leaf_best / leaf_flops;

    // --- GEMM probe (2·s³ flops).
    let mut gemm_best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        let c = linalg::matmul(&a, &b);
        std::hint::black_box(&c);
        gemm_best = gemm_best.min(t0.elapsed().as_secs_f64());
    }
    let gemm_flops = 2.0 * (s as f64).powi(3);
    let sec_per_gemm_flop = gemm_best / gemm_flops;

    // --- block-metadata probe: clone + retag a block, amortized.
    let blocks: Vec<Matrix> = (0..64)
        .map(|i| Matrix::random_uniform(16, 16, 0.0, 1.0, &mut rng.fork(i)))
        .collect();
    let t0 = Instant::now();
    let mut acc = 0.0;
    for blk in &blocks {
        let copy = blk.clone();
        acc += copy.get(0, 0);
    }
    std::hint::black_box(acc);
    let sec_per_block_op = (t0.elapsed().as_secs_f64() / blocks.len() as f64).max(1e-8);

    // --- communication constant from the configured interconnect.
    let sec_per_element_comm = network.transfer_secs(8) - network.latency_us * 1e-6;

    let constants = CostConstants {
        sec_per_leaf_flop,
        sec_per_gemm_flop,
        sec_per_block_op,
        sec_per_element_comm: sec_per_element_comm.max(1e-12),
        sec_per_stage: 1e-4,
    };
    CalibrationReport {
        leaf_gflops: 1e-9 / sec_per_leaf_flop,
        gemm_gflops: 1e-9 / sec_per_gemm_flop,
        probe_size: s,
        constants,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_produces_sane_constants() {
        let net = NetworkConfig {
            bandwidth_gbps: 14.0,
            latency_us: 50.0,
        };
        let rep = calibrate(96, &net);
        let k = &rep.constants;
        // One core does between 0.01 and 100 GFLOP/s, generously.
        assert!(rep.gemm_gflops > 0.01 && rep.gemm_gflops < 100.0, "{rep:?}");
        assert!(rep.leaf_gflops > 0.001 && rep.leaf_gflops < 100.0);
        assert!(k.sec_per_block_op > 0.0);
        assert!(k.sec_per_element_comm > 0.0);
        // 8 bytes over 14 Gb/s ≈ 4.6e-9 s.
        assert!((k.sec_per_element_comm - 8.0 * 8.0 / 14e9).abs() < 1e-9);
    }

    #[test]
    fn calibrated_model_is_finite_and_positive() {
        let net = NetworkConfig {
            bandwidth_gbps: 14.0,
            latency_us: 50.0,
        };
        let rep = calibrate(64, &net);
        let c = super::super::spin_cost(512, 8, 30, &rep.constants);
        assert!(c.total().is_finite() && c.total() > 0.0);
    }
}
