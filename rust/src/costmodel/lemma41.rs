//! Lemma 4.1 — SPIN's wall-clock cost model.
//!
//! Per level `i ∈ [0, m)` with `m = log2 b`, the recursion has `2^i` nodes,
//! each holding a `(b/2^i)²`-block matrix of `(n/b)²`-element blocks:
//!
//! * `breakMat`  — scans `b²/4^i` blocks,        PF `min(b²/4^i, cores)`
//! * `xy`        — 4 filters over `b²/4^i` plus 4 maps over `b²/4^(i+1)`
//! * `multiply`  — 6 products of half-grid `h = b/2^(i+1)`:
//!                 `6·h³` block GEMMs of `2·(n/b)³` flops,
//!                 PF `min(n²/4^(i+1), cores)`; plus replication traffic of
//!                 `2·h³` blocks per product, PF `min(b²/4^(i+1), cores)`
//! * `subtract`  — 2 maps over `(n/2^(i+1))²` elements
//! * `scalarMul` — 1 map over `b²/4^(i+1)` blocks
//! * `arrange`   — re-index maps over `4·(b²/4^(i+1))` blocks
//!
//! Leaves: `b` blocks inverted serially (`~2/3·(n/b)³` flops each), no PF —
//! the recursion sequences them (the paper's eq. 2, `n³/b²`).
//!
//! Summed over levels with constant PF this reproduces the paper's closed
//! forms (eqs. 3–11); machine constants come from [`super::CostConstants`].

use super::{pf, CostBreakdown, CostConstants};

/// Evaluate the SPIN cost model (seconds).
pub fn spin_cost(n: usize, b: usize, cores: usize, k: &CostConstants) -> CostBreakdown {
    assert!(b.is_power_of_two() && n % b == 0, "need pow2 splits dividing n");
    let nb = (n / b) as f64; // block edge
    let m = b.trailing_zeros() as usize; // recursion depth
    let mut out = CostBreakdown::default();

    // ---- leaves: b serial inversions of nb×nb, sequenced by recursion.
    let leaf_flops = (2.0 / 3.0) * nb.powi(3) + 2.0 * nb.powi(3); // LU + solve
    out.leaf_node = b as f64 * leaf_flops * k.sec_per_leaf_flop + b as f64 * k.sec_per_stage;

    for i in 0..m {
        let nodes = (1u64 << i) as f64;
        let blocks_in = (b as f64 / 2f64.powi(i as i32)).powi(2); // b²/4^i
        let blocks_half = blocks_in / 4.0; // b²/4^(i+1)
        let h = b as f64 / 2f64.powi(i as i32 + 1); // half-grid edge

        // breakMat: one pass over the node's blocks.
        out.break_mat += nodes * (blocks_in * k.sec_per_block_op + k.sec_per_stage)
            / pf(blocks_in, cores);

        // xy: 4 filters (full scan) + 4 maps (quarter scan).
        out.xy += nodes * 4.0 * (blocks_in * k.sec_per_block_op + k.sec_per_stage)
            / pf(blocks_in, cores);
        out.xy += nodes * 4.0 * (blocks_half * k.sec_per_block_op + k.sec_per_stage)
            / pf(blocks_half, cores);

        // multiply: 6 half-grid products, h³ block-GEMM tasks each.
        //
        // The paper's PF here is `min(n²/4^(i+1), cores)` — element count —
        // which saturates to `cores` even when a product has a single block
        // task. We use the task count `h³` (what a Spark stage actually
        // schedules), which matches the measured substrate; for large grids
        // the two coincide.
        let gemm_flops_per_product = 2.0 * h.powi(3) * nb.powi(3) * 2.0; // h³ GEMMs + adds
        out.multiply += nodes * 6.0
            * (gemm_flops_per_product * k.sec_per_gemm_flop + k.sec_per_stage)
            / pf(h.powi(3), cores);

        // multiply replication traffic: each product replicates both
        // operands b-fold at its grid size: 2·h³ blocks of nb² elements.
        let comm_elems = 2.0 * h.powi(3) * nb * nb;
        out.communication += nodes * 6.0 * comm_elems * k.sec_per_element_comm
            / pf(blocks_half, cores);

        // subtract: 2 per level over half-size matrices (h² block tasks).
        let elems_half = (n as f64 / 2f64.powi(i as i32 + 1)).powi(2); // n²/4^(i+1)
        out.subtract += nodes * 2.0
            * (elems_half * k.sec_per_leaf_flop + k.sec_per_stage)
            / pf(h * h, cores);

        // scalarMul: 1 per level over the half grid.
        out.scalar_mul += nodes * (blocks_half * k.sec_per_block_op + k.sec_per_stage)
            / pf(blocks_half, cores);

        // arrange: 4 re-index maps over quarter grids.
        out.arrange += nodes * 4.0 * (blocks_half * k.sec_per_block_op + k.sec_per_stage)
            / pf(blocks_half, cores);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    fn k() -> CostConstants {
        CostConstants::default()
    }

    #[test]
    fn b1_is_pure_leaf() {
        let c = spin_cost(512, 1, 30, &k());
        assert!(c.leaf_node > 0.0);
        assert_eq!(c.multiply, 0.0);
        assert_eq!(c.break_mat, 0.0);
        assert!((c.total() - c.leaf_node).abs() < 1e-15);
    }

    #[test]
    fn leaf_term_matches_eq2_scaling() {
        // leafNode ∝ n³/b²: quadrupling b should cut leaf time ~16x.
        let c2 = spin_cost(1024, 2, 30, &k());
        let c8 = spin_cost(1024, 8, 30, &k());
        let ratio = c2.leaf_node / c8.leaf_node;
        assert!((ratio - 16.0).abs() / 16.0 < 0.05, "ratio {ratio}");
    }

    #[test]
    fn multiply_work_grows_with_b() {
        // With PF forced to 1 (cores=1) the multiply term is pure compute,
        // which grows with recursion depth: Σ 2^i·6·(b/2^(i+1))³ block GEMMs.
        let k = k();
        let c2 = spin_cost(1024, 2, 1, &k);
        let c16 = spin_cost(1024, 16, 1, &k);
        assert!(c16.multiply > c2.multiply);
        // Total replication traffic (PF=1) grows ≈ linearly with b.
        assert!(c16.communication > c2.communication);
    }

    #[test]
    fn u_shape_has_interior_minimum() {
        // The paper's headline analytic behaviour (Fig. 3/4).
        let k = k();
        let n = 4096;
        let bs: Vec<usize> = (1..=8).map(|e| 1usize << e).collect(); // 2..256
        let costs: Vec<f64> = bs.iter().map(|&b| spin_cost(n, b, 30, &k).total()).collect();
        let (argmin, _) = costs
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        assert!(
            argmin > 0 && argmin < bs.len() - 1,
            "minimum at edge: b={} costs={costs:?}",
            bs[argmin]
        );
    }

    #[test]
    fn more_cores_never_slower() {
        forall(
            "cost monotone in cores",
            0x41,
            24,
            |r| {
                let n = 1usize << (8 + r.next_usize(4)); // 256..2048
                let b = 1usize << (1 + r.next_usize(4)); // 2..16
                let cores = 1 + r.next_usize(64);
                (n, b, cores)
            },
            |&(n, b, cores)| {
                let k = CostConstants::default();
                let c1 = spin_cost(n, b, cores, &k).total();
                let c2 = spin_cost(n, b, cores + 8, &k).total();
                if c2 <= c1 + 1e-12 {
                    Ok(())
                } else {
                    Err(format!("cores {cores}->{}: {c1} -> {c2}", cores + 8))
                }
            },
        );
    }

    #[test]
    fn cost_scales_cubically_in_n_for_fixed_b() {
        let k = k();
        let c1 = spin_cost(512, 4, 30, &k).total();
        let c2 = spin_cost(1024, 4, 30, &k).total();
        let ratio = c2 / c1;
        assert!(ratio > 6.0 && ratio < 10.0, "n-doubling ratio {ratio}");
    }

    #[test]
    #[should_panic(expected = "pow2")]
    fn rejects_non_pow2_b() {
        spin_cost(512, 3, 30, &k());
    }
}
