//! Lemma 4.2 — the LU baseline's wall-clock cost model.
//!
//! Mirrors the structure of `algos::lu` (which follows Liu et al. 2016):
//!
//! * recursive block LU — per level: 3 half-grid multiplies, 1 subtract,
//!   and (recursively) two block-triangular inversions of the level's
//!   leading quadrant factors;
//! * block-triangular inversion — per level: 2 half-grid multiplies,
//!   1 scalarMul, plus 2 recursive calls;
//! * leaves — the paper's "9 O((n/b)³) operations": LU factorization plus
//!   two triangular inversions per leaf position across the trees
//!   (vs. SPIN's single inversion);
//! * the "Additional Cost" — the final full-grid product `U⁻¹·L⁻¹`
//!   (the paper's `7·(n/2)³` term).
//!
//! The same [`super::CostConstants`] are used for both lemmas, so the
//! SPIN-vs-LU comparison depends only on algorithm structure.

use super::{pf, CostBreakdown, CostConstants};

/// Evaluate the LU-baseline cost model (seconds).
pub fn lu_cost(n: usize, b: usize, cores: usize, k: &CostConstants) -> CostBreakdown {
    assert!(b.is_power_of_two() && n % b == 0, "need pow2 splits dividing n");
    let mut out = CostBreakdown::default();
    // Full-grid product U⁻¹·L⁻¹ — the paper's Additional Cost: b³ block
    // GEMMs at the top grid size.
    add_multiply(&mut out, n, b, b, 1.0, cores, k);
    lu_rec(&mut out, n, b, b, 1.0, cores, k);
    tri_rec(&mut out, n, b, b, 1.0, cores, k); // L⁻¹ tree
    tri_rec(&mut out, n, b, b, 1.0, cores, k); // U⁻¹ tree
    out
}

/// Cost of `count` distributed multiplies on a `g×g` grid of `(n/b)`-blocks.
fn add_multiply(
    out: &mut CostBreakdown,
    n: usize,
    b: usize,
    g: usize,
    count: f64,
    cores: usize,
    k: &CostConstants,
) {
    let nb = (n / b) as f64;
    let gf = g as f64;
    // Task-based PF (g³ block GEMMs), matching lemma41's convention.
    let gemm_flops = 2.0 * gf.powi(3) * nb.powi(3) * 2.0;
    out.multiply += count * (gemm_flops * k.sec_per_gemm_flop + k.sec_per_stage)
        / pf(gf.powi(3), cores);
    let comm_elems = 2.0 * gf.powi(3) * nb * nb;
    out.communication +=
        count * comm_elems * k.sec_per_element_comm / pf(gf * gf, cores);
}

/// breakMat + xy + arrange bookkeeping for one recursion node on a `g` grid.
fn add_bookkeeping(out: &mut CostBreakdown, g: usize, count: f64, cores: usize, k: &CostConstants) {
    let blocks = (g * g) as f64;
    let blocks_half = blocks / 4.0;
    out.break_mat += count * (blocks * k.sec_per_block_op + k.sec_per_stage) / pf(blocks, cores);
    out.xy += count * 4.0 * (blocks * k.sec_per_block_op + k.sec_per_stage) / pf(blocks, cores);
    out.xy +=
        count * 4.0 * (blocks_half * k.sec_per_block_op + k.sec_per_stage) / pf(blocks_half, cores);
    out.arrange +=
        count * 4.0 * (blocks_half * k.sec_per_block_op + k.sec_per_stage) / pf(blocks_half, cores);
}

/// Recursive block-LU cost on a `g×g` grid (`count` concurrent nodes).
fn lu_rec(
    out: &mut CostBreakdown,
    n: usize,
    b: usize,
    g: usize,
    count: f64,
    cores: usize,
    k: &CostConstants,
) {
    let nb = (n / b) as f64;
    if g == 1 {
        // Leaf: serial pivot-free LU (~2/3·nb³ flops) emitted twice in the
        // implementation (L pass + U pass).
        let flops = 2.0 * (2.0 / 3.0) * nb.powi(3);
        out.leaf_node += count * (flops * k.sec_per_leaf_flop + 2.0 * k.sec_per_stage);
        return;
    }
    add_bookkeeping(out, g, count, cores, k);
    let h = g / 2;
    // Two recursive LU calls (A11 and the Schur complement)…
    lu_rec(out, n, b, h, 2.0 * count, cores, k);
    // …two triangular inversions of the half-grid factors…
    tri_rec(out, n, b, h, count, cores, k);
    tri_rec(out, n, b, h, count, cores, k);
    // …3 multiplies + 1 subtract at the half grid.
    add_multiply(out, n, b, h, 3.0 * count, cores, k);
    let elems_half = ((h as f64) * nb).powi(2);
    out.subtract += count * (elems_half * k.sec_per_leaf_flop + k.sec_per_stage)
        / pf((h * h) as f64, cores);
}

/// Recursive block-triangular inversion cost on a `g×g` grid.
fn tri_rec(
    out: &mut CostBreakdown,
    n: usize,
    b: usize,
    g: usize,
    count: f64,
    cores: usize,
    k: &CostConstants,
) {
    let nb = (n / b) as f64;
    if g == 1 {
        // Serial triangular inversion ≈ nb³/3 flops.
        let flops = nb.powi(3) / 3.0;
        out.leaf_node += count * (flops * k.sec_per_leaf_flop + k.sec_per_stage);
        return;
    }
    add_bookkeeping(out, g, count, cores, k);
    let h = g / 2;
    tri_rec(out, n, b, h, 2.0 * count, cores, k);
    add_multiply(out, n, b, h, 2.0 * count, cores, k);
    let blocks_half = ((h * h) as f64).max(1.0);
    out.scalar_mul +=
        count * (blocks_half * k.sec_per_block_op + k.sec_per_stage) / pf(blocks_half, cores);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::spin_cost;

    fn k() -> CostConstants {
        CostConstants::default()
    }

    #[test]
    fn lu_leaf_work_comparable_but_stage_heavy() {
        // Paper counts 9 uniform O((n/b)³) leaf ops for LU vs 1 for SPIN.
        // In this formulation LU's leaves are cheaper *kernels*
        // (factorizations / triangular inverses, ~nb³·14/3 flops total at
        // b=2) but 4–7× more *stages*; the flop totals stay within 2× of
        // SPIN's full inversions while LU's multiply side explodes — which
        // is where the measured gap comes from (see EXPERIMENTS.md).
        for b in [2usize, 4, 8, 16] {
            let lu = lu_cost(1024, b, 30, &k());
            let spin = spin_cost(1024, b, 30, &k());
            let ratio = lu.leaf_node / spin.leaf_node;
            assert!(
                (0.4..4.0).contains(&ratio),
                "b={b}: LU/SPIN leaf ratio {ratio}"
            );
        }
    }

    #[test]
    fn lu_total_exceeds_spin_everywhere() {
        // The paper's headline (Figs. 2–3): SPIN wins at every (n, b).
        for n in [512usize, 1024, 4096] {
            for b in [2usize, 4, 8, 16] {
                let lu = lu_cost(n, b, 30, &k()).total();
                let spin = spin_cost(n, b, 30, &k()).total();
                assert!(lu > spin, "n={n} b={b}: LU {lu} <= SPIN {spin}");
            }
        }
    }

    #[test]
    fn gap_grows_with_n() {
        // Figure 2: the SPIN-LU gap widens monotonically with matrix size.
        let k = k();
        let gap = |n: usize| {
            let b = 8;
            lu_cost(n, b, 30, &k).total() - spin_cost(n, b, 30, &k).total()
        };
        assert!(gap(1024) > gap(512));
        assert!(gap(2048) > gap(1024));
    }

    #[test]
    fn lu_also_u_shaped() {
        let k = k();
        let costs: Vec<f64> = (1..=7)
            .map(|e| lu_cost(4096, 1 << e, 30, &k).total())
            .collect();
        let (argmin, _) = costs
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap();
        assert!(argmin > 0 && argmin < costs.len() - 1, "costs={costs:?}");
    }

    #[test]
    fn b1_has_no_distributed_work() {
        let c = lu_cost(256, 1, 30, &k());
        assert_eq!(c.break_mat, 0.0);
        assert!(c.leaf_node > 0.0);
        // b=1 still pays the final U⁻¹·L⁻¹ product of the single block.
        assert!(c.multiply > 0.0);
    }
}
