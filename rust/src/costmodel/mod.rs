//! The paper's §4 wall-clock cost analysis: Lemma 4.1 (SPIN), Lemma 4.2
//! (the LU baseline), the Table 1 summary, and the calibration that fits
//! the model's machine constants from measured probes.
//!
//! The model follows the paper's derivation exactly: per recursion level
//! `i` (of `m = log2 b`), each method contributes
//! `computation(i) / min(tasks(i), cores)` plus communication for the
//! shuffle-bearing methods; leaves contribute the serial `n³/b²` term with
//! no parallelization factor (one block on one worker, sequenced by the
//! recursion). Summing levels reproduces the paper's closed forms (their
//! equations 2–11) up to the machine constants κ, which the paper leaves
//! implicit and we fit by calibration.

mod calibrate;
mod lemma41;
mod lemma42;
mod table1;

pub use calibrate::{calibrate, CalibrationReport};
pub use lemma41::spin_cost;
pub use lemma42::lu_cost;
pub use table1::render_table1;

/// Machine constants for the cost model (the κ's the paper folds into its
/// big-O terms). Fitted by [`calibrate`]; defaults are order-of-magnitude
/// sane for one modern core.
#[derive(Debug, Clone, PartialEq)]
pub struct CostConstants {
    /// Seconds per FLOP of serial leaf inversion (LU ≈ 2/3·s³ flops).
    pub sec_per_leaf_flop: f64,
    /// Seconds per FLOP of block GEMM (2·s³ flops per block product).
    pub sec_per_gemm_flop: f64,
    /// Seconds per block handled by a metadata pass (breakMat / xy /
    /// scalarMul / arrange task bodies).
    pub sec_per_block_op: f64,
    /// Seconds per matrix element crossing the shuffle.
    pub sec_per_element_comm: f64,
    /// Fixed per-stage scheduling overhead (Spark task-launch analogue).
    pub sec_per_stage: f64,
}

impl Default for CostConstants {
    fn default() -> Self {
        CostConstants {
            sec_per_leaf_flop: 1.5e-9,
            sec_per_gemm_flop: 4.0e-10,
            sec_per_block_op: 2.0e-5,
            sec_per_element_comm: 1.0e-9,
            sec_per_stage: 1.0e-4,
        }
    }
}

/// Per-method cost decomposition (the paper's Table 3 rows, in seconds).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct CostBreakdown {
    pub leaf_node: f64,
    pub break_mat: f64,
    pub xy: f64,
    pub multiply: f64,
    pub subtract: f64,
    pub scalar_mul: f64,
    pub arrange: f64,
    /// Shuffle/communication time (multiply replication traffic).
    pub communication: f64,
}

impl CostBreakdown {
    pub fn total(&self) -> f64 {
        self.leaf_node
            + self.break_mat
            + self.xy
            + self.multiply
            + self.subtract
            + self.scalar_mul
            + self.arrange
            + self.communication
    }

    /// Named rows for reports.
    pub fn rows(&self) -> Vec<(&'static str, f64)> {
        vec![
            ("leafNode", self.leaf_node),
            ("breakMat", self.break_mat),
            ("xy", self.xy),
            ("multiply", self.multiply),
            ("subtract", self.subtract),
            ("scalar", self.scalar_mul),
            ("arrange", self.arrange),
            ("communication", self.communication),
        ]
    }
}

/// The paper's parallelization factor `min(tasks, cores)`.
pub(crate) fn pf(tasks: f64, cores: usize) -> f64 {
    tasks.min(cores as f64).max(1.0)
}

/// Closed-form distributed multiply-round counts per algorithm at grid
/// `b` (each round = one multiply/multiply_sub node = 2 exchange stages),
/// mirrored from the lemma recursion trees: Lemma 4.1's six half-grid
/// products per SPIN level over two recursive calls (`S(g) = 2S(g/2) + 6`
/// ⇒ `6·(b − 1)`), Lemma 4.2's three factor-level products plus two per
/// triangular level plus the final full-size product, the Cholesky
/// variant with one triangular inversion and a two-product factor level,
/// and Newton's two products per pass less the skipped final update.
///
/// `max_iters` applies to `newton` only. `None` for unknown algorithms.
/// The static plan verifier (`spin lint`) cross-checks the counts it
/// derives from plan structure against these forms.
pub fn analytic_multiply_rounds(algo: &str, b: usize, max_iters: usize) -> Option<usize> {
    fn tri(b: usize) -> usize {
        if b <= 1 {
            return 0;
        }
        2 * tri(b / 2) + 2
    }
    fn lu_factor(b: usize) -> usize {
        if b <= 1 {
            return 0;
        }
        2 * lu_factor(b / 2) + 2 * tri(b / 2) + 3
    }
    fn chol_factor(b: usize) -> usize {
        if b <= 1 {
            return 0;
        }
        2 * chol_factor(b / 2) + tri(b / 2) + 2
    }
    match algo {
        "spin" => Some(6 * b.saturating_sub(1)),
        "lu" => Some(lu_factor(b) + 2 * tri(b) + 1),
        "cholesky" => Some(chol_factor(b) + tri(b) + 1),
        "newton" => Some(2 * max_iters.saturating_sub(1) + 1),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn breakdown_total_sums_rows() {
        let b = CostBreakdown {
            leaf_node: 1.0,
            break_mat: 2.0,
            xy: 3.0,
            multiply: 4.0,
            subtract: 5.0,
            scalar_mul: 6.0,
            arrange: 7.0,
            communication: 8.0,
        };
        let row_sum: f64 = b.rows().iter().map(|(_, v)| v).sum();
        assert!((b.total() - 36.0).abs() < 1e-12);
        assert!((row_sum - 36.0).abs() < 1e-12);
    }

    #[test]
    fn pf_clamps() {
        assert_eq!(pf(100.0, 30), 30.0);
        assert_eq!(pf(4.0, 30), 4.0);
        assert_eq!(pf(0.25, 30), 1.0);
    }
}
