//! Table 1 — "Summary of the cost analysis of LU and SPIN": the paper's
//! symbolic per-method computation costs and parallelization factors,
//! plus a numeric evaluation column from our calibrated model.

use super::{lu_cost, spin_cost, CostConstants};
use crate::util::fmt::{self, Table};

/// Render the paper's Table 1 (symbolic) with numeric totals for a given
/// configuration appended.
pub fn render_table1(n: usize, b: usize, cores: usize, k: &CostConstants) -> String {
    let mut t = Table::new(vec!["Method", "LU cost", "SPIN cost", "LU PF", "SPIN PF"]);
    t.row(vec![
        "leafNode",
        "9·n³/b²",
        "n³/b²",
        "—",
        "—",
    ]);
    t.row(vec![
        "breakMat",
        "2/3·(b²−3b+2)",
        "2b²−2b",
        "min[b²/4^i, cores]",
        "min[b²/4^i, cores]",
    ]);
    t.row(vec![
        "xy (filter)",
        "2/3·(b²−3b+2)",
        "8b²−4b",
        "min[b²/4^(i+1), cores]",
        "min[b²/4^i, cores]",
    ]);
    t.row(vec![
        "xy (map)",
        "1/6·(b²−3b+2)",
        "2b²−2b",
        "min[b²/4^(i+2), cores]",
        "min[b²/4^(i+1), cores]",
    ]);
    t.row(vec![
        "multiply",
        "16n³/21b³·(b³−7b+6)",
        "n³/6b²·(b²−1)",
        "min[n²/4^i, cores]",
        "min[n²/4^(i+1), cores]",
    ]);
    t.row(vec![
        "multiply comm.",
        "8n²(b²−1)(8b²−112)/105b²",
        "n²(b²−1)/6b",
        "min[b²/4^i, cores]",
        "min[b²/4^(i+1), cores]",
    ]);
    t.row(vec![
        "subtract",
        "2n²/3b²·(b²−3b+2)",
        "n²/2b·(b−1)",
        "min[n²/4^i, cores]",
        "min[n²/4^(i+1), cores]",
    ]);
    t.row(vec![
        "scalarMul",
        "4/3·(b²−3b+2)",
        "b/2·(b−1)",
        "min[b²/4^i, cores]",
        "min[b²/4^(i+1), cores]",
    ]);
    t.row(vec![
        "arrange",
        "—",
        "b/2·(b−1)",
        "—",
        "min[b²/4^(i+1), cores]",
    ]);
    t.row(vec![
        "Additional Cost",
        "7·(n/2)³",
        "—",
        "min[n²/4, cores]",
        "—",
    ]);

    let lu = lu_cost(n, b, cores, k);
    let spin = spin_cost(n, b, cores, k);
    let mut numeric = Table::new(vec!["Method", "LU (model)", "SPIN (model)"]);
    for ((name, luv), (_, spinv)) in lu.rows().into_iter().zip(spin.rows()) {
        numeric.row(vec![
            name.to_string(),
            fmt::secs(luv),
            fmt::secs(spinv),
        ]);
    }
    numeric.row(vec![
        "TOTAL".to_string(),
        fmt::secs(lu.total()),
        fmt::secs(spin.total()),
    ]);

    format!(
        "Table 1 — symbolic cost summary (paper, per level i):\n{}\n\
         Numeric evaluation at n={n}, b={b}, cores={cores}:\n{}",
        t.render(),
        numeric.render()
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_all_methods() {
        let s = render_table1(1024, 8, 30, &CostConstants::default());
        for m in [
            "leafNode",
            "breakMat",
            "xy (filter)",
            "multiply",
            "subtract",
            "scalarMul",
            "arrange",
            "Additional Cost",
            "TOTAL",
        ] {
            assert!(s.contains(m), "missing row {m}");
        }
        assert!(s.contains("n³/b²"));
        assert!(s.contains("min[b²/4^i, cores]"));
    }
}
