//! Crate-wide error type (hand-rolled `Display`/`Error` impls — `thiserror`
//! is not in the offline vendor set).

use std::fmt;

/// Unified error for every layer of the stack.
#[derive(Debug)]
pub enum SpinError {
    /// Configuration file / CLI flag problems.
    Config(String),

    /// Filesystem and serialization I/O.
    Io(std::io::Error),

    /// JSON syntax or schema violations (hand-rolled parser in `ser::json`).
    Json { msg: String, line: usize, col: usize },

    /// Matrix dimension / block-grid mismatches.
    Shape(String),

    /// Singular pivots, non-finite values, failed residual checks.
    Numerical(String),

    /// Missing or malformed AOT artifacts (`artifacts/manifest.json`).
    Artifact(String),

    /// PJRT / XLA runtime failures.
    Xla(String),

    /// Scheduler / executor / shuffle failures in the cluster substrate.
    Cluster(String),

    /// Static plan-verifier violations (`spin lint`, `verify_plans`).
    Plan(String),
}

impl fmt::Display for SpinError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SpinError::Config(msg) => write!(f, "config error: {msg}"),
            SpinError::Io(e) => write!(f, "io error: {e}"),
            SpinError::Json { msg, line, col } => {
                write!(f, "json error at line {line}, col {col}: {msg}")
            }
            SpinError::Shape(msg) => write!(f, "shape error: {msg}"),
            SpinError::Numerical(msg) => write!(f, "numerical error: {msg}"),
            SpinError::Artifact(msg) => write!(f, "artifact error: {msg}"),
            SpinError::Xla(msg) => write!(f, "xla error: {msg}"),
            SpinError::Cluster(msg) => write!(f, "cluster error: {msg}"),
            SpinError::Plan(msg) => write!(f, "plan verification error: {msg}"),
        }
    }
}

impl std::error::Error for SpinError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SpinError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for SpinError {
    fn from(e: std::io::Error) -> Self {
        SpinError::Io(e)
    }
}

impl From<xla::Error> for SpinError {
    fn from(e: xla::Error) -> Self {
        SpinError::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, SpinError>;

impl SpinError {
    /// Shorthand used by shape validators.
    pub fn shape(msg: impl Into<String>) -> Self {
        SpinError::Shape(msg.into())
    }

    pub fn config(msg: impl Into<String>) -> Self {
        SpinError::Config(msg.into())
    }

    pub fn numerical(msg: impl Into<String>) -> Self {
        SpinError::Numerical(msg.into())
    }

    pub fn artifact(msg: impl Into<String>) -> Self {
        SpinError::Artifact(msg.into())
    }

    pub fn cluster(msg: impl Into<String>) -> Self {
        SpinError::Cluster(msg.into())
    }

    pub fn plan(msg: impl Into<String>) -> Self {
        SpinError::Plan(msg.into())
    }
}
