//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every layer of the stack.
#[derive(Error, Debug)]
pub enum SpinError {
    /// Configuration file / CLI flag problems.
    #[error("config error: {0}")]
    Config(String),

    /// Filesystem and serialization I/O.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),

    /// JSON syntax or schema violations (hand-rolled parser in `ser::json`).
    #[error("json error at line {line}, col {col}: {msg}")]
    Json { msg: String, line: usize, col: usize },

    /// Matrix dimension / block-grid mismatches.
    #[error("shape error: {0}")]
    Shape(String),

    /// Singular pivots, non-finite values, failed residual checks.
    #[error("numerical error: {0}")]
    Numerical(String),

    /// Missing or malformed AOT artifacts (`artifacts/manifest.json`).
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT / XLA runtime failures.
    #[error("xla error: {0}")]
    Xla(String),

    /// Scheduler / executor / shuffle failures in the cluster substrate.
    #[error("cluster error: {0}")]
    Cluster(String),
}

impl From<xla::Error> for SpinError {
    fn from(e: xla::Error) -> Self {
        SpinError::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, SpinError>;

impl SpinError {
    /// Shorthand used by shape validators.
    pub fn shape(msg: impl Into<String>) -> Self {
        SpinError::Shape(msg.into())
    }

    pub fn config(msg: impl Into<String>) -> Self {
        SpinError::Config(msg.into())
    }

    pub fn numerical(msg: impl Into<String>) -> Self {
        SpinError::Numerical(msg.into())
    }

    pub fn artifact(msg: impl Into<String>) -> Self {
        SpinError::Artifact(msg.into())
    }

    pub fn cluster(msg: impl Into<String>) -> Self {
        SpinError::Cluster(msg.into())
    }
}
