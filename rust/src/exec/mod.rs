//! exec — persistent work-stealing partition runtime.
//!
//! Every stage the simulated cluster runs — narrow passes, shuffle
//! map/reduce waves, real-sleep straggler waves — fans out here when
//! `ClusterConfig::exec_threads > 1`. The pool is **process-wide** (one
//! pool per thread count, shared across every `Cluster` via
//! [`ExecPool::shared`]) and **persistent**: workers are spawned once and
//! park between stages, so per-stage submission costs a queue push, not a
//! thread spawn.
//!
//! ## Pool model
//!
//! * `threads` is the stage-level concurrency target: the pool spawns
//!   `threads − 1` dedicated workers and the *submitting thread helps
//!   execute* until its stage completes, so a stage runs on exactly
//!   `threads` lanes (more when several jobs submit concurrently — work
//!   conservation is the point of sharing one pool).
//! * Each worker owns a deque; submission round-robins tasks across the
//!   deques. Workers pop their own deque from the front and **steal from
//!   the back** of a victim's when empty. A claimed ticket (the
//!   `pending` count under the pool mutex) guarantees a task exists
//!   somewhere, so the scan loops until it finds one.
//! * **Panic isolation**: every task runs under `catch_unwind`; the first
//!   payload is re-thrown on the *submitting* thread after the stage's
//!   remaining tasks finish — a panicking partition fails its stage, not
//!   the pool (workers never die) and not unrelated jobs.
//! * **Scope inheritance**: `Metrics` scopes are thread-local, so a pool
//!   worker would otherwise record a job's stages into scope 0. The
//!   submitting thread's scope is captured at submission and re-entered
//!   around every task (see the regression test
//!   `overlapping_scopes_on_shared_pool_stay_separate`).
//!
//! ## Determinism contract
//!
//! Task *outputs* land in per-task slots indexed by submission order —
//! execution order and stealing never reorder results, so a parallel
//! stage is bit-identical to the sequential inline path. Shuffle reduce
//! ordering is the other half of the contract; see
//! `cluster/shuffle.rs::route_parallel` and `docs/EXECUTOR.md`.

use std::any::Any;
use std::collections::{BTreeMap, VecDeque};
use std::panic::{self, AssertUnwindSafe};
use std::sync::{Arc, Condvar, Mutex, OnceLock, Weak};
use std::time::Instant;

use crate::cluster::Metrics;
use crate::util::{plock, pwait};

/// Where a task ran — passed to every task so steals can be counted.
struct TaskCtx {
    stolen: bool,
}

type Runnable = Box<dyn FnOnce(&TaskCtx) + Send + 'static>;

/// Per-stage execution statistics measured by the pool (real wall clock,
/// not virtual time). Sums are over the stage's tasks.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StageExecStats {
    pub tasks: usize,
    /// Tasks that ran on a worker other than the one they were queued on.
    pub steals: usize,
    /// Total nanoseconds tasks spent queued before starting.
    pub queue_ns: u64,
    /// Total nanoseconds tasks spent executing.
    pub run_ns: u64,
    /// Real wall-clock nanoseconds from submission to stage completion.
    pub wall_ns: u64,
}

/// A completed stage: outputs in submission order, per-task measured
/// seconds (same order), and the pool's execution statistics.
pub struct StageRun<U> {
    pub outputs: Vec<U>,
    pub durations: Vec<f64>,
    pub stats: StageExecStats,
}

struct TaskResult<U> {
    value: U,
    secs: f64,
    queue_ns: u64,
    run_ns: u64,
    stolen: bool,
}

struct PoolState {
    /// Pushed-but-unclaimed task count. Incremented after a push,
    /// decremented when a worker claims a ticket; a claimed ticket
    /// guarantees some deque holds a task.
    pending: usize,
    shutdown: bool,
}

struct Shared {
    queues: Vec<Mutex<VecDeque<Runnable>>>,
    state: Mutex<PoolState>,
    available: Condvar,
}

impl Shared {
    /// Redeem a claimed ticket: pop the owner's deque front, else steal
    /// from a victim's back. Tickets outstanding never exceed tasks
    /// queued, so the scan retries until it wins one.
    fn take(&self, me: usize) -> (Runnable, bool) {
        loop {
            if let Some(task) = plock(&self.queues[me % self.queues.len()]).pop_front() {
                return (task, false);
            }
            for off in 1..self.queues.len() {
                let victim = (me + off) % self.queues.len();
                if let Some(task) = plock(&self.queues[victim]).pop_back() {
                    return (task, true);
                }
            }
            std::thread::yield_now();
        }
    }

    /// Claim a ticket without blocking; `Some` means a task is owed.
    fn try_claim(&self) -> bool {
        let mut st = plock(&self.state);
        if st.pending > 0 {
            st.pending -= 1;
            true
        } else {
            false
        }
    }
}

/// Stage-completion latch: counts down as tasks finish; the submitter
/// blocks on it before `run_stage` returns (which is what makes the
/// lifetime erasure in `run_stage` sound).
struct Latch {
    remaining: Mutex<usize>,
    done: Condvar,
}

impl Latch {
    fn new(n: usize) -> Self {
        Latch {
            remaining: Mutex::new(n),
            done: Condvar::new(),
        }
    }

    fn count_down(&self) {
        let mut g = plock(&self.remaining);
        *g -= 1;
        if *g == 0 {
            self.done.notify_all();
        }
    }

    fn is_done(&self) -> bool {
        *plock(&self.remaining) == 0
    }

    fn wait_done(&self) {
        let mut g = plock(&self.remaining);
        while *g > 0 {
            g = pwait(&self.done, g);
        }
    }
}

/// Waits out in-flight borrowed tasks even if the submitting frame
/// unwinds, so the stack state they reference cannot die under them.
struct LatchGuard<'a>(&'a Latch);

impl Drop for LatchGuard<'_> {
    fn drop(&mut self) {
        self.0.wait_done();
    }
}

/// The persistent work-stealing pool. See the module docs for the model.
pub struct ExecPool {
    shared: Arc<Shared>,
    threads: usize,
    workers: Mutex<Vec<std::thread::JoinHandle<()>>>,
}

impl ExecPool {
    /// Build a private pool with `threads` execution lanes
    /// (`threads − 1` dedicated workers; the submitter is the last lane).
    //
    // expect is confined to worker-thread spawning: the pool is built at
    // process/cluster startup, where failing to spawn is unrecoverable.
    #[allow(clippy::expect_used)]
    pub fn new(threads: usize) -> Arc<ExecPool> {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            queues: (0..threads).map(|_| Mutex::new(VecDeque::new())).collect(),
            state: Mutex::new(PoolState {
                pending: 0,
                shutdown: false,
            }),
            available: Condvar::new(),
        });
        let workers = (0..threads.saturating_sub(1))
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("spin-exec-{i}"))
                    .spawn(move || worker_loop(&shared, i))
                    .expect("spawn exec worker")
            })
            .collect();
        Arc::new(ExecPool {
            shared,
            threads,
            workers: Mutex::new(workers),
        })
    }

    /// The process-wide pool for `threads` lanes. Clusters configured with
    /// the same `exec_threads` share one pool (and its worker threads);
    /// the pool is dropped when the last cluster using it goes away.
    pub fn shared(threads: usize) -> Arc<ExecPool> {
        static REGISTRY: OnceLock<Mutex<BTreeMap<usize, Weak<ExecPool>>>> = OnceLock::new();
        let registry = REGISTRY.get_or_init(|| Mutex::new(BTreeMap::new()));
        let mut reg = plock(registry);
        if let Some(pool) = reg.get(&threads).and_then(Weak::upgrade) {
            return pool;
        }
        let pool = ExecPool::new(threads);
        reg.insert(threads, Arc::downgrade(&pool));
        pool
    }

    /// Stage-level concurrency (worker threads + the submitting thread).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run one stage: one task per element of `tasks`, outputs and
    /// per-task measured seconds in submission order. Blocks until every
    /// task has finished; if any task panicked, the first payload is
    /// re-thrown here (on the submitting thread) after the rest complete.
    //
    // expect is invariant-backed: the latch releases only after every
    // task wrote its slot (or recorded a panic, which re-raises before
    // the slots are read).
    #[allow(clippy::expect_used)]
    pub fn run_stage<T: Send, U: Send>(
        &self,
        tasks: Vec<T>,
        f: impl Fn(T) -> U + Sync,
    ) -> StageRun<U> {
        let n = tasks.len();
        if n == 0 {
            return StageRun {
                outputs: Vec::new(),
                durations: Vec::new(),
                stats: StageExecStats::default(),
            };
        }
        let stage_start = Instant::now();
        let scope = Metrics::current_scope();
        let slots: Vec<Mutex<Option<TaskResult<U>>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let latch = Latch::new(n);
        let first_panic: Mutex<Option<Box<dyn Any + Send>>> = Mutex::new(None);
        let f = &f;
        let latch_ref = &latch;
        let panic_ref = &first_panic;
        for (i, task) in tasks.into_iter().enumerate() {
            let slot = &slots[i];
            let enqueued = Instant::now();
            let job: Box<dyn FnOnce(&TaskCtx) + Send + '_> = Box::new(move |ctx| {
                let queue_ns = enqueued.elapsed().as_nanos() as u64;
                // Workers record into the submitting job's metric scope.
                let _scope = Metrics::enter_scope(scope);
                let run_start = Instant::now();
                let out = panic::catch_unwind(AssertUnwindSafe(|| f(task)));
                let run = run_start.elapsed();
                match out {
                    Ok(value) => {
                        *plock(slot) = Some(TaskResult {
                            value,
                            secs: run.as_secs_f64(),
                            queue_ns,
                            run_ns: run.as_nanos() as u64,
                            stolen: ctx.stolen,
                        });
                    }
                    Err(payload) => {
                        plock(panic_ref).get_or_insert(payload);
                    }
                }
                latch_ref.count_down();
            });
            // SAFETY: the task borrows `slots`/`latch`/`first_panic`/`f`
            // from this frame. `run_stage` blocks on the latch before
            // returning, and `LatchGuard` blocks on it even during an
            // unwind, so every borrow strictly outlives every task.
            #[allow(clippy::useless_transmute)] // lifetime-only erasure, not a no-op
            let job: Runnable = unsafe {
                std::mem::transmute::<Box<dyn FnOnce(&TaskCtx) + Send + '_>, Runnable>(job)
            };
            plock(&self.shared.queues[i % self.shared.queues.len()]).push_back(job);
            plock(&self.shared.state).pending += 1;
            self.shared.available.notify_one();
        }
        let _guard = LatchGuard(&latch);
        // The submitting thread is a pool lane too: help drain (any
        // stage's) tasks until this stage's latch opens.
        while !latch.is_done() {
            if self.shared.try_claim() {
                let (task, stolen) = self.shared.take(0);
                task(&TaskCtx { stolen });
            } else {
                latch.wait_done();
            }
        }
        latch.wait_done();
        if let Some(payload) = plock(&first_panic).take() {
            panic::resume_unwind(payload);
        }
        let mut outputs = Vec::with_capacity(n);
        let mut durations = Vec::with_capacity(n);
        let mut stats = StageExecStats {
            tasks: n,
            ..StageExecStats::default()
        };
        for slot in &slots {
            let r = plock(slot)
                .take()
                .expect("exec task finished without result or panic");
            durations.push(r.secs);
            stats.queue_ns += r.queue_ns;
            stats.run_ns += r.run_ns;
            if r.stolen {
                stats.steals += 1;
            }
            outputs.push(r.value);
        }
        stats.wall_ns = stage_start.elapsed().as_nanos().min(u64::MAX as u128) as u64;
        StageRun {
            outputs,
            durations,
            stats,
        }
    }

    /// Run a wave of real sleeps in parallel — fault injection's
    /// `straggle` under the pool. Each entry is extra seconds for one
    /// task (zeros are free); capped at 2 s apiece so a pathological
    /// fault stream cannot wedge a stage. Returns the wave's wall time
    /// in nanoseconds.
    pub fn sleep_parallel(&self, extra_secs: &[f64]) -> u64 {
        if extra_secs.iter().all(|&s| s <= 0.0) {
            return 0;
        }
        let run = self.run_stage(extra_secs.to_vec(), |s| {
            if s > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(s.min(2.0)));
            }
        });
        run.stats.wall_ns
    }
}

impl Drop for ExecPool {
    fn drop(&mut self) {
        plock(&self.shared.state).shutdown = true;
        self.shared.available.notify_all();
        for handle in plock(&self.workers).drain(..) {
            let _ = handle.join();
        }
    }
}

fn worker_loop(shared: &Shared, me: usize) {
    loop {
        {
            let mut st = plock(&shared.state);
            loop {
                if st.pending > 0 {
                    st.pending -= 1;
                    break;
                }
                if st.shutdown {
                    return;
                }
                st = pwait(&shared.available, st);
            }
        }
        let (task, stolen) = shared.take(me);
        task(&TaskCtx { stolen });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_stage_preserves_submission_order() {
        let pool = ExecPool::new(4);
        let run = pool.run_stage((0..100u64).collect(), |i| i * i);
        assert_eq!(run.outputs, (0..100u64).map(|i| i * i).collect::<Vec<_>>());
        assert_eq!(run.durations.len(), 100);
        assert_eq!(run.stats.tasks, 100);
        assert!(run.stats.wall_ns > 0);
        assert!(run.stats.run_ns > 0);
    }

    #[test]
    fn single_lane_pool_runs_inline() {
        let pool = ExecPool::new(1);
        let run = pool.run_stage(vec![1, 2, 3], |i| i + 10);
        assert_eq!(run.outputs, vec![11, 12, 13]);
        assert_eq!(run.stats.steals, 0);
    }

    #[test]
    fn empty_stage_is_fine() {
        let pool = ExecPool::new(3);
        let run = pool.run_stage(Vec::<u32>::new(), |i| i);
        assert!(run.outputs.is_empty());
        assert_eq!(run.stats, StageExecStats::default());
    }

    #[test]
    fn panicking_task_fails_stage_not_pool() {
        let pool = ExecPool::new(3);
        let caught = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run_stage(vec![0, 1, 2, 3], |i| {
                if i == 2 {
                    panic!("partition 2 exploded");
                }
                i
            })
        }));
        let msg = caught.unwrap_err();
        let msg = msg
            .downcast_ref::<&str>()
            .map(|s| s.to_string())
            .or_else(|| msg.downcast_ref::<String>().cloned())
            .unwrap_or_default();
        assert!(msg.contains("partition 2 exploded"), "{msg}");
        // Workers survived; the pool keeps serving.
        let run = pool.run_stage(vec![5, 6], |i| i * 2);
        assert_eq!(run.outputs, vec![10, 12]);
    }

    #[test]
    fn workers_inherit_submitting_scope() {
        let pool = ExecPool::new(4);
        let _scope = Metrics::enter_scope(42);
        let run = pool.run_stage(vec![(); 32], |()| Metrics::current_scope());
        assert!(run.outputs.iter().all(|&s| s == 42), "{:?}", run.outputs);
    }

    /// Regression for the job-scope propagation bug: two jobs submitting
    /// concurrently to ONE shared pool must each see their own scope on
    /// every task, even when workers interleave tasks from both.
    #[test]
    fn overlapping_scopes_on_shared_pool_stay_separate() {
        let pool = ExecPool::new(4);
        std::thread::scope(|s| {
            let submit = |scope: u64| {
                let pool = &pool;
                move || {
                    let _guard = Metrics::enter_scope(scope);
                    for _ in 0..8 {
                        let run = pool.run_stage(vec![(); 16], |()| Metrics::current_scope());
                        assert!(
                            run.outputs.iter().all(|&got| got == scope),
                            "scope {scope} leaked: {:?}",
                            run.outputs
                        );
                    }
                }
            };
            let a = s.spawn(submit(11));
            let b = s.spawn(submit(22));
            a.join().unwrap();
            b.join().unwrap();
        });
    }

    #[test]
    fn shared_registry_returns_same_pool_per_thread_count() {
        let a = ExecPool::shared(5);
        let b = ExecPool::shared(5);
        let c = ExecPool::shared(6);
        assert!(Arc::ptr_eq(&a, &b));
        assert!(!Arc::ptr_eq(&a, &c));
        assert_eq!(a.threads(), 5);
        assert_eq!(c.threads(), 6);
    }

    #[test]
    fn sleep_parallel_overlaps_sleeps() {
        let pool = ExecPool::new(4);
        let start = Instant::now();
        let wall_ns = pool.sleep_parallel(&[0.02, 0.02, 0.02, 0.02]);
        let elapsed = start.elapsed().as_secs_f64();
        assert!(wall_ns > 0);
        // Four 20 ms sleeps on four lanes: well under the 80 ms serial sum.
        assert!(elapsed < 0.075, "sleep wave took {elapsed}s");
        assert_eq!(pool.sleep_parallel(&[0.0, 0.0]), 0);
    }

    #[test]
    fn stealing_happens_under_imbalanced_queues() {
        // Many more tasks than lanes: round-robin spreads them over every
        // deque, and whichever lane drains first steals from the rest.
        let pool = ExecPool::new(4);
        let run = pool.run_stage(vec![2u64; 256], |ms| {
            std::thread::sleep(std::time::Duration::from_micros(200));
            ms
        });
        assert_eq!(run.outputs.len(), 256);
        // Steals are timing-dependent; just require the counter is sane.
        assert!(run.stats.steals <= 256);
    }
}
