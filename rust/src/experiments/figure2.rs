//! Figure 2 — "Fastest running time of LU and Strassen's based inversion
//! among different block sizes": for each matrix size, sweep the split
//! count b for both algorithms and report each algorithm's best time.

use crate::config::{ClusterConfig, JobConfig};
use crate::error::Result;
use crate::experiments::{report, run_inversion, split_sweep, Scale};
use crate::util::fmt::{self, Table};

/// One row of the figure: per-n fastest times and the winning b.
#[derive(Debug, Clone)]
pub struct Figure2Row {
    pub n: usize,
    pub spin_best_secs: f64,
    pub spin_best_b: usize,
    pub lu_best_secs: f64,
    pub lu_best_b: usize,
}

/// Run the sweep. Returns rows ordered by n.
pub fn run(cluster: &ClusterConfig, scale: &Scale, seed: u64) -> Result<Vec<Figure2Row>> {
    let mut rows = Vec::new();
    for &n in &scale.sizes {
        let mut best: [(f64, usize); 2] = [(f64::INFINITY, 0); 2];
        for b in split_sweep(n, scale.max_b) {
            let mut job = JobConfig::new(n, n / b);
            job.seed = seed ^ n as u64;
            for (slot, algo) in ["spin", "lu"].into_iter().enumerate() {
                let r = run_inversion(cluster, &job, algo)?;
                log::info!(
                    "figure2 n={n} b={b} {algo}: {:.3}s (virtual)",
                    r.virtual_secs
                );
                if r.virtual_secs < best[slot].0 {
                    best[slot] = (r.virtual_secs, b);
                }
            }
        }
        rows.push(Figure2Row {
            n,
            spin_best_secs: best[0].0,
            spin_best_b: best[0].1,
            lu_best_secs: best[1].0,
            lu_best_b: best[1].1,
        });
    }
    Ok(rows)
}

/// Render the figure as a table + chart, write `figure2.csv`.
pub fn render(rows: &[Figure2Row]) -> Result<String> {
    let mut t = Table::new(vec![
        "n",
        "SPIN best",
        "SPIN b*",
        "LU best",
        "LU b*",
        "speedup",
    ]);
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            fmt::secs(r.spin_best_secs),
            r.spin_best_b.to_string(),
            fmt::secs(r.lu_best_secs),
            r.lu_best_b.to_string(),
            format!("{:.2}x", r.lu_best_secs / r.spin_best_secs),
        ]);
    }
    let mut csv = Table::new(vec!["n", "spin_secs", "spin_b", "lu_secs", "lu_b"]);
    for r in rows {
        csv.row(vec![
            r.n.to_string(),
            format!("{}", r.spin_best_secs),
            r.spin_best_b.to_string(),
            format!("{}", r.lu_best_secs),
            r.lu_best_b.to_string(),
        ]);
    }
    let path = report::write_csv("figure2", &csv)?;
    let xs: Vec<String> = rows.iter().map(|r| r.n.to_string()).collect();
    let chart = report::ascii_chart(
        "Figure 2: fastest wall time vs matrix size",
        &xs,
        &[
            ("SPIN", rows.iter().map(|r| r.spin_best_secs).collect()),
            ("LU", rows.iter().map(|r| r.lu_best_secs).collect()),
        ],
    );
    Ok(format!(
        "{}\n{chart}\ncsv: {}\n",
        t.render(),
        path.display()
    ))
}

/// Paper-shape checks used by tests and asserted in EXPERIMENTS.md:
/// SPIN ≤ LU everywhere and (with `require_growth`, meaningful only at
/// non-smoke scales where timing noise is small) the gap grows with n.
pub fn check_shape_opts(
    rows: &[Figure2Row],
    require_growth: bool,
) -> std::result::Result<(), String> {
    for r in rows {
        if r.spin_best_secs > r.lu_best_secs {
            return Err(format!(
                "n={}: SPIN {:.3}s slower than LU {:.3}s",
                r.n, r.spin_best_secs, r.lu_best_secs
            ));
        }
    }
    if !require_growth {
        return Ok(());
    }
    for w in rows.windows(2) {
        let g0 = w[0].lu_best_secs - w[0].spin_best_secs;
        let g1 = w[1].lu_best_secs - w[1].spin_best_secs;
        if g1 < g0 * 0.8 {
            return Err(format!(
                "gap shrank: n={} gap {:.3}s -> n={} gap {:.3}s",
                w[0].n, g0, w[1].n, g1
            ));
        }
    }
    Ok(())
}

/// Full-strictness shape check (bench scales).
pub fn check_shape(rows: &[Figure2Row]) -> std::result::Result<(), String> {
    check_shape_opts(rows, true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_scale_runs_and_holds_shape() {
        let cluster = ClusterConfig::paper();
        let scale = Scale::smoke();
        let rows = run(&cluster, &scale, 7).unwrap();
        assert_eq!(rows.len(), 2);
        for r in &rows {
            assert!(r.spin_best_secs.is_finite() && r.spin_best_secs > 0.0);
            assert!(r.spin_best_b >= 2);
        }
        // Headline: SPIN at least matches LU at smoke scale (gap growth is
        // only asserted at bench scales where timing noise is negligible).
        check_shape_opts(&rows, false).unwrap();
        std::env::set_var(
            "SPIN_RESULTS_DIR",
            std::env::temp_dir().join("spin_fig2_test"),
        );
        let out = render(&rows).unwrap();
        assert!(out.contains("SPIN best"));
        std::env::remove_var("SPIN_RESULTS_DIR");
    }
}
