//! Figure 3 — "Comparing running time of LU and SPIN … for increasing
//! partition size": the U-shaped wall-clock curve over split count b, per
//! matrix size, for both algorithms.

use crate::config::{ClusterConfig, JobConfig};
use crate::error::Result;
use crate::experiments::{report, run_inversion, split_sweep, Scale};
use crate::util::fmt::{self, Table};

/// One (n, b) sample for both algorithms.
#[derive(Debug, Clone)]
pub struct Figure3Row {
    pub n: usize,
    pub b: usize,
    pub spin_secs: f64,
    pub lu_secs: f64,
}

pub fn run(cluster: &ClusterConfig, scale: &Scale, seed: u64) -> Result<Vec<Figure3Row>> {
    let mut rows = Vec::new();
    for &n in &scale.sizes {
        // Paper §5.3: "we increase the partition size until we get an
        // intuitive change in the results" — sweep to max_b, then keep
        // doubling while SPIN's time is still falling (so every panel
        // exposes its rising arm), down to 16×16 blocks.
        let mut swept = split_sweep(n, scale.max_b);
        let mut i = 0;
        while i < swept.len() {
            let b = swept[i];
            let mut job = JobConfig::new(n, n / b);
            job.seed = seed ^ (n as u64) << 8 ^ b as u64;
            let spin = run_inversion(cluster, &job, "spin")?;
            let lu = run_inversion(cluster, &job, "lu")?;
            log::info!(
                "figure3 n={n} b={b}: spin {:.3}s lu {:.3}s",
                spin.virtual_secs,
                lu.virtual_secs
            );
            rows.push(Figure3Row {
                n,
                b,
                spin_secs: spin.virtual_secs,
                lu_secs: lu.virtual_secs,
            });
            let panel: Vec<&Figure3Row> = rows.iter().filter(|r| r.n == n).collect();
            let still_falling = match panel.len() {
                0 | 1 => true,
                l => panel[l - 1].spin_secs < panel[l - 2].spin_secs * 0.97,
            };
            if i == swept.len() - 1 && still_falling && n / (b * 2) >= 16 {
                swept.push(b * 2);
            }
            i += 1;
        }
    }
    Ok(rows)
}

pub fn render(rows: &[Figure3Row]) -> Result<String> {
    let mut t = Table::new(vec!["n", "b", "SPIN", "LU", "LU/SPIN"]);
    let mut csv = Table::new(vec!["n", "b", "spin_secs", "lu_secs"]);
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            r.b.to_string(),
            fmt::secs(r.spin_secs),
            fmt::secs(r.lu_secs),
            format!("{:.2}x", r.lu_secs / r.spin_secs),
        ]);
        csv.row(vec![
            r.n.to_string(),
            r.b.to_string(),
            format!("{}", r.spin_secs),
            format!("{}", r.lu_secs),
        ]);
    }
    let path = report::write_csv("figure3", &csv)?;

    let mut out = t.render();
    // One chart per matrix size (the paper's three panels).
    let sizes: Vec<usize> = {
        let mut s: Vec<usize> = rows.iter().map(|r| r.n).collect();
        s.dedup();
        s
    };
    for n in sizes {
        let panel: Vec<&Figure3Row> = rows.iter().filter(|r| r.n == n).collect();
        let xs: Vec<String> = panel.iter().map(|r| r.b.to_string()).collect();
        out.push('\n');
        out.push_str(&report::ascii_chart(
            &format!("Figure 3 panel: n={n}, time vs partition count b"),
            &xs,
            &[
                ("SPIN", panel.iter().map(|r| r.spin_secs).collect()),
                ("LU", panel.iter().map(|r| r.lu_secs).collect()),
            ],
        ));
    }
    out.push_str(&format!("csv: {}\n", path.display()));
    Ok(out)
}

/// Shape checks: SPIN beats LU at every same-(n, b) point, and each panel
/// is U-ish (min not at the largest b once the sweep is wide enough).
pub fn check_shape(rows: &[Figure3Row], require_u: bool) -> std::result::Result<(), String> {
    for r in rows {
        if r.spin_secs > r.lu_secs {
            return Err(format!(
                "n={} b={}: SPIN {:.3}s > LU {:.3}s",
                r.n, r.b, r.spin_secs, r.lu_secs
            ));
        }
    }
    if require_u {
        let sizes: Vec<usize> = {
            let mut s: Vec<usize> = rows.iter().map(|r| r.n).collect();
            s.dedup();
            s
        };
        for n in sizes {
            let panel: Vec<&Figure3Row> = rows.iter().filter(|r| r.n == n).collect();
            if panel.len() < 3 {
                continue;
            }
            let times: Vec<f64> = panel.iter().map(|r| r.spin_secs).collect();
            let argmin = times
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| i)
                .unwrap_or(0);
            if argmin == times.len() - 1 {
                return Err(format!(
                    "n={n}: no rising arm — min at the largest b ({times:?})"
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_scale_spin_wins_at_best_b() {
        // Pointwise wins at every b are a release-scale claim (debug builds
        // distort the leaf/GEMM cost ratio at the smallest b); the paper's
        // headline — SPIN's best-over-b beats LU's best-over-b — must hold
        // even at smoke scale.
        let cluster = ClusterConfig::paper();
        let scale = Scale::smoke();
        let rows = run(&cluster, &scale, 13).unwrap();
        assert!(!rows.is_empty());
        let sizes: Vec<usize> = {
            let mut s: Vec<usize> = rows.iter().map(|r| r.n).collect();
            s.dedup();
            s
        };
        for n in sizes {
            let panel: Vec<&Figure3Row> = rows.iter().filter(|r| r.n == n).collect();
            let spin_best = panel.iter().map(|r| r.spin_secs).fold(f64::INFINITY, f64::min);
            let lu_best = panel.iter().map(|r| r.lu_secs).fold(f64::INFINITY, f64::min);
            assert!(
                spin_best <= lu_best * 1.05,
                "n={n}: SPIN best {spin_best:.3}s vs LU best {lu_best:.3}s"
            );
        }
    }
}
