//! Figure 4 — "Comparing theoretical and experimental running time of
//! SPIN": evaluate the calibrated Lemma 4.1 model on the same (n, b) grid
//! as the measurement and report both series.

use crate::config::{ClusterConfig, JobConfig};
use crate::costmodel::{calibrate, spin_cost, CostConstants};
use crate::error::Result;
use crate::experiments::{report, run_inversion, split_sweep, Scale};
use crate::util::fmt::{self, Table};

#[derive(Debug, Clone)]
pub struct Figure4Row {
    pub n: usize,
    pub b: usize,
    pub measured_secs: f64,
    pub model_secs: f64,
}

/// Calibrate the model once, then sweep.
pub fn run(
    cluster: &ClusterConfig,
    scale: &Scale,
    seed: u64,
) -> Result<(Vec<Figure4Row>, CostConstants)> {
    let cal = calibrate(128, &cluster.network);
    log::info!(
        "calibration: leaf {:.2} GF/s, gemm {:.2} GF/s",
        cal.leaf_gflops,
        cal.gemm_gflops
    );
    let cores = cluster.total_cores();
    let mut rows = Vec::new();
    for &n in &scale.sizes {
        for b in split_sweep(n, scale.max_b) {
            let mut job = JobConfig::new(n, n / b);
            job.seed = seed ^ (n as u64) << 4 ^ b as u64;
            let measured = run_inversion(cluster, &job, "spin")?;
            let model = spin_cost(n, b, cores, &cal.constants).total();
            log::info!(
                "figure4 n={n} b={b}: measured {:.3}s model {:.3}s",
                measured.virtual_secs,
                model
            );
            rows.push(Figure4Row {
                n,
                b,
                measured_secs: measured.virtual_secs,
                model_secs: model,
            });
        }
    }
    Ok((rows, cal.constants))
}

pub fn render(rows: &[Figure4Row]) -> Result<String> {
    let mut t = Table::new(vec!["n", "b", "measured", "model", "model/measured"]);
    let mut csv = Table::new(vec!["n", "b", "measured_secs", "model_secs"]);
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            r.b.to_string(),
            fmt::secs(r.measured_secs),
            fmt::secs(r.model_secs),
            format!("{:.2}", r.model_secs / r.measured_secs),
        ]);
        csv.row(vec![
            r.n.to_string(),
            r.b.to_string(),
            format!("{}", r.measured_secs),
            format!("{}", r.model_secs),
        ]);
    }
    let path = report::write_csv("figure4", &csv)?;
    let mut out = t.render();
    let sizes: Vec<usize> = {
        let mut s: Vec<usize> = rows.iter().map(|r| r.n).collect();
        s.dedup();
        s
    };
    for n in sizes {
        let panel: Vec<&Figure4Row> = rows.iter().filter(|r| r.n == n).collect();
        let xs: Vec<String> = panel.iter().map(|r| r.b.to_string()).collect();
        out.push('\n');
        out.push_str(&report::ascii_chart(
            &format!("Figure 4 panel: n={n}, theory vs measurement"),
            &xs,
            &[
                ("measured", panel.iter().map(|r| r.measured_secs).collect()),
                ("model", panel.iter().map(|r| r.model_secs).collect()),
            ],
        ));
    }
    out.push_str(&format!("csv: {}\n", path.display()));
    Ok(out)
}

/// Shape check: per panel, model and measurement correlate (same ordering
/// tendency — Spearman-ish sign agreement) and agree within an order of
/// magnitude pointwise.
pub fn check_shape(rows: &[Figure4Row]) -> std::result::Result<(), String> {
    for r in rows {
        let ratio = r.model_secs / r.measured_secs;
        if !(0.1..=10.0).contains(&ratio) {
            return Err(format!(
                "n={} b={}: model/measured ratio {ratio:.2} outside [0.1, 10]",
                r.n, r.b
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_model_tracks_measurement() {
        let cluster = ClusterConfig::paper();
        let scale = Scale::smoke();
        let (rows, _k) = run(&cluster, &scale, 5).unwrap();
        assert!(!rows.is_empty());
        for r in &rows {
            assert!(r.model_secs.is_finite() && r.model_secs > 0.0);
        }
    }
}
