//! Figure 5 — "The scalability of SPIN, in comparison with ideal
//! scalability": wall time vs executor count, with the ideal `T(1)/k` line
//! overplotted.

use crate::cluster::{list_schedule_makespan, StageReport};
use crate::config::{ClusterConfig, JobConfig, NetworkConfig};
use crate::error::Result;
use crate::experiments::{report, run_inversion, Scale};
use crate::util::fmt::{self, Table};

#[derive(Debug, Clone)]
pub struct Figure5Row {
    pub n: usize,
    pub executors: usize,
    pub secs: f64,
    /// T(1) / executors.
    pub ideal_secs: f64,
}

/// Replay a measured stage log on a different topology: list-schedule the
/// recorded per-task durations onto `executors × cores` slots and re-price
/// the shuffle traffic for that executor count. Deterministic — the same
/// measured compute drives every point of the scaling curve (the paper
/// reruns instead, but its cluster timing is far less noisy than a
/// single-core host re-executing O(n³) twice per point).
pub fn replay_virtual_secs(
    stages: &[StageReport],
    executors: usize,
    cores_per_executor: usize,
    network: &NetworkConfig,
) -> f64 {
    let slots = (executors * cores_per_executor).max(1);
    let mut total = 0.0;
    let mut pending_shuffle = 0.0; // overlaps with the next compute stage
    for s in stages {
        // Of the bytes that changed partition, ≈ (k−1)/k land on a
        // different executor under round-robin placement.
        let moved = if executors <= 1 {
            0
        } else {
            s.shuffle_total_bytes * (executors as u64 - 1) / executors as u64
        };
        if moved > 0 {
            pending_shuffle += network.transfer_secs((moved / executors as u64).max(1));
        }
        if !s.task_durations.is_empty() {
            let compute = list_schedule_makespan(&s.task_durations, slots);
            total += compute.max(pending_shuffle);
            pending_shuffle = 0.0;
        }
    }
    total + pending_shuffle
}

/// Sweep executor counts for each matrix size (block size fixed at the
/// per-n sweet spot; paper keeps its resource plan fixed too). The job is
/// executed once per n; each executor count is a deterministic replay.
pub fn run(cluster: &ClusterConfig, scale: &Scale, seed: u64) -> Result<Vec<Figure5Row>> {
    let mut rows = Vec::new();
    for &n in &scale.fig5_sizes {
        // Scaling needs (a) compute-dominated stages — ≥256² blocks so one
        // block GEMM outweighs its transfer on the simulated fabric — and
        // (b) tasks ≫ slots (the recursion serializes stages, capping
        // speedup at ≈ b²/slots). Hence b grows with n at fixed 256²
        // blocks; small n cannot satisfy both, which is the paper's own
        // "minor deviation … when the size of the matrix is low".
        let b = (n / 256).clamp(2, scale.max_b);
        let mut job = JobConfig::new(n, n / b);
        job.seed = seed ^ n as u64;
        let measured = run_inversion(cluster, &job, "spin")?;
        let stages = measured.metrics.stages();
        let k0 = scale.executor_sweep[0];
        let t1 = replay_virtual_secs(stages, k0, cluster.cores_per_executor, &cluster.network)
            * k0 as f64;
        for &k in &scale.executor_sweep {
            let t = replay_virtual_secs(stages, k, cluster.cores_per_executor, &cluster.network);
            log::info!("figure5 n={n} executors={k}: {t:.3}s");
            rows.push(Figure5Row {
                n,
                executors: k,
                secs: t,
                ideal_secs: t1 / k as f64,
            });
        }
    }
    Ok(rows)
}

pub fn render(rows: &[Figure5Row]) -> Result<String> {
    let mut t = Table::new(vec!["n", "executors", "measured", "ideal", "efficiency"]);
    let mut csv = Table::new(vec!["n", "executors", "secs", "ideal_secs"]);
    for r in rows {
        t.row(vec![
            r.n.to_string(),
            r.executors.to_string(),
            fmt::secs(r.secs),
            fmt::secs(r.ideal_secs),
            format!("{:.0}%", 100.0 * r.ideal_secs / r.secs),
        ]);
        csv.row(vec![
            r.n.to_string(),
            r.executors.to_string(),
            format!("{}", r.secs),
            format!("{}", r.ideal_secs),
        ]);
    }
    let path = report::write_csv("figure5", &csv)?;
    let mut out = t.render();
    let sizes: Vec<usize> = {
        let mut s: Vec<usize> = rows.iter().map(|r| r.n).collect();
        s.dedup();
        s
    };
    for n in sizes {
        let panel: Vec<&Figure5Row> = rows.iter().filter(|r| r.n == n).collect();
        let xs: Vec<String> = panel.iter().map(|r| r.executors.to_string()).collect();
        out.push('\n');
        out.push_str(&report::ascii_chart(
            &format!("Figure 5 panel: n={n}, time vs executors"),
            &xs,
            &[
                ("SPIN", panel.iter().map(|r| r.secs).collect()),
                ("ideal", panel.iter().map(|r| r.ideal_secs).collect()),
            ],
        ));
    }
    out.push_str(&format!("csv: {}\n", path.display()));
    Ok(out)
}

/// Shape check: time decreases with executors; larger n tracks the ideal
/// line more closely (the paper's observation).
pub fn check_shape(rows: &[Figure5Row]) -> std::result::Result<(), String> {
    let sizes: Vec<usize> = {
        let mut s: Vec<usize> = rows.iter().map(|r| r.n).collect();
        s.dedup();
        s
    };
    for n in &sizes {
        let panel: Vec<&Figure5Row> = rows.iter().filter(|r| r.n == *n).collect();
        for w in panel.windows(2) {
            // Allow 5% relative or 5 ms absolute: tiny jobs pay fixed
            // shuffle latency per added executor (real Spark does too);
            // the paper's panels are all compute-dominated sizes.
            if w[1].secs > w[0].secs * 1.05 + 5e-3 {
                return Err(format!(
                    "n={n}: time rose {:.3}s -> {:.3}s at {} -> {} executors",
                    w[0].secs, w[1].secs, w[0].executors, w[1].executors
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_scaling_decreases() {
        let cluster = ClusterConfig::paper();
        let mut scale = Scale::smoke();
        scale.sizes = vec![256];
        let rows = run(&cluster, &scale, 3).unwrap();
        assert_eq!(rows.len(), scale.executor_sweep.len());
        check_shape(&rows).unwrap();
        // efficiency ≤ ~100%
        for r in &rows {
            assert!(r.secs + 1e-9 >= r.ideal_secs * 0.5, "superlinear? {r:?}");
        }
    }
}
