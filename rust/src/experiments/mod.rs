//! Experiment drivers — one module per table/figure in the paper's §5
//! evaluation, shared by the bench binaries (`rust/benches/`) and the CLI
//! (`spin exp …`).
//!
//! | module    | reproduces                                            |
//! |-----------|-------------------------------------------------------|
//! | `figure2` | fastest wall time over block sizes, SPIN vs LU, per n |
//! | `figure3` | wall time vs partition count b (the U-shape), per n   |
//! | `figure4` | theoretical (Lemma 4.1, calibrated) vs measured SPIN  |
//! | `figure5` | wall time vs executor count + ideal T(1)/k line       |
//! | `table3`  | per-method wall-clock breakdown over b                |
//!
//! All reported times are **virtual wall clock** from the simulated
//! cluster (see `cluster` module docs and DESIGN.md §3); every task's
//! compute is really executed and measured on this host.

pub mod figure2;
pub mod figure3;
pub mod figure4;
pub mod figure5;
pub mod report;
pub mod table3;

use crate::algos::AlgorithmRegistry;
use crate::blockmatrix::BlockMatrix;
use crate::cluster::{Cluster, MetricsSnapshot};
use crate::config::{ClusterConfig, JobConfig};
use crate::error::Result;
use crate::linalg::inverse_residual;
use crate::runtime::make_backend;
use crate::util::timer::time_it;

/// One measured inversion run.
#[derive(Debug, Clone)]
pub struct RunResult {
    /// Registry name of the algorithm that ran (`"spin"`, `"lu"`, …).
    pub algo: String,
    pub n: usize,
    pub b: usize,
    /// Simulated cluster wall clock (the paper's reported quantity).
    pub virtual_secs: f64,
    /// Real single-host seconds spent executing all tasks.
    pub real_secs: f64,
    /// Relative inversion residual ‖A·X−I‖∞/(‖A‖∞‖X‖∞n).
    pub residual: f64,
    pub metrics: MetricsSnapshot,
}

/// Execute one inversion job on a fresh simulated cluster. `algo` is a
/// registry name resolved against the built-in [`AlgorithmRegistry`].
pub fn run_inversion(
    cluster_cfg: &ClusterConfig,
    job: &JobConfig,
    algo: &str,
) -> Result<RunResult> {
    job.validate()?;
    let scheme = AlgorithmRegistry::with_defaults().get(algo)?;
    let cluster = Cluster::new(cluster_cfg.clone());
    let kernels = make_backend(cluster_cfg)?;
    let a = BlockMatrix::random(job)?;
    let a_dense = a.to_dense()?;

    cluster.reset();
    let (inv, real_secs) = time_it(|| scheme.invert(&cluster, kernels.as_ref(), &a, job));
    let inv = inv?;
    let virtual_secs = cluster.virtual_secs();
    let residual = inverse_residual(&a_dense, &inv.to_dense()?);
    Ok(RunResult {
        algo: algo.to_string(),
        n: job.n,
        b: job.num_splits(),
        virtual_secs,
        real_secs,
        residual,
        metrics: cluster.metrics(),
    })
}

/// Block sizes (powers of two) giving split counts `b ∈ [2, max_b]` for `n`.
pub fn split_sweep(n: usize, max_b: usize) -> Vec<usize> {
    let mut bs = Vec::new();
    let mut b = 2usize;
    while b <= max_b && n / b >= 2 {
        bs.push(b);
        b *= 2;
    }
    bs
}

/// Default experiment scales (kept laptop-sized; `full` upgrades toward the
/// paper's 16384² on capable hosts).
#[derive(Debug, Clone)]
pub struct Scale {
    pub sizes: Vec<usize>,
    pub max_b: usize,
    pub executor_sweep: Vec<usize>,
    /// Matrix sizes for the scalability experiment (Figure 5). Scaling on
    /// a 30-slot simulated cluster over a 14 Gb/s fabric requires the
    /// compute-dominated regime (≥256² blocks with enough of them), i.e.
    /// larger matrices than the U-shape sweeps need — exactly the paper's
    /// observation that small n deviates from ideal.
    pub fig5_sizes: Vec<usize>,
}

impl Scale {
    pub fn default_scale() -> Self {
        Scale {
            sizes: vec![512, 1024, 2048],
            // Sweep far enough to expose the U-shape's rising arm — after
            // the §Perf pass the per-block GEMM is fast enough that the
            // multiply/overhead term only overtakes the shrinking leaf
            // term beyond b = 16 at these sizes.
            max_b: 32,
            executor_sweep: vec![1, 2, 3, 4, 5, 6],
            fig5_sizes: vec![1024, 2048, 4096],
        }
    }

    pub fn smoke() -> Self {
        Scale {
            sizes: vec![128, 256],
            max_b: 8,
            executor_sweep: vec![1, 2, 4],
            fig5_sizes: vec![256],
        }
    }

    pub fn full() -> Self {
        Scale {
            sizes: vec![512, 1024, 2048, 4096],
            max_b: 32,
            executor_sweep: vec![1, 2, 3, 4, 5, 6],
            fig5_sizes: vec![2048, 4096, 8192],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_sweep_powers_of_two() {
        assert_eq!(split_sweep(256, 16), vec![2, 4, 8, 16]);
        // stops when blocks would drop below 2x2
        assert_eq!(split_sweep(16, 64), vec![2, 4, 8]);
    }

    #[test]
    fn run_inversion_smoke() {
        let cfg = ClusterConfig::local(4);
        let job = JobConfig::new(32, 8);
        let r = run_inversion(&cfg, &job, "spin").unwrap();
        assert_eq!(r.algo, "spin");
        assert!(r.residual < 1e-10, "residual {}", r.residual);
        assert!(r.virtual_secs > 0.0);
        assert!(r.real_secs > 0.0);
        assert_eq!(r.b, 4);
        assert!(r.metrics.method("multiply").is_some());
    }

    #[test]
    fn spin_and_lu_agree_in_harness() {
        let cfg = ClusterConfig::local(4);
        let job = JobConfig::new(32, 8);
        let s = run_inversion(&cfg, &job, "spin").unwrap();
        let l = run_inversion(&cfg, &job, "lu").unwrap();
        assert!(s.residual < 1e-9 && l.residual < 1e-9);
    }

    #[test]
    fn run_inversion_rejects_unknown_algorithm() {
        let cfg = ClusterConfig::local(2);
        let job = JobConfig::new(16, 4);
        let err = run_inversion(&cfg, &job, "qr").unwrap_err();
        assert!(err.to_string().contains("unknown algorithm"), "{err}");
    }
}
