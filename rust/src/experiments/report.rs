//! Report sink: ASCII tables + CSV files under `bench_results/`, plus a
//! small ASCII chart for eyeballing U-shapes and scaling lines.

use std::path::{Path, PathBuf};

use crate::error::Result;
use crate::util::fmt::Table;

/// Where experiment CSVs land (created on demand).
pub fn results_dir() -> PathBuf {
    std::env::var("SPIN_RESULTS_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("bench_results"))
}

/// Write a table to `<results_dir>/<name>.csv` and return its path.
pub fn write_csv(name: &str, table: &Table) -> Result<PathBuf> {
    let dir = results_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.csv"));
    std::fs::write(&path, table.to_csv())?;
    Ok(path)
}

/// Render one or more named series as a log-scale ASCII chart.
/// `xs` are shared x labels; each series is (name, ys).
pub fn ascii_chart(title: &str, xs: &[String], series: &[(&str, Vec<f64>)]) -> String {
    const ROWS: usize = 12;
    let all: Vec<f64> = series
        .iter()
        .flat_map(|(_, ys)| ys.iter().copied())
        .filter(|v| *v > 0.0)
        .collect();
    if all.is_empty() || xs.is_empty() {
        return format!("{title}\n(no data)\n");
    }
    let lo = all.iter().copied().fold(f64::INFINITY, f64::min).ln();
    let hi = all.iter().copied().fold(0.0f64, f64::max).ln();
    let span = (hi - lo).max(1e-9);
    let col_w = 8usize;
    let marks = ['*', 'o', '+', 'x', '#'];

    let mut grid = vec![vec![' '; xs.len() * col_w]; ROWS];
    for (si, (_, ys)) in series.iter().enumerate() {
        for (xi, &y) in ys.iter().enumerate() {
            if y <= 0.0 {
                continue;
            }
            let frac = (y.ln() - lo) / span;
            let row = ROWS - 1 - ((frac * (ROWS - 1) as f64).round() as usize).min(ROWS - 1);
            let col = xi * col_w + col_w / 2;
            grid[row][col] = marks[si % marks.len()];
        }
    }

    let mut out = format!("{title}  (log y)\n");
    for (ri, row) in grid.iter().enumerate() {
        let y_val = (hi - span * ri as f64 / (ROWS - 1) as f64).exp();
        out.push_str(&format!("{:>9.3} |", y_val));
        out.push_str(&row.iter().collect::<String>());
        out.push('\n');
    }
    out.push_str(&format!("{:>9} +", ""));
    out.push_str(&"-".repeat(xs.len() * col_w));
    out.push('\n');
    out.push_str(&format!("{:>10}", ""));
    for x in xs {
        out.push_str(&format!("{:^col_w$}", x));
    }
    out.push('\n');
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("    {} = {}\n", marks[si % marks.len()], name));
    }
    out
}

/// Convenience: make sure a parent directory exists for a path.
pub fn ensure_parent(path: &Path) -> Result<()> {
    if let Some(p) = path.parent() {
        std::fs::create_dir_all(p)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chart_renders_series() {
        let xs: Vec<String> = ["2", "4", "8"].iter().map(|s| s.to_string()).collect();
        let chart = ascii_chart(
            "U-shape",
            &xs,
            &[("spin", vec![4.0, 1.0, 3.0]), ("lu", vec![8.0, 2.5, 6.0])],
        );
        assert!(chart.contains("U-shape"));
        assert!(chart.contains("* = spin"));
        assert!(chart.contains("o = lu"));
        assert!(chart.lines().count() > 10);
    }

    #[test]
    fn chart_empty_data() {
        assert!(ascii_chart("t", &[], &[]).contains("no data"));
    }

    #[test]
    fn csv_roundtrip() {
        std::env::set_var("SPIN_RESULTS_DIR", std::env::temp_dir().join("spin_results_test"));
        let mut t = Table::new(vec!["a", "b"]);
        t.row(vec!["1", "2"]);
        let p = write_csv("unit_test", &t).unwrap();
        let s = std::fs::read_to_string(&p).unwrap();
        assert_eq!(s, "a,b\n1,2\n");
        std::env::remove_var("SPIN_RESULTS_DIR");
    }
}
