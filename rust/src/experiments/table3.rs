//! Table 3 — "Experimental results of wall clock execution time of
//! different methods in SPIN": per-method breakdown over split counts for
//! one matrix size (paper: n = 4096, b ∈ {2, 4, 8, 16}).

use crate::config::{ClusterConfig, JobConfig};
use crate::error::Result;
use crate::experiments::{report, run_inversion, split_sweep};
use crate::util::fmt::Table;

/// Paper row order.
pub const METHODS: [&str; 7] = [
    "leafNode",
    "breakMat",
    "xy",
    "multiply",
    "subtract",
    "scalar",
    "arrange",
];

#[derive(Debug, Clone)]
pub struct Table3Column {
    pub b: usize,
    /// Per-method virtual milliseconds, in [`METHODS`] order.
    pub method_ms: Vec<f64>,
    /// Per-method cross-executor shuffle bytes, in [`METHODS`] order —
    /// the partitioner-aware dataflow shows up here as zeros on every
    /// narrow method, with only multiply's pairing round paying bytes.
    pub method_shuffle_bytes: Vec<u64>,
    pub total_ms: f64,
    pub total_shuffle_bytes: u64,
    /// Stage count of the optimized plan pipeline (Schur fusion on).
    pub plan_stages: usize,
    /// Same job with `plan_optimizer = false`: the unfused
    /// multiply+subtract plan — the lazy-plan layer's before/after.
    pub unfused_total_ms: f64,
    pub unfused_stages: usize,
}

/// Run SPIN for each split count and collect the per-method breakdown —
/// once with the plan optimizer (the default pipeline) and once with it
/// disabled, so the report carries the optimized-vs-unfused comparison.
pub fn run(cluster: &ClusterConfig, n: usize, max_b: usize, seed: u64) -> Result<Vec<Table3Column>> {
    let mut unfused_cfg = cluster.clone();
    unfused_cfg.plan_optimizer = false;
    let mut cols = Vec::new();
    for b in split_sweep(n, max_b) {
        let mut job = JobConfig::new(n, n / b);
        job.seed = seed ^ b as u64;
        let r = run_inversion(cluster, &job, "spin")?;
        let r_unfused = run_inversion(&unfused_cfg, &job, "spin")?;
        let method_ms: Vec<f64> = METHODS
            .iter()
            .map(|m| {
                r.metrics
                    .method(m)
                    .map(|s| s.virtual_secs * 1e3)
                    .unwrap_or(0.0)
            })
            .collect();
        let method_shuffle_bytes: Vec<u64> = METHODS
            .iter()
            .map(|m| r.metrics.method(m).map(|s| s.shuffle_bytes).unwrap_or(0))
            .collect();
        let total_ms = r.virtual_secs * 1e3;
        let total_shuffle_bytes = r.metrics.total_shuffle_bytes();
        log::info!("table3 n={n} b={b}: total {total_ms:.1} ms, shuffled {total_shuffle_bytes} B");
        cols.push(Table3Column {
            b,
            method_ms,
            method_shuffle_bytes,
            total_ms,
            total_shuffle_bytes,
            plan_stages: r.metrics.stages().len(),
            unfused_total_ms: r_unfused.virtual_secs * 1e3,
            unfused_stages: r_unfused.metrics.stages().len(),
        });
    }
    Ok(cols)
}

pub fn render(n: usize, cols: &[Table3Column]) -> Result<String> {
    let mut header = vec!["Method".to_string()];
    header.extend(cols.iter().map(|c| format!("b = {}", c.b)));
    let mut t = Table::new(header.clone());
    for (mi, m) in METHODS.iter().enumerate() {
        let mut row = vec![m.to_string()];
        row.extend(cols.iter().map(|c| format!("{:.0}", c.method_ms[mi])));
        t.row(row);
    }
    let mut total = vec!["Total".to_string()];
    total.extend(cols.iter().map(|c| format!("{:.0}", c.total_ms)));
    t.row(total);
    let mut shuffled = vec!["ShuffledKB".to_string()];
    shuffled.extend(
        cols.iter()
            .map(|c| format!("{:.0}", c.total_shuffle_bytes as f64 / 1024.0)),
    );
    t.row(shuffled);
    // Optimized-vs-unfused plan comparison: same job with the plan
    // optimizer off (no Schur fusion, no CSE).
    let mut unfused = vec!["TotalUnfusedPlan".to_string()];
    unfused.extend(cols.iter().map(|c| format!("{:.0}", c.unfused_total_ms)));
    t.row(unfused);
    let mut stages = vec!["Stages opt/unfused".to_string()];
    stages.extend(
        cols.iter()
            .map(|c| format!("{}/{}", c.plan_stages, c.unfused_stages)),
    );
    t.row(stages);

    let mut csv = Table::new(header);
    for (mi, m) in METHODS.iter().enumerate() {
        let mut row = vec![m.to_string()];
        row.extend(cols.iter().map(|c| format!("{}", c.method_ms[mi])));
        csv.row(row);
    }
    for (mi, m) in METHODS.iter().enumerate() {
        let mut row = vec![format!("{m}_shuffle_bytes")];
        row.extend(cols.iter().map(|c| format!("{}", c.method_shuffle_bytes[mi])));
        csv.row(row);
    }
    let mut row = vec!["plan_stages".to_string()];
    row.extend(cols.iter().map(|c| c.plan_stages.to_string()));
    csv.row(row);
    let mut row = vec!["unfused_total_ms".to_string()];
    row.extend(cols.iter().map(|c| format!("{}", c.unfused_total_ms)));
    csv.row(row);
    let mut row = vec!["unfused_stages".to_string()];
    row.extend(cols.iter().map(|c| c.unfused_stages.to_string()));
    csv.row(row);
    let path = report::write_csv("table3", &csv)?;
    Ok(format!(
        "Table 3 analogue (n = {n}, virtual ms):\n{}\ncsv: {}\n",
        t.render(),
        path.display()
    ))
}

/// Shape checks from the paper's discussion of Table 3:
/// * leafNode decreases sharply with b (∝ n³/b²);
/// * multiply becomes ever more dominant relative to leafNode and rises
///   again at the tail of the sweep (its own U: serial products at tiny b,
///   replication/overhead at large b);
/// * the total is U-shaped.
pub fn check_shape(cols: &[Table3Column]) -> std::result::Result<(), String> {
    let leaf_i = 0;
    let mult_i = 3;
    for w in cols.windows(2) {
        let (a, b) = (&w[0], &w[1]);
        if b.method_ms[leaf_i] > a.method_ms[leaf_i] * 1.05 {
            return Err(format!(
                "leafNode rose with b: {:.0} -> {:.0} ms (b {} -> {})",
                a.method_ms[leaf_i], b.method_ms[leaf_i], a.b, b.b
            ));
        }
        // multiply / leafNode dominance must be non-decreasing.
        let ra = a.method_ms[mult_i] / a.method_ms[leaf_i].max(1e-9);
        let rb = b.method_ms[mult_i] / b.method_ms[leaf_i].max(1e-9);
        if rb < ra * 0.9 {
            return Err(format!(
                "multiply/leaf dominance fell with b: {ra:.1} -> {rb:.1} (b {} -> {})",
                a.b, b.b
            ));
        }
    }
    if let Some(last) = cols.last() {
        if last.method_ms[leaf_i] > last.total_ms * 0.5 {
            return Err("at the largest b, leafNode should no longer dominate".into());
        }
    }
    if cols.len() >= 4 {
        let totals: Vec<f64> = cols.iter().map(|c| c.total_ms).collect();
        let argmin = totals
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0);
        if argmin == 0 || argmin == totals.len() - 1 {
            return Err(format!("total not U-shaped: {totals:?}"));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoke_breakdown_has_all_methods() {
        let cluster = ClusterConfig::paper();
        let cols = run(&cluster, 256, 8, 11).unwrap();
        assert_eq!(cols.len(), 3); // b = 2, 4, 8
        for c in &cols {
            assert_eq!(c.method_ms.len(), METHODS.len());
            assert_eq!(c.method_shuffle_bytes.len(), METHODS.len());
            assert!(c.total_ms > 0.0);
            // The plan optimizer's fusion deletes stages per level, so the
            // unfused arm always runs strictly more stages.
            assert!(
                c.unfused_stages > c.plan_stages,
                "b={}: unfused {} stages vs optimized {}",
                c.b,
                c.unfused_stages,
                c.plan_stages
            );
            assert!(c.unfused_total_ms > 0.0);
            // Narrow methods shuffle nothing under the partitioner-aware
            // dataflow; only multiply pays an exchange.
            for (mi, m) in METHODS.iter().enumerate() {
                if *m != "multiply" {
                    assert_eq!(c.method_shuffle_bytes[mi], 0, "{m} shuffled");
                }
            }
        }
        // leafNode falls with b.
        assert!(cols[0].method_ms[0] > cols.last().unwrap().method_ms[0]);
    }
}
