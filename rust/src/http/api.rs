//! Request routing and JSON rendering for the job API.

use crate::error::SpinError;
use crate::ser::json::Json;
use crate::service::{JobHandle, JobSpec, JobStatus};

use super::wire::{Request, Response};
use super::{RecoveredJob, ServerState};

/// What the connection handler should do with a routed request.
pub(crate) enum Reply {
    Plain(Response),
    /// Upgrade to a server-sent-event stream for this job.
    EventStream { job_id: u64 },
}

pub(crate) fn route(state: &ServerState, request: &Request) -> Reply {
    let segments = request.segments();
    let method = request.method.as_str();
    let plain = |r: Response| Reply::Plain(r);
    match segments.as_slice() {
        ["v1", "healthz"] if method == "GET" => plain(Response::json(
            200,
            &Json::object(vec![
                ("ok", Json::Bool(true)),
                ("generation", Json::num(state.generation as f64)),
            ]),
        )),
        ["v1", "metrics"] if method == "GET" => plain(global_metrics(state)),
        ["v1", "jobs"] if method == "POST" => plain(submit(state, &request.body)),
        ["v1", "jobs", id] if method == "GET" => plain(with_id(id, |id| job_status(state, id))),
        ["v1", "jobs", id, "cancel"] if method == "POST" => {
            plain(with_id(id, |id| cancel(state, id)))
        }
        ["v1", "jobs", id, "explain"] if method == "GET" => {
            plain(with_id(id, |id| explain(state, id)))
        }
        ["v1", "jobs", id, "analysis"] if method == "GET" => {
            plain(with_id(id, |id| analysis(state, id)))
        }
        ["v1", "jobs", id, "metrics"] if method == "GET" => {
            plain(with_id(id, |id| job_metrics(state, id)))
        }
        ["v1", "jobs", id, "events"] if method == "GET" => match parse_id(id) {
            Some(job_id)
                if state.service.job(job_id).is_some()
                    || state.recovered.contains_key(&job_id) =>
            {
                Reply::EventStream { job_id }
            }
            Some(job_id) => plain(Response::error(404, &format!("unknown job {job_id}"))),
            None => plain(Response::error(400, &format!("bad job id `{id}`"))),
        },
        ["v1", "healthz" | "metrics" | "jobs", ..] => {
            plain(Response::error(405, &format!("{method} not allowed here")))
        }
        _ => plain(Response::error(404, &format!("no route for {}", request.path))),
    }
}

fn parse_id(raw: &str) -> Option<u64> {
    raw.parse::<u64>().ok().filter(|&id| id > 0)
}

fn with_id(raw: &str, f: impl FnOnce(u64) -> Response) -> Response {
    match parse_id(raw) {
        Some(id) => f(id),
        None => Response::error(400, &format!("bad job id `{raw}`")),
    }
}

/// Map a service error onto the closest HTTP status: a tenant over its
/// queue quota is 429 with a `Retry-After` hint (only that tenant must
/// back off), global saturation is retryable (503), an id conflict is
/// 409, anything else the client said wrong is 400.
fn error_response(e: &SpinError) -> Response {
    let msg = e.to_string();
    if msg.contains("queue quota") {
        return Response::error(429, &msg).header("Retry-After", "1");
    }
    let status = if msg.contains("queue is full") || msg.contains("shutting down") {
        503
    } else if msg.contains("different spec") {
        409
    } else {
        400
    };
    Response::error(status, &msg)
}

/// `POST /v1/jobs`: body is a strict [`JobSpec`] JSON object, plus an
/// optional top-level `"id"` for id-stable (idempotent) resubmits.
fn submit(state: &ServerState, body: &[u8]) -> Response {
    let Ok(text) = std::str::from_utf8(body) else {
        return Response::error(400, "body is not UTF-8");
    };
    let parsed = match Json::parse(text) {
        Ok(v) => v,
        Err(e) => return Response::error(400, &e.to_string()),
    };
    let (fixed_id, spec_json) = match parsed {
        Json::Object(mut map) => {
            let fixed_id = match map.remove("id") {
                None => None,
                Some(v) => match v.as_i64().and_then(|n| u64::try_from(n).ok()).filter(|&n| n > 0)
                {
                    Some(id) => Some(id),
                    None => return Response::error(400, "`id` must be a positive integer"),
                },
            };
            (fixed_id, Json::Object(map))
        }
        other => (None, other),
    };
    let spec = match JobSpec::from_json(&spec_json) {
        Ok(spec) => spec,
        Err(e) => return Response::error(400, &e.to_string()),
    };
    // A resubmit of a job that finished before the last restart is
    // answered from the log — same id, no second execution.
    if let Some(id) = fixed_id {
        if let Some(recovered) = state.recovered.get(&id) {
            if recovered.spec != spec {
                return Response::error(409, &format!("job {id} already exists with a different spec"));
            }
            return Response::json(200, &recovered_json(id, recovered));
        }
    }
    let result = match fixed_id {
        Some(id) => state.service.submit_with_id(id, spec),
        None => state.service.submit(spec),
    };
    match result {
        Ok(handle) => Response::json(
            202,
            &Json::object(vec![
                ("id", Json::num(handle.id() as f64)),
                ("status", Json::str(handle.status().name())),
            ]),
        ),
        Err(e) => error_response(&e),
    }
}

fn history_json(handle: &JobHandle) -> Json {
    Json::Array(
        handle
            .history()
            .iter()
            .map(|e| {
                Json::object(vec![
                    ("seq", Json::num(e.seq as f64)),
                    ("status", Json::str(e.status.name())),
                    ("ts_ms", Json::num(e.ts_ms as f64)),
                ])
            })
            .collect(),
    )
}

fn recovered_json(id: u64, job: &RecoveredJob) -> Json {
    let mut pairs = vec![
        ("id", Json::num(id as f64)),
        ("status", Json::str(job.terminal.status.name())),
        ("recovered", Json::Bool(true)),
        ("kind", Json::str(job.spec.kind.name())),
        ("tenant", Json::str(job.spec.tenant.clone())),
        ("label", Json::str(job.spec.label.clone())),
    ];
    if let Some(e) = &job.terminal.error {
        pairs.push(("error", Json::str(e.clone())));
    }
    if let Some(r) = job.terminal.residual {
        pairs.push(("residual", Json::Number(r)));
    }
    Json::object(pairs)
}

/// `GET /v1/jobs/:id`: live jobs report status/history/outcome summary;
/// jobs terminal before the last restart answer from the recovered log.
fn job_status(state: &ServerState, id: u64) -> Response {
    if let Some(handle) = state.service.job(id) {
        let spec = handle.spec();
        let mut pairs = vec![
            ("id", Json::num(id as f64)),
            ("status", Json::str(handle.status().name())),
            ("kind", Json::str(spec.kind.name())),
            ("tenant", Json::str(spec.tenant.clone())),
            ("label", Json::str(spec.label.clone())),
            (
                "submit_driver_blocks",
                Json::num(handle.submit_driver_blocks() as f64),
            ),
            ("history", history_json(&handle)),
        ];
        if let Some(algo) = &spec.algo {
            pairs.push(("algo", Json::str(algo.clone())));
        }
        if let Some(terminal) = handle.terminal() {
            if let Some(e) = terminal.error {
                pairs.push(("error", Json::str(e)));
            }
            if let Some(r) = terminal.residual {
                pairs.push(("residual", Json::Number(r)));
            }
        }
        return Response::json(200, &Json::object(pairs));
    }
    match state.recovered.get(&id) {
        Some(job) => Response::json(200, &recovered_json(id, job)),
        None => Response::error(404, &format!("unknown job {id}")),
    }
}

fn cancel(state: &ServerState, id: u64) -> Response {
    if let Some(handle) = state.service.job(id) {
        let cancelled = handle.cancel();
        return Response::json(
            200,
            &Json::object(vec![
                ("id", Json::num(id as f64)),
                ("cancelled", Json::Bool(cancelled)),
                ("status", Json::str(handle.status().name())),
            ]),
        );
    }
    match state.recovered.get(&id) {
        // Already terminal before the restart: nothing to cancel.
        Some(job) => Response::json(
            200,
            &Json::object(vec![
                ("id", Json::num(id as f64)),
                ("cancelled", Json::Bool(false)),
                ("status", Json::str(job.terminal.status.name())),
            ]),
        ),
        None => Response::error(404, &format!("unknown job {id}")),
    }
}

fn explain(state: &ServerState, id: u64) -> Response {
    let Some(handle) = state.service.job(id) else {
        return match state.recovered.get(&id) {
            Some(_) => Response::error(404, &format!("job {id} finished before the last restart; its plan is not retained")),
            None => Response::error(404, &format!("unknown job {id}")),
        };
    };
    match handle.explain() {
        Ok(text) => Response::json(
            200,
            &Json::object(vec![
                ("id", Json::num(id as f64)),
                ("explain", Json::str(text)),
            ]),
        ),
        Err(e) => Response::error(500, &e.to_string()),
    }
}

/// Static-verifier verdict for a job's plan, plus — once the job has
/// completed — its measured shuffle totals and whether they stayed within
/// the prediction. Measured may legitimately run *under* the prediction
/// (plan-cache sharing skips already-materialized subtrees; iterative
/// schemes may converge early), so divergence means `measured > predicted`.
fn analysis(state: &ServerState, id: u64) -> Response {
    let Some(handle) = state.service.job(id) else {
        return match state.recovered.get(&id) {
            Some(_) => Response::error(404, &format!("job {id} finished before the last restart; its plan is not retained")),
            None => Response::error(404, &format!("unknown job {id}")),
        };
    };
    match handle.analysis() {
        Ok(verdict) => {
            let mut fields = vec![
                ("id", Json::num(id as f64)),
                ("analysis", verdict.to_json()),
            ];
            if let Some(outcome) = handle.outcome() {
                let stages = outcome.metrics.total_shuffle_stages();
                let bytes = outcome.metrics.total_shuffle_bytes();
                let predicted = verdict.analysis.total;
                fields.push((
                    "measured",
                    Json::object(vec![
                        ("shuffle_stages", Json::num(stages as f64)),
                        ("shuffle_bytes", Json::num(bytes as f64)),
                        (
                            "driver_collects",
                            Json::num(outcome.metrics.driver_collects() as f64),
                        ),
                    ]),
                ));
                fields.push((
                    "within_prediction",
                    Json::Bool(
                        stages <= predicted.exchange_stages
                            && bytes <= predicted.shuffle_bytes_ceiling,
                    ),
                ));
            }
            Response::json(200, &Json::object(fields))
        }
        Err(e) => Response::error(500, &e.to_string()),
    }
}

/// `GET /v1/jobs/:id/metrics`: the completed outcome's snapshot when
/// terminal, the live scoped window while running.
fn job_metrics(state: &ServerState, id: u64) -> Response {
    let Some(handle) = state.service.job(id) else {
        return match state.recovered.get(&id) {
            Some(job) => Response::json(200, &recovered_json(id, job)),
            None => Response::error(404, &format!("unknown job {id}")),
        };
    };
    let snapshot = match handle.outcome() {
        Some(outcome) => outcome.metrics,
        None => handle.metrics(),
    };
    Response::json(
        200,
        &Json::object(vec![
            ("id", Json::num(id as f64)),
            ("status", Json::str(handle.status().name())),
            ("methods", snapshot.to_json()),
            (
                "total_shuffle_stages",
                Json::num(snapshot.total_shuffle_stages() as f64),
            ),
            (
                "total_shuffle_bytes",
                Json::num(snapshot.total_shuffle_bytes() as f64),
            ),
            (
                "driver_collects",
                Json::num(snapshot.driver_collects() as f64),
            ),
            ("resilience", resilience_json(snapshot.resilience())),
            ("convergence", convergence_json(&snapshot)),
        ]),
    )
}

/// Convergence counters + per-run residual trajectories as one JSON
/// object (per-job and service-wide). `reports` is empty when no
/// iterative scheme ran in the window.
fn convergence_json(snapshot: &crate::cluster::MetricsSnapshot) -> Json {
    let totals = snapshot.convergence_totals();
    Json::object(vec![
        ("runs", Json::num(totals.runs as f64)),
        ("iterations", Json::num(totals.iterations as f64)),
        ("converged_runs", Json::num(totals.converged_runs as f64)),
        (
            "reports",
            Json::Array(
                snapshot
                    .convergence()
                    .iter()
                    .map(|r| {
                        Json::object(vec![
                            ("algo", Json::str(r.algo.clone())),
                            ("iterations", Json::num(r.iterations as f64)),
                            ("converged", Json::Bool(r.converged)),
                            ("tolerance", Json::Number(r.tolerance)),
                            ("final_residual", Json::Number(r.final_residual)),
                            (
                                "residuals",
                                Json::Array(
                                    r.residuals.iter().map(|&v| Json::Number(v)).collect(),
                                ),
                            ),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Recovery counters as one JSON object (per-job and service-wide).
fn resilience_json(r: &crate::cluster::ResilienceTotals) -> Json {
    Json::object(vec![
        ("retries", Json::num(r.retries as f64)),
        ("retry_exhausted", Json::num(r.retry_exhausted as f64)),
        (
            "speculative_launched",
            Json::num(r.speculative_launched as f64),
        ),
        ("speculative_won", Json::num(r.speculative_won as f64)),
        (
            "checkpoints_written",
            Json::num(r.checkpoints_written as f64),
        ),
        (
            "checkpoints_restored",
            Json::num(r.checkpoints_restored as f64),
        ),
    ])
}

/// `GET /v1/metrics`: the service-wide snapshot — cluster metrics plus
/// plan-cache, value-lifecycle, retention and queue counters.
fn global_metrics(state: &ServerState) -> Response {
    let service = &state.service;
    let m = service.metrics();
    let plans = service.plan_cache_stats();
    let cache = service.cache_stats();
    Response::json(
        200,
        &Json::object(vec![
            ("methods", m.to_json()),
            ("total_shuffle_stages", Json::num(m.total_shuffle_stages() as f64)),
            ("total_shuffle_bytes", Json::num(m.total_shuffle_bytes() as f64)),
            ("driver_collects", Json::num(m.driver_collects() as f64)),
            (
                "retained_stage_records",
                Json::num(m.retained_stage_records() as f64),
            ),
            (
                "released_stage_records",
                Json::num(m.released_stage_records() as f64),
            ),
            ("released_scopes", Json::num(m.released_scopes() as f64)),
            (
                "plan_cache",
                Json::object(vec![
                    ("entries", Json::num(plans.entries as f64)),
                    ("hits", Json::num(plans.hits as f64)),
                    ("misses", Json::num(plans.misses as f64)),
                ]),
            ),
            (
                "cache",
                Json::object(vec![
                    ("resident_bytes", Json::num(cache.resident_bytes as f64)),
                    ("pinned_bytes", Json::num(cache.pinned_bytes as f64)),
                    ("entries", Json::num(cache.entries as f64)),
                    ("evictions", Json::num(cache.evictions as f64)),
                    ("evicted_bytes", Json::num(cache.evicted_bytes as f64)),
                ]),
            ),
            ("queued_jobs", Json::num(service.queued_jobs() as f64)),
            ("workers", Json::num(service.worker_count() as f64)),
            ("generation", Json::num(state.generation as f64)),
            ("resilience", resilience_json(m.resilience())),
            ("convergence", convergence_json(&m)),
            (
                "tenants",
                Json::Array(
                    service
                        .tenant_gauges()
                        .iter()
                        .map(|g| {
                            Json::object(vec![
                                ("tenant", Json::str(g.tenant.clone())),
                                ("queued", Json::num(g.queued as f64)),
                                ("running", Json::num(g.running as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]),
    )
}

/// Render one job event in the SSE `data:` JSON shape (shared with the
/// stream writer).
pub(crate) fn event_json(e: &crate::service::JobEvent) -> Json {
    Json::object(vec![
        ("job_id", Json::num(e.job_id as f64)),
        ("seq", Json::num(e.seq as f64)),
        ("status", Json::str(e.status.name())),
        ("ts_ms", Json::num(e.ts_ms as f64)),
    ])
}

/// Synthetic terminal event JSON for jobs recovered from the log (their
/// live event history did not survive the restart).
pub(crate) fn recovered_event_json(id: u64, status: JobStatus) -> Json {
    Json::object(vec![
        ("job_id", Json::num(id as f64)),
        ("status", Json::str(status.name())),
        ("recovered", Json::Bool(true)),
    ])
}
