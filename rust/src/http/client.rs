//! A minimal blocking HTTP client for the job API, used by the CLI
//! smoke path and the end-to-end tests (the build is offline, so the
//! test suite brings its own client).
//!
//! One request per connection, mirroring the server's
//! `Connection: close` model: connect, write, read to EOF, parse.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;

use crate::error::{Result, SpinError};
use crate::ser::json::Json;

/// Client for one server address (`host:port`).
#[derive(Debug, Clone)]
pub struct HttpClient {
    addr: String,
}

impl HttpClient {
    pub fn new(addr: impl Into<String>) -> Self {
        HttpClient { addr: addr.into() }
    }

    /// `GET path` → (status, parsed JSON body).
    pub fn get(&self, path: &str) -> Result<(u16, Json)> {
        self.request("GET", path, None)
    }

    /// `POST path` with an optional JSON body → (status, parsed body).
    pub fn post(&self, path: &str, body: Option<&Json>) -> Result<(u16, Json)> {
        self.request("POST", path, body)
    }

    fn request(&self, method: &str, path: &str, body: Option<&Json>) -> Result<(u16, Json)> {
        let mut stream = TcpStream::connect(&self.addr)?;
        let payload = body.map(|b| b.compact()).unwrap_or_default();
        write!(
            stream,
            "{method} {path} HTTP/1.1\r\nHost: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{payload}",
            self.addr,
            payload.len()
        )?;
        stream.flush()?;
        let mut raw = String::new();
        stream.take(16 << 20).read_to_string(&mut raw)?;
        Self::parse_response(&raw)
    }

    fn parse_response(raw: &str) -> Result<(u16, Json)> {
        let Some((head, body)) = raw.split_once("\r\n\r\n") else {
            return Err(SpinError::config(format!(
                "malformed HTTP response: {raw:?}"
            )));
        };
        let status_line = head.lines().next().unwrap_or("");
        let status = status_line
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse::<u16>().ok())
            .ok_or_else(|| {
                SpinError::config(format!("malformed HTTP status line: {status_line:?}"))
            })?;
        let json = if body.trim().is_empty() {
            Json::Null
        } else {
            Json::parse(body)?
        };
        Ok((status, json))
    }

    /// Open `path` as a server-sent-event stream and read it to the
    /// `end` event (or EOF), returning `(event_name, data)` pairs.
    /// Heartbeat comment lines are counted but not returned.
    pub fn follow_events(&self, path: &str) -> Result<Vec<(String, Json)>> {
        let mut stream = TcpStream::connect(&self.addr)?;
        write!(
            stream,
            "GET {path} HTTP/1.1\r\nHost: {}\r\nAccept: text/event-stream\r\nConnection: close\r\n\r\n",
            self.addr
        )?;
        stream.flush()?;
        let mut reader = BufReader::new(stream);
        // Status line + headers.
        let mut line = String::new();
        reader.read_line(&mut line)?;
        if !line.contains("200") {
            return Err(SpinError::config(format!(
                "event stream refused: {}",
                line.trim()
            )));
        }
        loop {
            let mut header = String::new();
            if reader.read_line(&mut header)? == 0 || header.trim_end().is_empty() {
                break;
            }
        }
        // Frames: `event:` + `data:` lines separated by blank lines.
        let mut events = Vec::new();
        let mut name = String::new();
        let mut data = String::new();
        loop {
            let mut line = String::new();
            if reader.read_line(&mut line)? == 0 {
                break; // EOF mid-stream (e.g. server shutdown)
            }
            let line = line.trim_end();
            if let Some(rest) = line.strip_prefix("event:") {
                name = rest.trim().to_string();
            } else if let Some(rest) = line.strip_prefix("data:") {
                data = rest.trim().to_string();
            } else if line.starts_with(':') {
                continue; // heartbeat comment
            } else if line.is_empty() && !name.is_empty() {
                let parsed = if data.is_empty() {
                    Json::Null
                } else {
                    Json::parse(&data)?
                };
                let done = name == "end";
                events.push((std::mem::take(&mut name), parsed));
                data.clear();
                if done {
                    break;
                }
            }
        }
        Ok(events)
    }
}
