//! HTTP front door for the job service: a minimal hand-rolled HTTP/1.1
//! server over `std::net` (the build is offline — no framework deps)
//! that exposes [`crate::service::SpinService`] to network clients.
//!
//! Endpoints (see `docs/HTTP_API.md` for curl examples):
//!
//! | Method + path                | Purpose                                 |
//! |------------------------------|-----------------------------------------|
//! | `POST /v1/jobs`              | submit a [`JobSpec`] JSON → job id      |
//! | `GET  /v1/jobs/:id`          | status + terminal outcome summary       |
//! | `POST /v1/jobs/:id/cancel`   | cancel a still-queued job               |
//! | `GET  /v1/jobs/:id/explain`  | optimized plan rendering                |
//! | `GET  /v1/jobs/:id/metrics`  | per-job metrics snapshot                |
//! | `GET  /v1/jobs/:id/events`   | phase transitions as server-sent events |
//! | `GET  /v1/metrics`           | service-wide metrics snapshot           |
//! | `GET  /v1/healthz`           | liveness probe                          |
//!
//! The server pairs with the durable job log
//! ([`crate::store::joblog`]): submits are fsynced before the id is
//! acknowledged, terminals before they are observable, and
//! `spin serve --http` replays the log at startup — still-pending jobs
//! re-enqueue under their original ids (resubmit is idempotent by id)
//! and already-terminal jobs are answered from the log without
//! re-execution.
//!
//! Connection model: one request per connection (`Connection: close`),
//! a detached thread per connection, and a nonblocking accept loop that
//! polls a shutdown flag — no event loop, no unsafe, no dependencies.
//! SSE connections stay open, streaming until the job's terminal event.

mod api;
pub mod client;
mod sse;
mod wire;

pub use client::HttpClient;
pub use wire::{Request, Response};

use std::collections::BTreeMap;
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::Duration;

use crate::config::HttpConfig;
use crate::error::Result;
use crate::service::{JobSpec, SpinService, TerminalSummary};

/// How often the accept loop re-checks the shutdown flag while idle.
const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Per-connection read timeout: a client that connects and goes silent
/// releases its thread instead of pinning it forever.
const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// A job that was already terminal in the job log at startup: served
/// from the log (status, idempotent resubmit, SSE terminal replay)
/// without re-execution.
#[derive(Debug, Clone)]
pub struct RecoveredJob {
    pub spec: JobSpec,
    pub terminal: TerminalSummary,
}

/// Everything a request handler can reach: the service, the wire
/// limits, and the jobs recovered terminal from the log at startup.
pub struct ServerState {
    pub service: SpinService,
    pub config: HttpConfig,
    /// Terminal jobs recovered from the job log, by id. Read-only after
    /// startup.
    pub recovered: BTreeMap<u64, RecoveredJob>,
    /// Job-log generation of this server start (0 = no durable log).
    pub generation: u64,
}

impl ServerState {
    pub fn new(service: SpinService, config: HttpConfig) -> Self {
        ServerState {
            service,
            config,
            recovered: BTreeMap::new(),
            generation: 0,
        }
    }
}

/// The listening server: an accept thread plus detached per-connection
/// handlers. Dropping it (or calling [`HttpServer::shutdown`]) stops
/// accepting; established SSE streams run to their terminal event.
pub struct HttpServer {
    addr: SocketAddr,
    state: Arc<ServerState>,
    stop: Arc<AtomicBool>,
    accept: Option<thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `state.config.listen` and start accepting. Port 0 binds an
    /// ephemeral port — read the real one from
    /// [`local_addr`](HttpServer::local_addr).
    pub fn bind(state: ServerState) -> Result<HttpServer> {
        state.config.validate()?;
        let listener = TcpListener::bind(&state.config.listen)?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let state = Arc::new(state);
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let state = Arc::clone(&state);
            let stop = Arc::clone(&stop);
            thread::Builder::new()
                .name("spin-http-accept".to_string())
                .spawn(move || accept_loop(listener, state, stop))?
        };
        Ok(HttpServer {
            addr,
            state,
            stop,
            accept: Some(accept),
        })
    }

    /// The actually-bound address (resolves an ephemeral port 0).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    pub fn state(&self) -> &Arc<ServerState> {
        &self.state
    }

    pub fn service(&self) -> &SpinService {
        &self.state.service
    }

    /// Stop accepting new connections and join the accept thread.
    /// Established connections (including SSE streams) finish on their
    /// own; pair with [`SpinService::wait_idle`] for a graceful drain.
    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(handle) = self.accept.take() {
            let _ = handle.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, state: Arc<ServerState>, stop: Arc<AtomicBool>) {
    loop {
        if stop.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                let state = Arc::clone(&state);
                let _ = thread::Builder::new()
                    .name("spin-http-conn".to_string())
                    .spawn(move || handle_connection(stream, state));
            }
            // Nonblocking accept: idle (or transient error) → poll the
            // shutdown flag at a human-invisible cadence.
            Err(_) => thread::sleep(ACCEPT_POLL),
        }
    }
}

fn handle_connection(stream: TcpStream, state: Arc<ServerState>) {
    let _ = stream.set_nonblocking(false);
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    let mut stream = stream;
    let request = match Request::read(&mut reader, state.config.max_body_bytes) {
        Ok(Some(request)) => request,
        Ok(None) => return, // clean close before a request
        Err(response) => {
            let _ = response.write(&mut stream);
            return;
        }
    };
    match api::route(&state, &request) {
        api::Reply::Plain(response) => {
            let _ = response.write(&mut stream);
        }
        api::Reply::EventStream { job_id } => {
            let _ = sse::stream_events(stream, &state, job_id);
        }
    }
}
