//! Server-sent-event streaming of job phase transitions.
//!
//! Wire shape per event:
//!
//! ```text
//! event: phase
//! data: {"job_id":7,"seq":12,"status":"running","ts_ms":1754600000000}
//! ```
//!
//! The stream replays the job's full history first (subscribe happens
//! *before* the snapshot so no transition can fall between them; the
//! writer dedups by `seq`), then follows live events until the terminal
//! transition, closing with an `event: end`. While idle it emits
//! `: heartbeat` comment lines every `sse_heartbeat_ms` so proxies and
//! clients can distinguish "still running" from "connection died".
//!
//! Dead sockets cannot pin server memory: a failed event or heartbeat
//! write ends the handler, and dropping its subscription deregisters
//! the listener immediately (see
//! [`crate::service::EventSubscription`]) — even when the job is
//! already terminal and no further event would ever flush it out.

use std::io::Write;
use std::net::TcpStream;
use std::sync::mpsc::RecvTimeoutError;
use std::sync::Arc;
use std::time::Duration;

use crate::service::JobEvent;

use super::api::{event_json, recovered_event_json};
use super::ServerState;

fn write_headers(stream: &mut TcpStream) -> std::io::Result<()> {
    write!(
        stream,
        "HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\nCache-Control: no-cache\r\nConnection: close\r\n\r\n"
    )?;
    stream.flush()
}

fn write_event(stream: &mut TcpStream, event: &JobEvent) -> std::io::Result<()> {
    writeln!(
        stream,
        "event: phase\ndata: {}\n",
        event_json(event).compact()
    )?;
    stream.flush()
}

fn write_end(stream: &mut TcpStream, job_id: u64) -> std::io::Result<()> {
    writeln!(stream, "event: end\ndata: {{\"job_id\":{job_id}}}\n")?;
    stream.flush()
}

pub(crate) fn stream_events(
    mut stream: TcpStream,
    state: &Arc<ServerState>,
    job_id: u64,
) -> std::io::Result<()> {
    // SSE streams may legitimately idle far longer than a request read;
    // the heartbeat keeps the connection visibly alive instead.
    let _ = stream.set_read_timeout(None);
    write_headers(&mut stream)?;

    // A job that was already terminal before the last restart has no
    // live event history: replay its terminal status from the log.
    if state.service.job(job_id).is_none() {
        if let Some(recovered) = state.recovered.get(&job_id) {
            writeln!(
                stream,
                "event: phase\ndata: {}\n",
                recovered_event_json(job_id, recovered.terminal.status).compact()
            )?;
            return write_end(&mut stream, job_id);
        }
        // Routed here but evicted since: close with an end event.
        return write_end(&mut stream, job_id);
    }

    let (history, rx) = state.service.subscribe(Some(job_id));
    let mut last_seq = 0u64;
    for event in &history {
        write_event(&mut stream, event)?;
        last_seq = event.seq;
        if event.status.is_terminal() {
            return write_end(&mut stream, job_id);
        }
    }
    let heartbeat = Duration::from_millis(state.config.sse_heartbeat_ms.max(1));
    loop {
        match rx.recv_timeout(heartbeat) {
            Ok(event) => {
                // The subscription was registered before the history
                // snapshot, so events already replayed above come
                // through again — drop them by sequence number.
                if event.seq <= last_seq {
                    continue;
                }
                write_event(&mut stream, &event)?;
                last_seq = event.seq;
                if event.status.is_terminal() {
                    return write_end(&mut stream, job_id);
                }
            }
            Err(RecvTimeoutError::Timeout) => {
                // The idle-time liveness probe doubles as dead-socket
                // detection: a client that vanished fails this write
                // within a heartbeat or two (RST after the first buffered
                // write), the `?` ends the handler, and the subscription
                // guard drops — freeing the subscriber slot.
                stream.write_all(b": heartbeat\n\n")?;
                stream.flush()?;
            }
            // Service dropped (shutdown): the stream cannot progress.
            Err(RecvTimeoutError::Disconnected) => return write_end(&mut stream, job_id),
        }
    }
}
