//! HTTP/1.1 wire format: request parsing and response writing, scoped
//! to exactly what the job API needs (no chunked bodies, no keep-alive
//! — every response carries `Connection: close`).

use std::io::{BufRead, Read, Write};

use crate::ser::json::Json;

/// One parsed request: method, path and the (possibly empty) body.
#[derive(Debug)]
pub struct Request {
    pub method: String,
    pub path: String,
    pub body: Vec<u8>,
}

impl Request {
    /// Read one request off the connection. `Ok(None)` = the peer closed
    /// before sending anything; `Err(response)` = a malformed or
    /// oversized request, with the error response to send back.
    pub fn read(
        reader: &mut impl BufRead,
        max_body: usize,
    ) -> std::result::Result<Option<Request>, Response> {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) => return Ok(None),
            Ok(_) => {}
            Err(_) => return Err(Response::error(400, "malformed request line")),
        }
        let mut parts = line.split_whitespace();
        let (Some(method), Some(path), Some(version)) =
            (parts.next(), parts.next(), parts.next())
        else {
            return Err(Response::error(400, "malformed request line"));
        };
        if !version.starts_with("HTTP/1.") {
            return Err(Response::error(505, "only HTTP/1.x is supported"));
        }
        let method = method.to_string();
        let path = path.to_string();
        let mut content_length = 0usize;
        loop {
            let mut header = String::new();
            match reader.read_line(&mut header) {
                Ok(0) => return Err(Response::error(400, "connection closed mid-headers")),
                Ok(_) => {}
                Err(_) => return Err(Response::error(400, "unreadable header")),
            }
            let header = header.trim_end();
            if header.is_empty() {
                break;
            }
            let Some((name, value)) = header.split_once(':') else {
                return Err(Response::error(400, "malformed header"));
            };
            if name.eq_ignore_ascii_case("content-length") {
                content_length = match value.trim().parse::<usize>() {
                    Ok(n) => n,
                    Err(_) => return Err(Response::error(400, "bad Content-Length")),
                };
            }
        }
        if content_length > max_body {
            return Err(Response::error(
                413,
                &format!("request body exceeds {max_body} bytes"),
            ));
        }
        let mut body = vec![0u8; content_length];
        if reader.read_exact(&mut body).is_err() {
            return Err(Response::error(400, "connection closed mid-body"));
        }
        Ok(Some(Request { method, path, body }))
    }

    /// Path split into non-empty segments: `/v1/jobs/7` → `["v1",
    /// "jobs", "7"]` (any query string is dropped).
    pub fn segments(&self) -> Vec<&str> {
        self.path
            .split('?')
            .next()
            .unwrap_or("")
            .split('/')
            .filter(|s| !s.is_empty())
            .collect()
    }
}

/// One response, always fully buffered (SSE bypasses this type and
/// writes its stream directly).
#[derive(Debug)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    /// Extra response headers (e.g. `Retry-After` on a 429).
    pub headers: Vec<(&'static str, String)>,
}

impl Response {
    pub fn json(status: u16, value: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: value.compact().into_bytes(),
            headers: Vec::new(),
        }
    }

    /// `{"error": msg}` with the given status.
    pub fn error(status: u16, msg: &str) -> Response {
        Response::json(status, &Json::object(vec![("error", Json::str(msg))]))
    }

    /// Attach an extra header (builder-style).
    pub fn header(mut self, name: &'static str, value: impl Into<String>) -> Response {
        self.headers.push((name, value.into()));
        self
    }

    pub fn reason(status: u16) -> &'static str {
        match status {
            200 => "OK",
            202 => "Accepted",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            413 => "Payload Too Large",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            505 => "HTTP Version Not Supported",
            _ => "Unknown",
        }
    }

    pub fn write(&self, w: &mut impl Write) -> std::io::Result<()> {
        write!(
            w,
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n",
            self.status,
            Response::reason(self.status),
            self.content_type,
            self.body.len()
        )?;
        for (name, value) in &self.headers {
            write!(w, "{name}: {value}\r\n")?;
        }
        write!(w, "\r\n")?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &str, max_body: usize) -> std::result::Result<Option<Request>, Response> {
        Request::read(&mut BufReader::new(raw.as_bytes()), max_body)
    }

    #[test]
    fn parses_request_with_body() {
        let raw = "POST /v1/jobs HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd";
        let req = parse(raw, 1024).unwrap().unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.segments(), vec!["v1", "jobs"]);
        assert_eq!(req.body, b"abcd");
    }

    #[test]
    fn parses_bodyless_get_and_query_strings() {
        let raw = "GET /v1/jobs/7/events?x=1 HTTP/1.0\r\n\r\n";
        let req = parse(raw, 1024).unwrap().unwrap();
        assert_eq!(req.segments(), vec!["v1", "jobs", "7", "events"]);
        assert!(req.body.is_empty());
    }

    #[test]
    fn rejects_oversized_and_malformed() {
        let raw = "POST / HTTP/1.1\r\nContent-Length: 999\r\n\r\n";
        assert_eq!(parse(raw, 10).unwrap_err().status, 413);
        assert_eq!(parse("garbage\r\n\r\n", 10).unwrap_err().status, 400);
        let raw = "GET / SPDY/3\r\n\r\n";
        assert_eq!(parse(raw, 10).unwrap_err().status, 505);
        // Truncated body.
        let raw = "POST / HTTP/1.1\r\nContent-Length: 8\r\n\r\nabc";
        assert_eq!(parse(raw, 10).unwrap_err().status, 400);
        // Clean EOF before any request.
        assert!(parse("", 10).unwrap().is_none());
    }

    #[test]
    fn response_renders_status_line_and_length() {
        let mut out = Vec::new();
        Response::error(404, "nope").write(&mut out).unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 404 Not Found\r\n"), "{text}");
        assert!(text.contains("Content-Length: 16"), "{text}");
        assert!(text.ends_with("{\"error\":\"nope\"}"), "{text}");
    }

    #[test]
    fn extra_headers_render_before_the_body() {
        let mut out = Vec::new();
        Response::error(429, "slow down")
            .header("Retry-After", "1")
            .write(&mut out)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"), "{text}");
        let (head, body) = text.split_once("\r\n\r\n").unwrap();
        assert!(head.contains("Retry-After: 1"), "{text}");
        assert_eq!(body, "{\"error\":\"slow down\"}");
    }
}
