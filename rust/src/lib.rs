//! # SPIN — Strassen-based distributed matrix inversion
//!
//! Reproduction of *SPIN: A Fast and Scalable Matrix Inversion Method in
//! Apache Spark* (Misra et al., ICDCN '18) as a three-layer Rust + JAX +
//! Pallas system.
//!
//! ## Public API: sessions and matrix handles
//!
//! The front door is [`session::SpinSession`]: a builder-configured context
//! that owns the simulated cluster, the block-kernel backend, and the job
//! defaults, and hands out [`session::DistMatrix`] handles with methods —
//! no more threading `Cluster` + `&dyn BlockKernels` + `JobConfig` through
//! free functions.
//!
//! ```no_run
//! use spin::session::SpinSession;
//!
//! fn main() -> spin::Result<()> {
//!     let session = SpinSession::builder().cores(4).build()?;
//!     let a = session.random_spd(256, 64)?;     // 4×4 grid of 64×64 blocks
//!     let inv = a.inverse()?;                   // SPIN recursion
//!     assert!(a.inverse_residual(&inv)? < 1e-10);
//!
//!     let b = session.random_seeded(256, 64, 7)?;
//!     let x = a.solve(&b)?;                     // X = A⁻¹·B
//!     let pinv = a.pseudo_inverse()?;           // (AᵀA)⁻¹·Aᵀ
//!     let lu = session.invert_with("lu", &a)?;  // any registered algorithm
//!     # let _ = (x, pinv, lu);
//!     Ok(())
//! }
//! ```
//!
//! Inversion schemes are open-ended: implement
//! [`algos::InversionAlgorithm`] and register it in the session builder (or
//! an [`algos::AlgorithmRegistry`]) under a new name — the CLI's `--algo`
//! flag and the experiment harness resolve through the same registry. The
//! old closed `algos::Algorithm` enum and the `spin_inverse` /
//! `lu_inverse_distributed` free functions remain as `#[deprecated]` shims.
//!
//! ## Layers
//!
//! * **Layer 3 (this crate)** — the coordinator: a Spark-like dataflow
//!   substrate ([`cluster`]), the distributed [`blockmatrix`] algebra, the
//!   SPIN recursion and its LU baseline behind the algorithm registry
//!   ([`algos`]), the session API ([`session`]), the paper's wall-clock
//!   cost model ([`costmodel`]) and every experiment in the evaluation
//!   section ([`experiments`]).
//! * **Layer 2/1 (build-time Python)** — block-level compute lowered once
//!   from JAX + Pallas to HLO text, loaded and executed from Rust through
//!   the PJRT CPU client ([`runtime`]).
//!
//! Python never runs on the request path: after `make artifacts` the `spin`
//! binary is self-contained.

pub mod algos;
pub mod blockmatrix;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod costmodel;
pub mod error;
pub mod experiments;
pub mod linalg;
pub mod runtime;
pub mod ser;
pub mod session;
pub mod util;

pub use config::{ClusterConfig, JobConfig};
pub use error::{Result, SpinError};
pub use session::{AlgorithmRegistry, DistMatrix, InversionAlgorithm, SessionBuilder, SpinSession};
