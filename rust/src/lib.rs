//! # SPIN — Strassen-based distributed matrix inversion
//!
//! Reproduction of *SPIN: A Fast and Scalable Matrix Inversion Method in
//! Apache Spark* (Misra et al., ICDCN '18) as a three-layer Rust + JAX +
//! Pallas system.
//!
//! ## Public API: an HTTP job server over the service layer
//!
//! The network front door is the [`http`] module: `spin serve --http
//! ADDR --store DIR` runs a dependency-free HTTP/1.1 server (hand-rolled
//! over `std::net` — the build is offline) exposing the job service.
//! Submit a JSON [`service::JobSpec`] to `POST /v1/jobs`, poll
//! `GET /v1/jobs/:id`, follow phase transitions live over server-sent
//! events at `GET /v1/jobs/:id/events`, and scrape `GET /v1/metrics`.
//! With `--store DIR` every submit and terminal outcome is fsynced to an
//! append-only job log before it becomes observable, and a restart
//! replays the log: jobs still pending resume under their original ids,
//! finished jobs answer from the log without re-execution, and resubmits
//! are idempotent by id. See `docs/HTTP_API.md` for the wire format.
//!
//! ```no_run
//! use spin::config::HttpConfig;
//! use spin::http::{HttpClient, HttpServer, ServerState};
//! use spin::service::SpinService;
//!
//! fn main() -> spin::Result<()> {
//!     // In production use `spin serve --http 127.0.0.1:8017 --store jobs/`;
//!     // embedding the server in-process works the same way:
//!     let service = SpinService::builder().cores(4).workers(2).build()?;
//!     let config = HttpConfig { listen: "127.0.0.1:0".into(), ..HttpConfig::default() };
//!     let server = HttpServer::bind(ServerState::new(service, config))?;
//!
//!     let client = HttpClient::new(server.local_addr().to_string());
//!     let spec = spin::ser::json::Json::parse(
//!         r#"{"kind":"invert","tenant":"alice","matrix":{"n":256,"block_size":64,"seed":7}}"#,
//!     )?;
//!     let (status, reply) = client.post("/v1/jobs", Some(&spec))?;
//!     assert_eq!(status, 202); // fsynced durable before the id is issued
//!     let id = reply.req("id")?.as_i64().unwrap();
//!     // Streams queued → running → done, then an `end` event.
//!     for (event, data) in client.follow_events(&format!("/v1/jobs/{id}/events"))? {
//!         println!("{event}: {}", data.compact());
//!     }
//!     Ok(())
//! }
//! ```
//!
//! Underneath sits [`service::SpinService`]: an async, multi-tenant job
//! layer. Callers `submit()` workloads described by a serializable
//! [`service::JobSpec`] (invert / solve / multiply / pseudo-inverse over
//! parameter-described matrices) and get a [`service::JobHandle`] back
//! immediately — poll `status()`, block on `wait()`, `cancel()` while
//! queued, read per-job `metrics()`, subscribe to phase events, or
//! `explain()` the optimized plan. A fair-share scheduler drains a
//! bounded queue round-robin across tenants onto worker threads, and a
//! **cross-job plan cache** interns structurally-equal plan subtrees so
//! concurrent jobs over the same data materialize shared work exactly
//! once.
//!
//! Inputs are **lazy**: `submit()` does O(1) matrix work. A
//! `MatrixSpec` — a generator family, or a block-store directory via
//! [`service::MatrixSpec::from_store`] (`spin ingest` writes one, see
//! [`store`]) — lowers to a lazy plan leaf whose blocks are produced
//! per-partition on the *workers* at first materialization,
//! bit-identical to eager generation of the same parameters. And the
//! service is built to run forever: a finished job's metric records are
//! released at its terminal phase (`--set metrics_history=N` windows the
//! rest), and a panicking job fails alone while the workers keep
//! serving.
//!
//! ```no_run
//! use spin::service::{JobSpec, MatrixSpec, SpinService};
//!
//! fn main() -> spin::Result<()> {
//!     // `--set exec_threads=N` (or SPIN_EXEC_THREADS) runs every stage's
//!     // partitions on the work-stealing pool in `spin::exec` — results
//!     // stay bit-identical to sequential execution (see docs/EXECUTOR.md).
//!     let service = SpinService::builder().cores(4).workers(2).build()?;
//!     // O(1): no block of the 256×256 input exists yet.
//!     let a = MatrixSpec::new(256, 64).seeded(7); // 4×4 grid of 64×64 blocks
//!     let inv = service.submit(JobSpec::invert(a.clone()).tenant("alice"))?;
//!     let rhs = MatrixSpec::new(256, 64).seeded(8);
//!     let sol = service.submit(JobSpec::solve(a, rhs).tenant("bob"))?;
//!     println!("{}", sol.explain()?);  // optimized plan + cache decisions
//!     // Both jobs need invert[spin](A): the plan cache interns one node,
//!     // so whichever worker arrives first pays and the other reuses.
//!     let inv_out = inv.wait()?;
//!     let sol_out = sol.wait()?;
//!     assert!(inv_out.residual.unwrap() < 1e-10);
//!     println!("solve exchanges: {}", sol_out.metrics.total_shuffle_stages());
//!     Ok(())
//! }
//! ```
//!
//! Underneath, [`session::SpinSession`] remains the single-caller API: a
//! builder-configured context owning the simulated cluster, the
//! block-kernel backend, and the job defaults, handing out **lazy**
//! [`session::DistMatrix`] handles whose operator methods build a
//! [`plan::MatExpr`] DAG. Distributed work runs only at materialization
//! points, after the rule-based optimizer has fused multiply+subtract,
//! pushed down transposes, folded scalars, and CSE'd shared subtrees.
//!
//! ## Value lifecycle: persist / unpersist / LRU
//!
//! Materialized plan-node values are memoized but no longer pinned
//! forever: the session's [`plan::CacheManager`] tracks every value, and
//! with `ClusterConfig::cache_budget_bytes` set (CLI:
//! `--set cache_budget_bytes=N`) an LRU evictor keeps the resident set
//! under budget — evicted values recompute bit-identically on the next
//! read (lazily-born source values simply regenerate on the workers).
//! `DistMatrix::persist()` pins a value against eviction — pinned bytes
//! are excluded from the budget (only the evictable set is bounded) and
//! surfaced in `MetricsSnapshot::pinned_bytes`; `unpersist()` releases
//! immediately. `explain()` shows the per-node cache decision
//! (`[cached]` / `[evictable]` / `[pinned]`) and predicted resident
//! bytes.
//!
//! Inversion schemes are open-ended: implement
//! [`algos::InversionAlgorithm`] and register it in the session builder (or
//! an [`algos::AlgorithmRegistry`]) under a new name — the CLI's `--algo`
//! flag and the experiment harness resolve through the same registry, and
//! a scheme can expose its per-level plan for `explain` via the trait's
//! `plan` hook. Four schemes ship built in: `spin` (default), `lu` (the
//! paper's baseline), `newton` (Newton–Schulz iterative inversion with
//! SLA early-stop — set `tolerance`/`max_iters` via the session builder,
//! `--set tolerance=1e-8` on the CLI, or top-level `JobSpec` fields; the
//! knobs are rejected for the exact schemes) and `cholesky`
//! (block-recursive for SPD inputs, strictly fewer exchange stages than
//! LU). Iterative runs report per-iteration residual trajectories
//! through the metrics layer (`ConvergenceReport`, `/v1/metrics`); see
//! `docs/ALGORITHMS.md`. New plan rewrites are added as optimizer
//! rules — see the rule contract in [`plan::optimizer`] — not as new
//! `BlockMatrix` methods; PR 2's hand-fused Schur step is now just the
//! fusion rule.
//!
//! ## Static plan verification
//!
//! The [`analysis`] module proves a plan's standing contracts **before
//! it runs**: geometry/partitioner propagation, rewrite and lifecycle
//! soundness, and the exact distributed cost — stage and collect counts
//! as equalities (iteration ceilings for `newton`), shuffle bytes as a
//! proved upper bound. `spin lint` sweeps the whole corpus from the CLI
//! (the CI `plan-lint` job gates on it), `spin explain --verify` checks
//! one plan, and `--set verify_plans=true` arms a per-node runtime
//! cross-check that fails any job diverging from the proof. In code:
//!
//! ```no_run
//! fn main() -> spin::Result<()> {
//!     let session = spin::SpinSession::local(4)?;
//!     // No matrix exists and nothing executes — the verdict is a
//!     // property of the optimized plan alone.
//!     let verdict = session.analyze_invert("spin", 256, 64)?;
//!     assert!(verdict.ok());
//!     // b = 4 grid: 6(b−1) = 18 multiply rounds, 2 exchanges each.
//!     assert_eq!(verdict.analysis.total.exchange_stages, 36);
//!     println!("{}", verdict.to_json().pretty());
//!     Ok(())
//! }
//! ```
//!
//! See `docs/ANALYSIS.md` for what is proved vs sampled and the derived
//! cost model.
//!
//! ## Layers
//!
//! * **Layer 3 (this crate)** — the coordinator: a Spark-like dataflow
//!   substrate ([`cluster`]), the distributed [`blockmatrix`] algebra, the
//!   lazy expression-plan layer ([`plan`]: DAG, optimizer, executor,
//!   explain), the SPIN recursion and its LU baseline behind the algorithm
//!   registry ([`algos`]) — both expressing each recursion level as a
//!   plan — the session API ([`session`]), the multi-tenant job service
//!   ([`service`]), the paper's wall-clock cost model ([`costmodel`]) and
//!   every experiment in the evaluation section ([`experiments`]).
//! * **Layer 2/1 (build-time Python)** — block-level compute lowered once
//!   from JAX + Pallas to HLO text, loaded and executed from Rust through
//!   the PJRT CPU client ([`runtime`]).
//!
//! Python never runs on the request path: after `make artifacts` the `spin`
//! binary is self-contained.

// Lint ratchet (CI runs clippy with `-D warnings`): non-test library code
// must not panic through `unwrap`/`expect` — fallible paths return
// `SpinError`, and lock access goes through the poison-tolerant
// `util::plock`/`util::pwait` wrappers (the sanctioned allow site).
// Invariant-backed exceptions carry a scoped `#[allow]` stating the
// invariant at the use site. Tests keep their unwraps.
#![cfg_attr(not(test), warn(clippy::unwrap_used, clippy::expect_used))]
#![warn(unused_qualifications)]

pub mod algos;
pub mod analysis;
pub mod blockmatrix;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod costmodel;
pub mod error;
pub mod exec;
pub mod experiments;
pub mod http;
pub mod linalg;
pub mod plan;
pub mod runtime;
pub mod ser;
pub mod service;
pub mod session;
pub mod store;
pub mod util;

pub use config::{ClusterConfig, HttpConfig, JobConfig};
pub use error::{Result, SpinError};
pub use http::{HttpClient, HttpServer, ServerState};
pub use service::{JobEvent, JobHandle, JobSpec, JobStatus, MatrixSpec, SpinService, TerminalSummary};
pub use session::{AlgorithmRegistry, DistMatrix, InversionAlgorithm, SessionBuilder, SpinSession};
