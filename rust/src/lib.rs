//! # SPIN — Strassen-based distributed matrix inversion
//!
//! Reproduction of *SPIN: A Fast and Scalable Matrix Inversion Method in
//! Apache Spark* (Misra et al., ICDCN '18) as a three-layer Rust + JAX +
//! Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: a Spark-like dataflow
//!   substrate ([`cluster`]), the distributed [`blockmatrix`] algebra, the
//!   SPIN recursion and its LU baseline ([`algos`]), the paper's wall-clock
//!   cost model ([`costmodel`]) and every experiment in the evaluation
//!   section ([`experiments`]).
//! * **Layer 2/1 (build-time Python)** — block-level compute lowered once
//!   from JAX + Pallas to HLO text, loaded and executed from Rust through
//!   the PJRT CPU client ([`runtime`]).
//!
//! Python never runs on the request path: after `make artifacts` the `spin`
//! binary is self-contained.

pub mod algos;
pub mod blockmatrix;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod costmodel;
pub mod error;
pub mod experiments;
pub mod linalg;
pub mod runtime;
pub mod ser;
pub mod util;

pub use config::{ClusterConfig, JobConfig};
pub use error::{Result, SpinError};
