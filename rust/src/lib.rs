//! # SPIN — Strassen-based distributed matrix inversion
//!
//! Reproduction of *SPIN: A Fast and Scalable Matrix Inversion Method in
//! Apache Spark* (Misra et al., ICDCN '18) as a three-layer Rust + JAX +
//! Pallas system.
//!
//! ## Public API: sessions, lazy matrix plans, and `explain()`
//!
//! The front door is [`session::SpinSession`]: a builder-configured context
//! that owns the simulated cluster, the block-kernel backend, and the job
//! defaults, and hands out [`session::DistMatrix`] handles. Handles are
//! **lazy**: operator methods (`multiply`, `subtract`, `inverse`, `solve`,
//! `pseudo_inverse`, …) build a [`plan::MatExpr`] expression DAG and
//! return immediately. Distributed work runs only at materialization
//! points (`collect`, `to_dense`, `inverse_residual`, `solve_dense`) —
//! after a rule-based optimizer has fused multiply+subtract into one
//! reduce stage, pushed transposes into multiply operands, folded scalars,
//! and deduplicated common subexpressions with automatic `cache()`
//! insertion. `DistMatrix::explain()` (and `spin explain` on the CLI)
//! prints the optimized plan with predicted shuffle stages per node.
//!
//! ```no_run
//! use spin::session::SpinSession;
//!
//! fn main() -> spin::Result<()> {
//!     let session = SpinSession::builder().cores(4).build()?;
//!     let a = session.random_spd(256, 64)?;     // 4×4 grid of 64×64 blocks
//!     let inv = a.inverse()?;                   // lazy: builds a plan node
//!     assert!(a.inverse_residual(&inv)? < 1e-10); // materializes here
//!
//!     let b = session.random_seeded(256, 64, 7)?;
//!     let x = a.solve(&b)?;                     // X = A⁻¹·B, one lazy plan
//!     println!("{}", x.explain()?);             // optimized plan + shuffle predictions
//!     x.collect()?;                             // run it (memoized afterwards)
//!
//!     let pinv = a.pseudo_inverse()?;           // (AᵀA)⁻¹·Aᵀ — Aᵀ is CSE-cached
//!     let lu = session.invert_with("lu", &a)?;  // any registered algorithm
//!     # let _ = (pinv, lu);
//!     Ok(())
//! }
//! ```
//!
//! Inversion schemes are open-ended: implement
//! [`algos::InversionAlgorithm`] and register it in the session builder (or
//! an [`algos::AlgorithmRegistry`]) under a new name — the CLI's `--algo`
//! flag and the experiment harness resolve through the same registry, and
//! a scheme can expose its per-level plan for `explain` via the trait's
//! `plan` hook. New plan rewrites are added as optimizer rules — see the
//! rule contract in [`plan::optimizer`] — not as new `BlockMatrix`
//! methods; PR 2's hand-fused Schur step is now just the fusion rule.
//!
//! ## Layers
//!
//! * **Layer 3 (this crate)** — the coordinator: a Spark-like dataflow
//!   substrate ([`cluster`]), the distributed [`blockmatrix`] algebra, the
//!   lazy expression-plan layer ([`plan`]: DAG, optimizer, executor,
//!   explain), the SPIN recursion and its LU baseline behind the algorithm
//!   registry ([`algos`]) — both expressing each recursion level as a
//!   plan — the session API ([`session`]), the paper's wall-clock cost
//!   model ([`costmodel`]) and every experiment in the evaluation section
//!   ([`experiments`]).
//! * **Layer 2/1 (build-time Python)** — block-level compute lowered once
//!   from JAX + Pallas to HLO text, loaded and executed from Rust through
//!   the PJRT CPU client ([`runtime`]).
//!
//! Python never runs on the request path: after `make artifacts` the `spin`
//! binary is self-contained.

pub mod algos;
pub mod blockmatrix;
pub mod cli;
pub mod cluster;
pub mod config;
pub mod costmodel;
pub mod error;
pub mod experiments;
pub mod linalg;
pub mod plan;
pub mod runtime;
pub mod ser;
pub mod session;
pub mod util;

pub use config::{ClusterConfig, JobConfig};
pub use error::{Result, SpinError};
pub use session::{AlgorithmRegistry, DistMatrix, InversionAlgorithm, SessionBuilder, SpinSession};
