//! LU factorization (partial pivoting) and serial inversion — leaf kernels.
//!
//! The paper's leaf step inverts one block "in any approach (e.g., LU, QR,
//! SVD)"; the Liu et al. baseline additionally needs LU factors themselves
//! at its leaves. Both live here.

use crate::error::{Result, SpinError};
use crate::linalg::Matrix;

/// Packed LU factors: `lu` holds L (unit diagonal, below) and U (on/above),
/// `perm[i]` is the source row of output row i, `sign` the permutation sign.
pub struct LuFactors {
    pub lu: Matrix,
    pub perm: Vec<usize>,
    pub sign: f64,
}

impl LuFactors {
    /// Extract the unit-lower-triangular L.
    pub fn l(&self) -> Matrix {
        let n = self.lu.rows();
        let mut l = Matrix::identity(n);
        for j in 0..n {
            for i in (j + 1)..n {
                l.set(i, j, self.lu.get(i, j));
            }
        }
        l
    }

    /// Extract the upper-triangular U.
    pub fn u(&self) -> Matrix {
        let n = self.lu.rows();
        let mut u = Matrix::zeros(n, n);
        for j in 0..n {
            for i in 0..=j {
                u.set(i, j, self.lu.get(i, j));
            }
        }
        u
    }

    /// The permutation as a matrix P with P·A = L·U.
    pub fn p(&self) -> Matrix {
        let n = self.perm.len();
        let mut p = Matrix::zeros(n, n);
        for (i, &src) in self.perm.iter().enumerate() {
            p.set(i, src, 1.0);
        }
        p
    }

    pub fn det(&self) -> f64 {
        let n = self.lu.rows();
        (0..n).fold(self.sign, |acc, i| acc * self.lu.get(i, i))
    }
}

/// LU with partial pivoting: P·A = L·U. Errors on (numerically) singular A.
pub fn lu_decompose(a: &Matrix) -> Result<LuFactors> {
    if !a.is_square() {
        return Err(SpinError::shape("LU needs a square matrix"));
    }
    let n = a.rows();
    let mut lu = a.clone();
    let mut perm: Vec<usize> = (0..n).collect();
    let mut sign = 1.0;

    for k in 0..n {
        // pivot search in column k, rows k..n
        let mut p = k;
        let mut pmax = lu.get(k, k).abs();
        for i in (k + 1)..n {
            let v = lu.get(i, k).abs();
            if v > pmax {
                pmax = v;
                p = i;
            }
        }
        if pmax < f64::EPSILON * n as f64 {
            return Err(SpinError::numerical(format!(
                "singular pivot at column {k} (|pivot|={pmax:.3e})"
            )));
        }
        if p != k {
            // swap rows k and p
            for j in 0..n {
                let t = lu.get(k, j);
                lu.set(k, j, lu.get(p, j));
                lu.set(p, j, t);
            }
            perm.swap(k, p);
            sign = -sign;
        }
        eliminate_column(&mut lu, k);
    }
    Ok(LuFactors { lu, perm, sign })
}

/// One Gaussian-elimination step on packed LU storage, column-oriented.
///
/// §Perf: computes the multiplier column once (contiguous scale of
/// `lu[k+1.., k]`), then updates each trailing column with a contiguous
/// axpy against it — the column-major-friendly `jki` form of the strided
/// row update (EXPERIMENTS.md §Perf, L3-1).
fn eliminate_column(lu: &mut Matrix, k: usize) {
    let n = lu.rows();
    let pivot = lu.get(k, k);
    {
        let ck = &mut lu.col_mut(k)[k + 1..n];
        for v in ck.iter_mut() {
            *v /= pivot;
        }
    }
    for j in (k + 1)..n {
        let ukj = lu.get(k, j);
        if ukj == 0.0 {
            continue;
        }
        // Columns k and j are disjoint slices of the backing buffer.
        let data = lu.data_mut();
        let (head, tail) = data.split_at_mut(j * n);
        let ck = &head[k * n + k + 1..k * n + n];
        let cj = &mut tail[k + 1..n];
        for (cv, &fv) in cj.iter_mut().zip(ck) {
            *cv -= fv * ukj;
        }
    }
}

/// Solve A·x = rhs (multiple right-hand sides) via the packed factors.
///
/// §Perf: column-sweep substitution. The packed factors are column-major,
/// so the inner updates run over one contiguous factor column against one
/// contiguous solution column (an axpy that auto-vectorizes) instead of a
/// strided row walk (EXPERIMENTS.md §Perf, L3-1).
pub fn solve(f: &LuFactors, rhs: &Matrix) -> Result<Matrix> {
    let n = f.lu.rows();
    if rhs.rows() != n {
        return Err(SpinError::shape("solve: rhs row count mismatch"));
    }
    let m = rhs.cols();
    let mut x = Matrix::zeros(n, m);
    // apply permutation
    for j in 0..m {
        for i in 0..n {
            x.set(i, j, rhs.get(f.perm[i], j));
        }
    }
    for j in 0..m {
        // forward substitution (L, unit diagonal), column-oriented:
        // once x[p] is final, subtract x[p]·L[p+1.., p] from the rows below.
        for p in 0..n {
            let xp = x.get(p, j);
            if xp != 0.0 {
                let lu_col = &f.lu.col(p)[p + 1..n];
                let x_col = &mut x.col_mut(j)[p + 1..n];
                for (xi, &lv) in x_col.iter_mut().zip(lu_col) {
                    *xi -= lv * xp;
                }
            }
        }
        // back substitution (U), column-oriented.
        for p in (0..n).rev() {
            let xp = x.get(p, j) / f.lu.get(p, p);
            x.set(p, j, xp);
            if xp != 0.0 {
                let lu_col = &f.lu.col(p)[..p];
                let x_col = &mut x.col_mut(j)[..p];
                for (xi, &uv) in x_col.iter_mut().zip(lu_col) {
                    *xi -= uv * xp;
                }
            }
        }
    }
    Ok(x)
}

/// LU **without pivoting**: A = L·U with L unit-lower, U upper.
///
/// Block-recursive LU (the Liu et al. baseline) cannot permute rows across
/// blocks, so its leaf kernel must be pivot-free; errors if a pivot
/// (numerically) vanishes. Safe for the diagonally-dominant / SPD workload
/// families whose principal minors never vanish.
pub fn lu_decompose_nopivot(a: &Matrix) -> Result<(Matrix, Matrix)> {
    if !a.is_square() {
        return Err(SpinError::shape("LU needs a square matrix"));
    }
    let n = a.rows();
    let mut lu = a.clone();
    for k in 0..n {
        let pivot = lu.get(k, k);
        if pivot.abs() < f64::EPSILON * n as f64 {
            return Err(SpinError::numerical(format!(
                "zero pivot at column {k} in pivot-free LU (|pivot|={:.3e})",
                pivot.abs()
            )));
        }
        eliminate_column(&mut lu, k);
    }
    let mut l = Matrix::identity(n);
    let mut u = Matrix::zeros(n, n);
    for j in 0..n {
        for i in 0..n {
            if i > j {
                l.set(i, j, lu.get(i, j));
            } else {
                u.set(i, j, lu.get(i, j));
            }
        }
    }
    Ok((l, u))
}

/// A⁻¹ via LU + n-column solve — the default leaf method.
pub fn lu_inverse(a: &Matrix) -> Result<Matrix> {
    let f = lu_decompose(a)?;
    solve(&f, &Matrix::identity(a.rows()))
}

/// A⁻¹ via Gauss-Jordan with partial pivoting on the augmented [A | I] —
/// mirrors the Pallas leaf kernel exactly (same algorithm, same pivoting).
pub fn gauss_jordan_inverse(a: &Matrix) -> Result<Matrix> {
    if !a.is_square() {
        return Err(SpinError::shape("gauss_jordan needs a square matrix"));
    }
    let n = a.rows();
    let mut aug = Matrix::zeros(n, 2 * n);
    aug.set_submatrix(0, 0, a)?;
    aug.set_submatrix(0, n, &Matrix::identity(n))?;

    for k in 0..n {
        let mut p = k;
        let mut pmax = aug.get(k, k).abs();
        for i in (k + 1)..n {
            let v = aug.get(i, k).abs();
            if v > pmax {
                pmax = v;
                p = i;
            }
        }
        if pmax < f64::EPSILON * n as f64 {
            return Err(SpinError::numerical(format!(
                "singular pivot at column {k}"
            )));
        }
        if p != k {
            for j in 0..2 * n {
                let t = aug.get(k, j);
                aug.set(k, j, aug.get(p, j));
                aug.set(p, j, t);
            }
        }
        // §Perf: column-sweep elimination (see `eliminate_column`) — one
        // multiplier vector, then a contiguous axpy per augmented column.
        let pivot = aug.get(k, k);
        for j in 0..2 * n {
            let v = aug.get(k, j) / pivot;
            aug.set(k, j, v);
        }
        let mut factors: Vec<f64> = aug.col(k).to_vec();
        factors[k] = 0.0;
        for j in 0..2 * n {
            let akj = aug.get(k, j);
            if akj == 0.0 {
                continue;
            }
            let col = aug.col_mut(j);
            for (cv, &fv) in col.iter_mut().zip(&factors) {
                *cv -= fv * akj;
            }
        }
    }
    aug.submatrix(0, n, n, n)
}

/// Cholesky factorization A = L·Lᵀ of a symmetric positive-definite
/// matrix; returns the lower-triangular factor L.
///
/// §Perf: left-looking, column-oriented — column j is finished with one
/// contiguous axpy per prior column (the `jki` form, same discipline as
/// `eliminate_column`). A non-positive pivot means the symmetric input is
/// not positive definite: the factorization *is* the SPD test, and the
/// error names the failing pivot so block-level callers can surface it.
pub fn cholesky_factor(a: &Matrix) -> Result<Matrix> {
    if !a.is_square() {
        return Err(SpinError::shape("cholesky needs a square matrix"));
    }
    let n = a.rows();
    let mut l = Matrix::zeros(n, n);
    // Copy the lower triangle; the upper is never read.
    for j in 0..n {
        for i in j..n {
            l.set(i, j, a.get(i, j));
        }
    }
    for j in 0..n {
        // Fold prior columns into column j: l[j.., j] -= l[j, k]·l[j.., k].
        for k in 0..j {
            let ljk = l.get(j, k);
            if ljk == 0.0 {
                continue;
            }
            // Columns k and j are disjoint slices of the backing buffer.
            let data = l.data_mut();
            let (head, tail) = data.split_at_mut(j * n);
            let ck = &head[k * n + j..k * n + n];
            let cj = &mut tail[j..n];
            for (cv, &kv) in cj.iter_mut().zip(ck) {
                *cv -= kv * ljk;
            }
        }
        let d = l.get(j, j);
        if d <= 0.0 || !d.is_finite() {
            return Err(SpinError::numerical(format!(
                "matrix is not positive definite (pivot {d:.3e} at row {j})"
            )));
        }
        let root = d.sqrt();
        let cj = &mut l.col_mut(j)[j..n];
        for v in cj.iter_mut() {
            *v /= root;
        }
        l.set(j, j, root);
    }
    Ok(l)
}

/// Serial inversion dispatch used across the crate.
pub fn inverse(a: &Matrix) -> Result<Matrix> {
    lu_inverse(a)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::generate::{diag_dominant, spd};
    use crate::linalg::matmul;
    use crate::linalg::inverse_residual;
    use crate::util::check::forall;
    use crate::util::Rng;

    #[test]
    fn lu_reconstructs_pa() {
        let mut rng = Rng::new(1);
        let a = diag_dominant(16, &mut rng);
        let f = lu_decompose(&a).unwrap();
        let pa = matmul(&f.p(), &a);
        let lu = matmul(&f.l(), &f.u());
        assert!(pa.max_abs_diff(&lu) < 1e-10);
    }

    #[test]
    fn lu_pivots_zero_leading_entry() {
        let a = Matrix::from_vec(3, 3, vec![0.0, 1.0, 4.0, 1.0, 0.0, 5.0, 2.0, 3.0, 0.0]).unwrap();
        let inv = lu_inverse(&a).unwrap();
        assert!(inverse_residual(&a, &inv) < 1e-12);
    }

    #[test]
    fn lu_rejects_singular() {
        let a = Matrix::from_fn(4, 4, |i, _| i as f64); // rank 1
        assert!(lu_decompose(&a).is_err());
    }

    #[test]
    fn det_of_known_matrix() {
        let a = Matrix::from_vec(2, 2, vec![3.0, 1.0, 2.0, 4.0]).unwrap();
        // det = 3*4 - 2*1 = 10
        let f = lu_decompose(&a).unwrap();
        assert!((f.det() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn solve_matches_direct() {
        let mut rng = Rng::new(2);
        let a = diag_dominant(12, &mut rng);
        let x_true = Matrix::random_uniform(12, 3, -2.0, 2.0, &mut rng);
        let rhs = matmul(&a, &x_true);
        let f = lu_decompose(&a).unwrap();
        let x = solve(&f, &rhs).unwrap();
        assert!(x.max_abs_diff(&x_true) < 1e-9);
    }

    #[test]
    fn inverse_of_identity() {
        let inv = lu_inverse(&Matrix::identity(8)).unwrap();
        assert!(inv.max_abs_diff(&Matrix::identity(8)) < 1e-14);
    }

    #[test]
    fn gauss_jordan_matches_lu() {
        let mut rng = Rng::new(3);
        for n in [1usize, 2, 5, 16, 40] {
            let a = diag_dominant(n, &mut rng);
            let gj = gauss_jordan_inverse(&a).unwrap();
            let lu = lu_inverse(&a).unwrap();
            assert!(gj.max_abs_diff(&lu) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn gauss_jordan_needs_pivoting_case() {
        let a = Matrix::from_vec(3, 3, vec![0.0, 1.0, 4.0, 1.0, 0.0, 5.0, 2.0, 3.0, 0.0]).unwrap();
        let inv = gauss_jordan_inverse(&a).unwrap();
        assert!(inverse_residual(&a, &inv) < 1e-12);
    }

    #[test]
    fn spd_inversion_residual() {
        let mut rng = Rng::new(4);
        let a = spd(32, &mut rng);
        let inv = lu_inverse(&a).unwrap();
        assert!(inverse_residual(&a, &inv) < 1e-12);
    }

    #[test]
    fn property_inverse_roundtrip() {
        forall(
            "inv(inv(A)) == A",
            0xE1,
            16,
            |r| diag_dominant(4 + r.next_usize(28), r),
            |a| {
                let twice = lu_inverse(&lu_inverse(a).unwrap()).unwrap();
                let d = twice.max_abs_diff(a);
                if d < 1e-7 {
                    Ok(())
                } else {
                    Err(format!("diff {d}"))
                }
            },
        );
    }

    #[test]
    fn cholesky_reconstructs_spd() {
        let mut rng = Rng::new(7);
        for n in [1usize, 2, 5, 16, 33] {
            let a = spd(n, &mut rng);
            let l = cholesky_factor(&a).unwrap();
            // L is lower triangular with positive diagonal.
            for j in 0..n {
                assert!(l.get(j, j) > 0.0, "n={n} diag {j}");
                for i in 0..j {
                    assert_eq!(l.get(i, j), 0.0, "n={n} upper ({i},{j})");
                }
            }
            let llt = matmul(&l, &l.transpose());
            assert!(llt.max_abs_diff(&a) < 1e-9, "n={n}");
        }
    }

    #[test]
    fn cholesky_rejects_non_spd() {
        // Indefinite: symmetric but with a negative eigenvalue.
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 2.0, 1.0]).unwrap();
        let err = cholesky_factor(&a).unwrap_err().to_string();
        assert!(err.contains("not positive definite"), "{err}");
        // Non-square.
        assert!(cholesky_factor(&Matrix::zeros(2, 3)).is_err());
    }

    #[test]
    fn property_cholesky_solves_inversion() {
        forall(
            "‖A·(L⁻ᵀL⁻¹)−I‖ small for SPD A",
            0xE3,
            16,
            |r| spd(2 + r.next_usize(40), r),
            |a| {
                let l = cholesky_factor(a).unwrap();
                let li = lu_inverse(&l).unwrap();
                let inv = matmul(&li.transpose(), &li);
                let resid = inverse_residual(a, &inv);
                if resid < 1e-10 {
                    Ok(())
                } else {
                    Err(format!("residual {resid}"))
                }
            },
        );
    }

    #[test]
    fn property_residuals_small() {
        forall(
            "‖A·A⁻¹−I‖ small",
            0xE2,
            16,
            |r| {
                let n = 2 + r.next_usize(48);
                if r.next_f64() < 0.5 {
                    diag_dominant(n, r)
                } else {
                    spd(n, r)
                }
            },
            |a| {
                let inv = lu_inverse(a).unwrap();
                let resid = inverse_residual(a, &inv);
                if resid < 1e-10 {
                    Ok(())
                } else {
                    Err(format!("residual {resid}"))
                }
            },
        );
    }
}
