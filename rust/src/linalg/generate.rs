//! Invertible test-matrix generators (replacing the paper's `java.util.Random`
//! workload; see DESIGN.md §3 for why plain uniform random is not
//! Strassen-safe in general).

use crate::linalg::{matmul, Matrix};
use crate::util::Rng;

/// Strictly diagonally dominant: uniform(-1,1) off-diagonal, diagonal set to
/// ±(row abs-sum + 1). Every principal minor is nonsingular, so the Strassen
/// recursion never meets a singular A11 or Schur complement.
pub fn diag_dominant(n: usize, rng: &mut Rng) -> Matrix {
    let mut m = Matrix::random_uniform(n, n, -1.0, 1.0, rng);
    for i in 0..n {
        let mut row_sum = 0.0;
        for j in 0..n {
            if j != i {
                row_sum += m.get(i, j).abs();
            }
        }
        let sign = if m.get(i, i) >= 0.0 { 1.0 } else { -1.0 };
        m.set(i, i, sign * (row_sum + 1.0));
    }
    m
}

/// Symmetric positive definite: `B·Bᵀ + n·I` — the paper's stated scope
/// ("square positive definite and invertible matrices").
pub fn spd(n: usize, rng: &mut Rng) -> Matrix {
    let b = Matrix::random_uniform(n, n, -1.0, 1.0, rng);
    let mut m = matmul(&b, &b.transpose());
    for i in 0..n {
        m.add_assign_at(i, i, n as f64);
    }
    m
}

// ---------------------------------------------------------------------
// Per-block generation: seed-derived independent RNG streams, one per
// block index. This is the generation domain shared by the eager
// `BlockMatrix::random` constructor and the lazy `ExprOp::LazySource`
// plan leaves — both call [`crate::linalg::generate_block`], so a lazily
// materialized matrix is bit-identical to its eagerly generated twin no
// matter which worker produces which block, or in what order.
// ---------------------------------------------------------------------

/// The RNG stream of block `(bi, bj)` under `seed`. Streams are derived,
/// not sliced from one sequential stream, so any block is generable in
/// O(block) work without replaying its predecessors.
pub fn block_stream(seed: u64, bi: usize, bj: usize) -> Rng {
    let mut base = Rng::new(seed);
    base.fork(((bi as u64) << 32) | bj as u64)
}

/// Raw uniform(-1, 1) payload of block `(bi, bj)` — the common substrate
/// of both per-block families below.
fn uniform_block(block_size: usize, seed: u64, bi: usize, bj: usize) -> Matrix {
    let mut rng = block_stream(seed, bi, bj);
    Matrix::random_uniform(block_size, block_size, -1.0, 1.0, &mut rng)
}

/// Block `(bi, bj)` of the per-block diagonally-dominant family: uniform
/// off-diagonal entries, diagonal entries rewritten to ±(row abs-sum + 1).
/// A diagonal block needs its whole block-row's entries for the row sums;
/// they are regenerated locally from the sibling streams (deterministic
/// and O(n·block_size) work) rather than shuffled in.
pub fn diag_dominant_block(
    n: usize,
    block_size: usize,
    bi: usize,
    bj: usize,
    seed: u64,
) -> Matrix {
    let mut m = uniform_block(block_size, seed, bi, bj);
    if bi == bj {
        let nblocks = n / block_size;
        let row: Vec<Matrix> = (0..nblocks)
            .map(|bk| {
                if bk == bi {
                    m.clone()
                } else {
                    uniform_block(block_size, seed, bi, bk)
                }
            })
            .collect();
        for i in 0..block_size {
            let mut row_sum = 0.0;
            for (bk, blk) in row.iter().enumerate() {
                for j in 0..block_size {
                    if !(bk == bi && j == i) {
                        row_sum += blk.get(i, j).abs();
                    }
                }
            }
            let sign = if m.get(i, i) >= 0.0 { 1.0 } else { -1.0 };
            m.set(i, i, sign * (row_sum + 1.0));
        }
    }
    m
}

/// Block `(bi, bj)` of the per-block SPD family `B·Bᵀ + n·I`, where `B`'s
/// blocks come from the per-block streams: the output block is
/// `Σ_k B(bi,k)·B(bj,k)ᵀ` (+ `n·I` on the diagonal), accumulated in fixed
/// `k` order so every producer computes identical bits.
pub fn spd_block(n: usize, block_size: usize, bi: usize, bj: usize, seed: u64) -> Matrix {
    let nblocks = n / block_size;
    let mut acc = Matrix::zeros(block_size, block_size);
    for bk in 0..nblocks {
        let left = uniform_block(block_size, seed, bi, bk);
        let right = uniform_block(block_size, seed, bj, bk);
        let prod = matmul(&left, &right.transpose());
        for j in 0..block_size {
            for i in 0..block_size {
                acc.add_assign_at(i, j, prod.get(i, j));
            }
        }
    }
    if bi == bj {
        for i in 0..block_size {
            acc.add_assign_at(i, i, n as f64);
        }
    }
    acc
}

/// Hilbert matrix H[i][j] = 1/(i+j+1) — notoriously ill-conditioned;
/// used by numerical edge-case tests only.
pub fn hilbert(n: usize) -> Matrix {
    Matrix::from_fn(n, n, |i, j| 1.0 / (i + j + 1) as f64)
}

/// A generically invertible (not necessarily dominant) random matrix:
/// uniform entries plus a small diagonal shift.
pub fn random_invertible(n: usize, rng: &mut Rng) -> Matrix {
    let mut m = Matrix::random_uniform(n, n, -1.0, 1.0, rng);
    for i in 0..n {
        m.add_assign_at(i, i, 2.0);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::lu_inverse;
    use crate::util::check::forall;

    #[test]
    fn diag_dominant_is_dominant() {
        let mut rng = Rng::new(1);
        let m = diag_dominant(32, &mut rng);
        for i in 0..32 {
            let mut off = 0.0;
            for j in 0..32 {
                if j != i {
                    off += m.get(i, j).abs();
                }
            }
            assert!(m.get(i, i).abs() > off, "row {i} not dominant");
        }
    }

    #[test]
    fn spd_is_symmetric_and_pd() {
        let mut rng = Rng::new(2);
        let m = spd(24, &mut rng);
        assert!(m.max_abs_diff(&m.transpose()) < 1e-12);
        // PD ⇒ xᵀMx > 0 for random x.
        for _ in 0..8 {
            let x = Matrix::random_uniform(24, 1, -1.0, 1.0, &mut rng);
            let q = matmul(&matmul(&x.transpose(), &m), &x).get(0, 0);
            assert!(q > 0.0);
        }
    }

    #[test]
    fn hilbert_values() {
        let h = hilbert(3);
        assert_eq!(h.get(0, 0), 1.0);
        assert!((h.get(1, 2) - 0.25).abs() < 1e-15);
        assert_eq!(h.get(2, 1), h.get(1, 2));
    }

    #[test]
    fn per_block_diag_dominant_is_dominant_and_deterministic() {
        let (n, bs) = (32, 8);
        let mut dense = Matrix::zeros(n, n);
        for bi in 0..n / bs {
            for bj in 0..n / bs {
                let blk = diag_dominant_block(n, bs, bi, bj, 7);
                dense.set_submatrix(bi * bs, bj * bs, &blk).unwrap();
            }
        }
        for i in 0..n {
            let mut off = 0.0;
            for j in 0..n {
                if j != i {
                    off += dense.get(i, j).abs();
                }
            }
            assert!(dense.get(i, i).abs() > off, "row {i} not dominant");
        }
        // Same (seed, index) ⇒ same bits, regardless of generation order.
        let a = diag_dominant_block(n, bs, 2, 2, 7);
        let b = diag_dominant_block(n, bs, 2, 2, 7);
        assert_eq!(a.max_abs_diff(&b), 0.0);
        assert!(diag_dominant_block(n, bs, 2, 2, 8).max_abs_diff(&a) > 0.0);
    }

    #[test]
    fn per_block_spd_assembles_symmetric_pd() {
        let (n, bs) = (24, 8);
        let mut dense = Matrix::zeros(n, n);
        for bi in 0..n / bs {
            for bj in 0..n / bs {
                let blk = spd_block(n, bs, bi, bj, 5);
                dense.set_submatrix(bi * bs, bj * bs, &blk).unwrap();
            }
        }
        assert!(dense.max_abs_diff(&dense.transpose()) < 1e-12);
        let mut rng = Rng::new(3);
        for _ in 0..8 {
            let x = Matrix::random_uniform(n, 1, -1.0, 1.0, &mut rng);
            let q = matmul(&matmul(&x.transpose(), &dense), &x).get(0, 0);
            assert!(q > 0.0);
        }
        lu_inverse(&dense).unwrap();
    }

    #[test]
    fn property_spd_block_is_symmetric_pd_at_all_grids() {
        use crate::linalg::cholesky_factor;
        // Three sizes × two grids: the assembled per-block SPD family must
        // be symmetric and positive definite at every geometry — the
        // contract the `cholesky` scheme relies on. A successful Cholesky
        // factorization is the PD certificate (it exists iff SPD).
        for n in [16usize, 24, 32] {
            for g in [2usize, 4] {
                let bs = n / g;
                let mut dense = Matrix::zeros(n, n);
                for bi in 0..g {
                    for bj in 0..g {
                        dense
                            .set_submatrix(bi * bs, bj * bs, &spd_block(n, bs, bi, bj, 11))
                            .unwrap();
                    }
                }
                assert!(
                    dense.max_abs_diff(&dense.transpose()) < 1e-12,
                    "n={n} g={g}: not symmetric"
                );
                let l = cholesky_factor(&dense)
                    .unwrap_or_else(|e| panic!("n={n} g={g} not PD: {e}"));
                assert!((0..n).all(|i| l.get(i, i) > 0.0));
            }
        }
    }

    #[test]
    fn block_streams_are_independent() {
        let mut a = block_stream(1, 0, 0);
        let mut b = block_stream(1, 0, 1);
        let mut c = block_stream(1, 1, 0);
        let same_ab = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        let same_bc = (0..64).filter(|_| b.next_u64() == c.next_u64()).count();
        assert!(same_ab < 2 && same_bc < 2);
    }

    #[test]
    fn property_generators_invertible() {
        forall(
            "generated matrices invert",
            0xF1,
            12,
            |r| {
                let n = 2 + r.next_usize(30);
                match r.next_usize(3) {
                    0 => diag_dominant(n, r),
                    1 => spd(n, r),
                    _ => random_invertible(n, r),
                }
            },
            |a| lu_inverse(a).map(|_| ()).map_err(|e| e.to_string()),
        );
    }
}
