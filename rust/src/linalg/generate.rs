//! Invertible test-matrix generators (replacing the paper's `java.util.Random`
//! workload; see DESIGN.md §3 for why plain uniform random is not
//! Strassen-safe in general).

use crate::linalg::{matmul, Matrix};
use crate::util::Rng;

/// Strictly diagonally dominant: uniform(-1,1) off-diagonal, diagonal set to
/// ±(row abs-sum + 1). Every principal minor is nonsingular, so the Strassen
/// recursion never meets a singular A11 or Schur complement.
pub fn diag_dominant(n: usize, rng: &mut Rng) -> Matrix {
    let mut m = Matrix::random_uniform(n, n, -1.0, 1.0, rng);
    for i in 0..n {
        let mut row_sum = 0.0;
        for j in 0..n {
            if j != i {
                row_sum += m.get(i, j).abs();
            }
        }
        let sign = if m.get(i, i) >= 0.0 { 1.0 } else { -1.0 };
        m.set(i, i, sign * (row_sum + 1.0));
    }
    m
}

/// Symmetric positive definite: `B·Bᵀ + n·I` — the paper's stated scope
/// ("square positive definite and invertible matrices").
pub fn spd(n: usize, rng: &mut Rng) -> Matrix {
    let b = Matrix::random_uniform(n, n, -1.0, 1.0, rng);
    let mut m = matmul(&b, &b.transpose());
    for i in 0..n {
        m.add_assign_at(i, i, n as f64);
    }
    m
}

/// Hilbert matrix H[i][j] = 1/(i+j+1) — notoriously ill-conditioned;
/// used by numerical edge-case tests only.
pub fn hilbert(n: usize) -> Matrix {
    Matrix::from_fn(n, n, |i, j| 1.0 / (i + j + 1) as f64)
}

/// A generically invertible (not necessarily dominant) random matrix:
/// uniform entries plus a small diagonal shift.
pub fn random_invertible(n: usize, rng: &mut Rng) -> Matrix {
    let mut m = Matrix::random_uniform(n, n, -1.0, 1.0, rng);
    for i in 0..n {
        m.add_assign_at(i, i, 2.0);
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::lu_inverse;
    use crate::util::check::forall;

    #[test]
    fn diag_dominant_is_dominant() {
        let mut rng = Rng::new(1);
        let m = diag_dominant(32, &mut rng);
        for i in 0..32 {
            let mut off = 0.0;
            for j in 0..32 {
                if j != i {
                    off += m.get(i, j).abs();
                }
            }
            assert!(m.get(i, i).abs() > off, "row {i} not dominant");
        }
    }

    #[test]
    fn spd_is_symmetric_and_pd() {
        let mut rng = Rng::new(2);
        let m = spd(24, &mut rng);
        assert!(m.max_abs_diff(&m.transpose()) < 1e-12);
        // PD ⇒ xᵀMx > 0 for random x.
        for _ in 0..8 {
            let x = Matrix::random_uniform(24, 1, -1.0, 1.0, &mut rng);
            let q = matmul(&matmul(&x.transpose(), &m), &x).get(0, 0);
            assert!(q > 0.0);
        }
    }

    #[test]
    fn hilbert_values() {
        let h = hilbert(3);
        assert_eq!(h.get(0, 0), 1.0);
        assert!((h.get(1, 2) - 0.25).abs() < 1e-15);
        assert_eq!(h.get(2, 1), h.get(1, 2));
    }

    #[test]
    fn property_generators_invertible() {
        forall(
            "generated matrices invert",
            0xF1,
            12,
            |r| {
                let n = 2 + r.next_usize(30);
                match r.next_usize(3) {
                    0 => diag_dominant(n, r),
                    1 => spd(n, r),
                    _ => random_invertible(n, r),
                }
            },
            |a| lu_inverse(a).map(|_| ()).map_err(|e| e.to_string()),
        );
    }
}
