//! Column-major dense matrix (the paper stores block payloads column-major).

use crate::error::{Result, SpinError};
use crate::util::Rng;

/// Dense f64 matrix, column-major storage: element `(i, j)` lives at
/// `data[i + j * rows]`.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl std::fmt::Debug for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Matrix {}x{} [", self.rows, self.cols)?;
        let show_r = self.rows.min(6);
        let show_c = self.cols.min(6);
        for i in 0..show_r {
            write!(f, "  ")?;
            for j in 0..show_c {
                write!(f, "{:>10.4} ", self.get(i, j))?;
            }
            writeln!(f, "{}", if self.cols > show_c { "…" } else { "" })?;
        }
        if self.rows > show_r {
            writeln!(f, "  …")?;
        }
        write!(f, "]")
    }
}

impl Matrix {
    // ---------- constructors ----------

    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m.set(i, i, 1.0);
        }
        m
    }

    /// Take ownership of a column-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(SpinError::shape(format!(
                "buffer of {} elements cannot be a {rows}x{cols} matrix",
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Build from a closure over (row, col).
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for j in 0..cols {
            for i in 0..rows {
                m.set(i, j, f(i, j));
            }
        }
        m
    }

    /// Uniform random entries in [lo, hi).
    pub fn random_uniform(rows: usize, cols: usize, lo: f64, hi: f64, rng: &mut Rng) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        rng.fill_uniform(&mut m.data, lo, hi);
        m
    }

    // ---------- accessors ----------

    #[inline(always)]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline(always)]
    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn is_square(&self) -> bool {
        self.rows == self.cols
    }

    #[inline(always)]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i + j * self.rows]
    }

    #[inline(always)]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i + j * self.rows] = v;
    }

    #[inline(always)]
    pub fn add_assign_at(&mut self, i: usize, j: usize, v: f64) {
        debug_assert!(i < self.rows && j < self.cols);
        self.data[i + j * self.rows] += v;
    }

    /// Raw column-major payload.
    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f64] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f64> {
        self.data
    }

    /// Column `j` as a slice (contiguous in column-major order).
    #[inline(always)]
    pub fn col(&self, j: usize) -> &[f64] {
        &self.data[j * self.rows..(j + 1) * self.rows]
    }

    #[inline(always)]
    pub fn col_mut(&mut self, j: usize) -> &mut [f64] {
        let r = self.rows;
        &mut self.data[j * r..(j + 1) * r]
    }

    /// Payload size in bytes — drives the shuffle cost accounting.
    pub fn size_bytes(&self) -> u64 {
        (self.data.len() * std::mem::size_of::<f64>()) as u64
    }

    // ---------- elementwise ----------

    pub fn add(&self, other: &Matrix) -> Result<Matrix> {
        self.zip_with(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Matrix) -> Result<Matrix> {
        self.zip_with(other, |a, b| a - b)
    }

    pub fn scale(&self, s: f64) -> Matrix {
        let mut out = self.clone();
        for v in &mut out.data {
            *v *= s;
        }
        out
    }

    pub fn neg(&self) -> Matrix {
        self.scale(-1.0)
    }

    fn zip_with(&self, other: &Matrix, f: impl Fn(f64, f64) -> f64) -> Result<Matrix> {
        if self.rows != other.rows || self.cols != other.cols {
            return Err(SpinError::shape(format!(
                "elementwise op on {}x{} vs {}x{}",
                self.rows, self.cols, other.rows, other.cols
            )));
        }
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| f(a, b))
            .collect();
        Ok(Matrix {
            rows: self.rows,
            cols: self.cols,
            data,
        })
    }

    /// Cache-blocked tiled transpose. The naive double loop streams one
    /// side of the copy with stride `rows`, missing cache on every element
    /// once the matrix outgrows L1; walking TILE×TILE tiles keeps both the
    /// contiguous source column segment and the strided destination rows
    /// resident while they are reused (§Perf: the distributed `transpose`
    /// op and the pseudo-inverse's Gram step call this per block).
    pub fn transpose(&self) -> Matrix {
        const TILE: usize = 32;
        let (r, c) = (self.rows, self.cols);
        let mut out = Matrix::zeros(c, r);
        for i0 in (0..r).step_by(TILE) {
            let i1 = (i0 + TILE).min(r);
            for j0 in (0..c).step_by(TILE) {
                let j1 = (j0 + TILE).min(c);
                for j in j0..j1 {
                    // Contiguous column segment of the source tile…
                    let src = &self.data[j * r + i0..j * r + i1];
                    for (t, &v) in src.iter().enumerate() {
                        // …scattered into row `j` of the output tile.
                        out.data[(i0 + t) * c + j] = v;
                    }
                }
            }
        }
        out
    }

    // ---------- norms / predicates ----------

    /// ∞-norm: max absolute row sum.
    pub fn inf_norm(&self) -> f64 {
        let mut row_sums = vec![0.0f64; self.rows];
        for j in 0..self.cols {
            for (i, &v) in self.col(j).iter().enumerate() {
                row_sums[i] += v.abs();
            }
        }
        row_sums.into_iter().fold(0.0, f64::max)
    }

    /// 1-norm: max absolute column sum.
    pub fn one_norm(&self) -> f64 {
        (0..self.cols)
            .map(|j| self.col(j).iter().map(|v| v.abs()).sum::<f64>())
            .fold(0.0, f64::max)
    }

    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|v| v * v).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }

    /// Max elementwise |self − other| (∞ if shapes differ).
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        if self.rows != other.rows || self.cols != other.cols {
            return f64::INFINITY;
        }
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|v| v.is_finite())
    }

    // ---------- block extraction / assembly ----------

    /// Copy the `rows×cols` submatrix whose top-left corner is `(r0, c0)`.
    pub fn submatrix(&self, r0: usize, c0: usize, rows: usize, cols: usize) -> Result<Matrix> {
        if r0 + rows > self.rows || c0 + cols > self.cols {
            return Err(SpinError::shape(format!(
                "submatrix ({r0},{c0})+{rows}x{cols} out of bounds for {}x{}",
                self.rows, self.cols
            )));
        }
        let mut out = Matrix::zeros(rows, cols);
        for j in 0..cols {
            let src = &self.col(c0 + j)[r0..r0 + rows];
            out.col_mut(j).copy_from_slice(src);
        }
        Ok(out)
    }

    /// Paste `block` with its top-left corner at `(r0, c0)`.
    pub fn set_submatrix(&mut self, r0: usize, c0: usize, block: &Matrix) -> Result<()> {
        if r0 + block.rows > self.rows || c0 + block.cols > self.cols {
            return Err(SpinError::shape(format!(
                "set_submatrix ({r0},{c0})+{}x{} out of bounds for {}x{}",
                block.rows, block.cols, self.rows, self.cols
            )));
        }
        for j in 0..block.cols {
            let dst_col = c0 + j;
            let r = self.rows;
            self.data[dst_col * r + r0..dst_col * r + r0 + block.rows]
                .copy_from_slice(block.col(j));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn storage_is_column_major() {
        let m = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        // col 0 = [1,2], col 1 = [3,4]
        assert_eq!(m.get(0, 0), 1.0);
        assert_eq!(m.get(1, 0), 2.0);
        assert_eq!(m.get(0, 1), 3.0);
        assert_eq!(m.get(1, 1), 4.0);
        assert_eq!(m.col(1), &[3.0, 4.0]);
    }

    #[test]
    fn from_vec_validates_len() {
        assert!(Matrix::from_vec(2, 3, vec![0.0; 5]).is_err());
    }

    #[test]
    fn identity_and_elementwise() {
        let i = Matrix::identity(3);
        let two_i = i.add(&i).unwrap();
        assert_eq!(two_i.get(1, 1), 2.0);
        assert_eq!(two_i.sub(&i).unwrap(), i);
        assert_eq!(i.scale(-4.0).get(2, 2), -4.0);
        assert_eq!(i.neg().get(0, 0), -1.0);
    }

    #[test]
    fn elementwise_shape_mismatch() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(3, 2);
        assert!(a.add(&b).is_err());
        assert!(a.sub(&b).is_err());
        assert_eq!(a.max_abs_diff(&b), f64::INFINITY);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(4);
        let m = Matrix::random_uniform(5, 3, -1.0, 1.0, &mut rng);
        assert_eq!(m.transpose().transpose(), m);
        assert_eq!(m.transpose().get(2, 4), m.get(4, 2));
    }

    #[test]
    fn property_tiled_transpose_matches_naive_on_rectangles() {
        use crate::util::check::forall;
        forall(
            "tiled transpose ≡ naive",
            0x7A,
            24,
            |r| {
                // Rectangular shapes straddling the 32-wide tile boundary.
                let rows = 1 + r.next_usize(80);
                let cols = 1 + r.next_usize(80);
                Matrix::random_uniform(rows, cols, -1.0, 1.0, r)
            },
            |m| {
                let tiled = m.transpose();
                let naive = Matrix::from_fn(m.cols(), m.rows(), |i, j| m.get(j, i));
                if tiled.rows() != m.cols() || tiled.cols() != m.rows() {
                    return Err("shape mismatch".into());
                }
                if tiled != naive {
                    return Err(format!(
                        "tiled differs from naive on {}x{}",
                        m.rows(),
                        m.cols()
                    ));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn norms() {
        let m = Matrix::from_vec(2, 2, vec![1.0, -3.0, 2.0, 4.0]).unwrap();
        // rows: [1, 2] sum 3; [-3, 4] sum 7
        assert_eq!(m.inf_norm(), 7.0);
        assert!((m.fro_norm() - (1.0f64 + 9.0 + 4.0 + 16.0).sqrt()).abs() < 1e-15);
        assert_eq!(m.max_abs(), 4.0);
    }

    #[test]
    fn submatrix_round_trip() {
        let mut rng = Rng::new(5);
        let m = Matrix::random_uniform(8, 8, -1.0, 1.0, &mut rng);
        let sub = m.submatrix(2, 4, 3, 2).unwrap();
        assert_eq!(sub.get(0, 0), m.get(2, 4));
        assert_eq!(sub.get(2, 1), m.get(4, 5));
        let mut copy = Matrix::zeros(8, 8);
        copy.set_submatrix(2, 4, &sub).unwrap();
        assert_eq!(copy.get(4, 5), m.get(4, 5));
        assert_eq!(copy.get(0, 0), 0.0);
    }

    #[test]
    fn submatrix_bounds_checked() {
        let m = Matrix::zeros(4, 4);
        assert!(m.submatrix(2, 2, 3, 1).is_err());
        let mut m2 = Matrix::zeros(4, 4);
        assert!(m2.set_submatrix(3, 3, &Matrix::zeros(2, 2)).is_err());
    }

    #[test]
    fn quadrant_split_and_reassemble() {
        let mut rng = Rng::new(6);
        let m = Matrix::random_uniform(6, 6, -1.0, 1.0, &mut rng);
        let h = 3;
        let a11 = m.submatrix(0, 0, h, h).unwrap();
        let a12 = m.submatrix(0, h, h, h).unwrap();
        let a21 = m.submatrix(h, 0, h, h).unwrap();
        let a22 = m.submatrix(h, h, h, h).unwrap();
        let mut back = Matrix::zeros(6, 6);
        back.set_submatrix(0, 0, &a11).unwrap();
        back.set_submatrix(0, h, &a12).unwrap();
        back.set_submatrix(h, 0, &a21).unwrap();
        back.set_submatrix(h, h, &a22).unwrap();
        assert_eq!(back, m);
    }

    #[test]
    fn size_bytes() {
        assert_eq!(Matrix::zeros(4, 8).size_bytes(), 4 * 8 * 8);
    }
}
