//! Dense f64 linear algebra substrate — the JBlas stand-in.
//!
//! Everything the distributed layers need from a serial BLAS/LAPACK:
//! column-major [`Matrix`], GEMM ([`matmul`]), LU with partial pivoting,
//! Gauss-Jordan and LU-based inversion, triangular kernels for the Liu et
//! al. baseline, norms, and the invertible test-matrix generators.

mod decomp;
mod generate;
mod matrix;
mod multiply;
mod triangular;

pub use decomp::{
    gauss_jordan_inverse, inverse, lu_decompose, lu_decompose_nopivot, lu_inverse, solve,
    LuFactors,
};
pub use generate::{diag_dominant, hilbert, random_invertible, spd};
pub use matrix::Matrix;
pub use multiply::{matmul, matmul_acc, matmul_naive, MICRO_BLOCK};
pub use triangular::{invert_lower, invert_upper, is_lower_triangular, is_upper_triangular};

use crate::config::GeneratorKind;
use crate::util::Rng;

/// FLOP count of an `n×n` GEMM (2n³, the roofline denominator).
pub fn gemm_flops(n: usize) -> f64 {
    2.0 * (n as f64).powi(3)
}

/// Generate a test matrix of the given family.
pub fn generate(kind: GeneratorKind, n: usize, rng: &mut Rng) -> Matrix {
    match kind {
        GeneratorKind::DiagDominant => diag_dominant(n, rng),
        GeneratorKind::Spd => spd(n, rng),
    }
}

/// Relative inversion residual ‖A·X − I‖∞ / (‖A‖∞‖X‖∞·n) — the acceptance
/// metric used by integration tests and `--residual-check`.
pub fn inverse_residual(a: &Matrix, x: &Matrix) -> f64 {
    let prod = matmul(a, x);
    let n = a.rows();
    let mut resid: f64 = 0.0;
    for j in 0..n {
        for i in 0..n {
            let expect = if i == j { 1.0 } else { 0.0 };
            resid = resid.max((prod.get(i, j) - expect).abs());
        }
    }
    resid / (a.inf_norm() * x.inf_norm() * n as f64)
}
