//! Dense f64 linear algebra substrate — the JBlas stand-in.
//!
//! Everything the distributed layers need from a serial BLAS/LAPACK:
//! column-major [`Matrix`], GEMM ([`matmul`]), LU with partial pivoting,
//! Gauss-Jordan and LU-based inversion, triangular kernels for the Liu et
//! al. baseline, norms, and the invertible test-matrix generators.

mod decomp;
mod generate;
mod matrix;
mod multiply;
mod triangular;

pub use decomp::{
    cholesky_factor, gauss_jordan_inverse, inverse, lu_decompose, lu_decompose_nopivot,
    lu_inverse, solve, LuFactors,
};
pub use generate::{
    block_stream, diag_dominant, diag_dominant_block, hilbert, random_invertible, spd, spd_block,
};
pub use matrix::Matrix;
pub use multiply::{matmul, matmul_acc, matmul_naive, MICRO_BLOCK};
pub use triangular::{invert_lower, invert_upper, is_lower_triangular, is_upper_triangular};

use crate::config::GeneratorKind;

/// FLOP count of an `n×n` GEMM (2n³, the roofline denominator).
pub fn gemm_flops(n: usize) -> f64 {
    2.0 * (n as f64).powi(3)
}

// NOTE: there is deliberately no dense sequential-RNG `generate()`
// dispatcher anymore — every distributed generation path (eager
// `BlockMatrix::random`, lazy leaves, store ingest) goes through
// `generate_block`, keeping exactly one generation domain whose bits all
// paths agree on. The dense `diag_dominant`/`spd` helpers remain for
// serial unit tests only.

/// Block `(bi, bj)` of the seed-deterministic per-block generation scheme
/// — a pure function of `(kind, n, block_size, bi, bj, seed)`, so eager
/// driver-side generation and lazy per-partition worker generation
/// produce bit-identical matrices (see `generate::block_stream`).
pub fn generate_block(
    kind: GeneratorKind,
    n: usize,
    block_size: usize,
    bi: usize,
    bj: usize,
    seed: u64,
) -> Matrix {
    match kind {
        GeneratorKind::DiagDominant => diag_dominant_block(n, block_size, bi, bj, seed),
        GeneratorKind::Spd => spd_block(n, block_size, bi, bj, seed),
    }
}

/// Relative inversion residual ‖A·X − I‖∞ / (‖A‖∞‖X‖∞·n) — the acceptance
/// metric used by integration tests and `--residual-check`.
pub fn inverse_residual(a: &Matrix, x: &Matrix) -> f64 {
    let prod = matmul(a, x);
    let n = a.rows();
    let mut resid: f64 = 0.0;
    for j in 0..n {
        for i in 0..n {
            let expect = if i == j { 1.0 } else { 0.0 };
            resid = resid.max((prod.get(i, j) - expect).abs());
        }
    }
    resid / (a.inf_norm() * x.inf_norm() * n as f64)
}
