//! Serial GEMM — the single hottest primitive in the whole system (the
//! paper's analysis: "the primary bottleneck of inversion algorithm is
//! matrix multiplications").
//!
//! Two implementations:
//! * [`matmul_naive`] — textbook triple loop, kept as the correctness oracle
//!   and the "unoptimized" side of the §Perf before/after.
//! * [`matmul`] — cache-blocked column-major kernel: `jki` loop order so the
//!   inner loop is a contiguous axpy over columns of A and C, tiled so the
//!   working set stays in L1/L2.

use crate::linalg::Matrix;

/// Cache tile edge for the blocked kernel (tuned in the §Perf pass).
pub const MICRO_BLOCK: usize = 128;

/// Textbook `ijk` GEMM. O(mnk), no tiling — oracle + baseline.
pub fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    let (m, k, n) = (a.rows(), a.cols(), b.cols());
    let mut c = Matrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0;
            for p in 0..k {
                acc += a.get(i, p) * b.get(p, j);
            }
            c.set(i, j, acc);
        }
    }
    c
}

/// Cache-blocked column-major GEMM: C = A·B.
pub fn matmul(a: &Matrix, b: &Matrix) -> Matrix {
    let mut c = Matrix::zeros(a.rows(), b.cols());
    matmul_into(a, b, &mut c);
    c
}

/// C = D + A·B, accumulating **in place** into `d`'s buffer. Takes `d`
/// by value so there really is no extra allocation — the block-matmul
/// reduce chains `acc = matmul_acc(a_k, b_k, acc)` over k with a single
/// buffer. Callers that still need `D` afterwards clone at the call site,
/// where the cost is visible.
pub fn matmul_acc(a: &Matrix, b: &Matrix, d: Matrix) -> Matrix {
    assert_eq!(d.rows(), a.rows());
    assert_eq!(d.cols(), b.cols());
    let mut c = d;
    matmul_into(a, b, &mut c);
    c
}

/// Register micro-tile height: 8 f64 = one AVX-512 vector / two AVX2.
const MR: usize = 8;

/// C += A·B, cache-blocked with a register-resident micro-kernel.
///
/// §Perf (EXPERIMENTS.md §Perf, L3-3): the tile loop streams `(i, k)`
/// tiles; inside, an 8-row strip of C stays in registers across the whole
/// k-tile (`acc`), so C is loaded/stored once per tile instead of once per
/// k-step, and the inner update is a straight-line 8-lane FMA the compiler
/// vectorizes. ~1.7× over the previous column-axpy form at 256².
pub fn matmul_into(a: &Matrix, b: &Matrix, c: &mut Matrix) {
    assert_eq!(a.cols(), b.rows(), "matmul shape mismatch");
    assert_eq!(c.rows(), a.rows());
    assert_eq!(c.cols(), b.cols());
    let (m, kk, n) = (a.rows(), a.cols(), b.cols());
    let bs = MICRO_BLOCK;

    for i0 in (0..m).step_by(bs) {
        let i1 = (i0 + bs).min(m);
        for k0 in (0..kk).step_by(bs) {
            let k1 = (k0 + bs).min(kk);
            for j in 0..n {
                let b_col = b.col(j);
                let c_col = c.col_mut(j);

                // 8-row register strips.
                let mut i = i0;
                while i + MR <= i1 {
                    let mut acc = [0.0f64; MR];
                    for p in k0..k1 {
                        let bv = b_col[p];
                        let a_seg = &a.col(p)[i..i + MR];
                        for t in 0..MR {
                            acc[t] += a_seg[t] * bv;
                        }
                    }
                    let c_seg = &mut c_col[i..i + MR];
                    for t in 0..MR {
                        c_seg[t] += acc[t];
                    }
                    i += MR;
                }

                // Remainder rows (m not a multiple of 8).
                if i < i1 {
                    for p in k0..k1 {
                        let bv = b_col[p];
                        if bv == 0.0 {
                            continue;
                        }
                        let a_col = &a.col(p)[i..i1];
                        let c_seg = &mut c_col[i..i1];
                        for (cv, &av) in c_seg.iter_mut().zip(a_col) {
                            *cv += av * bv;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::Rng;

    fn rand_mat(r: &mut Rng, rows: usize, cols: usize) -> Matrix {
        Matrix::random_uniform(rows, cols, -1.0, 1.0, r)
    }

    #[test]
    fn blocked_matches_naive_square() {
        let mut rng = Rng::new(1);
        for n in [1usize, 2, 3, 7, 16, 33, 64, 100, 130] {
            let a = rand_mat(&mut rng, n, n);
            let b = rand_mat(&mut rng, n, n);
            let diff = matmul(&a, &b).max_abs_diff(&matmul_naive(&a, &b));
            assert!(diff < 1e-11, "n={n} diff={diff}");
        }
    }

    #[test]
    fn blocked_matches_naive_rectangular() {
        let mut rng = Rng::new(2);
        for (m, k, n) in [(3, 5, 7), (65, 30, 10), (128, 64, 96), (1, 100, 1)] {
            let a = rand_mat(&mut rng, m, k);
            let b = rand_mat(&mut rng, k, n);
            let diff = matmul(&a, &b).max_abs_diff(&matmul_naive(&a, &b));
            assert!(diff < 1e-11, "({m},{k},{n}) diff={diff}");
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = rand_mat(&mut rng, 20, 20);
        let i = Matrix::identity(20);
        assert!(matmul(&a, &i).max_abs_diff(&a) < 1e-15);
        assert!(matmul(&i, &a).max_abs_diff(&a) < 1e-15);
    }

    #[test]
    fn matmul_acc_adds() {
        let mut rng = Rng::new(4);
        let a = rand_mat(&mut rng, 10, 12);
        let b = rand_mat(&mut rng, 12, 8);
        let d = rand_mat(&mut rng, 10, 8);
        let got = matmul_acc(&a, &b, d.clone());
        let want = matmul(&a, &b).add(&d).unwrap();
        assert!(got.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    fn matmul_acc_chains_over_k() {
        // The block-matmul reduce pattern: one accumulator, k in-place adds.
        let mut rng = Rng::new(5);
        let terms: Vec<(Matrix, Matrix)> = (0..4)
            .map(|_| (rand_mat(&mut rng, 6, 5), rand_mat(&mut rng, 5, 7)))
            .collect();
        let mut acc = matmul(&terms[0].0, &terms[0].1);
        for (a, b) in &terms[1..] {
            acc = matmul_acc(a, b, acc);
        }
        let mut want = Matrix::zeros(6, 7);
        for (a, b) in &terms {
            want = want.add(&matmul(a, b)).unwrap();
        }
        assert!(acc.max_abs_diff(&want) < 1e-12);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn shape_mismatch_panics() {
        let a = Matrix::zeros(2, 3);
        let b = Matrix::zeros(2, 3);
        matmul(&a, &b);
    }

    #[test]
    fn property_associativity_with_vector() {
        // (A·B)·x == A·(B·x) — catches tiling index bugs cheaply.
        forall(
            "gemm associativity",
            0xAB,
            16,
            |r| {
                let n = 8 + r.next_usize(40);
                let a = rand_mat(r, n, n);
                let b = rand_mat(r, n, n);
                let x = rand_mat(r, n, 1);
                (a, b, x)
            },
            |(a, b, x)| {
                let left = matmul(&matmul(a, b), x);
                let right = matmul(a, &matmul(b, x));
                let d = left.max_abs_diff(&right);
                if d < 1e-10 {
                    Ok(())
                } else {
                    Err(format!("diff {d}"))
                }
            },
        );
    }

    #[test]
    fn property_distributes_over_add() {
        forall(
            "gemm distributivity",
            0xCD,
            12,
            |r| {
                let n = 4 + r.next_usize(28);
                (rand_mat(r, n, n), rand_mat(r, n, n), rand_mat(r, n, n))
            },
            |(a, b, c)| {
                let left = matmul(a, &b.add(c).unwrap());
                let right = matmul(a, b).add(&matmul(a, c)).unwrap();
                let d = left.max_abs_diff(&right);
                if d < 1e-10 {
                    Ok(())
                } else {
                    Err(format!("diff {d}"))
                }
            },
        );
    }
}
