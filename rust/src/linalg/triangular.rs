//! Triangular kernels for the LU-decomposition baseline (Liu et al. 2016).
//!
//! The baseline inverts A as U⁻¹·L⁻¹·P; its leaf step needs serial
//! triangular inversions and its recursion needs block-triangular inverses.

use crate::error::{Result, SpinError};
use crate::linalg::Matrix;

/// Invert a lower-triangular matrix by forward substitution.
pub fn invert_lower(l: &Matrix) -> Result<Matrix> {
    if !l.is_square() {
        return Err(SpinError::shape("invert_lower needs a square matrix"));
    }
    let n = l.rows();
    for i in 0..n {
        if l.get(i, i).abs() < f64::EPSILON * n as f64 {
            return Err(SpinError::numerical(format!(
                "zero diagonal at {i} in lower-triangular inverse"
            )));
        }
    }
    // §Perf: column-sweep forward substitution — contiguous axpy against
    // each factor column instead of a strided row walk (EXPERIMENTS.md
    // §Perf, L3-1).
    let mut inv = Matrix::zeros(n, n);
    for j in 0..n {
        inv.set(j, j, 1.0); // e_j
        for p in j..n {
            let xp = inv.get(p, j) / l.get(p, p);
            inv.set(p, j, xp);
            if xp != 0.0 && p + 1 < n {
                let l_col = &l.col(p)[p + 1..n];
                let x_col = &mut inv.col_mut(j)[p + 1..n];
                for (xi, &lv) in x_col.iter_mut().zip(l_col) {
                    *xi -= lv * xp;
                }
            }
        }
    }
    Ok(inv)
}

/// Invert an upper-triangular matrix by back substitution.
pub fn invert_upper(u: &Matrix) -> Result<Matrix> {
    if !u.is_square() {
        return Err(SpinError::shape("invert_upper needs a square matrix"));
    }
    let n = u.rows();
    for i in 0..n {
        if u.get(i, i).abs() < f64::EPSILON * n as f64 {
            return Err(SpinError::numerical(format!(
                "zero diagonal at {i} in upper-triangular inverse"
            )));
        }
    }
    // §Perf: column-sweep back substitution (see `invert_lower`).
    let mut inv = Matrix::zeros(n, n);
    for j in 0..n {
        inv.set(j, j, 1.0); // e_j
        for p in (0..=j).rev() {
            let xp = inv.get(p, j) / u.get(p, p);
            inv.set(p, j, xp);
            if xp != 0.0 && p > 0 {
                let u_col = &u.col(p)[..p];
                let x_col = &mut inv.col_mut(j)[..p];
                for (xi, &uv) in x_col.iter_mut().zip(u_col) {
                    *xi -= uv * xp;
                }
            }
        }
    }
    Ok(inv)
}

/// True if every element above the diagonal is (near) zero.
pub fn is_lower_triangular(m: &Matrix, tol: f64) -> bool {
    for j in 0..m.cols() {
        for i in 0..j.min(m.rows()) {
            if m.get(i, j).abs() > tol {
                return false;
            }
        }
    }
    true
}

/// True if every element below the diagonal is (near) zero.
pub fn is_upper_triangular(m: &Matrix, tol: f64) -> bool {
    for j in 0..m.cols() {
        for i in (j + 1)..m.rows() {
            if m.get(i, j).abs() > tol {
                return false;
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{lu_decompose, matmul};
    use crate::linalg::generate::diag_dominant;
    use crate::util::Rng;

    fn random_lower(n: usize, rng: &mut Rng) -> Matrix {
        Matrix::from_fn(n, n, |i, j| {
            if i > j {
                rng.uniform(-1.0, 1.0)
            } else if i == j {
                2.0 + rng.next_f64()
            } else {
                0.0
            }
        })
    }

    #[test]
    fn lower_inverse_correct() {
        let mut rng = Rng::new(1);
        for n in [1usize, 2, 8, 33] {
            let l = random_lower(n, &mut rng);
            let inv = invert_lower(&l).unwrap();
            assert!(is_lower_triangular(&inv, 1e-14), "inverse stays lower");
            let prod = matmul(&l, &inv);
            assert!(prod.max_abs_diff(&Matrix::identity(n)) < 1e-10, "n={n}");
        }
    }

    #[test]
    fn upper_inverse_correct() {
        let mut rng = Rng::new(2);
        for n in [1usize, 3, 16, 40] {
            let u = random_lower(n, &mut rng).transpose();
            let inv = invert_upper(&u).unwrap();
            assert!(is_upper_triangular(&inv, 1e-14));
            let prod = matmul(&inv, &u);
            assert!(prod.max_abs_diff(&Matrix::identity(n)) < 1e-10, "n={n}");
        }
    }

    #[test]
    fn rejects_zero_diagonal() {
        let mut l = Matrix::identity(4);
        l.set(2, 2, 0.0);
        assert!(invert_lower(&l).is_err());
        assert!(invert_upper(&l.transpose()).is_err());
    }

    #[test]
    fn lu_factors_invert_to_full_inverse() {
        // U⁻¹·L⁻¹·P == A⁻¹ — the identity the Liu baseline is built on.
        let mut rng = Rng::new(3);
        let a = diag_dominant(20, &mut rng);
        let f = lu_decompose(&a).unwrap();
        let li = invert_lower(&f.l()).unwrap();
        let ui = invert_upper(&f.u()).unwrap();
        let inv = matmul(&matmul(&ui, &li), &f.p());
        let prod = matmul(&a, &inv);
        assert!(prod.max_abs_diff(&Matrix::identity(20)) < 1e-9);
    }

    #[test]
    fn triangular_predicates() {
        let l = Matrix::from_vec(2, 2, vec![1.0, 5.0, 0.0, 2.0]).unwrap();
        assert!(is_lower_triangular(&l, 1e-12));
        assert!(!is_upper_triangular(&l, 1e-12));
        assert!(is_upper_triangular(&l.transpose(), 1e-12));
    }
}
