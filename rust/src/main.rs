//! `spin` — the coordinator binary. See `spin help`.

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(spin::cli::run(argv));
}
