//! Plan lowering: evaluate an optimized [`MatExpr`] DAG on the
//! partitioner-aware [`BlockMatrix`] ops.
//!
//! * Every unique node executes **at most once** — results are memoized on
//!   the node itself, so subtrees shared between plans (or a plan
//!   re-materialized later) never redo distributed work. This is the lazy
//!   equivalent of the eager API holding intermediates in variables.
//! * Sibling [`ExprOp::Quadrant`] nodes of the same child share one
//!   `breakMat` pass (the paper's Algorithm 3) through a per-executor
//!   memo, exactly like the eager `BlockMatrix::split`.
//! * Around each node's lowering the executor snapshots the cluster's
//!   metric totals and stamps a [`PlanNodeReport`] into
//!   [`crate::cluster::Metrics`] — `explain`'s *predicted* shuffle stages
//!   can be checked against what each node *actually* paid.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::blockmatrix::{Block, BlockMatrix, Quadrant};
use crate::cluster::{Cluster, PlanNodeReport, Rdd};
use crate::error::{Result, SpinError};
use crate::runtime::BlockKernels;
use crate::util::plock;

use super::{CacheManager, ExprOp, InvertOpts, MatExpr, Optimizer, OptimizerConfig};

/// Resolver for [`ExprOp::Invert`] nodes: maps a scheme name plus a
/// materialized operand to its inverse. The session layer resolves through
/// its [`crate::algos::AlgorithmRegistry`]; SPIN's recursion passes its own
/// level function.
pub type InvertFn<'f> = dyn Fn(&str, &InvertOpts, &BlockMatrix) -> Result<BlockMatrix> + 'f;

/// Evaluates optimized plans on one cluster + kernel backend.
pub struct PlanExec<'a> {
    cluster: &'a Cluster,
    kernels: &'a dyn BlockKernels,
    config: OptimizerConfig,
    /// `breakMat` output per (canonical) child node — sibling quadrant
    /// extractions reuse it instead of re-running the tagging pass.
    broken: Mutex<HashMap<u64, Rdd<(Quadrant, Block)>>>,
    /// Value-lifecycle registry (LRU budget + persist pins). `None` for
    /// algorithm-internal executors whose per-level DAGs die with the
    /// recursion frame and need no tracking.
    lifecycle: Option<&'a CacheManager>,
}

impl<'a> PlanExec<'a> {
    /// Executor with the optimizer configuration implied by the cluster's
    /// `plan_optimizer` knob.
    pub fn new(cluster: &'a Cluster, kernels: &'a dyn BlockKernels) -> Self {
        PlanExec::with_config(cluster, kernels, OptimizerConfig::from_cluster(cluster.config()))
    }

    /// Executor with an explicit rule configuration (rule ablations).
    pub fn with_config(
        cluster: &'a Cluster,
        kernels: &'a dyn BlockKernels,
        config: OptimizerConfig,
    ) -> Self {
        PlanExec {
            cluster,
            kernels,
            config,
            broken: Mutex::new(HashMap::new()),
            lifecycle: None,
        }
    }

    /// Attach the session's value-lifecycle manager: every non-source
    /// node this executor materializes is registered (and the LRU byte
    /// budget enforced) there.
    pub fn with_lifecycle(mut self, manager: &'a CacheManager) -> Self {
        self.lifecycle = Some(manager);
        self
    }

    pub fn config(&self) -> OptimizerConfig {
        self.config
    }

    /// Optimize + execute a plan that contains no `Invert` nodes.
    pub fn eval(&self, expr: &MatExpr) -> Result<BlockMatrix> {
        self.eval_with(expr, &|algo: &str, _opts: &InvertOpts, _m: &BlockMatrix| {
            Err(SpinError::config(format!(
                "plan contains an invert[{algo}] node but no inverter was supplied"
            )))
        })
    }

    /// Optimize + execute a plan, resolving `Invert` nodes through
    /// `invert`.
    pub fn eval_with(&self, expr: &MatExpr, invert: &InvertFn<'_>) -> Result<BlockMatrix> {
        // Canonicalization is memoized per node, but two threads racing
        // through a not-yet-memoized subtree would intern two distinct
        // canonical copies — and then execute the "shared" work twice.
        // The lifecycle manager's gate serializes the (cheap, driver-side)
        // optimize step across a session's concurrent jobs.
        let optimized = match self.lifecycle {
            Some(mgr) => {
                let _gate = mgr.optimize_gate();
                Optimizer::new(self.config).optimize(expr)?
            }
            None => Optimizer::new(self.config).optimize(expr)?,
        };
        self.exec_node(&optimized, invert)
    }

    fn exec_node(&self, e: &MatExpr, invert: &InvertFn<'_>) -> Result<BlockMatrix> {
        if let ExprOp::Source(m) = e.op() {
            return Ok(m.clone());
        }
        // Hold the memo slot for the whole lowering: a second evaluator of
        // a shared node (another job's worker thread) blocks here and then
        // reuses the value — exactly-once execution under concurrency.
        // Locks are only ever acquired parent→child along DAG edges, so a
        // wait cycle would require a cycle in the DAG: impossible.
        let mut slot = e.value_slot();
        if let Some(v) = (*slot).clone() {
            drop(slot);
            if let Some(mgr) = self.lifecycle {
                mgr.touch(e.id());
            }
            return Ok(v);
        }
        let out = match e.op() {
            // Handled by the early return above — and it must stay there:
            // eager sources must never reach the slot-assignment/lifecycle
            // registration below (inputs are the caller's storage, not
            // the budget's).
            ExprOp::Source(_) => unreachable!("sources return before the memo slot"),

            // Lazily-born leaves ARE session storage: produced on the
            // workers here, memoized in the slot, byte-accounted by the
            // lifecycle manager below, and re-produced bit-identically if
            // the evictor drops them.
            ExprOp::LazySource(spec) => self.measured(e, || spec.materialize(self.cluster))?,

            ExprOp::Multiply(a, b) => {
                let va = self.exec_node(a, invert)?;
                let vb = self.exec_node(b, invert)?;
                self.measured(e, || va.multiply(self.cluster, self.kernels, &vb))?
            }

            ExprOp::MultiplySub(a, b, d) => {
                let va = self.exec_node(a, invert)?;
                let vb = self.exec_node(b, invert)?;
                let vd = self.exec_node(d, invert)?;
                self.measured(e, || va.multiply_sub(self.cluster, self.kernels, &vb, &vd))?
            }

            ExprOp::Subtract(a, b) => {
                let va = self.exec_node(a, invert)?;
                let vb = self.exec_node(b, invert)?;
                self.measured(e, || va.subtract(self.cluster, self.kernels, &vb))?
            }

            ExprOp::Scale(x, s) => {
                let vx = self.exec_node(x, invert)?;
                let s = *s;
                self.measured(e, || vx.scalar_mul(self.cluster, self.kernels, s))?
            }

            ExprOp::Transpose(x) => {
                let vx = self.exec_node(x, invert)?;
                self.measured(e, || Ok(vx.transpose(self.cluster)))?
            }

            ExprOp::Invert { algo, opts, child } => {
                let vc = self.exec_node(child, invert)?;
                self.measured(e, || invert(algo, opts, &vc))?
            }

            ExprOp::Quadrant { child, which } => {
                let vc = self.exec_node(child, invert)?;
                let which = *which;
                let half = vc.nblocks() / 2;
                let bs = vc.block_size();
                let child_id = child.id();
                self.measured(e, || {
                    let broken = {
                        let mut memo = plock(&self.broken);
                        match memo.get(&child_id) {
                            Some(b) => b.clone(),
                            None => {
                                let b = vc.break_mat(self.cluster)?;
                                memo.insert(child_id, b.clone());
                                b
                            }
                        }
                    };
                    Ok(BlockMatrix::quadrant(
                        self.cluster,
                        &broken,
                        which,
                        half,
                        bs,
                    ))
                })?
            }

            ExprOp::Arrange(c11, c12, c21, c22) => {
                let v11 = self.exec_node(c11, invert)?;
                let v12 = self.exec_node(c12, invert)?;
                let v21 = self.exec_node(c21, invert)?;
                let v22 = self.exec_node(c22, invert)?;
                self.measured(e, || {
                    BlockMatrix::arrange(self.cluster, v11, v12, v21, v22)
                })?
            }
        };
        *slot = Some(out.clone());
        drop(slot);
        if let Some(mgr) = self.lifecycle {
            let rep = mgr.register(e);
            if rep.evicted > 0 {
                self.cluster.record_cache_eviction(rep.evicted, rep.bytes);
            }
        }
        Ok(out)
    }

    /// Run one node's lowering inside a metrics window and stamp the
    /// per-plan-node delta into the cluster's registry. The window reads
    /// *scope-local* totals, so a concurrent job interleaving stages on
    /// the same cluster cannot inflate this node's delta.
    fn measured(
        &self,
        e: &MatExpr,
        f: impl FnOnce() -> Result<BlockMatrix>,
    ) -> Result<BlockMatrix> {
        let before = self.cluster.metrics_totals_current();
        let out = f()?;
        let after = self.cluster.metrics_totals_current();
        let report = PlanNodeReport {
            node: format!("%{}", e.id()),
            op: e.op().name().to_string(),
            stages: after.stages.saturating_sub(before.stages),
            shuffle_stages: after.shuffle_stages.saturating_sub(before.shuffle_stages),
            shuffle_bytes: after.shuffle_bytes.saturating_sub(before.shuffle_bytes),
            driver_collects: after.driver_collects.saturating_sub(before.driver_collects),
            cse_cached: e.is_cse_cached(),
        };
        // Record before verifying so a divergence failure still leaves the
        // offending node's measured counters in the metrics registry.
        let verify = {
            let cfg = self.cluster.config();
            cfg.verify_plans && cfg.partitioner_aware
        };
        if verify {
            let check = self.verify_node(e, &report);
            self.cluster.record_plan_node(report);
            check?;
        } else {
            self.cluster.record_plan_node(report);
        }
        Ok(out)
    }

    /// The `verify_plans` debug mode: compare this node's measured metric
    /// deltas against the static verifier's predictions
    /// ([`crate::plan::predicted_exchanges`],
    /// [`crate::analysis::node_shuffle_bytes_ceiling`]) and fail the job
    /// on divergence. `Invert` windows aggregate a whole nested recursion
    /// whose own plan nodes are verified individually as they run, so
    /// they are skipped here; whole-recursion totals are covered by the
    /// analyzer's unfolded profiles and their tests.
    fn verify_node(&self, e: &MatExpr, rep: &PlanNodeReport) -> Result<()> {
        if matches!(e.op(), ExprOp::Invert { .. }) {
            return Ok(());
        }
        let predicted = super::predicted_exchanges(e.op(), true).unwrap_or(0);
        if rep.shuffle_stages != predicted {
            return Err(SpinError::plan(format!(
                "verify_plans: node %{} ({}) paid {} exchange stages, predicted {}",
                e.id(),
                e.op().name(),
                rep.shuffle_stages,
                predicted
            )));
        }
        let ceiling = crate::analysis::node_shuffle_bytes_ceiling(e.op(), e.nblocks(), e.n(), true);
        if rep.shuffle_bytes > ceiling {
            return Err(SpinError::plan(format!(
                "verify_plans: node %{} ({}) moved {} shuffle bytes, ceiling {}",
                e.id(),
                e.op().name(),
                rep.shuffle_bytes,
                ceiling
            )));
        }
        if rep.driver_collects != 0 {
            return Err(SpinError::plan(format!(
                "verify_plans: node %{} ({}) collected to the driver {} times; the \
                 partitioner-aware dataflow must never collect",
                e.id(),
                e.op().name(),
                rep.driver_collects
            )));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::linalg::{self, Matrix};
    use crate::runtime::NativeBackend;
    use crate::util::check::forall;
    use crate::util::Rng;

    fn cluster() -> Cluster {
        Cluster::new(ClusterConfig::local(4))
    }

    /// The satellite geometry: n = 128, block 16 (an 8×8 grid).
    const N: usize = 128;
    const BS: usize = 16;

    fn rand_pair(seed: u64) -> (Matrix, MatExpr) {
        let mut rng = Rng::new(seed);
        let dense = Matrix::random_uniform(N, N, -1.0, 1.0, &mut rng);
        let bm = BlockMatrix::from_dense(&dense, BS).unwrap();
        (dense, MatExpr::source(bm))
    }

    /// Evaluate `build`'s plan twice — optimized and raw — on fresh
    /// clusters, assert the results agree within `tol`, and hand both
    /// clusters to `check` for metric assertions.
    fn rule_preserves_results(
        tol: f64,
        build: impl Fn() -> MatExpr,
        check: impl Fn(&Cluster, &Cluster),
    ) -> std::result::Result<(), String> {
        let c_opt = cluster();
        let c_raw = cluster();
        let opt = PlanExec::with_config(&c_opt, &NativeBackend, OptimizerConfig::all())
            .eval(&build())
            .map_err(|e| e.to_string())?;
        let raw = PlanExec::with_config(&c_raw, &NativeBackend, OptimizerConfig::none())
            .eval(&build())
            .map_err(|e| e.to_string())?;
        let diff = opt
            .to_dense()
            .unwrap()
            .max_abs_diff(&raw.to_dense().unwrap());
        if diff > tol {
            return Err(format!("optimized vs raw diff {diff:.3e} > {tol:.0e}"));
        }
        check(&c_opt, &c_raw);
        Ok(())
    }

    #[test]
    fn fusion_rule_preserves_results_and_drops_a_stage() {
        forall(
            "fusion ≡ multiply+subtract at n=128/bs=16",
            0xF0,
            4,
            |r| r.next_u64(),
            |&seed| {
                let (_, a) = rand_pair(seed ^ 1);
                let (_, b) = rand_pair(seed ^ 2);
                let (_, d) = rand_pair(seed ^ 3);
                rule_preserves_results(
                    0.0, // multiply_sub is bit-identical to multiply+subtract
                    || a.multiply(&b).unwrap().subtract(&d).unwrap(),
                    |c_opt, c_raw| {
                        let (mo, mr) = (c_opt.metrics(), c_raw.metrics());
                        assert!(mo.method("subtract").is_none(), "subtract fused away");
                        assert!(mr.method("subtract").is_some());
                        assert!(mo.stages().len() < mr.stages().len());
                    },
                )
            },
        );
    }

    #[test]
    fn transpose_pushdown_preserves_results_and_saves_a_transpose() {
        forall(
            "pushdown ≡ raw transposes at n=128/bs=16",
            0xF1,
            4,
            |r| r.next_u64(),
            |&seed| {
                let (_, a) = rand_pair(seed ^ 4);
                let (_, b) = rand_pair(seed ^ 5);
                rule_preserves_results(
                    1e-12, // same products/sums, factors commuted
                    || a.transpose().multiply(&b).unwrap().transpose(),
                    |c_opt, c_raw| {
                        let to = c_opt.metrics().method("transpose").unwrap().calls;
                        let tr = c_raw.metrics().method("transpose").unwrap().calls;
                        assert!(to < tr, "pushdown must save a transpose: {to} vs {tr}");
                    },
                )
            },
        );
    }

    #[test]
    fn scalar_folding_preserves_results_and_drops_a_stage() {
        forall(
            "scale folding ≡ nested scales at n=128/bs=16",
            0xF2,
            4,
            |r| r.next_u64(),
            |&seed| {
                let (_, a) = rand_pair(seed ^ 6);
                rule_preserves_results(
                    0.0, // (−1)·(−1)·x and the folded identity agree bitwise
                    || a.scale(-1.0).scale(-1.0),
                    |c_opt, c_raw| {
                        assert!(c_opt.metrics().method("scalar").is_none());
                        assert_eq!(c_raw.metrics().method("scalar").unwrap().calls, 2);
                    },
                )
            },
        );
    }

    #[test]
    fn cse_executes_shared_subtree_exactly_once() {
        forall(
            "CSE single execution at n=128/bs=16",
            0xF3,
            4,
            |r| r.next_u64(),
            |&seed| {
                let (_, a) = rand_pair(seed ^ 7);
                let (_, b) = rand_pair(seed ^ 8);
                rule_preserves_results(
                    0.0, // identical products either way
                    || {
                        // Structurally identical products built twice.
                        let m1 = a.multiply(&b).unwrap();
                        let m2 = a.multiply(&b).unwrap();
                        m1.multiply(&m2).unwrap()
                    },
                    |c_opt, c_raw| {
                        // Each multiply pays exactly 2 exchange stages, so
                        // stage counts expose how many products really ran:
                        // CSE = 2 multiplies (shared + root), raw = 3.
                        let so = c_opt.metrics().method("multiply").unwrap().shuffle_stages;
                        let sr = c_raw.metrics().method("multiply").unwrap().shuffle_stages;
                        assert_eq!(so, 4, "optimized: shared product + root");
                        assert_eq!(sr, 6, "raw: duplicate product executes twice");
                    },
                )
            },
        );
    }

    #[test]
    fn plan_matches_dense_algebra_end_to_end() {
        let c = cluster();
        let (da, a) = rand_pair(21);
        let (db, b) = rand_pair(22);
        let (dd, d) = rand_pair(23);
        // ((A·B − D)ᵀ)·2 − A
        let expr = a
            .multiply(&b)
            .unwrap()
            .subtract(&d)
            .unwrap()
            .transpose()
            .scale(2.0)
            .subtract(&a)
            .unwrap();
        let exec = PlanExec::with_config(&c, &NativeBackend, OptimizerConfig::all());
        let got = exec.eval(&expr).unwrap().to_dense().unwrap();
        let want = linalg::matmul(&da, &db)
            .sub(&dd)
            .unwrap()
            .transpose()
            .scale(2.0)
            .sub(&da)
            .unwrap();
        assert!(got.max_abs_diff(&want) < 1e-10);
        // Per-plan-node metrics were stamped.
        let nodes = c.metrics();
        assert!(!nodes.plan_nodes().is_empty());
        assert!(nodes.plan_nodes().iter().any(|p| p.op == "multiply_sub"));
    }

    #[test]
    fn memoized_value_survives_re_evaluation() {
        let c = cluster();
        let (_, a) = rand_pair(31);
        let (_, b) = rand_pair(32);
        let expr = a.multiply(&b).unwrap();
        let exec = PlanExec::with_config(&c, &NativeBackend, OptimizerConfig::all());
        let first = exec.eval(&expr).unwrap();
        let stages_after_first = c.metrics().stages().len();
        let second = exec.eval(&expr).unwrap();
        assert_eq!(
            c.metrics().stages().len(),
            stages_after_first,
            "re-evaluating a materialized plan must be free"
        );
        assert_eq!(
            first
                .to_dense()
                .unwrap()
                .max_abs_diff(&second.to_dense().unwrap()),
            0.0
        );
    }

    #[test]
    fn lazy_split_shares_one_break_mat_and_arrange_round_trips() {
        let c = cluster();
        let mut rng = Rng::new(41);
        let dense = Matrix::random_uniform(16, 16, -1.0, 1.0, &mut rng);
        let a = MatExpr::source(BlockMatrix::from_dense(&dense, 4).unwrap());
        let (c11, c12, c21, c22) = a.split().unwrap();
        let back = MatExpr::arrange(&c11, &c12, &c21, &c22).unwrap();
        let exec = PlanExec::with_config(&c, &NativeBackend, OptimizerConfig::all());
        let got = exec.eval(&back).unwrap().to_dense().unwrap();
        assert!(got.max_abs_diff(&dense) < 1e-15);
        let m = c.metrics();
        assert_eq!(
            m.method("breakMat").unwrap().calls,
            1,
            "four quadrants share one breakMat pass"
        );
        assert_eq!(m.driver_collects(), 0);
    }

    #[test]
    fn lazy_source_materializes_once_and_regenerates_after_eviction() {
        use crate::config::GeneratorKind;
        use crate::plan::SourceSpec;
        let c = cluster();
        let spec = SourceSpec::Generated {
            n: 64,
            block_size: 16,
            seed: 0xD00D,
            generator: GeneratorKind::DiagDominant,
        };
        let leaf = MatExpr::lazy_source(spec).unwrap();
        let exec = PlanExec::with_config(&c, &NativeBackend, OptimizerConfig::all());
        let first = exec.eval(&leaf).unwrap().to_dense().unwrap();
        // Eager twin is bit-identical.
        let mut job = crate::config::JobConfig::new(64, 16);
        job.seed = 0xD00D;
        let eager = BlockMatrix::random(&job).unwrap().to_dense().unwrap();
        assert_eq!(first.max_abs_diff(&eager), 0.0);
        // Second read is memoized: no new generate stage.
        assert_eq!(c.metrics().method("generate").unwrap().calls, 1);
        exec.eval(&leaf).unwrap();
        assert_eq!(c.metrics().method("generate").unwrap().calls, 1);
        // Evict and re-read: regenerated on the workers, same bits.
        assert!(leaf.evict_value());
        let second = exec.eval(&leaf).unwrap().to_dense().unwrap();
        assert_eq!(c.metrics().method("generate").unwrap().calls, 2);
        assert_eq!(first.max_abs_diff(&second), 0.0);
        assert_eq!(c.metrics().driver_collects(), 0);
    }

    #[test]
    fn invert_node_needs_an_inverter() {
        let c = cluster();
        let (_, a) = rand_pair(51);
        let exec = PlanExec::with_config(&c, &NativeBackend, OptimizerConfig::all());
        let err = exec.eval(&a.invert("spin")).unwrap_err();
        assert!(err.to_string().contains("no inverter"), "{err}");
    }

    #[test]
    fn concurrent_evaluators_share_one_execution() {
        use crate::plan::CacheManager;
        // Two threads race to materialize the SAME plan on one cluster —
        // the memo-slot lock plus the optimize gate must make the shared
        // product execute exactly once (2 exchange stages, not 4).
        let c = cluster();
        let mgr = CacheManager::new(0);
        let (_, a) = rand_pair(61);
        let (_, b) = rand_pair(62);
        let expr = a.multiply(&b).unwrap();
        let exec = PlanExec::with_config(&c, &NativeBackend, OptimizerConfig::all())
            .with_lifecycle(&mgr);
        let barrier = std::sync::Barrier::new(2);
        let (d1, d2) = std::thread::scope(|scope| {
            let t1 = scope.spawn(|| {
                barrier.wait();
                exec.eval(&expr).unwrap().to_dense().unwrap()
            });
            let t2 = scope.spawn(|| {
                barrier.wait();
                exec.eval(&expr).unwrap().to_dense().unwrap()
            });
            (t1.join().unwrap(), t2.join().unwrap())
        });
        assert_eq!(d1.max_abs_diff(&d2), 0.0);
        let m = c.metrics();
        assert_eq!(
            m.method("multiply").unwrap().shuffle_stages,
            2,
            "shared node must execute exactly once"
        );
    }

    #[test]
    fn budget_evicts_and_recompute_is_bit_identical() {
        use crate::plan::CacheManager;
        let c = cluster();
        // Working set: product + fused node at 128x128 doubles = 128 KiB
        // each; a budget of one value forces evictions mid-plan.
        let mgr = CacheManager::new((N * N * 8) as u64);
        let (_, a) = rand_pair(71);
        let (_, b) = rand_pair(72);
        let (_, d) = rand_pair(73);
        let expr = a
            .multiply(&b)
            .unwrap()
            .subtract(&d)
            .unwrap()
            .transpose()
            .scale(2.0);
        let exec = PlanExec::with_config(&c, &NativeBackend, OptimizerConfig::all())
            .with_lifecycle(&mgr);
        let first = exec.eval(&expr).unwrap().to_dense().unwrap();
        assert!(
            c.metrics().cache_evictions() > 0,
            "half-working-set budget must evict"
        );
        let stats = mgr.stats();
        assert!(stats.budget_bytes.is_some());
        assert!(stats.resident_bytes <= (N * N * 8) as u64);
        assert!(stats.evictions > 0);
        // Evict everything that is left and re-read: same bits.
        let mut stack = vec![expr.clone()];
        let mut seen = std::collections::HashSet::new();
        while let Some(e) = stack.pop() {
            if seen.insert(e.id()) {
                e.evict_value();
                if let Some(canon) = e.canonical_for(OptimizerConfig::all()) {
                    stack.push(canon);
                }
                stack.extend(e.children());
            }
        }
        let second = exec.eval(&expr).unwrap().to_dense().unwrap();
        assert_eq!(
            first.max_abs_diff(&second),
            0.0,
            "recompute after eviction must be bit-identical"
        );
    }
}
