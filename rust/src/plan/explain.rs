//! `explain()` rendering: one SSA-style line per plan node with its
//! predicted shuffle cost and its cache/lifecycle decision, plus a
//! summary footer.
//!
//! The renderer walks the (optimized) DAG in deterministic postorder, so
//! shared subtrees print once and are referenced by `%k` — a CSE-marked
//! node renders as `cache(...)`, making the optimizer's automatic cache
//! insertion visible. Each non-source node also shows its predicted
//! resident bytes and its lifecycle state at render time: `[cached]`
//! (value memoized right now), `[pinned]` (persisted — the LRU evictor
//! must skip it), or `[evictable]` (subject to the session's
//! `cache_budget_bytes`). Sources render as `input` — their storage
//! belongs to the caller, not the evictor.

use std::collections::HashMap;

use crate::util::fmt;

use super::{ExprOp, MatExpr};

/// Predicted shuffle exchanges one node pays under the partitioner-aware
/// dataflow: `multiply`/`multiply_sub` route one shuffle round recorded as
/// two exchange stages (one per operand stream); every other op is narrow.
/// `Invert` is recursive and predicted separately (`None`).
pub fn predicted_exchanges(op: &ExprOp, partitioner_aware: bool) -> Option<usize> {
    match op {
        ExprOp::Invert { .. } => None,
        // Lazy sources generate (or load) one block per partition: narrow.
        ExprOp::LazySource(_) => Some(0),
        ExprOp::Multiply(..) | ExprOp::MultiplySub(..) => Some(2),
        // On the legacy dataflow even "narrow" ops cogroup or round-trip
        // the driver; flag them as one exchange so the prediction stays
        // honest when `partitioner_aware = false`.
        ExprOp::Subtract(..) if !partitioner_aware => Some(1),
        _ => Some(0),
    }
}

/// Render an (optimized) plan. `partitioner_aware` selects the shuffle
/// prediction model — pass the owning cluster's setting.
pub fn render_plan(root: &MatExpr, partitioner_aware: bool) -> String {
    render_plan_sized(root, partitioner_aware, None)
}

/// [`render_plan`] with an explicit payload block size for the resident-
/// bytes column. `spin explain` renders plan *shapes* over unit blocks
/// (explaining n = 65536 must not allocate an n×n matrix), so it passes
/// the real block size here; `None` reads each node's own geometry.
pub fn render_plan_sized(
    root: &MatExpr,
    partitioner_aware: bool,
    block_size_override: Option<usize>,
) -> String {
    let mut r = Renderer {
        ids: HashMap::new(),
        lines: Vec::new(),
        partitioner_aware,
        block_size_override,
        exchanges: 0,
        cached: 0,
        fused: 0,
        recursive: 0,
        resident: 0,
        pinned: 0,
    };
    let root_id = r.walk(root);
    let mut out = String::new();
    for line in &r.lines {
        out.push_str(line);
        out.push('\n');
    }
    out.push_str(&format!(
        "plan: {} nodes · result %{root_id} · predicted {} exchange stage(s){} · {} fused multiply_sub · {} cache point(s) (CSE) · predicted resident ≤ {} · pinned {}\n",
        r.lines.len(),
        r.exchanges,
        if r.recursive > 0 {
            format!(" + {} recursive inversion(s)", r.recursive)
        } else {
            String::new()
        },
        r.fused,
        r.cached,
        fmt::bytes(r.resident),
        fmt::bytes(r.pinned),
    ));
    out
}

struct Renderer {
    /// Node id → display index (postorder).
    ids: HashMap<u64, usize>,
    lines: Vec<String>,
    partitioner_aware: bool,
    block_size_override: Option<usize>,
    exchanges: usize,
    cached: usize,
    fused: usize,
    recursive: usize,
    /// Sum of non-source node payload bytes: the plan's worst-case
    /// resident set if nothing is ever evicted.
    resident: u64,
    /// Bytes of currently-pinned (`persist()`ed) node values — what the
    /// LRU evictor must step around.
    pinned: u64,
}

impl Renderer {
    /// Predicted value bytes of one node under the rendering block size.
    fn node_bytes(&self, e: &MatExpr) -> u64 {
        let n = (e.nblocks() * self.block_size_override.unwrap_or(e.block_size())) as u64;
        n * n * 8
    }

    fn walk(&mut self, e: &MatExpr) -> usize {
        if let Some(&n) = self.ids.get(&e.id()) {
            return n;
        }
        let child_nums: Vec<usize> = e.children().iter().map(|c| self.walk(c)).collect();
        let n = self.ids.len();
        self.ids.insert(e.id(), n);

        let mut desc = describe(e.op(), &child_nums);
        if e.is_cse_cached() {
            desc = format!("cache({desc})");
            self.cached += 1;
        }
        if matches!(e.op(), ExprOp::MultiplySub(..)) {
            self.fused += 1;
        }
        let cost = match predicted_exchanges(e.op(), self.partitioner_aware) {
            Some(0) => "narrow".to_string(),
            Some(k) => {
                self.exchanges += k;
                format!("{k} exchange stages")
            }
            None => {
                self.recursive += 1;
                "recursive".to_string()
            }
        };
        let mem = if matches!(e.op(), ExprOp::Source(_)) {
            "input".to_string()
        } else {
            let bytes = self.node_bytes(e);
            self.resident += bytes;
            let state = if e.is_pinned() {
                self.pinned += bytes;
                "[pinned]"
            } else if e.cached_value().is_some() {
                "[cached]"
            } else {
                "[evictable]"
            };
            format!("~{} {state}", fmt::bytes(bytes))
        };
        self.lines
            .push(format!("%{n:<3} = {desc:<44} shuffle: {cost:<17} mem: {mem}"));
        n
    }
}

fn describe(op: &ExprOp, kids: &[usize]) -> String {
    let refs = |i: usize| format!("%{}", kids[i]);
    match op {
        // Grid only: the plan's shape depends on the split count, not the
        // block payload size (which the explain header already states).
        ExprOp::Source(m) => format!("source[{0}x{0} grid]", m.nblocks()),
        ExprOp::LazySource(spec) => {
            format!("lazy_source[{0}x{0} grid · {1}]", spec.nblocks(), spec.label())
        }
        ExprOp::Multiply(..) => format!("multiply {} {}", refs(0), refs(1)),
        ExprOp::MultiplySub(..) => format!(
            "multiply_sub {} {} {}   (fused A·B − D)",
            refs(0),
            refs(1),
            refs(2)
        ),
        ExprOp::Subtract(..) => format!("subtract {} {}", refs(0), refs(1)),
        ExprOp::Scale(_, s) => format!("scale {} × {s}", refs(0)),
        ExprOp::Transpose(..) => format!("transpose {}", refs(0)),
        ExprOp::Invert { algo, opts, .. } => {
            // Default opts keep the seed format so pinned golden plans stay
            // stable; explicit iterative knobs render inline.
            let mut tag = algo.clone();
            if let Some(tol) = opts.tolerance {
                tag.push_str(&format!(" tol={tol:e}"));
            }
            if let Some(iters) = opts.max_iters {
                tag.push_str(&format!(" max_iters={iters}"));
            }
            format!("invert[{tag}] {}", refs(0))
        }
        ExprOp::Quadrant { which, .. } => {
            format!("quadrant[{}] {}", which.label(), refs(0))
        }
        ExprOp::Arrange(..) => format!(
            "arrange {} {} {} {}",
            refs(0),
            refs(1),
            refs(2),
            refs(3)
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blockmatrix::BlockMatrix;
    use crate::plan::{Optimizer, OptimizerConfig};

    fn src(nb: usize, bs: usize) -> MatExpr {
        MatExpr::source(BlockMatrix::zeros(nb, bs).unwrap())
    }

    #[test]
    fn renders_each_node_once_with_predictions() {
        let (a, b, d) = (src(2, 4), src(2, 4), src(2, 4));
        let expr = a.multiply(&b).unwrap().subtract(&d).unwrap();
        let opt = Optimizer::new(OptimizerConfig::all())
            .optimize(&expr)
            .unwrap();
        let text = render_plan(&opt, true);
        assert!(text.contains("multiply_sub"), "{text}");
        assert!(text.contains("2 exchange stages"), "{text}");
        assert!(text.contains("source[2x2 grid]"), "{text}");
        assert!(text.contains("predicted 2 exchange stage(s)"), "{text}");
        assert!(text.contains("1 fused multiply_sub"), "{text}");
    }

    #[test]
    fn shared_nodes_render_as_cache_points() {
        let (a, b, c) = (src(2, 4), src(2, 4), src(2, 4));
        let shared = a.multiply(&b).unwrap();
        let root = shared
            .multiply(&c)
            .unwrap()
            .subtract(&shared.transpose())
            .unwrap();
        let opt = Optimizer::new(OptimizerConfig::all())
            .optimize(&root)
            .unwrap();
        let text = render_plan(&opt, true);
        assert!(text.contains("cache(multiply"), "{text}");
        assert!(text.contains("cache point(s) (CSE)"), "{text}");
        // The shared product appears exactly once.
        assert_eq!(text.matches("cache(multiply").count(), 1, "{text}");
    }

    #[test]
    fn invert_nodes_are_marked_recursive() {
        let a = src(2, 4);
        let text = render_plan(&a.invert("spin"), true);
        assert!(text.contains("invert[spin]"), "{text}");
        assert!(text.contains("shuffle: recursive"), "{text}");
        assert!(text.contains("recursive inversion(s)"), "{text}");
    }

    /// Golden output: the exact rendering of one fused plan, including
    /// the cache-decision column. A change to any column is a deliberate
    /// format change and must update this literal.
    #[test]
    fn golden_output_fused_plan() {
        let (a, b, d) = (src(2, 4), src(2, 4), src(2, 4));
        let expr = a.multiply(&b).unwrap().subtract(&d).unwrap();
        let opt = Optimizer::new(OptimizerConfig::all())
            .optimize(&expr)
            .unwrap();
        let text = render_plan(&opt, true);
        let want = concat!(
            "%0   = source[2x2 grid]                             shuffle: narrow            mem: input\n",
            "%1   = source[2x2 grid]                             shuffle: narrow            mem: input\n",
            "%2   = source[2x2 grid]                             shuffle: narrow            mem: input\n",
            "%3   = multiply_sub %0 %1 %2   (fused A·B − D)      shuffle: 2 exchange stages mem: ~512 B [evictable]\n",
            "plan: 4 nodes · result %3 · predicted 2 exchange stage(s) · 1 fused multiply_sub · 0 cache point(s) (CSE) · predicted resident ≤ 512 B · pinned 0 B\n",
        );
        assert_eq!(text, want);
    }

    #[test]
    fn pinned_bytes_surface_in_the_footer() {
        let (a, b) = (src(2, 4), src(2, 4));
        let expr = a.multiply(&b).unwrap();
        let opt = Optimizer::new(OptimizerConfig::all())
            .optimize(&expr)
            .unwrap();
        opt.set_value(BlockMatrix::zeros(2, 4).unwrap());
        opt.set_pinned(true);
        let text = render_plan(&opt, true);
        assert!(text.contains("[pinned]"), "{text}");
        assert!(text.contains("pinned 512 B"), "{text}");
        opt.set_pinned(false);
    }

    #[test]
    fn lazy_sources_render_spec_and_narrow_cost() {
        use crate::config::GeneratorKind;
        use crate::plan::SourceSpec;
        let leaf = MatExpr::lazy_source(SourceSpec::Generated {
            n: 16,
            block_size: 4,
            seed: 9,
            generator: GeneratorKind::Spd,
        })
        .unwrap();
        let root = leaf.multiply(&leaf).unwrap();
        let opt = Optimizer::new(OptimizerConfig::all())
            .optimize(&root)
            .unwrap();
        let text = render_plan(&opt, true);
        assert!(text.contains("lazy_source[4x4 grid · seed 9 · spd]"), "{text}");
        // Unlike eager sources, lazy leaves are tracked session storage.
        assert!(text.contains("[evictable]"), "{text}");
        let store = MatExpr::lazy_source(SourceSpec::Store {
            dir: std::path::PathBuf::from("/data/a"),
            nblocks: 2,
            block_size: 4,
            store_id: None,
        })
        .unwrap();
        let text = render_plan(&store, true);
        assert!(text.contains("store /data/a"), "{text}");
        assert!(text.contains("shuffle: narrow"), "{text}");
    }

    #[test]
    fn lifecycle_states_annotate_nodes() {
        let (a, b) = (src(2, 4), src(2, 4));
        let expr = a.multiply(&b).unwrap();
        let opt = Optimizer::new(OptimizerConfig::all())
            .optimize(&expr)
            .unwrap();
        assert!(render_plan(&opt, true).contains("[evictable]"));
        // A memoized value renders as [cached]…
        opt.set_value(BlockMatrix::zeros(2, 4).unwrap());
        assert!(render_plan(&opt, true).contains("[cached]"));
        // …and a persisted one as [pinned] (pin wins over cached).
        opt.set_pinned(true);
        assert!(render_plan(&opt, true).contains("[pinned]"));
        opt.set_pinned(false);
        assert!(opt.evict_value());
        assert!(render_plan(&opt, true).contains("[evictable]"));
    }

    #[test]
    fn block_size_override_scales_resident_prediction() {
        let a = src(4, 1); // unit payload, the `spin explain` shape trick
        let expr = a.multiply(&a).unwrap();
        let opt = Optimizer::new(OptimizerConfig::all())
            .optimize(&expr)
            .unwrap();
        // 4 blocks of 64x64 → n = 256 → 512 KiB per node value.
        let text = render_plan_sized(&opt, true, Some(64));
        assert!(text.contains("~512.0 KiB"), "{text}");
        // Without the override the unit geometry is tiny.
        let text = render_plan(&opt, true);
        assert!(text.contains("~128 B"), "{text}");
    }
}
