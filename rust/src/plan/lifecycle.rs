//! Plan-value lifecycle: the LRU byte-budget evictor that replaces the
//! old pin-forever memoization.
//!
//! ## The lifecycle contract
//!
//! * A plan node's materialized value lives in the node itself (so shared
//!   subtrees still share one execution), but the session's
//!   [`CacheManager`] tracks every non-source value it materializes:
//!   node id → approximate payload bytes + last-use tick.
//! * The manager holds only [`Weak`] references — values are
//!   **ref-counted by the DAG**: when the last handle to a sub-plan drops,
//!   its `Arc<ExprNode>`s (and their block payloads) free themselves, and
//!   the manager merely forgets the dead entry. The manager never extends
//!   a value's lifetime.
//! * With `ClusterConfig::cache_budget_bytes > 0`, materializing a node
//!   that pushes the tracked resident total over budget evicts
//!   least-recently-used values until it fits. Eviction clears the node's
//!   memo slot; a later read recomputes from its children (bit-identical —
//!   the whole pipeline is deterministic), so eviction is always safe and
//!   never changes results.
//! * [`crate::session::DistMatrix::persist`] pins a value (the evictor
//!   skips pinned nodes); `unpersist` unpins and releases it immediately.
//! * In-flight values are protected structurally: the executor clones a
//!   child's blocks out of the memo slot before using them, and the
//!   evictor only `try_lock`s a slot — a node being written or read at
//!   this instant is simply skipped this pass.

use std::collections::{HashMap, HashSet};
use std::sync::atomic::Ordering;
use std::sync::{Mutex, Weak};

use crate::util::plock;

use super::{ExprNode, MatExpr};

/// What one enforcement pass evicted (recorded into
/// `cluster::Metrics::record_cache_eviction` by the caller).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct EvictionReport {
    /// Values dropped.
    pub evicted: usize,
    /// Bytes released.
    pub bytes: u64,
}

/// Point-in-time view of the manager's bookkeeping.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CacheStats {
    /// Bytes of tracked, still-live memoized values.
    pub resident_bytes: u64,
    /// Bytes of those values pinned by `persist()` — the evictor must
    /// step around them, and they do **not** count against the budget
    /// (the budget governs the evictable set; see `enforce`).
    pub pinned_bytes: u64,
    /// Tracked live entries.
    pub entries: usize,
    /// Configured budget (`None` = unlimited).
    pub budget_bytes: Option<u64>,
    /// Values evicted over this manager's lifetime.
    pub evictions: usize,
    /// Bytes released by those evictions.
    pub evicted_bytes: u64,
}

struct Entry {
    node: Weak<ExprNode>,
    bytes: u64,
    last_use: u64,
}

struct Inner {
    budget: Option<u64>,
    tick: u64,
    entries: HashMap<u64, Entry>,
    resident: u64,
    evictions: usize,
    evicted_bytes: u64,
}

/// Session-owned registry of materialized plan-node values with LRU
/// byte-budget eviction. Shared by every plan the session (or service)
/// executes, so the budget governs the whole application's resident set.
pub struct CacheManager {
    inner: Mutex<Inner>,
    /// Serializes plan canonicalization across a session's concurrent
    /// jobs — see `PlanExec::eval_with`.
    optimize_gate: Mutex<()>,
}

impl CacheManager {
    /// `budget_bytes = 0` means unlimited (track for stats, never evict).
    pub fn new(budget_bytes: u64) -> Self {
        CacheManager {
            inner: Mutex::new(Inner {
                budget: (budget_bytes > 0).then_some(budget_bytes),
                tick: 0,
                entries: HashMap::new(),
                resident: 0,
                evictions: 0,
                evicted_bytes: 0,
            }),
            optimize_gate: Mutex::new(()),
        }
    }

    /// Guard serializing the optimize step of concurrent materializations.
    pub(crate) fn optimize_gate(&self) -> std::sync::MutexGuard<'_, ()> {
        plock(&self.optimize_gate)
    }

    /// Track a freshly materialized node value and enforce the budget.
    /// Returns what the enforcement pass evicted so the caller can stamp
    /// it into the cluster metrics.
    pub(crate) fn register(&self, e: &MatExpr) -> EvictionReport {
        let bytes = e.approx_result_bytes();
        let mut inner = plock(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(old) = inner.entries.insert(
            e.id(),
            Entry {
                node: MatExpr::downgrade(e),
                bytes,
                last_use: tick,
            },
        ) {
            inner.resident = inner.resident.saturating_sub(old.bytes);
        }
        inner.resident += bytes;
        enforce(&mut inner)
    }

    /// Bump a node's recency (memo hit).
    pub(crate) fn touch(&self, id: u64) {
        let mut inner = plock(&self.inner);
        inner.tick += 1;
        let tick = inner.tick;
        if let Some(entry) = inner.entries.get_mut(&id) {
            entry.last_use = tick;
        }
    }

    /// Stop tracking a node (its value was released explicitly, e.g. by
    /// `unpersist`). Returns the bytes the entry accounted for.
    pub(crate) fn forget(&self, id: u64) -> u64 {
        let mut inner = plock(&self.inner);
        match inner.entries.remove(&id) {
            Some(entry) => {
                inner.resident = inner.resident.saturating_sub(entry.bytes);
                entry.bytes
            }
            None => 0,
        }
    }

    pub fn stats(&self) -> CacheStats {
        let mut inner = plock(&self.inner);
        purge_dead(&mut inner);
        CacheStats {
            resident_bytes: inner.resident,
            pinned_bytes: pinned_bytes(&inner),
            entries: inner.entries.len(),
            budget_bytes: inner.budget,
            evictions: inner.evictions,
            evicted_bytes: inner.evicted_bytes,
        }
    }
}

/// Bytes of tracked values whose nodes are currently pinned.
fn pinned_bytes(inner: &Inner) -> u64 {
    inner
        .entries
        .values()
        .filter_map(|entry| entry.node.upgrade().map(|node| (node, entry.bytes)))
        .filter(|(node, _)| node.pinned.load(Ordering::Relaxed))
        .map(|(_, bytes)| bytes)
        .sum()
}

/// Drop entries whose DAG died (every handle released its `Arc`): their
/// payloads are already freed, only the bookkeeping remains.
fn purge_dead(inner: &mut Inner) {
    let mut freed = 0u64;
    inner.entries.retain(|_, entry| {
        if entry.node.strong_count() > 0 {
            true
        } else {
            freed += entry.bytes;
            false
        }
    });
    inner.resident = inner.resident.saturating_sub(freed);
}

/// Evict least-recently-used, unpinned values until the **evictable**
/// total (resident minus pinned) fits the budget. Pinned bytes do not
/// count against the budget: `persist()` is a caller's promise that the
/// value stays resident, so charging it would make `pinned ≥ budget`
/// evict every unpinned value on every pass and thrash recomputation.
/// Best-effort: a node whose memo slot is momentarily locked (being read
/// or written) **stays tracked** and is skipped for the rest of this
/// pass — a later enforcement retries it, so the accounting never
/// diverges from the slots.
fn enforce(inner: &mut Inner) -> EvictionReport {
    let mut report = EvictionReport::default();
    let Some(budget) = inner.budget else {
        return report;
    };
    if inner.resident <= budget {
        return report;
    }
    purge_dead(inner);
    let pinned = pinned_bytes(inner);
    let mut busy: HashSet<u64> = HashSet::new();
    while inner.resident.saturating_sub(pinned) > budget {
        // LRU candidate among evictable entries not yet found busy.
        let mut victim: Option<(u64, u64)> = None; // (id, last_use)
        for (&id, entry) in &inner.entries {
            if busy.contains(&id) {
                continue;
            }
            let Some(node) = entry.node.upgrade() else {
                continue;
            };
            if node.pinned.load(Ordering::Relaxed) {
                continue;
            }
            if victim.map(|(_, lu)| entry.last_use < lu).unwrap_or(true) {
                victim = Some((id, entry.last_use));
            }
        }
        let Some((id, _)) = victim else { break };
        let node = inner.entries.get(&id).and_then(|e| e.node.upgrade());
        match node {
            Some(node) => match node.value.try_lock() {
                Ok(mut slot) => {
                    if let Some(entry) = inner.entries.remove(&id) {
                        inner.resident = inner.resident.saturating_sub(entry.bytes);
                        if slot.take().is_some() {
                            report.evicted += 1;
                            report.bytes += entry.bytes;
                        }
                    }
                }
                // In use right now: keep it tracked, try another victim.
                Err(_) => {
                    busy.insert(id);
                }
            },
            None => {
                if let Some(entry) = inner.entries.remove(&id) {
                    inner.resident = inner.resident.saturating_sub(entry.bytes);
                }
            }
        }
    }
    inner.evictions += report.evicted;
    inner.evicted_bytes += report.bytes;
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blockmatrix::BlockMatrix;
    use crate::plan::ExprOp;

    fn leafy(nb: usize, bs: usize) -> MatExpr {
        // A non-source node (sources are never tracked): transpose of a
        // zero source, with a value planted by hand.
        let src = MatExpr::source(BlockMatrix::zeros(nb, bs).unwrap());
        let t = src.transpose();
        t.set_value(BlockMatrix::zeros(nb, bs).unwrap());
        t
    }

    #[test]
    fn unlimited_budget_tracks_but_never_evicts() {
        let mgr = CacheManager::new(0);
        let a = leafy(2, 4);
        let rep = mgr.register(&a);
        assert_eq!(rep, EvictionReport::default());
        let stats = mgr.stats();
        assert_eq!(stats.entries, 1);
        assert_eq!(stats.resident_bytes, a.approx_result_bytes());
        assert_eq!(stats.budget_bytes, None);
        assert!(a.cached_value().is_some());
    }

    #[test]
    fn over_budget_evicts_lru_first() {
        // Each 2x4 node holds 8x8 doubles = 512 bytes; budget fits two.
        let mgr = CacheManager::new(1024);
        let (a, b, c) = (leafy(2, 4), leafy(2, 4), leafy(2, 4));
        assert_eq!(mgr.register(&a), EvictionReport::default());
        assert_eq!(mgr.register(&b), EvictionReport::default());
        mgr.touch(a.id()); // a is now more recent than b
        let rep = mgr.register(&c);
        assert_eq!(rep.evicted, 1);
        assert_eq!(rep.bytes, 512);
        assert!(b.cached_value().is_none(), "LRU (b) evicted");
        assert!(a.cached_value().is_some());
        assert!(c.cached_value().is_some());
        let stats = mgr.stats();
        assert_eq!(stats.evictions, 1);
        assert_eq!(stats.evicted_bytes, 512);
        assert!(stats.resident_bytes <= 1024);
    }

    #[test]
    fn pinned_values_survive_enforcement() {
        let mgr = CacheManager::new(512);
        let a = leafy(2, 4);
        a.set_pinned(true);
        mgr.register(&a);
        let b = leafy(2, 4);
        let c = leafy(2, 4);
        // Pinned bytes (512) do NOT count against the 512-byte budget:
        // one unpinned value (b, 512 evictable) still fits, so nothing
        // thrashes even though pinned ≥ budget.
        let rep = mgr.register(&b);
        assert_eq!(rep, EvictionReport::default(), "no thrash: {rep:?}");
        assert!(b.cached_value().is_some());
        // A second unpinned value pushes the evictable total over budget:
        // the LRU unpinned value (b) goes, the pinned one never does.
        let rep = mgr.register(&c);
        assert!(a.cached_value().is_some(), "pinned value must survive");
        assert_eq!(rep.evicted, 1);
        assert!(b.cached_value().is_none(), "LRU unpinned evicted");
        assert!(c.cached_value().is_some());
        let stats = mgr.stats();
        assert_eq!(stats.pinned_bytes, 512);
        assert_eq!(stats.resident_bytes, 1024);
    }

    #[test]
    fn dead_dags_are_forgotten_not_evicted() {
        let mgr = CacheManager::new(0);
        {
            let a = leafy(2, 4);
            mgr.register(&a);
            assert_eq!(mgr.stats().entries, 1);
        } // a drops here; its payload freed by the Arc, not the evictor
        let stats = mgr.stats();
        assert_eq!(stats.entries, 0);
        assert_eq!(stats.resident_bytes, 0);
        assert_eq!(stats.evictions, 0, "natural death is not an eviction");
    }

    #[test]
    fn forget_releases_accounting() {
        let mgr = CacheManager::new(0);
        let a = leafy(2, 4);
        mgr.register(&a);
        assert_eq!(mgr.forget(a.id()), 512);
        assert_eq!(mgr.forget(a.id()), 0);
        assert_eq!(mgr.stats().entries, 0);
    }

    #[test]
    fn source_bytes_match_geometry() {
        let src = MatExpr::source(BlockMatrix::zeros(4, 8).unwrap());
        assert!(matches!(src.op(), ExprOp::Source(_)));
        // 32x32 doubles.
        assert_eq!(src.approx_result_bytes(), 32 * 32 * 8);
    }
}
