//! Lazy matrix-expression plans: the [`MatExpr`] DAG, the rule-based
//! [`Optimizer`], the [`PlanExec`] lowering pass, and the
//! [`render_plan`] / `explain()` pretty-printer.
//!
//! ## Why lazy
//!
//! PR 2 fused SPIN's Schur step (`V = A21·III − A22`) by hand — a one-off
//! `BlockMatrix::multiply_sub` special case wired into `spin.rs`. Spark
//! gets the same effect *systematically* from lazy evaluation plus a plan
//! optimizer: operators build a logical DAG, rewrite rules fuse and prune
//! it, and only materialization points execute anything. This module is
//! that layer for the block-matrix algebra:
//!
//! * [`MatExpr`] — an immutable, shareable expression node (`Source`,
//!   `Multiply`, `Subtract`, `Scale`, `Transpose`, `Invert{algo}`,
//!   `Quadrant`/split, `Arrange`). Geometry (`nblocks`, `block_size`) is
//!   known at construction, so shape errors surface when a plan is *built*,
//!   not when it runs.
//! * [`Optimizer`] — bottom-up canonicalization applying the rewrite rules
//!   (multiply+subtract fusion, transpose pushdown, scalar folding, CSE
//!   with automatic cache marking). See [`optimizer`] for the rule
//!   contract new rules must follow.
//! * [`PlanExec`] — lowers an optimized DAG onto the partitioner-aware
//!   [`BlockMatrix`] ops, memoizes every node's result (each unique
//!   subtree executes exactly once), and stamps a per-plan-node metrics
//!   record into the owning cluster's [`crate::cluster::Metrics`].
//! * [`render_plan`] — the `explain()` renderer: one SSA-style line per
//!   node with its predicted shuffle cost.
//!
//! Materialization points are `DistMatrix::{collect, to_dense,
//! inverse_residual, block_matrix}` at the session layer and the
//! algorithm-internal recursion inside `algos::{spin, lu}` (a recursive
//! inversion needs its operand's *values*, so each recursion level is one
//! plan evaluated at the level boundary).
//!
//! Evaluation is memoized per node: re-materializing a handle, or sharing
//! a subexpression between two plans evaluated by the same session, never
//! re-executes distributed work — exactly the behaviour the eager API had
//! when intermediates were held in variables.

mod exec;
mod explain;
mod lifecycle;
pub mod optimizer;

pub use exec::{InvertFn, PlanExec};
pub use explain::{predicted_exchanges, render_plan, render_plan_sized};
pub use lifecycle::{CacheManager, CacheStats, EvictionReport};
pub use optimizer::{Optimizer, OptimizerConfig};

use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Weak};

use crate::blockmatrix::{BlockMatrix, Quadrant};
use crate::cluster::Cluster;
use crate::config::GeneratorKind;
use crate::error::{Result, SpinError};
use crate::linalg;
use crate::store::{BlockStore, LocalDirStore};
use crate::util::plock;

/// Globally unique expression-node ids (used for structural hashing,
/// memo keys, and `explain` labels).
static NEXT_EXPR_ID: AtomicU64 = AtomicU64::new(1);

/// Parameter description of a **lazily-born** source matrix: the leaf
/// holds this spec instead of blocks, and the blocks are produced
/// per-partition on the workers at first materialization — `O(1)` matrix
/// work to build the plan, `O(blocks)` distributed work to read it.
///
/// Generation is a pure per-block function
/// ([`crate::linalg::generate_block`]), so a lazy leaf's value is
/// bit-identical to the eager [`BlockMatrix::random`] twin of the same
/// parameters; a store leaf reads one serialized block per partition from
/// a [`crate::store::BlockStore`] directory.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SourceSpec {
    /// Seed-deterministic generated matrix.
    Generated {
        n: usize,
        block_size: usize,
        seed: u64,
        generator: GeneratorKind,
    },
    /// Blocks read from a block-store directory (one file per `(i, j)`).
    Store {
        dir: PathBuf,
        nblocks: usize,
        block_size: usize,
        /// The store generation recorded when this spec was built
        /// (`meta.json`'s `store_id`); re-checked at every
        /// (re)materialization so an in-place re-ingest fails loudly
        /// instead of silently breaking the evict ⇒ regenerate
        /// bit-identically invariant. `None` for pre-id stores.
        store_id: Option<String>,
    },
}

impl SourceSpec {
    /// Describe the matrix held by a block-store directory: reads only
    /// `meta.json` for the grid shape — the single lowering point shared
    /// by [`crate::session::SpinSession::from_store`] and
    /// [`crate::service::MatrixSpec::from_store`].
    pub fn from_dir(dir: impl Into<PathBuf>) -> Result<SourceSpec> {
        let dir: PathBuf = dir.into();
        let meta = crate::ser::bin::read_block_store_meta(&dir)?;
        Ok(SourceSpec::Store {
            dir,
            nblocks: meta.nblocks,
            block_size: meta.block_size,
            store_id: meta.store_id,
        })
    }

    /// Grid edge of the described matrix.
    pub fn nblocks(&self) -> usize {
        match self {
            SourceSpec::Generated { n, block_size, .. } => n / block_size,
            SourceSpec::Store { nblocks, .. } => *nblocks,
        }
    }

    pub fn block_size(&self) -> usize {
        match self {
            SourceSpec::Generated { block_size, .. } | SourceSpec::Store { block_size, .. } => {
                *block_size
            }
        }
    }

    /// Short human label for `explain`.
    pub fn label(&self) -> String {
        match self {
            SourceSpec::Generated {
                seed, generator, ..
            } => format!("seed {seed} · {}", generator.name()),
            SourceSpec::Store { dir, .. } => format!("store {}", dir.display()),
        }
    }

    /// Produce the described matrix, one block per partition, **on the
    /// workers** — the lowering of an [`ExprOp::LazySource`] leaf. The
    /// stage is attributed to `generate` (parameter families) or
    /// `loadBlock` (stores) in the caller's metric scope.
    pub(crate) fn materialize(&self, cluster: &Cluster) -> Result<BlockMatrix> {
        match self {
            SourceSpec::Generated {
                n,
                block_size,
                seed,
                generator,
            } => {
                let (n, block_size, seed, generator) = (*n, *block_size, *seed, *generator);
                BlockMatrix::materialize_blocks(
                    cluster,
                    "generate",
                    n / block_size,
                    block_size,
                    |i, j| Ok(linalg::generate_block(generator, n, block_size, i, j, seed)),
                )
            }
            SourceSpec::Store {
                dir,
                nblocks,
                block_size,
                store_id,
            } => {
                let store = LocalDirStore::open_unchecked(dir.clone());
                // Identity check on every (re)materialization: evicted
                // store leaves must regenerate the SAME bytes, so a store
                // re-ingested since this plan was built is a loud error,
                // never a silent mix of old intermediates and new data.
                let meta = store.meta()?;
                if meta.nblocks != *nblocks
                    || meta.block_size != *block_size
                    || meta.store_id != *store_id
                {
                    return Err(SpinError::artifact(format!(
                        "store {} changed since this plan was built \
                         (re-ingested?); resubmit against the current store",
                        dir.display()
                    )));
                }
                BlockMatrix::materialize_blocks(
                    cluster,
                    "loadBlock",
                    *nblocks,
                    *block_size,
                    |i, j| store.read_block(i, j),
                )
            }
        }
    }
}

/// Per-`Invert`-node overrides for iterative schemes. A `None` field
/// defers to the evaluating session's job defaults
/// (`JobConfig::tolerance` / `JobConfig::max_iters`); exact schemes
/// ignore both. Part of a node's structural identity: two inverts of the
/// same child under different tolerances are different values, so the
/// optimizer's CSE and the cross-job plan cache must not merge them.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct InvertOpts {
    /// Convergence threshold override (`‖I − A·Xₖ‖∞ ≤ tolerance`).
    pub tolerance: Option<f64>,
    /// Iteration-budget (SLA) override.
    pub max_iters: Option<usize>,
}

impl InvertOpts {
    /// True when neither field overrides the session defaults.
    pub fn is_default(&self) -> bool {
        self.tolerance.is_none() && self.max_iters.is_none()
    }

    /// Structural identity key (`f64` is not `Hash`/`Eq`; tolerances are
    /// compared bit-exactly, which is the right granularity for a cache
    /// key — a differently-written equal float is a different request).
    pub fn key(&self) -> (Option<u64>, Option<usize>) {
        (self.tolerance.map(f64::to_bits), self.max_iters)
    }
}

/// One logical operator in a matrix-expression plan.
///
/// Every variant preserves the square `nblocks × nblocks` grid geometry
/// except [`ExprOp::Quadrant`] (halves it) and [`ExprOp::Arrange`]
/// (doubles it).
pub enum ExprOp {
    /// A materialized distributed matrix (the DAG's leaves).
    Source(BlockMatrix),
    /// A described-not-materialized leaf: blocks are produced on the
    /// workers at first read (and re-produced bit-identically if the
    /// value is later evicted). Unlike [`ExprOp::Source`], the
    /// materialized value is session storage, so the lifecycle manager
    /// byte-accounts and may evict it.
    LazySource(SourceSpec),
    /// C = A·B.
    Multiply(MatExpr, MatExpr),
    /// C = A·B − D, fused into one multiply-reduce stage. Built by the
    /// optimizer's fusion rule (or explicitly via [`MatExpr::multiply_sub`]).
    MultiplySub(MatExpr, MatExpr, MatExpr),
    /// C = A − B.
    Subtract(MatExpr, MatExpr),
    /// C = s·A.
    Scale(MatExpr, f64),
    /// C = Aᵀ.
    Transpose(MatExpr),
    /// C = A⁻¹ through a named inversion scheme, supplied at evaluation
    /// time by the caller's [`InvertFn`].
    Invert {
        /// Scheme name resolved by the evaluating context (a registry
        /// entry at the session layer, the recursion itself inside SPIN).
        algo: String,
        /// Per-node overrides for iterative schemes (tolerance /
        /// iteration budget). `InvertOpts::default()` means "use the
        /// evaluating session's job defaults".
        opts: InvertOpts,
        child: MatExpr,
    },
    /// One quadrant of the half-grid split (the paper's `breakMat` + `xy`
    /// pipeline; sibling quadrants of the same child share one `breakMat`
    /// pass at execution time).
    Quadrant { child: MatExpr, which: Quadrant },
    /// Re-assemble four half-grid quadrants into the full grid
    /// (`C11, C12, C21, C22` order).
    Arrange(MatExpr, MatExpr, MatExpr, MatExpr),
}

impl ExprOp {
    /// Stable operator name used by `explain` and plan-node metrics.
    pub fn name(&self) -> &'static str {
        match self {
            ExprOp::Source(_) => "source",
            ExprOp::LazySource(_) => "lazy_source",
            ExprOp::Multiply(..) => "multiply",
            ExprOp::MultiplySub(..) => "multiply_sub",
            ExprOp::Subtract(..) => "subtract",
            ExprOp::Scale(..) => "scale",
            ExprOp::Transpose(..) => "transpose",
            ExprOp::Invert { .. } => "invert",
            ExprOp::Quadrant { .. } => "quadrant",
            ExprOp::Arrange(..) => "arrange",
        }
    }
}

/// Interior of one DAG node. Shared via [`MatExpr`] (an `Arc` handle);
/// the memo slots make repeated optimization / evaluation of the same
/// node free.
pub struct ExprNode {
    id: u64,
    op: ExprOp,
    nblocks: usize,
    block_size: usize,
    /// Canonical (optimized) form of this node under a given optimizer
    /// config — keeps rewritten identities stable across `optimize` calls
    /// so downstream value memos keep hitting.
    canonical: Mutex<Option<(OptimizerConfig, MatExpr)>>,
    /// Materialized result. A node evaluates at most once *concurrently*
    /// (the executor holds this slot while lowering, so plans shared
    /// between jobs never duplicate work); every further use reuses the
    /// value until the session's [`CacheManager`] evicts it under its
    /// byte budget — after which the next read recomputes from the
    /// children, bit-identically.
    value: Mutex<Option<BlockMatrix>>,
    /// Set by the optimizer's CSE pass on nodes referenced more than once
    /// in a plan: the automatic `cache()` insertion point shown by
    /// `explain`.
    cse_cached: AtomicBool,
    /// Pinned by [`crate::session::DistMatrix::persist`]: the LRU evictor
    /// must not drop this node's value.
    pinned: AtomicBool,
}

/// A lazy distributed-matrix expression: a cheap, clonable handle onto one
/// node of a shared DAG. Built by [`crate::session::DistMatrix`] operator
/// methods and by the algorithms' per-recursion-level plans; evaluated by
/// [`PlanExec`].
#[derive(Clone)]
pub struct MatExpr {
    node: Arc<ExprNode>,
}

impl MatExpr {
    // ---------- constructors ----------

    pub(crate) fn with_op(op: ExprOp, nblocks: usize, block_size: usize) -> MatExpr {
        MatExpr {
            node: Arc::new(ExprNode {
                id: NEXT_EXPR_ID.fetch_add(1, Ordering::Relaxed),
                op,
                nblocks,
                block_size,
                canonical: Mutex::new(None),
                value: Mutex::new(None),
                cse_cached: AtomicBool::new(false),
                pinned: AtomicBool::new(false),
            }),
        }
    }

    /// Wrap a materialized distributed matrix as a plan leaf.
    pub fn source(m: BlockMatrix) -> MatExpr {
        let (nb, bs) = (m.nblocks(), m.block_size());
        MatExpr::with_op(ExprOp::Source(m), nb, bs)
    }

    /// A lazy source leaf: `O(1)` to build — no blocks are generated or
    /// read until the node is materialized, and then on the workers.
    pub fn lazy_source(spec: SourceSpec) -> Result<MatExpr> {
        let (nb, bs) = (spec.nblocks(), spec.block_size());
        if nb == 0 || bs == 0 {
            return Err(SpinError::shape(format!(
                "lazy source needs a non-empty grid, got {nb}x{nb} of {bs}"
            )));
        }
        if let SourceSpec::Generated { n, block_size, .. } = &spec {
            if n % block_size != 0 {
                return Err(SpinError::shape(format!(
                    "lazy source: block size {block_size} does not divide n {n}"
                )));
            }
        }
        Ok(MatExpr::with_op(ExprOp::LazySource(spec), nb, bs))
    }

    /// C = A·B (lazy).
    pub fn multiply(&self, other: &MatExpr) -> Result<MatExpr> {
        self.check_same_grid(other, "multiply")?;
        Ok(MatExpr::with_op(
            ExprOp::Multiply(self.clone(), other.clone()),
            self.nblocks(),
            self.block_size(),
        ))
    }

    /// C = A·B − D as an explicitly fused node (the optimizer derives the
    /// same node from `multiply` + `subtract`).
    pub fn multiply_sub(&self, other: &MatExpr, d: &MatExpr) -> Result<MatExpr> {
        self.check_same_grid(other, "multiply_sub")?;
        self.check_same_grid(d, "multiply_sub")?;
        Ok(MatExpr::with_op(
            ExprOp::MultiplySub(self.clone(), other.clone(), d.clone()),
            self.nblocks(),
            self.block_size(),
        ))
    }

    /// C = A − B (lazy).
    pub fn subtract(&self, other: &MatExpr) -> Result<MatExpr> {
        self.check_same_grid(other, "subtract")?;
        Ok(MatExpr::with_op(
            ExprOp::Subtract(self.clone(), other.clone()),
            self.nblocks(),
            self.block_size(),
        ))
    }

    /// C = s·A (lazy).
    pub fn scale(&self, s: f64) -> MatExpr {
        MatExpr::with_op(
            ExprOp::Scale(self.clone(), s),
            self.nblocks(),
            self.block_size(),
        )
    }

    /// C = Aᵀ (lazy).
    pub fn transpose(&self) -> MatExpr {
        MatExpr::with_op(
            ExprOp::Transpose(self.clone()),
            self.nblocks(),
            self.block_size(),
        )
    }

    /// C = A⁻¹ through the named scheme, resolved by the evaluator's
    /// [`InvertFn`] at materialization time.
    pub fn invert(&self, algo: &str) -> MatExpr {
        self.invert_opts(algo, InvertOpts::default())
    }

    /// [`invert`](Self::invert) with per-node iterative-scheme overrides
    /// (tolerance / iteration budget) riding the plan node.
    pub fn invert_opts(&self, algo: &str, opts: InvertOpts) -> MatExpr {
        MatExpr::with_op(
            ExprOp::Invert {
                algo: algo.to_string(),
                opts,
                child: self.clone(),
            },
            self.nblocks(),
            self.block_size(),
        )
    }

    /// One quadrant of the half-grid split. Requires an even grid of at
    /// least 2×2 blocks.
    pub fn quadrant(&self, which: Quadrant) -> Result<MatExpr> {
        let b = self.nblocks();
        if b < 2 || b % 2 != 0 {
            return Err(SpinError::shape(format!(
                "cannot take a quadrant of a {b}x{b} block grid"
            )));
        }
        Ok(MatExpr::with_op(
            ExprOp::Quadrant {
                child: self.clone(),
                which,
            },
            b / 2,
            self.block_size(),
        ))
    }

    /// All four quadrants (`A11, A12, A21, A22`) — the lazy `split`.
    pub fn split(&self) -> Result<(MatExpr, MatExpr, MatExpr, MatExpr)> {
        Ok((
            self.quadrant(Quadrant::Q11)?,
            self.quadrant(Quadrant::Q12)?,
            self.quadrant(Quadrant::Q21)?,
            self.quadrant(Quadrant::Q22)?,
        ))
    }

    /// Re-assemble four equal half-grid quadrants into one full-grid plan.
    pub fn arrange(
        c11: &MatExpr,
        c12: &MatExpr,
        c21: &MatExpr,
        c22: &MatExpr,
    ) -> Result<MatExpr> {
        c11.check_same_grid(c12, "arrange")?;
        c11.check_same_grid(c21, "arrange")?;
        c11.check_same_grid(c22, "arrange")?;
        Ok(MatExpr::with_op(
            ExprOp::Arrange(c11.clone(), c12.clone(), c21.clone(), c22.clone()),
            2 * c11.nblocks(),
            c11.block_size(),
        ))
    }

    // ---------- geometry / accessors ----------

    /// Unique node id.
    pub fn id(&self) -> u64 {
        self.node.id
    }

    /// The logical operator at this node.
    pub fn op(&self) -> &ExprOp {
        &self.node.op
    }

    /// Grid edge of this expression's result.
    pub fn nblocks(&self) -> usize {
        self.node.nblocks
    }

    pub fn block_size(&self) -> usize {
        self.node.block_size
    }

    /// Full matrix order `n` of this expression's result.
    pub fn n(&self) -> usize {
        self.node.nblocks * self.node.block_size
    }

    /// Child expressions, in a fixed deterministic order.
    pub fn children(&self) -> Vec<MatExpr> {
        match &self.node.op {
            ExprOp::Source(_) | ExprOp::LazySource(_) => Vec::new(),
            ExprOp::Multiply(a, b) | ExprOp::Subtract(a, b) => vec![a.clone(), b.clone()],
            ExprOp::MultiplySub(a, b, d) => vec![a.clone(), b.clone(), d.clone()],
            ExprOp::Scale(x, _) | ExprOp::Transpose(x) => vec![x.clone()],
            ExprOp::Invert { child, .. } | ExprOp::Quadrant { child, .. } => vec![child.clone()],
            ExprOp::Arrange(a, b, c, d) => vec![a.clone(), b.clone(), c.clone(), d.clone()],
        }
    }

    /// Number of unique nodes in this DAG.
    pub fn node_count(&self) -> usize {
        let mut seen = std::collections::HashSet::new();
        let mut stack = vec![self.clone()];
        while let Some(e) = stack.pop() {
            if seen.insert(e.id()) {
                stack.extend(e.children());
            }
        }
        seen.len()
    }

    /// Blocks held by this DAG's **eager** `Source` leaves — matrix data
    /// that was materialized on the driver when the plan was built. The
    /// lazy submit paths keep this at 0 (leaves are [`ExprOp::LazySource`]
    /// descriptors); `spin bench` measures and gates it per run so an
    /// eager-generation regression in the service fails CI.
    pub fn driver_source_blocks(&self) -> usize {
        let mut seen = std::collections::HashSet::new();
        let mut stack = vec![self.clone()];
        let mut blocks = 0;
        while let Some(e) = stack.pop() {
            if seen.insert(e.id()) {
                if let ExprOp::Source(m) = e.op() {
                    blocks += m.nblocks() * m.nblocks();
                }
                stack.extend(e.children());
            }
        }
        blocks
    }

    /// Whether the optimizer marked this node as a CSE cache point.
    pub fn is_cse_cached(&self) -> bool {
        self.node.cse_cached.load(Ordering::Relaxed)
    }

    pub(crate) fn set_cse_cached(&self, on: bool) {
        self.node.cse_cached.store(on, Ordering::Relaxed);
    }

    /// The memoized materialized value, if this node already executed.
    pub fn cached_value(&self) -> Option<BlockMatrix> {
        plock(&self.node.value).clone()
    }

    pub(crate) fn set_value(&self, v: BlockMatrix) {
        *plock(&self.node.value) = Some(v);
    }

    /// Exclusive access to the memo slot. The executor holds this for a
    /// node's whole lowering so concurrent evaluators of a shared subtree
    /// serialize (exactly-once execution); lock acquisition follows DAG
    /// edges strictly downward, so no cycle — hence no deadlock — is
    /// possible. Poison-tolerant: a job that panicked mid-lowering leaves
    /// the slot either fully written or `None`, so recovering the guard is
    /// safe and later jobs sharing the node simply recompute.
    pub(crate) fn value_slot(&self) -> std::sync::MutexGuard<'_, Option<BlockMatrix>> {
        plock(&self.node.value)
    }

    /// Drop this node's memoized value (if any). The next materialization
    /// recomputes it from the children — always safe, always
    /// bit-identical. Returns whether a value was actually released.
    pub fn evict_value(&self) -> bool {
        plock(&self.node.value).take().is_some()
    }

    /// Whether [`crate::session::DistMatrix::persist`] pinned this node
    /// against LRU eviction.
    pub fn is_pinned(&self) -> bool {
        self.node.pinned.load(Ordering::Relaxed)
    }

    pub(crate) fn set_pinned(&self, on: bool) {
        self.node.pinned.store(on, Ordering::Relaxed);
    }

    /// Approximate bytes of this node's materialized value: its full
    /// `n × n` of f64 block payloads (what the LRU budget charges).
    pub fn approx_result_bytes(&self) -> u64 {
        let n = self.n() as u64;
        n * n * 8
    }

    pub(crate) fn downgrade(e: &MatExpr) -> Weak<ExprNode> {
        Arc::downgrade(&e.node)
    }

    /// Re-handle a weakly-held node, if its DAG is still alive.
    pub(crate) fn upgrade(node: &Weak<ExprNode>) -> Option<MatExpr> {
        node.upgrade().map(|node| MatExpr { node })
    }

    pub(crate) fn canonical_for(&self, config: OptimizerConfig) -> Option<MatExpr> {
        match &*plock(&self.node.canonical) {
            Some((cfg, e)) if *cfg == config => Some(e.clone()),
            _ => None,
        }
    }

    pub(crate) fn set_canonical(&self, config: OptimizerConfig, e: MatExpr) {
        *plock(&self.node.canonical) = Some((config, e));
    }

    /// Shape compatibility check for binary plan constructors — mirrors
    /// `BlockMatrix::check_same_grid` so lazy and eager errors read alike.
    pub(crate) fn check_same_grid(&self, other: &MatExpr, op: &str) -> Result<()> {
        if self.nblocks() != other.nblocks() || self.block_size() != other.block_size() {
            return Err(SpinError::shape(format!(
                "{op}: grid mismatch {}x{} (bs {}) vs {}x{} (bs {})",
                self.nblocks(),
                self.nblocks(),
                self.block_size(),
                other.nblocks(),
                other.nblocks(),
                other.block_size()
            )));
        }
        Ok(())
    }
}

impl std::fmt::Debug for MatExpr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "MatExpr#{}({}, {}x{} of {})",
            self.id(),
            self.op().name(),
            self.nblocks(),
            self.nblocks(),
            self.block_size()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn src(nb: usize, bs: usize) -> MatExpr {
        MatExpr::source(BlockMatrix::zeros(nb, bs).unwrap())
    }

    #[test]
    fn geometry_propagates() {
        let a = src(4, 8);
        assert_eq!(a.n(), 32);
        let m = a.multiply(&a).unwrap();
        assert_eq!((m.nblocks(), m.block_size()), (4, 8));
        let q = a.quadrant(Quadrant::Q21).unwrap();
        assert_eq!((q.nblocks(), q.block_size()), (2, 8));
        let (c11, c12, c21, c22) = a.split().unwrap();
        let back = MatExpr::arrange(&c11, &c12, &c21, &c22).unwrap();
        assert_eq!(back.nblocks(), 4);
        assert_eq!(back.n(), 32);
        assert_eq!(a.transpose().n(), 32);
        assert_eq!(a.scale(2.0).n(), 32);
        assert_eq!(a.invert("spin").n(), 32);
    }

    #[test]
    fn grid_mismatch_rejected_at_construction() {
        let a = src(4, 8);
        let b = src(2, 16);
        assert!(a.multiply(&b).is_err());
        assert!(a.subtract(&b).is_err());
        assert!(a.multiply_sub(&a, &b).is_err());
        assert!(MatExpr::arrange(&a, &a, &a, &b).is_err());
    }

    #[test]
    fn quadrant_needs_even_grid() {
        assert!(src(1, 4).quadrant(Quadrant::Q11).is_err());
        assert!(src(3, 4).quadrant(Quadrant::Q11).is_err());
        assert!(src(2, 4).quadrant(Quadrant::Q11).is_ok());
    }

    #[test]
    fn lazy_source_is_o1_and_geometry_checked() {
        let spec = SourceSpec::Generated {
            n: 1 << 20, // a terabyte-scale matrix: building the leaf is free
            block_size: 1 << 10,
            seed: 7,
            generator: GeneratorKind::DiagDominant,
        };
        let leaf = MatExpr::lazy_source(spec).unwrap();
        assert_eq!(leaf.nblocks(), 1 << 10);
        assert_eq!(leaf.n(), 1 << 20);
        assert!(leaf.cached_value().is_none(), "nothing materialized");
        assert_eq!(leaf.op().name(), "lazy_source");
        assert!(leaf.children().is_empty());
        // Degenerate specs are rejected at construction.
        assert!(MatExpr::lazy_source(SourceSpec::Generated {
            n: 0,
            block_size: 4,
            seed: 0,
            generator: GeneratorKind::DiagDominant,
        })
        .is_err());
        assert!(MatExpr::lazy_source(SourceSpec::Store {
            dir: PathBuf::from("x"),
            nblocks: 2,
            block_size: 0,
            store_id: None,
        })
        .is_err());
    }

    #[test]
    fn node_count_deduplicates_shared_subtrees() {
        let a = src(2, 4);
        let b = src(2, 4);
        let m = a.multiply(&b).unwrap();
        // m used twice: a, b, m, root = 4 unique nodes.
        let root = m.subtract(&m).unwrap();
        assert_eq!(root.node_count(), 4);
    }

    #[test]
    fn children_order_is_deterministic() {
        let a = src(2, 4);
        let b = src(2, 4);
        let m = a.multiply(&b).unwrap();
        let kids = m.children();
        assert_eq!(kids.len(), 2);
        assert_eq!(kids[0].id(), a.id());
        assert_eq!(kids[1].id(), b.id());
        assert_eq!(m.op().name(), "multiply");
    }
}
