//! Rule-based plan rewrites, run before lowering.
//!
//! ## The rewrite-rule contract
//!
//! Every rule must satisfy four properties — check them before adding one:
//!
//! 1. **Value-preserving, bit-for-bit where claimed.** A rule may only
//!    replace a subtree with one that computes the same blocks. Rules that
//!    re-associate floating-point sums are *not* admissible; reordering
//!    commutative products (`x·y → y·x` elementwise, as transpose pushdown
//!    does) and exact-scalar identities are. The executor's property
//!    tests compare every rule against the unoptimized plan at
//!    n = 128 / block 16.
//! 2. **Geometry-preserving.** The rewritten node must report the same
//!    `nblocks`/`block_size` as the node it replaces.
//! 3. **Cost-non-increasing.** Fire only when the rewrite cannot add
//!    distributed stages: the fusion rule checks the multiply operand is
//!    not shared (a shared product would be computed twice inside the
//!    fused node) and not already materialized; transpose pushdown fires
//!    only when it cancels at least one existing transpose.
//! 4. **Deterministic and idempotent.** Canonicalization is bottom-up and
//!    memoized per node (keyed by the [`OptimizerConfig`]); a rule must
//!    produce the same output for the same input so re-optimizing an
//!    already-optimized DAG is a no-op.
//!
//! ## The rules
//!
//! * **Fusion** — `Subtract(Multiply(a, b), d)` → `MultiplySub(a, b, d)`:
//!   the Schur-step fusion PR 2 hand-wired into `spin.rs`, generalized.
//!   The subtraction runs inside the multiply's reduce stage, deleting a
//!   whole narrow stage (and, on the legacy dataflow, a shuffle).
//! * **Transpose pushdown** — `Transpose(Transpose(x))` → `x`, and
//!   `Transpose(Multiply(a, b))` → `Multiply(tᵣ(b), tᵣ(a))` when `a` or
//!   `b` is itself a transpose (`tᵣ` strips a transpose if present, else
//!   wraps one) and the product has no other consumer — net transpose
//!   *and* multiply stages never increase.
//! * **Scalar folding** — `Scale(x, 1.0)` → `x`; nested
//!   `Scale(Scale(x, t), s)` → `Scale(x, s·t)` only when a factor is ±1,
//!   where the fold is bit-exact (general factors would re-associate a
//!   rounding step, violating rule 1).
//! * **CSE** — structurally identical subtrees are interned onto one node
//!   (so the executor's per-node memo runs them once), and every node
//!   referenced more than once is marked as an automatic `cache()` point,
//!   rendered by `explain` (e.g. `III = I·A12`, used three times per SPIN
//!   level).

use std::collections::{HashMap, HashSet};

use crate::config::ClusterConfig;
use crate::error::Result;

use super::{ExprOp, MatExpr};

/// Which rewrite rules run. `all()` is the production default; `none()`
/// reproduces the unoptimized plan (used by the ablation comparison and
/// `--set plan_optimizer=false`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OptimizerConfig {
    /// `Subtract(Multiply(a, b), d)` → fused `MultiplySub(a, b, d)`.
    pub fuse_multiply_sub: bool,
    /// Transpose cancellation and pushdown into multiply operands.
    pub transpose_pushdown: bool,
    /// Identity-scale elimination and nested-scale folding.
    pub fold_scalars: bool,
    /// Structural common-subexpression elimination + cache marking.
    pub cse: bool,
}

impl OptimizerConfig {
    /// Every rule on (the default).
    pub fn all() -> Self {
        OptimizerConfig {
            fuse_multiply_sub: true,
            transpose_pushdown: true,
            fold_scalars: true,
            cse: true,
        }
    }

    /// Every rule off — the plan lowers exactly as written.
    pub fn none() -> Self {
        OptimizerConfig {
            fuse_multiply_sub: false,
            transpose_pushdown: false,
            fold_scalars: false,
            cse: false,
        }
    }

    /// Derive from the cluster's `plan_optimizer` knob.
    pub fn from_cluster(cfg: &ClusterConfig) -> Self {
        if cfg.plan_optimizer {
            OptimizerConfig::all()
        } else {
            OptimizerConfig::none()
        }
    }
}

impl Default for OptimizerConfig {
    fn default() -> Self {
        OptimizerConfig::all()
    }
}

/// Structural identity of a canonicalized node — child ids plus operator
/// parameters. Two nodes with equal keys compute identical values, so the
/// CSE pass interns them onto one node.
#[derive(Hash, PartialEq, Eq)]
enum StructKey {
    Source(u64),
    Multiply(u64, u64),
    MultiplySub(u64, u64, u64),
    Subtract(u64, u64),
    Scale(u64, u64),
    Transpose(u64),
    /// Scheme name, per-node iterative overrides (tolerance bits /
    /// budget), child: different tolerances are different values.
    Invert(String, (Option<u64>, Option<usize>), u64),
    Quadrant(u64, crate::blockmatrix::Quadrant),
    Arrange(u64, u64, u64, u64),
}

/// Build a key from an operator plus explicit child ids — node ids for
/// interning canonical nodes, *representative* ids for the pre-pass that
/// detects structural sharing in the original DAG.
fn key_with(op: &ExprOp, kids: &[u64]) -> StructKey {
    match op {
        ExprOp::Source(_) | ExprOp::LazySource(_) => {
            unreachable!("sources are canonical by identity")
        }
        ExprOp::Multiply(..) => StructKey::Multiply(kids[0], kids[1]),
        ExprOp::MultiplySub(..) => StructKey::MultiplySub(kids[0], kids[1], kids[2]),
        ExprOp::Subtract(..) => StructKey::Subtract(kids[0], kids[1]),
        ExprOp::Scale(_, s) => StructKey::Scale(kids[0], s.to_bits()),
        ExprOp::Transpose(..) => StructKey::Transpose(kids[0]),
        ExprOp::Invert { algo, opts, .. } => StructKey::Invert(algo.clone(), opts.key(), kids[0]),
        ExprOp::Quadrant { which, .. } => StructKey::Quadrant(kids[0], *which),
        ExprOp::Arrange(..) => StructKey::Arrange(kids[0], kids[1], kids[2], kids[3]),
    }
}

fn struct_key(op: &ExprOp) -> StructKey {
    let kids: Vec<u64> = match op {
        ExprOp::Source(_) | ExprOp::LazySource(_) => Vec::new(),
        ExprOp::Multiply(a, b) | ExprOp::Subtract(a, b) => vec![a.id(), b.id()],
        ExprOp::MultiplySub(a, b, d) => vec![a.id(), b.id(), d.id()],
        ExprOp::Scale(x, _) | ExprOp::Transpose(x) => vec![x.id()],
        ExprOp::Invert { child, .. } | ExprOp::Quadrant { child, .. } => vec![child.id()],
        ExprOp::Arrange(a, b, c, d) => vec![a.id(), b.id(), c.id(), d.id()],
    };
    key_with(op, &kids)
}

/// The rewrite engine. One instance optimizes one (or more) roots; the
/// interning table is per-instance, while per-node canonical forms are
/// memoized on the nodes themselves, so repeated optimization — including
/// of subtrees shared with previously optimized plans — is stable and
/// cheap.
pub struct Optimizer {
    config: OptimizerConfig,
    interned: HashMap<StructKey, MatExpr>,
    /// Reference counts of the original DAG under the current root, keyed
    /// by *structural representative* — pointer-shared and
    /// structurally-duplicate consumers both count, so the sharing guards
    /// of the fusion and pushdown rules cannot be evaded by building the
    /// same subtree twice.
    use_counts: HashMap<u64, usize>,
    /// Original node id → structural representative id (first node seen
    /// with that structure).
    reps: HashMap<u64, u64>,
    rep_interned: HashMap<StructKey, u64>,
}

impl Optimizer {
    pub fn new(config: OptimizerConfig) -> Self {
        Optimizer {
            config,
            interned: HashMap::new(),
            use_counts: HashMap::new(),
            reps: HashMap::new(),
            rep_interned: HashMap::new(),
        }
    }

    /// Structural representative of an original node: two nodes share a
    /// representative iff they compute the same value (same op over
    /// representative-equal children, sources by identity).
    fn rep_of(&mut self, e: &MatExpr) -> u64 {
        if let Some(&r) = self.reps.get(&e.id()) {
            return r;
        }
        let r = match e.op() {
            // Leaves are their own representative: eager sources by
            // identity, lazy sources because the service's `PlanCache`
            // already interns equal specs onto one node.
            ExprOp::Source(_) | ExprOp::LazySource(_) => e.id(),
            op => {
                let kid_reps: Vec<u64> =
                    e.children().iter().map(|c| self.rep_of(c)).collect();
                let key = key_with(op, &kid_reps);
                *self.rep_interned.entry(key).or_insert_with(|| e.id())
            }
        };
        self.reps.insert(e.id(), r);
        r
    }

    /// Canonicalize + rewrite `root`, returning the optimized plan. With
    /// [`OptimizerConfig::none`] this is the identity (modulo fresh node
    /// identities for non-source nodes).
    pub fn optimize(&mut self, root: &MatExpr) -> Result<MatExpr> {
        self.count_uses(root);
        let out = self.canon(root)?;
        if self.config.cse {
            mark_shared(&out);
        }
        Ok(out)
    }

    /// Count every parent→child edge of the original DAG (each unique
    /// parent contributes once per child slot), attributed to the child's
    /// structural representative.
    fn count_uses(&mut self, root: &MatExpr) {
        let mut visited = HashSet::new();
        let mut stack = vec![root.clone()];
        while let Some(e) = stack.pop() {
            if !visited.insert(e.id()) {
                continue;
            }
            for c in e.children() {
                let rep = self.rep_of(&c);
                *self.use_counts.entry(rep).or_insert(0) += 1;
                stack.push(c);
            }
        }
    }

    fn intern(&mut self, op: ExprOp, nblocks: usize, block_size: usize) -> MatExpr {
        if !self.config.cse {
            return MatExpr::with_op(op, nblocks, block_size);
        }
        let key = struct_key(&op);
        if let Some(hit) = self.interned.get(&key) {
            return hit.clone();
        }
        let e = MatExpr::with_op(op, nblocks, block_size);
        self.interned.insert(key, e.clone());
        e
    }

    /// `Transpose(z)` with cancellation: strips one transpose if `z` is
    /// already a transpose, else wraps one.
    fn transpose_of(&mut self, z: &MatExpr) -> MatExpr {
        if let ExprOp::Transpose(inner) = z.op() {
            return inner.clone();
        }
        self.intern(
            ExprOp::Transpose(z.clone()),
            z.nblocks(),
            z.block_size(),
        )
    }

    fn canon(&mut self, e: &MatExpr) -> Result<MatExpr> {
        if let Some(hit) = e.canonical_for(self.config) {
            return Ok(hit);
        }
        let (nb, bs) = (e.nblocks(), e.block_size());
        let out = match e.op() {
            // Sources (eager and lazy) are canonical by identity.
            ExprOp::Source(_) | ExprOp::LazySource(_) => e.clone(),

            ExprOp::Multiply(a, b) => {
                let ca = self.canon(a)?;
                let cb = self.canon(b)?;
                self.intern(ExprOp::Multiply(ca, cb), nb, bs)
            }

            ExprOp::MultiplySub(a, b, d) => {
                let ca = self.canon(a)?;
                let cb = self.canon(b)?;
                let cd = self.canon(d)?;
                self.intern(ExprOp::MultiplySub(ca, cb, cd), nb, bs)
            }

            ExprOp::Subtract(a, b) => {
                let ca = self.canon(a)?;
                let cb = self.canon(b)?;
                // Fusion rule: A·B − D runs the subtraction inside the
                // multiply's reduce stage. Guards (contract rule 3): the
                // product must not be shared with another consumer —
                // pointer-shared *or* structurally duplicated (it would be
                // computed twice) — and must not already be materialized
                // (the cached value would go unused).
                let a_rep = self.rep_of(a);
                let shared = self.use_counts.get(&a_rep).copied().unwrap_or(1) > 1;
                let fused = if self.config.fuse_multiply_sub
                    && !shared
                    && ca.cached_value().is_none()
                {
                    match ca.op() {
                        ExprOp::Multiply(x, y) => Some((x.clone(), y.clone())),
                        _ => None,
                    }
                } else {
                    None
                };
                match fused {
                    Some((x, y)) => self.intern(ExprOp::MultiplySub(x, y, cb), nb, bs),
                    None => self.intern(ExprOp::Subtract(ca, cb), nb, bs),
                }
            }

            ExprOp::Scale(x, s) => {
                let cx = self.canon(x)?;
                let s = *s;
                if self.config.fold_scalars {
                    // Identity scale: exact, always drop.
                    if s == 1.0 {
                        return finish(e, self.config, cx);
                    }
                    // Nested folding fires only when a factor is ±1
                    // (contract rule 1: multiplying by ±1 is exact and
                    // sign-symmetric, so s·(t·x) and (s·t)·x agree bit for
                    // bit — general factors would re-associate a rounding
                    // step and make plan_optimizer observable in the last
                    // ulp, or in overflow behaviour).
                    let folded = match cx.op() {
                        ExprOp::Scale(y, t) if s == -1.0 || *t == 1.0 || *t == -1.0 => {
                            Some((y.clone(), s * t))
                        }
                        _ => None,
                    };
                    match folded {
                        Some((y, f)) if f == 1.0 => y,
                        Some((y, f)) => self.intern(ExprOp::Scale(y, f), nb, bs),
                        None => self.intern(ExprOp::Scale(cx, s), nb, bs),
                    }
                } else {
                    self.intern(ExprOp::Scale(cx, s), nb, bs)
                }
            }

            ExprOp::Transpose(x) => {
                let cx = self.canon(x)?;
                if self.config.transpose_pushdown {
                    if let ExprOp::Transpose(inner) = cx.op() {
                        // (Aᵀ)ᵀ = A.
                        return finish(e, self.config, inner.clone());
                    }
                    // (A·B)ᵀ = Bᵀ·Aᵀ — fire only when an operand is itself
                    // a transpose, so at least one stage cancels, and only
                    // when the product is this transpose's alone (contract
                    // rule 3: a shared or already-materialized product
                    // would still execute for its other consumer, making
                    // the rewrite a net extra multiply).
                    let x_rep = self.rep_of(x);
                    let x_shared = self.use_counts.get(&x_rep).copied().unwrap_or(1) > 1;
                    let pushdown = if x_shared || cx.cached_value().is_some() {
                        None
                    } else {
                        match cx.op() {
                            ExprOp::Multiply(a, b)
                                if matches!(a.op(), ExprOp::Transpose(_))
                                    || matches!(b.op(), ExprOp::Transpose(_)) =>
                            {
                                Some((a.clone(), b.clone()))
                            }
                            _ => None,
                        }
                    };
                    if let Some((a, b)) = pushdown {
                        let tb = self.transpose_of(&b);
                        let ta = self.transpose_of(&a);
                        self.intern(ExprOp::Multiply(tb, ta), nb, bs)
                    } else {
                        self.intern(ExprOp::Transpose(cx), nb, bs)
                    }
                } else {
                    self.intern(ExprOp::Transpose(cx), nb, bs)
                }
            }

            ExprOp::Invert { algo, opts, child } => {
                let cc = self.canon(child)?;
                let algo = algo.clone();
                let opts = *opts;
                self.intern(ExprOp::Invert { algo, opts, child: cc }, nb, bs)
            }

            ExprOp::Quadrant { child, which } => {
                let cc = self.canon(child)?;
                let which = *which;
                self.intern(ExprOp::Quadrant { child: cc, which }, nb, bs)
            }

            ExprOp::Arrange(a, b, c, d) => {
                let ca = self.canon(a)?;
                let cb = self.canon(b)?;
                let cc = self.canon(c)?;
                let cd = self.canon(d)?;
                self.intern(ExprOp::Arrange(ca, cb, cc, cd), nb, bs)
            }
        };
        finish(e, self.config, out)
    }
}

/// Store the canonical form on the original node and return it.
fn finish(original: &MatExpr, config: OptimizerConfig, canonical: MatExpr) -> Result<MatExpr> {
    original.set_canonical(config, canonical.clone());
    Ok(canonical)
}

/// CSE cache marking: any node referenced by more than one parent in the
/// optimized DAG is an automatic `cache()` point (sources excluded — they
/// are already materialized). The flag is *stored*, not or-ed, so a node
/// reused by a later plan where it is no longer shared is re-marked
/// accurately for that plan's `explain` and plan-node metrics.
fn mark_shared(root: &MatExpr) {
    let mut indegree: HashMap<u64, usize> = HashMap::new();
    let mut nodes: Vec<MatExpr> = Vec::new();
    let mut visited = HashSet::new();
    let mut stack = vec![root.clone()];
    while let Some(e) = stack.pop() {
        if !visited.insert(e.id()) {
            continue;
        }
        for c in e.children() {
            *indegree.entry(c.id()).or_insert(0) += 1;
            stack.push(c);
        }
        nodes.push(e);
    }
    for e in nodes {
        let shared = indegree.get(&e.id()).copied().unwrap_or(0) >= 2
            && !matches!(e.op(), ExprOp::Source(_));
        e.set_cse_cached(shared);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::blockmatrix::BlockMatrix;

    fn src(nb: usize, bs: usize) -> MatExpr {
        MatExpr::source(BlockMatrix::zeros(nb, bs).unwrap())
    }

    fn optimize(cfg: OptimizerConfig, e: &MatExpr) -> MatExpr {
        Optimizer::new(cfg).optimize(e).unwrap()
    }

    #[test]
    fn fusion_rewrites_multiply_subtract() {
        let (a, b, d) = (src(2, 4), src(2, 4), src(2, 4));
        let expr = a.multiply(&b).unwrap().subtract(&d).unwrap();
        let opt = optimize(OptimizerConfig::all(), &expr);
        assert!(matches!(opt.op(), ExprOp::MultiplySub(..)), "{opt:?}");
        // With the rule off, the shape is preserved.
        let raw = optimize(OptimizerConfig::none(), &expr);
        assert!(matches!(raw.op(), ExprOp::Subtract(..)));
    }

    #[test]
    fn fusion_respects_sharing_guard() {
        let (a, b, d) = (src(2, 4), src(2, 4), src(2, 4));
        let prod = a.multiply(&b).unwrap();
        // prod feeds both the subtract AND another consumer: fusing would
        // compute the product twice.
        let other = prod.scale(2.0);
        let root = prod
            .subtract(&d)
            .unwrap()
            .subtract(&other)
            .unwrap();
        let opt = optimize(OptimizerConfig::all(), &root);
        fn count_ops(e: &MatExpr, name: &str) -> usize {
            let mut seen = std::collections::HashSet::new();
            let mut stack = vec![e.clone()];
            let mut n = 0;
            while let Some(x) = stack.pop() {
                if !seen.insert(x.id()) {
                    continue;
                }
                if x.op().name() == name {
                    n += 1;
                }
                stack.extend(x.children());
            }
            n
        }
        assert_eq!(count_ops(&opt, "multiply_sub"), 0, "shared product must not fuse");
        assert_eq!(count_ops(&opt, "multiply"), 1);
    }

    #[test]
    fn double_transpose_cancels() {
        let a = src(2, 4);
        let expr = a.transpose().transpose();
        let opt = optimize(OptimizerConfig::all(), &expr);
        assert_eq!(opt.id(), a.id(), "(Aᵀ)ᵀ must canonicalize to A itself");
    }

    #[test]
    fn transpose_pushdown_cancels_inner_transpose() {
        let (a, b) = (src(2, 4), src(2, 4));
        // (Aᵀ·B)ᵀ  →  Bᵀ·A: one transpose instead of two.
        let expr = a.transpose().multiply(&b).unwrap().transpose();
        let opt = optimize(OptimizerConfig::all(), &expr);
        match opt.op() {
            ExprOp::Multiply(l, r) => {
                assert!(matches!(l.op(), ExprOp::Transpose(_)));
                assert_eq!(r.id(), a.id());
            }
            other => panic!("expected multiply, got {}", other.name()),
        }
        // Plain (A·B)ᵀ keeps its single transpose — pushdown would trade
        // one transpose stage for two.
        let plain = a.multiply(&b).unwrap().transpose();
        let opt = optimize(OptimizerConfig::all(), &plain);
        assert!(matches!(opt.op(), ExprOp::Transpose(_)));
    }

    #[test]
    fn scalar_folding_is_exact_only() {
        let a = src(2, 4);
        // Double negation folds to the identity (bit-exact).
        let expr = a.scale(-1.0).scale(-1.0);
        let opt = optimize(OptimizerConfig::all(), &expr);
        assert_eq!(opt.id(), a.id(), "(−1)·(−1) folds to the identity scale");
        // A ±1 factor folds into the other factor (bit-exact).
        let expr = a.scale(3.0).scale(-1.0);
        let opt = optimize(OptimizerConfig::all(), &expr);
        match opt.op() {
            ExprOp::Scale(x, s) => {
                assert_eq!(x.id(), a.id());
                assert_eq!(*s, -3.0);
            }
            other => panic!("expected scale, got {}", other.name()),
        }
        // General factors do NOT fold: s·(t·x) vs (s·t)·x re-associates a
        // rounding step, so the optimizer must leave the nest alone.
        let expr = a.scale(0.3).scale(0.5);
        let opt = optimize(OptimizerConfig::all(), &expr);
        match opt.op() {
            ExprOp::Scale(x, s) => {
                assert_eq!(*s, 0.5);
                assert!(matches!(x.op(), ExprOp::Scale(_, t) if *t == 0.3));
            }
            other => panic!("expected nested scale, got {}", other.name()),
        }
        // Identity scale drops.
        let opt = optimize(OptimizerConfig::all(), &a.scale(1.0));
        assert_eq!(opt.id(), a.id());
    }

    #[test]
    fn fusion_guard_catches_structural_duplicates() {
        // The reviewer scenario: two independently built, structurally
        // identical products — one under a subtract. Fusing would compute
        // the product twice (once fused, once for the CSE-shared node);
        // the representative-keyed use counts must block it.
        let (a, b, d) = (src(2, 4), src(2, 4), src(2, 4));
        let m1 = a.multiply(&b).unwrap();
        let m2 = a.multiply(&b).unwrap();
        let root = m1.subtract(&d).unwrap().multiply(&m2).unwrap();
        let opt = optimize(OptimizerConfig::all(), &root);
        let mut multiply_subs = 0;
        let mut multiplies = 0;
        let mut seen = std::collections::HashSet::new();
        let mut stack = vec![opt];
        while let Some(x) = stack.pop() {
            if !seen.insert(x.id()) {
                continue;
            }
            match x.op() {
                ExprOp::MultiplySub(..) => multiply_subs += 1,
                ExprOp::Multiply(..) => multiplies += 1,
                _ => {}
            }
            stack.extend(x.children());
        }
        assert_eq!(multiply_subs, 0, "duplicated product must not fuse");
        assert_eq!(multiplies, 2, "shared product + root multiply");
    }

    #[test]
    fn pushdown_guard_respects_shared_products() {
        // p = Aᵀ·B consumed both directly and through a transpose: the
        // pushdown would build a second multiply while p still executes
        // for its direct consumer — the guard must keep the cheap narrow
        // transpose instead.
        let (a, b) = (src(2, 4), src(2, 4));
        let p = a.transpose().multiply(&b).unwrap();
        let root = p.subtract(&p.transpose()).unwrap();
        let opt = optimize(OptimizerConfig::all(), &root);
        let mut multiplies = 0;
        let mut seen = std::collections::HashSet::new();
        let mut stack = vec![opt];
        while let Some(x) = stack.pop() {
            if !seen.insert(x.id()) {
                continue;
            }
            if matches!(x.op(), ExprOp::Multiply(..)) {
                multiplies += 1;
            }
            stack.extend(x.children());
        }
        assert_eq!(multiplies, 1, "shared product must not be duplicated");
    }

    #[test]
    fn cse_cache_marks_are_per_plan_not_sticky() {
        let (a, b, c) = (src(2, 4), src(2, 4), src(2, 4));
        let shared = a.multiply(&b).unwrap();
        // Plan 1: `shared` has two consumers → marked as a cache point.
        let plan1 = shared.subtract(&shared.transpose()).unwrap();
        let opt1 = optimize(OptimizerConfig::all(), &plan1);
        let canonical_shared = opt1
            .children()
            .into_iter()
            .find(|k| matches!(k.op(), ExprOp::Multiply(..)))
            .expect("left child is the canonical product");
        assert!(canonical_shared.is_cse_cached());
        // Plan 2 reuses the same subtree once: the mark must be recomputed
        // for this plan, not inherited from plan 1.
        let plan2 = shared.multiply(&c).unwrap();
        let _ = optimize(OptimizerConfig::all(), &plan2);
        assert!(
            !canonical_shared.is_cse_cached(),
            "cache mark must reflect the most recently optimized plan"
        );
    }

    #[test]
    fn cse_interns_structural_duplicates_and_marks_cache() {
        let (a, b) = (src(2, 4), src(2, 4));
        // Two independently built, structurally identical products.
        let m1 = a.multiply(&b).unwrap();
        let m2 = a.multiply(&b).unwrap();
        assert_ne!(m1.id(), m2.id());
        let root = m1.multiply(&m2).unwrap();
        let opt = optimize(OptimizerConfig::all(), &root);
        let kids = opt.children();
        assert_eq!(kids[0].id(), kids[1].id(), "CSE must intern the duplicates");
        assert!(kids[0].is_cse_cached(), "shared node is a cache point");
        assert_eq!(opt.node_count(), 4, "a, b, shared product, root");
        // Without CSE the duplicates stay distinct.
        let raw = optimize(OptimizerConfig::none(), &root);
        let kids = raw.children();
        assert_ne!(kids[0].id(), kids[1].id());
    }

    #[test]
    fn canonicalization_is_stable_across_calls() {
        let (a, b) = (src(2, 4), src(2, 4));
        let m = a.multiply(&b).unwrap();
        let first = optimize(OptimizerConfig::all(), &m);
        let second = optimize(OptimizerConfig::all(), &m);
        assert_eq!(
            first.id(),
            second.id(),
            "per-node canonical memo must keep identities stable"
        );
    }
}
