//! The block-kernel abstraction: every per-block compute the distributed
//! algorithms need, behind one trait so the same recursion can run on the
//! pure-Rust kernels (the JBlas stand-in) or on the AOT JAX/Pallas programs
//! via PJRT.

use crate::config::LeafMethod;
use crate::error::Result;
use crate::linalg::{self, Matrix};

/// Per-block compute vocabulary (mirrors `python/compile/model.py::OPS`).
///
/// Implementations must be `Send + Sync`: kernels are called from
/// worker-pool threads, and the service layer shares one backend across
/// its job-executor threads. Backends with thread-affine state (PJRT
/// handles are `!Send`) keep it in thread-locals, so the backend struct
/// itself stays freely movable.
pub trait BlockKernels: Send + Sync {
    /// Backend name for reports.
    fn name(&self) -> &'static str;

    /// C = A·B.
    fn matmul(&self, a: &Matrix, b: &Matrix) -> Result<Matrix>;

    /// C = D + A·B (block-matmul reduce step). `d` is taken by value and
    /// serves as the accumulator — native kernels add into its buffer
    /// in place, so chaining over k allocates nothing per term.
    fn matmul_acc(&self, a: &Matrix, b: &Matrix, d: Matrix) -> Result<Matrix>;

    /// C = A·B − D (SPIN's fused Schur step `V = IV − A22`).
    fn neg_matmul_sub(&self, a: &Matrix, b: &Matrix, d: &Matrix) -> Result<Matrix>;

    /// C = A − B.
    fn subtract(&self, a: &Matrix, b: &Matrix) -> Result<Matrix>;

    /// C = s·A (the paper's scalarMul payload).
    fn scale(&self, a: &Matrix, s: f64) -> Result<Matrix>;

    /// A⁻¹ for one leaf block.
    fn leaf_inverse(&self, a: &Matrix, method: LeafMethod) -> Result<Matrix>;

    /// Pivot-free leaf LU: A = L·U (baseline's leaf; errors on zero pivot).
    fn lu_factor(&self, a: &Matrix) -> Result<(Matrix, Matrix)>;

    /// L⁻¹ for a lower-triangular leaf block (baseline's leaf).
    fn invert_lower(&self, a: &Matrix) -> Result<Matrix>;

    /// Cholesky leaf factor A = L·Lᵀ for an SPD block (errors on a
    /// non-positive pivot — the SPD test). Default composes the serial
    /// kernel so every backend gets `cholesky` for free.
    fn cholesky_factor(&self, a: &Matrix) -> Result<Matrix> {
        linalg::cholesky_factor(a)
    }

    /// U⁻¹ for an upper-triangular leaf block (baseline's leaf).
    fn invert_upper(&self, a: &Matrix) -> Result<Matrix>;

    /// Fused Algorithm-1 step over a 2×2 grid of leaf blocks:
    /// returns (C11, C12, C21, C22). Optional optimization; the default
    /// composes the primitive kernels.
    fn strassen_2x2(
        &self,
        a11: &Matrix,
        a12: &Matrix,
        a21: &Matrix,
        a22: &Matrix,
        method: LeafMethod,
    ) -> Result<(Matrix, Matrix, Matrix, Matrix)> {
        let i = self.leaf_inverse(a11, method)?;
        let ii = self.matmul(a21, &i)?;
        let iii = self.matmul(&i, a12)?;
        let v = self.neg_matmul_sub(a21, &iii, a22)?;
        let vi = self.leaf_inverse(&v, method)?;
        let c12 = self.matmul(&iii, &vi)?;
        let c21 = self.matmul(&vi, &ii)?;
        let vii = self.matmul(&iii, &c21)?;
        let c11 = self.subtract(&i, &vii)?;
        let c22 = self.scale(&vi, -1.0)?;
        Ok((c11, c12, c21, c22))
    }
}

/// Pure-Rust backend over [`crate::linalg`] — always available, no
/// artifacts required. This is the "JBlas on the executor" role.
pub struct NativeBackend;

impl BlockKernels for NativeBackend {
    fn name(&self) -> &'static str {
        "native"
    }

    fn matmul(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        Ok(linalg::matmul(a, b))
    }

    fn matmul_acc(&self, a: &Matrix, b: &Matrix, d: Matrix) -> Result<Matrix> {
        Ok(linalg::matmul_acc(a, b, d))
    }

    fn neg_matmul_sub(&self, a: &Matrix, b: &Matrix, d: &Matrix) -> Result<Matrix> {
        let prod = linalg::matmul(a, b);
        prod.sub(d)
    }

    fn subtract(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        a.sub(b)
    }

    fn scale(&self, a: &Matrix, s: f64) -> Result<Matrix> {
        Ok(a.scale(s))
    }

    fn leaf_inverse(&self, a: &Matrix, method: LeafMethod) -> Result<Matrix> {
        match method {
            LeafMethod::Lu => linalg::lu_inverse(a),
            LeafMethod::GaussJordan => linalg::gauss_jordan_inverse(a),
        }
    }

    fn lu_factor(&self, a: &Matrix) -> Result<(Matrix, Matrix)> {
        linalg::lu_decompose_nopivot(a)
    }

    fn invert_lower(&self, a: &Matrix) -> Result<Matrix> {
        linalg::invert_lower(a)
    }

    fn invert_upper(&self, a: &Matrix) -> Result<Matrix> {
        linalg::invert_upper(a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{diag_dominant, inverse_residual, matmul};
    use crate::util::Rng;

    #[test]
    fn native_matmul_matches_linalg() {
        let mut rng = Rng::new(1);
        let a = Matrix::random_uniform(16, 16, -1.0, 1.0, &mut rng);
        let b = Matrix::random_uniform(16, 16, -1.0, 1.0, &mut rng);
        let got = NativeBackend.matmul(&a, &b).unwrap();
        assert!(got.max_abs_diff(&matmul(&a, &b)) < 1e-14);
    }

    #[test]
    fn native_fused_ops() {
        let mut rng = Rng::new(2);
        let a = Matrix::random_uniform(8, 8, -1.0, 1.0, &mut rng);
        let b = Matrix::random_uniform(8, 8, -1.0, 1.0, &mut rng);
        let d = Matrix::random_uniform(8, 8, -1.0, 1.0, &mut rng);
        let acc = NativeBackend.matmul_acc(&a, &b, d.clone()).unwrap();
        let want = matmul(&a, &b).add(&d).unwrap();
        assert!(acc.max_abs_diff(&want) < 1e-13);
        let nms = NativeBackend.neg_matmul_sub(&a, &b, &d).unwrap();
        let want2 = matmul(&a, &b).sub(&d).unwrap();
        assert!(nms.max_abs_diff(&want2) < 1e-13);
    }

    #[test]
    fn native_leaf_inverse_both_methods() {
        let mut rng = Rng::new(3);
        let a = diag_dominant(24, &mut rng);
        for m in [LeafMethod::Lu, LeafMethod::GaussJordan] {
            let inv = NativeBackend.leaf_inverse(&a, m).unwrap();
            assert!(inverse_residual(&a, &inv) < 1e-12, "{m:?}");
        }
    }

    #[test]
    fn default_strassen_2x2_inverts() {
        let mut rng = Rng::new(4);
        let n = 16;
        let full = diag_dominant(2 * n, &mut rng);
        let a11 = full.submatrix(0, 0, n, n).unwrap();
        let a12 = full.submatrix(0, n, n, n).unwrap();
        let a21 = full.submatrix(n, 0, n, n).unwrap();
        let a22 = full.submatrix(n, n, n, n).unwrap();
        let (c11, c12, c21, c22) = NativeBackend
            .strassen_2x2(&a11, &a12, &a21, &a22, LeafMethod::Lu)
            .unwrap();
        let mut inv = Matrix::zeros(2 * n, 2 * n);
        inv.set_submatrix(0, 0, &c11).unwrap();
        inv.set_submatrix(0, n, &c12).unwrap();
        inv.set_submatrix(n, 0, &c21).unwrap();
        inv.set_submatrix(n, n, &c22).unwrap();
        assert!(inverse_residual(&full, &inv) < 1e-10);
    }
}
