//! PJRT execution engine: load AOT HLO text, compile once, execute many.
//!
//! One `Engine` owns a PJRT CPU client plus a compiled-executable cache
//! keyed by `(op, block_size)`. PJRT handles wrap raw pointers and are
//! `!Send`, so an `Engine` must live and die on one thread — the
//! [`super::XlaBackend`] keeps one per worker thread in a thread-local.
//!
//! Data layout: [`crate::linalg::Matrix`] is column-major; XLA's default
//! parameter/result layout for `f64[n,n]` is row-major (`{1,0}` minor-to-
//! major), so payloads are transposed on the way in and out. This copy is
//! O(bs²) against O(bs³) compute and is measured in the microbenches.

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Result, SpinError};
use crate::linalg::Matrix;
use crate::runtime::manifest::Manifest;

/// A PJRT CPU client + compiled executables for one artifacts directory.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    cache: RefCell<HashMap<(String, usize), xla::PjRtLoadedExecutable>>,
}

impl Engine {
    /// Create a client and load the manifest (compilation is lazy).
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        log::debug!(
            "PJRT engine up: platform={} artifacts={} programs={}",
            client.platform_name(),
            artifacts_dir.display(),
            manifest.len()
        );
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// True if an AOT program exists for `(op, block_size)`.
    pub fn supports(&self, op: &str, block_size: usize) -> bool {
        self.manifest.has(op, block_size)
    }

    fn compile(&self, op: &str, block_size: usize) -> Result<()> {
        let entry = self.manifest.get(op, block_size).ok_or_else(|| {
            SpinError::artifact(format!("no artifact for op `{op}` at block size {block_size}"))
        })?;
        let path: PathBuf = self.manifest.hlo_path(entry);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str()
                .ok_or_else(|| SpinError::artifact("non-UTF8 artifact path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.cache
            .borrow_mut()
            .insert((op.to_string(), block_size), exe);
        Ok(())
    }

    /// Execute `(op, block_size)` on block payloads + scalars, returning the
    /// output blocks. Compiles and caches the executable on first use.
    pub fn run(
        &self,
        op: &str,
        block_size: usize,
        blocks: &[&Matrix],
        scalars: &[f64],
    ) -> Result<Vec<Matrix>> {
        let (n_blocks, n_scalars, n_outputs) = {
            let entry = self.manifest.get(op, block_size).ok_or_else(|| {
                SpinError::artifact(format!(
                    "no artifact for op `{op}` at block size {block_size}"
                ))
            })?;
            (
                entry.num_block_inputs,
                entry.num_scalar_inputs,
                entry.num_outputs,
            )
        };
        if blocks.len() != n_blocks || scalars.len() != n_scalars {
            return Err(SpinError::artifact(format!(
                "op `{op}` expects {n_blocks} blocks + {n_scalars} scalars, \
                 got {} + {}",
                blocks.len(),
                scalars.len()
            )));
        }
        for m in blocks {
            if m.rows() != block_size || m.cols() != block_size {
                return Err(SpinError::shape(format!(
                    "op `{op}` artifact is {block_size}x{block_size}, got {}x{}",
                    m.rows(),
                    m.cols()
                )));
            }
        }

        if !self.cache.borrow().contains_key(&(op.to_string(), block_size)) {
            self.compile(op, block_size)?;
        }

        let mut args: Vec<xla::Literal> = Vec::with_capacity(blocks.len() + scalars.len());
        for m in blocks {
            args.push(matrix_to_literal(m)?);
        }
        for &s in scalars {
            args.push(xla::Literal::scalar(s));
        }

        let cache = self.cache.borrow();
        let exe = cache.get(&(op.to_string(), block_size)).ok_or_else(|| {
            SpinError::artifact(format!(
                "kernel for `{op}` at block size {block_size} missing after compile"
            ))
        })?;
        let result = exe.execute::<xla::Literal>(&args)?[0][0].to_literal_sync()?;
        drop(cache);

        // aot.py lowers with return_tuple=True: output is always a tuple.
        let outs = result.to_tuple()?;
        if outs.len() != n_outputs {
            return Err(SpinError::Xla(format!(
                "op `{op}` returned {} outputs, manifest says {n_outputs}",
                outs.len()
            )));
        }
        outs.into_iter()
            .map(|lit| literal_to_matrix(&lit, block_size))
            .collect()
    }
}

/// Column-major Matrix -> row-major XLA literal of shape [n, n].
fn matrix_to_literal(m: &Matrix) -> Result<xla::Literal> {
    let (rows, cols) = (m.rows(), m.cols());
    let mut rm = vec![0.0f64; rows * cols];
    for j in 0..cols {
        let col = m.col(j);
        for i in 0..rows {
            rm[i * cols + j] = col[i];
        }
    }
    Ok(xla::Literal::vec1(&rm).reshape(&[rows as i64, cols as i64])?)
}

/// Row-major XLA literal -> column-major Matrix.
fn literal_to_matrix(lit: &xla::Literal, block_size: usize) -> Result<Matrix> {
    let rm = lit.to_vec::<f64>()?;
    if rm.len() != block_size * block_size {
        return Err(SpinError::Xla(format!(
            "output literal has {} elements, expected {}",
            rm.len(),
            block_size * block_size
        )));
    }
    let mut out = Matrix::zeros(block_size, block_size);
    for i in 0..block_size {
        for j in 0..block_size {
            out.set(i, j, rm[i * block_size + j]);
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{self, diag_dominant, inverse_residual};
    use crate::util::Rng;

    fn artifacts_dir() -> Option<PathBuf> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn layout_round_trip() {
        let mut rng = Rng::new(1);
        let m = Matrix::random_uniform(5, 5, -1.0, 1.0, &mut rng);
        let lit = matrix_to_literal(&m).unwrap();
        let back = literal_to_matrix(&lit, 5).unwrap();
        assert_eq!(back.max_abs_diff(&m), 0.0);
    }

    // The remaining tests exercise the real PJRT path and only run after
    // `make artifacts`.

    #[test]
    fn engine_matmul_matches_native() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::new(&dir).unwrap();
        let mut rng = Rng::new(2);
        let a = Matrix::random_uniform(64, 64, -1.0, 1.0, &mut rng);
        let b = Matrix::random_uniform(64, 64, -1.0, 1.0, &mut rng);
        let out = engine.run("matmul", 64, &[&a, &b], &[]).unwrap();
        assert_eq!(out.len(), 1);
        let want = linalg::matmul(&a, &b);
        assert!(out[0].max_abs_diff(&want) < 1e-10);
    }

    #[test]
    fn engine_leaf_inverse_works() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::new(&dir).unwrap();
        let mut rng = Rng::new(3);
        let a = diag_dominant(32, &mut rng);
        let out = engine.run("leaf_inverse", 32, &[&a], &[]).unwrap();
        assert!(inverse_residual(&a, &out[0]) < 1e-10);
    }

    #[test]
    fn engine_scale_uses_scalar_input() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::new(&dir).unwrap();
        let mut rng = Rng::new(4);
        let a = Matrix::random_uniform(16, 16, -1.0, 1.0, &mut rng);
        let out = engine.run("scale", 16, &[&a], &[-2.0]).unwrap();
        assert!(out[0].max_abs_diff(&a.scale(-2.0)) < 1e-14);
    }

    #[test]
    fn engine_strassen_2x2_four_outputs() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::new(&dir).unwrap();
        let mut rng = Rng::new(5);
        let n = 16;
        let full = diag_dominant(2 * n, &mut rng);
        let a11 = full.submatrix(0, 0, n, n).unwrap();
        let a12 = full.submatrix(0, n, n, n).unwrap();
        let a21 = full.submatrix(n, 0, n, n).unwrap();
        let a22 = full.submatrix(n, n, n, n).unwrap();
        let out = engine
            .run("strassen_2x2", n, &[&a11, &a12, &a21, &a22], &[])
            .unwrap();
        assert_eq!(out.len(), 4);
        let mut inv = Matrix::zeros(2 * n, 2 * n);
        inv.set_submatrix(0, 0, &out[0]).unwrap();
        inv.set_submatrix(0, n, &out[1]).unwrap();
        inv.set_submatrix(n, 0, &out[2]).unwrap();
        inv.set_submatrix(n, n, &out[3]).unwrap();
        assert!(inverse_residual(&full, &inv) < 1e-9);
    }

    #[test]
    fn engine_rejects_unknown_op_and_bad_shapes() {
        let Some(dir) = artifacts_dir() else { return };
        let engine = Engine::new(&dir).unwrap();
        let a = Matrix::zeros(16, 16);
        assert!(engine.run("nonexistent", 16, &[&a], &[]).is_err());
        assert!(engine.run("matmul", 16, &[&a], &[]).is_err()); // arity
        let b = Matrix::zeros(8, 8);
        assert!(engine.run("matmul", 16, &[&b, &b], &[]).is_err()); // shape
    }
}
