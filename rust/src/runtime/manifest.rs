//! `artifacts/manifest.json` loader — the contract between `make artifacts`
//! (python, build time) and the Rust runtime (request time).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::error::{Result, SpinError};
use crate::ser::json::Json;

/// Manifest schema version this runtime understands.
pub const SUPPORTED_VERSION: i64 = 2;

/// One AOT-compiled (op, block_size) program.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub op: String,
    pub block_size: usize,
    /// HLO text file, relative to the artifacts dir.
    pub file: String,
    pub num_block_inputs: usize,
    pub num_scalar_inputs: usize,
    pub num_outputs: usize,
    pub dtype: String,
}

/// Parsed manifest with (op, block_size) lookup.
#[derive(Debug, Clone)]
pub struct Manifest {
    dir: PathBuf,
    pub dtype: String,
    pub block_sizes: Vec<usize>,
    entries: HashMap<(String, usize), ManifestEntry>,
}

impl Manifest {
    /// Load `dir/manifest.json`.
    pub fn load(dir: &Path) -> Result<Self> {
        let path = dir.join("manifest.json");
        if !path.exists() {
            return Err(SpinError::artifact(format!(
                "{} not found — run `make artifacts` first",
                path.display()
            )));
        }
        let json = Json::from_file(&path)?;
        Self::from_json(dir, &json)
    }

    pub fn from_json(dir: &Path, json: &Json) -> Result<Self> {
        let version = json
            .req("version")?
            .as_i64()
            .ok_or_else(|| SpinError::artifact("manifest `version` must be an integer"))?;
        if version != SUPPORTED_VERSION {
            return Err(SpinError::artifact(format!(
                "manifest version {version} unsupported (runtime expects {SUPPORTED_VERSION})"
            )));
        }
        let dtype = json
            .req("dtype")?
            .as_str()
            .ok_or_else(|| SpinError::artifact("manifest `dtype` must be a string"))?
            .to_string();
        let block_sizes = json
            .req("block_sizes")?
            .as_array()
            .ok_or_else(|| SpinError::artifact("manifest `block_sizes` must be an array"))?
            .iter()
            .map(|v| {
                v.as_usize()
                    .ok_or_else(|| SpinError::artifact("block size must be a positive integer"))
            })
            .collect::<Result<Vec<_>>>()?;
        let mut entries = HashMap::new();
        for e in json
            .req("entries")?
            .as_array()
            .ok_or_else(|| SpinError::artifact("manifest `entries` must be an array"))?
        {
            let entry = ManifestEntry {
                op: e
                    .req("op")?
                    .as_str()
                    .ok_or_else(|| SpinError::artifact("entry `op` must be a string"))?
                    .to_string(),
                block_size: e
                    .req("block_size")?
                    .as_usize()
                    .ok_or_else(|| SpinError::artifact("entry `block_size` invalid"))?,
                file: e
                    .req("file")?
                    .as_str()
                    .ok_or_else(|| SpinError::artifact("entry `file` must be a string"))?
                    .to_string(),
                num_block_inputs: e
                    .req("num_block_inputs")?
                    .as_usize()
                    .ok_or_else(|| SpinError::artifact("entry `num_block_inputs` invalid"))?,
                num_scalar_inputs: e
                    .req("num_scalar_inputs")?
                    .as_usize()
                    .ok_or_else(|| SpinError::artifact("entry `num_scalar_inputs` invalid"))?,
                num_outputs: e
                    .req("num_outputs")?
                    .as_usize()
                    .ok_or_else(|| SpinError::artifact("entry `num_outputs` invalid"))?,
                dtype: dtype.clone(),
            };
            entries.insert((entry.op.clone(), entry.block_size), entry);
        }
        if entries.is_empty() {
            return Err(SpinError::artifact("manifest has no entries"));
        }
        Ok(Manifest {
            dir: dir.to_path_buf(),
            dtype,
            block_sizes,
            entries,
        })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn get(&self, op: &str, block_size: usize) -> Option<&ManifestEntry> {
        self.entries.get(&(op.to_string(), block_size))
    }

    pub fn has(&self, op: &str, block_size: usize) -> bool {
        self.get(op, block_size).is_some()
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, entry: &ManifestEntry) -> PathBuf {
        self.dir.join(&entry.file)
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Json {
        Json::parse(
            r#"{
              "version": 2,
              "dtype": "float64",
              "block_sizes": [16, 32],
              "entries": [
                {"op": "matmul", "block_size": 16, "file": "matmul_b16.hlo.txt",
                 "num_block_inputs": 2, "num_scalar_inputs": 0, "num_outputs": 1},
                {"op": "scale", "block_size": 32, "file": "scale_b32.hlo.txt",
                 "num_block_inputs": 1, "num_scalar_inputs": 1, "num_outputs": 1}
              ]
            }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_entries() {
        let m = Manifest::from_json(Path::new("/tmp/a"), &sample_json()).unwrap();
        assert_eq!(m.len(), 2);
        assert_eq!(m.dtype, "float64");
        assert_eq!(m.block_sizes, vec![16, 32]);
        let e = m.get("matmul", 16).unwrap();
        assert_eq!(e.num_block_inputs, 2);
        assert!(m.has("scale", 32));
        assert!(!m.has("matmul", 32));
        assert_eq!(m.hlo_path(e), PathBuf::from("/tmp/a/matmul_b16.hlo.txt"));
    }

    #[test]
    fn rejects_wrong_version() {
        let mut j = sample_json();
        if let Json::Object(ref mut map) = j {
            map.insert("version".into(), Json::Number(1.0));
        }
        assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err());
    }

    #[test]
    fn rejects_missing_fields() {
        let j = Json::parse(r#"{"version": 2}"#).unwrap();
        assert!(Manifest::from_json(Path::new("/tmp"), &j).is_err());
    }

    #[test]
    fn missing_file_is_artifact_error() {
        let err = Manifest::load(Path::new("/nonexistent-dir-xyz")).unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn loads_real_manifest_when_built() {
        // Integration-ish: only runs when `make artifacts` has been executed.
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let m = Manifest::load(&dir).unwrap();
            assert!(m.has("matmul", 64));
            assert!(m.has("leaf_inverse", 128));
            assert!(m.has("strassen_2x2", 32));
        }
    }
}
