//! Runtime layer: the block-kernel abstraction and its two backends —
//! pure-Rust (`native`) and the AOT JAX/Pallas programs executed through
//! the PJRT CPU client (`xla`), plus the artifact manifest loader.

mod backend;
mod engine;
mod manifest;
mod xla_backend;

pub use backend::{BlockKernels, NativeBackend};
pub use engine::Engine;
pub use manifest::{Manifest, ManifestEntry};
pub use xla_backend::XlaBackend;

use crate::config::{BackendKind, ClusterConfig};
use crate::error::Result;

/// Instantiate the configured backend.
pub fn make_backend(config: &ClusterConfig) -> Result<Box<dyn BlockKernels>> {
    match config.backend {
        BackendKind::Native => Ok(Box::new(NativeBackend)),
        BackendKind::Xla => Ok(Box::new(XlaBackend::new(config.artifacts_dir.clone())?)),
    }
}
