//! [`BlockKernels`] backend executing the AOT JAX/Pallas programs via PJRT.
//!
//! PJRT handles are `!Send`, so each worker thread lazily builds its own
//! [`Engine`] (client + executable cache) in a thread-local, keyed by the
//! artifacts directory. Block sizes without an AOT program fall back to the
//! native kernels with a warning (counted, so experiments can report purity).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::PathBuf;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::LeafMethod;
use crate::error::Result;
use crate::linalg::Matrix;
use crate::runtime::backend::{BlockKernels, NativeBackend};
use crate::runtime::engine::Engine;
use crate::runtime::manifest::Manifest;

thread_local! {
    /// One engine per (thread, artifacts dir).
    static ENGINES: RefCell<HashMap<PathBuf, Rc<Engine>>> = RefCell::new(HashMap::new());
}

/// PJRT-backed block kernels.
pub struct XlaBackend {
    artifacts_dir: PathBuf,
    /// Ops satisfied natively because no artifact matched.
    fallbacks: AtomicU64,
    /// Ops executed through PJRT.
    executed: AtomicU64,
}

impl XlaBackend {
    /// Validates the manifest eagerly (fail fast on a missing
    /// `make artifacts`), then hands out thread-local engines on demand.
    pub fn new(artifacts_dir: PathBuf) -> Result<Self> {
        let _ = Manifest::load(&artifacts_dir)?;
        Ok(XlaBackend {
            artifacts_dir,
            fallbacks: AtomicU64::new(0),
            executed: AtomicU64::new(0),
        })
    }

    /// Number of block ops that fell back to the native kernels.
    pub fn fallback_count(&self) -> u64 {
        self.fallbacks.load(Ordering::Relaxed)
    }

    /// Number of block ops executed through PJRT.
    pub fn executed_count(&self) -> u64 {
        self.executed.load(Ordering::Relaxed)
    }

    fn with_engine<T>(&self, f: impl FnOnce(&Engine) -> Result<T>) -> Result<T> {
        ENGINES.with(|cell| {
            let mut map = cell.borrow_mut();
            let engine = match map.get(&self.artifacts_dir) {
                Some(e) => Rc::clone(e),
                None => {
                    let e = Rc::new(Engine::new(&self.artifacts_dir)?);
                    map.insert(self.artifacts_dir.clone(), Rc::clone(&e));
                    e
                }
            };
            drop(map);
            f(&engine)
        })
    }

    /// Run `(op, bs)` through PJRT if an artifact exists, else fall back.
    fn run_or_fallback(
        &self,
        op: &str,
        bs: usize,
        blocks: &[&Matrix],
        scalars: &[f64],
        native: impl FnOnce() -> Result<Matrix>,
    ) -> Result<Matrix> {
        let supported = self.with_engine(|e| Ok(e.supports(op, bs)))?;
        if supported {
            self.executed.fetch_add(1, Ordering::Relaxed);
            self.with_engine(|e| Ok(e.run(op, bs, blocks, scalars)?.remove(0)))
        } else {
            log::warn!("no artifact for `{op}` b={bs}; using native fallback");
            self.fallbacks.fetch_add(1, Ordering::Relaxed);
            native()
        }
    }
}

impl BlockKernels for XlaBackend {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn matmul(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        let bs = a.rows();
        if a.is_square() && b.is_square() && a.rows() == b.rows() {
            self.run_or_fallback("matmul", bs, &[a, b], &[], || NativeBackend.matmul(a, b))
        } else {
            self.fallbacks.fetch_add(1, Ordering::Relaxed);
            NativeBackend.matmul(a, b)
        }
    }

    fn matmul_acc(&self, a: &Matrix, b: &Matrix, d: Matrix) -> Result<Matrix> {
        // Inlined run_or_fallback: the PJRT branch borrows `d` for the
        // input buffer, the native branch consumes it as the accumulator.
        let bs = a.rows();
        if self.with_engine(|e| Ok(e.supports("matmul_acc", bs)))? {
            self.executed.fetch_add(1, Ordering::Relaxed);
            self.with_engine(|e| Ok(e.run("matmul_acc", bs, &[a, b, &d], &[])?.remove(0)))
        } else {
            log::warn!("no artifact for `matmul_acc` b={bs}; using native fallback");
            self.fallbacks.fetch_add(1, Ordering::Relaxed);
            NativeBackend.matmul_acc(a, b, d)
        }
    }

    fn neg_matmul_sub(&self, a: &Matrix, b: &Matrix, d: &Matrix) -> Result<Matrix> {
        let bs = a.rows();
        self.run_or_fallback("neg_matmul_sub", bs, &[a, b, d], &[], || {
            NativeBackend.neg_matmul_sub(a, b, d)
        })
    }

    fn subtract(&self, a: &Matrix, b: &Matrix) -> Result<Matrix> {
        let bs = a.rows();
        self.run_or_fallback("subtract", bs, &[a, b], &[], || NativeBackend.subtract(a, b))
    }

    fn scale(&self, a: &Matrix, s: f64) -> Result<Matrix> {
        let bs = a.rows();
        self.run_or_fallback("scale", bs, &[a], &[s], || NativeBackend.scale(a, s))
    }

    fn leaf_inverse(&self, a: &Matrix, method: LeafMethod) -> Result<Matrix> {
        // The AOT leaf kernel implements Gauss-Jordan; honor an explicit LU
        // request via the native path.
        let bs = a.rows();
        match method {
            LeafMethod::GaussJordan => self.run_or_fallback("leaf_inverse", bs, &[a], &[], || {
                NativeBackend.leaf_inverse(a, method)
            }),
            LeafMethod::Lu => {
                self.fallbacks.fetch_add(1, Ordering::Relaxed);
                NativeBackend.leaf_inverse(a, method)
            }
        }
    }

    fn lu_factor(&self, a: &Matrix) -> Result<(Matrix, Matrix)> {
        let bs = a.rows();
        let supported = self.with_engine(|e| Ok(e.supports("lu_factor", bs)))?;
        if supported {
            self.executed.fetch_add(1, Ordering::Relaxed);
            let mut outs = self.with_engine(|e| e.run("lu_factor", bs, &[a], &[]))?;
            let u = outs.remove(1);
            let l = outs.remove(0);
            Ok((l, u))
        } else {
            log::warn!("no artifact for `lu_factor` b={bs}; using native fallback");
            self.fallbacks.fetch_add(1, Ordering::Relaxed);
            NativeBackend.lu_factor(a)
        }
    }

    fn invert_lower(&self, a: &Matrix) -> Result<Matrix> {
        let bs = a.rows();
        self.run_or_fallback("invert_lower", bs, &[a], &[], || {
            NativeBackend.invert_lower(a)
        })
    }

    fn invert_upper(&self, a: &Matrix) -> Result<Matrix> {
        let bs = a.rows();
        self.run_or_fallback("invert_upper", bs, &[a], &[], || {
            NativeBackend.invert_upper(a)
        })
    }

    fn strassen_2x2(
        &self,
        a11: &Matrix,
        a12: &Matrix,
        a21: &Matrix,
        a22: &Matrix,
        method: LeafMethod,
    ) -> Result<(Matrix, Matrix, Matrix, Matrix)> {
        let bs = a11.rows();
        let supported = self.with_engine(|e| Ok(e.supports("strassen_2x2", bs)))?;
        if supported {
            self.executed.fetch_add(1, Ordering::Relaxed);
            let mut outs =
                self.with_engine(|e| e.run("strassen_2x2", bs, &[a11, a12, a21, a22], &[]))?;
            let c22 = outs.remove(3);
            let c21 = outs.remove(2);
            let c12 = outs.remove(1);
            let c11 = outs.remove(0);
            Ok((c11, c12, c21, c22))
        } else {
            log::warn!("no artifact for `strassen_2x2` b={bs}; composing natively");
            self.fallbacks.fetch_add(1, Ordering::Relaxed);
            NativeBackend.strassen_2x2(a11, a12, a21, a22, method)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{self, diag_dominant, inverse_residual};
    use crate::util::Rng;
    use std::path::Path;

    fn backend() -> Option<XlaBackend> {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json")
            .exists()
            .then(|| XlaBackend::new(dir).unwrap())
    }

    #[test]
    fn missing_artifacts_dir_fails_fast() {
        assert!(XlaBackend::new(PathBuf::from("/no/such/dir")).is_err());
    }

    #[test]
    fn xla_matmul_matches_native() {
        let Some(be) = backend() else { return };
        let mut rng = Rng::new(1);
        let a = Matrix::random_uniform(64, 64, -1.0, 1.0, &mut rng);
        let b = Matrix::random_uniform(64, 64, -1.0, 1.0, &mut rng);
        let got = be.matmul(&a, &b).unwrap();
        assert!(got.max_abs_diff(&linalg::matmul(&a, &b)) < 1e-10);
        assert_eq!(be.executed_count(), 1);
        assert_eq!(be.fallback_count(), 0);
    }

    #[test]
    fn xla_leaf_inverse_gj() {
        let Some(be) = backend() else { return };
        let mut rng = Rng::new(2);
        let a = diag_dominant(128, &mut rng);
        let inv = be.leaf_inverse(&a, LeafMethod::GaussJordan).unwrap();
        assert!(inverse_residual(&a, &inv) < 1e-10);
    }

    #[test]
    fn unsupported_block_size_falls_back() {
        let Some(be) = backend() else { return };
        let mut rng = Rng::new(3);
        // 8 is not among the default lowered block sizes {16,32,64,128,256}.
        let a = Matrix::random_uniform(8, 8, -1.0, 1.0, &mut rng);
        let b = Matrix::random_uniform(8, 8, -1.0, 1.0, &mut rng);
        let got = be.matmul(&a, &b).unwrap();
        assert!(got.max_abs_diff(&linalg::matmul(&a, &b)) < 1e-12);
        assert!(be.fallback_count() > 0);
    }

    #[test]
    fn xla_scale_and_subtract() {
        let Some(be) = backend() else { return };
        let mut rng = Rng::new(4);
        let a = Matrix::random_uniform(32, 32, -1.0, 1.0, &mut rng);
        let b = Matrix::random_uniform(32, 32, -1.0, 1.0, &mut rng);
        assert!(be.scale(&a, 3.0).unwrap().max_abs_diff(&a.scale(3.0)) < 1e-14);
        assert!(be
            .subtract(&a, &b)
            .unwrap()
            .max_abs_diff(&a.sub(&b).unwrap())
            < 1e-14);
    }

    #[test]
    fn xla_strassen_2x2_matches_native_composition() {
        let Some(be) = backend() else { return };
        let mut rng = Rng::new(5);
        let n = 32;
        let full = diag_dominant(2 * n, &mut rng);
        let a11 = full.submatrix(0, 0, n, n).unwrap();
        let a12 = full.submatrix(0, n, n, n).unwrap();
        let a21 = full.submatrix(n, 0, n, n).unwrap();
        let a22 = full.submatrix(n, n, n, n).unwrap();
        let (c11, c12, c21, c22) = be
            .strassen_2x2(&a11, &a12, &a21, &a22, LeafMethod::GaussJordan)
            .unwrap();
        let (n11, n12, n21, n22) = NativeBackend
            .strassen_2x2(&a11, &a12, &a21, &a22, LeafMethod::GaussJordan)
            .unwrap();
        assert!(c11.max_abs_diff(&n11) < 1e-8);
        assert!(c12.max_abs_diff(&n12) < 1e-8);
        assert!(c21.max_abs_diff(&n21) < 1e-8);
        assert!(c22.max_abs_diff(&n22) < 1e-8);
    }
}
