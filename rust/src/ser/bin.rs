//! Binary on-disk matrix format — the HDFS stand-in.
//!
//! A *dense file* holds one matrix: magic `SPINMAT1`, u64 rows, u64 cols,
//! then `rows*cols` little-endian f64 in column-major order (the paper's
//! `BlockMatrix` stores block payloads column-major).
//!
//! A *block store* is a directory with `meta.json` (grid shape) and one
//! dense file per block, `block_<row>_<col>.mat` — the unit of distribution.

use std::fs;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::error::{Result, SpinError};
use crate::linalg::Matrix;
use crate::ser::json::Json;

const MAGIC: &[u8; 8] = b"SPINMAT1";

/// Write one dense matrix to `path`.
pub fn write_matrix(path: &Path, m: &Matrix) -> Result<()> {
    let file = fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    w.write_all(MAGIC)?;
    w.write_all(&(m.rows() as u64).to_le_bytes())?;
    w.write_all(&(m.cols() as u64).to_le_bytes())?;
    for &v in m.data() {
        w.write_all(&v.to_le_bytes())?;
    }
    w.flush()?;
    Ok(())
}

/// Read one dense matrix from `path`.
pub fn read_matrix(path: &Path) -> Result<Matrix> {
    let file = fs::File::open(path)?;
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(SpinError::artifact(format!(
            "{}: bad magic (not a SPINMAT1 file)",
            path.display()
        )));
    }
    let mut u64buf = [0u8; 8];
    r.read_exact(&mut u64buf)?;
    let rows = u64::from_le_bytes(u64buf) as usize;
    r.read_exact(&mut u64buf)?;
    let cols = u64::from_le_bytes(u64buf) as usize;
    let count = rows
        .checked_mul(cols)
        .ok_or_else(|| SpinError::artifact("matrix dims overflow"))?;
    let mut bytes = vec![0u8; count * 8];
    r.read_exact(&mut bytes)?;
    let data: Vec<f64> = bytes
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]]))
        .collect();
    Matrix::from_vec(rows, cols, data)
}

/// Fresh identity token for one store generation. Every (re)creation of
/// a store gets a new id, so lazy readers that recorded the id at plan
/// time can detect an in-place re-ingest and fail loudly instead of
/// silently mixing old cached intermediates with new bytes.
fn new_store_id() -> String {
    let nanos = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_nanos())
        .unwrap_or(0);
    format!("{}-{nanos:x}", std::process::id())
}

/// Write a block grid (row-major iteration of an `nblocks × nblocks` grid of
/// equally sized square blocks) into a block-store directory.
pub fn write_block_store(
    dir: &Path,
    nblocks: usize,
    block_size: usize,
    blocks: impl Iterator<Item = ((usize, usize), Matrix)>,
) -> Result<()> {
    fs::create_dir_all(dir)?;
    let meta = Json::object(vec![
        ("format", Json::str("spin-block-store-v1")),
        ("nblocks", Json::num(nblocks as f64)),
        ("block_size", Json::num(block_size as f64)),
        ("store_id", Json::str(new_store_id())),
    ]);
    meta.to_file(&dir.join("meta.json"))?;
    for ((bi, bj), m) in blocks {
        if m.rows() != block_size || m.cols() != block_size {
            return Err(SpinError::shape(format!(
                "block ({bi},{bj}) is {}x{}, store expects {block_size}",
                m.rows(),
                m.cols()
            )));
        }
        write_matrix(&dir.join(format!("block_{bi}_{bj}.mat")), &m)?;
    }
    Ok(())
}

/// Block-store metadata.
pub struct BlockStoreMeta {
    pub nblocks: usize,
    pub block_size: usize,
    /// Identity of this store generation (`None` for stores written
    /// before the id was introduced) — see `new_store_id`.
    pub store_id: Option<String>,
}

/// Read block-store metadata.
pub fn read_block_store_meta(dir: &Path) -> Result<BlockStoreMeta> {
    let meta = Json::from_file(&dir.join("meta.json"))?;
    if meta.req("format")?.as_str() != Some("spin-block-store-v1") {
        return Err(SpinError::artifact(format!(
            "{}: not a spin block store",
            dir.display()
        )));
    }
    Ok(BlockStoreMeta {
        nblocks: meta
            .req("nblocks")?
            .as_usize()
            .ok_or_else(|| SpinError::artifact("bad nblocks"))?,
        block_size: meta
            .req("block_size")?
            .as_usize()
            .ok_or_else(|| SpinError::artifact("bad block_size"))?,
        store_id: meta
            .get("store_id")
            .and_then(Json::as_str)
            .map(str::to_string),
    })
}

/// Read one block from a block store.
pub fn read_block(dir: &Path, bi: usize, bj: usize) -> Result<Matrix> {
    read_matrix(&dir.join(format!("block_{bi}_{bj}.mat")))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("spin_bin_test_{tag}_{}", std::process::id()));
        let _ = fs::remove_dir_all(&d);
        fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn matrix_round_trip() {
        let d = tmpdir("rt");
        let mut rng = Rng::new(1);
        let m = Matrix::random_uniform(7, 5, -3.0, 3.0, &mut rng);
        let path = d.join("m.mat");
        write_matrix(&path, &m).unwrap();
        let back = read_matrix(&path).unwrap();
        assert_eq!(back.rows(), 7);
        assert_eq!(back.cols(), 5);
        assert_eq!(back.data(), m.data());
    }

    #[test]
    fn rejects_bad_magic() {
        let d = tmpdir("magic");
        let path = d.join("bad.mat");
        fs::write(&path, b"NOTAMATRIX______").unwrap();
        assert!(read_matrix(&path).is_err());
    }

    #[test]
    fn block_store_round_trip() {
        let d = tmpdir("store");
        let mut rng = Rng::new(2);
        let blocks: Vec<((usize, usize), Matrix)> = (0..2)
            .flat_map(|i| (0..2).map(move |j| (i, j)))
            .map(|(i, j)| ((i, j), Matrix::random_uniform(4, 4, 0.0, 1.0, &mut rng.fork((i * 2 + j) as u64))))
            .collect();
        let expect = blocks.clone();
        write_block_store(&d.join("s"), 2, 4, blocks.into_iter()).unwrap();
        let meta = read_block_store_meta(&d.join("s")).unwrap();
        assert_eq!(meta.nblocks, 2);
        assert_eq!(meta.block_size, 4);
        for ((i, j), m) in expect {
            let back = read_block(&d.join("s"), i, j).unwrap();
            assert_eq!(back.data(), m.data(), "block {i},{j}");
        }
    }

    #[test]
    fn block_store_rejects_wrong_size() {
        let d = tmpdir("wrong");
        let m = Matrix::zeros(3, 3);
        let r = write_block_store(&d.join("s"), 1, 4, vec![((0usize, 0usize), m)].into_iter());
        assert!(r.is_err());
    }
}
