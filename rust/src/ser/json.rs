//! Minimal JSON: recursive-descent parser + writer.
//!
//! Used for `artifacts/manifest.json`, cluster/job config files and
//! experiment result dumps.  Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP (sufficient for our ASCII data).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Result, SpinError};

/// A JSON value. Objects use a BTreeMap for deterministic output ordering.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Number(f64),
    String(String),
    Array(Vec<Json>),
    Object(BTreeMap<String, Json>),
}

impl Json {
    // ---------- accessors ----------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Object(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required config fields.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| SpinError::config(format!("missing required key `{key}`")))
    }

    /// Strict-deserialization guard: errors if this object holds a key
    /// outside `known`, naming the offending key and the accepted set so
    /// a client typo fails at parse time instead of silently running
    /// defaults. Non-objects pass (their shape errors surface elsewhere).
    pub fn check_known_keys(&self, context: &str, known: &[&str]) -> Result<()> {
        let Json::Object(map) = self else {
            return Ok(());
        };
        for key in map.keys() {
            if !known.contains(&key.as_str()) {
                return Err(SpinError::config(format!(
                    "unknown {context} key `{key}` (expected one of: {})",
                    known.join(", ")
                )));
            }
        }
        Ok(())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Number(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Number(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|v| usize::try_from(v).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::String(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Array(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Object(m) => Some(m),
            _ => None,
        }
    }

    // ---------- builders ----------

    pub fn object(pairs: Vec<(&str, Json)>) -> Json {
        Json::Object(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Number(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::String(s.into())
    }

    // ---------- io ----------

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser::new(text);
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if !p.eof() {
            return Err(p.err("trailing content after JSON value"));
        }
        Ok(v)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Json> {
        Json::parse(&std::fs::read_to_string(path)?)
    }

    pub fn to_file(&self, path: &std::path::Path) -> Result<()> {
        Ok(std::fs::write(path, self.pretty())?)
    }

    /// Compact single-line rendering.
    pub fn compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty rendering with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Number(x) => write_number(out, *x),
            Json::String(s) => write_escaped(out, s),
            Json::Array(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Object(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        out.push_str(&" ".repeat(w * depth));
    }
}

fn write_number(out: &mut String, x: f64) {
    if x.fract() == 0.0 && x.abs() < 9e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    line: usize,
    col: usize,
}

impl<'a> Parser<'a> {
    fn new(text: &'a str) -> Self {
        Parser {
            bytes: text.as_bytes(),
            pos: 0,
            line: 1,
            col: 1,
        }
    }

    fn err(&self, msg: impl Into<String>) -> SpinError {
        SpinError::Json {
            msg: msg.into(),
            line: self.line,
            col: self.col,
        }
    }

    fn eof(&self) -> bool {
        self.pos >= self.bytes.len()
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        if b == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.bump();
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        match self.bump() {
            Some(got) if got == b => Ok(()),
            Some(got) => Err(self.err(format!(
                "expected `{}`, found `{}`",
                b as char, got as char
            ))),
            None => Err(self.err(format!("expected `{}`, found EOF", b as char))),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        for &b in word.as_bytes() {
            self.expect(b)?;
        }
        Ok(value)
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::String(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(self.err(format!("unexpected character `{}`", c as char))),
            None => Err(self.err("unexpected EOF")),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.bump();
            return Ok(Json::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Object(map)),
                _ => return Err(self.err("expected `,` or `}` in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.bump();
            return Ok(Json::Array(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Array(items)),
                _ => return Err(self.err("expected `,` or `]` in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("EOF in \\u"))?;
                            let d = (c as char)
                                .to_digit(16)
                                .ok_or_else(|| self.err("bad hex in \\u"))?;
                            code = code * 16 + d;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences.
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    for _ in 1..len {
                        self.bump();
                    }
                    let chunk = &self.bytes[start..start + len];
                    s.push_str(
                        std::str::from_utf8(chunk)
                            .map_err(|_| self.err("invalid UTF-8 in string"))?,
                    );
                }
                None => return Err(self.err("EOF inside string")),
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.bump();
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.bump();
        }
        if self.peek() == Some(b'.') {
            self.bump();
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.bump();
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.bump();
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.bump();
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.bump();
            }
        }
        // The scanned range is ASCII digits/sign/exponent by construction.
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap_or("");
        text.parse::<f64>()
            .map(Json::Number)
            .map_err(|_| self.err(format!("invalid number `{text}`")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Number(-350.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::String("a\nb".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_array().unwrap();
        assert_eq!(arr[1].as_i64(), Some(2));
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn round_trip_pretty_and_compact() {
        let src = r#"{"entries":[{"op":"matmul","block_size":64}],"version":2}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
        assert_eq!(Json::parse(&v.compact()).unwrap(), v);
    }

    #[test]
    fn unicode_and_escapes_round_trip() {
        let v = Json::String("héllo \"wörld\" \t ∞".into());
        assert_eq!(Json::parse(&v.compact()).unwrap(), v);
    }

    #[test]
    fn u_escape() {
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::String("Aé".into())
        );
    }

    #[test]
    fn error_has_position() {
        match Json::parse("{\n  \"a\": oops}") {
            Err(SpinError::Json { line, .. }) => assert_eq!(line, 2),
            other => panic!("expected Json error, got {other:?}"),
        }
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn numbers_render_as_integers_when_integral() {
        assert_eq!(Json::Number(64.0).compact(), "64");
        assert_eq!(Json::Number(0.5).compact(), "0.5");
    }

    #[test]
    fn req_reports_missing_key() {
        let v = Json::parse("{}").unwrap();
        assert!(v.req("nope").is_err());
    }

    #[test]
    fn check_known_keys_names_the_offender() {
        let v = Json::parse(r#"{"n": 4, "blocksize": 2}"#).unwrap();
        let err = v.check_known_keys("matrix", &["n", "block_size"]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("`blocksize`"), "{msg}");
        assert!(msg.contains("block_size"), "{msg}");
        v.check_known_keys("matrix", &["n", "blocksize"]).unwrap();
        // Non-objects pass: their shape errors surface elsewhere.
        Json::Number(1.0).check_known_keys("x", &[]).unwrap();
    }

    #[test]
    fn property_round_trip_random_values() {
        use crate::util::check::forall;
        fn gen_json(r: &mut crate::util::Rng, depth: usize) -> Json {
            match if depth > 2 { r.next_usize(4) } else { r.next_usize(6) } {
                0 => Json::Null,
                1 => Json::Bool(r.next_f64() < 0.5),
                2 => Json::Number((r.uniform(-1e6, 1e6) * 8.0).round() / 8.0),
                3 => Json::String(format!("s{}", r.next_u64() % 1000)),
                4 => Json::Array((0..r.next_usize(4)).map(|_| gen_json(r, depth + 1)).collect()),
                _ => Json::Object(
                    (0..r.next_usize(4))
                        .map(|i| (format!("k{i}"), gen_json(r, depth + 1)))
                        .collect(),
                ),
            }
        }
        forall(
            "json round-trip",
            0xDEAD,
            64,
            |r| gen_json(r, 0),
            |v| {
                let back = Json::parse(&v.pretty()).map_err(|e| e.to_string())?;
                if &back == v {
                    Ok(())
                } else {
                    Err(format!("{back:?} != {v:?}"))
                }
            },
        );
    }
}
