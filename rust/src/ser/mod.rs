//! Serialization substrates: a hand-rolled JSON parser/writer (the offline
//! vendor set has no `serde`) and the binary on-disk matrix format that
//! stands in for the paper's HDFS block storage.

pub mod bin;
pub mod json;

pub use json::Json;
