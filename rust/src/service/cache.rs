//! [`PlanCache`]: service-owned structural interning of [`MatExpr`]
//! subtrees, so concurrent jobs over the same data share plan **nodes** —
//! and therefore, through the executor's per-node memoization and the
//! exactly-once slot locking, share materialized **results**.
//!
//! ## The cross-job cache key
//!
//! Interning is keyed structurally, bottom-up:
//!
//! * a source is keyed by its [`MatrixSpec`] parameters
//!   `(n, block_size, seed, generator)` — generation is
//!   seed-deterministic, so equal keys denote bit-identical matrices;
//! * an operator node is keyed by `(op, child node ids…, params)` —
//!   children are interned first, so id equality is value equality.
//!
//! Two jobs that both need `invert[spin](A)` therefore hold the *same*
//! `Arc`'d plan node: whichever job materializes first pays, the other
//! reuses.
//!
//! Retention is bounded by live jobs: the cache holds only **weak**
//! references, so when the last handle to a plan drops, its nodes — and
//! the source payloads inside them — free naturally and the dead entry
//! is purged on the next lookup. (Value residency of *materialized*
//! intermediates is governed separately by the session's
//! [`crate::plan::CacheManager`] LRU budget.) Source generation runs
//! **outside** the cache lock — a tenant submitting a huge matrix must
//! not stall every other tenant's submit — with a re-check on insert so
//! two racing submitters of the same spec still converge on one node.

use std::collections::HashMap;
use std::sync::{Mutex, Weak};

use crate::blockmatrix::BlockMatrix;
use crate::error::Result;
use crate::plan::{ExprNode, MatExpr};

use super::spec::MatrixSpec;

/// Structural identity of an interned node.
#[derive(Debug, Clone, Hash, PartialEq, Eq)]
enum PlanKey {
    Source {
        n: usize,
        block_size: usize,
        seed: u64,
        generator: &'static str,
    },
    Invert {
        algo: String,
        child: u64,
    },
    Multiply {
        a: u64,
        b: u64,
    },
    Transpose {
        x: u64,
    },
}

/// Hit/miss/size counters for reports and tests. `entries` counts only
/// entries whose plans are still alive.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PlanCacheStats {
    pub hits: u64,
    pub misses: u64,
    pub entries: usize,
}

#[derive(Default)]
struct Inner {
    map: HashMap<PlanKey, Weak<ExprNode>>,
    hits: u64,
    misses: u64,
}

/// Thread-safe interner of job plan subtrees (see module docs).
#[derive(Default)]
pub struct PlanCache {
    inner: Mutex<Inner>,
}

impl PlanCache {
    pub fn new() -> Self {
        PlanCache::default()
    }

    fn intern(
        &self,
        key: PlanKey,
        build: impl FnOnce() -> Result<MatExpr>,
    ) -> Result<MatExpr> {
        {
            let mut inner = self.inner.lock().unwrap();
            if let Some(hit) = inner.map.get(&key).and_then(MatExpr::upgrade) {
                inner.hits += 1;
                return Ok(hit);
            }
        }
        // Build with the lock RELEASED: source generation materializes a
        // whole matrix, and one tenant's big input must not stall every
        // other tenant's submit.
        let candidate = build()?;
        let mut inner = self.inner.lock().unwrap();
        if let Some(hit) = inner.map.get(&key).and_then(MatExpr::upgrade) {
            // Raced with another submitter: adopt the winner's node so
            // both jobs share one plan (our duplicate generation is
            // discarded; the data is seed-deterministic either way).
            inner.hits += 1;
            return Ok(hit);
        }
        // Dead entries (all referencing jobs finished and dropped their
        // handles) are purged here, keeping retention bounded by live
        // plans. Operator keys over dead child ids can never hit again —
        // a rebuilt child gets a fresh node id.
        inner.map.retain(|_, node| node.strong_count() > 0);
        inner.misses += 1;
        inner.map.insert(key, MatExpr::downgrade(&candidate));
        Ok(candidate)
    }

    /// The interned plan leaf for a described matrix (generates the
    /// blocks on first use).
    pub fn source(&self, spec: &MatrixSpec) -> Result<MatExpr> {
        self.intern(
            PlanKey::Source {
                n: spec.n,
                block_size: spec.block_size,
                seed: spec.seed,
                generator: spec.generator.name(),
            },
            || Ok(MatExpr::source(BlockMatrix::random(&spec.to_job())?)),
        )
    }

    /// Interned `child⁻¹` through the named scheme.
    pub fn invert(&self, child: &MatExpr, algo: &str) -> Result<MatExpr> {
        self.intern(
            PlanKey::Invert {
                algo: algo.to_string(),
                child: child.id(),
            },
            || Ok(child.invert(algo)),
        )
    }

    /// Interned `a·b`.
    pub fn multiply(&self, a: &MatExpr, b: &MatExpr) -> Result<MatExpr> {
        self.intern(
            PlanKey::Multiply {
                a: a.id(),
                b: b.id(),
            },
            || a.multiply(b),
        )
    }

    /// Interned `xᵀ`.
    pub fn transpose(&self, x: &MatExpr) -> Result<MatExpr> {
        self.intern(PlanKey::Transpose { x: x.id() }, || Ok(x.transpose()))
    }

    pub fn stats(&self) -> PlanCacheStats {
        let mut inner = self.inner.lock().unwrap();
        inner.map.retain(|_, node| node.strong_count() > 0);
        PlanCacheStats {
            hits: inner.hits,
            misses: inner.misses,
            entries: inner.map.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn equal_specs_intern_to_one_source() {
        let cache = PlanCache::new();
        let spec = MatrixSpec::new(16, 4).seeded(3);
        let a = cache.source(&spec).unwrap();
        let b = cache.source(&MatrixSpec::new(16, 4).seeded(3)).unwrap();
        assert_eq!(a.id(), b.id(), "same spec must share one node");
        // A different seed is a different matrix.
        let c = cache.source(&spec.clone().seeded(4)).unwrap();
        assert_ne!(a.id(), c.id());
        let stats = cache.stats();
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.misses, 2);
        assert_eq!(stats.entries, 2);
    }

    #[test]
    fn operators_intern_structurally() {
        let cache = PlanCache::new();
        let a = cache.source(&MatrixSpec::new(16, 4).seeded(1)).unwrap();
        let b = cache.source(&MatrixSpec::new(16, 4).seeded(2)).unwrap();
        let inv1 = cache.invert(&a, "spin").unwrap();
        let inv2 = cache.invert(&a, "spin").unwrap();
        assert_eq!(inv1.id(), inv2.id());
        assert_ne!(cache.invert(&a, "lu").unwrap().id(), inv1.id());
        let m1 = cache.multiply(&inv1, &b).unwrap();
        let m2 = cache.multiply(&inv2, &b).unwrap();
        assert_eq!(m1.id(), m2.id(), "solve tails built twice share");
        // Operand order matters.
        assert_ne!(cache.multiply(&b, &inv1).unwrap().id(), m1.id());
        let t1 = cache.transpose(&a).unwrap();
        let t2 = cache.transpose(&a).unwrap();
        assert_eq!(t1.id(), t2.id());
    }

    #[test]
    fn grid_mismatch_surfaces_from_constructor() {
        let cache = PlanCache::new();
        let a = cache.source(&MatrixSpec::new(16, 4)).unwrap();
        let b = cache.source(&MatrixSpec::new(16, 8)).unwrap();
        assert!(cache.multiply(&a, &b).is_err());
    }

    #[test]
    fn dead_plans_are_released_not_pinned() {
        let cache = PlanCache::new();
        let spec = MatrixSpec::new(16, 4).seeded(9);
        {
            let a = cache.source(&spec).unwrap();
            let _inv = cache.invert(&a, "spin").unwrap();
            assert_eq!(cache.stats().entries, 2);
        } // last handles drop: payloads free, entries purge
        assert_eq!(
            cache.stats().entries,
            0,
            "weak interning must not pin dead plans' payloads"
        );
        // A re-lookup regenerates: a fresh miss, a fresh node.
        let again = cache.source(&spec).unwrap();
        assert_eq!(cache.stats().misses, 3);
        assert_eq!(cache.stats().entries, 1);
        drop(again);
    }
}
